"""Availability gate for the Python test suite.

The Rust side gates its artifact-dependent integration tests on what
is actually present (``rust/tests/runtime_integration.rs`` skips —
loudly — when ``artifacts/`` is missing).  This conftest applies the
same policy here:

* if ``jax`` (or ``numpy``) cannot be imported, the whole suite is
  ignored at collection time — CI treats "nothing collected" as a
  skip, not a failure;
* tests marked ``needs_artifacts`` are skipped unless the AOT artifact
  directory (``artifacts/`` at the repo root, built by the compile
  pipeline) exists.
"""

import importlib.util
import pathlib

import pytest


def _importable(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is not None
    except (ImportError, ValueError):
        return False


_DEPS_OK = all(_importable(m) for m in ("jax", "numpy", "hypothesis"))

# Ignore every test module when the stack is absent: the modules
# import jax at top level, so letting collection proceed would turn a
# missing optional dependency into an error.
collect_ignore_glob = [] if _DEPS_OK else ["test_*.py"]

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "needs_artifacts: test executes AOT artifacts from artifacts/",
    )
    if not _DEPS_OK:
        print(
            "SKIP: jax/numpy/hypothesis unavailable — python tests "
            "gated off"
        )


def pytest_collection_modifyitems(config, items):
    if ARTIFACTS.exists():
        return
    skip = pytest.mark.skip(
        reason="artifacts/ missing — run `make artifacts` (same gate as "
        "rust/tests/runtime_integration.rs)"
    )
    for item in items:
        if "needs_artifacts" in item.keywords:
            item.add_marker(skip)
