"""Cross-language golden vector: the SAME input and expected symbols are
asserted by the Rust quantizer test
(`rust/src/formats/quantizer.rs::matches_python_golden_vector`) and by
the PJRT parity integration test.  If any of the three implementations
(jnp ref, Pallas kernel, Rust) drifts, exactly one side of this pin
moves and the suite catches it.
"""

import jax.numpy as jnp
import numpy as np

from compile.kernels import quantize, ref

RAMP = np.array([[(i - 15.5) / 4.0 for i in range(32)]], np.float32)

GOLDEN_SYMBOLS = [
    255, 254, 253, 252, 251, 250, 249, 248, 247, 245, 243, 241, 238, 234,
    228, 215, 87, 100, 106, 110, 113, 115, 117, 119, 120, 121, 122, 123,
    124, 125, 126, 127,
]
GOLDEN_SCALE = 0.008072917349636555  # 3.875 * fl(1/480)


class TestGoldenVector:
    def test_ref_matches_golden(self):
        s, sc = ref.quantize_blocks_ref(jnp.asarray(RAMP))
        assert list(np.asarray(s)[0]) == GOLDEN_SYMBOLS
        assert float(sc[0]) == GOLDEN_SCALE

    def test_kernel_matches_golden(self):
        s, sc = quantize.quantize_blocks(jnp.asarray(RAMP))
        assert list(np.asarray(s)[0]) == GOLDEN_SYMBOLS
        assert float(sc[0]) == GOLDEN_SCALE

    def test_symmetry_structure(self):
        # The ramp is antisymmetric: element i and 31-i mirror in
        # magnitude but the quantizer is sign-magnitude, so symbol
        # pairs differ exactly by the sign bit where magnitudes match.
        s = GOLDEN_SYMBOLS
        assert s[0] == 0xFF and s[31] == 0x7F  # ±absmax → top codes
        for i in range(13):  # exact mirror region
            assert s[i] ^ 0x80 == s[31 - i], i
