"""AOT artifact tests: HLO text validity, determinism, manifest schema."""

import json

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def ffn_hlo():
    return aot.lower_ffn_step()


@pytest.fixture(scope="module")
def quant_hlo():
    return aot.lower_quantize()


class TestHloText:
    def test_ffn_is_hlo_text(self, ffn_hlo):
        assert ffn_hlo.startswith("HloModule")
        assert "ENTRY" in ffn_hlo

    def test_quantize_is_hlo_text(self, quant_hlo):
        assert quant_hlo.startswith("HloModule")

    def test_no_mosaic_custom_calls(self, ffn_hlo, quant_hlo):
        # interpret=True must lower Pallas into plain HLO; a Mosaic
        # custom-call would be unrunnable on the CPU PJRT client.
        for text in (ffn_hlo, quant_hlo):
            assert "tpu_custom_call" not in text
            assert "mosaic" not in text.lower()

    def test_ffn_entry_signature(self, ffn_hlo):
        # 5 f32 parameters; tuple of 16 outputs (8 × symbols+scales).
        layout = [l for l in ffn_hlo.splitlines()
                  if "entry_computation_layout" in l][0]
        assert layout.count("f32[") >= 5 + 8  # 5 params + 8 scale outputs
        assert layout.count("u8[") == 8       # 8 symbol outputs

    def test_deterministic_lowering(self, ffn_hlo):
        assert aot.lower_ffn_step() == ffn_hlo

    def test_no_elided_constants(self, ffn_hlo, quant_hlo):
        # The default HLO printer elides large literals as "{...}",
        # which the xla_extension 0.5.1 text parser silently reads back
        # as zeros — destroying the e4m3 boundary table (this bit us;
        # see aot.to_hlo_text).
        for text in (ffn_hlo, quant_hlo):
            assert "{...}" not in text


class TestManifest:
    def test_schema(self):
        man = aot.build_manifest()
        assert set(man) == {"ffn_step", "quantize"}
        ffn = man["ffn_step"]
        assert [i["name"] for i in ffn["inputs"]] == \
            ["x", "wg", "wu", "w2", "dy"]
        assert [o["name"] for o in ffn["outputs"]] == list(model.TENSOR_NAMES)
        for o in ffn["outputs"]:
            blocks, width = o["symbols_shape"]
            assert width == 32
            assert o["scales_shape"] == [blocks]

    def test_json_serializable(self):
        json.dumps(aot.build_manifest())

    def test_block_math(self):
        man = aot.build_manifest()
        for o in man["ffn_step"]["outputs"]:
            if o["name"] == "ffn1_act":
                assert o["symbols_shape"] == \
                    [model.N_TOKENS * model.D_FF // 32, 32]
