"""Golden-value and property tests for the shared e4m3 tables.

The Rust unit tests in ``rust/src/formats/e4m3.rs`` assert the same
golden values — keep the two lists in sync.
"""

import numpy as np
import pytest

from compile.kernels import e4m3


class TestMagnitudeTable:
    def test_length(self):
        assert e4m3.magnitude_table().shape == (128,)

    def test_zero(self):
        assert e4m3.magnitude_table()[0] == 0.0

    def test_min_subnormal(self):
        # 2^-9
        assert e4m3.magnitude_table()[1] == pytest.approx(0.001953125)

    def test_max_subnormal(self):
        # 7 * 2^-9
        assert e4m3.magnitude_table()[7] == pytest.approx(7 * 2.0**-9)

    def test_min_normal(self):
        # 1.0 * 2^-6
        assert e4m3.magnitude_table()[8] == pytest.approx(2.0**-6)

    def test_one(self):
        # exp field 7 (bias 7), mantissa 0 → 1.0 at index 0b0111_000
        assert e4m3.magnitude_table()[0x38] == 1.0

    def test_max_exmy(self):
        assert e4m3.max_finite(e4m3.EXMY) == 480.0

    def test_max_ocp(self):
        assert e4m3.max_finite(e4m3.OCP) == 448.0

    def test_ocp_nan_slot(self):
        assert np.isinf(e4m3.magnitude_table(e4m3.OCP)[127])

    def test_strictly_increasing(self):
        t = e4m3.magnitude_table()
        assert (np.diff(t) > 0).all()

    def test_subnormal_spacing_uniform(self):
        t = e4m3.magnitude_table()
        steps = np.diff(t[:9])  # subnormals + first normal
        assert np.allclose(steps, 2.0**-9)

    def test_golden_spot_values(self):
        t = e4m3.magnitude_table()
        # (index, value) pairs mirrored in the Rust tests.
        golden = [
            (0x08, 0.015625),   # 2^-6
            (0x0F, 0.029296875),  # 1.875 * 2^-6
            (0x30, 0.5),
            (0x3C, 1.5),
            (0x40, 2.0),
            (0x7F, 480.0),
        ]
        for idx, val in golden:
            assert t[idx] == pytest.approx(val), hex(idx)


class TestBoundaries:
    def test_count(self):
        assert e4m3.decision_boundaries(e4m3.EXMY).shape == (127,)
        assert e4m3.decision_boundaries(e4m3.OCP).shape == (126,)

    def test_interleaving(self):
        t = e4m3.magnitude_table()
        b = e4m3.decision_boundaries()
        assert ((t[:-1] < b) & (b < t[1:])).all()

    def test_first_boundary(self):
        # midpoint of 0 and 2^-9
        assert e4m3.decision_boundaries()[0] == pytest.approx(2.0**-10)


class TestValueTable:
    def test_length(self):
        assert e4m3.value_table().shape == (256,)

    def test_negative_mirror(self):
        v = e4m3.value_table()
        assert (v[128:] == -v[:128]).all()

    def test_negative_zero(self):
        v = e4m3.value_table()
        assert v[128] == 0.0 and np.signbit(v[128])

    def test_ocp_nans(self):
        v = e4m3.value_table(e4m3.OCP)
        assert np.isnan(v[127]) and np.isnan(v[255])
        assert np.isfinite(np.delete(v, [127, 255])).all()

    def test_unknown_variant_raises(self):
        with pytest.raises(ValueError):
            e4m3.magnitude_table("e5m2")
