"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

The kernel must be *bit-identical* to ``ref.py`` (symbols and scales):
the Rust formats::BlockQuantizer mirrors the same rule, and any drift
between the three implementations silently corrupts every compression
measurement downstream.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import e4m3, quantize, ref


def _assert_match(x, variant=e4m3.EXMY):
    s_ref, sc_ref = ref.quantize_blocks_ref(x, variant)
    s_ker, sc_ker = quantize.quantize_blocks(x, variant)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_ker))
    np.testing.assert_array_equal(np.asarray(sc_ref), np.asarray(sc_ker))
    return np.asarray(s_ref), np.asarray(sc_ref)


class TestKernelMatchesRef:
    @pytest.mark.parametrize("dist", ["normal", "laplace", "uniform"])
    @pytest.mark.parametrize("blocks", [1, 7, 64, 256])
    def test_distributions(self, dist, blocks):
        rng = np.random.default_rng(hash((dist, blocks)) % 2**31)
        x = jnp.asarray(getattr(rng, dist)(size=(blocks, 32)).astype(np.float32))
        _assert_match(x)

    def test_ocp_variant(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
        s_ref, _ = ref.quantize_blocks_ref(x, e4m3.OCP)
        s_ker, _ = quantize.quantize_blocks(x, e4m3.OCP)
        np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_ker))
        # OCP NaN codes (0x7F / 0xFF) must never be emitted.
        assert not np.isin(np.asarray(s_ref) & 0x7F, [0x7F]).any()

    def test_all_zero_block(self):
        x = jnp.zeros((4, 32), jnp.float32)
        s, sc = _assert_match(x)
        assert (s == 0).all()
        assert (sc == 1.0).all()

    def test_single_nonzero(self):
        x = jnp.zeros((1, 32), jnp.float32).at[0, 5].set(-3.25)
        s, sc = _assert_match(x)
        assert s[0, 5] == 0x80 | 0x7F  # absmax element → top code, negative
        assert sc[0] == np.float32(3.25) * np.float32(1.0 / 480.0)

    def test_extreme_magnitudes(self):
        # Huge dynamic range within a block: small values must flush to 0.
        x = jnp.asarray(
            np.array([[1e30] + [1e20] * 3 + [1e-10] * 28], np.float32))
        s, _ = _assert_match(x)
        assert s[0, 0] == 0x7F
        assert (s[0, 4:] == 0).all()

    def test_tiny_values(self):
        x = jnp.asarray(
            np.full((2, 32), 1e-38, np.float32))  # near f32 subnormal
        _assert_match(x)

    def test_row_block_variants(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32))
        base, _ = ref.quantize_blocks_ref(x)
        for rb in (1, 2, 32, 64, 128):
            s, _ = quantize.quantize_blocks(x, row_block=rb)
            np.testing.assert_array_equal(np.asarray(base), np.asarray(s))

    @settings(max_examples=40, deadline=None)
    @given(
        blocks=st.integers(1, 48),
        seed=st.integers(0, 2**31 - 1),
        scale_exp=st.integers(-30, 30),
        dist=st.sampled_from(["normal", "laplace", "uniform", "lognormal"]),
    )
    def test_hypothesis_sweep(self, blocks, seed, scale_exp, dist):
        rng = np.random.default_rng(seed)
        x = getattr(rng, dist)(size=(blocks, 32)).astype(np.float32)
        x *= np.float32(2.0**scale_exp)
        _assert_match(jnp.asarray(x))

    @settings(max_examples=20, deadline=None)
    @given(data=st.lists(
        st.floats(-1e4, 1e4, allow_nan=False, width=32),
        min_size=32, max_size=32))
    def test_hypothesis_adversarial_floats(self, data):
        x = jnp.asarray(np.array([data], np.float32))
        _assert_match(x)

    def test_exact_tie_goes_even(self):
        # Construct a block whose scaled magnitude hits a boundary
        # exactly: absmax element maps to 480; choose a second value v
        # so that v/scale is exactly the first boundary 2^-10.
        absmax = np.float32(480.0)  # scale becomes exactly 1.0*(1/480)*480
        scale = absmax * np.float32(1.0 / 480.0)
        v = np.float32(2.0**-10) * scale
        x = np.zeros((1, 32), np.float32)
        x[0, 0] = absmax
        x[0, 1] = v
        s, _ = _assert_match(jnp.asarray(x))
        assert s[0, 1] == 0  # tie between idx 0 and 1 → even (0)


class TestDequantize:
    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(11)
        x = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32))
        s, sc = ref.quantize_blocks_ref(x)
        xq = ref.dequantize_blocks_ref(s, sc)
        # Relative step between consecutive e4m3 normals ≤ 2^-3; nearest
        # rounding halves it.  Subnormal region: absolute step bound.
        err = np.abs(np.asarray(xq - x))
        tol = np.maximum(np.abs(np.asarray(x)) * 2.0**-4,
                         np.asarray(sc)[:, None] * 2.0**-10 * 1.001)
        assert (err <= tol).all()

    def test_grid_fixpoint(self):
        # Quantizing already-quantized data is the identity.
        rng = np.random.default_rng(12)
        x = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
        s1, sc1 = ref.quantize_blocks_ref(x)
        xq = ref.dequantize_blocks_ref(s1, sc1)
        s2, sc2 = ref.quantize_blocks_ref(xq)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


class TestVmemEstimate:
    def test_fits_vmem(self):
        # DESIGN.md §Perf: the default tile must fit comfortably in a
        # 16 MiB TPU VMEM (we budget < 1 MiB to leave room for
        # double-buffering).
        assert quantize.vmem_footprint_bytes(128) < 1 << 20

    def test_monotone_in_row_block(self):
        assert (quantize.vmem_footprint_bytes(256)
                > quantize.vmem_footprint_bytes(64))
