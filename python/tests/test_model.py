"""L2 model tests: shapes, gradients, harvested-tensor statistics."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import e4m3


def _make_inputs(seed=1, gate_gain=2.5):
    """Realistic inputs (see DESIGN.md §2): heavy-tailed tokens and a
    saturating gate projection, emulating trained-LLM statistics."""
    rng = np.random.default_rng(seed)
    tok = rng.lognormal(0.0, 0.5, size=(model.N_TOKENS, 1)).astype(np.float32)
    x = rng.normal(size=(model.N_TOKENS, model.D_MODEL)).astype(np.float32) * tok
    wg = (rng.normal(size=(model.D_MODEL, model.D_FF))
          * gate_gain / math.sqrt(model.D_MODEL)).astype(np.float32)
    wu = (rng.normal(size=(model.D_MODEL, model.D_FF))
          / math.sqrt(model.D_MODEL)).astype(np.float32)
    w2 = (rng.normal(size=(model.D_FF, model.D_MODEL))
          / math.sqrt(model.D_FF)).astype(np.float32)
    dy = rng.normal(size=(model.N_TOKENS, model.D_MODEL)).astype(np.float32)
    return tuple(jnp.asarray(a) for a in (x, wg, wu, w2, dy))


@pytest.fixture(scope="module")
def step_outputs():
    return model.ffn_step(*_make_inputs())


class TestShapes:
    def test_output_count(self, step_outputs):
        assert len(step_outputs) == 2 * len(model.TENSOR_NAMES)

    def test_manifest_matches_outputs(self, step_outputs):
        man = model.output_manifest()
        for i, entry in enumerate(man):
            syms, scales = step_outputs[2 * i], step_outputs[2 * i + 1]
            assert list(syms.shape) == entry["symbols_shape"], entry["name"]
            assert list(scales.shape) == entry["scales_shape"], entry["name"]
            assert syms.dtype == jnp.uint8
            assert scales.dtype == jnp.float32

    def test_input_specs_cover_ffn_step(self):
        specs = model.input_specs()
        assert len(specs) == 5
        assert specs[0].shape == (model.N_TOKENS, model.D_MODEL)


class TestBackwardCorrectness:
    def test_manual_backward_matches_autodiff(self):
        x, wg, wu, w2, dy = _make_inputs(seed=5)

        def loss(wg, wu, w2):
            y, _ = model.ffn_forward(x, wg, wu, w2)
            return jnp.vdot(y, dy)

        g_auto = jax.grad(loss, argnums=(0, 1, 2))(wg, wu, w2)
        y, saved = model.ffn_forward(x, wg, wu, w2)
        _, dwg, dwu, dw2, _, _ = model.ffn_backward(x, wg, wu, w2, dy, saved)
        for a, b in zip(g_auto, (dwg, dwu, dw2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)

    def test_dx_matches_autodiff(self):
        x, wg, wu, w2, dy = _make_inputs(seed=6)

        def loss(x):
            y, _ = model.ffn_forward(x, wg, wu, w2)
            return jnp.vdot(y, dy)

        dx_auto = jax.grad(loss)(x)
        _, saved = model.ffn_forward(x, wg, wu, w2)
        dx, *_ = model.ffn_backward(x, wg, wu, w2, dy, saved)
        np.testing.assert_allclose(np.asarray(dx_auto), np.asarray(dx),
                                   rtol=1e-4, atol=1e-4)


class TestHarvestedStatistics:
    """The paper's qualitative observations must hold on our substitute
    data (DESIGN.md §2): FFN1 activations smooth, FFN2 activations
    zero-spiked with lower-entropy-potential."""

    @staticmethod
    def _pmf(symbols):
        s = np.asarray(symbols).ravel()
        return np.bincount(s, minlength=256) / s.size

    def test_ffn2_act_zero_spike(self, step_outputs):
        i = model.TENSOR_NAMES.index("ffn2_act")
        p = self._pmf(step_outputs[2 * i])
        assert p[0] > 0.05, "bf16 GELU saturation must produce a 0 spike"
        assert p[0] == p.max()

    def test_ffn1_act_no_zero_spike(self, step_outputs):
        i = model.TENSOR_NAMES.index("ffn1_act")
        p = self._pmf(step_outputs[2 * i])
        assert p[0] < 0.01

    def test_entropy_ranges(self, step_outputs):
        for i, name in enumerate(model.TENSOR_NAMES):
            p = self._pmf(step_outputs[2 * i])
            ent = -(p[p > 0] * np.log2(p[p > 0])).sum()
            assert 4.0 < ent < 7.9, (name, ent)

    def test_gelu_bf16_emits_exact_zeros(self):
        t = jnp.linspace(-8.0, -4.0, 64)
        out = np.asarray(model._gelu_bf16(t))
        assert (out == 0.0).any()


class TestQuantizeOp:
    def test_shapes(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(model.QUANT_BLOCKS, 32))
                        .astype(np.float32))
        syms, scales = model.quantize_op(x)
        assert syms.shape == (model.QUANT_BLOCKS, 32)
        assert scales.shape == (model.QUANT_BLOCKS,)

    def test_matches_ref(self):
        from compile.kernels import ref
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(model.QUANT_BLOCKS, 32))
                        .astype(np.float32))
        s1, _ = model.quantize_op(x)
        s2, _ = ref.quantize_blocks_ref(x)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
