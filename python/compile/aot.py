"""AOT compile path: lower the L2 model (and the standalone quantizer)
to HLO **text** artifacts that the Rust runtime loads via the `xla`
crate's PJRT CPU client.

HLO text — NOT ``lowered.compile().serialize()`` — is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids
which xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/load_hlo/ and its README.

Usage (from ``python/``):  ``python -m compile.aot --out ../artifacts``
(a single ``--out path/model.hlo.txt`` is also accepted for Makefile
compatibility — the directory of that path is used).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big literals as ``{...}``, which the xla_extension 0.5.1 text
    parser silently reads back as zeros — the e4m3 boundary table inside
    the Pallas quantizer would be destroyed.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)  # positional: print_large_constants


def lower_ffn_step() -> str:
    return to_hlo_text(jax.jit(model.ffn_step).lower(*model.input_specs()))


def lower_quantize() -> str:
    return to_hlo_text(
        jax.jit(model.quantize_op).lower(*model.quantize_input_specs())
    )


def build_manifest() -> dict:
    return {
        "ffn_step": {
            "hlo": "ffn_step.hlo.txt",
            "inputs": [
                {"name": "x", "shape": [model.N_TOKENS, model.D_MODEL]},
                {"name": "wg", "shape": [model.D_MODEL, model.D_FF]},
                {"name": "wu", "shape": [model.D_MODEL, model.D_FF]},
                {"name": "w2", "shape": [model.D_FF, model.D_MODEL]},
                {"name": "dy", "shape": [model.N_TOKENS, model.D_MODEL]},
            ],
            "outputs": model.output_manifest(),
        },
        "quantize": {
            "hlo": "quantize.hlo.txt",
            "inputs": [{"name": "x", "shape": [model.QUANT_BLOCKS, 32]}],
            "outputs": [
                {
                    "name": "data",
                    "symbols_shape": [model.QUANT_BLOCKS, 32],
                    "scales_shape": [model.QUANT_BLOCKS],
                }
            ],
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory (or any path inside it)")
    args = ap.parse_args()

    out_dir = args.out
    if out_dir.endswith(".txt") or out_dir.endswith(".json"):
        out_dir = os.path.dirname(out_dir) or "."
    os.makedirs(out_dir, exist_ok=True)

    for name, text in (
        ("ffn_step.hlo.txt", lower_ffn_step()),
        ("quantize.hlo.txt", lower_quantize()),
    ):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars  {path}")

    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(build_manifest(), f, indent=2)
    print(f"wrote manifest        {manifest_path}")

    # Makefile tracks artifacts/model.hlo.txt as the stamp target; keep a
    # copy under that name so `make -q artifacts` stays accurate.
    stamp = os.path.join(out_dir, "model.hlo.txt")
    with open(os.path.join(out_dir, "ffn_step.hlo.txt")) as f:
        text = f.read()
    with open(stamp, "w") as f:
        f.write(text)
    print(f"stamped               {stamp}")


if __name__ == "__main__":
    main()
