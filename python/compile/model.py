"""Layer-2 JAX model: a Gemma-style GeGLU feed-forward block, forward
and backward, with every tensor the paper analyzes quantized to e4m3
symbol streams by the Layer-1 Pallas kernel.

Paper §3: the authors harvest FFN1/FFN2 weight, activation, weight-
gradient and activation-gradient tensors from Gemma 2B during SFT.  We
reproduce the same eight tensor *types* from one FFN block:

  index  name            tensor                       PMF character
  0      ffn1_act        gate = x @ wg                smooth, two-sided
  1      ffn2_act        h = gelu(gate) * up          zero-spiked (GeGLU)
  2      ffn1_weight     wg                           smooth
  3      ffn2_weight     w2                           smooth
  4      ffn1_wgrad      dL/dwg                       smooth
  5      ffn2_wgrad      dL/dw2                       smooth
  6      ffn1_agrad      dL/dgate                     zero-spiked
  7      ffn2_agrad      dL/dh                        smooth/spiked

"FFN1 activation" is the pre-nonlinearity projection output and "FFN2
activation" is the post-GeGLU input of the down projection — the paper
attributes FFN2's dominant zero symbol to "the intervening non-linear
activation function", which is exactly what GeGLU produces here.

This module is build-time only: ``aot.py`` lowers :func:`ffn_step` once
to HLO text and the Rust runtime executes it to generate real tensors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import quantize

# Artifact dimensions (kept modest: interpret-mode Pallas must run on
# the CPU PJRT client inside the Rust hot loop).
N_TOKENS = 256
D_MODEL = 256
D_FF = 512

TENSOR_NAMES = (
    "ffn1_act",
    "ffn2_act",
    "ffn1_weight",
    "ffn2_weight",
    "ffn1_wgrad",
    "ffn2_wgrad",
    "ffn1_agrad",
    "ffn2_agrad",
)


def _gelu_bf16(t):
    """GELU evaluated in bfloat16, as in real mixed-precision training.

    This matters for the paper's Fig. 4: in bf16 the tanh saturates to
    exactly -1 for sufficiently negative pre-activations, so GELU emits
    *exact zeros* — the source of the dominant zero symbol the paper
    observes in FFN2 activations ("due to the intervening non-linear
    activation function").  A pure-f32 GELU never reaches zero and would
    miss that spike entirely.
    """
    return jax.nn.gelu(t.astype(jnp.bfloat16)).astype(jnp.float32)


def ffn_forward(x, wg, wu, w2):
    """GeGLU FFN forward. Returns (y, (gate, up, h))."""
    gate = x @ wg
    up = x @ wu
    h = _gelu_bf16(gate) * up
    y = h @ w2
    return y, (gate, up, h)


def ffn_backward(x, wg, wu, w2, dy, saved):
    """Manual backward pass (keeps every intermediate we must harvest)."""
    gate, up, h = saved
    dh = dy @ w2.T
    dw2 = h.T @ dy

    def h_fn(gate, up):
        return _gelu_bf16(gate) * up

    _, h_vjp = jax.vjp(h_fn, gate, up)
    dgate, dup = h_vjp(dh)

    dwg = x.T @ dgate
    dwu = x.T @ dup
    dx = dgate @ wg.T + dup @ wu.T
    return dx, dwg, dwu, dw2, dgate, dh


def ffn_step(x, wg, wu, w2, dy):
    """One fwd+bwd step; every harvested tensor quantized to e4m3.

    Returns a flat tuple: for each name in :data:`TENSOR_NAMES`, two
    entries ``(symbols u8 (blocks, 32), scales f32 (blocks,))`` — 16
    outputs total.  The Rust runtime consumes this tuple positionally
    (see ``artifacts/manifest.json``).
    """
    y, saved = ffn_forward(x, wg, wu, w2)
    _, dwg, _, dw2, dgate, dh = ffn_backward(x, wg, wu, w2, dy, saved)
    gate, _, h = saved

    harvested = (gate, h, wg, w2, dwg, dw2, dgate, dh)
    outs = []
    for t in harvested:
        syms, scales = quantize.quantize_tensor(t)
        outs.append(syms)
        outs.append(scales)
    return tuple(outs)


def input_specs():
    """ShapeDtypeStructs for :func:`ffn_step`, in argument order."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((N_TOKENS, D_MODEL), f32),  # x
        jax.ShapeDtypeStruct((D_MODEL, D_FF), f32),      # wg
        jax.ShapeDtypeStruct((D_MODEL, D_FF), f32),      # wu
        jax.ShapeDtypeStruct((D_FF, D_MODEL), f32),      # w2
        jax.ShapeDtypeStruct((N_TOKENS, D_MODEL), f32),  # dy
    )


def output_manifest():
    """Names/shapes of the flat output tuple, for the Rust runtime."""
    shapes = {
        "ffn1_act": (N_TOKENS, D_FF),
        "ffn2_act": (N_TOKENS, D_FF),
        "ffn1_weight": (D_MODEL, D_FF),
        "ffn2_weight": (D_FF, D_MODEL),
        "ffn1_wgrad": (D_MODEL, D_FF),
        "ffn2_wgrad": (D_FF, D_MODEL),
        "ffn1_agrad": (N_TOKENS, D_FF),
        "ffn2_agrad": (N_TOKENS, D_FF),
    }
    outs = []
    for name in TENSOR_NAMES:
        shape = shapes[name]
        blocks = shape[0] * shape[1] // 32
        outs.append({
            "name": name,
            "symbols_shape": [blocks, 32],
            "scales_shape": [blocks],
        })
    return outs


# ---------------------------------------------------------------------------
# Standalone quantizer artifact: Rust feeds arbitrary (QUANT_BLOCKS, 32)
# f32 data and gets symbol streams back without re-lowering the model.
QUANT_BLOCKS = 8192


def quantize_op(x):
    """(QUANT_BLOCKS, 32) f32 → (symbols, scales)."""
    return quantize.quantize_blocks(x)


def quantize_input_specs():
    return (jax.ShapeDtypeStruct((QUANT_BLOCKS, 32), jnp.float32),)
