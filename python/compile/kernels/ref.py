"""Pure-jnp oracle for block-scaled e4m3 quantization.

This is the CORE correctness signal: the Pallas kernel in
``quantize.py`` must produce bit-identical symbols, and the Rust
``formats::BlockQuantizer`` mirrors the same decision-boundary rule.

Quantization rule (paper §3: "quantization block size is 32"):

1. split the flat tensor into blocks of 32 contiguous elements;
2. ``scale = absmax(block) / MAX_FINITE`` (1.0 if the block is all
   zeros, so zeros encode as symbol 0);
3. each element's magnitude ``|x| / scale`` is mapped to the nearest
   e4m3 magnitude via the shared decision boundaries (ties to the even
   index), clamped to the top code;
4. symbol byte = ``sign << 7 | magnitude_index``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import e4m3


def _tables(variant: str):
    bounds = jnp.asarray(e4m3.decision_boundaries(variant), dtype=jnp.float32)
    maxf = jnp.float32(e4m3.max_finite(variant))
    return bounds, maxf


def quantize_blocks_ref(x: jnp.ndarray, variant: str = e4m3.EXMY):
    """Quantize ``x`` of shape (num_blocks, 32) → (symbols u8, scales f32).

    ``symbols`` has the same shape as ``x``; ``scales`` has shape
    (num_blocks,).
    """
    assert x.ndim == 2 and x.shape[1] == e4m3.BLOCK, x.shape
    bounds, maxf = _tables(variant)
    x = x.astype(jnp.float32)

    absmax = jnp.max(jnp.abs(x), axis=1)
    # Explicit reciprocal-multiply: XLA rewrites division-by-constant as
    # a multiply, interpret/numpy does not — writing the multiply keeps
    # ref, kernel and the Rust quantizer bit-identical.
    scale = jnp.where(absmax > 0, absmax * (jnp.float32(1.0) / maxf),
                      jnp.float32(1.0))
    mag = jnp.abs(x) / scale[:, None]
    mag = jnp.minimum(mag, maxf)

    # idx = #{b : mag > b}; tie (mag == b_i) → even index (i or i+1).
    gt = (mag[:, :, None] > bounds[None, None, :]).sum(axis=-1)
    eq = (mag[:, :, None] == bounds[None, None, :]).any(axis=-1)
    idx = jnp.where(eq & (gt % 2 == 1), gt + 1, gt)

    sign = (x < 0).astype(jnp.uint8)
    symbols = (sign << 7) | idx.astype(jnp.uint8)
    return symbols, scale


def dequantize_blocks_ref(symbols: jnp.ndarray, scales: jnp.ndarray,
                          variant: str = e4m3.EXMY) -> jnp.ndarray:
    """Inverse of :func:`quantize_blocks_ref` (lossy: returns the e4m3
    grid values)."""
    table = jnp.asarray(
        np.nan_to_num(e4m3.value_table(variant)), dtype=jnp.float32
    )
    return table[symbols.astype(jnp.int32)] * scales[:, None]


def quantize_tensor_ref(x: jnp.ndarray, variant: str = e4m3.EXMY):
    """Flatten an arbitrary tensor to (N/32, 32) blocks and quantize.

    The caller must ensure ``x.size`` is a multiple of 32 (all model
    tensors in this repo are).
    """
    flat = x.reshape(-1, e4m3.BLOCK)
    return quantize_blocks_ref(flat, variant)
