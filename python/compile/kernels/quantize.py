"""Layer-1 Pallas kernel: block-scaled e4m3 quantization.

The compute hot-spot of the pipeline: turns f32 tensors into the
byte-symbol streams that the Quad Length / Huffman codecs compress.

TPU mapping (DESIGN.md §Hardware-Adaptation): each grid step stages a
``(row_block, 32)`` tile plus the 127-entry decision-boundary vector in
VMEM, performs the per-block absmax reduction and the broadcast
compare-count (the VMEM analogue of the paper's 256-entry LUT) on the
vector unit, and streams u8 symbols back to HBM.  Lowered with
``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import e4m3


def _pick_row_block(num_blocks: int, preferred: int = 128) -> int:
    """Largest power-of-two ≤ ``preferred`` dividing ``num_blocks``."""
    rb = preferred
    while rb > 1 and num_blocks % rb != 0:
        rb //= 2
    return max(rb, 1)


def _quantize_kernel(bounds_ref, x_ref, syms_ref, scales_ref, *, maxf):
    x = x_ref[...]  # (R, 32) f32 tile in VMEM
    bounds = bounds_ref[...]  # (num_bounds,) f32 in VMEM

    absmax = jnp.max(jnp.abs(x), axis=1)
    # Reciprocal-multiply, bit-identical to ref.py and formats::e4m3.rs.
    scale = jnp.where(absmax > 0, absmax * (1.0 / maxf), jnp.float32(1.0))
    mag = jnp.minimum(jnp.abs(x) / scale[:, None], maxf)

    # Nearest e4m3 magnitude: count boundaries strictly below, resolve
    # exact ties to the even index (same rule as ref.py / Rust).
    gt = (mag[:, :, None] > bounds[None, None, :]).sum(axis=-1)
    eq = (mag[:, :, None] == bounds[None, None, :]).any(axis=-1)
    idx = jnp.where(eq & (gt % 2 == 1), gt + 1, gt)

    sign = (x < 0).astype(jnp.uint8)
    syms_ref[...] = (sign << jnp.uint8(7)) | idx.astype(jnp.uint8)
    scales_ref[...] = scale


def quantize_blocks(x: jnp.ndarray, variant: str = e4m3.EXMY,
                    row_block: int | None = None):
    """Pallas quantizer over ``x`` of shape (num_blocks, 32).

    Returns ``(symbols u8 (num_blocks, 32), scales f32 (num_blocks,))``
    — bit-identical to :func:`ref.quantize_blocks_ref`.
    """
    assert x.ndim == 2 and x.shape[1] == e4m3.BLOCK, x.shape
    num_blocks = x.shape[0]
    rb = row_block or _pick_row_block(num_blocks)
    assert num_blocks % rb == 0, (num_blocks, rb)

    bounds = jnp.asarray(e4m3.decision_boundaries(variant), jnp.float32)
    nb = bounds.shape[0]
    maxf = float(e4m3.max_finite(variant))

    # maxf must stay a python float: Pallas kernels may not capture
    # traced array constants, but scalar literals are inlined fine.
    kernel = functools.partial(_quantize_kernel, maxf=maxf)
    return pl.pallas_call(
        kernel,
        grid=(num_blocks // rb,),
        in_specs=[
            pl.BlockSpec((nb,), lambda i: (0,)),  # boundaries: replicated
            pl.BlockSpec((rb, e4m3.BLOCK), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rb, e4m3.BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_blocks, e4m3.BLOCK), jnp.uint8),
            jax.ShapeDtypeStruct((num_blocks,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT: Mosaic custom-calls are not runnable
    )(bounds, x.astype(jnp.float32))


def quantize_tensor(x: jnp.ndarray, variant: str = e4m3.EXMY):
    """Flatten an arbitrary tensor into 32-wide blocks and quantize."""
    assert x.size % e4m3.BLOCK == 0, x.shape
    return quantize_blocks(x.reshape(-1, e4m3.BLOCK), variant)


def vmem_footprint_bytes(row_block: int = 128,
                         variant: str = e4m3.EXMY) -> int:
    """Static VMEM estimate per grid step (DESIGN.md §Perf, L1): input
    tile + boundary vector + u8 output tile + scale vector + the
    (R,32,B) compare intermediate the vector unit materializes."""
    nb = len(e4m3.decision_boundaries(variant))
    tile_in = row_block * e4m3.BLOCK * 4
    tile_out = row_block * e4m3.BLOCK * 1
    scales = row_block * 4
    compare = row_block * e4m3.BLOCK * nb // 8  # 1-bit lanes, packed
    return tile_in + tile_out + scales + nb * 4 + compare
