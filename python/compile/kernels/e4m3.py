"""e4m3 numeric-format tables shared by the Pallas kernel, the jnp
reference oracle, and the python tests.

Two variants are implemented:

* ``EXMY`` — the eXmY e4m3 used by the paper: all 256 encodings are
  finite.  Max magnitude = 1.875 * 2**8 = 480.
* ``OCP`` — the OCP MX e4m3: ``S.1111.111`` is NaN, max magnitude 448.
  (Only the finite table differs; the paper notes the 2 NaN encodings
  "will have minimal effect".)

Layout of a symbol byte: ``sign(1) | exponent(4) | mantissa(3)``, bias 7.
``exp == 0`` encodes subnormals ``m * 2**-9``; otherwise
``(1 + m/8) * 2**(exp-7)``.

The Rust implementation in ``rust/src/formats/e4m3.rs`` mirrors these
tables bit-for-bit; ``python/tests/test_e4m3.py`` asserts the golden
values that the Rust unit tests also assert.
"""

from __future__ import annotations

import numpy as np

SIGN_BIT = 0x80
EXP_BITS = 4
MAN_BITS = 3
BIAS = 7

EXMY = "exmy"
OCP = "ocp"


def magnitude_table(variant: str = EXMY) -> np.ndarray:
    """The 128 non-negative magnitudes, indexed by the low 7 bits.

    For the OCP variant index 127 (``1111.111``) is NaN; we return
    ``inf`` there so that the quantizer never selects it (boundaries
    computed from the finite prefix only).
    """
    mags = np.empty(128, dtype=np.float64)
    for i in range(128):
        e = i >> MAN_BITS
        m = i & ((1 << MAN_BITS) - 1)
        if e == 0:
            mags[i] = m * 2.0 ** (1 - BIAS - MAN_BITS)  # m * 2^-9
        else:
            mags[i] = (1.0 + m / 8.0) * 2.0 ** (e - BIAS)
    if variant == OCP:
        mags[127] = np.inf
    elif variant != EXMY:
        raise ValueError(f"unknown e4m3 variant: {variant!r}")
    return mags


def max_finite(variant: str = EXMY) -> float:
    """Largest finite magnitude: 480 for eXmY, 448 for OCP."""
    t = magnitude_table(variant)
    return float(t[np.isfinite(t)].max())


def decision_boundaries(variant: str = EXMY) -> np.ndarray:
    """Midpoints between consecutive finite magnitudes.

    ``idx(x) = #{b : x > b}`` with ties (x == b exactly) resolved to the
    even index — a deterministic stand-in for round-half-to-even that the
    jnp oracle, the Pallas kernel, and the Rust quantizer all share.
    Length 127 for eXmY (128 finite values), 126 for OCP.
    """
    mags = magnitude_table(variant)
    mags = mags[np.isfinite(mags)]
    return (mags[:-1] + mags[1:]) / 2.0


def value_table(variant: str = EXMY) -> np.ndarray:
    """All 256 symbol values (float64); OCP NaN slots are NaN.

    Index 0x80 is negative zero (-0.0).
    """
    mags = magnitude_table(variant)
    mags = np.where(np.isinf(mags), np.nan, mags)
    return np.concatenate([mags, -mags])


BLOCK = 32  # paper's quantization block size
