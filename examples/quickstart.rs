//! Quickstart: quantize a tensor to e4m3, fit a Quad Length Code to
//! its symbol distribution, compress, decompress, verify.
//!
//! Run: `cargo run --release --example quickstart`

use qlc::codecs::frame;
use qlc::codecs::qlc::{AreaScheme, QlcCodec};
use qlc::codecs::CodecRegistry;
use qlc::codecs::Codec;
use qlc::data::{TensorGen, TensorKind};
use qlc::formats::{BlockQuantizer, Variant};
use qlc::stats::Histogram;
use qlc::util::rng::Rng;

fn main() {
    // 1. A tensor with LLM-activation statistics (or bring your own).
    let gen = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY);
    let mut rng = Rng::new(42);
    let tensor: Vec<f32> = gen.generate(&mut rng, 1 << 20);

    // 2. Block-32 e4m3 quantization (the paper's §3 setting).
    let quant = BlockQuantizer::new(Variant::ExmY);
    let q = quant.quantize(&tensor);
    println!("quantized {} f32 -> {} e4m3 symbols + {} block scales",
             tensor.len(), q.symbols.len(), q.scales.len());

    // 3. Fit the paper's Table 1 scheme to the measured PMF.
    let hist = Histogram::from_symbols(&q.symbols);
    let pmf = hist.pmf();
    println!("symbol entropy: {:.3} bits (ideal compressibility {:.1}%)",
             pmf.entropy(), pmf.ideal_compressibility() * 100.0);
    let codec = QlcCodec::from_pmf(AreaScheme::table1(), &pmf);

    // 4. Compress.
    let encoded = codec.encode_to_vec(&q.symbols);
    println!(
        "qlc-t1: {} -> {} bytes ({:.1}% compressibility; paper: 13.9%)",
        q.symbols.len(),
        encoded.len(),
        (1.0 - encoded.len() as f64 / q.symbols.len() as f64) * 100.0
    );

    // 5. Decompress and verify losslessness.
    let decoded = codec.decode_from_slice(&encoded, q.symbols.len()).unwrap();
    assert_eq!(decoded, q.symbols);
    println!("roundtrip OK (bit-exact)");

    // 6. Or use the self-describing frame container (tables embedded,
    //    chunked QLF2 — independent chunks decode in parallel).
    let handle = CodecRegistry::global().resolve("qlc", &hist).unwrap();
    let framed = frame::compress(&handle, &q.symbols).unwrap();
    let back = frame::decompress(&framed).unwrap();
    assert_eq!(back, q.symbols);
    println!(
        "framed (optimized scheme + embedded LUT): {} bytes",
        framed.len()
    );

    // 7. Dequantize to verify the numeric path.  Error is bounded by
    //    half an e4m3 step of the block's scale.
    let restored = quant.dequantize(&q);
    let max_err = tensor
        .chunks(32)
        .zip(restored.chunks(32))
        .zip(&q.scales)
        .map(|((xs, ys), &scale)| {
            xs.iter()
                .zip(ys)
                .map(|(x, y)| (x - y).abs() / (scale * 480.0))
                .fold(0f32, f32::max)
        })
        .fold(0f32, f32::max);
    println!(
        "max quantization error: {:.4} of block absmax (≤ half an e4m3 step)",
        max_err
    );
}
