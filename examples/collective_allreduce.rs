//! Compressed collective demo — the paper's motivating scenario (§1):
//! a gradient all-reduce over a bandwidth-bound ring, with and without
//! lossless e4m3 compression on the transport.  Runs both the
//! simulated fabric (modelled time) and the real threaded engine
//! (wall time), and verifies that compression changes bytes, never
//! values.
//!
//! Run: `cargo run --release --example collective_allreduce`

use qlc::collective::{engine, ring_allreduce, Fabric, Transport};
use qlc::data::{TensorGen, TensorKind};
use qlc::formats::Variant;
use qlc::stats::Histogram;
use qlc::util::rng::Rng;

fn main() {
    let workers = 8;
    let elems = 1 << 20; // per worker
    println!("ring all-reduce: {workers} workers × {elems} f32 gradients");

    let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
    let mut rng = Rng::new(7);
    let data: Vec<Vec<f32>> =
        (0..workers).map(|_| gen.generate(&mut rng, elems)).collect();
    // Paper §7: codec tables fitted apriori on same-type data.
    let calibration =
        Histogram::from_symbols(&gen.symbols(&mut rng, 1 << 16));

    let fabric = Fabric::pod(workers); // 50 GB/s links, 2 µs hops
    let mut baseline = None;
    for codec in ["raw", "qlc", "huffman"] {
        let transport = if codec == "raw" {
            Transport::Raw
        } else {
            Transport::Compressed {
                codec: codec.into(),
                calibration: Box::new(calibration.clone()),
            }
        };
        let (result, report) =
            ring_allreduce(&fabric, &data, &transport).unwrap();
        match &baseline {
            None => baseline = Some(result),
            Some(b) => assert_eq!(
                b, &result,
                "lossless transport must not change the reduction"
            ),
        }
        println!(
            "  {:<8} wire {:>12} B  ratio {:>5.3}  network {:>7.3} ms  \
             codec {:>8.3} ms  total {:>8.3} ms  pipelined {:>8.3} ms \
             ({:.0}% hidden)",
            codec,
            report.wire_bytes,
            report.compression_ratio(),
            report.network_time_s * 1e3,
            report.codec_time_s * 1e3,
            report.total_time_s() * 1e3,
            report.pipelined_time_s * 1e3,
            report.overlap_savings() * 100.0
        );
    }

    println!("\nthreaded engine (real threads/channels, wall clock):");
    for codec in ["raw", "qlc"] {
        let transport = if codec == "raw" {
            Transport::Raw
        } else {
            Transport::Compressed {
                codec: codec.into(),
                calibration: Box::new(calibration.clone()),
            }
        };
        let (result, report) =
            engine::threaded_allreduce(workers, data.clone(), &transport)
                .unwrap();
        assert_eq!(&result, baseline.as_ref().unwrap());
        println!(
            "  {:<8} wall {:>7.1} ms  wire {:>12} B (of {} raw)",
            codec,
            report.wall_time_s * 1e3,
            report.wire_bytes,
            report.raw_bytes
        );
    }

    println!("\nbandwidth sweep (modelled total all-reduce time, ms):");
    println!("  {:>8} {:>10} {:>10} {:>10}", "GB/s", "raw", "qlc", "speedup");
    for gbps in [5.0, 10.0, 25.0, 50.0, 100.0] {
        let fabric = Fabric {
            workers,
            link_bandwidth: gbps * 1e9,
            link_latency: 2e-6,
        };
        let (_, raw) = ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let (_, comp) = ring_allreduce(
            &fabric,
            &data,
            &Transport::Compressed {
                codec: "qlc".into(),
                calibration: Box::new(calibration.clone()),
            },
        )
        .unwrap();
        println!(
            "  {:>8.0} {:>10.3} {:>10.3} {:>9.2}x",
            gbps,
            raw.network_time_s * 1e3,
            comp.network_time_s * 1e3,
            raw.network_time_s / comp.network_time_s
        );
    }
}
