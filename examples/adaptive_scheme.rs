//! Scheme adaptation — the paper's §6 and §8: Table 1 fits smooth
//! FFN1-like PMFs, Table 2 fits zero-spiked FFN2-like PMFs, and the DP
//! optimizer (our implementation of the paper's "future work"
//! formulation) derives a tuned scheme for *any* distribution.
//!
//! Run: `cargo run --release --example adaptive_scheme`

use qlc::codecs::huffman::HuffmanCodec;
use qlc::codecs::qlc::{optimizer, AreaScheme};
use qlc::codecs::Codec;
use qlc::data::{TensorGen, TensorKind};
use qlc::formats::Variant;
use qlc::stats::Histogram;
use qlc::util::rng::Rng;

fn describe(label: &str, scheme: &AreaScheme) {
    let sizes: Vec<u16> = scheme.areas.iter().map(|a| a.size).collect();
    println!(
        "  {label}: P={}, areas {:?}, lengths {:?}",
        scheme.prefix_bits,
        sizes,
        scheme.distinct_lengths()
    );
}

fn main() {
    let mut rng = Rng::new(21);
    for kind in TensorKind::all() {
        let gen = TensorGen::new(kind, Variant::ExmY);
        let symbols = gen.symbols(&mut rng, 1 << 20);
        let hist = Histogram::from_symbols(&symbols);
        let pmf = hist.pmf();
        let sorted = pmf.sorted_desc();
        println!(
            "=== {} (entropy {:.3} bits, p(zero-symbol) {:.3}) ===",
            kind.name(),
            pmf.entropy(),
            pmf.p[0]
        );
        let huff = HuffmanCodec::from_histogram(&hist);
        let t1 = AreaScheme::table1();
        let t2 = AreaScheme::table2();
        let opt = optimizer::optimize_scheme(&sorted);
        describe("optimized", &opt);
        println!(
            "  compressibility: huffman {:>5.2}% | t1 {:>5.2}% | t2 {:>5.2}% \
             | optimized {:>5.2}% | ideal {:>5.2}%",
            pmf.compressibility(&huff.code_lengths()) * 100.0,
            t1.compressibility_sorted(&sorted) * 100.0,
            t2.compressibility_sorted(&sorted) * 100.0,
            opt.compressibility_sorted(&sorted) * 100.0,
            pmf.ideal_compressibility() * 100.0
        );
        // The optimizer's scheme is a real codec: verify roundtrip.
        let codec = qlc::codecs::qlc::QlcCodec::from_pmf(opt, &pmf);
        let enc = codec.encode_to_vec(&symbols);
        assert_eq!(
            codec.decode_from_slice(&enc, symbols.len()).unwrap(),
            symbols
        );
        println!(
            "  encoded {} -> {} bytes (verified lossless)\n",
            symbols.len(),
            enc.len()
        );
    }
}
