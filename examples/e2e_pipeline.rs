//! End-to-end driver (DESIGN.md §6): proves all layers compose.
//!
//!   L2/L1 (AOT JAX + Pallas, via PJRT)  →  real FFN fwd/bwd tensors,
//!       quantized to e4m3 on-device over several "training" steps;
//!   L3 codecs  →  per-tensor-type QLC LUTs fitted apriori (paper §7);
//!   L3 coordinator  →  parallel compression pipeline over the streams;
//!   L3 collective  →  compressed gradient all-reduce across 8 workers;
//!   hw model  →  decoder cycle comparison on the harvested data.
//!
//! Requires `artifacts/` (run `make artifacts` first).
//!
//! Run: `cargo run --release --example e2e_pipeline`

use std::collections::BTreeMap;
use std::time::Instant;

use qlc::codecs::huffman::HuffmanCodec;
use qlc::codecs::qlc::{optimizer, QlcCodec};
use qlc::codecs::Codec;
use qlc::collective::{engine, Transport};
use qlc::coordinator::{Pipeline, PipelineConfig};
use qlc::formats::{BlockQuantizer, Variant};
use qlc::hw;
use qlc::runtime::inputs::{make_step_inputs, InputStats};
use qlc::runtime::Runtime;
use qlc::stats::Histogram;
use qlc::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let steps = 6;
    let workers = 8;
    println!("=== e2e: {steps} FFN steps via PJRT, then compress + collective ===\n");

    // --- Phase 1: harvest real tensors through the AOT artifacts. ----
    let t0 = Instant::now();
    let rt = Runtime::load(std::path::Path::new("artifacts"))?;
    let mut rng = Rng::new(1234);
    let mut streams: BTreeMap<String, Vec<u8>> = BTreeMap::new();
    for _ in 0..steps {
        let ins = make_step_inputs(
            rt.input_shapes(),
            InputStats::default(),
            &mut rng,
        );
        for t in rt.harvest_step(&ins)? {
            streams.entry(t.name).or_default().extend(t.symbols);
        }
    }
    println!(
        "harvested {} tensor streams × {steps} steps in {:.2?}",
        streams.len(),
        t0.elapsed()
    );

    // --- Phase 2: per-tensor-type LUTs, calibrated on step 0 only. ---
    println!("\nper-tensor-type compression (LUTs fitted on first 20%):");
    println!(
        "  {:<12} {:>8} {:>8} {:>9} {:>9} {:>9}",
        "tensor", "entropy", "p(zero)", "ideal%", "huffman%", "qlc-opt%"
    );
    let mut grad_symbols: Vec<u8> = Vec::new();
    for (name, symbols) in &streams {
        let cut = symbols.len() / 5;
        let cal = Histogram::from_symbols(&symbols[..cut]);
        let rest = &symbols[cut..];
        let pmf = Histogram::from_symbols(rest).pmf();
        let huff = HuffmanCodec::from_histogram(&cal);
        let scheme = optimizer::optimize_scheme(&cal.pmf().sorted_desc());
        let qlc_codec = QlcCodec::from_pmf(scheme, &cal.pmf());
        let h_bytes = huff.encode_to_vec(rest).len();
        let q_bytes = qlc_codec.encode_to_vec(rest).len();
        assert_eq!(
            qlc_codec.decode_from_slice(
                &qlc_codec.encode_to_vec(rest), rest.len()).unwrap(),
            rest,
        );
        println!(
            "  {:<12} {:>8.3} {:>8.3} {:>9.2} {:>9.2} {:>9.2}",
            name,
            pmf.entropy(),
            pmf.p[0],
            pmf.ideal_compressibility() * 100.0,
            (1.0 - h_bytes as f64 / rest.len() as f64) * 100.0,
            (1.0 - q_bytes as f64 / rest.len() as f64) * 100.0
        );
        if name.ends_with("wgrad") {
            grad_symbols.extend_from_slice(rest);
        }
    }

    // --- Phase 3: coordinator pipeline throughput on the biggest
    // stream. --------------------------------------------------------
    let biggest = streams
        .values()
        .max_by_key(|s| s.len())
        .expect("streams nonempty");
    let cal = Histogram::from_symbols(biggest);
    let pipe = Pipeline::new(
        PipelineConfig { workers: 4, chunk_size: 64 * 1024, queue_depth: 8 },
        "qlc",
        &cal,
    )
    .map_err(anyhow::Error::msg)?;
    let t0 = Instant::now();
    let frames = pipe.compress_stream(biggest);
    let wall = t0.elapsed().as_secs_f64();
    let m = pipe.metrics();
    println!(
        "\ncoordinator pipeline: {} chunks, {:.1}% compressibility, \
         {:.0} MB/s end-to-end ({} workers)",
        frames.len(),
        m.compressibility() * 100.0,
        biggest.len() as f64 / wall / 1e6,
        4
    );

    // --- Phase 4: compressed gradient all-reduce. ---------------------
    // Split the harvested weight-gradient f32s across workers by
    // re-running dequantization per worker slice (symbols → values).
    let quant = BlockQuantizer::new(Variant::ExmY);
    // Each worker's tensor is itself ring-chunked w ways, so round to
    // a multiple of workers × block.
    let per = grad_symbols.len() / workers / (workers * 32) * (workers * 32);
    let grad_cal = Histogram::from_symbols(&grad_symbols);
    let worker_grads: Vec<Vec<f32>> = (0..workers)
        .map(|i| {
            let slice = &grad_symbols[i * per..(i + 1) * per];
            let scales = vec![1.0f32; per / 32];
            quant.dequantize(&qlc::formats::QuantizedBlocks {
                symbols: slice.to_vec(),
                scales,
                variant: Variant::ExmY,
            })
        })
        .collect();
    for codec in ["raw", "qlc"] {
        let transport = if codec == "raw" {
            Transport::Raw
        } else {
            Transport::Compressed {
                codec: "qlc".into(),
                calibration: Box::new(grad_cal.clone()),
            }
        };
        let (results, rep) =
            engine::threaded_allreduce(workers, worker_grads.clone(), &transport)
                .map_err(anyhow::Error::msg)?;
        assert!(results.iter().all(|r| r == &results[0]));
        println!(
            "allreduce[{codec:<4}] wall {:>7.1} ms  wire {:>10} B (raw {})",
            rep.wall_time_s * 1e3,
            rep.wire_bytes,
            rep.raw_bytes
        );
    }

    // --- Phase 5: hardware decoder model on harvested FFN1 acts. -----
    let ffn1 = &streams["ffn1_act"];
    let hist = Histogram::from_symbols(ffn1);
    let huff = HuffmanCodec::from_histogram(&hist);
    let scheme = optimizer::optimize_scheme(&hist.pmf().sorted_desc());
    let qlc_codec = QlcCodec::from_pmf(scheme, &hist.pmf());
    let reports = hw::compare_on_stream(huff.book(), &qlc_codec, ffn1);
    println!("\nhw decoder model on harvested ffn1_act:");
    for r in &reports {
        println!(
            "  {:<16} {:>7.3} cycles/sym  {:>9} storage bits  {:>2} stages",
            r.model,
            r.cycles_per_symbol(),
            r.storage_bits,
            r.worst_stages
        );
    }
    println!(
        "  QLC decode speedup vs bit-serial Huffman: {:.2}x",
        hw::qlc_speedup_vs_serial(&reports)
    );
    println!("\ne2e OK");
    Ok(())
}
