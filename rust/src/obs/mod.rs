//! Observability substrate: counters, log2 latency histograms and
//! lightweight spans, with Chrome-trace and Prometheus-text exporters.
//!
//! Dependency-free by design (no tracing/prometheus crates — the same
//! offline discipline as [`crate::analysis`]), because it instruments
//! the hot paths whose performance the repo's claims rest on:
//!
//! * [`Registry`] — named atomic [`Counter`]s plus fixed-bucket log2
//!   [`Hist`]ograms (p50/p90/p99 via [`HistSnapshot::quantile`]).  A
//!   process-wide instance lives behind [`Registry::global`]; unit
//!   tests and the coordinator pipeline use private instances so
//!   concurrent runs never cross-contaminate counts.  [`Snapshot`]s
//!   are order- and partition-invariant under [`Snapshot::merge`], so
//!   per-rank snapshots from a `qlc launch` world fold into one.
//! * [`span`] — RAII spans recorded into per-thread ring buffers
//!   behind a runtime switch ([`set_trace`] / `QLC_TRACE=1`).  When
//!   tracing is off a span is one relaxed atomic load and no clock
//!   read; nothing is allocated or recorded.
//! * [`chrome_trace`] / [`Snapshot::to_prometheus`] — exporters: the
//!   Chrome trace-event JSON loads in Perfetto (`qlc launch --trace`
//!   merges one pid per rank, one tid per worker thread); the
//!   Prometheus-style text carries counter lines and summary-quantile
//!   lines for every histogram.
//!
//! Metric keys carry their labels inline in Prometheus form —
//! `base{k="v",...}` via [`label`] — so the registry map is flat and
//! the exporters never re-parse label sets.

mod export;
mod registry;
mod span;

pub use export::{
    chrome_trace, chrome_trace_from, merge_chrome_traces, write_metrics,
    write_trace,
};
pub use registry::{
    label, Counter, Hist, HistSnapshot, Registry, Snapshot, Stopwatch,
    HIST_BUCKETS,
};
pub use span::{
    drain_events, set_trace, span, trace_enabled, SpanEvent, SpanGuard,
    ThreadEvents,
};

/// The process-wide registry ([`Registry::global`]), re-exported as a
/// free function because every instrumentation site uses it.
pub fn global() -> &'static Registry {
    Registry::global()
}
