//! Lightweight RAII spans behind a runtime switch.
//!
//! When tracing is off ([`trace_enabled`] == false) a [`span`] call is
//! one relaxed atomic load: no clock read, no allocation, no lock.
//! When on, each thread records `{name, start_ns, dur_ns, args}`
//! events into its own fixed-capacity ring buffer; rings are
//! registered in a process-wide list so [`drain_events`] can collect
//! events from worker threads that have already exited (scoped threads
//! in the frame encoder, the collective fleet, the coordinator pool).
//!
//! The switch initialises from the `QLC_TRACE` environment variable on
//! first query and can be forced either way with [`set_trace`] (the
//! `--trace` CLI flag does this before running work).

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Per-thread ring capacity.  A 4-rank loopback collective emits a few
/// thousand spans per rank; 16Ki gives generous headroom while
/// bounding memory at ~1.5 MiB/thread worst case.
const RING_CAP: usize = 16 * 1024;

/// Trace switch states for [`TRACE`].
const TRACE_UNINIT: u8 = 0;
const TRACE_OFF: u8 = 1;
const TRACE_ON: u8 = 2;

static TRACE: AtomicU8 = AtomicU8::new(TRACE_UNINIT);

/// Monotonic id handed to each thread's ring, used as the `tid` in the
/// Chrome trace export.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Force tracing on or off for the whole process (overrides
/// `QLC_TRACE`).
pub fn set_trace(on: bool) {
    TRACE.store(if on { TRACE_ON } else { TRACE_OFF }, Ordering::Relaxed);
}

/// Whether spans are being recorded.  After first use this is a single
/// relaxed load — the entire cost of an inactive [`span`] call.
pub fn trace_enabled() -> bool {
    match TRACE.load(Ordering::Relaxed) {
        TRACE_ON => true,
        TRACE_OFF => false,
        _ => {
            let on = std::env::var("QLC_TRACE").map_or(false, |v| v == "1");
            set_trace(on);
            on
        }
    }
}

/// Process-wide monotonic epoch all span timestamps are relative to,
/// so events from different threads share one timeline.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    u64::try_from(epoch().elapsed().as_nanos()).unwrap_or(u64::MAX)
}

fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// One completed span.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub name: String,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// `(key, value)` pairs attached via [`SpanGuard::arg`].
    pub args: Vec<(String, String)>,
}

/// All events drained from one thread's ring.
#[derive(Clone, Debug)]
pub struct ThreadEvents {
    pub tid: u64,
    pub thread_name: String,
    pub events: Vec<SpanEvent>,
    /// Events overwritten because the ring filled (oldest dropped).
    pub dropped: u64,
}

/// Fixed-capacity event ring for one thread.
struct Ring {
    tid: u64,
    thread_name: String,
    events: Vec<SpanEvent>,
    /// Next overwrite position once `events` reached capacity.
    head: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, ev: SpanEvent) {
        if self.events.len() < RING_CAP {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head = (self.head + 1) % RING_CAP;
            self.dropped += 1;
        }
    }

    /// Take the buffered events in chronological order and reset.
    fn drain(&mut self) -> (Vec<SpanEvent>, u64) {
        let dropped = self.dropped;
        self.dropped = 0;
        let head = self.head;
        self.head = 0;
        let mut events = std::mem::take(&mut self.events);
        events.rotate_left(head);
        (events, dropped)
    }
}

/// Registry of every thread's ring, so events outlive their threads.
fn rings() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    static RINGS: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());
    &RINGS
}

thread_local! {
    static LOCAL_RING: Arc<Mutex<Ring>> = {
        let ring = Arc::new(Mutex::new(Ring {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            thread_name: std::thread::current()
                .name()
                .unwrap_or("thread")
                .to_string(),
            events: Vec::new(),
            head: 0,
            dropped: 0,
        }));
        lock_or_recover(rings()).push(ring.clone());
        ring
    };
}

/// Collect (and clear) every thread's buffered events, including rings
/// whose threads have exited.  Rings stay registered so long-lived
/// threads keep recording into the same `tid` afterwards.
pub fn drain_events() -> Vec<ThreadEvents> {
    let rings = lock_or_recover(rings()).clone();
    let mut out = Vec::with_capacity(rings.len());
    for ring in rings {
        let mut r = lock_or_recover(&ring);
        let (events, dropped) = r.drain();
        if events.is_empty() && dropped == 0 {
            continue;
        }
        out.push(ThreadEvents {
            tid: r.tid,
            thread_name: r.thread_name.clone(),
            events,
            dropped,
        });
    }
    out
}

/// RAII guard: records one [`SpanEvent`] when dropped.  Inactive
/// guards (tracing off) carry no state and drop for free.
pub struct SpanGuard {
    /// `Some` only while tracing is active.
    active: Option<ActiveSpan>,
}

struct ActiveSpan {
    name: &'static str,
    start_ns: u64,
    args: Vec<(String, String)>,
}

impl SpanGuard {
    /// Attach a `key=value` argument (shows up under `args` in the
    /// Chrome trace).  No-op — and no formatting — when inactive.
    pub fn arg(mut self, key: &str, value: impl std::fmt::Display) -> Self {
        if let Some(a) = self.active.as_mut() {
            a.args.push((key.to_string(), value.to_string()));
        }
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(a) = self.active.take() else { return };
        let ev = SpanEvent {
            name: a.name.to_string(),
            start_ns: a.start_ns,
            dur_ns: now_ns().saturating_sub(a.start_ns),
            args: a.args,
        };
        LOCAL_RING.with(|ring| lock_or_recover(ring).push(ev));
    }
}

/// Open a span covering the enclosing scope.  When tracing is off this
/// is one relaxed atomic load and returns an inert guard.
pub fn span(name: &'static str) -> SpanGuard {
    if !trace_enabled() {
        return SpanGuard { active: None };
    }
    SpanGuard {
        active: Some(ActiveSpan {
            name,
            start_ns: now_ns(),
            args: Vec::new(),
        }),
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Span tests toggle the process-wide switch and drain the shared
    /// rings; serialise them (export.rs tests join in) so parallel
    /// test threads don't steal each other's events.
    pub(crate) fn trace_lock() -> MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        lock_or_recover(&LOCK)
    }

    /// Drain only the events whose span names start with `prefix` —
    /// other tests' stragglers on this shared ring are not ours.
    pub(crate) fn drain_named(prefix: &str) -> Vec<SpanEvent> {
        drain_events()
            .into_iter()
            .flat_map(|t| t.events)
            .filter(|e| e.name.starts_with(prefix))
            .collect()
    }

    #[test]
    fn disabled_tracing_records_nothing() {
        let _g = trace_lock();
        set_trace(false);
        drop(span("span_test_disabled").arg("k", 1));
        let got = drain_named("span_test_disabled");
        assert!(got.is_empty(), "disabled trace recorded {got:?}");
    }

    #[test]
    fn enabled_tracing_records_name_args_and_duration() {
        let _g = trace_lock();
        set_trace(true);
        {
            let _s = span("span_test_enabled").arg("rank", 3).arg("step", "x");
        }
        set_trace(false);
        let got = drain_named("span_test_enabled");
        assert_eq!(got.len(), 1, "{got:?}");
        let ev = &got[0];
        assert_eq!(ev.name, "span_test_enabled");
        assert_eq!(
            ev.args,
            vec![
                ("rank".to_string(), "3".to_string()),
                ("step".to_string(), "x".to_string()),
            ]
        );
        // dur is computed after start on the same monotonic epoch.
        assert!(ev.start_ns <= ev.start_ns + ev.dur_ns);
    }

    #[test]
    fn drain_clears_and_spans_survive_thread_exit() {
        let _g = trace_lock();
        set_trace(true);
        std::thread::scope(|s| {
            s.spawn(|| {
                drop(span("span_test_scoped"));
            });
        });
        set_trace(false);
        assert_eq!(drain_named("span_test_scoped").len(), 1);
        // A second drain finds nothing: the ring was cleared.
        assert!(drain_named("span_test_scoped").is_empty());
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut r = Ring {
            tid: 0,
            thread_name: "t".into(),
            events: Vec::new(),
            head: 0,
            dropped: 0,
        };
        for i in 0..(RING_CAP as u64 + 3) {
            r.push(SpanEvent {
                name: "x".into(),
                start_ns: i,
                dur_ns: 0,
                args: Vec::new(),
            });
        }
        let (events, dropped) = r.drain();
        assert_eq!(events.len(), RING_CAP);
        assert_eq!(dropped, 3);
        // Chronological order after rotation: oldest surviving first.
        assert_eq!(events[0].start_ns, 3);
        assert_eq!(events[RING_CAP - 1].start_ns, RING_CAP as u64 + 2);
    }
}
