//! Atomic counters, log2 histograms and mergeable snapshots.
//!
//! The hot path is lock-free: a [`Counter`] or [`Hist`] handle is an
//! `Arc` onto shared atomics, acquired once (construction time) under
//! a short registry lock and then recorded into with relaxed atomic
//! adds.  Histograms use fixed power-of-two buckets — bucket `i` holds
//! values whose bit length is `i` (bucket 0 holds zero, the last
//! bucket absorbs everything ≥ 2^62) — so `merge` is a bucketwise sum
//! and therefore order- and partition-invariant, and quantiles come
//! from a cumulative scan returning the bucket's upper edge (a ≤ 2×
//! overestimate, monotone in `q` by construction).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::util::json::Json;

/// Number of histogram buckets: one per possible bit length (0..=63,
/// with the last bucket absorbing 64-bit values too).
pub const HIST_BUCKETS: usize = 64;

/// Monotonic nanosecond stopwatch for latency histograms.  Under Miri
/// (which interprets no host clocks deterministically enough for
/// throughput accounting) it reads zero, so instrumented library paths
/// stay interpretable.
pub struct Stopwatch {
    #[cfg(not(miri))]
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch {
            #[cfg(not(miri))]
            start: std::time::Instant::now(),
        }
    }

    /// Nanoseconds since [`Stopwatch::start`] (saturating at u64::MAX).
    pub fn elapsed_ns(&self) -> u64 {
        #[cfg(not(miri))]
        {
            u64::try_from(self.start.elapsed().as_nanos()).unwrap_or(u64::MAX)
        }
        #[cfg(miri)]
        {
            0
        }
    }
}

/// Lock a mutex, recovering the data from a poisoned lock (the only
/// writers are atomic handle acquisitions; a panic mid-insert leaves
/// the map structurally valid).
fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Shared storage of one histogram.
#[derive(Debug)]
struct HistCore {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for HistCore {
    // std ships Default for arrays only up to length 32, so spell the
    // 64-bucket zero state out.
    fn default() -> HistCore {
        HistCore {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

/// Bucket index of a value: its bit length, clamped into the table.
fn bucket_index(v: u64) -> usize {
    ((u64::BITS - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Upper edge of bucket `i` — the quantile estimate for values that
/// landed there.
fn bucket_edge(i: usize) -> u64 {
    if i >= 63 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Handle onto a registered counter; `clone` is cheap and all clones
/// add into the same atomic.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// A counter not attached to any registry (for tests / defaults).
    pub fn detached() -> Counter {
        Counter(Arc::new(AtomicU64::new(0)))
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Handle onto a registered histogram; `clone` is cheap and all clones
/// record into the same buckets.
#[derive(Clone, Debug)]
pub struct Hist(Arc<HistCore>);

impl Hist {
    /// A histogram not attached to any registry.
    pub fn detached() -> Hist {
        Hist(Arc::new(HistCore::default()))
    }

    pub fn record(&self, v: u64) {
        self.0.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (slot, b) in buckets.iter_mut().zip(&self.0.buckets) {
            *slot = b.load(Ordering::Relaxed);
        }
        HistSnapshot {
            buckets,
            count: self.0.count.load(Ordering::Relaxed),
            sum: self.0.sum.load(Ordering::Relaxed),
        }
    }

    /// Quantile estimate straight off the live buckets.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        self.snapshot().quantile(q)
    }
}

/// Frozen histogram state: mergeable, serializable, quantile-queryable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum: u64,
}

impl Default for HistSnapshot {
    fn default() -> HistSnapshot {
        HistSnapshot { buckets: [0; HIST_BUCKETS], count: 0, sum: 0 }
    }
}

impl HistSnapshot {
    /// Bucketwise sum — the operation that makes cross-thread and
    /// cross-rank aggregation order- and partition-invariant.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// The value at quantile `q` (clamped to `[0, 1]`): the upper edge
    /// of the first bucket whose cumulative count reaches `ceil(q *
    /// count)`.  `None` on an empty histogram.  Monotone in `q`.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return Some(bucket_edge(i));
            }
        }
        // Bucket counts sum to `count`, so the scan always returns
        // above; this arm is unreachable but cheap to keep total.
        Some(bucket_edge(HIST_BUCKETS - 1))
    }

    /// Mean of the recorded values (exact — the sum is tracked
    /// outside the buckets).  `None` on an empty histogram.
    pub fn mean(&self) -> Option<f64> {
        if self.count == 0 {
            None
        } else {
            Some(self.sum as f64 / self.count as f64)
        }
    }
}

/// A set of named counters and histograms.  Hot-path handles are
/// acquired once and recorded into lock-free; the internal maps are
/// locked only at acquisition and snapshot time.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    hists: Mutex<BTreeMap<String, Arc<HistCore>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The process-wide registry every library instrumentation site
    /// records into by default.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    /// Handle onto the counter named `name` (created on first use).
    pub fn counter(&self, name: &str) -> Counter {
        let mut map = lock_or_recover(&self.counters);
        Counter(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(AtomicU64::new(0)))
                .clone(),
        )
    }

    /// Handle onto the histogram named `name` (created on first use).
    pub fn hist(&self, name: &str) -> Hist {
        let mut map = lock_or_recover(&self.hists);
        Hist(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(HistCore::default()))
                .clone(),
        )
    }

    /// Freeze every metric into a [`Snapshot`].
    pub fn snapshot(&self) -> Snapshot {
        let counters = lock_or_recover(&self.counters)
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let hists = lock_or_recover(&self.hists)
            .iter()
            .map(|(k, core)| (k.clone(), Hist(core.clone()).snapshot()))
            .collect();
        Snapshot { counters, hists }
    }
}

/// Build a metric key with inline Prometheus-style labels:
/// `label("x_ns", &[("codec", "qlc")])` → `x_ns{codec="qlc"}`.
pub fn label(base: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return base.to_string();
    }
    let mut out = String::with_capacity(base.len() + 16 * labels.len());
    out.push_str(base);
    out.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        out.push_str(v);
        out.push('"');
    }
    out.push('}');
    out
}

/// Split a `base{labels}` key into `(base, labels-with-braces)`;
/// plain keys return an empty label part.
fn split_key(key: &str) -> (&str, &str) {
    match key.find('{') {
        Some(i) => (&key[..i], &key[i..]),
        None => (key, ""),
    }
}

/// Insert one more label into a key's label set (creating it if the
/// key has none) — used to stamp `quantile="..."` onto summary lines.
fn with_extra_label(key: &str, extra: &str) -> String {
    let (base, labels) = split_key(key);
    if labels.is_empty() {
        format!("{base}{{{extra}}}")
    } else {
        // labels == "{...}": splice before the closing brace.
        let inner = &labels[1..labels.len() - 1];
        format!("{base}{{{inner},{extra}}}")
    }
}

/// Suffix a key's *base* name, keeping its labels: `x{k="v"}` →
/// `x_count{k="v"}`.
fn with_suffix(key: &str, suffix: &str) -> String {
    let (base, labels) = split_key(key);
    format!("{base}{suffix}{labels}")
}

/// Frozen registry state: serializable (JSON), renderable (Prometheus
/// text) and mergeable across threads, processes and ranks.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl Snapshot {
    /// Fold `other` into `self`: counters add, histograms merge
    /// bucketwise.  Commutative and associative — a world-level
    /// snapshot is the same whatever order the ranks merge in.
    pub fn merge(&mut self, other: &Snapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }

    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters = counters.set(k, *v as f64);
        }
        let mut hists = Json::obj();
        for (k, h) in &self.hists {
            let buckets: Vec<f64> =
                h.buckets.iter().map(|&b| b as f64).collect();
            hists = hists.set(
                k,
                Json::obj()
                    .set("count", h.count as f64)
                    .set("sum", h.sum as f64)
                    .set("buckets", buckets),
            );
        }
        Json::obj().set("counters", counters).set("hists", hists)
    }

    pub fn from_json(j: &Json) -> Result<Snapshot, String> {
        let mut snap = Snapshot::default();
        if let Some(Json::Obj(m)) = j.get("counters") {
            for (k, v) in m {
                let v = v
                    .as_f64()
                    .ok_or_else(|| format!("counter {k} is not a number"))?;
                snap.counters.insert(k.clone(), v as u64);
            }
        }
        if let Some(Json::Obj(m)) = j.get("hists") {
            for (k, h) in m {
                let count = h
                    .get("count")
                    .and_then(|x| x.as_f64())
                    .ok_or_else(|| format!("hist {k} missing count"))?
                    as u64;
                let sum = h
                    .get("sum")
                    .and_then(|x| x.as_f64())
                    .ok_or_else(|| format!("hist {k} missing sum"))?
                    as u64;
                let arr = h
                    .get("buckets")
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| format!("hist {k} missing buckets"))?;
                if arr.len() > HIST_BUCKETS {
                    return Err(format!(
                        "hist {k} has {} buckets (max {HIST_BUCKETS})",
                        arr.len()
                    ));
                }
                let mut buckets = [0u64; HIST_BUCKETS];
                for (i, b) in arr.iter().enumerate() {
                    buckets[i] = b
                        .as_f64()
                        .ok_or_else(|| format!("hist {k} bucket {i}"))?
                        as u64;
                }
                snap.hists
                    .insert(k.clone(), HistSnapshot { buckets, count, sum });
            }
        }
        Ok(snap)
    }

    pub fn parse(text: &str) -> Result<Snapshot, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        Snapshot::from_json(&j)
    }

    /// Prometheus-style text exposition: one line per counter, and for
    /// every histogram a summary — `quantile="0.5|0.9|0.99"` lines
    /// plus `_count` / `_sum`.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_base = "";
        for (k, v) in &self.counters {
            let (base, _) = split_key(k);
            if base != last_base {
                out.push_str(&format!("# TYPE {base} counter\n"));
                last_base = base;
            }
            out.push_str(&format!("{k} {v}\n"));
        }
        let mut last_base = "";
        for (k, h) in &self.hists {
            let (base, _) = split_key(k);
            if base != last_base {
                out.push_str(&format!("# TYPE {base} summary\n"));
                last_base = base;
            }
            for (qs, q) in [("0.5", 0.5), ("0.9", 0.9), ("0.99", 0.99)] {
                let line = with_extra_label(k, &format!("quantile=\"{qs}\""));
                match h.quantile(q) {
                    Some(v) => out.push_str(&format!("{line} {v}\n")),
                    None => out.push_str(&format!("{line} NaN\n")),
                }
            }
            out.push_str(&format!("{} {}\n", with_suffix(k, "_count"), h.count));
            out.push_str(&format!("{} {}\n", with_suffix(k, "_sum"), h.sum));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, Config};

    #[test]
    fn bucket_scheme_is_bit_length() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(bucket_edge(0), 0);
        assert_eq!(bucket_edge(1), 1);
        assert_eq!(bucket_edge(2), 3);
        assert_eq!(bucket_edge(63), u64::MAX);
    }

    #[test]
    fn counters_accumulate_across_clones() {
        let reg = Registry::new();
        let a = reg.counter("hits");
        let b = reg.counter("hits");
        a.add(2);
        b.inc();
        assert_eq!(reg.counter("hits").get(), 3);
        assert_eq!(reg.snapshot().counters["hits"], 3);
    }

    #[test]
    fn hist_quantiles_track_recorded_values() {
        let reg = Registry::new();
        let h = reg.hist("lat_ns");
        for v in [10u64, 20, 30, 1000, 2000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 3060);
        // p50 lands in 30's bucket [16,31]; the edge estimate is 31.
        assert_eq!(s.quantile(0.5), Some(31));
        // p99 lands in the top recorded bucket [1024,2047].
        assert_eq!(s.quantile(0.99), Some(2047));
        assert!(s.mean().unwrap() > 0.0);
        assert_eq!(HistSnapshot::default().quantile(0.5), None);
        assert_eq!(HistSnapshot::default().mean(), None);
    }

    #[test]
    fn label_builder_and_key_surgery() {
        assert_eq!(label("x", &[]), "x");
        let k = label("x_ns", &[("codec", "qlc"), ("mode", "lanes")]);
        assert_eq!(k, "x_ns{codec=\"qlc\",mode=\"lanes\"}");
        assert_eq!(
            with_extra_label(&k, "quantile=\"0.5\""),
            "x_ns{codec=\"qlc\",mode=\"lanes\",quantile=\"0.5\"}"
        );
        assert_eq!(
            with_extra_label("plain", "quantile=\"0.9\""),
            "plain{quantile=\"0.9\"}"
        );
        assert_eq!(
            with_suffix(&k, "_count"),
            "x_ns_count{codec=\"qlc\",mode=\"lanes\"}"
        );
        assert_eq!(with_suffix("plain", "_sum"), "plain_sum");
    }

    #[test]
    fn snapshot_json_roundtrip() {
        let reg = Registry::new();
        reg.counter("c{op=\"x\"}").add(7);
        let h = reg.hist("h_ns");
        h.record(5);
        h.record(500);
        let snap = reg.snapshot();
        let back =
            Snapshot::parse(&snap.to_json().to_string_pretty()).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn prometheus_text_has_quantiles_and_counts() {
        let reg = Registry::new();
        reg.counter("ops_total").inc();
        let h = reg.hist("lat_ns{codec=\"qlc\"}");
        h.record(100);
        let text = reg.snapshot().to_prometheus();
        assert!(text.contains("# TYPE ops_total counter"), "{text}");
        assert!(text.contains("ops_total 1"), "{text}");
        assert!(
            text.contains("lat_ns{codec=\"qlc\",quantile=\"0.5\"} 127"),
            "{text}"
        );
        assert!(
            text.contains("lat_ns{codec=\"qlc\",quantile=\"0.99\"} 127"),
            "{text}"
        );
        assert!(text.contains("lat_ns_count{codec=\"qlc\"} 1"), "{text}");
        assert!(text.contains("lat_ns_sum{codec=\"qlc\"} 100"), "{text}");
    }

    #[test]
    fn empty_hist_renders_nan_quantiles() {
        let reg = Registry::new();
        let _ = reg.hist("never_recorded_ns");
        let text = reg.snapshot().to_prometheus();
        assert!(
            text.contains("never_recorded_ns{quantile=\"0.5\"} NaN"),
            "{text}"
        );
    }

    /// Random values, random shard partition: merging per-shard
    /// histograms (in shuffled order) must equal one histogram that
    /// recorded everything — the invariant cross-rank merge rests on.
    #[test]
    fn prop_merge_is_order_and_partition_invariant() {
        prop::check(
            "hist_merge_invariance",
            Config { cases: 64, base_seed: 0x0b5e, max_size: 512 },
            |rng, size| {
                let n = rng.below(size.max(1) as u64) as usize;
                let values: Vec<u64> =
                    (0..n).map(|_| rng.next_u64() >> (rng.below(64) as u32)).collect();
                let shards = 1 + rng.below(7) as usize;
                // Single recorder over everything.
                let single = Hist::detached();
                for &v in &values {
                    single.record(v);
                }
                // Sharded recorders, assigned pseudo-randomly.
                let parts: Vec<Hist> =
                    (0..shards).map(|_| Hist::detached()).collect();
                for &v in &values {
                    parts[rng.below(shards as u64) as usize].record(v);
                }
                // Merge in a rotated (i.e. non-canonical) order.
                let start = rng.below(shards as u64) as usize;
                let mut merged = HistSnapshot::default();
                for i in 0..shards {
                    merged.merge(&parts[(start + i) % shards].snapshot());
                }
                if merged != single.snapshot() {
                    return Err(format!(
                        "merged {shards} shards != single recorder for \
                         {n} values"
                    ));
                }
                Ok(())
            },
        );
    }

    /// Quantiles must be monotone in `q` and bracket the recorded
    /// range (upper-edge estimates are ≥ the true quantile value and
    /// ≤ 2× its bucket ceiling).
    #[test]
    fn prop_quantiles_monotone() {
        prop::check(
            "hist_quantile_monotone",
            Config { cases: 64, base_seed: 0x9a17, max_size: 512 },
            |rng, size| {
                let n = 1 + rng.below(size.max(1) as u64) as usize;
                let h = Hist::detached();
                let mut max = 0u64;
                for _ in 0..n {
                    let v = rng.next_u64() >> (rng.below(64) as u32);
                    max = max.max(v);
                    h.record(v);
                }
                let s = h.snapshot();
                let mut prev = None;
                for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
                    let v = s
                        .quantile(q)
                        .ok_or("non-empty hist returned None")?;
                    if let Some(p) = prev {
                        if v < p {
                            return Err(format!(
                                "quantile({q}) = {v} < previous {p}"
                            ));
                        }
                    }
                    prev = Some(v);
                }
                // p100 is the upper edge of the max value's bucket.
                let top = s.quantile(1.0).ok_or("empty")?;
                if top < max {
                    return Err(format!("p100 {top} < recorded max {max}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn snapshot_merge_adds_counters_and_buckets() {
        let a_reg = Registry::new();
        a_reg.counter("c").add(1);
        a_reg.hist("h").record(8);
        let b_reg = Registry::new();
        b_reg.counter("c").add(2);
        b_reg.counter("only_b").add(5);
        b_reg.hist("h").record(8);
        let mut merged = a_reg.snapshot();
        merged.merge(&b_reg.snapshot());
        assert_eq!(merged.counters["c"], 3);
        assert_eq!(merged.counters["only_b"], 5);
        assert_eq!(merged.hists["h"].count, 2);
        assert_eq!(merged.hists["h"].buckets[bucket_index(8)], 2);
    }

    #[test]
    fn stopwatch_reports_monotone_ns() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_ns();
        let b = sw.elapsed_ns();
        assert!(b >= a);
    }
}
