//! Exporters: Chrome trace-event JSON and metrics snapshots.
//!
//! The trace format is the Chrome/Perfetto "trace event" JSON object:
//! `{"traceEvents": [...]}` with complete-duration (`ph:"X"`) events —
//! `ts`/`dur` in *microseconds* — plus `ph:"M"` metadata events naming
//! each process and thread.  A `qlc launch` world merges one such
//! trace per rank ([`merge_chrome_traces`]), with the rank as the
//! `pid`, so Perfetto shows one process track per rank and one thread
//! track per worker thread.
//!
//! Metrics go out via [`write_metrics`]: a `.json` path gets the
//! [`Snapshot`] JSON form (machine-mergeable), any other path gets the
//! Prometheus-style text exposition (human-readable, carries
//! p50/p90/p99 per histogram).

use std::path::Path;

use crate::obs::registry::Snapshot;
use crate::obs::span::{drain_events, ThreadEvents};
use crate::util::json::Json;

/// Build one Chrome trace-event JSON object from drained span events.
/// `pid` labels every event (one pid per rank in a launch world) and
/// `process_name` becomes its Perfetto track title.
pub fn chrome_trace_from(
    threads: &[ThreadEvents],
    pid: u64,
    process_name: &str,
) -> Json {
    let mut events: Vec<Json> = Vec::new();
    events.push(
        Json::obj()
            .set("ph", "M")
            .set("name", "process_name")
            .set("pid", pid as f64)
            .set("tid", 0.0)
            .set("args", Json::obj().set("name", process_name)),
    );
    for t in threads {
        events.push(
            Json::obj()
                .set("ph", "M")
                .set("name", "thread_name")
                .set("pid", pid as f64)
                .set("tid", t.tid as f64)
                .set(
                    "args",
                    Json::obj().set(
                        "name",
                        format!("{} (tid {})", t.thread_name, t.tid),
                    ),
                ),
        );
        for ev in &t.events {
            let mut args = Json::obj();
            for (k, v) in &ev.args {
                args = args.set(k, v.as_str());
            }
            events.push(
                Json::obj()
                    .set("ph", "X")
                    .set("name", ev.name.as_str())
                    .set("pid", pid as f64)
                    .set("tid", t.tid as f64)
                    .set("ts", ev.start_ns as f64 / 1000.0)
                    .set("dur", ev.dur_ns as f64 / 1000.0)
                    .set("args", args),
            );
        }
        if t.dropped > 0 {
            // Surface ring overflow in the trace itself rather than
            // silently under-reporting.
            events.push(
                Json::obj()
                    .set("ph", "M")
                    .set("name", "dropped_events")
                    .set("pid", pid as f64)
                    .set("tid", t.tid as f64)
                    .set(
                        "args",
                        Json::obj().set("dropped", t.dropped as f64),
                    ),
            );
        }
    }
    Json::obj().set("traceEvents", events)
}

/// Drain this process's span rings into a Chrome trace object.
pub fn chrome_trace(pid: u64, process_name: &str) -> Json {
    chrome_trace_from(&drain_events(), pid, process_name)
}

/// Concatenate the `traceEvents` arrays of several traces (typically
/// one per rank, each already stamped with its own pid).
pub fn merge_chrome_traces(traces: &[Json]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for t in traces {
        if let Some(arr) = t.get("traceEvents").and_then(|e| e.as_arr()) {
            events.extend(arr.iter().cloned());
        }
    }
    Json::obj().set("traceEvents", events)
}

/// Drain spans and write a Chrome trace file.
pub fn write_trace(
    path: &Path,
    pid: u64,
    process_name: &str,
) -> std::io::Result<()> {
    let trace = chrome_trace(pid, process_name);
    std::fs::write(path, trace.to_string_pretty())
}

/// Write a metrics snapshot: `.json` paths get the JSON form, anything
/// else the Prometheus-style text exposition.
pub fn write_metrics(path: &Path, snap: &Snapshot) -> std::io::Result<()> {
    let is_json = path
        .extension()
        .map_or(false, |e| e.eq_ignore_ascii_case("json"));
    let body = if is_json {
        snap.to_json().to_string_pretty()
    } else {
        snap.to_prometheus()
    };
    std::fs::write(path, body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Registry;
    use crate::obs::span::tests::{drain_named, trace_lock};
    use crate::obs::span::{set_trace, span, SpanEvent};
    use crate::util::prop::{self, Config};
    use crate::util::rng::Rng;

    fn arb_threads(rng: &mut Rng, size: usize) -> Vec<ThreadEvents> {
        let n_threads = rng.below(4) as usize;
        (0..n_threads)
            .map(|i| {
                let n_ev = rng.below(size.max(1) as u64) as usize;
                let events = (0..n_ev)
                    .map(|_| SpanEvent {
                        name: format!("ev{}", rng.below(5)),
                        start_ns: rng.next_u64() >> 20,
                        dur_ns: rng.next_u64() >> 24,
                        args: if rng.below(2) == 0 {
                            vec![(
                                "k\"quoted\\".to_string(),
                                format!("v{}", rng.below(9)),
                            )]
                        } else {
                            Vec::new()
                        },
                    })
                    .collect();
                ThreadEvents {
                    tid: i as u64 + 1,
                    thread_name: format!("w{i}"),
                    events,
                    dropped: rng.below(2),
                }
            })
            .collect()
    }

    /// The export must round-trip through the repo's own JSON parser
    /// (i.e. be valid JSON even with hostile span args) and every
    /// duration event must carry a non-negative `dur`.
    #[test]
    fn prop_chrome_trace_is_valid_json_with_nonnegative_durations() {
        prop::check(
            "chrome_trace_valid",
            Config { cases: 48, base_seed: 0xc0de, max_size: 64 },
            |rng, size| {
                let threads = arb_threads(rng, size);
                let n_events: usize =
                    threads.iter().map(|t| t.events.len()).sum();
                let trace = chrome_trace_from(&threads, 7, "rank 7");
                let text = trace.to_string_pretty();
                let parsed = Json::parse(&text)
                    .map_err(|e| format!("invalid JSON: {e}"))?;
                let arr = parsed
                    .get("traceEvents")
                    .and_then(|e| e.as_arr())
                    .ok_or("missing traceEvents")?;
                let mut n_x = 0usize;
                for ev in arr {
                    let ph = ev
                        .get("ph")
                        .and_then(|p| p.as_str())
                        .ok_or("event missing ph")?;
                    if ph != "X" {
                        continue;
                    }
                    n_x += 1;
                    let dur = ev
                        .get("dur")
                        .and_then(|d| d.as_f64())
                        .ok_or("X event missing dur")?;
                    if dur < 0.0 {
                        return Err(format!("negative dur {dur}"));
                    }
                    if ev.get("pid").and_then(|p| p.as_f64()) != Some(7.0) {
                        return Err("wrong pid".into());
                    }
                }
                if n_x != n_events {
                    return Err(format!(
                        "{n_x} X events exported for {n_events} spans"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn merge_concatenates_rank_traces() {
        let mk = |pid: u64| {
            chrome_trace_from(
                &[ThreadEvents {
                    tid: 1,
                    thread_name: "main".into(),
                    events: vec![SpanEvent {
                        name: "hop".into(),
                        start_ns: 1000,
                        dur_ns: 500,
                        args: Vec::new(),
                    }],
                    dropped: 0,
                }],
                pid,
                &format!("rank {pid}"),
            )
        };
        let merged = merge_chrome_traces(&[mk(0), mk(1), mk(2)]);
        let arr = merged.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        let mut pids: Vec<f64> = arr
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .filter_map(|e| e.get("pid").and_then(|p| p.as_f64()))
            .collect();
        pids.sort_by(f64::total_cmp);
        assert_eq!(pids, vec![0.0, 1.0, 2.0]);
        // One process_name metadata record per rank survives the merge.
        let names = arr
            .iter()
            .filter(|e| {
                e.get("name").and_then(|n| n.as_str())
                    == Some("process_name")
            })
            .count();
        assert_eq!(names, 3);
    }

    #[test]
    fn live_spans_export_through_chrome_trace() {
        let _g = trace_lock();
        set_trace(true);
        {
            let _s = span("export_test_live").arg("band", 2);
        }
        set_trace(false);
        let events = drain_named("export_test_live");
        assert_eq!(events.len(), 1);
        let trace = chrome_trace_from(
            &[ThreadEvents {
                tid: 9,
                thread_name: "t".into(),
                events,
                dropped: 0,
            }],
            0,
            "rank 0",
        );
        let text = trace.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        let arr = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        let x = arr
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .unwrap();
        assert_eq!(
            x.get("name").and_then(|n| n.as_str()),
            Some("export_test_live")
        );
        assert_eq!(
            x.get("args").and_then(|a| a.get("band")).and_then(|b| b.as_str()),
            Some("2")
        );
    }

    #[test]
    fn write_metrics_picks_format_by_extension() {
        let reg = Registry::new();
        reg.counter("c_total").add(4);
        reg.hist("d_ns").record(1_000);
        let snap = reg.snapshot();
        let dir = std::env::temp_dir();
        let txt = dir.join("qlc_obs_test_metrics.txt");
        let json = dir.join("qlc_obs_test_metrics.json");
        write_metrics(&txt, &snap).unwrap();
        write_metrics(&json, &snap).unwrap();
        let prom = std::fs::read_to_string(&txt).unwrap();
        assert!(prom.contains("c_total 4"), "{prom}");
        assert!(prom.contains("d_ns{quantile=\"0.5\"}"), "{prom}");
        let back =
            Snapshot::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(back, snap);
        let _ = std::fs::remove_file(&txt);
        let _ = std::fs::remove_file(&json);
    }
}
