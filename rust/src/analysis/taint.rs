//! Intra-procedural wire-taint analysis for `qlc analyze` v2.
//!
//! Runs over the statement trees recovered by [`super::cfg`] and
//! tracks, per function, which values are *wire-derived* (attacker
//! shaped): reads of wire-named parameters and struct fields
//! (`payload_len`, `n_symbols`, ...), and results of
//! `from_le_bytes`-family decodes.  Taint propagates through `let`
//! bindings and assignments; it is killed by **sanitizers**:
//!
//! * a comparison guard whose branch diverges (`if len > CAP
//!   { return Err(..) }`) or encloses the use (`if len <= CAP
//!   { .. }`),
//! * bounding calls — any opaque call result is clean, which covers
//!   `.min(cap)`, `try_from`, `checked_mul`, `saturating_sub`, and
//!   `.len()` of in-memory buffers alike,
//! * `%` (modulo bounds the result by its right operand),
//! * a `while` condition's negation after the loop exits.
//!
//! **Sinks** are allocations (`with_capacity` / `vec![x; n]` /
//! `reserve` / `resize`), narrowing `as u8/u16/u32` casts, slice
//! indexing, and `for`/`while` loop bounds.  A tainted value that
//! went through unchecked `+`/`*` arithmetic and then reaches a sink
//! is reported as arithmetic instead, since overflow there defeats
//! any later cap.  Every finding carries the taint chain (source →
//! intermediate bindings → sink) so the report reads as a dataflow
//! witness, not a line match.
//!
//! The module also hosts the reactor-lifecycle check
//! ([`reactor_leaks`]): a `Reactor::register` call must not be
//! followed by an early exit (`?` or `return`) before the function's
//! next `deregister` — the fd-interest analogue of kill-on-drop.

use std::collections::BTreeMap;

use super::cfg::{
    self, is_close, is_open, pattern_names, skip_group, text_at, Block,
    Function, Stmt, Tok, TokKind,
};

/// Taint attached to one value: the dataflow chain from its source,
/// plus whether it went through unchecked `+`/`*` arithmetic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Taint {
    pub chain: Vec<String>,
    pub arith: bool,
}

/// Per-path facts: `Some(taint)` = tainted, `None` = proven clean.
/// Paths absent from the map fall back to the wire-name vocabulary.
type State = BTreeMap<String, Option<Taint>>;

/// What kind of sink a tainted value reached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SinkKind {
    /// `with_capacity` / `vec![x; n]` / `reserve` / `resize`.
    Alloc,
    /// Slice or array indexing.
    Index,
    /// `as u8` / `as u16` / `as u32`.
    Narrow,
    /// A `for` iterator or `while` condition.
    LoopBound,
    /// Unchecked `+`/`*` on tainted lengths reaching any sink above.
    Arith,
}

/// One taint finding, positioned at the sink.
#[derive(Clone, Debug)]
pub struct TaintFinding {
    pub line: usize,
    pub kind: SinkKind,
    /// Short sink description (`"with_capacity argument"`, ...).
    pub what: String,
    /// Source-to-sink dataflow chain, rendered per step.
    pub chain: Vec<String>,
}

/// Does `name` read as a wire-shaped count/length/ordinal?  This is
/// the taint vocabulary: parameters and struct fields with these
/// names are wire-derived unless the analysis proves otherwise.
pub fn wire_named(name: &str) -> bool {
    if name.chars().any(|c| c.is_ascii_uppercase()) {
        return false; // SCREAMING_CASE caps and type names are not values
    }
    matches!(
        name,
        "n" | "len" | "count" | "size" | "seq" | "hop" | "rank" | "world"
    ) || name.starts_with("n_")
        || name.ends_with("len")
        || name.ends_with("_count")
        || name.ends_with("_size")
        || name.ends_with("_symbols")
        || name.ends_with("_chunks")
        || name.ends_with("_shards")
        || name.ends_with("_scales")
}

/// Byte-decode constructors whose results are wire-derived.
fn is_source_call(name: &str) -> bool {
    matches!(name, "from_le_bytes" | "from_be_bytes" | "from_ne_bytes")
}

/// Constructor-like calls that pass their argument through
/// unchanged: enum/tuple-struct constructors (`Some`, `Ok`, ...) and
/// lossless `From` conversions.
fn propagates(name: &str) -> bool {
    name == "from"
        || name
            .chars()
            .next()
            .is_some_and(|c| c.is_ascii_uppercase())
}

/// Comparison-shaped tokens that make a condition a range guard.
fn has_comparison(toks: &[Tok]) -> bool {
    toks.iter().any(|t| {
        (t.kind == TokKind::Punct
            && matches!(
                t.text.as_str(),
                "<" | ">" | "<=" | ">=" | "==" | "!="
            ))
            || (t.kind == TokKind::Ident
                && matches!(t.text.as_str(), "contains" | "matches"))
    })
}

/// Does this `let` initializer start a block expression whose inner
/// statements carry their own control flow?
fn is_block_expr(toks: &[Tok]) -> bool {
    matches!(
        text_at(toks, 0),
        "if" | "match" | "loop" | "while" | "unsafe" | "{"
    )
}

/// Result of evaluating one expression's token list.
struct Eval {
    taint: Option<Taint>,
    /// Normalized paths read with taint (candidates for guard
    /// sanitization when the enclosing condition compares them).
    reads: Vec<String>,
}

struct Engine {
    file: String,
    findings: Vec<TaintFinding>,
}

/// Read a dotted/pathed term (`a.b.c`, `u32::try_from`, `t.0`)
/// starting at `i`; returns the segments and the next index.
fn read_path(toks: &[Tok], mut i: usize) -> (Vec<String>, usize) {
    let mut segs = Vec::new();
    while i < toks.len() {
        let t = &toks[i];
        if t.kind == TokKind::Ident || (t.kind == TokKind::Num && !segs.is_empty())
        {
            segs.push(t.text.clone());
            i += 1;
            let sep = text_at(toks, i);
            let next_is_seg = toks
                .get(i + 1)
                .is_some_and(|u| u.kind == TokKind::Ident || u.kind == TokKind::Num);
            if (sep == "." || sep == "::") && next_is_seg {
                i += 1;
                continue;
            }
            break;
        }
        break;
    }
    (segs, i)
}

/// The primary-expression tokens immediately before an `as` at
/// `as_idx` — the cast operand (`(rank + 1) as u32` captures the
/// whole parenthesized group).
fn operand_before(toks: &[Tok], as_idx: usize) -> &[Tok] {
    let mut k = as_idx as isize - 1;
    while k >= 0 {
        let t = &toks[k as usize];
        let txt = t.text.as_str();
        if is_close(txt) {
            // Walk back over the whole group.
            let mut depth = 0isize;
            let mut moved = false;
            while k >= 0 {
                let u = text_at(toks, k as usize);
                if is_close(u) {
                    depth += 1;
                } else if is_open(u) {
                    depth -= 1;
                    if depth == 0 {
                        k -= 1;
                        moved = true;
                        break;
                    }
                }
                k -= 1;
            }
            if !moved {
                break;
            }
            continue;
        }
        if (t.kind == TokKind::Ident
            && !cfg::KEYWORDS.contains(&txt))
            || t.kind == TokKind::Num
            || txt == "."
            || txt == "::"
        {
            k -= 1;
            continue;
        }
        break;
    }
    let start = (k + 1).max(0) as usize;
    &toks[start..as_idx]
}

impl Engine {
    fn lookup(&self, st: &State, key: &str, line: usize) -> Option<Taint> {
        if let Some(v) = st.get(key) {
            return v.clone();
        }
        // A tainted base taints every field under it.
        let mut p = key;
        while let Some(cut) = p.rfind('.') {
            p = &p[..cut];
            if let Some(Some(t)) = st.get(p) {
                let mut t = t.clone();
                if t.chain.len() < 8 {
                    t.chain.push(format!(
                        "field `{key}` of tainted `{p}` at {}:{line}",
                        self.file
                    ));
                }
                return Some(t);
            }
        }
        // Vocabulary fallback: wire-named fields/params are tainted
        // until a guard or a binding proves otherwise.
        let last = key.rsplit('.').next().unwrap_or(key);
        if wire_named(last) {
            return Some(Taint {
                chain: vec![format!(
                    "wire-shaped value `{key}` read at {}:{line}",
                    self.file
                )],
                arith: false,
            });
        }
        None
    }

    /// Evaluate an expression token list under `st`.
    fn eval(&self, st: &State, toks: &[Tok]) -> Eval {
        let mut taint: Option<Taint> = None;
        let mut reads: Vec<String> = Vec::new();
        let mut arith = false;
        let mut modulo = false;
        let mut checked = false;
        let mut i = 0usize;
        while i < toks.len() {
            let t = &toks[i];
            if t.kind == TokKind::Ident {
                let (segs, next) = read_path(toks, i);
                if segs.is_empty() {
                    i += 1;
                    continue;
                }
                let last = segs.last().map(String::as_str).unwrap_or("");
                if text_at(toks, next) == "(" {
                    let end = skip_group(toks, next);
                    let inner_end = end.saturating_sub(1);
                    let inner = if next + 1 <= inner_end {
                        &toks[next + 1..inner_end]
                    } else {
                        &[]
                    };
                    if is_source_call(last) {
                        merge(
                            &mut taint,
                            Taint {
                                chain: vec![format!(
                                    "decoded via `{last}` at {}:{}",
                                    self.file, t.line
                                )],
                                arith: false,
                            },
                        );
                    } else if propagates(last) {
                        let sub = self.eval(st, inner);
                        if let Some(tn) = sub.taint {
                            merge(&mut taint, tn);
                        }
                        reads.extend(sub.reads);
                    } else {
                        // Opaque or bounding call: result is clean.
                        // A postfix method (`(..).min(cap)`) consumes
                        // the receiver's accumulated taint too.
                        if i > 0 && toks[i - 1].is(".") {
                            taint = None;
                            arith = false;
                        }
                        if last.starts_with("checked_")
                            || last.starts_with("saturating_")
                        {
                            checked = true;
                        }
                    }
                    i = end;
                    continue;
                }
                if text_at(toks, next) == "!" {
                    // Macro invocation: its body is scanned for
                    // sinks elsewhere; the value is opaque here.
                    let after = next + 1;
                    if is_open(text_at(toks, after)) {
                        i = skip_group(toks, after);
                    } else {
                        i = after;
                    }
                    continue;
                }
                // A path followed by a single `:` is a struct-literal
                // field name or ascription, not a value read.
                if text_at(toks, next) == ":" {
                    i = next + 1;
                    continue;
                }
                let key = segs.join(".");
                if let Some(tn) = self.lookup(st, &key, t.line) {
                    if !reads.contains(&key) {
                        reads.push(key.clone());
                    }
                    merge(&mut taint, tn);
                }
                i = next;
                continue;
            }
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "%" => modulo = true,
                    "+" | "*" => {
                        // Binary only: a primary must end just left.
                        if i > 0 {
                            let p = &toks[i - 1];
                            if p.kind == TokKind::Ident
                                || p.kind == TokKind::Num
                                || is_close(&p.text)
                            {
                                arith = true;
                            }
                        }
                    }
                    _ => {}
                }
            }
            i += 1;
        }
        if modulo {
            // `x % bound` is bounded by construction.
            return Eval { taint: None, reads };
        }
        if let Some(tn) = taint.as_mut() {
            if arith && !checked {
                tn.arith = true;
            }
        }
        Eval { taint, reads }
    }

    fn bind(
        &self,
        st: &mut State,
        names: &[String],
        taint: &Option<Taint>,
        line: usize,
    ) {
        for n in names {
            let v = taint.clone().map(|mut t| {
                if t.chain.len() < 8 {
                    t.chain.push(format!(
                        "flows into `{n}` at {}:{line}",
                        self.file
                    ));
                }
                t
            });
            st.insert(n.clone(), v);
        }
    }

    fn sanitize(&self, st: &mut State, paths: &[String]) {
        for p in paths {
            st.insert(p.clone(), None);
        }
    }

    fn sink(
        &mut self,
        st: &State,
        toks: &[Tok],
        kind: SinkKind,
        line: usize,
        what: &str,
    ) {
        let ev = self.eval(st, toks);
        if let Some(t) = ev.taint {
            let kind = if t.arith && kind != SinkKind::LoopBound {
                SinkKind::Arith
            } else {
                kind
            };
            self.findings.push(TaintFinding {
                line,
                kind,
                what: what.to_string(),
                chain: t.chain,
            });
        }
    }

    /// Scan a flat token list for sinks (allocations, narrowing
    /// casts, indexing) and report the tainted ones.
    fn check_sinks(&mut self, st: &State, toks: &[Tok]) {
        let mut i = 0usize;
        while i < toks.len() {
            let t = &toks[i];
            if t.kind == TokKind::Ident
                && t.is("vec")
                && text_at(toks, i + 1) == "!"
                && text_at(toks, i + 2) == "["
            {
                let end = skip_group(toks, i + 2);
                let inner_end = end.saturating_sub(1);
                let inner = if i + 3 <= inner_end {
                    &toks[i + 3..inner_end]
                } else {
                    &[]
                };
                // `vec![elem; len]`: only the length is a sink.
                let mut depth = 0isize;
                let mut semi = None;
                for (k, u) in inner.iter().enumerate() {
                    if is_open(&u.text) {
                        depth += 1;
                    } else if is_close(&u.text) {
                        depth -= 1;
                    } else if u.is(";") && depth == 0 {
                        semi = Some(k);
                    }
                }
                if let Some(k) = semi {
                    self.sink(
                        st,
                        &inner[k + 1..],
                        SinkKind::Alloc,
                        t.line,
                        "vec! length",
                    );
                }
                i = end;
                continue;
            }
            if t.kind == TokKind::Ident
                && matches!(
                    t.text.as_str(),
                    "with_capacity" | "reserve" | "reserve_exact" | "resize"
                )
                && text_at(toks, i + 1) == "("
            {
                let end = skip_group(toks, i + 1);
                let inner_end = end.saturating_sub(1);
                let inner = if i + 2 <= inner_end {
                    &toks[i + 2..inner_end]
                } else {
                    &[]
                };
                // For `resize(len, fill)` only the length matters.
                let mut arg = inner;
                if t.is("resize") {
                    let mut depth = 0isize;
                    for (k, u) in inner.iter().enumerate() {
                        if is_open(&u.text) {
                            depth += 1;
                        } else if is_close(&u.text) {
                            depth -= 1;
                        } else if u.is(",") && depth == 0 {
                            arg = &inner[..k];
                            break;
                        }
                    }
                }
                let what = format!("`{}` argument", t.text);
                self.sink(st, arg, SinkKind::Alloc, t.line, &what);
                i = end;
                continue;
            }
            if t.kind == TokKind::Ident && t.is("as") {
                let target = text_at(toks, i + 1);
                if matches!(target, "u8" | "u16" | "u32") {
                    let operand = operand_before(toks, i);
                    let what = format!("`as {target}` cast");
                    self.sink(st, operand, SinkKind::Narrow, t.line, &what);
                    i += 2;
                    continue;
                }
            }
            if t.is("[") && i > 0 {
                let p = &toks[i - 1];
                let indexable = (p.kind == TokKind::Ident
                    && !cfg::KEYWORDS.contains(&p.text.as_str()))
                    || p.is(")")
                    || p.is("]");
                if indexable {
                    let end = skip_group(toks, i);
                    let inner_end = end.saturating_sub(1);
                    if i + 1 <= inner_end {
                        self.sink(
                            st,
                            &toks[i + 1..inner_end],
                            SinkKind::Index,
                            t.line,
                            "slice index",
                        );
                    }
                }
                // Fall through so nested groups are scanned too.
            }
            i += 1;
        }
    }

    fn run_block(&mut self, b: &Block, st: &mut State) -> bool {
        let mut diverged = false;
        for s in &b.stmts {
            if diverged {
                break; // unreachable
            }
            diverged = self.run_stmt(s, st);
        }
        diverged
    }

    fn run_stmt(&mut self, s: &Stmt, st: &mut State) -> bool {
        match s {
            Stmt::Let { names, rhs, else_block, line } => {
                if is_block_expr(rhs) {
                    // `let x = match .. { .. }` / `= if .. { .. }` /
                    // `= loop { .. }`: run the initializer
                    // structurally so arm-local guards reach their
                    // sinks, instead of scanning it as flat tokens.
                    let stmts = cfg::parse_stmts(rhs);
                    let mut sub = st.clone();
                    let mut diverged = false;
                    for s in &stmts {
                        if diverged {
                            break;
                        }
                        diverged = self.run_stmt(s, &mut sub);
                    }
                    *st = join(st, &sub);
                    let ev = self.eval(st, rhs);
                    self.bind(st, names, &ev.taint, *line);
                    return false;
                }
                self.check_sinks(st, rhs);
                let ev = self.eval(st, rhs);
                if let Some(eb) = else_block {
                    // The else block diverges by language rule; run
                    // it for its own sinks under the pre-state.
                    let mut est = st.clone();
                    let _ = self.run_block(eb, &mut est);
                }
                self.bind(st, names, &ev.taint, *line);
                false
            }
            Stmt::Assign { lhs, op, rhs, line } => {
                self.check_sinks(st, lhs);
                self.check_sinks(st, rhs);
                let ev = self.eval(st, rhs);
                if let Some(key) = place_key(lhs) {
                    let merged = if op == "=" {
                        ev.taint.clone()
                    } else {
                        // Compound assignment keeps existing taint.
                        let cur = self.lookup(st, &key, *line);
                        let arith_op =
                            matches!(op.as_str(), "+=" | "*=" | "<<=");
                        match (cur, ev.taint.clone()) {
                            (None, None) => None,
                            (a, b) => {
                                let mut t = a.or(b).unwrap_or(Taint {
                                    chain: Vec::new(),
                                    arith: false,
                                });
                                if arith_op {
                                    t.arith = true;
                                }
                                Some(t)
                            }
                        }
                    };
                    self.bind(st, &[key], &merged, *line);
                }
                false
            }
            Stmt::If { cond, then_block, else_block, line } => {
                let (binders, cexpr) = split_let(cond);
                self.check_sinks(st, cexpr);
                let ev = self.eval(st, cexpr);
                let guard = has_comparison(cexpr);
                let mut then_st = st.clone();
                if guard {
                    self.sanitize(&mut then_st, &ev.reads);
                }
                self.bind(&mut then_st, &binders, &ev.taint, *line);
                let then_div = self.run_block(then_block, &mut then_st);
                match else_block {
                    Some(eb) => {
                        let mut else_st = st.clone();
                        let else_div = self.run_block(eb, &mut else_st);
                        match (then_div, else_div) {
                            (true, true) => true,
                            (true, false) => {
                                *st = else_st;
                                false
                            }
                            (false, true) => {
                                *st = then_st;
                                false
                            }
                            (false, false) => {
                                *st = join(&then_st, &else_st);
                                false
                            }
                        }
                    }
                    None => {
                        if then_div {
                            // `if bad { return Err }`: the
                            // fall-through is the sanitized world.
                            if guard {
                                self.sanitize(st, &ev.reads);
                            }
                        } else {
                            *st = join(st, &then_st);
                        }
                        false
                    }
                }
            }
            Stmt::While { cond, body, line } => {
                let (binders, cexpr) = split_let(cond);
                self.check_sinks(st, cexpr);
                let ev = self.eval(st, cexpr);
                if binders.is_empty() {
                    if let Some(t) = &ev.taint {
                        self.findings.push(TaintFinding {
                            line: *line,
                            kind: SinkKind::LoopBound,
                            what: "`while` bound".to_string(),
                            chain: t.chain.clone(),
                        });
                    }
                }
                let mut body_st = st.clone();
                self.bind(&mut body_st, &binders, &ev.taint, *line);
                let _ = self.run_block(body, &mut body_st);
                *st = join(st, &body_st);
                // On exit the condition is false: its compared
                // paths are bounded (`while len > CAP { shrink }`).
                if has_comparison(cexpr) {
                    self.sanitize(st, &ev.reads);
                }
                false
            }
            Stmt::For { names, iter, body, line } => {
                self.check_sinks(st, iter);
                let ev = self.eval(st, iter);
                if let Some(t) = &ev.taint {
                    self.findings.push(TaintFinding {
                        line: *line,
                        kind: SinkKind::LoopBound,
                        what: "`for` iterator bound".to_string(),
                        chain: t.chain.clone(),
                    });
                }
                let mut body_st = st.clone();
                self.bind(&mut body_st, names, &ev.taint, *line);
                let _ = self.run_block(body, &mut body_st);
                *st = join(st, &body_st);
                false
            }
            Stmt::Loop { body, .. } => {
                let mut body_st = st.clone();
                let _ = self.run_block(body, &mut body_st);
                *st = join(st, &body_st);
                false
            }
            Stmt::Match { scrutinee, arms, line } => {
                self.check_sinks(st, scrutinee);
                let ev = self.eval(st, scrutinee);
                let mut exits: Vec<State> = Vec::new();
                let mut all_div = !arms.is_empty();
                for (binders, blk) in arms {
                    let mut s = st.clone();
                    self.bind(&mut s, binders, &ev.taint, *line);
                    let d = self.run_block(blk, &mut s);
                    if !d {
                        exits.push(s);
                        all_div = false;
                    }
                }
                if let Some((first, rest)) = exits.split_first() {
                    let mut j = first.clone();
                    for s in rest {
                        j = join(&j, s);
                    }
                    *st = j;
                }
                all_div
            }
            Stmt::Return { value, .. } => {
                self.check_sinks(st, value);
                true
            }
            Stmt::Break { .. } | Stmt::Continue { .. } => true,
            Stmt::BlockStmt { body, .. } => self.run_block(body, st),
            Stmt::Expr { toks, .. } => {
                self.check_sinks(st, toks);
                false
            }
        }
    }
}

fn merge(dst: &mut Option<Taint>, src: Taint) {
    match dst {
        None => *dst = Some(src),
        Some(d) => d.arith |= src.arith, // keep the first chain
    }
}

fn join(a: &State, b: &State) -> State {
    let mut out = a.clone();
    for (k, v) in b {
        match (out.get(k), v) {
            // Tainted on either branch stays tainted.
            (Some(Some(_)), _) => {}
            (_, Some(t)) => {
                out.insert(k.clone(), Some(t.clone()));
            }
            (Some(None), None) => {}
            (None, None) => {
                out.insert(k.clone(), None);
            }
        }
    }
    out
}

/// `if let PAT = EXPR` / `while let PAT = EXPR`: pattern binders and
/// the scrutinee expression; plain conditions bind nothing.
fn split_let(cond: &[Tok]) -> (Vec<String>, &[Tok]) {
    if text_at(cond, 0) != "let" {
        return (Vec::new(), cond);
    }
    let mut depth = 0isize;
    for (k, t) in cond.iter().enumerate().skip(1) {
        if is_open(&t.text) {
            depth += 1;
        } else if is_close(&t.text) {
            depth -= 1;
        } else if t.is("=") && depth == 0 {
            return (pattern_names(&cond[1..k]), &cond[k + 1..]);
        }
    }
    (Vec::new(), cond)
}

/// A pure assignable path (`x`, `self.a.b`) as a state key; complex
/// places (`arr[i]`, `*p`) return `None` and only get sink-checked.
fn place_key(lhs: &[Tok]) -> Option<String> {
    let mut segs = Vec::new();
    for (k, t) in lhs.iter().enumerate() {
        if t.kind == TokKind::Ident || (t.kind == TokKind::Num && k > 0) {
            segs.push(t.text.clone());
        } else if t.is(".") || t.is("::") {
            continue;
        } else {
            return None;
        }
    }
    if segs.is_empty() {
        None
    } else {
        Some(segs.join("."))
    }
}

/// Analyze one function; returns findings positioned at their sinks.
pub fn analyze_fn(file: &str, func: &Function) -> Vec<TaintFinding> {
    let mut eng =
        Engine { file: file.to_string(), findings: Vec::new() };
    let mut st = State::new();
    for p in &func.params {
        if p != "self" && wire_named(p) {
            st.insert(
                p.clone(),
                Some(Taint {
                    chain: vec![format!(
                        "wire-shaped parameter `{p}` of `{}` at {file}:{}",
                        func.name, func.line
                    )],
                    arith: false,
                }),
            );
        } else {
            st.insert(p.clone(), None);
        }
    }
    let _ = eng.run_block(&func.body, &mut st);
    eng.findings
}

// ---------------------------------------------------------------
// Reactor interest lifecycle
// ---------------------------------------------------------------

/// One fd-interest leak: a `register` that can exit early before the
/// function's next `deregister`.
#[derive(Clone, Debug)]
pub struct LeakFinding {
    pub reg_line: usize,
    pub exit_line: usize,
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    Reg(usize),
    Dereg(usize),
    Exit(usize),
}

fn has_method_call(toks: &[Tok], name: &str) -> bool {
    toks.windows(3).any(|w| {
        w[0].is(".") && w[1].is(name) && w[1].kind == TokKind::Ident
            && w[2].is("(")
    })
}

fn note_head(toks: &[Tok], line: usize, evs: &mut Vec<Ev>, suppress: bool) {
    let reg = has_method_call(toks, "register");
    if reg {
        evs.push(Ev::Reg(line));
    }
    if has_method_call(toks, "deregister") {
        evs.push(Ev::Dereg(line));
    }
    // A `?` on the register's own statement is its own error path,
    // not a leak of the (never-completed) registration.
    if !suppress && !reg {
        if let Some(q) = toks.iter().find(|t| t.is("?")) {
            evs.push(Ev::Exit(q.line));
        }
    }
}

fn collect_events(b: &Block, evs: &mut Vec<Ev>, suppress: bool) {
    for s in &b.stmts {
        match s {
            Stmt::Let { rhs, else_block, line, .. } => {
                note_head(rhs, *line, evs, suppress);
                if let Some(eb) = else_block {
                    collect_events(eb, evs, suppress);
                }
            }
            Stmt::Assign { lhs, rhs, line, .. } => {
                let mut all = lhs.clone();
                all.extend(rhs.iter().cloned());
                note_head(&all, *line, evs, suppress);
            }
            Stmt::If { cond, then_block, else_block, line } => {
                let reg_in_cond = has_method_call(cond, "register");
                note_head(cond, *line, evs, suppress);
                // Branches of `if reactor.register(..).is_err()` are
                // the register's own error handling.
                let sub = suppress || reg_in_cond;
                collect_events(then_block, evs, sub);
                if let Some(eb) = else_block {
                    collect_events(eb, evs, sub);
                }
            }
            Stmt::While { cond, body, line } => {
                note_head(cond, *line, evs, suppress);
                collect_events(body, evs, suppress);
            }
            Stmt::For { iter, body, line, .. } => {
                note_head(iter, *line, evs, suppress);
                collect_events(body, evs, suppress);
            }
            Stmt::Loop { body, .. } | Stmt::BlockStmt { body, .. } => {
                collect_events(body, evs, suppress);
            }
            Stmt::Match { scrutinee, arms, line } => {
                note_head(scrutinee, *line, evs, suppress);
                for (_, blk) in arms {
                    collect_events(blk, evs, suppress);
                }
            }
            Stmt::Return { value, line } => {
                note_head(value, *line, evs, suppress);
                if !suppress {
                    evs.push(Ev::Exit(*line));
                }
            }
            Stmt::Break { .. } | Stmt::Continue { .. } => {}
            Stmt::Expr { toks, line } => {
                note_head(toks, *line, evs, suppress);
            }
        }
    }
}

/// Find `register` calls that can leak fd interest: an early exit
/// (`?` / `return`) strictly between the `register` and the
/// function's next `deregister`.  A function with no `deregister`
/// after a `register` transfers ownership (the reactor outlives the
/// call) and is not flagged.
pub fn reactor_leaks(func: &Function) -> Vec<LeakFinding> {
    let mut evs = Vec::new();
    collect_events(&func.body, &mut evs, false);
    let mut out = Vec::new();
    for (i, e) in evs.iter().enumerate() {
        let Ev::Reg(reg_line) = e else { continue };
        let Some(off) = evs[i + 1..]
            .iter()
            .position(|x| matches!(x, Ev::Dereg(_)))
        else {
            continue;
        };
        for x in &evs[i + 1..i + 1 + off] {
            if let Ev::Exit(exit_line) = x {
                out.push(LeakFinding {
                    reg_line: *reg_line,
                    exit_line: *exit_line,
                });
                break; // first leaking exit per register is enough
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer;

    fn taint_of(src: &str) -> Vec<TaintFinding> {
        let fns = cfg::parse_functions(&lexer::strip(src).code);
        let mut out = Vec::new();
        for f in &fns {
            out.extend(analyze_fn("src/x.rs", f));
        }
        out
    }

    fn leaks_of(src: &str) -> Vec<LeakFinding> {
        let fns = cfg::parse_functions(&lexer::strip(src).code);
        let mut out = Vec::new();
        for f in &fns {
            out.extend(reactor_leaks(f));
        }
        out
    }

    #[test]
    fn wire_vocabulary_matches_protocol_names() {
        for name in
            ["n", "payload_len", "n_symbols", "header_len", "world", "dlen"]
        {
            assert!(wire_named(name), "{name} should be wire-shaped");
        }
        for name in ["out", "buf", "codec", "reactor", "payload"] {
            assert!(!wire_named(name), "{name} should be neutral");
        }
    }

    #[test]
    fn guard_on_the_wrong_variable_no_longer_suppresses() {
        // PR 6's text heuristic accepted this: the guard line
        // mentions `hdr`, which is also a path segment of the
        // allocation expression.  Flow facts see through it.
        let src = "\
fn f(&self) -> Vec<u8> {
    if self.hdr.n_scales > MAX_SCALES {
        return Vec::new();
    }
    vec![0u8; self.hdr.payload_len]
}
";
        let f = taint_of(src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].kind, SinkKind::Alloc);
        assert_eq!(f[0].line, 5);
        let chain = f[0].chain.join(" -> ");
        assert!(chain.contains("self.hdr.payload_len"), "{chain}");
    }

    #[test]
    fn guard_on_the_right_variable_sanitizes() {
        let src = "\
fn f(&self) -> Vec<u8> {
    if self.hdr.payload_len > MAX_PAYLOAD {
        return Vec::new();
    }
    vec![0u8; self.hdr.payload_len]
}
";
        assert!(taint_of(src).is_empty());
    }

    #[test]
    fn enclosing_guard_sanitizes_the_then_branch() {
        let src = "\
fn f(len: usize) -> Vec<u8> {
    if len <= MAX_BODY {
        return vec![0u8; len];
    }
    Vec::new()
}
";
        assert!(taint_of(src).is_empty());
    }

    #[test]
    fn tainted_loop_bound_is_flagged_and_guard_sanitizes() {
        let bad = "\
fn f(n_chunks: usize) {
    for _ in 0..n_chunks {
        step();
    }
}
";
        let f = taint_of(bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].kind, SinkKind::LoopBound);
        assert_eq!(f[0].line, 2);

        let good = "\
fn f(n_chunks: usize) -> Result<(), String> {
    if n_chunks > MAX_CHUNKS {
        return Err(\"cap\".into());
    }
    for _ in 0..n_chunks {
        step();
    }
    Ok(())
}
";
        assert!(taint_of(good).is_empty());
    }

    #[test]
    fn tainted_while_bound_is_flagged() {
        let src = "\
fn f(mut n: usize) {
    while n > 0 {
        n -= 1;
    }
}
";
        let f = taint_of(src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].kind, SinkKind::LoopBound);
    }

    #[test]
    fn tainted_length_arithmetic_is_flagged_at_the_sink() {
        let src = "\
fn f(n_rows: usize, row_len: usize, out: &mut Vec<u8>) {
    let total = n_rows * row_len;
    out.reserve(total);
}
";
        let f = taint_of(src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].kind, SinkKind::Arith);
        assert_eq!(f[0].line, 3);
        let chain = f[0].chain.join(" -> ");
        assert!(chain.contains("total"), "{chain}");
    }

    #[test]
    fn checked_arithmetic_is_clean() {
        let src = "\
fn f(n_rows: usize, row_len: usize, out: &mut Vec<u8>) -> Result<(), String> {
    let total = n_rows
        .checked_mul(row_len)
        .ok_or(\"overflow\")?;
    if total > MAX_TOTAL {
        return Err(\"cap\".into());
    }
    out.reserve(total);
    Ok(())
}
";
        assert!(taint_of(src).is_empty());
    }

    #[test]
    fn from_le_bytes_is_a_source_and_min_is_a_sanitizer() {
        let bad = "\
fn f(b: [u8; 4]) -> Vec<u8> {
    let want = u32::from_le_bytes(b) as usize;
    Vec::with_capacity(want)
}
";
        let f = taint_of(bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].kind, SinkKind::Alloc);
        assert!(f[0].chain.join(" ").contains("from_le_bytes"));

        let good = "\
fn f(b: [u8; 4]) -> Vec<u8> {
    let want = (u32::from_le_bytes(b) as usize).min(MAX_WANT);
    Vec::with_capacity(want)
}
";
        assert!(taint_of(good).is_empty());
    }

    #[test]
    fn modulo_bounds_the_result() {
        let src = "\
fn f(rank: usize, world: usize, table: &[u8]) -> u8 {
    table[(rank + 1) % world]
}
";
        assert!(taint_of(src).is_empty());
    }

    #[test]
    fn tainted_slice_index_is_flagged() {
        let src = "\
fn f(idx_len: usize, table: &[u8]) -> u8 {
    table[idx_len]
}
";
        let f = taint_of(src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].kind, SinkKind::Index);
    }

    #[test]
    fn while_negation_sanitizes_after_the_loop() {
        // The encode_ack idiom: shrink until under the cap, then
        // allocate by the now-bounded length.
        let src = "\
fn f(mut msg_len: usize, out: &mut Vec<u8>) {
    while msg_len > MAX_ACK {
        msg_len = shrink(msg_len);
    }
    out.reserve(msg_len);
}
";
        let f = taint_of(src);
        // The `while` itself flags the tainted bound; the reserve
        // after the loop must NOT flag.
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].kind, SinkKind::LoopBound);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn opaque_call_results_are_clean() {
        let src = "\
fn f(chunks: &[Chunk]) -> Vec<u8> {
    let total = chunks.iter().map(len_of).sum();
    Vec::with_capacity(total)
}
";
        assert!(taint_of(src).is_empty());
    }

    #[test]
    fn guard_inside_a_match_arm_initializer_sanitizes() {
        // The serve handle_frame shape: the sink lives inside a
        // match arm that is itself a `let` initializer.  The arm's
        // own guard must reach it.
        let src = "\
fn f(&self) -> Result<(Vec<u8>, usize), String> {
    let (payload, n) = match self.op {
        Op::Fill => {
            let n = self.msg.n_symbols;
            if n > MAX_CHUNK {
                return Err(\"cap\".into());
            }
            (vec![0u8; n], n)
        }
        Op::Echo => (Vec::new(), 0),
    };
    Ok((payload, n))
}
";
        assert!(taint_of(src).is_empty());

        let unguarded = "\
fn f(&self) -> Vec<u8> {
    let out = match self.op {
        Op::Fill => vec![0u8; self.msg.n_symbols],
        Op::Echo => Vec::new(),
    };
    out
}
";
        let f = taint_of(unguarded);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].kind, SinkKind::Alloc);
    }

    #[test]
    fn loop_expression_initializer_is_run_structurally() {
        // The client handshake shape: `let ack = loop { .. }` where
        // the slice bound is a Read::read return, proven clean by
        // the inner `let` binding — not a flat-token vocabulary hit.
        let src = "\
fn f(stream: &mut S, inbuf: &mut Vec<u8>) {
    let ack = loop {
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break None;
        }
        inbuf.extend_from_slice(&chunk[..n]);
    };
}
";
        assert!(taint_of(src).is_empty());
    }

    #[test]
    fn register_then_early_exit_before_deregister_leaks() {
        let src = "\
fn open(&mut self, fd: i32) -> Result<(), String> {
    self.reactor.register(fd, 0, READABLE)?;
    self.probe()?;
    self.reactor.deregister(fd)?;
    Ok(())
}
";
        let l = leaks_of(src);
        assert_eq!(l.len(), 1, "{l:?}");
        assert_eq!(l[0].reg_line, 2);
        assert_eq!(l[0].exit_line, 3);
    }

    #[test]
    fn balanced_register_paths_are_clean() {
        let src = "\
fn open(&mut self, fd: i32) -> Result<(), String> {
    self.reactor.register(fd, 0, READABLE)?;
    if self.probe().is_err() {
        let _ = self.reactor.deregister(fd);
        return Err(\"probe\".into());
    }
    self.reactor.deregister(fd)?;
    Ok(())
}
";
        assert!(leaks_of(src).is_empty());
    }

    #[test]
    fn ownership_transfer_without_deregister_is_clean() {
        // The `bind`/`connect` pattern: the registration outlives
        // the constructor; no deregister exists in this scope.
        let src = "\
fn connect(addr: &str) -> Result<Client, String> {
    let reactor = new_reactor()?;
    reactor.register(fd, 0, READABLE)?;
    Ok(Client { reactor })
}
";
        assert!(leaks_of(src).is_empty());
    }

    #[test]
    fn register_inside_its_own_error_check_is_clean() {
        // The accept-loop pattern: the `if` branch handles the
        // failed registration itself.
        let src = "\
fn accept_ready(&mut self) -> Result<(), String> {
    loop {
        if self.reactor.register(fd, tok, READABLE).is_err() {
            continue;
        }
        self.conns.push(fd);
        self.reactor.deregister(fd)?;
    }
}
";
        assert!(leaks_of(src).is_empty());
    }
}
