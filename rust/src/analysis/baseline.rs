//! Baseline file support: `analysis/baseline.txt` grandfathers
//! pre-existing findings so `qlc analyze` fails only on *new*
//! violations.  The format is one rendered finding per line
//! (`file:line: rule: message`), with `#` comments and blank lines
//! ignored; `qlc analyze --update-baseline` regenerates it.

use std::collections::BTreeSet;

use super::rules::Finding;

/// Parse a baseline file into the set of grandfathered finding lines.
pub fn parse(text: &str) -> BTreeSet<String> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

/// Render findings into baseline-file form (deterministic: findings
/// arrive sorted by file then line from the tree walk).
pub fn render(findings: &[Finding]) -> String {
    let mut out = String::new();
    out.push_str(
        "# qlc analyze baseline: grandfathered findings.\n\
         # One rendered finding per line; `#` comments ignored.\n\
         # Regenerate: cargo run --bin qlc -- analyze --update-baseline\n",
    );
    for f in findings {
        out.push_str(&f.render());
        out.push('\n');
    }
    out
}

/// Split findings into (new, grandfathered) against a baseline set.
pub fn split<'a>(
    findings: &'a [Finding],
    baseline: &BTreeSet<String>,
) -> (Vec<&'a Finding>, Vec<&'a Finding>) {
    let mut fresh = Vec::new();
    let mut known = Vec::new();
    for f in findings {
        if baseline.contains(&f.render()) {
            known.push(f);
        } else {
            fresh.push(f);
        }
    }
    (fresh, known)
}

/// Baseline entries that match no current finding — the fix landed
/// (or the code moved) but the grandfather line was never pruned.
/// Reported as a warning by default and an error under
/// `--deny-stale`, so the baseline only ever shrinks.
pub fn stale(
    findings: &[Finding],
    baseline: &BTreeSet<String>,
) -> Vec<String> {
    let rendered: BTreeSet<String> =
        findings.iter().map(Finding::render).collect();
    baseline
        .iter()
        .filter(|entry| !rendered.contains(*entry))
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(file: &str, line: usize) -> Finding {
        Finding {
            file: file.to_string(),
            line,
            rule: "panic-free",
            msg: "test message".to_string(),
        }
    }

    #[test]
    fn roundtrip_through_render_and_parse() {
        let fs = vec![finding("src/a.rs", 3), finding("src/b.rs", 9)];
        let set = parse(&render(&fs));
        assert_eq!(set.len(), 2);
        assert!(set.contains(&fs[0].render()));
        assert!(set.contains(&fs[1].render()));
    }

    #[test]
    fn comments_and_blanks_are_ignored() {
        let set = parse("# header\n\n  \nsrc/a.rs:1: x: y\n");
        assert_eq!(set.len(), 1);
    }

    #[test]
    fn split_separates_new_from_grandfathered() {
        let fs = vec![finding("src/a.rs", 3), finding("src/b.rs", 9)];
        let baseline = parse(&fs[0].render());
        let (fresh, known) = split(&fs, &baseline);
        assert_eq!(fresh.len(), 1);
        assert_eq!(known.len(), 1);
        assert_eq!(fresh[0].line, 9);
        assert_eq!(known[0].line, 3);
    }

    #[test]
    fn stale_reports_entries_with_no_matching_finding() {
        let fs = vec![finding("src/a.rs", 3)];
        let baseline = parse(&format!(
            "{}\nsrc/gone.rs:7: panic-free: fixed long ago\n",
            fs[0].render()
        ));
        let dead = stale(&fs, &baseline);
        assert_eq!(
            dead,
            vec!["src/gone.rs:7: panic-free: fixed long ago".to_string()]
        );
        assert!(stale(&fs, &parse(&fs[0].render())).is_empty());
    }
}
