//! `qlc analyze`: a dependency-free static-analysis pass over the
//! crate's own source tree.
//!
//! The paper's argument is that a 256-entry LUT is simple enough to
//! get right in hardware; this module gives the software reproduction
//! the same property mechanically.  PR 5's headline bug — an
//! unchecked `chunk.len() as u32` silently colliding with the QLF2
//! adaptive-delta flag bit — was a *class* bug fixed at one site by
//! hand; the five rules here (see [`rules`]) make the whole class a
//! CI failure for wire/serde modules, unsafe kernels, and library
//! panic paths.
//!
//! Everything is hand-rolled (no `syn`, no network): [`lexer`] masks
//! comments, strings, and test-only regions; [`rules`] scans the
//! masked view; [`baseline`] grandfathers pre-existing findings so CI
//! fails only on new ones.

pub mod baseline;
pub mod lexer;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

pub use rules::{check_file, Finding};

/// Analyze every `.rs` file under `src_root` (recursively), returning
/// findings sorted by file label then line.  Labels are
/// `<root-name>/<relative-path>` with forward slashes — stable across
/// platforms and working directories so baseline entries match.
pub fn analyze_tree(src_root: &Path) -> Result<Vec<Finding>, String> {
    let mut files = Vec::new();
    collect_rs_files(src_root, &mut files)?;
    files.sort();
    let root_name = src_root
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "src".to_string());
    let mut findings = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(src_root)
            .map_err(|e| format!("analyze: bad path {}: {e}", path.display()))?;
        let label = format!(
            "{root_name}/{}",
            rel.to_string_lossy().replace('\\', "/")
        );
        let bytes = fs::read(&path)
            .map_err(|e| format!("analyze: read {}: {e}", path.display()))?;
        let text = String::from_utf8_lossy(&bytes);
        findings.extend(check_file(&label, &text));
    }
    Ok(findings)
}

fn collect_rs_files(
    dir: &Path,
    out: &mut Vec<PathBuf>,
) -> Result<(), String> {
    let entries = fs::read_dir(dir)
        .map_err(|e| format!("analyze: read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry
            .map_err(|e| format!("analyze: walk {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_tree(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("qlc-analysis-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(dir.join("src/transport/net")).unwrap();
        dir
    }

    #[test]
    fn analyze_tree_walks_and_labels_findings() {
        let dir = tmp_tree("walk");
        fs::write(
            dir.join("src/transport/net/bad.rs"),
            "fn put(n: usize, o: &mut Vec<u8>) {\n    \
             o.extend_from_slice(&(n as u32).to_le_bytes());\n}\n",
        )
        .unwrap();
        fs::write(dir.join("src/clean.rs"), "pub fn ok() -> u8 { 0 }\n")
            .unwrap();
        let findings = analyze_tree(&dir.join("src")).unwrap();
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].file, "src/transport/net/bad.rs");
        assert_eq!(findings[0].line, 2);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn analyze_tree_errors_on_missing_root() {
        let dir = std::env::temp_dir().join("qlc-analysis-absent");
        let _ = fs::remove_dir_all(&dir);
        assert!(analyze_tree(&dir).is_err());
    }
}
