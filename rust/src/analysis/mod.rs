//! `qlc analyze`: a dependency-free static-analysis pass over the
//! crate's own source tree.
//!
//! The paper's argument is that a 256-entry LUT is simple enough to
//! get right in hardware; this module gives the software reproduction
//! the same property mechanically.  PR 5's headline bug — an
//! unchecked `chunk.len() as u32` silently colliding with the QLF2
//! adaptive-delta flag bit — was a *class* bug fixed at one site by
//! hand; the five rules here (see [`rules`]) make the whole class a
//! CI failure for wire/serde modules, unsafe kernels, and library
//! panic paths.
//!
//! Everything is hand-rolled (no `syn`, no network): [`lexer`] masks
//! comments, strings, and test-only regions; [`rules`] scans the
//! masked view; [`baseline`] grandfathers pre-existing findings so CI
//! fails only on new ones.

pub mod baseline;
pub mod cfg;
pub mod lexer;
pub mod rules;
pub mod taint;

use std::fs;
use std::path::{Path, PathBuf};

pub use rules::{check_file, Finding};

/// Analyze every `.rs` file under `src_root` (recursively), returning
/// findings sorted by file label then line.  Labels are
/// `<root-name>/<relative-path>` with forward slashes — stable across
/// platforms and working directories so baseline entries match.
pub fn analyze_tree(src_root: &Path) -> Result<Vec<Finding>, String> {
    let mut files = Vec::new();
    collect_rs_files(src_root, &mut files)?;
    files.sort();
    let root_name = src_root
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "src".to_string());
    let mut findings = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(src_root)
            .map_err(|e| format!("analyze: bad path {}: {e}", path.display()))?;
        let label = format!(
            "{root_name}/{}",
            rel.to_string_lossy().replace('\\', "/")
        );
        let bytes = fs::read(&path)
            .map_err(|e| format!("analyze: read {}: {e}", path.display()))?;
        let text = String::from_utf8_lossy(&bytes);
        findings.extend(check_file(&label, &text));
    }
    Ok(findings)
}

/// Build the `--json` report for a finished analysis run.
///
/// Schema (`version: 2`):
/// ```json
/// {
///   "version": 2,
///   "rules": ["unchecked-narrowing", ...],
///   "counts": {"total": N, "new": N, "baselined": N, "stale": N},
///   "findings": [{"file", "line", "rule", "msg", "status"}, ...],
///   "stale_baseline": ["<exact baseline line>", ...]
/// }
/// ```
/// `status` is `"new"` or `"baselined"`; `stale_baseline` lists
/// grandfathered entries that no longer match any finding.
pub fn json_report(
    findings: &[Finding],
    baseline_set: &std::collections::BTreeSet<String>,
) -> crate::util::json::Json {
    use crate::util::json::Json;
    let (fresh, known) = baseline::split(findings, baseline_set);
    let stale = baseline::stale(findings, baseline_set);
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            let status = if baseline_set.contains(&f.render()) {
                "baselined"
            } else {
                "new"
            };
            Json::obj()
                .set("file", f.file.as_str())
                .set("line", f.line)
                .set("rule", f.rule)
                .set("msg", f.msg.as_str())
                .set("status", status)
        })
        .collect();
    let rule_names: Vec<Json> =
        rules::RULES.iter().map(|r| Json::from(r.name)).collect();
    let stale_items: Vec<Json> =
        stale.iter().map(|s| Json::from(s.as_str())).collect();
    Json::obj()
        .set("version", 2usize)
        .set("rules", rule_names)
        .set(
            "counts",
            Json::obj()
                .set("total", findings.len())
                .set("new", fresh.len())
                .set("baselined", known.len())
                .set("stale", stale.len()),
        )
        .set("findings", items)
        .set("stale_baseline", stale_items)
}

fn collect_rs_files(
    dir: &Path,
    out: &mut Vec<PathBuf>,
) -> Result<(), String> {
    let entries = fs::read_dir(dir)
        .map_err(|e| format!("analyze: read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry
            .map_err(|e| format!("analyze: walk {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_tree(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("qlc-analysis-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(dir.join("src/transport/net")).unwrap();
        dir
    }

    #[test]
    fn analyze_tree_walks_and_labels_findings() {
        let dir = tmp_tree("walk");
        fs::write(
            dir.join("src/transport/net/bad.rs"),
            "fn put(n: usize, o: &mut Vec<u8>) {\n    \
             o.extend_from_slice(&(n as u32).to_le_bytes());\n}\n",
        )
        .unwrap();
        fs::write(dir.join("src/clean.rs"), "pub fn ok() -> u8 { 0 }\n")
            .unwrap();
        let findings = analyze_tree(&dir.join("src")).unwrap();
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].file, "src/transport/net/bad.rs");
        assert_eq!(findings[0].line, 2);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn analyze_tree_errors_on_missing_root() {
        let dir = std::env::temp_dir().join("qlc-analysis-absent");
        let _ = fs::remove_dir_all(&dir);
        assert!(analyze_tree(&dir).is_err());
    }

    #[test]
    fn json_report_counts_and_statuses_are_consistent() {
        let findings = vec![
            Finding {
                file: "src/a.rs".to_string(),
                line: 3,
                rule: rules::RULE_PANIC_FREE,
                msg: "old".to_string(),
            },
            Finding {
                file: "src/b.rs".to_string(),
                line: 7,
                rule: rules::RULE_CAP_ALLOC,
                msg: "fresh".to_string(),
            },
        ];
        let base = baseline::parse(&format!(
            "{}\nsrc/gone.rs:1: panic-free: fixed\n",
            findings[0].render()
        ));
        let report = json_report(&findings, &base);
        let counts = report.get("counts").unwrap();
        assert_eq!(counts.get("total").unwrap().as_usize(), Some(2));
        assert_eq!(counts.get("new").unwrap().as_usize(), Some(1));
        assert_eq!(counts.get("baselined").unwrap().as_usize(), Some(1));
        assert_eq!(counts.get("stale").unwrap().as_usize(), Some(1));
        let items = report.get("findings").unwrap().as_arr().unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(
            items[0].get("status").unwrap().as_str(),
            Some("baselined")
        );
        assert_eq!(items[1].get("status").unwrap().as_str(), Some("new"));
        assert_eq!(
            report.get("rules").unwrap().as_arr().unwrap().len(),
            rules::RULES.len()
        );
        let stale = report.get("stale_baseline").unwrap().as_arr().unwrap();
        assert_eq!(stale.len(), 1);
        // The report must survive its own serializer.
        let parsed =
            crate::util::json::Json::parse(&report.to_string_pretty())
                .unwrap();
        assert_eq!(parsed.get("version").unwrap().as_usize(), Some(2));
    }
}
