//! Region stripper for the `qlc analyze` linter: a tiny hand-rolled
//! Rust lexer (no `syn`, no regex — the offline crate lints itself).
//!
//! [`strip`] masks comment and string *contents* to spaces (newlines
//! preserved, so findings keep their 1-indexed line numbers into the
//! original file), records waiver comments and safety comments before
//! they vanish, and then blanks `#[cfg(test)]` / `#[test]` regions so
//! the rules in [`super::rules`] only ever see real library code.
//!
//! The masking is deliberately lossy and deliberately forgiving: on
//! malformed input (unterminated strings, stray quotes, arbitrary
//! bytes) it masks to end-of-file rather than erroring — the linter
//! must never be the thing that panics.

use std::collections::BTreeMap;

/// One waiver comment: `// lint: <kind>(<why>)`.  A waiver suppresses
/// findings of the matching rule on its own line and the four lines
/// below it (enough to cover a multi-line statement under the
/// comment).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Waiver {
    /// 1-indexed line of the waiver comment.
    pub line: usize,
    /// The waiver kind: `cast-checked`, `cap-checked`, `infallible`.
    pub kind: String,
}

/// The stripped view of one source file.
#[derive(Clone, Debug, Default)]
pub struct Masked {
    /// Source with comments, strings, char literals and test-only
    /// regions blanked to spaces.  Line structure matches the input.
    pub code: String,
    /// All `lint:` waivers found in comments.
    pub waivers: Vec<Waiver>,
    /// 1-indexed lines whose comments state a safety invariant
    /// (`SAFETY:` or a `# Safety` doc section).
    pub safety_lines: Vec<usize>,
}

impl Masked {
    /// Is a finding of `kind` at `line` waived?  (Waiver on the same
    /// line or up to four lines above.)
    pub fn waived(&self, line: usize, kind: &str) -> bool {
        self.waivers
            .iter()
            .any(|w| w.kind == kind && w.line <= line && line - w.line <= 4)
    }

    /// Is there a safety comment adjacent to `line` (same line or up
    /// to eight lines above — enough for a doc block plus attributes
    /// between the comment and the `unsafe` token)?
    pub fn has_safety_comment(&self, line: usize) -> bool {
        self.safety_lines
            .iter()
            .any(|&s| s <= line && line - s <= 8)
    }
}

/// Strip `text` down to lintable code (see the module docs).
pub fn strip(text: &str) -> Masked {
    let (mut code, comments) = mask_comments_and_strings(text);
    strip_test_regions(&mut code);
    let mut waivers = Vec::new();
    let mut safety_lines = Vec::new();
    for (line, comment) in comments {
        if comment.contains("SAFETY:") || comment.contains("# Safety") {
            safety_lines.push(line);
        }
        let mut rest = comment.as_str();
        while let Some(pos) = rest.find("lint:") {
            rest = &rest[pos + 5..];
            let kind: String = rest
                .trim_start()
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '-')
                .collect();
            if !kind.is_empty() {
                waivers.push(Waiver { line, kind });
            }
        }
    }
    Masked { code, waivers, safety_lines }
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// If `chars[i..]` begins a raw-string introducer (`r`/`br` plus
/// hashes plus a quote) at an identifier boundary, the offset of the
/// opening quote from `i` and the hash count.
fn raw_string_intro(chars: &[char], i: usize) -> Option<(usize, usize)> {
    if i > 0 && is_ident_char(chars[i - 1]) {
        return None;
    }
    let mut j = match chars.get(i) {
        Some('r') => i + 1,
        Some('b') if chars.get(i + 1) == Some(&'r') => i + 2,
        _ => return None,
    };
    let hash_start = j;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((j - i, j - hash_start))
    } else {
        None
    }
}

/// Mask comment/string/char-literal contents to spaces (preserving
/// newlines) and collect per-line comment text.
fn mask_comments_and_strings(
    text: &str,
) -> (String, BTreeMap<usize, String>) {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(text.len());
    let mut comments: BTreeMap<usize, String> = BTreeMap::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        // Line comment (also covers `///` and `//!` doc comments).
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            while i < n && chars[i] != '\n' {
                comments.entry(line).or_default().push(chars[i]);
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment, nested per Rust rules.
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 0usize;
            while i < n {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    comments.entry(line).or_default().push_str("/*");
                    out.push_str("  ");
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth = depth.saturating_sub(1);
                    comments.entry(line).or_default().push_str("*/");
                    out.push_str("  ");
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    if chars[i] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        comments.entry(line).or_default().push(chars[i]);
                        out.push(' ');
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw (byte) string: no escapes, closed by `"` + same hashes.
        if let Some((quote_off, hashes)) = raw_string_intro(&chars, i) {
            for _ in 0..=quote_off {
                out.push(' ');
            }
            i += quote_off + 1;
            while i < n {
                if chars[i] == '"' {
                    let mut h = 0usize;
                    while h < hashes && chars.get(i + 1 + h) == Some(&'#') {
                        h += 1;
                    }
                    if h == hashes {
                        for _ in 0..=hashes {
                            out.push(' ');
                        }
                        i += hashes + 1;
                        break;
                    }
                }
                if chars[i] == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            continue;
        }
        // Plain (or byte) string with escapes.
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < n {
                let d = chars[i];
                if d == '\\' && i + 1 < n {
                    out.push(' ');
                    if chars[i + 1] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 2;
                    continue;
                }
                if d == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                }
                if d == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime: `'x'` / `'\n'` mask, `'a` keeps.
        if c == '\'' {
            let literal = if chars.get(i + 1) == Some(&'\\') {
                true
            } else {
                chars.get(i + 2) == Some(&'\'')
            };
            if literal {
                out.push(' ');
                i += 1;
                while i < n {
                    let d = chars[i];
                    if d == '\\' && i + 1 < n {
                        out.push(' ');
                        if chars[i + 1] == '\n' {
                            out.push('\n');
                            line += 1;
                        } else {
                            out.push(' ');
                        }
                        i += 2;
                        continue;
                    }
                    if d == '\'' {
                        out.push(' ');
                        i += 1;
                        break;
                    }
                    if d == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
                continue;
            }
            // Lifetime: fall through and keep the quote.
        }
        if c == '\n' {
            line += 1;
        }
        out.push(c);
        i += 1;
    }
    (out, comments)
}

/// Blank `#[cfg(test)]` / `#[test]` attributes and the item that
/// follows each (to its matching close brace, or to `;` for
/// brace-less items).  Operates on already comment/string-masked
/// text, so attribute detection cannot be fooled by literals.
fn strip_test_regions(code: &mut String) {
    let chars: Vec<char> = code.chars().collect();
    let mut masked = vec![false; chars.len()];
    let mut i = 0usize;
    while i < chars.len() {
        if chars[i] == '#' && chars.get(i + 1) == Some(&'[') {
            // Read the attribute content up to the matching `]`.
            let mut j = i + 2;
            let mut depth = 1usize;
            let mut content = String::new();
            while j < chars.len() && depth > 0 {
                match chars[j] {
                    '[' => depth += 1,
                    ']' => depth -= 1,
                    d if !d.is_whitespace() && depth == 1 => content.push(d),
                    _ => {}
                }
                j += 1;
            }
            if content == "test" || content == "cfg(test)" {
                let end = item_end(&chars, j);
                for flag in masked.iter_mut().take(end).skip(i) {
                    *flag = true;
                }
                i = end;
                continue;
            }
            i = j.max(i + 1);
            continue;
        }
        i += 1;
    }
    let mut out = String::with_capacity(code.len());
    for (k, c) in chars.iter().enumerate() {
        if masked[k] && *c != '\n' {
            out.push(' ');
        } else {
            out.push(*c);
        }
    }
    *code = out;
}

/// End (exclusive) of the item starting after an attribute: the first
/// top-level `;` before any brace, or the close of the first brace
/// group.
fn item_end(chars: &[char], from: usize) -> usize {
    let mut depth = 0usize;
    let mut seen_brace = false;
    let mut k = from;
    while k < chars.len() {
        match chars[k] {
            '{' => {
                depth += 1;
                seen_brace = true;
            }
            '}' => {
                depth = depth.saturating_sub(1);
                if seen_brace && depth == 0 {
                    return k + 1;
                }
            }
            ';' if !seen_brace && depth == 0 => return k + 1,
            _ => {}
        }
        k += 1;
    }
    chars.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, Config};

    #[test]
    fn comments_and_strings_are_masked() {
        let src = "let x = \"a.unwrap()\"; // b.unwrap()\nlet y = 1;\n";
        let m = strip(src);
        assert!(!m.code.contains("unwrap"));
        assert!(m.code.contains("let x ="));
        assert!(m.code.contains("let y = 1;"));
        assert_eq!(m.code.matches('\n').count(), 2);
    }

    #[test]
    fn raw_strings_and_char_literals_are_masked() {
        let src = "let a = r#\"x.unwrap()\"#; let b = 'u'; let c = '\\n';";
        let m = strip(src);
        assert!(!m.code.contains("unwrap"));
        assert!(!m.code.contains('\''));
    }

    #[test]
    fn lifetimes_survive_masking() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }";
        let m = strip(src);
        assert_eq!(m.code, src);
    }

    #[test]
    fn waivers_and_safety_comments_are_recorded() {
        let src = "\
// lint: infallible(slice length checked above)
let x = v.first();
// SAFETY: pointer is in bounds
unsafe { body() }
";
        let m = strip(src);
        assert_eq!(m.waivers.len(), 1);
        assert_eq!(m.waivers[0].kind, "infallible");
        assert_eq!(m.waivers[0].line, 1);
        assert!(m.waived(2, "infallible"));
        assert!(!m.waived(2, "cast-checked"));
        assert!(!m.waived(7, "infallible"), "waiver reach is bounded");
        assert_eq!(m.safety_lines, vec![3]);
        assert!(m.has_safety_comment(4));
    }

    #[test]
    fn waiver_markers_inside_strings_are_ignored() {
        let src = "let s = \"lint: infallible(nope)\";\nlet t = 1;\n";
        let m = strip(src);
        assert!(m.waivers.is_empty());
    }

    #[test]
    fn cfg_test_regions_are_blanked() {
        let src = "\
fn lib() -> usize { 1 }
#[cfg(test)]
mod tests {
    #[test]
    fn t() { x.unwrap(); }
}
fn lib2() -> usize { 2 }
";
        let m = strip(src);
        assert!(!m.code.contains("unwrap"));
        assert!(!m.code.contains("mod tests"));
        assert!(m.code.contains("fn lib()"));
        assert!(m.code.contains("fn lib2()"));
        assert_eq!(m.code.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn test_attribute_on_single_fn_is_blanked() {
        let src = "#[test]\nfn t() { panic!(\"x\") }\nfn keep() {}\n";
        let m = strip(src);
        assert!(!m.code.contains("panic!"));
        assert!(m.code.contains("fn keep()"));
    }

    #[test]
    fn cfg_test_on_braceless_item_stops_at_semicolon() {
        let src = "#[cfg(test)]\nuse crate::thing;\nfn keep() {}\n";
        let m = strip(src);
        assert!(!m.code.contains("thing"));
        assert!(m.code.contains("fn keep()"));
    }

    #[test]
    fn strip_never_panics_and_is_line_stable() {
        prop::check(
            "lexer strip on arbitrary bytes",
            Config { cases: 256, ..Config::default() },
            |rng, size| {
                let bytes = prop::arb_bytes(rng, size);
                let text = String::from_utf8_lossy(&bytes).into_owned();
                let a = strip(&text);
                // Line structure is preserved exactly.
                if a.code.matches('\n').count() != text.matches('\n').count()
                {
                    return Err("newline count changed".into());
                }
                // String delimiters never leak into the code view.
                if a.code.contains('"') {
                    return Err("unmasked string quote".into());
                }
                // Deterministic: a second run agrees byte-for-byte.
                let b = strip(&text);
                if a.code != b.code
                    || a.waivers != b.waivers
                    || a.safety_lines != b.safety_lines
                {
                    return Err("strip is not deterministic".into());
                }
                Ok(())
            },
        );
    }
}
