//! The `qlc analyze` rule set — eight rules targeting this repo's
//! proven bug classes (see ROADMAP.md § Static analysis).
//!
//! Since v2 the wire rules run on a real dataflow engine
//! ([`super::cfg`] recovers functions and statements from the masked
//! token stream; [`super::taint`] tracks wire-derived values from
//! sources through assignments to sinks), replacing the v1 "some
//! earlier line in this function mentions the identifier next to a
//! comparison" text heuristic.  The practical difference: a cap
//! check on the *wrong variable* no longer suppresses a finding, and
//! every finding carries its source-to-sink chain.
//!
//! * **unchecked-narrowing** (L1): a wire-derived value reaches an
//!   `as u8/u16/u32` cast with no reaching sanitizer.
//! * **cap-before-alloc** (L2): a wire-derived length reaches
//!   `Vec::with_capacity` / `vec![x; n]` / `reserve` / `resize` or a
//!   slice index with no reaching cap.
//! * **panic-free** (L3): `unwrap()` / `expect(` / `panic!` /
//!   `unreachable!` / `todo!` / `unimplemented!` in library code
//!   needs a `// lint: infallible(<why>)` waiver; `main.rs` exempt.
//! * **safety-comment** (L4): every `unsafe` token needs an adjacent
//!   `// SAFETY:` comment within the eight lines above it.
//! * **forbidden-construct** (L5): `transmute` and `static mut` are
//!   rejected everywhere, with no waiver syntax.
//! * **tainted-loop-bound** (L6): a wire-derived count bounds a
//!   `for`/`while` loop with no cap on any path to it.
//! * **tainted-length-arith** (L7): `a + b` / `a * b` on tainted
//!   lengths flows to a sink without a checked_/saturating_ op or a
//!   prior cap — overflow there defeats any later comparison.
//! * **reactor-interest-leak** (L8): a `Reactor::register` in
//!   `serve/`/`transport/` followed by an early exit (`?`/`return`)
//!   before the function's next `deregister` leaks fd interest.
//!
//! All scanning happens on the lexer's masked view, so string
//! literals, comments, and test code can never false-positive.
//! Waivers stay cheap and reviewable: `// lint: <kind>(<why>)` on
//! the finding line or up to four lines above it.

use super::cfg;
use super::lexer::{self, Masked};
use super::taint::{self, SinkKind};

/// One analysis finding, rendered as `file:line: rule: message`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!("{}:{}: {}: {}", self.file, self.line, self.rule, self.msg)
    }
}

pub const RULE_NARROWING: &str = "unchecked-narrowing";
pub const RULE_CAP_ALLOC: &str = "cap-before-alloc";
pub const RULE_PANIC_FREE: &str = "panic-free";
pub const RULE_SAFETY: &str = "safety-comment";
pub const RULE_FORBIDDEN: &str = "forbidden-construct";
pub const RULE_LOOP_BOUND: &str = "tainted-loop-bound";
pub const RULE_LEN_ARITH: &str = "tainted-length-arith";
pub const RULE_REACTOR_LEAK: &str = "reactor-interest-leak";

/// Documentation record for one rule, surfaced by
/// `qlc analyze --explain <rule>`.
pub struct RuleInfo {
    pub name: &'static str,
    /// What the rule proves / rejects.
    pub contract: &'static str,
    /// Waiver syntax, or a statement that none exists.
    pub waiver: &'static str,
    /// One worked example: a violation and its fix.
    pub example: &'static str,
}

/// Every registered rule, in L1..L8 order.  `--explain` iterates
/// this; a test asserts it stays in sync with the `RULE_*` consts.
pub const RULES: [RuleInfo; 8] = [
    RuleInfo {
        name: RULE_NARROWING,
        contract: "A wire-derived value (length/count field, \
                   from_le_bytes result, wire-shaped parameter) must \
                   not reach an `as u8`/`as u16`/`as u32` cast unless \
                   a sanitizer reaches the cast on every path: a \
                   diverging comparison guard, `.min(CAP)`, \
                   `try_from`, or `%`.",
        waiver: "// lint: cast-checked(<why>) on the cast line or up \
                 to 4 lines above",
        example: "BAD:  fn put(n: usize) -> u32 { n as u32 }\n\
                  GOOD: if n > MAX_N { return Err(..); }\n      \
                  out.push(n as u32);",
    },
    RuleInfo {
        name: RULE_CAP_ALLOC,
        contract: "A wire-derived length must not size an allocation \
                   (`Vec::with_capacity`, `vec![x; n]`, `reserve`, \
                   `resize`) or index a slice unless a cap reaches \
                   it.  Checks on a different variable do not count.",
        waiver: "// lint: cap-checked(<why>) on the allocation line \
                 or up to 4 lines above",
        example: "BAD:  vec![0u8; hdr.payload_len]\n\
                  GOOD: if hdr.payload_len > MAX_PAYLOAD \
                  { return Err(..); }\n      \
                  vec![0u8; hdr.payload_len]",
    },
    RuleInfo {
        name: RULE_PANIC_FREE,
        contract: "Library code must not contain `unwrap()`, \
                   `expect(`, `panic!`, `unreachable!`, `todo!` or \
                   `unimplemented!`; return `Err` instead.  `main.rs` \
                   (the CLI) is exempt; test code is invisible to \
                   the lexer.",
        waiver: "// lint: infallible(<why>) on the panicking line or \
                 up to 4 lines above",
        example: "BAD:  let b = v.first().unwrap();\n\
                  GOOD: let b = v.first().ok_or(\"empty\")?;",
    },
    RuleInfo {
        name: RULE_SAFETY,
        contract: "Every `unsafe` token needs a `// SAFETY:` comment \
                   (or a `# Safety` doc section) within the eight \
                   lines above it, stating the upheld invariant.",
        waiver: "no waiver: write the SAFETY comment",
        example: "BAD:  unsafe { *p }\n\
                  GOOD: // SAFETY: caller guarantees p is valid\n      \
                  unsafe { *p }",
    },
    RuleInfo {
        name: RULE_FORBIDDEN,
        contract: "`transmute` and `static mut` are rejected \
                   everywhere in the crate: both defeated review in \
                   past incidents and have safe replacements \
                   (`to_bits`/`from_bits`, `OnceLock`, atomics).",
        waiver: "no waiver: the constructs are banned outright",
        example: "BAD:  unsafe { std::mem::transmute::<u32, f32>(x) }\n\
                  GOOD: f32::from_bits(x)",
    },
    RuleInfo {
        name: RULE_LOOP_BOUND,
        contract: "A wire-derived count must not bound a `for` or \
                   `while` loop with no cap on any path to it — an \
                   attacker-chosen iteration count is a CPU-time \
                   amplifier even when each step is cheap.",
        waiver: "// lint: loop-capped(<why>) on the loop header line \
                 or up to 4 lines above",
        example: "BAD:  for _ in 0..hdr.n_chunks { step(); }\n\
                  GOOD: if hdr.n_chunks > MAX_CHUNKS \
                  { return Err(..); }\n      \
                  for _ in 0..hdr.n_chunks { step(); }",
    },
    RuleInfo {
        name: RULE_LEN_ARITH,
        contract: "Unchecked `+`/`*` on wire-derived lengths must not \
                   flow to a sink: the product can wrap before any \
                   later comparison sees it.  Use `checked_mul`/\
                   `checked_add`/`saturating_*` or cap each operand \
                   first.",
        waiver: "// lint: arith-checked(<why>) on the sink line or up \
                 to 4 lines above",
        example: "BAD:  let total = n_rows * row_len; \
                  out.reserve(total);\n\
                  GOOD: let total = n_rows.checked_mul(row_len)\
                  .ok_or(\"overflow\")?;",
    },
    RuleInfo {
        name: RULE_REACTOR_LEAK,
        contract: "In `serve/` and `transport/`, a `register` call \
                   followed by an early exit (`?` or `return`) before \
                   the function's next `deregister` leaks fd interest \
                   in the reactor.  Functions with no `deregister` \
                   transfer ownership and are exempt; branches \
                   handling the register's own failure are exempt.",
        waiver: "// lint: interest-balanced(<why>) on the register \
                 line or up to 4 lines above",
        example: "BAD:  reactor.register(fd, ..)?; probe()?; \
                  reactor.deregister(fd)?;\n\
                  GOOD: deregister on the probe-error path before \
                  returning",
    },
];

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Identifier tokens of `text`, in order, with their char columns.
fn idents(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut start = 0usize;
    for (col, c) in text.chars().enumerate() {
        if is_ident_char(c) {
            if cur.is_empty() {
                start = col;
            }
            cur.push(c);
        } else if !cur.is_empty() {
            out.push((start, std::mem::take(&mut cur)));
        }
    }
    if !cur.is_empty() {
        out.push((start, cur));
    }
    out
}

/// Does this path belong to the wire/serde taint scope of
/// L1/L2/L6/L7?  Everything that parses or frames attacker-shaped
/// bytes: the QWC1 socket modules, the container/scheme serializers,
/// and (since v2) the serve subsystem's QSV1/QSA1 handlers.
fn in_wire_scope(path: &str) -> bool {
    path.contains("transport/net/")
        || path.ends_with("codecs/frame.rs")
        || path.ends_with("codecs/qlc/serde.rs")
        || path.ends_with("serve/server.rs")
        || path.ends_with("serve/client.rs")
        || path.ends_with("serve/io.rs")
}

/// Does this path fall under the reactor-lifecycle rule (L8)?
fn in_reactor_scope(path: &str) -> bool {
    path.contains("serve/") || path.contains("transport/")
}

/// The rule and waiver kind a taint sink maps to.
fn sink_rule(kind: SinkKind) -> (&'static str, &'static str) {
    match kind {
        SinkKind::Narrow => (RULE_NARROWING, "cast-checked"),
        SinkKind::Alloc | SinkKind::Index => (RULE_CAP_ALLOC, "cap-checked"),
        SinkKind::LoopBound => (RULE_LOOP_BOUND, "loop-capped"),
        SinkKind::Arith => (RULE_LEN_ARITH, "arith-checked"),
    }
}

/// Run every rule over one file.  `path` is the label findings carry
/// (forward slashes); `text` is the raw source.
pub fn check_file(path: &str, text: &str) -> Vec<Finding> {
    let path = path.replace('\\', "/");
    let masked = lexer::strip(text);
    let wire = in_wire_scope(&path);
    let reactor = in_reactor_scope(&path);
    let panic_exempt = path.ends_with("main.rs");
    let mut out = Vec::new();
    for (i, raw_line) in masked.code.lines().enumerate() {
        let lineno = i + 1;
        if !panic_exempt {
            check_panic_free(&path, lineno, raw_line, &masked, &mut out);
        }
        check_safety(&path, lineno, raw_line, &masked, &mut out);
        check_forbidden(&path, lineno, raw_line, &mut out);
    }
    if wire || reactor {
        let funcs = cfg::parse_functions(&masked.code);
        for func in &funcs {
            if wire {
                for tf in taint::analyze_fn(&path, func) {
                    let (rule, waiver_kind) = sink_rule(tf.kind);
                    if masked.waived(tf.line, waiver_kind) {
                        continue;
                    }
                    out.push(Finding {
                        file: path.clone(),
                        line: tf.line,
                        rule,
                        msg: taint_msg(&path, &tf, waiver_kind),
                    });
                }
            }
            if reactor {
                for leak in taint::reactor_leaks(func) {
                    if masked.waived(leak.reg_line, "interest-balanced") {
                        continue;
                    }
                    out.push(Finding {
                        file: path.clone(),
                        line: leak.reg_line,
                        rule: RULE_REACTOR_LEAK,
                        msg: format!(
                            "fd interest registered here can leak: early \
                             exit at {path}:{} runs before the next \
                             deregister (balance the exit or \
                             // lint: interest-balanced(why))",
                            leak.exit_line
                        ),
                    });
                }
            }
        }
    }
    out.sort_by(|a, b| {
        (a.line, a.rule, &a.msg).cmp(&(b.line, b.rule, &b.msg))
    });
    out.dedup();
    out
}

/// Render a taint finding's message with its source-to-sink chain.
fn taint_msg(path: &str, tf: &taint::TaintFinding, waiver_kind: &str) -> String {
    let mut chain = tf.chain.join(" -> ");
    if chain.is_empty() {
        chain = "wire-derived value".to_string();
    }
    format!(
        "{chain} -> reaches {} at {path}:{} with no reaching sanitizer \
         (cap it or // lint: {waiver_kind}(why))",
        tf.what, tf.line
    )
}

/// L3: panicking constructs in library code.
fn check_panic_free(
    path: &str,
    lineno: usize,
    line: &str,
    masked: &Masked,
    out: &mut Vec<Finding>,
) {
    const PATTERNS: [&str; 6] = [
        ".unwrap()", ".expect(", "panic!", "unreachable!", "todo!",
        "unimplemented!",
    ];
    for pat in PATTERNS {
        if !line.contains(pat) {
            continue;
        }
        if masked.waived(lineno, "infallible") {
            continue;
        }
        out.push(Finding {
            file: path.to_string(),
            line: lineno,
            rule: RULE_PANIC_FREE,
            msg: format!(
                "'{pat}' in library code (return Err or \
                 // lint: infallible(why))"
            ),
        });
    }
}

/// L4: `unsafe` without an adjacent SAFETY comment.
fn check_safety(
    path: &str,
    lineno: usize,
    line: &str,
    masked: &Masked,
    out: &mut Vec<Finding>,
) {
    if !idents(line).iter().any(|(_, id)| id == "unsafe") {
        return;
    }
    if masked.has_safety_comment(lineno) {
        return;
    }
    out.push(Finding {
        file: path.to_string(),
        line: lineno,
        rule: RULE_SAFETY,
        msg: "`unsafe` without an adjacent // SAFETY: comment stating \
              the invariant"
            .to_string(),
    });
}

/// L5: transmute / static mut, no waiver syntax.
fn check_forbidden(
    path: &str,
    lineno: usize,
    line: &str,
    out: &mut Vec<Finding>,
) {
    let toks = idents(line);
    for (k, (_, tok)) in toks.iter().enumerate() {
        let what = if tok == "transmute" {
            "transmute"
        } else if tok == "static"
            && toks.get(k + 1).is_some_and(|(_, t)| t == "mut")
        {
            "static mut"
        } else {
            continue;
        };
        out.push(Finding {
            file: path.to_string(),
            line: lineno,
            rule: RULE_FORBIDDEN,
            msg: format!("'{what}' is forbidden in this crate"),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const WIRE: &str = "src/transport/net/fixture.rs";
    const SERVE: &str = "src/serve/fixture.rs";
    const LIB: &str = "src/collective/fixture.rs";

    fn rules_of(path: &str, src: &str) -> Vec<&'static str> {
        check_file(path, src).iter().map(|f| f.rule).collect()
    }

    // ---- L1 unchecked-narrowing ----

    #[test]
    fn narrowing_cast_without_guard_is_flagged() {
        let src = "\
fn put(n: usize, out: &mut Vec<u8>) {
    out.extend_from_slice(&(n as u32).to_le_bytes());
}
";
        let f = check_file(WIRE, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_NARROWING);
        assert_eq!(f[0].line, 2);
        assert!(f[0].render().starts_with(WIRE), "{}", f[0].render());
    }

    #[test]
    fn narrowing_cast_with_guard_passes() {
        let src = "\
fn put(n: usize, out: &mut Vec<u8>) -> Result<(), String> {
    if n > 1000 {
        return Err(\"too big\".into());
    }
    out.extend_from_slice(&(n as u32).to_le_bytes());
    Ok(())
}
";
        assert!(rules_of(WIRE, src).is_empty());
    }

    #[test]
    fn narrowing_cast_with_waiver_passes() {
        let src = "\
fn put(n: usize, out: &mut Vec<u8>) {
    // lint: cast-checked(n is a table index bounded by 256 upstream)
    out.extend_from_slice(&(n as u32).to_le_bytes());
}
";
        assert!(rules_of(WIRE, src).is_empty());
    }

    #[test]
    fn narrowing_cast_outside_wire_scope_is_ignored() {
        let src = "fn f(n: usize) -> u32 { n as u32 }\n";
        assert!(rules_of("src/stats/fixture.rs", src).is_empty());
    }

    #[test]
    fn literal_cast_is_ignored() {
        let src = "fn f() -> u8 { 7 as u8 }\n";
        assert!(rules_of(WIRE, src).is_empty());
    }

    #[test]
    fn cast_in_string_or_comment_is_ignored() {
        let src = "\
fn f() -> &'static str {
    // n as u32 would truncate here
    \"n as u32\"
}
";
        assert!(rules_of(WIRE, src).is_empty());
    }

    // ---- L2 cap-before-alloc ----

    #[test]
    fn uncapped_alloc_is_flagged() {
        let src = "\
fn read(len: usize) -> Vec<u8> {
    vec![0u8; len]
}
";
        let f = check_file(WIRE, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_CAP_ALLOC);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn capped_alloc_passes() {
        let src = "\
fn read(len: usize) -> Result<Vec<u8>, String> {
    if len > MAX_BODY {
        return Err(\"cap\".into());
    }
    Ok(vec![0u8; len])
}
";
        assert!(rules_of(WIRE, src).is_empty());
    }

    #[test]
    fn with_capacity_variants_are_flagged_and_waivable() {
        let bad = "\
fn f(n: usize) {
    let mut v = Vec::with_capacity(n);
    v.reserve(n);
}
";
        assert_eq!(rules_of(WIRE, bad), vec![RULE_CAP_ALLOC, RULE_CAP_ALLOC]);
        let waived = "\
fn f(n: usize) {
    // lint: cap-checked(n mirrors an in-memory buffer length)
    let mut v: Vec<u8> = Vec::with_capacity(n);
}
";
        assert!(rules_of(WIRE, waived).is_empty());
    }

    #[test]
    fn constant_sized_alloc_passes() {
        let src = "fn f() -> Vec<u8> { Vec::with_capacity(256) }\n";
        assert!(rules_of(WIRE, src).is_empty());
    }

    #[test]
    fn cap_on_the_wrong_variable_no_longer_suppresses() {
        // The exact shape PR 6's heuristic wrongly accepted: guard
        // mentions `hdr` (shared base), allocation is sized by a
        // *different* field of it.
        let src = "\
fn body(&self) -> Vec<u8> {
    if self.hdr.n_scales > MAX_SCALES {
        return Vec::new();
    }
    vec![0u8; self.hdr.payload_len]
}
";
        let f = check_file(WIRE, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_CAP_ALLOC);
        assert_eq!(f[0].line, 5);
        assert!(f[0].msg.contains("payload_len"), "{}", f[0].msg);
        let twin = "\
fn body(&self) -> Vec<u8> {
    if self.hdr.payload_len > MAX_PAYLOAD {
        return Vec::new();
    }
    vec![0u8; self.hdr.payload_len]
}
";
        assert!(rules_of(WIRE, twin).is_empty());
    }

    // ---- L6 tainted-loop-bound ----

    #[test]
    fn tainted_loop_bound_is_flagged_and_waivable() {
        let bad = "\
fn walk(n_chunks: usize) {
    for _ in 0..n_chunks {
        step();
    }
}
";
        let f = check_file(WIRE, bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_LOOP_BOUND);
        assert_eq!(f[0].line, 2);
        let waived = "\
fn walk(n_chunks: usize) {
    // lint: loop-capped(n_chunks <= 64 by construction upstream)
    for _ in 0..n_chunks {
        step();
    }
}
";
        assert!(rules_of(WIRE, waived).is_empty());
        let guarded = "\
fn walk(n_chunks: usize) -> Result<(), String> {
    if n_chunks > MAX_CHUNKS {
        return Err(\"cap\".into());
    }
    for _ in 0..n_chunks {
        step();
    }
    Ok(())
}
";
        assert!(rules_of(WIRE, guarded).is_empty());
    }

    // ---- L7 tainted-length-arith ----

    #[test]
    fn tainted_length_arith_is_flagged_and_checked_mul_passes() {
        let bad = "\
fn grow(n_rows: usize, row_len: usize, out: &mut Vec<u8>) {
    let total = n_rows * row_len;
    out.reserve(total);
}
";
        let f = check_file(WIRE, bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_LEN_ARITH);
        assert_eq!(f[0].line, 3);
        let good = "\
fn grow(n_rows: usize, row_len: usize, out: &mut Vec<u8>) -> Result<(), String> {
    let total = n_rows.checked_mul(row_len).ok_or(\"overflow\")?;
    if total > MAX_TOTAL {
        return Err(\"cap\".into());
    }
    out.reserve(total);
    Ok(())
}
";
        assert!(rules_of(WIRE, good).is_empty());
    }

    // ---- L8 reactor-interest-leak ----

    #[test]
    fn register_with_early_exit_before_deregister_is_flagged() {
        let src = "\
fn open(&mut self, fd: i32) -> Result<(), String> {
    self.reactor.register(fd, 0, READABLE)?;
    self.probe()?;
    self.reactor.deregister(fd)?;
    Ok(())
}
";
        let f = check_file(SERVE, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_REACTOR_LEAK);
        assert_eq!(f[0].line, 2);
        assert!(f[0].msg.contains(":3"), "{}", f[0].msg);
    }

    #[test]
    fn balanced_or_transferred_registration_passes() {
        let balanced = "\
fn open(&mut self, fd: i32) -> Result<(), String> {
    self.reactor.register(fd, 0, READABLE)?;
    if self.probe().is_err() {
        let _ = self.reactor.deregister(fd);
        return Err(\"probe\".into());
    }
    self.reactor.deregister(fd)?;
    Ok(())
}
";
        assert!(rules_of(SERVE, balanced).is_empty());
        let transfer = "\
fn connect(addr: &str) -> Result<Client, String> {
    let reactor = new_reactor()?;
    reactor.register(fd, 0, READABLE)?;
    Ok(Client { reactor })
}
";
        assert!(rules_of(SERVE, transfer).is_empty());
    }

    #[test]
    fn reactor_leak_is_waivable_and_scoped() {
        let src = "\
fn open(&mut self, fd: i32) -> Result<(), String> {
    // lint: interest-balanced(probe failure tears down the reactor)
    self.reactor.register(fd, 0, READABLE)?;
    self.probe()?;
    self.reactor.deregister(fd)?;
    Ok(())
}
";
        assert!(rules_of(SERVE, src).is_empty());
        // Outside serve//transport/ the rule does not run at all.
        let unscoped = "\
fn open(&mut self, fd: i32) -> Result<(), String> {
    self.reactor.register(fd, 0, READABLE)?;
    self.probe()?;
    self.reactor.deregister(fd)?;
    Ok(())
}
";
        assert!(rules_of(LIB, unscoped).is_empty());
    }

    // ---- L3 panic-free ----

    #[test]
    fn unwrap_in_library_is_flagged() {
        let src = "fn f(v: &[u8]) -> u8 { *v.first().unwrap() }\n";
        let f = check_file(LIB, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_PANIC_FREE);
    }

    #[test]
    fn waived_unwrap_passes() {
        let src = "\
fn f(v: &[u8]) -> u8 {
    // lint: infallible(caller guarantees non-empty)
    *v.first().unwrap()
}
";
        assert!(rules_of(LIB, src).is_empty());
    }

    #[test]
    fn unwrap_in_test_code_is_ignored() {
        let src = "\
fn lib() -> usize { 1 }
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        std::fs::read(\"x\").unwrap();
        panic!(\"boom\");
    }
}
";
        assert!(rules_of(LIB, src).is_empty());
    }

    #[test]
    fn main_rs_is_exempt_from_panic_free() {
        let src = "fn main() { run().unwrap(); }\n";
        assert!(rules_of("src/main.rs", src).is_empty());
    }

    #[test]
    fn panic_macros_are_flagged() {
        let src = "\
fn f(x: u8) {
    if x > 3 {
        panic!(\"x\");
    }
    unreachable!()
}
";
        assert_eq!(
            rules_of(LIB, src),
            vec![RULE_PANIC_FREE, RULE_PANIC_FREE]
        );
    }

    #[test]
    fn unwrap_or_variants_pass() {
        let src = "fn f(v: Option<u8>) -> u8 { v.unwrap_or(0) }\n";
        assert!(rules_of(LIB, src).is_empty());
    }

    // ---- L4 safety-comment ----

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        let src = "\
fn f(p: *const u8) -> u8 {
    unsafe { *p }
}
";
        let f = check_file(LIB, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_SAFETY);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn unsafe_with_safety_comment_passes() {
        let src = "\
fn f(p: *const u8) -> u8 {
    // SAFETY: caller guarantees p is valid for reads
    unsafe { *p }
}
";
        assert!(rules_of(LIB, src).is_empty());
    }

    #[test]
    fn unsafe_fn_with_safety_doc_section_passes() {
        let src = "\
/// Reads a byte.
///
/// # Safety
/// `p` must be valid for reads.
unsafe fn read(p: *const u8) -> u8 {
    // SAFETY: contract forwarded to the caller.
    unsafe { *p }
}
";
        assert!(rules_of(LIB, src).is_empty());
    }

    #[test]
    fn unsafe_in_string_is_ignored() {
        let src = "fn f() -> &'static str { \"unsafe\" }\n";
        assert!(rules_of(LIB, src).is_empty());
    }

    // ---- L5 forbidden-construct ----

    #[test]
    fn transmute_is_flagged() {
        let src = "\
fn f(x: u32) -> f32 {
    // SAFETY: same size
    unsafe { std::mem::transmute(x) }
}
";
        let f = check_file(LIB, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_FORBIDDEN);
    }

    #[test]
    fn static_mut_is_flagged_even_in_main() {
        let src = "static mut COUNTER: u32 = 0;\nfn main() {}\n";
        let f = check_file("src/main.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_FORBIDDEN);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn plain_static_passes() {
        let src = "static NAME: &str = \"qlc\";\n";
        assert!(rules_of(LIB, src).is_empty());
    }

    // ---- scope plumbing ----

    #[test]
    fn guard_in_previous_function_does_not_leak() {
        let src = "\
fn checked(n: usize) -> bool {
    n < 100
}
fn put(n: usize, out: &mut Vec<u8>) {
    out.extend_from_slice(&(n as u32).to_le_bytes());
}
";
        let f = check_file(WIRE, src);
        assert_eq!(f.len(), 1, "guard must not leak across fns: {f:?}");
        assert_eq!(f[0].line, 5);
    }

    #[test]
    fn findings_carry_a_taint_chain() {
        let src = "\
fn read(len: usize) -> Vec<u8> {
    let want = len;
    vec![0u8; want]
}
";
        let f = check_file(WIRE, src);
        assert_eq!(f.len(), 1, "{f:?}");
        let msg = &f[0].msg;
        assert!(msg.contains("`len`"), "{msg}");
        assert!(msg.contains("flows into `want`"), "{msg}");
        assert!(msg.contains("reaches"), "{msg}");
    }

    #[test]
    fn all_five_rules_fire_on_a_seeded_fixture() {
        let src = "\
static mut GLOBAL: u32 = 0;
fn bad(n: usize, v: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    out.push((n as u8).to_le_bytes()[0]);
    let first = *v.first().unwrap();
    let x: f32 = unsafe { std::mem::transmute(n as u32) };
    out.push(first.wrapping_add(x as u8));
    out
}
";
        let rules: Vec<&str> = rules_of(WIRE, src);
        for rule in [
            RULE_NARROWING,
            RULE_CAP_ALLOC,
            RULE_PANIC_FREE,
            RULE_SAFETY,
            RULE_FORBIDDEN,
        ] {
            assert!(rules.contains(&rule), "{rule} missing from {rules:?}");
        }
    }

    // ---- rule registry ----

    #[test]
    fn registry_covers_every_rule_exactly_once() {
        let names: Vec<&str> = RULES.iter().map(|r| r.name).collect();
        assert_eq!(
            names,
            vec![
                RULE_NARROWING,
                RULE_CAP_ALLOC,
                RULE_PANIC_FREE,
                RULE_SAFETY,
                RULE_FORBIDDEN,
                RULE_LOOP_BOUND,
                RULE_LEN_ARITH,
                RULE_REACTOR_LEAK,
            ]
        );
        for r in &RULES {
            assert!(!r.contract.is_empty());
            assert!(!r.waiver.is_empty());
            assert!(!r.example.is_empty());
        }
    }
}
