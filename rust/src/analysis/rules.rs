//! The `qlc analyze` rule set — five rules targeting this repo's
//! proven bug classes (see ROADMAP.md § Static analysis):
//!
//! * **unchecked-narrowing** (L1): `as u8/u16/u32` casts in wire and
//!   serde modules must follow a visible range check on the cast
//!   operand earlier in the same function, or carry a
//!   `// lint: cast-checked(<why>)` waiver.  PR 5's chunk-table
//!   length-collision bug was exactly this shape.
//! * **cap-before-alloc** (L2): `Vec::with_capacity` / `vec![x; n]` /
//!   `.reserve(n)` sized by a runtime value in a wire module needs an
//!   earlier cap comparison in the same function, or a
//!   `// lint: cap-checked(<why>)` waiver.
//! * **panic-free** (L3): `unwrap()` / `expect(` / `panic!` /
//!   `unreachable!` / `todo!` / `unimplemented!` in library code
//!   needs a `// lint: infallible(<why>)` waiver.  `main.rs` is
//!   exempt (the CLI may die loudly); tests and benches never reach
//!   the rules because the lexer blanks `#[cfg(test)]` regions and
//!   the tree walk only visits `src/`.
//! * **safety-comment** (L4): every `unsafe` token needs an adjacent
//!   `// SAFETY:` comment (or `# Safety` doc section) within the
//!   eight lines above it.
//! * **forbidden-construct** (L5): `transmute` and `static mut` are
//!   rejected everywhere, with no waiver syntax.
//!
//! All scanning happens on the lexer's masked view, so string
//! literals, comments, and test code can never false-positive.  The
//! guard heuristic is deliberately crude — "some earlier line in this
//! function mentions the same identifier next to a comparison-ish
//! token" — because a waiver comment is cheap and reviewable, while a
//! missed unchecked cast costs a corrupted frame.

use super::lexer::{self, Masked};

/// One analysis finding, rendered as `file:line: rule: message`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!("{}:{}: {}: {}", self.file, self.line, self.rule, self.msg)
    }
}

pub const RULE_NARROWING: &str = "unchecked-narrowing";
pub const RULE_CAP_ALLOC: &str = "cap-before-alloc";
pub const RULE_PANIC_FREE: &str = "panic-free";
pub const RULE_SAFETY: &str = "safety-comment";
pub const RULE_FORBIDDEN: &str = "forbidden-construct";

/// Tokens that read as "a range/cap check happened here".
const GUARD_MARKS: [&str; 10] = [
    "<", ">", "try_from", "try_into", ".min(", ".clamp(", "contains(",
    "MAX", "CAP", "assert",
];

/// Identifier-shaped tokens that carry no information about which
/// value is being cast or sized.
const NOISE_IDENTS: [&str; 44] = [
    "as", "bool", "break", "const", "continue", "crate", "else", "enum",
    "false", "fn", "for", "if", "impl", "in", "let", "loop", "match",
    "move", "mut", "pub", "ref", "return", "self", "Self", "static",
    "struct", "super", "true", "u8", "u16", "u32", "u64", "u128",
    "usize", "i8", "i16", "i32", "i64", "i128", "isize", "f32", "f64",
    "use", "while",
];

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Identifier tokens of `text`, in order, with their char columns.
fn idents(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut start = 0usize;
    for (col, c) in text.chars().enumerate() {
        if is_ident_char(c) {
            if cur.is_empty() {
                start = col;
            }
            cur.push(c);
        } else if !cur.is_empty() {
            out.push((start, std::mem::take(&mut cur)));
        }
    }
    if !cur.is_empty() {
        out.push((start, cur));
    }
    out
}

/// Identifiers in `text` that plausibly name the value being cast or
/// sized (everything minus keywords/primitive types, deduplicated).
fn value_idents(text: &str) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for (_, id) in idents(text) {
        if id.chars().next().is_some_and(|c| c.is_ascii_digit()) {
            continue;
        }
        if NOISE_IDENTS.contains(&id.as_str()) {
            continue;
        }
        if !out.contains(&id) {
            out.push(id);
        }
    }
    out
}

/// Does `line` look like a range/cap check that mentions any of the
/// given identifiers?  (Token-level identifier match, substring-level
/// guard-mark match.)
fn line_guards(line: &str, wanted: &[String]) -> bool {
    if !GUARD_MARKS.iter().any(|m| line.contains(m)) {
        return false;
    }
    idents(line).iter().any(|(_, id)| wanted.iter().any(|w| w == id))
}

/// For each 0-indexed line, the 1-indexed start line of the innermost
/// enclosing `fn` body, if any.  Brace-depth tracking over the masked
/// text — closures do not start a scope, only the `fn` keyword does.
fn enclosing_fn_map(code: &str) -> Vec<Option<usize>> {
    let mut map: Vec<Option<usize>> = vec![None];
    let mut stack: Vec<(usize, usize)> = Vec::new(); // (fn line, depth)
    let mut depth = 0usize;
    let mut pending_fn: Option<usize> = None;
    let mut line = 1usize;
    let mut cur = String::new();
    for c in code.chars() {
        if is_ident_char(c) {
            cur.push(c);
            continue;
        }
        if cur == "fn" {
            pending_fn = Some(line);
        }
        cur.clear();
        match c {
            '{' => {
                if let Some(fl) = pending_fn.take() {
                    stack.push((fl, depth));
                }
                depth += 1;
            }
            '}' => {
                depth = depth.saturating_sub(1);
                if stack.last().is_some_and(|&(_, d)| d == depth) {
                    stack.pop();
                }
            }
            ';' => pending_fn = None,
            '\n' => {
                line += 1;
                map.push(stack.last().map(|&(fl, _)| fl));
            }
            _ => {}
        }
    }
    map
}

/// Is any line in `[from_line, to_line)` (1-indexed, exclusive end) a
/// guard for `wanted`?
fn guarded_between(
    lines: &[&str],
    from_line: usize,
    to_line: usize,
    wanted: &[String],
) -> bool {
    lines
        .iter()
        .enumerate()
        .skip(from_line.saturating_sub(1))
        .take_while(|(i, _)| i + 1 < to_line)
        .any(|(_, l)| line_guards(l, wanted))
}

/// Does this path belong to the wire/serde scope of L1/L2?
fn in_wire_scope(path: &str) -> bool {
    path.contains("transport/net/")
        || path.ends_with("codecs/frame.rs")
        || path.ends_with("codecs/qlc/serde.rs")
}

/// Run every rule over one file.  `path` is the label findings carry
/// (forward slashes); `text` is the raw source.
pub fn check_file(path: &str, text: &str) -> Vec<Finding> {
    let path = path.replace('\\', "/");
    let masked = lexer::strip(text);
    let lines: Vec<&str> = masked.code.lines().collect();
    let fn_map = enclosing_fn_map(&masked.code);
    let wire = in_wire_scope(&path);
    let panic_exempt = path.ends_with("main.rs");
    let mut out = Vec::new();
    for (i, raw_line) in lines.iter().enumerate() {
        let lineno = i + 1;
        if wire {
            check_narrowing(
                &path, lineno, raw_line, &lines, &fn_map, &masked, &mut out,
            );
            check_cap_alloc(
                &path, lineno, raw_line, &lines, &fn_map, &masked, &mut out,
            );
        }
        if !panic_exempt {
            check_panic_free(&path, lineno, raw_line, &masked, &mut out);
        }
        check_safety(&path, lineno, raw_line, &masked, &mut out);
        check_forbidden(&path, lineno, raw_line, &mut out);
    }
    out
}

/// L1: `<expr> as u8/u16/u32` with no earlier guard on the operand.
fn check_narrowing(
    path: &str,
    lineno: usize,
    line: &str,
    lines: &[&str],
    fn_map: &[Option<usize>],
    masked: &Masked,
    out: &mut Vec<Finding>,
) {
    let toks = idents(line);
    for (k, (col, tok)) in toks.iter().enumerate() {
        if tok != "as" {
            continue;
        }
        let Some((next_col, next)) = toks.get(k + 1) else { continue };
        if !matches!(next.as_str(), "u8" | "u16" | "u32") {
            continue;
        }
        // Only whitespace may separate `as` from the target type.
        let between: String = line
            .chars()
            .skip(col + 2)
            .take(next_col - (col + 2))
            .collect();
        if !between.chars().all(|c| c.is_whitespace()) {
            continue;
        }
        // The operand: identifiers on this line before the `as`.
        let before: String = line.chars().take(*col).collect();
        let wanted = value_idents(&before);
        if wanted.is_empty() {
            continue; // literal cast, nothing dynamic to range-check
        }
        if masked.waived(lineno, "cast-checked") {
            continue;
        }
        let fn_start =
            fn_map.get(lineno - 1).copied().flatten().unwrap_or(lineno);
        // Search strictly after the `fn` line: signatures are full of
        // `<`/`>` (generics, `->`) and mention every parameter, so
        // including them would vacuously guard everything.
        if guarded_between(lines, fn_start + 1, lineno, &wanted) {
            continue;
        }
        let ident = wanted.last().cloned().unwrap_or_default();
        out.push(Finding {
            file: path.to_string(),
            line: lineno,
            rule: RULE_NARROWING,
            msg: format!(
                "narrowing `as {next}` cast of '{ident}' with no visible \
                 range check (add one or // lint: cast-checked(why))"
            ),
        });
    }
}

/// L2: allocation sized by a runtime value with no earlier cap check.
fn check_cap_alloc(
    path: &str,
    lineno: usize,
    line: &str,
    lines: &[&str],
    fn_map: &[Option<usize>],
    masked: &Masked,
    out: &mut Vec<Finding>,
) {
    let mut size_exprs: Vec<String> = Vec::new();
    for pat in ["with_capacity(", ".reserve("] {
        if let Some(pos) = line.find(pat) {
            let after = &line[pos + pat.len()..];
            size_exprs.push(paren_arg(after, '(', ')'));
        }
    }
    if let Some(pos) = line.find("vec![") {
        let inner = paren_arg(&line[pos + 5..], '[', ']');
        // `vec![elem; len]` — only the length expression matters.
        if let Some(semi) = inner.rfind(';') {
            size_exprs.push(inner[semi + 1..].to_string());
        }
    }
    for expr in size_exprs {
        let wanted = value_idents(&expr);
        if wanted.is_empty() {
            continue; // constant-sized allocation
        }
        if masked.waived(lineno, "cap-checked") {
            continue;
        }
        let fn_start =
            fn_map.get(lineno - 1).copied().flatten().unwrap_or(lineno);
        if guarded_between(lines, fn_start + 1, lineno, &wanted) {
            continue;
        }
        let ident = wanted.last().cloned().unwrap_or_default();
        out.push(Finding {
            file: path.to_string(),
            line: lineno,
            rule: RULE_CAP_ALLOC,
            msg: format!(
                "allocation sized by '{ident}' with no earlier cap \
                 comparison (add one or // lint: cap-checked(why))"
            ),
        });
    }
}

/// The argument text from `after` up to the matching close delimiter
/// (or end of line if it never closes on this line).
fn paren_arg(after: &str, open: char, close: char) -> String {
    let mut depth = 0usize;
    let mut out = String::new();
    for c in after.chars() {
        if c == open {
            depth += 1;
        } else if c == close {
            if depth == 0 {
                break;
            }
            depth -= 1;
        }
        out.push(c);
    }
    out
}

/// L3: panicking constructs in library code.
fn check_panic_free(
    path: &str,
    lineno: usize,
    line: &str,
    masked: &Masked,
    out: &mut Vec<Finding>,
) {
    const PATTERNS: [&str; 6] = [
        ".unwrap()", ".expect(", "panic!", "unreachable!", "todo!",
        "unimplemented!",
    ];
    for pat in PATTERNS {
        if !line.contains(pat) {
            continue;
        }
        if masked.waived(lineno, "infallible") {
            continue;
        }
        out.push(Finding {
            file: path.to_string(),
            line: lineno,
            rule: RULE_PANIC_FREE,
            msg: format!(
                "'{pat}' in library code (return Err or \
                 // lint: infallible(why))"
            ),
        });
    }
}

/// L4: `unsafe` without an adjacent SAFETY comment.
fn check_safety(
    path: &str,
    lineno: usize,
    line: &str,
    masked: &Masked,
    out: &mut Vec<Finding>,
) {
    if !idents(line).iter().any(|(_, id)| id == "unsafe") {
        return;
    }
    if masked.has_safety_comment(lineno) {
        return;
    }
    out.push(Finding {
        file: path.to_string(),
        line: lineno,
        rule: RULE_SAFETY,
        msg: "`unsafe` without an adjacent // SAFETY: comment stating \
              the invariant"
            .to_string(),
    });
}

/// L5: transmute / static mut, no waiver syntax.
fn check_forbidden(
    path: &str,
    lineno: usize,
    line: &str,
    out: &mut Vec<Finding>,
) {
    let toks = idents(line);
    for (k, (_, tok)) in toks.iter().enumerate() {
        let what = if tok == "transmute" {
            "transmute"
        } else if tok == "static"
            && toks.get(k + 1).is_some_and(|(_, t)| t == "mut")
        {
            "static mut"
        } else {
            continue;
        };
        out.push(Finding {
            file: path.to_string(),
            line: lineno,
            rule: RULE_FORBIDDEN,
            msg: format!("'{what}' is forbidden in this crate"),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const WIRE: &str = "src/transport/net/fixture.rs";
    const LIB: &str = "src/collective/fixture.rs";

    fn rules_of(path: &str, src: &str) -> Vec<&'static str> {
        check_file(path, src).iter().map(|f| f.rule).collect()
    }

    // ---- L1 unchecked-narrowing ----

    #[test]
    fn narrowing_cast_without_guard_is_flagged() {
        let src = "\
fn put(n: usize, out: &mut Vec<u8>) {
    out.extend_from_slice(&(n as u32).to_le_bytes());
}
";
        let f = check_file(WIRE, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_NARROWING);
        assert_eq!(f[0].line, 2);
        assert!(f[0].render().starts_with(WIRE), "{}", f[0].render());
    }

    #[test]
    fn narrowing_cast_with_guard_passes() {
        let src = "\
fn put(n: usize, out: &mut Vec<u8>) -> Result<(), String> {
    if n > 1000 {
        return Err(\"too big\".into());
    }
    out.extend_from_slice(&(n as u32).to_le_bytes());
    Ok(())
}
";
        assert!(rules_of(WIRE, src).is_empty());
    }

    #[test]
    fn narrowing_cast_with_waiver_passes() {
        let src = "\
fn put(n: usize, out: &mut Vec<u8>) {
    // lint: cast-checked(n is a table index bounded by 256 upstream)
    out.extend_from_slice(&(n as u32).to_le_bytes());
}
";
        assert!(rules_of(WIRE, src).is_empty());
    }

    #[test]
    fn narrowing_cast_outside_wire_scope_is_ignored() {
        let src = "fn f(n: usize) -> u32 { n as u32 }\n";
        assert!(rules_of("src/stats/fixture.rs", src).is_empty());
    }

    #[test]
    fn literal_cast_is_ignored() {
        let src = "fn f() -> u8 { 7 as u8 }\n";
        assert!(rules_of(WIRE, src).is_empty());
    }

    #[test]
    fn cast_in_string_or_comment_is_ignored() {
        let src = "\
fn f() -> &'static str {
    // n as u32 would truncate here
    \"n as u32\"
}
";
        assert!(rules_of(WIRE, src).is_empty());
    }

    // ---- L2 cap-before-alloc ----

    #[test]
    fn uncapped_alloc_is_flagged() {
        let src = "\
fn read(len: usize) -> Vec<u8> {
    vec![0u8; len]
}
";
        let f = check_file(WIRE, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_CAP_ALLOC);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn capped_alloc_passes() {
        let src = "\
fn read(len: usize) -> Result<Vec<u8>, String> {
    if len > MAX_BODY {
        return Err(\"cap\".into());
    }
    Ok(vec![0u8; len])
}
";
        assert!(rules_of(WIRE, src).is_empty());
    }

    #[test]
    fn with_capacity_variants_are_flagged_and_waivable() {
        let bad = "\
fn f(n: usize) {
    let mut v = Vec::with_capacity(n);
    v.reserve(n);
}
";
        assert_eq!(rules_of(WIRE, bad), vec![RULE_CAP_ALLOC, RULE_CAP_ALLOC]);
        let waived = "\
fn f(n: usize) {
    // lint: cap-checked(n mirrors an in-memory buffer length)
    let mut v: Vec<u8> = Vec::with_capacity(n);
}
";
        assert!(rules_of(WIRE, waived).is_empty());
    }

    #[test]
    fn constant_sized_alloc_passes() {
        let src = "fn f() -> Vec<u8> { Vec::with_capacity(256) }\n";
        assert!(rules_of(WIRE, src).is_empty());
    }

    // ---- L3 panic-free ----

    #[test]
    fn unwrap_in_library_is_flagged() {
        let src = "fn f(v: &[u8]) -> u8 { *v.first().unwrap() }\n";
        let f = check_file(LIB, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_PANIC_FREE);
    }

    #[test]
    fn waived_unwrap_passes() {
        let src = "\
fn f(v: &[u8]) -> u8 {
    // lint: infallible(caller guarantees non-empty)
    *v.first().unwrap()
}
";
        assert!(rules_of(LIB, src).is_empty());
    }

    #[test]
    fn unwrap_in_test_code_is_ignored() {
        let src = "\
fn lib() -> usize { 1 }
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        std::fs::read(\"x\").unwrap();
        panic!(\"boom\");
    }
}
";
        assert!(rules_of(LIB, src).is_empty());
    }

    #[test]
    fn main_rs_is_exempt_from_panic_free() {
        let src = "fn main() { run().unwrap(); }\n";
        assert!(rules_of("src/main.rs", src).is_empty());
    }

    #[test]
    fn panic_macros_are_flagged() {
        let src = "\
fn f(x: u8) {
    if x > 3 {
        panic!(\"x\");
    }
    unreachable!()
}
";
        assert_eq!(
            rules_of(LIB, src),
            vec![RULE_PANIC_FREE, RULE_PANIC_FREE]
        );
    }

    #[test]
    fn unwrap_or_variants_pass() {
        let src = "fn f(v: Option<u8>) -> u8 { v.unwrap_or(0) }\n";
        assert!(rules_of(LIB, src).is_empty());
    }

    // ---- L4 safety-comment ----

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        let src = "\
fn f(p: *const u8) -> u8 {
    unsafe { *p }
}
";
        let f = check_file(LIB, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_SAFETY);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn unsafe_with_safety_comment_passes() {
        let src = "\
fn f(p: *const u8) -> u8 {
    // SAFETY: caller guarantees p is valid for reads
    unsafe { *p }
}
";
        assert!(rules_of(LIB, src).is_empty());
    }

    #[test]
    fn unsafe_fn_with_safety_doc_section_passes() {
        let src = "\
/// Reads a byte.
///
/// # Safety
/// `p` must be valid for reads.
unsafe fn read(p: *const u8) -> u8 {
    // SAFETY: contract forwarded to the caller.
    unsafe { *p }
}
";
        assert!(rules_of(LIB, src).is_empty());
    }

    #[test]
    fn unsafe_in_string_is_ignored() {
        let src = "fn f() -> &'static str { \"unsafe\" }\n";
        assert!(rules_of(LIB, src).is_empty());
    }

    // ---- L5 forbidden-construct ----

    #[test]
    fn transmute_is_flagged() {
        let src = "\
fn f(x: u32) -> f32 {
    // SAFETY: same size
    unsafe { std::mem::transmute(x) }
}
";
        let f = check_file(LIB, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_FORBIDDEN);
    }

    #[test]
    fn static_mut_is_flagged_even_in_main() {
        let src = "static mut COUNTER: u32 = 0;\nfn main() {}\n";
        let f = check_file("src/main.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_FORBIDDEN);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn plain_static_passes() {
        let src = "static NAME: &str = \"qlc\";\n";
        assert!(rules_of(LIB, src).is_empty());
    }

    // ---- scope plumbing ----

    #[test]
    fn guard_in_previous_function_does_not_leak() {
        let src = "\
fn checked(n: usize) -> bool {
    n < 100
}
fn put(n: usize, out: &mut Vec<u8>) {
    out.extend_from_slice(&(n as u32).to_le_bytes());
}
";
        let f = check_file(WIRE, src);
        assert_eq!(f.len(), 1, "guard must not leak across fns: {f:?}");
        assert_eq!(f[0].line, 5);
    }

    #[test]
    fn all_five_rules_fire_on_a_seeded_fixture() {
        let src = "\
static mut GLOBAL: u32 = 0;
fn bad(n: usize, v: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    out.push((n as u8).to_le_bytes()[0]);
    let first = *v.first().unwrap();
    let x: f32 = unsafe { std::mem::transmute(n as u32) };
    out.push(first.wrapping_add(x as u8));
    out
}
";
        let rules: Vec<&str> = rules_of(WIRE, src);
        for rule in [
            RULE_NARROWING,
            RULE_CAP_ALLOC,
            RULE_PANIC_FREE,
            RULE_SAFETY,
            RULE_FORBIDDEN,
        ] {
            assert!(rules.contains(&rule), "{rule} missing from {rules:?}");
        }
    }
}
