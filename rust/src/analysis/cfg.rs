//! Function-level control-flow recovery for `qlc analyze` v2.
//!
//! Built directly on the [`super::lexer`] masked view: [`tokenize`]
//! turns masked source into a flat token stream (comments, strings,
//! and test regions are already spaces, so every token is real code),
//! and [`parse_functions`] recovers `fn` items — name, parameter
//! names, and a statement tree with `let` bindings, assignments,
//! branches, loops, and `match` arms — without pulling in `syn`.
//!
//! The recovery is deliberately approximate: it only needs to be
//! good enough for the intra-procedural taint pass in
//! [`super::taint`].  Whatever it cannot classify becomes an opaque
//! [`Stmt::Expr`], which the taint pass still scans for sinks, so
//! parse imprecision degrades to the old line-level behaviour rather
//! than to silence.  On malformed input the parser must never panic
//! (a proptest holds it to that) — it simply returns fewer or
//! stranger statements.

/// Token kind, as coarse as the rules need.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `let`, `payload_len`, `u32`, ...).
    Ident,
    /// Numeric literal (`0`, `0xFF`, `1_024`, `4u32`).
    Num,
    /// Lifetime or loop label (`'a`, `'pump`).
    Lifetime,
    /// Punctuation, multi-char operators kept whole (`=>`, `::`, `?`).
    Punct,
}

/// One token of masked source, carrying its 1-indexed line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tok {
    pub line: usize,
    pub kind: TokKind,
    pub text: String,
}

impl Tok {
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }
}

/// Multi-char punctuation, longest first so the scan is greedy.
const PUNCT3: [&str; 3] = ["<<=", ">>=", "..="];
const PUNCT2: [&str; 19] = [
    "==", "!=", "<=", ">=", "->", "=>", "::", "+=", "-=", "*=", "/=",
    "%=", "&&", "||", "<<", ">>", "..", "&=", "|=",
];

/// Tokenize masked code.  Never fails: unknown bytes become 1-char
/// punctuation tokens.
pub fn tokenize(code: &str) -> Vec<Tok> {
    let chars: Vec<char> = code.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c == '_' || c.is_ascii_alphabetic() {
            let s = i;
            while i < n && (chars[i] == '_' || chars[i].is_ascii_alphanumeric())
            {
                i += 1;
            }
            let text: String = chars[s..i].iter().collect();
            toks.push(Tok { line, kind: TokKind::Ident, text });
            continue;
        }
        if c.is_ascii_digit() {
            let s = i;
            while i < n {
                let d = chars[i];
                // Stop before `..` so ranges stay punctuation.
                if d == '.' && chars.get(i + 1) == Some(&'.') {
                    break;
                }
                if d == '_' || d == '.' || d.is_ascii_alphanumeric() {
                    i += 1;
                } else {
                    break;
                }
            }
            let text: String = chars[s..i].iter().collect();
            toks.push(Tok { line, kind: TokKind::Num, text });
            continue;
        }
        if c == '\'' {
            // The lexer already masked char literals; what remains is
            // a lifetime or loop label.
            let s = i;
            i += 1;
            while i < n && (chars[i] == '_' || chars[i].is_ascii_alphanumeric())
            {
                i += 1;
            }
            let text: String = chars[s..i].iter().collect();
            toks.push(Tok { line, kind: TokKind::Lifetime, text });
            continue;
        }
        let rest: String = chars[i..n.min(i + 3)].iter().collect();
        let mut len = 1usize;
        if PUNCT3.iter().any(|p| rest.starts_with(p)) {
            len = 3;
        } else if PUNCT2.iter().any(|p| rest.starts_with(p)) {
            len = 2;
        }
        let text: String = chars[i..i + len].iter().collect();
        toks.push(Tok { line, kind: TokKind::Punct, text });
        i += len;
    }
    toks
}

/// One recovered statement.  Expression token lists (`rhs`, `cond`,
/// ...) are flat — nested calls and blocks inside them are kept as
/// raw tokens for the taint pass to scan.
#[derive(Clone, Debug)]
pub enum Stmt {
    /// `let <pat>(: ty)? = <rhs> (else { .. })? ;`
    Let {
        names: Vec<String>,
        rhs: Vec<Tok>,
        else_block: Option<Block>,
        line: usize,
    },
    /// `<lhs> =|+=|*=|... <rhs> ;`
    Assign {
        lhs: Vec<Tok>,
        op: String,
        rhs: Vec<Tok>,
        line: usize,
    },
    If {
        cond: Vec<Tok>,
        then_block: Block,
        else_block: Option<Block>,
        line: usize,
    },
    While {
        cond: Vec<Tok>,
        body: Block,
        line: usize,
    },
    For {
        names: Vec<String>,
        iter: Vec<Tok>,
        body: Block,
        line: usize,
    },
    Loop {
        body: Block,
        line: usize,
    },
    /// `match <scrutinee> { arms }` — each arm is (pattern binders,
    /// arm body as a block).
    Match {
        scrutinee: Vec<Tok>,
        arms: Vec<(Vec<String>, Block)>,
        line: usize,
    },
    Return {
        value: Vec<Tok>,
        line: usize,
    },
    Break {
        line: usize,
    },
    Continue {
        line: usize,
    },
    /// Plain or `unsafe` block used as a statement.
    BlockStmt {
        body: Block,
        line: usize,
    },
    /// Anything else: opaque expression statement.
    Expr {
        toks: Vec<Tok>,
        line: usize,
    },
}

/// A `{ ... }` statement list.
#[derive(Clone, Debug, Default)]
pub struct Block {
    pub stmts: Vec<Stmt>,
}

/// One recovered `fn` item (free function, method, or nested fn).
#[derive(Clone, Debug)]
pub struct Function {
    pub name: String,
    /// Parameter names (`self` included verbatim).
    pub params: Vec<String>,
    pub body: Block,
    /// 1-indexed line of the `fn` keyword.
    pub line: usize,
}

pub(crate) fn text_at<'a>(toks: &'a [Tok], i: usize) -> &'a str {
    toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

pub(crate) fn is_open(t: &str) -> bool {
    t == "(" || t == "[" || t == "{"
}

pub(crate) fn is_close(t: &str) -> bool {
    t == ")" || t == "]" || t == "}"
}

/// Index one past the delimiter group opening at `i` (any of `([{`,
/// matched loosely against any closer — good enough on real code,
/// never panics on bad code).
pub(crate) fn skip_group(toks: &[Tok], i: usize) -> usize {
    let mut depth = 0isize;
    let mut k = i;
    while k < toks.len() {
        let t = text_at(toks, k);
        if is_open(t) {
            depth += 1;
        } else if is_close(t) {
            depth -= 1;
            if depth <= 0 {
                return k + 1;
            }
        }
        k += 1;
    }
    toks.len()
}

pub(crate) const KEYWORDS: [&str; 22] = [
    "mut", "ref", "move", "let", "if", "else", "match", "while", "for",
    "loop", "in", "fn", "return", "break", "continue", "as", "box",
    "dyn", "impl", "where", "pub", "unsafe",
];

/// Lowercase identifiers in a pattern that plausibly bind values
/// (skips keywords, `_`, and capitalized constructor names).
pub(crate) fn pattern_names(toks: &[Tok]) -> Vec<String> {
    let mut out = Vec::new();
    for (k, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || t.text == "_" {
            continue;
        }
        let first = t.text.chars().next().unwrap_or('_');
        if !(first.is_ascii_lowercase() || first == '_') {
            continue;
        }
        if KEYWORDS.contains(&t.text.as_str()) {
            continue;
        }
        // Skip path segments (`mod::name`) and struct field keys
        // followed by `:` then a different binder.
        if text_at(toks, k + 1) == "::" || text_at(toks, k.wrapping_sub(1)) == "::"
        {
            continue;
        }
        if !out.contains(&t.text) {
            out.push(t.text.clone());
        }
    }
    out
}

/// Parse a parameter list starting at `toks[i] == "("`; returns the
/// parameter names and the index one past the closing paren.
fn parse_params(toks: &[Tok], i: usize) -> (Vec<String>, usize) {
    let end = skip_group(toks, i);
    let inner_end = end.saturating_sub(1);
    let inner = if i + 1 <= inner_end { &toks[i + 1..inner_end] } else { &[] };
    let mut params = Vec::new();
    let mut piece: Vec<Tok> = Vec::new();
    let mut depth = 0isize;
    let mut flush = |piece: &mut Vec<Tok>, params: &mut Vec<String>| {
        if piece.iter().any(|t| t.is("self")) {
            params.push("self".to_string());
        } else {
            // Names are the pattern before the depth-0 `:`.
            let mut d = 0isize;
            let mut cut = piece.len();
            for (k, t) in piece.iter().enumerate() {
                if is_open(&t.text) {
                    d += 1;
                } else if is_close(&t.text) {
                    d -= 1;
                } else if t.is(":") && d == 0 {
                    cut = k;
                    break;
                }
            }
            for name in pattern_names(&piece[..cut]) {
                params.push(name);
            }
        }
        piece.clear();
    };
    for t in inner {
        if is_open(&t.text) {
            depth += 1;
        } else if is_close(&t.text) {
            depth -= 1;
        }
        if t.is(",") && depth == 0 {
            flush(&mut piece, &mut params);
        } else {
            piece.push(t.clone());
        }
    }
    if !piece.is_empty() {
        flush(&mut piece, &mut params);
    }
    (params, end)
}

/// Skip a generic parameter list starting at `toks[i] == "<"`,
/// tolerating `Fn(..) -> T` bounds and shift-shaped closers.
fn skip_generics(toks: &[Tok], i: usize) -> usize {
    let mut depth = 0isize;
    let mut k = i;
    let mut steps = 0usize;
    while k < toks.len() && steps < 4096 {
        steps += 1;
        match text_at(toks, k) {
            "<" => depth += 1,
            "<<" => depth += 2,
            ">" => depth -= 1,
            ">>" => depth -= 2,
            "(" | "[" => {
                k = skip_group(toks, k);
                continue;
            }
            "{" | ";" => return k, // malformed; bail before the body
            _ => {}
        }
        k += 1;
        if depth <= 0 {
            return k;
        }
    }
    k
}

/// All `fn` items in masked code, including nested and `impl` fns.
pub fn parse_functions(code: &str) -> Vec<Function> {
    let toks = tokenize(code);
    let mut fns = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        if !(t.kind == TokKind::Ident && t.is("fn")) {
            i += 1;
            continue;
        }
        let Some(name_tok) = toks.get(i + 1) else { break };
        if name_tok.kind != TokKind::Ident {
            i += 1;
            continue;
        }
        let line = t.line;
        let name = name_tok.text.clone();
        let mut j = i + 2;
        if text_at(&toks, j) == "<" {
            j = skip_generics(&toks, j);
        }
        if text_at(&toks, j) != "(" {
            i += 1;
            continue;
        }
        let (params, after_params) = parse_params(&toks, j);
        // Scan past the return type / where clause to the body.
        let mut k = after_params;
        let mut depth = 0isize;
        let mut body_at: Option<usize> = None;
        while k < toks.len() {
            let txt = text_at(&toks, k);
            if depth == 0 && txt == ";" {
                break; // trait method / extern decl: no body
            }
            if depth == 0 && txt == "{" {
                body_at = Some(k);
                break;
            }
            if txt == "(" || txt == "[" {
                depth += 1;
            } else if txt == ")" || txt == "]" {
                depth -= 1;
            }
            k += 1;
        }
        if let Some(b) = body_at {
            let mut bi = b;
            let body = parse_block(&toks, &mut bi);
            fns.push(Function { name, params, body, line });
            // Continue scanning *inside* the body so nested fns are
            // found too (parse_stmt skips them as statements).
            i = b + 1;
        } else {
            i = k.max(i + 1);
        }
    }
    fns
}

/// Parse a `{ ... }` block with the cursor on the opening brace.
fn parse_block(toks: &[Tok], i: &mut usize) -> Block {
    let mut stmts = Vec::new();
    if text_at(toks, *i) != "{" {
        return Block { stmts };
    }
    *i += 1;
    while *i < toks.len() && text_at(toks, *i) != "}" {
        let before = *i;
        if let Some(s) = parse_stmt(toks, i) {
            stmts.push(s);
        }
        if *i == before {
            *i += 1; // always make progress
        }
    }
    if *i < toks.len() {
        *i += 1; // consume the closing brace
    }
    Block { stmts }
}

/// Parse a flat token slice as a statement list.  Used for
/// block-expression `let` initializers (`let x = match .. { .. }`),
/// where the initializer's inner statements carry their own control
/// flow and must not be scanned as one flat expression.
pub(crate) fn parse_stmts(toks: &[Tok]) -> Vec<Stmt> {
    let mut stmts = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let before = i;
        if let Some(s) = parse_stmt(toks, &mut i) {
            stmts.push(s);
        }
        if i == before {
            i += 1; // always make progress
        }
    }
    stmts
}

/// Collect expression tokens until a depth-0 `;` (consumed) or the
/// enclosing block's `}` (left in place).
fn collect_expr(toks: &[Tok], i: &mut usize) -> Vec<Tok> {
    let mut out = Vec::new();
    let mut depth = 0isize;
    while *i < toks.len() {
        let t = &toks[*i];
        if depth == 0 && t.is(";") {
            *i += 1;
            break;
        }
        if t.is("}") && depth == 0 {
            break;
        }
        if is_open(&t.text) {
            depth += 1;
        } else if is_close(&t.text) {
            depth -= 1;
        }
        out.push(t.clone());
        *i += 1;
    }
    out
}

/// Collect tokens until a depth-0 `{` (left in place) — used for
/// `if`/`while` conditions and `for` iterators, where Rust forbids
/// bare struct literals.
fn collect_until_brace(toks: &[Tok], i: &mut usize) -> Vec<Tok> {
    let mut out = Vec::new();
    let mut depth = 0isize;
    while *i < toks.len() {
        let t = &toks[*i];
        if depth == 0 && t.is("{") {
            break;
        }
        if t.is("(") || t.is("[") {
            depth += 1;
        } else if t.is(")") || t.is("]") {
            depth -= 1;
        } else if t.is("{") {
            depth += 1; // closure body inside the condition
        } else if t.is("}") {
            depth -= 1;
        }
        out.push(t.clone());
        *i += 1;
    }
    out
}

const ASSIGN_OPS: [&str; 10] =
    ["=", "+=", "-=", "*=", "/=", "%=", "<<=", ">>=", "&=", "|="];

/// Is `toks[..k]` a plain assignable place (path, maybe indexed)?
fn looks_like_place(toks: &[Tok]) -> bool {
    !toks.is_empty()
        && toks.iter().all(|t| {
            t.kind == TokKind::Ident
                || t.kind == TokKind::Num
                || matches!(
                    t.text.as_str(),
                    "." | "::" | "[" | "]" | "*" | "(" | ")"
                )
        })
        && !toks
            .iter()
            .any(|t| t.kind == TokKind::Ident && KEYWORDS.contains(&t.text.as_str()))
}

fn parse_stmt(toks: &[Tok], i: &mut usize) -> Option<Stmt> {
    let t = toks.get(*i)?;
    let line = t.line;
    // Statement attributes: `#[cfg(unix)]` etc.
    if t.is("#") {
        *i += 1;
        if text_at(toks, *i) == "[" {
            *i = skip_group(toks, *i);
        }
        return None;
    }
    // Loop labels: `'pump: loop { ... }`.
    if t.kind == TokKind::Lifetime && text_at(toks, *i + 1) == ":" {
        *i += 2;
        return None;
    }
    if t.kind == TokKind::Ident {
        match t.text.as_str() {
            "let" => return parse_let(toks, i),
            "if" => return Some(parse_if(toks, i)),
            "while" => {
                *i += 1;
                let cond = collect_until_brace(toks, i);
                let body = parse_block(toks, i);
                return Some(Stmt::While { cond, body, line });
            }
            "for" => {
                *i += 1;
                // Pattern until depth-0 `in`.
                let mut pat = Vec::new();
                let mut depth = 0isize;
                while *i < toks.len() {
                    let p = &toks[*i];
                    if depth == 0 && p.is("in") && p.kind == TokKind::Ident {
                        *i += 1;
                        break;
                    }
                    if is_open(&p.text) {
                        depth += 1;
                    } else if is_close(&p.text) {
                        depth -= 1;
                    }
                    pat.push(p.clone());
                    *i += 1;
                }
                let iter = collect_until_brace(toks, i);
                let body = parse_block(toks, i);
                return Some(Stmt::For {
                    names: pattern_names(&pat),
                    iter,
                    body,
                    line,
                });
            }
            "loop" => {
                *i += 1;
                let body = parse_block(toks, i);
                return Some(Stmt::Loop { body, line });
            }
            "match" => return Some(parse_match(toks, i)),
            "return" => {
                *i += 1;
                let value = collect_expr(toks, i);
                return Some(Stmt::Return { value, line });
            }
            "break" => {
                *i += 1;
                let _ = collect_expr(toks, i);
                return Some(Stmt::Break { line });
            }
            "continue" => {
                *i += 1;
                let _ = collect_expr(toks, i);
                return Some(Stmt::Continue { line });
            }
            "unsafe" if text_at(toks, *i + 1) == "{" => {
                *i += 1;
                let body = parse_block(toks, i);
                return Some(Stmt::BlockStmt { body, line });
            }
            // Nested items inside a fn body: skip them whole (nested
            // fns are picked up by parse_functions' own scan).
            "fn" | "impl" | "struct" | "enum" | "trait" | "mod"
            | "extern" | "union" | "macro_rules" => {
                skip_item(toks, i);
                return None;
            }
            "use" | "type" | "const" | "static" => {
                let _ = collect_expr(toks, i);
                return None;
            }
            _ => {}
        }
    }
    if t.is("{") {
        let body = parse_block(toks, i);
        return Some(Stmt::BlockStmt { body, line });
    }
    // Expression statement; classify simple assignments.
    let toks_e = collect_expr(toks, i);
    if toks_e.is_empty() {
        return None;
    }
    let mut depth = 0isize;
    for (k, tok) in toks_e.iter().enumerate() {
        if is_open(&tok.text) {
            depth += 1;
        } else if is_close(&tok.text) {
            depth -= 1;
        } else if depth == 0
            && k > 0
            && tok.kind == TokKind::Punct
            && ASSIGN_OPS.contains(&tok.text.as_str())
        {
            let (lhs, rhs) = toks_e.split_at(k);
            if looks_like_place(lhs) {
                return Some(Stmt::Assign {
                    lhs: lhs.to_vec(),
                    op: tok.text.clone(),
                    rhs: rhs[1..].to_vec(),
                    line,
                });
            }
            break;
        }
    }
    Some(Stmt::Expr { toks: toks_e, line })
}

/// Skip a nested item (`fn`/`impl`/`mod`/...) with the cursor on its
/// introducing keyword: to the end of its first brace group, or the
/// first top-level `;` for brace-less forms.
fn skip_item(toks: &[Tok], i: &mut usize) {
    let mut depth = 0isize;
    while *i < toks.len() {
        let t = text_at(toks, *i);
        if depth == 0 && t == ";" {
            *i += 1;
            return;
        }
        if t == "{" {
            *i = skip_group(toks, *i);
            return;
        }
        if t == "(" || t == "[" {
            depth += 1;
        } else if t == ")" || t == "]" {
            depth -= 1;
        }
        *i += 1;
    }
}

fn parse_let(toks: &[Tok], i: &mut usize) -> Option<Stmt> {
    let line = toks.get(*i)?.line;
    *i += 1;
    // Pattern up to `:` / `=` / `;` at depth 0.
    let mut pat = Vec::new();
    let mut depth = 0isize;
    let mut saw_eq = false;
    while *i < toks.len() {
        let t = &toks[*i];
        if depth == 0 {
            if t.is("=") {
                saw_eq = true;
                *i += 1;
                break;
            }
            if t.is(";") {
                *i += 1;
                break;
            }
            if t.is(":") {
                // Type annotation: skip to the depth-0 `=` or `;`.
                *i += 1;
                while *i < toks.len() {
                    let u = &toks[*i];
                    if depth == 0 && u.is("=") {
                        saw_eq = true;
                        *i += 1;
                        break;
                    }
                    if depth == 0 && (u.is(";") || u.is("}")) {
                        if u.is(";") {
                            *i += 1;
                        }
                        break;
                    }
                    if is_open(&u.text) {
                        depth += 1;
                    } else if is_close(&u.text) {
                        depth -= 1;
                    }
                    *i += 1;
                }
                break;
            }
        }
        if is_open(&t.text) {
            depth += 1;
        } else if is_close(&t.text) {
            depth -= 1;
            if depth < 0 {
                break;
            }
        }
        pat.push(t.clone());
        *i += 1;
    }
    let names = pattern_names(&pat);
    if !saw_eq {
        return Some(Stmt::Let { names, rhs: Vec::new(), else_block: None, line });
    }
    // RHS until depth-0 `;`, with let-else detection.  An `else` at
    // depth 0 is a let-else only when the RHS is not itself an
    // `if`/`match`/`loop` expression (whose own `else` stays inline).
    let mut rhs: Vec<Tok> = Vec::new();
    let mut else_block = None;
    let mut depth = 0isize;
    let mut block_expr_rhs = false;
    while *i < toks.len() {
        let t = &toks[*i];
        if rhs.is_empty() {
            block_expr_rhs = matches!(
                t.text.as_str(),
                "if" | "match" | "loop" | "while" | "unsafe" | "{"
            );
        }
        if depth == 0 && t.is(";") {
            *i += 1;
            break;
        }
        if depth == 0 && t.is("else") && !block_expr_rhs {
            *i += 1;
            else_block = Some(parse_block(toks, i));
            if text_at(toks, *i) == ";" {
                *i += 1;
            }
            break;
        }
        if t.is("}") && depth == 0 {
            break;
        }
        if is_open(&t.text) {
            depth += 1;
        } else if is_close(&t.text) {
            depth -= 1;
        }
        rhs.push(t.clone());
        *i += 1;
    }
    Some(Stmt::Let { names, rhs, else_block, line })
}

fn parse_if(toks: &[Tok], i: &mut usize) -> Stmt {
    let line = toks.get(*i).map(|t| t.line).unwrap_or(1);
    *i += 1;
    let cond = collect_until_brace(toks, i);
    let then_block = parse_block(toks, i);
    let mut else_block = None;
    if text_at(toks, *i) == "else" {
        *i += 1;
        if text_at(toks, *i) == "if" {
            let nested = parse_if(toks, i);
            else_block = Some(Block { stmts: vec![nested] });
        } else {
            else_block = Some(parse_block(toks, i));
        }
    }
    Stmt::If { cond, then_block, else_block, line }
}

fn parse_match(toks: &[Tok], i: &mut usize) -> Stmt {
    let line = toks.get(*i).map(|t| t.line).unwrap_or(1);
    *i += 1;
    let scrutinee = collect_until_brace(toks, i);
    let mut arms = Vec::new();
    if text_at(toks, *i) == "{" {
        *i += 1;
        while *i < toks.len() && text_at(toks, *i) != "}" {
            // Arm pattern (with optional guard) up to depth-0 `=>`.
            let mut pat = Vec::new();
            let mut depth = 0isize;
            let mut saw_arrow = false;
            while *i < toks.len() {
                let t = &toks[*i];
                if depth == 0 && t.is("=>") {
                    saw_arrow = true;
                    *i += 1;
                    break;
                }
                if depth == 0 && t.is("}") {
                    break;
                }
                if is_open(&t.text) {
                    depth += 1;
                } else if is_close(&t.text) {
                    depth -= 1;
                }
                pat.push(t.clone());
                *i += 1;
            }
            if !saw_arrow {
                break;
            }
            // Arm body: a block, or an expression up to depth-0 `,`.
            let body = if text_at(toks, *i) == "{" {
                parse_block(toks, i)
            } else {
                let mut btoks = Vec::new();
                let bline =
                    toks.get(*i).map(|t| t.line).unwrap_or(line);
                let mut d = 0isize;
                while *i < toks.len() {
                    let t = &toks[*i];
                    if d == 0 && (t.is(",") || t.is("}")) {
                        if t.is(",") {
                            *i += 1;
                        }
                        break;
                    }
                    if is_open(&t.text) {
                        d += 1;
                    } else if is_close(&t.text) {
                        d -= 1;
                    }
                    btoks.push(t.clone());
                    *i += 1;
                }
                // Re-parse the expression tokens as a one-stmt block
                // so `return` arms and nested sinks are seen.
                let mut bi = 0usize;
                let mut stmts = Vec::new();
                while bi < btoks.len() {
                    let before = bi;
                    if let Some(s) = parse_stmt(&btoks, &mut bi) {
                        stmts.push(s);
                    }
                    if bi == before {
                        bi += 1;
                    }
                }
                if stmts.is_empty() && !btoks.is_empty() {
                    stmts.push(Stmt::Expr { toks: btoks, line: bline });
                }
                Block { stmts }
            };
            // Strip an `if` guard's tokens from the binder set.
            let guard_at = pat
                .iter()
                .position(|t| t.kind == TokKind::Ident && t.is("if"));
            let pat_only = match guard_at {
                Some(g) => &pat[..g],
                None => &pat[..],
            };
            arms.push((pattern_names(pat_only), body));
            if text_at(toks, *i) == "," {
                *i += 1;
            }
        }
        if text_at(toks, *i) == "}" {
            *i += 1;
        }
    }
    Stmt::Match { scrutinee, arms, line }
}

/// Total number of blocks (the function body plus every nested
/// block).  Used by the proptests: comment insertion must never
/// change this count, because masked comments carry no tokens.
pub fn block_count(f: &Function) -> usize {
    fn of_block(b: &Block) -> usize {
        let mut n = 1usize;
        for s in &b.stmts {
            n += of_stmt(s);
        }
        n
    }
    fn of_stmt(s: &Stmt) -> usize {
        match s {
            Stmt::Let { else_block, .. } => {
                else_block.as_ref().map(of_block).unwrap_or(0)
            }
            Stmt::If { then_block, else_block, .. } => {
                of_block(then_block)
                    + else_block.as_ref().map(of_block).unwrap_or(0)
            }
            Stmt::While { body, .. }
            | Stmt::For { body, .. }
            | Stmt::Loop { body, .. }
            | Stmt::BlockStmt { body, .. } => of_block(body),
            Stmt::Match { arms, .. } => {
                arms.iter().map(|(_, b)| of_block(b)).sum()
            }
            _ => 0,
        }
    }
    of_block(&f.body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer;
    use crate::util::prop::{self, Config};

    fn functions(src: &str) -> Vec<Function> {
        parse_functions(&lexer::strip(src).code)
    }

    #[test]
    fn recovers_name_params_and_lines() {
        let src = "\
fn put(n: usize, out: &mut Vec<u8>) {
    out.push(0);
}
";
        let fns = functions(src);
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "put");
        assert_eq!(fns[0].params, vec!["n", "out"]);
        assert_eq!(fns[0].line, 1);
        assert_eq!(fns[0].body.stmts.len(), 1);
    }

    #[test]
    fn recovers_methods_and_self() {
        let src = "\
impl Foo {
    fn go(&mut self, len: usize) -> usize {
        self.total += len;
        self.total
    }
}
";
        let fns = functions(src);
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "go");
        assert_eq!(fns[0].params, vec!["self", "len"]);
        assert!(matches!(fns[0].body.stmts[0], Stmt::Assign { .. }));
    }

    #[test]
    fn let_else_and_if_else_chains_parse() {
        let src = "\
fn f(x: Option<usize>) -> usize {
    let Some(v) = x else {
        return 0;
    };
    if v > 4 {
        v
    } else if v > 2 {
        1
    } else {
        2
    }
}
";
        let fns = functions(src);
        assert_eq!(fns.len(), 1);
        let stmts = &fns[0].body.stmts;
        match &stmts[0] {
            Stmt::Let { names, else_block, .. } => {
                assert_eq!(names, &vec!["v".to_string()]);
                assert!(else_block.is_some());
            }
            other => panic!("expected let-else, got {other:?}"),
        }
        match &stmts[1] {
            Stmt::If { else_block, .. } => assert!(else_block.is_some()),
            other => panic!("expected if, got {other:?}"),
        }
    }

    #[test]
    fn loops_and_match_arms_parse() {
        let src = "\
fn f(n: usize) -> usize {
    let mut acc = 0;
    for i in 0..n {
        acc += i;
    }
    while acc > 10 {
        acc -= 1;
    }
    match acc {
        0 => 1,
        v => {
            v
        }
    }
}
";
        let fns = functions(src);
        let stmts = &fns[0].body.stmts;
        assert!(matches!(stmts[1], Stmt::For { .. }));
        assert!(matches!(stmts[2], Stmt::While { .. }));
        match &stmts[3] {
            Stmt::Match { arms, .. } => {
                assert_eq!(arms.len(), 2);
                assert_eq!(arms[1].0, vec!["v".to_string()]);
            }
            other => panic!("expected match, got {other:?}"),
        }
    }

    #[test]
    fn nested_fns_are_separate_functions() {
        let src = "\
fn outer(n: usize) -> usize {
    fn inner(m: usize) -> usize {
        m + 1
    }
    inner(n)
}
";
        let fns = functions(src);
        let names: Vec<&str> =
            fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["outer", "inner"]);
        // The nested fn's body is not duplicated into outer's stmts.
        assert_eq!(fns[0].body.stmts.len(), 1);
    }

    #[test]
    fn trait_decls_without_bodies_are_skipped() {
        let src = "\
trait T {
    fn sig_only(&self, n: usize) -> usize;
    fn with_default(&self) -> usize {
        1
    }
}
";
        let fns = functions(src);
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "with_default");
    }

    #[test]
    fn generic_fn_bounds_do_not_eat_the_param_list() {
        let src = "\
fn apply<F: FnMut(usize) -> usize>(f: F, seed: usize) -> usize {
    f(seed)
}
";
        let fns = functions(src);
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].params, vec!["f", "seed"]);
    }

    #[test]
    fn raw_strings_and_lifetimes_do_not_derail_recovery() {
        let src = "\
fn lit() -> &'static str {
    r#\"fn fake(x: usize) { vec![0; x] }\"#
}
fn real<'a>(s: &'a str) -> &'a str {
    'outer: loop {
        break 'outer;
    }
    s
}
";
        let fns = functions(src);
        let names: Vec<&str> =
            fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["lit", "real"]);
    }

    #[test]
    fn nested_cfg_test_modules_are_invisible() {
        let src = "\
fn lib() -> usize { 1 }
#[cfg(test)]
mod tests {
    fn helper(n: usize) -> usize { n }
    mod inner {
        fn deeper() {}
    }
}
";
        let fns = functions(src);
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "lib");
    }

    #[test]
    fn multi_line_attribute_macros_are_skipped() {
        let src = "\
#[derive(
    Clone,
    Debug
)]
struct S;
fn keep(n: usize) -> usize {
    #[cfg(unix)]
    let x = n;
    x
}
";
        let fns = functions(src);
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "keep");
    }

    #[test]
    fn tokenizer_separates_labels_from_char_masks() {
        let toks = tokenize("'pump: loop { break 'pump; }");
        assert_eq!(toks[0].kind, TokKind::Lifetime);
        assert_eq!(toks[0].text, "'pump");
        assert!(toks.iter().any(|t| t.is("loop")));
    }

    #[test]
    fn block_count_counts_every_nesting() {
        let src = "\
fn f(n: usize) {
    if n > 1 {
        for _ in 0..n {
            let _ = n;
        }
    } else {
        while n > 0 {
            break;
        }
    }
}
";
        let fns = functions(src);
        // body + then + for-body + else + while-body = 5
        assert_eq!(block_count(&fns[0]), 5);
    }

    /// CFG recovery never panics, whatever bytes it is fed.
    #[test]
    fn cfg_recovery_never_panics_on_arbitrary_bytes() {
        prop::check(
            "cfg recovery on arbitrary bytes",
            Config { cases: 128, ..Config::default() },
            |rng, size| {
                let bytes = prop::arb_bytes(rng, size);
                let text = String::from_utf8_lossy(&bytes).into_owned();
                let fns = parse_functions(&lexer::strip(&text).code);
                for f in &fns {
                    let _ = block_count(f);
                }
                Ok(())
            },
        );
    }

    /// On token-shaped input (random fragments from a Rust-ish pool),
    /// inserting a line comment at any line boundary never changes
    /// the recovered (fn name, block count) shape — masked comments
    /// carry no tokens.
    #[test]
    fn cfg_block_counts_are_stable_under_comment_insertion() {
        const POOL: [&str; 30] = [
            "fn", "f", "g", "let", "x", "=", "{", "}", "(", ")", ";",
            "if", "else", "match", "=>", ",", "0", "+", "*", "loop",
            "while", "for", "in", "..", "return", "n", "vec", "!",
            "[", "]",
        ];
        prop::check(
            "cfg comment-insertion stability",
            Config {
                cases: 128,
                max_size: 256,
                ..Config::default()
            },
            |rng, size| {
                let n_frag = 1 + rng.below(size as u64 + 1) as usize;
                let mut src = String::new();
                for k in 0..n_frag {
                    src.push_str(POOL[rng.below(POOL.len() as u64) as usize]);
                    // Mix separators so tokens land on many lines.
                    src.push(if k % 3 == 0 { '\n' } else { ' ' });
                }
                let shape = |text: &str| -> Vec<(String, usize)> {
                    parse_functions(&lexer::strip(text).code)
                        .iter()
                        .map(|f| (f.name.clone(), block_count(f)))
                        .collect()
                };
                let before = shape(&src);
                let mut lines: Vec<String> =
                    src.split('\n').map(str::to_string).collect();
                let at = rng.below(lines.len() as u64 + 1) as usize;
                lines.insert(
                    at.min(lines.len()),
                    "// inserted comment".to_string(),
                );
                let after = shape(&lines.join("\n"));
                if before != after {
                    return Err(format!(
                        "comment insertion changed recovery: \
                         {before:?} vs {after:?} in\n{src}"
                    ));
                }
                Ok(())
            },
        );
    }
}
