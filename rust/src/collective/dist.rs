//! Distributed collective worker: one OS process per rank, ring-wired
//! over TCP ([`crate::transport::net`]), running the exact lockstep
//! chunk exchange the threaded engine runs on channels
//! ([`super::engine::allreduce_worker`]).
//!
//! Workloads are deterministic from `(seed, rank)`, so N processes
//! that never share memory still agree on calibration histograms,
//! codec tables and input tensors — and a test harness can regenerate
//! the same inputs to check the distributed result against the
//! in-process engine bit-for-bit ([`rank_tensor`], [`calibration`],
//! [`stream_symbols`]).
//!
//! # Timing semantics
//!
//! Over real sockets the chunk pipeline's overlap is *physical*: the
//! measured wall time of the exchange IS the pipelined time, so the
//! [`CollectiveReport`] is filled in from measurement rather than the
//! simulator's recurrence:
//!
//! * `pipelined_time_s` — measured wall time of the collective (codec
//!   work already overlapped with the wire);
//! * `codec_time_s`     — measured per-chunk encode+decode wall time;
//! * `network_time_s`   — the measured wall again: with the pipeline
//!   hiding the codec, the wall is the wire's share.
//!
//! `total_time_s = network + codec = wall + codec` is the serial
//! estimate: a whole-payload transport pays the same transfers plus
//! the codec back-to-back instead of overlapped.  `overlap_savings`
//! is therefore the *measured* codec share the sockets buried —
//! `codec / (wall + codec)` — not a modelled quantity.  (The estimate
//! is slightly generous to the pipeline when codec time leaks onto
//! the critical path — that leak is already inside `wall`.)

use std::time::{Duration, Instant};

use super::engine::{self, WorkerStats};
use super::{CollectiveReport, Transport};
use crate::codecs::frame::{self, FrameOptions, ShardManifest};
use crate::codecs::registry::TAG_RAW;
use crate::codecs::CodecRegistry;
use crate::data::{TensorGen, TensorKind};
use crate::formats::{Variant, BLOCK};
use crate::obs;
use crate::stats::Histogram;
use crate::transport::net::{form_ring, NetConfig};
use crate::transport::{SimLink, DEFAULT_TRANSPORT_CHUNK};
use crate::util::rng::Rng;

/// Which collective the worker runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistOp {
    /// Ring all-reduce of per-rank f32 tensors (quantize-per-hop
    /// reduce-scatter + lossless all-gather).
    Allreduce,
    /// Ring all-gather of QLS1 shard bodies placed by a
    /// [`ShardManifest`] — the shard-granular weight-distribution
    /// path.
    AllgatherShards,
}

impl DistOp {
    pub fn parse(name: &str) -> Result<DistOp, String> {
        match name {
            "allreduce" => Ok(DistOp::Allreduce),
            "allgather" | "allgather-shards" => Ok(DistOp::AllgatherShards),
            other => Err(format!(
                "unknown distributed op '{other}' (expected \
                 allreduce|allgather)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DistOp::Allreduce => "allreduce",
            DistOp::AllgatherShards => "allgather_shards",
        }
    }
}

/// Everything one `qlc worker` process needs to join a collective.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub rank: usize,
    pub world: usize,
    /// Rendezvous address: rank 0 listens here, other ranks connect.
    /// Unused when `world == 1`.
    pub addr: String,
    pub op: DistOp,
    /// Transport codec name ("raw" disables compression).
    pub codec: String,
    /// Workload size, already aligned via [`round_size`]: f32 elements
    /// per rank (allreduce) or total symbols across shards
    /// (allgather).
    pub elems: usize,
    /// Transport chunk granularity in symbols.
    pub chunk_symbols: usize,
    pub seed: u64,
    /// Socket progress timeout (rendezvous and data plane).
    pub timeout: Duration,
}

impl WorkerConfig {
    pub fn new(rank: usize, world: usize, addr: String) -> WorkerConfig {
        WorkerConfig {
            rank,
            world,
            addr,
            op: DistOp::Allreduce,
            codec: "qlc".to_string(),
            elems: 1 << 18,
            chunk_symbols: DEFAULT_TRANSPORT_CHUNK,
            seed: 1,
            timeout: Duration::from_secs(30),
        }
    }
}

/// One finished worker: its report plus the raw result for
/// cross-process comparison.
#[derive(Clone, Debug)]
pub struct DistOutcome {
    pub rank: usize,
    pub report: CollectiveReport,
    /// FNV-1a over `result_bytes` — what `qlc launch` compares across
    /// ranks to assert bit-identical results.
    pub checksum: u64,
    /// The collective's result: f32 little-endian bytes (allreduce) or
    /// the reassembled symbol stream (allgather).
    pub result_bytes: Vec<u8>,
}

/// Round a requested size down to the collective's alignment
/// (`world × BLOCK`), which also guarantees the shard plan yields
/// exactly one shard per rank.  Err when nothing is left.
pub fn round_size(size: usize, world: usize) -> Result<usize, String> {
    if world == 0 {
        return Err("world must be at least 1".into());
    }
    let align = world * BLOCK;
    let n = size - size % align;
    if n == 0 {
        return Err(format!(
            "size {size} is smaller than one alignment unit \
             (world × block = {align})"
        ));
    }
    Ok(n)
}

/// The deterministic per-rank all-reduce input: every process (and
/// every test harness) derives the same tensor from `(seed, rank)`.
pub fn rank_tensor(seed: u64, rank: usize, elems: usize) -> Vec<f32> {
    let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
    let mut base = Rng::new(seed);
    let mut rng = base.fork(rank as u64 + 1);
    gen.generate(&mut rng, elems)
}

/// The deterministic shared symbol stream the allgather workload
/// shards (identical on every rank).
pub fn stream_symbols(seed: u64, total: usize) -> Vec<u8> {
    let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
    let mut rng = Rng::new(seed);
    gen.symbols(&mut rng, total)
}

/// The deterministic calibration histogram all ranks fit their
/// transport codec tables on (paper §7: tables shared apriori).
pub fn calibration(seed: u64) -> Histogram {
    let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
    let mut rng = Rng::new(seed);
    Histogram::from_symbols(&gen.symbols(&mut rng, 256 * BLOCK))
}

/// FNV-1a 64-bit — tiny, dependency-free, good enough to compare
/// results across processes.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Build the [`CollectiveReport`] from measured numbers (module docs:
/// wall IS the pipelined time; serial = wall + codec back-to-back).
fn measured_report(
    op: DistOp,
    transport: String,
    steps: usize,
    stats: &WorkerStats,
    wall_s: f64,
) -> CollectiveReport {
    let wall = wall_s.max(0.0);
    CollectiveReport {
        op: op.name().into(),
        transport,
        steps,
        wire_bytes: stats.wire_bytes,
        raw_bytes: stats.raw_bytes,
        network_time_s: wall,
        codec_time_s: stats.codec_s.max(0.0),
        pipelined_time_s: wall,
    }
}

/// Run one rank of the collective end to end: rendezvous (unless
/// `world == 1`), lockstep exchange, report.
pub fn run_worker(cfg: &WorkerConfig) -> Result<DistOutcome, String> {
    if cfg.world == 0 {
        return Err("world must be at least 1".into());
    }
    if cfg.rank >= cfg.world {
        return Err(format!(
            "rank {} out of range for world {}",
            cfg.rank, cfg.world
        ));
    }
    if cfg.elems == 0 || cfg.elems % (cfg.world * BLOCK) != 0 {
        return Err(format!(
            "size {} must be a non-zero multiple of world × block = {} \
             (see round_size)",
            cfg.elems,
            cfg.world * BLOCK
        ));
    }
    match cfg.op {
        DistOp::Allreduce => run_allreduce(cfg),
        DistOp::AllgatherShards => run_allgather(cfg),
    }
}

fn run_allreduce(cfg: &WorkerConfig) -> Result<DistOutcome, String> {
    let transport = if cfg.codec == "raw" {
        Transport::Raw
    } else {
        Transport::Compressed {
            codec: cfg.codec.clone(),
            calibration: Box::new(calibration(cfg.seed)),
        }
    };
    let handle = transport.resolve()?;
    let tag = handle.as_ref().map(|h| h.wire_tag()).unwrap_or(TAG_RAW);
    let data = rank_tensor(cfg.seed, cfg.rank, cfg.elems);

    let (result, stats, wall_s) = if cfg.world == 1 {
        let mut link = SimLink::new();
        let t0 = Instant::now();
        let (r, s) = engine::allreduce_worker(
            &mut link,
            0,
            1,
            data,
            handle.as_ref(),
            cfg.chunk_symbols,
        )?;
        (r, s, t0.elapsed().as_secs_f64())
    } else {
        let net = NetConfig::new(tag).with_timeout(cfg.timeout);
        let ring_sp = obs::span("dist.form_ring").arg("rank", cfg.rank);
        let mut link = form_ring(cfg.rank, cfg.world, &cfg.addr, &net)?;
        drop(ring_sp);
        let _sp = obs::span("dist.allreduce")
            .arg("rank", cfg.rank)
            .arg("world", cfg.world)
            .arg("codec", &cfg.codec);
        let t0 = Instant::now();
        let (r, s) = engine::allreduce_worker(
            &mut link,
            cfg.rank,
            cfg.world,
            data,
            handle.as_ref(),
            cfg.chunk_symbols,
        )?;
        (r, s, t0.elapsed().as_secs_f64())
    };

    let report = measured_report(
        cfg.op,
        transport.name(),
        2 * (cfg.world - 1),
        &stats,
        wall_s,
    );
    let result_bytes: Vec<u8> =
        result.iter().flat_map(|v| v.to_le_bytes()).collect();
    Ok(DistOutcome {
        rank: cfg.rank,
        checksum: fnv1a64(&result_bytes),
        report,
        result_bytes,
    })
}

fn run_allgather(cfg: &WorkerConfig) -> Result<DistOutcome, String> {
    // Every rank derives the same stream, shard plan and codec tables;
    // it then *encodes only its own shard* and gathers the rest as
    // opaque QLS1 bodies.
    let symbols = stream_symbols(cfg.seed, cfg.elems);
    let hist = Histogram::from_symbols(&symbols);
    let handle = CodecRegistry::global().resolve(&cfg.codec, &hist)?;
    let plan = frame::shard_plan(symbols.len(), cfg.world);
    if plan.len() != cfg.world {
        return Err(format!(
            "size {} yields only {} shards for world {}",
            cfg.elems,
            plan.len(),
            cfg.world
        ));
    }
    let manifest = ShardManifest::from_handle(
        &handle,
        plan.iter().map(|d| d.n_symbols as u64).collect(),
    );
    let desc = plan[cfg.rank];
    let body = frame::compress_shard(
        &handle,
        desc.index as u32,
        &symbols[desc.start..desc.start + desc.n_symbols],
        &FrameOptions::serial(),
    )
    .map_err(|e| e.to_string())?;

    let (bodies, stats, wall_s) = if cfg.world == 1 {
        (vec![body], WorkerStats::default(), 0.0)
    } else {
        let net = NetConfig::new(TAG_RAW).with_timeout(cfg.timeout);
        let ring_sp = obs::span("dist.form_ring").arg("rank", cfg.rank);
        let mut link = form_ring(cfg.rank, cfg.world, &cfg.addr, &net)?;
        drop(ring_sp);
        let _sp = obs::span("dist.allgather")
            .arg("rank", cfg.rank)
            .arg("world", cfg.world)
            .arg("codec", &cfg.codec);
        let t0 = Instant::now();
        let (b, s) = engine::allgather_shards_worker(
            &mut link,
            cfg.rank,
            cfg.world,
            body,
            manifest.shard_symbols(),
        )?;
        (b, s, t0.elapsed().as_secs_f64())
    };

    let gathered =
        frame::decompress_sharded(&manifest, &bodies, &FrameOptions::default())
            .map_err(|e| e.to_string())?;
    if gathered != symbols {
        return Err(
            "gathered shards do not reassemble the source tensor".into()
        );
    }
    let report = measured_report(
        cfg.op,
        format!("qls1:{}", handle.name()),
        cfg.world - 1,
        &stats,
        wall_s,
    );
    Ok(DistOutcome {
        rank: cfg.rank,
        checksum: fnv1a64(&gathered),
        report,
        result_bytes: gathered,
    })
}

/// Kill-on-drop guard over the worker processes `qlc launch` spawns.
///
/// Every exit path that abandons the fleet — a spawn error halfway
/// through the ranks, a failed rank, unparseable worker output, an
/// `Err` in the polling loop — must not leave orphan workers holding
/// their sockets until their own timeouts expire (a broken worker
/// could hang CI's distributed-smoke job that way).  Dropping the
/// fleet kills and reaps whatever is still running.
pub struct Fleet {
    children: Vec<Option<std::process::Child>>,
}

impl Fleet {
    pub fn new() -> Fleet {
        Fleet { children: Vec::new() }
    }

    /// Track a spawned worker; its index is its rank order.
    pub fn push(&mut self, child: std::process::Child) {
        self.children.push(Some(child));
    }

    pub fn len(&self) -> usize {
        self.children.len()
    }

    pub fn is_empty(&self) -> bool {
        self.children.is_empty()
    }

    /// Non-blocking poll of child `i`: `Ok(Some(status))` once it has
    /// exited (the child stays tracked until [`Fleet::take_output`]),
    /// `Ok(None)` while running or after it was collected.
    pub fn try_wait(
        &mut self,
        i: usize,
    ) -> Result<Option<std::process::ExitStatus>, String> {
        match self.children[i].as_mut() {
            None => Ok(None),
            Some(child) => child
                .try_wait()
                .map_err(|e| format!("wait for rank {i}: {e}")),
        }
    }

    /// Collect an exited child's captured output, untracking it.
    pub fn take_output(
        &mut self,
        i: usize,
    ) -> Result<std::process::Output, String> {
        let child = self.children[i]
            .take()
            .ok_or_else(|| format!("rank {i} already collected"))?;
        child
            .wait_with_output()
            .map_err(|e| format!("collect rank {i}: {e}"))
    }

    /// Kill and reap every child still tracked (idempotent; also what
    /// `Drop` runs).
    pub fn kill_all(&mut self) {
        for slot in &mut self.children {
            if let Some(child) = slot.as_mut() {
                let _ = child.kill();
            }
        }
        for slot in &mut self.children {
            if let Some(mut child) = slot.take() {
                let _ = child.wait();
            }
        }
    }
}

impl Default for Fleet {
    fn default() -> Fleet {
        Fleet::new()
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.kill_all();
    }
}

/// A free `127.0.0.1` address for a rendezvous listener.  The probe
/// listener is dropped before the address is used, so there is a tiny
/// reuse race — connect retries in the rendezvous absorb it.
pub fn free_loopback_addr() -> Result<String, String> {
    let l = std::net::TcpListener::bind("127.0.0.1:0")
        .map_err(|e| format!("bind 127.0.0.1:0: {e}"))?;
    Ok(l.local_addr().map_err(|e| e.to_string())?.to_string())
}

/// Run a whole `world` on loopback TCP inside this process, one thread
/// per rank — the same code path `qlc launch` runs as N processes,
/// handy for benches and tests.  Outcomes come back in rank order.
pub fn run_local_ring(
    template: &WorkerConfig,
) -> Result<Vec<DistOutcome>, String> {
    if template.world == 0 {
        return Err("world must be at least 1".into());
    }
    let addr = free_loopback_addr()?;
    let mut handles = Vec::with_capacity(template.world);
    for rank in 0..template.world {
        let mut cfg = template.clone();
        cfg.rank = rank;
        cfg.addr = addr.clone();
        handles.push(std::thread::spawn(move || run_worker(&cfg)));
    }
    let mut outcomes = Vec::with_capacity(template.world);
    for h in handles {
        outcomes.push(h.join().map_err(|_| "worker thread panicked")??);
    }
    Ok(outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::engine::threaded_allreduce;

    fn local_cfg(world: usize, op: DistOp, codec: &str) -> WorkerConfig {
        let mut cfg = WorkerConfig::new(0, world, String::new());
        cfg.op = op;
        cfg.codec = codec.to_string();
        cfg.elems = round_size(world * BLOCK * 32, world).unwrap();
        cfg.seed = 11;
        cfg.timeout = Duration::from_secs(20);
        cfg
    }

    #[test]
    fn round_size_aligns_or_errors() {
        assert_eq!(round_size(4 * BLOCK, 4).unwrap(), 4 * BLOCK);
        assert_eq!(
            round_size(4 * BLOCK + 17, 4).unwrap(),
            4 * BLOCK
        );
        assert!(round_size(BLOCK, 4).is_err(), "too small");
        assert!(round_size(100, 0).is_err(), "zero world");
    }

    #[test]
    fn fleet_kills_children_on_drop() {
        // Two long-sleeping children stand in for hung workers; the
        // fleet's Drop must kill and reap them promptly (a plain wait
        // would block the full 30 s and fail the bound below).
        let t0 = Instant::now();
        {
            let mut fleet = Fleet::new();
            for _ in 0..2 {
                let child = match std::process::Command::new("sleep")
                    .arg("30")
                    .spawn()
                {
                    Ok(c) => c,
                    // No `sleep` binary in this environment — nothing
                    // to reap, nothing to test.
                    Err(_) => return,
                };
                fleet.push(child);
            }
            assert_eq!(fleet.len(), 2);
            assert!(!fleet.is_empty());
            // Children are alive: polling reports still-running.
            let mut f = fleet;
            assert!(f.try_wait(0).unwrap().is_none());
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "fleet drop must kill children, not wait for them \
             ({:?})",
            t0.elapsed()
        );
    }

    #[test]
    fn fnv_distinguishes_streams() {
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"abd"));
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn rank_tensors_are_deterministic_and_distinct() {
        let a = rank_tensor(5, 0, 2 * BLOCK);
        let b = rank_tensor(5, 0, 2 * BLOCK);
        let c = rank_tensor(5, 1, 2 * BLOCK);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn dist_op_parses() {
        assert_eq!(DistOp::parse("allreduce").unwrap(), DistOp::Allreduce);
        assert_eq!(
            DistOp::parse("allgather").unwrap(),
            DistOp::AllgatherShards
        );
        assert!(DistOp::parse("broadcast").is_err());
    }

    #[test]
    fn bad_configs_are_errors() {
        let mut cfg = WorkerConfig::new(0, 0, String::new());
        assert!(run_worker(&cfg).is_err(), "zero world");
        cfg.world = 2;
        cfg.rank = 2;
        assert!(run_worker(&cfg).is_err(), "rank out of range");
        cfg.rank = 0;
        cfg.elems = BLOCK + 1;
        assert!(run_worker(&cfg).is_err(), "unaligned size");
    }

    #[test]
    fn world_one_runs_without_sockets() {
        for op in [DistOp::Allreduce, DistOp::AllgatherShards] {
            let cfg = local_cfg(1, op, "qlc");
            let out = run_worker(&cfg).unwrap();
            assert!(!out.result_bytes.is_empty(), "{op:?}");
            let r = &out.report;
            assert!(r.pipelined_time_s <= r.total_time_s() * (1.0 + 1e-9));
        }
    }

    #[test]
    fn local_tcp_ring_matches_threaded_engine_bit_for_bit() {
        let world = 3;
        let cfg = local_cfg(world, DistOp::Allreduce, "qlc");
        let outcomes = run_local_ring(&cfg).unwrap();
        assert_eq!(outcomes.len(), world);
        for o in &outcomes[1..] {
            assert_eq!(
                o.checksum, outcomes[0].checksum,
                "ranks must agree bit-for-bit"
            );
        }
        // The in-process engine over identically generated tensors.
        let data: Vec<Vec<f32>> = (0..world)
            .map(|r| rank_tensor(cfg.seed, r, cfg.elems))
            .collect();
        let transport = Transport::Compressed {
            codec: "qlc".into(),
            calibration: Box::new(calibration(cfg.seed)),
        };
        let (expect, _) =
            threaded_allreduce(world, data, &transport).unwrap();
        for (rank, o) in outcomes.iter().enumerate() {
            let want: Vec<u8> = expect[rank]
                .iter()
                .flat_map(|v| v.to_le_bytes())
                .collect();
            assert_eq!(
                o.result_bytes, want,
                "rank {rank} diverged from the threaded engine"
            );
            let r = &o.report;
            assert!(r.wire_bytes > 0);
            assert!(
                r.wire_bytes < r.raw_bytes,
                "qlc transport must compress: {} !< {}",
                r.wire_bytes,
                r.raw_bytes
            );
            assert!(
                r.pipelined_time_s <= r.total_time_s() * (1.0 + 1e-9),
                "pipelined {} > serial {}",
                r.pipelined_time_s,
                r.total_time_s()
            );
            // The overlap metric is measured, not tautological: a real
            // codec spends real time, so the serial estimate strictly
            // exceeds the pipelined wall.
            assert!(r.codec_time_s > 0.0, "qlc must cost codec time");
            assert!(
                r.overlap_savings() > 0.0,
                "pipeline must hide a non-zero codec share"
            );
        }
    }

    #[test]
    fn local_tcp_ring_gathers_shards() {
        let world = 3;
        let cfg = local_cfg(world, DistOp::AllgatherShards, "qlc");
        let outcomes = run_local_ring(&cfg).unwrap();
        let stream = stream_symbols(cfg.seed, cfg.elems);
        for o in &outcomes {
            assert_eq!(o.result_bytes, stream, "rank {}", o.rank);
            assert_eq!(o.checksum, fnv1a64(&stream));
        }
        let r = &outcomes[0].report;
        assert_eq!(r.steps, world - 1);
        assert!(r.wire_bytes > 0);
        assert!(r.wire_bytes < r.raw_bytes, "shard bodies must compress");
    }
}
