//! Bandwidth-bound collective operations with lossless compression on
//! the transport — the paper's motivating application (§1: "collective
//! operations are typically bounded by network bandwidth; lossless
//! compression is an effective way to reduce the network traffic").
//!
//! [`Fabric`] models a homogeneous ring of `W` workers with per-link
//! bandwidth and latency.  The ops move *real* data (symbols are
//! actually encoded, shipped, decoded, reduced) so byte counts are
//! exact; time is `latency + bytes/bandwidth` per hop plus measured
//! codec wall-time, with all links in a step running in parallel.
//!
//! Transport framing: codec tables are fitted **apriori** and shared by
//! both endpoints (paper §7: per-tensor-type LUTs "obtained apriori"),
//! so hops carry payload bits only — no per-hop table headers.  Codecs
//! are resolved once per collective through the
//! [`crate::codecs::CodecRegistry`], and every hop reuses one
//! [`EncoderSession`]/[`DecoderSession`] pair per endpoint, so the
//! hot path allocates no codec state.
//!
//! All-reduce semantics: the reduce-scatter phase necessarily
//! re-quantizes partial sums each hop (the wire format is e4m3);
//! after it, each worker quantizes its owned reduced chunk **once**,
//! and the all-gather phase circulates those (symbols, scales)
//! losslessly.  All workers therefore finish with bit-identical
//! results.
//!
//! [`engine`] runs the same ring on real threads and channels.

pub mod engine;

use std::time::Instant;

use crate::codecs::{
    CodecHandle, CodecRegistry, DecoderSession, EncoderSession,
};
use crate::formats::{BlockQuantizer, QuantizedBlocks, Variant, BLOCK};
use crate::stats::Histogram;

/// Network model.
#[derive(Clone, Copy, Debug)]
pub struct Fabric {
    pub workers: usize,
    /// Per-link bandwidth, bytes/second.
    pub link_bandwidth: f64,
    /// Per-hop latency, seconds.
    pub link_latency: f64,
}

impl Fabric {
    /// A pod-like default: 8 workers, 50 GB/s links, 2 µs hops.
    pub fn pod(workers: usize) -> Self {
        Fabric { workers, link_bandwidth: 50e9, link_latency: 2e-6 }
    }

    fn wire_time(&self, bytes: usize) -> f64 {
        self.link_latency + bytes as f64 / self.link_bandwidth
    }
}

/// What travels on each hop.
#[derive(Clone, Debug)]
pub enum Transport {
    /// Raw e4m3 symbols + scales.
    Raw,
    /// Symbols compressed with the named codec (tables fitted on a
    /// calibration histogram, shared apriori by all endpoints).
    Compressed { codec: String, calibration: Box<Histogram> },
}

impl Transport {
    pub fn name(&self) -> String {
        match self {
            Transport::Raw => "raw".into(),
            Transport::Compressed { codec, .. } => codec.clone(),
        }
    }

    /// Resolve the transport codec through the global registry.
    /// `None` means raw (no codec on the wire).
    pub fn resolve(&self) -> Result<Option<CodecHandle>, String> {
        match self {
            Transport::Raw => Ok(None),
            Transport::Compressed { codec, calibration } => Ok(Some(
                CodecRegistry::global().resolve(codec, calibration)?,
            )),
        }
    }
}

/// Measured outcome of one collective.
#[derive(Clone, Debug, Default)]
pub struct CollectiveReport {
    pub op: String,
    pub transport: String,
    pub steps: usize,
    /// Total payload bytes shipped (all links, all steps).
    pub wire_bytes: u64,
    /// Bytes the same op would ship uncompressed.
    pub raw_bytes: u64,
    /// Modelled network time (latency + busiest-link bytes / bw).
    pub network_time_s: f64,
    /// Measured encode+decode wall time on the critical path.
    pub codec_time_s: f64,
}

impl CollectiveReport {
    pub fn total_time_s(&self) -> f64 {
        self.network_time_s + self.codec_time_s
    }

    pub fn compression_ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.wire_bytes.max(1) as f64
    }
}

/// Payload-only encode (tables pre-shared; see module docs).  The
/// session is `None` for raw transport.
pub(crate) fn encode_payload(
    enc: &mut Option<EncoderSession<'_>>,
    symbols: &[u8],
) -> Vec<u8> {
    match enc {
        None => symbols.to_vec(),
        Some(s) => s.encode_chunk_to_vec(symbols),
    }
}

pub(crate) fn decode_payload(
    dec: &mut Option<DecoderSession<'_>>,
    payload: &[u8],
    n_symbols: usize,
) -> Vec<u8> {
    match dec {
        None => payload.to_vec(),
        Some(s) => s
            .decode_chunk_to_vec(payload, n_symbols)
            .expect("transport payload"),
    }
}

/// Bytes on the wire for a hop: payload + one byte per 32-symbol block
/// (E8M0-style shared scale, as in the OCP MX formats).
pub(crate) fn hop_bytes(payload_len: usize, n_blocks: usize) -> usize {
    payload_len + n_blocks
}

/// Ring all-reduce over per-worker f32 tensors. Returns the reduced
/// tensor per worker (bit-identical across workers) plus the report.
pub fn ring_allreduce(
    fabric: &Fabric,
    worker_data: &[Vec<f32>],
    transport: &Transport,
) -> Result<(Vec<Vec<f32>>, CollectiveReport), String> {
    let w = fabric.workers;
    assert_eq!(worker_data.len(), w, "one tensor per worker");
    let n = worker_data[0].len();
    assert!(worker_data.iter().all(|d| d.len() == n));
    assert!(
        n % (w * BLOCK) == 0,
        "tensor must split into w block-aligned chunks"
    );
    let chunk = n / w;
    let quant = BlockQuantizer::new(Variant::ExmY);
    let handle = transport.resolve()?;
    let mut enc = handle.as_ref().map(|h| h.encoder());
    let mut dec = handle.as_ref().map(|h| h.decoder());

    let mut report = CollectiveReport {
        op: "allreduce".into(),
        transport: transport.name(),
        ..Default::default()
    };

    // Working f32 chunks per worker.
    let mut chunks: Vec<Vec<Vec<f32>>> = worker_data
        .iter()
        .map(|d| d.chunks(chunk).map(|c| c.to_vec()).collect())
        .collect();

    // --- Reduce-scatter: quantize per hop, dequantize + add. ---------
    for s in 0..w - 1 {
        let mut max_bytes = 0usize;
        let mut max_codec = 0f64;
        let mut deliveries: Vec<(usize, usize, Vec<f32>)> = Vec::new();
        for i in 0..w {
            let ci = (i + w - s) % w;
            let t0 = Instant::now();
            let q = quant.quantize(&chunks[i][ci]);
            let payload = encode_payload(&mut enc, &q.symbols);
            let symbols = decode_payload(&mut dec, &payload, q.symbols.len());
            let received = quant.dequantize(&QuantizedBlocks {
                symbols,
                scales: q.scales.clone(),
                variant: Variant::ExmY,
            });
            max_codec = max_codec.max(t0.elapsed().as_secs_f64());
            let bytes = hop_bytes(payload.len(), q.scales.len());
            report.wire_bytes += bytes as u64;
            report.raw_bytes += (q.symbols.len() + q.scales.len()) as u64;
            max_bytes = max_bytes.max(bytes);
            deliveries.push(((i + 1) % w, ci, received));
        }
        for (dst, ci, data) in deliveries {
            for (acc, v) in chunks[dst][ci].iter_mut().zip(&data) {
                *acc += v;
            }
        }
        report.steps += 1;
        report.network_time_s += fabric.wire_time(max_bytes);
        report.codec_time_s += max_codec;
    }

    // --- Final quantization of each worker's owned chunk. ------------
    // Worker i owns chunk (i + 1) mod w after reduce-scatter.
    let mut owned: Vec<(usize, QuantizedBlocks)> = (0..w)
        .map(|i| {
            let ci = (i + 1) % w;
            (ci, quant.quantize(&chunks[i][ci]))
        })
        .collect();

    // --- All-gather: circulate (symbols, scales) losslessly. ---------
    // have[i][ci] = Some(quantized chunk) once worker i holds it.
    let mut have: Vec<Vec<Option<QuantizedBlocks>>> =
        vec![vec![None; w]; w];
    for (i, (ci, q)) in owned.drain(..).enumerate() {
        have[i][ci] = Some(q);
    }
    for s in 0..w - 1 {
        let mut max_bytes = 0usize;
        let mut max_codec = 0f64;
        let mut deliveries: Vec<(usize, usize, QuantizedBlocks)> = Vec::new();
        for i in 0..w {
            let ci = (i + 1 + w - s) % w;
            let q = have[i][ci].as_ref().expect("ring invariant");
            let t0 = Instant::now();
            let payload = encode_payload(&mut enc, &q.symbols);
            let symbols = decode_payload(&mut dec, &payload, q.symbols.len());
            max_codec = max_codec.max(t0.elapsed().as_secs_f64());
            let bytes = hop_bytes(payload.len(), q.scales.len());
            report.wire_bytes += bytes as u64;
            report.raw_bytes += (q.symbols.len() + q.scales.len()) as u64;
            max_bytes = max_bytes.max(bytes);
            deliveries.push((
                (i + 1) % w,
                ci,
                QuantizedBlocks {
                    symbols,
                    scales: q.scales.clone(),
                    variant: Variant::ExmY,
                },
            ));
        }
        for (dst, ci, q) in deliveries {
            have[dst][ci] = Some(q);
        }
        report.steps += 1;
        report.network_time_s += fabric.wire_time(max_bytes);
        report.codec_time_s += max_codec;
    }

    // Materialize: every worker dequantizes the same symbol streams.
    let results: Vec<Vec<f32>> = (0..w)
        .map(|i| {
            (0..w)
                .flat_map(|ci| {
                    quant.dequantize(have[i][ci].as_ref().expect("complete"))
                })
                .collect()
        })
        .collect();
    Ok((results, report))
}

/// Ring all-gather of per-worker e4m3 symbol streams (already
/// quantized — e.g. sharded weights).  Returns the gathered stream
/// (identical across workers, asserted) and the report.
pub fn ring_allgather(
    fabric: &Fabric,
    worker_symbols: &[Vec<u8>],
    worker_scales: &[Vec<f32>],
    transport: &Transport,
) -> Result<(Vec<u8>, CollectiveReport), String> {
    let w = fabric.workers;
    assert_eq!(worker_symbols.len(), w);
    let handle = transport.resolve()?;
    let mut enc = handle.as_ref().map(|h| h.encoder());
    let mut dec = handle.as_ref().map(|h| h.decoder());
    let mut report = CollectiveReport {
        op: "allgather".into(),
        transport: transport.name(),
        ..Default::default()
    };

    let mut have: Vec<Vec<Option<Vec<u8>>>> = (0..w)
        .map(|i| {
            (0..w)
                .map(|j| (i == j).then(|| worker_symbols[j].clone()))
                .collect()
        })
        .collect();

    for s in 0..w - 1 {
        let mut max_bytes = 0usize;
        let mut max_codec = 0f64;
        let mut deliveries: Vec<(usize, usize, Vec<u8>)> = Vec::new();
        for i in 0..w {
            let shard = (i + w - s) % w;
            let symbols =
                have[i][shard].as_ref().expect("ring invariant").clone();
            let t0 = Instant::now();
            let payload = encode_payload(&mut enc, &symbols);
            let decoded = decode_payload(&mut dec, &payload, symbols.len());
            max_codec = max_codec.max(t0.elapsed().as_secs_f64());
            let bytes =
                hop_bytes(payload.len(), worker_scales[shard].len());
            report.wire_bytes += bytes as u64;
            report.raw_bytes +=
                (symbols.len() + worker_scales[shard].len()) as u64;
            max_bytes = max_bytes.max(bytes);
            deliveries.push(((i + 1) % w, shard, decoded));
        }
        for (dst, shard, data) in deliveries {
            have[dst][shard] = Some(data);
        }
        report.steps += 1;
        report.network_time_s += fabric.wire_time(max_bytes);
        report.codec_time_s += max_codec;
    }

    let gathered: Vec<u8> = (0..w)
        .flat_map(|j| have[0][j].clone().expect("complete"))
        .collect();
    for i in 1..w {
        let other: Vec<u8> = (0..w)
            .flat_map(|j| have[i][j].clone().expect("complete"))
            .collect();
        assert_eq!(other, gathered, "allgather divergence at worker {i}");
    }
    Ok((gathered, report))
}

/// All-to-all of symbol shards: worker i sends shard j to worker j.
pub fn alltoall(
    fabric: &Fabric,
    shards: &[Vec<Vec<u8>>],
    transport: &Transport,
) -> Result<(Vec<Vec<Vec<u8>>>, CollectiveReport), String> {
    let w = fabric.workers;
    assert_eq!(shards.len(), w);
    assert!(shards.iter().all(|s| s.len() == w));
    let handle = transport.resolve()?;
    let mut enc = handle.as_ref().map(|h| h.encoder());
    let mut dec = handle.as_ref().map(|h| h.decoder());
    let mut report = CollectiveReport {
        op: "alltoall".into(),
        transport: transport.name(),
        ..Default::default()
    };
    let mut out: Vec<Vec<Vec<u8>>> = vec![vec![Vec::new(); w]; w];
    for i in 0..w {
        out[i][i] = shards[i][i].clone();
    }
    for s in 1..w {
        let mut max_bytes = 0usize;
        let mut max_codec = 0f64;
        for i in 0..w {
            let dst = (i + s) % w;
            let data = &shards[i][dst];
            let t0 = Instant::now();
            let payload = encode_payload(&mut enc, data);
            let decoded = decode_payload(&mut dec, &payload, data.len());
            max_codec = max_codec.max(t0.elapsed().as_secs_f64());
            report.wire_bytes += payload.len() as u64;
            report.raw_bytes += data.len() as u64;
            max_bytes = max_bytes.max(payload.len());
            out[dst][i] = decoded;
        }
        report.steps += 1;
        // s ring hops to reach distance s.
        report.network_time_s += fabric.wire_time(max_bytes) * s as f64;
        report.codec_time_s += max_codec;
    }
    Ok((out, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{TensorGen, TensorKind};
    use crate::util::rng::Rng;

    fn random_data(w: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..w)
            .map(|_| {
                let mut v = vec![0f32; n];
                rng.fill_normal_f32(&mut v, 0.0, 1.0);
                v
            })
            .collect()
    }

    fn exact_sum(data: &[Vec<f32>]) -> Vec<f32> {
        let n = data[0].len();
        let mut out = vec![0f32; n];
        for d in data {
            for (o, v) in out.iter_mut().zip(d) {
                *o += v;
            }
        }
        out
    }

    fn calib(seed: u64) -> Box<Histogram> {
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(seed);
        Box::new(Histogram::from_symbols(&gen.symbols(&mut rng, 256 * BLOCK)))
    }

    #[test]
    fn allreduce_workers_bit_identical() {
        let fabric = Fabric::pod(4);
        let data = random_data(4, 4 * BLOCK * 4, 1);
        for transport in [
            Transport::Raw,
            Transport::Compressed { codec: "huffman".into(), calibration: calib(1) },
        ] {
            let (results, report) =
                ring_allreduce(&fabric, &data, &transport).unwrap();
            for (wkr, r) in results.iter().enumerate() {
                assert_eq!(
                    r, &results[0],
                    "worker {wkr} diverged via {}",
                    transport.name()
                );
            }
            assert_eq!(report.steps, 2 * (4 - 1));
        }
    }

    #[test]
    fn allreduce_approximates_exact_sum() {
        let fabric = Fabric::pod(4);
        let data = random_data(4, 4 * BLOCK * 8, 3);
        let want = exact_sum(&data);
        let (results, _) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let scale: f32 = want.iter().fold(0f32, |a, &x| a.max(x.abs()));
        for (a, b) in results[0].iter().zip(&want) {
            // Each of the ≤ w quantizations adds ≤ 2^-4 relative noise.
            assert!((a - b).abs() <= scale * 0.25 + 0.2, "{a} vs {b}");
        }
    }

    #[test]
    fn allreduce_lossless_transport_invariant() {
        // Raw vs Huffman transport must give *identical* results — the
        // codec is lossless, so only bytes differ, never values.
        let fabric = Fabric::pod(4);
        let data = random_data(4, 4 * BLOCK * 8, 4);
        let (raw, _) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let (comp, _) = ring_allreduce(
            &fabric,
            &data,
            &Transport::Compressed {
                codec: "qlc".into(),
                calibration: calib(4),
            },
        )
        .unwrap();
        assert_eq!(raw, comp);
    }

    #[test]
    fn allreduce_compression_reduces_wire_bytes() {
        let fabric = Fabric::pod(4);
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(2);
        let data: Vec<Vec<f32>> =
            (0..4).map(|_| gen.generate(&mut rng, 4 * BLOCK * 32)).collect();
        let (_, raw) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let (_, comp) = ring_allreduce(
            &fabric,
            &data,
            &Transport::Compressed {
                codec: "qlc".into(),
                calibration: calib(2),
            },
        )
        .unwrap();
        assert!(
            comp.wire_bytes < raw.wire_bytes,
            "{} !< {}",
            comp.wire_bytes,
            raw.wire_bytes
        );
        assert!(comp.compression_ratio() > 1.0);
        assert_eq!(comp.raw_bytes, raw.raw_bytes);
    }

    #[test]
    fn allgather_collects_identical_streams() {
        let fabric = Fabric::pod(4);
        let gen = TensorGen::new(TensorKind::Weight, Variant::ExmY);
        let mut rng = Rng::new(4);
        let shards: Vec<Vec<u8>> =
            (0..4).map(|_| gen.symbols(&mut rng, 8 * BLOCK)).collect();
        let scales: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; 8]).collect();
        let cal = Histogram::from_symbols(&shards.concat());
        let (gathered, report) = ring_allgather(
            &fabric,
            &shards,
            &scales,
            &Transport::Compressed {
                codec: "huffman".into(),
                calibration: Box::new(cal),
            },
        )
        .unwrap();
        assert_eq!(gathered, shards.concat());
        assert_eq!(report.steps, 3);
        assert!(report.wire_bytes > 0);
    }

    #[test]
    fn alltoall_permutes_shards() {
        let fabric = Fabric::pod(3);
        let shards: Vec<Vec<Vec<u8>>> = (0..3)
            .map(|i| (0..3).map(|j| vec![(i * 3 + j) as u8; 64]).collect())
            .collect();
        let (out, report) =
            alltoall(&fabric, &shards, &Transport::Raw).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(out[j][i], shards[i][j], "shard {i}->{j}");
            }
        }
        assert_eq!(report.steps, 2);
    }

    #[test]
    fn network_time_decreases_with_bandwidth() {
        let data = random_data(4, 4 * BLOCK * 16, 5);
        let slow =
            Fabric { workers: 4, link_bandwidth: 1e9, link_latency: 1e-6 };
        let fast =
            Fabric { workers: 4, link_bandwidth: 100e9, link_latency: 1e-6 };
        let (_, r_slow) =
            ring_allreduce(&slow, &data, &Transport::Raw).unwrap();
        let (_, r_fast) =
            ring_allreduce(&fast, &data, &Transport::Raw).unwrap();
        assert!(r_slow.network_time_s > r_fast.network_time_s);
        assert_eq!(r_slow.wire_bytes, r_fast.wire_bytes);
    }
}

/// Ring reduce-scatter: each worker ends with the fully-reduced shard
/// it owns (`(i + 1) mod w`), quantized.  The first phase of
/// [`ring_allreduce`], exposed standalone (ZeRO-style sharded
/// optimizers consume exactly this).
pub fn ring_reduce_scatter(
    fabric: &Fabric,
    worker_data: &[Vec<f32>],
    transport: &Transport,
) -> Result<(Vec<(usize, QuantizedBlocks)>, CollectiveReport), String> {
    let w = fabric.workers;
    assert_eq!(worker_data.len(), w);
    let n = worker_data[0].len();
    assert!(n % (w * BLOCK) == 0);
    let chunk = n / w;
    let quant = BlockQuantizer::new(Variant::ExmY);
    let handle = transport.resolve()?;
    let mut enc = handle.as_ref().map(|h| h.encoder());
    let mut dec = handle.as_ref().map(|h| h.decoder());
    let mut report = CollectiveReport {
        op: "reduce_scatter".into(),
        transport: transport.name(),
        ..Default::default()
    };
    let mut chunks: Vec<Vec<Vec<f32>>> = worker_data
        .iter()
        .map(|d| d.chunks(chunk).map(|c| c.to_vec()).collect())
        .collect();
    for s in 0..w - 1 {
        let mut max_bytes = 0usize;
        let mut max_codec = 0f64;
        let mut deliveries: Vec<(usize, usize, Vec<f32>)> = Vec::new();
        for i in 0..w {
            let ci = (i + w - s) % w;
            let t0 = Instant::now();
            let q = quant.quantize(&chunks[i][ci]);
            let payload = encode_payload(&mut enc, &q.symbols);
            let symbols = decode_payload(&mut dec, &payload, q.symbols.len());
            let received = quant.dequantize(&QuantizedBlocks {
                symbols,
                scales: q.scales.clone(),
                variant: Variant::ExmY,
            });
            max_codec = max_codec.max(t0.elapsed().as_secs_f64());
            let bytes = hop_bytes(payload.len(), q.scales.len());
            report.wire_bytes += bytes as u64;
            report.raw_bytes += (q.symbols.len() + q.scales.len()) as u64;
            max_bytes = max_bytes.max(bytes);
            deliveries.push(((i + 1) % w, ci, received));
        }
        for (dst, ci, data) in deliveries {
            for (acc, v) in chunks[dst][ci].iter_mut().zip(&data) {
                *acc += v;
            }
        }
        report.steps += 1;
        report.network_time_s += fabric.wire_time(max_bytes);
        report.codec_time_s += max_codec;
    }
    let owned = (0..w)
        .map(|i| {
            let ci = (i + 1) % w;
            (ci, quant.quantize(&chunks[i][ci]))
        })
        .collect();
    Ok((owned, report))
}

#[cfg(test)]
mod reduce_scatter_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn shards_partition_and_match_allreduce() {
        let w = 4;
        let mut rng = Rng::new(8);
        let data: Vec<Vec<f32>> = (0..w)
            .map(|_| {
                let mut v = vec![0f32; w * BLOCK * 4];
                rng.fill_normal_f32(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let fabric = Fabric::pod(w);
        let (shards, report) =
            ring_reduce_scatter(&fabric, &data, &Transport::Raw).unwrap();
        let (full, _) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let quant = BlockQuantizer::new(Variant::ExmY);
        let chunk = data[0].len() / w;
        // Every owned shard dequantizes to the matching slice of the
        // all-reduce result (all-reduce gathers exactly these shards).
        let mut covered = vec![false; w];
        for (ci, q) in &shards {
            let deq = quant.dequantize(q);
            assert_eq!(&full[0][ci * chunk..(ci + 1) * chunk], &deq[..]);
            covered[*ci] = true;
        }
        assert!(covered.iter().all(|&c| c), "shards must partition");
        assert_eq!(report.steps, w - 1);
    }

    #[test]
    fn half_the_allreduce_traffic() {
        let w = 4;
        let mut rng = Rng::new(9);
        let data: Vec<Vec<f32>> = (0..w)
            .map(|_| {
                let mut v = vec![0f32; w * BLOCK * 8];
                rng.fill_normal_f32(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let fabric = Fabric::pod(w);
        let (_, rs) =
            ring_reduce_scatter(&fabric, &data, &Transport::Raw).unwrap();
        let (_, ar) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        assert_eq!(rs.wire_bytes * 2, ar.wire_bytes);
    }
}
