//! Bandwidth-bound collective operations with lossless compression on
//! the transport — the paper's motivating application (§1: "collective
//! operations are typically bounded by network bandwidth; lossless
//! compression is an effective way to reduce the network traffic").
//!
//! The ops move *real* data (symbols are actually encoded, shipped,
//! decoded, reduced) so byte counts are exact.  Since PR 2 every hop
//! goes through the chunk-granular [`crate::transport`] layer: a hop's
//! message streams as independent byte-aligned chunks, so decode of
//! chunk `k` overlaps the transfer of chunk `k+1`.  Each step reports
//! both the serial time (`latency + bytes/bandwidth` plus measured
//! codec wall-time, as before) and the pipelined time under the
//! transport's hop recurrence — the gap between them is the codec cost
//! the pipeline hides behind the wire.
//!
//! Transport framing: codec tables are fitted **apriori** and shared by
//! both endpoints (paper §7: per-tensor-type LUTs "obtained apriori"),
//! so hops carry payload bits only — no per-hop table headers.  Codecs
//! are resolved once per collective through the
//! [`crate::codecs::CodecRegistry`], and every hop reuses one
//! [`crate::codecs::EncoderSession`]/[`crate::codecs::DecoderSession`]
//! pair per endpoint, so the hot path allocates no codec state.
//!
//! All-reduce semantics: the reduce-scatter phase necessarily
//! re-quantizes partial sums each hop (the wire format is e4m3);
//! after it, each worker quantizes its owned reduced chunk **once**,
//! and the all-gather phase circulates those (symbols, scales)
//! losslessly.  All workers therefore finish with bit-identical
//! results.
//!
//! [`engine`] runs the same chunk-granular ring on real threads and
//! bounded channels (the transport's threaded backend).

pub mod dist;
pub mod engine;

use std::time::Instant;

use crate::codecs::frame::{self, FrameOptions, ShardManifest};
use crate::codecs::{CodecHandle, CodecRegistry};
use crate::formats::{BlockQuantizer, QuantizedBlocks, Variant, BLOCK};
use crate::stats::Histogram;
use crate::transport::{
    exchange_hop, HopTrace, SimLink, DEFAULT_TRANSPORT_CHUNK,
};

pub use crate::transport::Fabric;

/// What travels on each hop.
#[derive(Clone, Debug)]
pub enum Transport {
    /// Raw e4m3 symbols + scales.
    Raw,
    /// Symbols compressed with the named codec (tables fitted on a
    /// calibration histogram, shared apriori by all endpoints).
    Compressed { codec: String, calibration: Box<Histogram> },
}

impl Transport {
    pub fn name(&self) -> String {
        match self {
            Transport::Raw => "raw".into(),
            Transport::Compressed { codec, .. } => codec.clone(),
        }
    }

    /// Resolve the transport codec through the global registry.
    /// `None` means raw (no codec on the wire).
    pub fn resolve(&self) -> Result<Option<CodecHandle>, String> {
        match self {
            Transport::Raw => Ok(None),
            Transport::Compressed { codec, calibration } => Ok(Some(
                CodecRegistry::global().resolve(codec, calibration)?,
            )),
        }
    }
}

/// Measured outcome of one collective.
#[derive(Clone, Debug, Default)]
pub struct CollectiveReport {
    pub op: String,
    pub transport: String,
    pub steps: usize,
    /// Total payload bytes shipped (all links, all steps).
    pub wire_bytes: u64,
    /// Bytes the same op would ship uncompressed.
    pub raw_bytes: u64,
    /// Modelled network time (latency + busiest-link bytes / bw).
    pub network_time_s: f64,
    /// Measured encode+decode wall time on the critical path.  Both
    /// halves run the batched kernels via the chunk sessions — encode
    /// through the [`crate::codecs::EncodeKernel`] staging-word path,
    /// decode through the [`crate::codecs::DecodeKernel`]
    /// word-at-a-time path — so this number reflects the kernels the
    /// paper's speed argument is about, not the scalar reference
    /// paths.
    pub codec_time_s: f64,
    /// Modelled wall time with chunk-granular pipelining: decode of
    /// chunk `k` overlaps transfer of chunk `k+1`, so codec time hides
    /// behind the wire.  Always ≤ [`Self::total_time_s`].
    pub pipelined_time_s: f64,
}

impl CollectiveReport {
    /// Non-pipelined total: wire time plus codec time back-to-back.
    pub fn total_time_s(&self) -> f64 {
        self.network_time_s + self.codec_time_s
    }

    /// Fraction of the serial total hidden by chunk pipelining,
    /// in `[0, 1)`.
    pub fn overlap_savings(&self) -> f64 {
        let total = self.total_time_s();
        if total <= 0.0 {
            return 0.0;
        }
        (1.0 - self.pipelined_time_s / total).max(0.0)
    }

    pub fn compression_ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.wire_bytes.max(1) as f64
    }
}

// ---------------------------------------------------------------------------
// Validation (malformed inputs are errors, not panics)

fn validate_workers(fabric_w: usize, provided: usize) -> Result<(), String> {
    if fabric_w == 0 {
        return Err("collective requires at least one worker".into());
    }
    if provided != fabric_w {
        return Err(format!(
            "expected one entry per worker ({fabric_w}), got {provided}"
        ));
    }
    Ok(())
}

/// Check the per-worker tensors are non-empty, equal-length and split
/// into `w` block-aligned chunks; returns the chunk length.
fn validate_tensors(
    worker_data: &[Vec<f32>],
    w: usize,
) -> Result<usize, String> {
    let n = worker_data[0].len();
    if worker_data.iter().any(|d| d.len() != n) {
        return Err("worker tensors must all have the same length".into());
    }
    if n == 0 || n % (w * BLOCK) != 0 {
        return Err(format!(
            "tensor length {n} must be a non-zero multiple of \
             workers × block = {}",
            w * BLOCK
        ));
    }
    Ok(n / w)
}

// ---------------------------------------------------------------------------
// Per-step time aggregation

/// Accumulates the busiest-link times of one ring step (all links run
/// in parallel, so the step costs the max over links).
#[derive(Default)]
struct StepAgg {
    max_bytes: usize,
    max_codec: f64,
    max_pipelined: f64,
}

impl StepAgg {
    /// Fold one link's hop into the step.  `extra_codec_s` is serial
    /// per-link codec work outside the chunk pipeline (quantize /
    /// dequantize), charged to both the serial and pipelined models.
    fn add_link(
        &mut self,
        fabric: &Fabric,
        trace: &HopTrace,
        wire_bytes: usize,
        extra_codec_s: f64,
    ) {
        self.max_bytes = self.max_bytes.max(wire_bytes);
        self.max_codec = self.max_codec.max(trace.codec_s() + extra_codec_s);
        self.max_pipelined = self
            .max_pipelined
            .max(trace.pipelined_s(fabric) + extra_codec_s);
    }

    /// Commit the step into the report; `hops` scales the wire terms
    /// for multi-hop deliveries (the all-to-all's distance-`s` sends).
    fn commit(self, fabric: &Fabric, hops: usize, report: &mut CollectiveReport) {
        let wire = fabric.wire_time(self.max_bytes) * hops as f64;
        report.steps += 1;
        report.network_time_s += wire;
        report.codec_time_s += self.max_codec;
        // The recurrence can exceed the serial sum only by float
        // rounding; clamp so the ≤ invariant is exact.
        let serial = wire + self.max_codec;
        let pipelined = (self.max_pipelined
            + fabric.wire_time(self.max_bytes) * (hops - 1) as f64)
            .min(serial);
        report.pipelined_time_s += pipelined;
    }
}

/// Ring all-reduce over per-worker f32 tensors with the default
/// transport chunk granularity.  Returns the reduced tensor per worker
/// (bit-identical across workers) plus the report.
pub fn ring_allreduce(
    fabric: &Fabric,
    worker_data: &[Vec<f32>],
    transport: &Transport,
) -> Result<(Vec<Vec<f32>>, CollectiveReport), String> {
    ring_allreduce_with(fabric, worker_data, transport, DEFAULT_TRANSPORT_CHUNK)
}

/// [`ring_allreduce`] with an explicit transport chunk size (symbols
/// per pipelined chunk).  Chunking changes timing, never results.
pub fn ring_allreduce_with(
    fabric: &Fabric,
    worker_data: &[Vec<f32>],
    transport: &Transport,
    chunk_symbols: usize,
) -> Result<(Vec<Vec<f32>>, CollectiveReport), String> {
    let w = fabric.workers;
    validate_workers(w, worker_data.len())?;
    let chunk = validate_tensors(worker_data, w)?;
    let quant = BlockQuantizer::new(Variant::ExmY);
    let handle = transport.resolve()?;
    let mut enc = handle.as_ref().map(|h| h.encoder());
    let mut dec = handle.as_ref().map(|h| h.decoder());
    let mut link = SimLink::new();

    let mut report = CollectiveReport {
        op: "allreduce".into(),
        transport: transport.name(),
        ..Default::default()
    };

    // Working f32 chunks per worker.
    let mut chunks: Vec<Vec<Vec<f32>>> = worker_data
        .iter()
        .map(|d| d.chunks(chunk).map(|c| c.to_vec()).collect())
        .collect();

    // --- Reduce-scatter: quantize per hop, dequantize + add. ---------
    for s in 0..w - 1 {
        let mut agg = StepAgg::default();
        let mut deliveries: Vec<(usize, usize, Vec<f32>)> = Vec::new();
        for i in 0..w {
            let ci = (i + w - s) % w;
            let t0 = Instant::now();
            let q = quant.quantize(&chunks[i][ci]);
            let quant_s = t0.elapsed().as_secs_f64();
            let ex = exchange_hop(
                &mut link,
                &mut enc,
                &mut dec,
                &q.symbols,
                &q.scales,
                chunk_symbols,
            )?;
            report.wire_bytes += ex.wire_bytes;
            report.raw_bytes += ex.raw_bytes;
            let wire = ex.wire_bytes as usize;
            let trace = ex.trace;
            let t1 = Instant::now();
            let received = quant.dequantize(&QuantizedBlocks {
                symbols: ex.symbols,
                scales: ex.scales,
                variant: Variant::ExmY,
            });
            let dequant_s = t1.elapsed().as_secs_f64();
            agg.add_link(fabric, &trace, wire, quant_s + dequant_s);
            deliveries.push(((i + 1) % w, ci, received));
        }
        for (dst, ci, data) in deliveries {
            for (acc, v) in chunks[dst][ci].iter_mut().zip(&data) {
                *acc += v;
            }
        }
        agg.commit(fabric, 1, &mut report);
    }

    // --- Final quantization of each worker's owned chunk. ------------
    // Worker i owns chunk (i + 1) mod w after reduce-scatter.
    let mut owned: Vec<(usize, QuantizedBlocks)> = (0..w)
        .map(|i| {
            let ci = (i + 1) % w;
            (ci, quant.quantize(&chunks[i][ci]))
        })
        .collect();

    // --- All-gather: circulate (symbols, scales) losslessly. ---------
    // have[i][ci] = Some(quantized chunk) once worker i holds it.
    let mut have: Vec<Vec<Option<QuantizedBlocks>>> =
        vec![vec![None; w]; w];
    for (i, (ci, q)) in owned.drain(..).enumerate() {
        have[i][ci] = Some(q);
    }
    for s in 0..w - 1 {
        let mut agg = StepAgg::default();
        let mut deliveries: Vec<(usize, usize, QuantizedBlocks)> = Vec::new();
        for i in 0..w {
            let ci = (i + 1 + w - s) % w;
            let q = have[i][ci].as_ref().ok_or("ring invariant broken")?;
            let ex = exchange_hop(
                &mut link,
                &mut enc,
                &mut dec,
                &q.symbols,
                &q.scales,
                chunk_symbols,
            )?;
            report.wire_bytes += ex.wire_bytes;
            report.raw_bytes += ex.raw_bytes;
            agg.add_link(fabric, &ex.trace, ex.wire_bytes as usize, 0.0);
            deliveries.push((
                (i + 1) % w,
                ci,
                QuantizedBlocks {
                    symbols: ex.symbols,
                    scales: ex.scales,
                    variant: Variant::ExmY,
                },
            ));
        }
        for (dst, ci, q) in deliveries {
            have[dst][ci] = Some(q);
        }
        agg.commit(fabric, 1, &mut report);
    }

    // Materialize: every worker dequantizes the same symbol streams.
    let results: Vec<Vec<f32>> = (0..w)
        .map(|i| {
            (0..w)
                .flat_map(|ci| {
                    // lint: infallible(every chunk present after w-1 steps)
                    quant.dequantize(have[i][ci].as_ref().expect("complete"))
                })
                .collect()
        })
        .collect();
    Ok((results, report))
}

/// Ring all-gather of per-worker e4m3 symbol streams (already
/// quantized — e.g. sharded weights).  Returns the gathered stream
/// (identical across workers, asserted) and the report.
pub fn ring_allgather(
    fabric: &Fabric,
    worker_symbols: &[Vec<u8>],
    worker_scales: &[Vec<f32>],
    transport: &Transport,
) -> Result<(Vec<u8>, CollectiveReport), String> {
    let w = fabric.workers;
    validate_workers(w, worker_symbols.len())?;
    if worker_scales.len() != w {
        return Err(format!(
            "expected one scale vector per worker ({w}), got {}",
            worker_scales.len()
        ));
    }
    let handle = transport.resolve()?;
    let mut enc = handle.as_ref().map(|h| h.encoder());
    let mut dec = handle.as_ref().map(|h| h.decoder());
    let mut link = SimLink::new();
    let mut report = CollectiveReport {
        op: "allgather".into(),
        transport: transport.name(),
        ..Default::default()
    };

    let mut have: Vec<Vec<Option<Vec<u8>>>> = (0..w)
        .map(|i| {
            (0..w)
                .map(|j| (i == j).then(|| worker_symbols[j].clone()))
                .collect()
        })
        .collect();

    for s in 0..w - 1 {
        let mut agg = StepAgg::default();
        let mut deliveries: Vec<(usize, usize, Vec<u8>)> = Vec::new();
        for i in 0..w {
            let shard = (i + w - s) % w;
            let symbols = have[i][shard]
                .as_ref()
                .ok_or("ring invariant broken")?
                .clone();
            let ex = exchange_hop(
                &mut link,
                &mut enc,
                &mut dec,
                &symbols,
                &worker_scales[shard],
                DEFAULT_TRANSPORT_CHUNK,
            )?;
            report.wire_bytes += ex.wire_bytes;
            report.raw_bytes += ex.raw_bytes;
            agg.add_link(fabric, &ex.trace, ex.wire_bytes as usize, 0.0);
            deliveries.push(((i + 1) % w, shard, ex.symbols));
        }
        for (dst, shard, data) in deliveries {
            have[dst][shard] = Some(data);
        }
        agg.commit(fabric, 1, &mut report);
    }

    let gathered: Vec<u8> = (0..w)
        // lint: infallible(after w-1 ring steps every slot is filled)
        .flat_map(|j| have[0][j].clone().expect("complete"))
        .collect();
    for i in 1..w {
        let other: Vec<u8> = (0..w)
            // lint: infallible(after w-1 ring steps every slot is filled)
            .flat_map(|j| have[i][j].clone().expect("complete"))
            .collect();
        assert_eq!(other, gathered, "allgather divergence at worker {i}");
    }
    Ok((gathered, report))
}

/// Ring all-gather of pre-compressed QLS1 shard bodies placed by a
/// [`ShardManifest`]: worker `i` holds shard `i`'s body; the bodies
/// circulate opaquely (they are already compressed — no transport
/// codec is stacked on top) and every worker reassembles the full
/// tensor via [`frame::decompress_sharded`].  This is the
/// shard-granular placement path: what the coordinator shards once is
/// what the collective moves, one table header for the whole set.
///
/// The report's `wire_bytes` are the shard-body bytes actually
/// shipped; `raw_bytes` are the symbols an uncompressed gather would
/// ship, so `compression_ratio` reflects the shard codec.  Returns
/// the reassembled symbols (identical across workers, asserted) and
/// the report.
pub fn ring_allgather_shards(
    fabric: &Fabric,
    manifest: &ShardManifest,
    bodies: &[Vec<u8>],
) -> Result<(Vec<u8>, CollectiveReport), String> {
    let w = fabric.workers;
    validate_workers(w, bodies.len())?;
    if manifest.n_shards() != w {
        return Err(format!(
            "manifest describes {} shards for {w} workers (one shard \
             per worker required)",
            manifest.n_shards()
        ));
    }
    let mut enc = None;
    let mut dec = None;
    let mut link = SimLink::new();
    let mut report = CollectiveReport {
        op: "allgather_shards".into(),
        transport: "qls1".into(),
        ..Default::default()
    };
    let shard_syms = manifest.shard_symbols();

    let mut have: Vec<Vec<Option<Vec<u8>>>> = (0..w)
        .map(|i| {
            (0..w)
                .map(|j| (i == j).then(|| bodies[j].clone()))
                .collect()
        })
        .collect();
    for s in 0..w - 1 {
        let mut agg = StepAgg::default();
        let mut deliveries: Vec<(usize, usize, Vec<u8>)> = Vec::new();
        for i in 0..w {
            let shard = (i + w - s) % w;
            // Borrow the body for the hop only — no per-hop clone.
            let ex = {
                let body = have[i][shard]
                    .as_ref()
                    .ok_or("ring invariant broken")?;
                exchange_hop(
                    &mut link,
                    &mut enc,
                    &mut dec,
                    body,
                    &[],
                    DEFAULT_TRANSPORT_CHUNK,
                )?
            };
            report.wire_bytes += ex.wire_bytes;
            report.raw_bytes += shard_syms[shard];
            agg.add_link(fabric, &ex.trace, ex.wire_bytes as usize, 0.0);
            deliveries.push(((i + 1) % w, shard, ex.symbols));
        }
        for (dst, shard, data) in deliveries {
            have[dst][shard] = Some(data);
        }
        agg.commit(fabric, 1, &mut report);
    }

    // Every worker reassembles from its gathered bodies; all must
    // agree with worker 0 bit-for-bit.
    let mut first: Option<Vec<u8>> = None;
    for (i, worker_bodies) in have.into_iter().enumerate() {
        let mut gathered = Vec::with_capacity(w);
        for b in worker_bodies {
            gathered.push(b.ok_or("ring gather incomplete")?);
        }
        let tensor = frame::decompress_sharded(
            manifest,
            &gathered,
            &FrameOptions::default(),
        )
        .map_err(|e| e.to_string())?;
        match &first {
            None => first = Some(tensor),
            Some(f) => {
                if &tensor != f {
                    return Err(format!(
                        "allgather_shards divergence at worker {i}"
                    ));
                }
            }
        }
    }
    Ok((first.ok_or("no workers")?, report))
}

/// All-to-all of symbol shards: worker i sends shard j to worker j.
pub fn alltoall(
    fabric: &Fabric,
    shards: &[Vec<Vec<u8>>],
    transport: &Transport,
) -> Result<(Vec<Vec<Vec<u8>>>, CollectiveReport), String> {
    let w = fabric.workers;
    validate_workers(w, shards.len())?;
    if shards.iter().any(|s| s.len() != w) {
        return Err(format!("each worker must hold {w} shards"));
    }
    let handle = transport.resolve()?;
    let mut enc = handle.as_ref().map(|h| h.encoder());
    let mut dec = handle.as_ref().map(|h| h.decoder());
    let mut link = SimLink::new();
    let mut report = CollectiveReport {
        op: "alltoall".into(),
        transport: transport.name(),
        ..Default::default()
    };
    let mut out: Vec<Vec<Vec<u8>>> = vec![vec![Vec::new(); w]; w];
    for i in 0..w {
        out[i][i] = shards[i][i].clone();
    }
    for s in 1..w {
        let mut agg = StepAgg::default();
        for i in 0..w {
            let dst = (i + s) % w;
            let data = &shards[i][dst];
            let ex = exchange_hop(
                &mut link,
                &mut enc,
                &mut dec,
                data,
                &[],
                DEFAULT_TRANSPORT_CHUNK,
            )?;
            report.wire_bytes += ex.wire_bytes;
            report.raw_bytes += ex.raw_bytes;
            agg.add_link(fabric, &ex.trace, ex.wire_bytes as usize, 0.0);
            out[dst][i] = ex.symbols;
        }
        // s ring hops to reach distance s.
        agg.commit(fabric, s, &mut report);
    }
    Ok((out, report))
}

/// Ring reduce-scatter: each worker ends with the fully-reduced shard
/// it owns (`(i + 1) mod w`), quantized.  The first phase of
/// [`ring_allreduce`], exposed standalone (ZeRO-style sharded
/// optimizers consume exactly this).
pub fn ring_reduce_scatter(
    fabric: &Fabric,
    worker_data: &[Vec<f32>],
    transport: &Transport,
) -> Result<(Vec<(usize, QuantizedBlocks)>, CollectiveReport), String> {
    let w = fabric.workers;
    validate_workers(w, worker_data.len())?;
    let chunk = validate_tensors(worker_data, w)?;
    let quant = BlockQuantizer::new(Variant::ExmY);
    let handle = transport.resolve()?;
    let mut enc = handle.as_ref().map(|h| h.encoder());
    let mut dec = handle.as_ref().map(|h| h.decoder());
    let mut link = SimLink::new();
    let mut report = CollectiveReport {
        op: "reduce_scatter".into(),
        transport: transport.name(),
        ..Default::default()
    };
    let mut chunks: Vec<Vec<Vec<f32>>> = worker_data
        .iter()
        .map(|d| d.chunks(chunk).map(|c| c.to_vec()).collect())
        .collect();
    for s in 0..w - 1 {
        let mut agg = StepAgg::default();
        let mut deliveries: Vec<(usize, usize, Vec<f32>)> = Vec::new();
        for i in 0..w {
            let ci = (i + w - s) % w;
            let t0 = Instant::now();
            let q = quant.quantize(&chunks[i][ci]);
            let quant_s = t0.elapsed().as_secs_f64();
            let ex = exchange_hop(
                &mut link,
                &mut enc,
                &mut dec,
                &q.symbols,
                &q.scales,
                DEFAULT_TRANSPORT_CHUNK,
            )?;
            report.wire_bytes += ex.wire_bytes;
            report.raw_bytes += ex.raw_bytes;
            let wire = ex.wire_bytes as usize;
            let trace = ex.trace;
            let t1 = Instant::now();
            let received = quant.dequantize(&QuantizedBlocks {
                symbols: ex.symbols,
                scales: ex.scales,
                variant: Variant::ExmY,
            });
            let dequant_s = t1.elapsed().as_secs_f64();
            agg.add_link(fabric, &trace, wire, quant_s + dequant_s);
            deliveries.push(((i + 1) % w, ci, received));
        }
        for (dst, ci, data) in deliveries {
            for (acc, v) in chunks[dst][ci].iter_mut().zip(&data) {
                *acc += v;
            }
        }
        agg.commit(fabric, 1, &mut report);
    }
    let owned = (0..w)
        .map(|i| {
            let ci = (i + 1) % w;
            (ci, quant.quantize(&chunks[i][ci]))
        })
        .collect();
    Ok((owned, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{TensorGen, TensorKind};
    use crate::util::rng::Rng;

    fn random_data(w: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..w)
            .map(|_| {
                let mut v = vec![0f32; n];
                rng.fill_normal_f32(&mut v, 0.0, 1.0);
                v
            })
            .collect()
    }

    fn exact_sum(data: &[Vec<f32>]) -> Vec<f32> {
        let n = data[0].len();
        let mut out = vec![0f32; n];
        for d in data {
            for (o, v) in out.iter_mut().zip(d) {
                *o += v;
            }
        }
        out
    }

    fn calib(seed: u64) -> Box<Histogram> {
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(seed);
        Box::new(Histogram::from_symbols(&gen.symbols(&mut rng, 256 * BLOCK)))
    }

    #[test]
    fn allreduce_workers_bit_identical() {
        let fabric = Fabric::pod(4);
        let data = random_data(4, 4 * BLOCK * 4, 1);
        for transport in [
            Transport::Raw,
            Transport::Compressed { codec: "huffman".into(), calibration: calib(1) },
        ] {
            let (results, report) =
                ring_allreduce(&fabric, &data, &transport).unwrap();
            for (wkr, r) in results.iter().enumerate() {
                assert_eq!(
                    r, &results[0],
                    "worker {wkr} diverged via {}",
                    transport.name()
                );
            }
            assert_eq!(report.steps, 2 * (4 - 1));
        }
    }

    #[test]
    fn allreduce_approximates_exact_sum() {
        let fabric = Fabric::pod(4);
        let data = random_data(4, 4 * BLOCK * 8, 3);
        let want = exact_sum(&data);
        let (results, _) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let scale: f32 = want.iter().fold(0f32, |a, &x| a.max(x.abs()));
        for (a, b) in results[0].iter().zip(&want) {
            // Each of the ≤ w quantizations adds ≤ 2^-4 relative noise.
            assert!((a - b).abs() <= scale * 0.25 + 0.2, "{a} vs {b}");
        }
    }

    #[test]
    fn allreduce_lossless_transport_invariant() {
        // Raw vs Huffman transport must give *identical* results — the
        // codec is lossless, so only bytes differ, never values.
        let fabric = Fabric::pod(4);
        let data = random_data(4, 4 * BLOCK * 8, 4);
        let (raw, _) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let (comp, _) = ring_allreduce(
            &fabric,
            &data,
            &Transport::Compressed {
                codec: "qlc".into(),
                calibration: calib(4),
            },
        )
        .unwrap();
        assert_eq!(raw, comp);
    }

    #[test]
    fn chunk_granularity_never_changes_results() {
        // Whole-payload (usize::MAX), default and tiny transport
        // chunks must produce bit-identical reductions and identical
        // raw byte accounting.
        let fabric = Fabric::pod(4);
        let data = random_data(4, 4 * BLOCK * 8, 6);
        let transport = Transport::Compressed {
            codec: "huffman".into(),
            calibration: calib(6),
        };
        let (whole, whole_rep) =
            ring_allreduce_with(&fabric, &data, &transport, usize::MAX)
                .unwrap();
        for chunk_symbols in [BLOCK, 100, DEFAULT_TRANSPORT_CHUNK] {
            let (chunked, rep) = ring_allreduce_with(
                &fabric, &data, &transport, chunk_symbols,
            )
            .unwrap();
            assert_eq!(chunked, whole, "chunk_symbols={chunk_symbols}");
            assert_eq!(rep.raw_bytes, whole_rep.raw_bytes);
        }
    }

    #[test]
    fn pipelined_time_within_serial_budget() {
        let fabric = Fabric::ethernet(4);
        let data = random_data(4, 4 * BLOCK * 64, 7);
        for transport in [
            Transport::Raw,
            Transport::Compressed {
                codec: "qlc".into(),
                calibration: calib(7),
            },
        ] {
            let (_, rep) = ring_allreduce_with(
                &fabric, &data, &transport, 4 * BLOCK,
            )
            .unwrap();
            assert!(rep.pipelined_time_s > 0.0);
            assert!(
                rep.pipelined_time_s <= rep.total_time_s(),
                "{} > {} via {}",
                rep.pipelined_time_s,
                rep.total_time_s(),
                transport.name()
            );
            let savings = rep.overlap_savings();
            assert!((0.0..1.0).contains(&savings), "{savings}");
        }
    }

    #[test]
    fn malformed_inputs_are_errors_not_panics() {
        let fabric = Fabric::pod(4);
        // Wrong worker count.
        let three = random_data(3, 4 * BLOCK * 4, 8);
        assert!(ring_allreduce(&fabric, &three, &Transport::Raw).is_err());
        assert!(
            ring_reduce_scatter(&fabric, &three, &Transport::Raw).is_err()
        );
        // Non-divisible tensor size.
        let ragged = random_data(4, 4 * BLOCK * 4 + 1, 9);
        assert!(ring_allreduce(&fabric, &ragged, &Transport::Raw).is_err());
        // Empty tensors.
        let empty = vec![Vec::new(); 4];
        assert!(ring_allreduce(&fabric, &empty, &Transport::Raw).is_err());
        // Mismatched lengths between workers.
        let mut uneven = random_data(4, 4 * BLOCK * 4, 10);
        uneven[2].truncate(4 * BLOCK * 2);
        assert!(ring_allreduce(&fabric, &uneven, &Transport::Raw).is_err());
        // Zero workers.
        let none = Fabric { workers: 0, ..Fabric::pod(1) };
        assert!(ring_allreduce(&none, &[], &Transport::Raw).is_err());
        // Allgather / alltoall shape errors.
        let syms = vec![vec![1u8; 64]; 3];
        let scales = vec![vec![1.0f32; 2]; 3];
        assert!(
            ring_allgather(&fabric, &syms, &scales, &Transport::Raw).is_err()
        );
        let shards = vec![vec![vec![0u8; 8]; 3]; 4];
        assert!(alltoall(&fabric, &shards, &Transport::Raw).is_err());
    }

    #[test]
    fn allreduce_compression_reduces_wire_bytes() {
        let fabric = Fabric::pod(4);
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(2);
        let data: Vec<Vec<f32>> =
            (0..4).map(|_| gen.generate(&mut rng, 4 * BLOCK * 32)).collect();
        let (_, raw) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let (_, comp) = ring_allreduce(
            &fabric,
            &data,
            &Transport::Compressed {
                codec: "qlc".into(),
                calibration: calib(2),
            },
        )
        .unwrap();
        assert!(
            comp.wire_bytes < raw.wire_bytes,
            "{} !< {}",
            comp.wire_bytes,
            raw.wire_bytes
        );
        assert!(comp.compression_ratio() > 1.0);
        assert_eq!(comp.raw_bytes, raw.raw_bytes);
    }

    #[test]
    fn allgather_collects_identical_streams() {
        let fabric = Fabric::pod(4);
        let gen = TensorGen::new(TensorKind::Weight, Variant::ExmY);
        let mut rng = Rng::new(4);
        let shards: Vec<Vec<u8>> =
            (0..4).map(|_| gen.symbols(&mut rng, 8 * BLOCK)).collect();
        let scales: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; 8]).collect();
        let cal = Histogram::from_symbols(&shards.concat());
        let (gathered, report) = ring_allgather(
            &fabric,
            &shards,
            &scales,
            &Transport::Compressed {
                codec: "huffman".into(),
                calibration: Box::new(cal),
            },
        )
        .unwrap();
        assert_eq!(gathered, shards.concat());
        assert_eq!(report.steps, 3);
        assert!(report.wire_bytes > 0);
    }

    #[test]
    fn allgather_shards_moves_manifest_placed_bodies() {
        // Shard a stream with the coordinator-side sharder, hand one
        // QLS1 body per worker, gather — every worker reassembles the
        // source tensor, and compressed bodies beat raw symbols on
        // the wire.
        let w = 4;
        let fabric = Fabric::pod(w);
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(21);
        let symbols = gen.symbols(&mut rng, 256 * BLOCK);
        let hist = Histogram::from_symbols(&symbols);
        let handle =
            CodecRegistry::global().resolve("qlc", &hist).unwrap();
        let (manifest, bodies) = crate::codecs::frame::compress_sharded(
            &handle,
            &symbols,
            w,
            &crate::codecs::frame::FrameOptions::serial(),
        )
        .unwrap();
        let (gathered, report) =
            ring_allgather_shards(&fabric, &manifest, &bodies).unwrap();
        assert_eq!(gathered, symbols);
        assert_eq!(report.steps, w - 1);
        assert!(report.wire_bytes > 0);
        assert!(
            report.wire_bytes < report.raw_bytes,
            "qlc shard bodies must beat raw symbols: {} !< {}",
            report.wire_bytes,
            report.raw_bytes
        );
        assert!(
            report.pipelined_time_s
                <= report.total_time_s() * (1.0 + 1e-9)
        );
        // Shape mismatches are errors, not panics.
        assert!(ring_allgather_shards(
            &Fabric::pod(3),
            &manifest,
            &bodies[..3]
        )
        .is_err());
        assert!(
            ring_allgather_shards(&fabric, &manifest, &bodies[..3])
                .is_err()
        );
    }

    #[test]
    fn alltoall_permutes_shards() {
        let fabric = Fabric::pod(3);
        let shards: Vec<Vec<Vec<u8>>> = (0..3)
            .map(|i| (0..3).map(|j| vec![(i * 3 + j) as u8; 64]).collect())
            .collect();
        let (out, report) =
            alltoall(&fabric, &shards, &Transport::Raw).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(out[j][i], shards[i][j], "shard {i}->{j}");
            }
        }
        assert_eq!(report.steps, 2);
        assert!(report.pipelined_time_s <= report.total_time_s());
    }

    #[test]
    fn network_time_decreases_with_bandwidth() {
        let data = random_data(4, 4 * BLOCK * 16, 5);
        let slow =
            Fabric { workers: 4, link_bandwidth: 1e9, link_latency: 1e-6 };
        let fast =
            Fabric { workers: 4, link_bandwidth: 100e9, link_latency: 1e-6 };
        let (_, r_slow) =
            ring_allreduce(&slow, &data, &Transport::Raw).unwrap();
        let (_, r_fast) =
            ring_allreduce(&fast, &data, &Transport::Raw).unwrap();
        assert!(r_slow.network_time_s > r_fast.network_time_s);
        assert_eq!(r_slow.wire_bytes, r_fast.wire_bytes);
    }
}

#[cfg(test)]
mod reduce_scatter_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn shards_partition_and_match_allreduce() {
        let w = 4;
        let mut rng = Rng::new(8);
        let data: Vec<Vec<f32>> = (0..w)
            .map(|_| {
                let mut v = vec![0f32; w * BLOCK * 4];
                rng.fill_normal_f32(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let fabric = Fabric::pod(w);
        let (shards, report) =
            ring_reduce_scatter(&fabric, &data, &Transport::Raw).unwrap();
        let (full, _) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let quant = BlockQuantizer::new(Variant::ExmY);
        let chunk = data[0].len() / w;
        // Every owned shard dequantizes to the matching slice of the
        // all-reduce result (all-reduce gathers exactly these shards).
        let mut covered = vec![false; w];
        for (ci, q) in &shards {
            let deq = quant.dequantize(q);
            assert_eq!(&full[0][ci * chunk..(ci + 1) * chunk], &deq[..]);
            covered[*ci] = true;
        }
        assert!(covered.iter().all(|&c| c), "shards must partition");
        assert_eq!(report.steps, w - 1);
    }

    #[test]
    fn half_the_allreduce_traffic() {
        let w = 4;
        let mut rng = Rng::new(9);
        let data: Vec<Vec<f32>> = (0..w)
            .map(|_| {
                let mut v = vec![0f32; w * BLOCK * 8];
                rng.fill_normal_f32(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let fabric = Fabric::pod(w);
        let (_, rs) =
            ring_reduce_scatter(&fabric, &data, &Transport::Raw).unwrap();
        let (_, ar) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        assert_eq!(rs.wire_bytes * 2, ar.wire_bytes);
    }
}
