//! Threaded collective engine: the chunk-pipelined ring all-reduce of
//! [`super::ring_allreduce`] executed by real worker threads exchanging
//! compressed chunks over the transport layer's bounded channels
//! ([`crate::transport::threaded`]).  Validates that the simulated
//! ring and a concurrent implementation agree bit-for-bit, and
//! measures real end-to-end wall time — here the overlap of decode(k)
//! with transfer(k+1) is physical, not modelled: while one worker
//! decodes a chunk, its upstream neighbour is already encoding and
//! sending the next.

use std::sync::Arc;
use std::thread;
use std::time::Instant;

use super::Transport;
use crate::codecs::CodecHandle;
use crate::formats::{BlockQuantizer, QuantizedBlocks, Variant};
use crate::transport::{exchange_hop, threaded, DEFAULT_TRANSPORT_CHUNK};

/// Wall-clock result of a threaded all-reduce.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub wall_time_s: f64,
    pub wire_bytes: u64,
    pub raw_bytes: u64,
    /// Transport chunk granularity the run used (symbols).
    pub chunk_symbols: usize,
}

/// Threaded ring all-reduce with default chunking. Semantically
/// identical to [`super::ring_allreduce`]: lossy quantize-per-hop
/// reduce-scatter, then lossless circulation of (symbols, scales).
pub fn threaded_allreduce(
    workers: usize,
    worker_data: Vec<Vec<f32>>,
    transport: &Transport,
) -> Result<(Vec<Vec<f32>>, EngineReport), String> {
    threaded_allreduce_with(
        workers,
        worker_data,
        transport,
        DEFAULT_TRANSPORT_CHUNK,
        2,
    )
}

/// [`threaded_allreduce`] with explicit transport chunk size and
/// per-link channel depth (chunks buffered in flight).  Chunking and
/// depth change scheduling, never results.
pub fn threaded_allreduce_with(
    workers: usize,
    worker_data: Vec<Vec<f32>>,
    transport: &Transport,
    chunk_symbols: usize,
    channel_depth: usize,
) -> Result<(Vec<Vec<f32>>, EngineReport), String> {
    // Same input contract as the simulated ring (one set of rules for
    // both backends — their bit-for-bit agreement depends on it).
    super::validate_workers(workers, worker_data.len())?;
    let chunk = super::validate_tensors(&worker_data, workers)?;

    // Resolve the codec once (fitting qlc tables is expensive); the
    // read-only handle is shared by every worker, each of which keeps
    // its own mutable sessions.
    let shared_codec: Arc<Option<CodecHandle>> =
        Arc::new(transport.resolve()?);

    // Ring links: endpoint i sends to i+1, receives from i-1.
    let endpoints = threaded::ring(workers, channel_depth);

    let start = Instant::now();
    let mut handles = Vec::new();
    for ((i, data), mut link) in
        worker_data.into_iter().enumerate().zip(endpoints)
    {
        let codec = shared_codec.clone();
        handles.push(thread::spawn(
            move || -> Result<(usize, Vec<f32>, u64, u64), String> {
                // One session pair per worker, reused for every hop.
                let mut enc = (*codec).as_ref().map(|h| h.encoder());
                let mut dec = (*codec).as_ref().map(|h| h.decoder());
                let quant = BlockQuantizer::new(Variant::ExmY);
                let mut chunks: Vec<Vec<f32>> =
                    data.chunks(chunk).map(|c| c.to_vec()).collect();
                let w = chunks.len();
                let mut wire = 0u64;
                let mut raw = 0u64;

                // --- Reduce-scatter (quantize per hop). --------------
                for s in 0..w - 1 {
                    let send_ci = (i + w - s) % w;
                    let q = quant.quantize(&chunks[send_ci]);
                    let ex = exchange_hop(
                        &mut link,
                        &mut enc,
                        &mut dec,
                        &q.symbols,
                        &q.scales,
                        chunk_symbols,
                    )?;
                    wire += ex.wire_bytes;
                    raw += ex.raw_bytes;
                    let incoming = quant.dequantize(&QuantizedBlocks {
                        symbols: ex.symbols,
                        scales: ex.scales,
                        variant: Variant::ExmY,
                    });
                    let recv_ci = (i + w - s - 1) % w;
                    for (acc, v) in chunks[recv_ci].iter_mut().zip(&incoming)
                    {
                        *acc += v;
                    }
                }

                // --- Final quantization of the owned chunk. ----------
                let owned_ci = (i + 1) % w;
                let mut quantized: Vec<Option<QuantizedBlocks>> =
                    (0..w).map(|_| None).collect();
                quantized[owned_ci] =
                    Some(quant.quantize(&chunks[owned_ci]));

                // --- All-gather (lossless circulation). --------------
                for s in 0..w - 1 {
                    let send_ci = (i + 1 + w - s) % w;
                    let q = quantized[send_ci]
                        .as_ref()
                        .ok_or("ring invariant broken")?;
                    let ex = exchange_hop(
                        &mut link,
                        &mut enc,
                        &mut dec,
                        &q.symbols,
                        &q.scales,
                        chunk_symbols,
                    )?;
                    wire += ex.wire_bytes;
                    raw += ex.raw_bytes;
                    let recv_ci = (i + w - s) % w;
                    quantized[recv_ci] = Some(QuantizedBlocks {
                        symbols: ex.symbols,
                        scales: ex.scales,
                        variant: Variant::ExmY,
                    });
                }

                let result: Vec<f32> = (0..w)
                    .flat_map(|ci| {
                        quant.dequantize(
                            quantized[ci].as_ref().expect("complete"),
                        )
                    })
                    .collect();
                Ok((i, result, wire, raw))
            },
        ));
    }

    let mut results: Vec<Vec<f32>> = vec![Vec::new(); workers];
    let mut wire_bytes = 0u64;
    let mut raw_bytes = 0u64;
    for h in handles {
        let (i, data, wire, raw) =
            h.join().map_err(|_| "worker panicked")??;
        results[i] = data;
        wire_bytes += wire;
        raw_bytes += raw;
    }
    let report = EngineReport {
        wall_time_s: start.elapsed().as_secs_f64(),
        wire_bytes,
        raw_bytes,
        chunk_symbols,
    };
    Ok((results, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{ring_allreduce, Fabric};
    use crate::data::{TensorGen, TensorKind};
    use crate::formats::BLOCK;
    use crate::stats::Histogram;
    use crate::util::rng::Rng;

    fn make_data(w: usize, per: usize, seed: u64) -> Vec<Vec<f32>> {
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(seed);
        (0..w).map(|_| gen.generate(&mut rng, per)).collect()
    }

    #[test]
    fn threaded_matches_simulated_raw() {
        let w = 4;
        let data = make_data(w, w * BLOCK * 8, 1);
        let fabric = Fabric::pod(w);
        let (sim, _) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let (thr, report) =
            threaded_allreduce(w, data, &Transport::Raw).unwrap();
        assert_eq!(sim, thr, "threaded ring must equal simulated ring");
        assert!(report.wall_time_s > 0.0);
        assert_eq!(report.wire_bytes, report.raw_bytes);
    }

    #[test]
    fn threaded_matches_simulated_compressed() {
        let w = 4;
        let data = make_data(w, w * BLOCK * 32, 2);
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(3);
        let cal = Histogram::from_symbols(&gen.symbols(&mut rng, 256 * BLOCK));
        let transport = Transport::Compressed {
            codec: "qlc".into(),
            calibration: Box::new(cal),
        };
        let fabric = Fabric::pod(w);
        let (sim, _) = ring_allreduce(&fabric, &data, &transport).unwrap();
        let (thr, report) = threaded_allreduce(w, data, &transport).unwrap();
        assert_eq!(sim, thr);
        assert!(
            report.wire_bytes < report.raw_bytes,
            "{} !< {}",
            report.wire_bytes,
            report.raw_bytes
        );
    }

    #[test]
    fn chunked_pipeline_agrees_with_whole_payload() {
        // Many small chunks through shallow channels vs one chunk per
        // hop: identical results, identical raw byte accounting.
        let w = 4;
        let data = make_data(w, w * BLOCK * 16, 5);
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(6);
        let cal = Histogram::from_symbols(&gen.symbols(&mut rng, 128 * BLOCK));
        let transport = Transport::Compressed {
            codec: "huffman".into(),
            calibration: Box::new(cal),
        };
        let (whole, whole_rep) = threaded_allreduce_with(
            w,
            data.clone(),
            &transport,
            usize::MAX,
            2,
        )
        .unwrap();
        for (chunk_symbols, depth) in [(BLOCK, 1), (3 * BLOCK, 2), (256, 4)] {
            let (chunked, rep) = threaded_allreduce_with(
                w,
                data.clone(),
                &transport,
                chunk_symbols,
                depth,
            )
            .unwrap();
            assert_eq!(
                chunked, whole,
                "chunk_symbols={chunk_symbols} depth={depth}"
            );
            assert_eq!(rep.raw_bytes, whole_rep.raw_bytes);
        }
    }

    #[test]
    fn scales_with_worker_count() {
        for w in [2usize, 3, 8] {
            let data = make_data(w, w * BLOCK * 2, w as u64);
            let (results, _) =
                threaded_allreduce(w, data, &Transport::Raw).unwrap();
            assert_eq!(results.len(), w);
            for r in &results[1..] {
                assert_eq!(r, &results[0], "w={w}: workers must agree");
            }
        }
    }

    #[test]
    fn malformed_inputs_are_errors_not_panics() {
        // Wrong worker count.
        let data = make_data(3, 3 * BLOCK * 2, 11);
        assert!(threaded_allreduce(4, data, &Transport::Raw).is_err());
        // Non-divisible tensor size.
        let ragged = vec![vec![0f32; 4 * BLOCK * 2 + 3]; 4];
        assert!(threaded_allreduce(4, ragged, &Transport::Raw).is_err());
        // Empty tensors.
        let empty = vec![Vec::new(); 4];
        assert!(threaded_allreduce(4, empty, &Transport::Raw).is_err());
        // Mismatched lengths.
        let mut uneven = make_data(4, 4 * BLOCK * 2, 12);
        uneven[1].truncate(4 * BLOCK);
        assert!(threaded_allreduce(4, uneven, &Transport::Raw).is_err());
        // Zero workers.
        assert!(
            threaded_allreduce(0, Vec::new(), &Transport::Raw).is_err()
        );
    }
}
