//! Threaded collective engine: the ring all-reduce of
//! [`super::ring_allreduce`] executed by real worker threads exchanging
//! compressed payloads over channels.  Validates that the simulated
//! ring and a concurrent implementation agree bit-for-bit, and measures
//! real end-to-end wall time (the codec is on the critical path here,
//! as it would be on a NIC offload engine).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use super::{decode_payload, encode_payload, Transport};
use crate::codecs::CodecHandle;
use crate::formats::{BlockQuantizer, QuantizedBlocks, Variant, BLOCK};

/// One hop's message: compressed symbols + block scales.
struct Msg {
    payload: Vec<u8>,
    scales: Vec<f32>,
    n_symbols: usize,
}

/// Wall-clock result of a threaded all-reduce.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub wall_time_s: f64,
    pub wire_bytes: u64,
    pub raw_bytes: u64,
}

/// Threaded ring all-reduce. Semantically identical to
/// [`super::ring_allreduce`]: lossy quantize-per-hop reduce-scatter,
/// then lossless circulation of the final (symbols, scales).
pub fn threaded_allreduce(
    workers: usize,
    worker_data: Vec<Vec<f32>>,
    transport: &Transport,
) -> Result<(Vec<Vec<f32>>, EngineReport), String> {
    assert_eq!(worker_data.len(), workers);
    let n = worker_data[0].len();
    assert!(n % (workers * BLOCK) == 0);
    let chunk = n / workers;

    // Resolve the codec once (fitting qlc tables is expensive); the
    // read-only handle is shared by every worker, each of which keeps
    // its own mutable sessions.
    let shared_codec: Arc<Option<CodecHandle>> =
        Arc::new(transport.resolve()?);

    // Ring links: worker i sends to i+1.
    let mut senders: Vec<Option<SyncSender<Msg>>> = Vec::new();
    let mut receivers: Vec<Option<Receiver<Msg>>> =
        (0..workers).map(|_| None).collect();
    for i in 0..workers {
        let (tx, rx) = sync_channel::<Msg>(2);
        senders.push(Some(tx));
        receivers[(i + 1) % workers] = Some(rx);
    }

    let start = Instant::now();
    let mut handles = Vec::new();
    for (i, data) in worker_data.into_iter().enumerate() {
        let tx = senders[i].take().unwrap();
        let rx = receivers[i].take().unwrap();
        let codec = shared_codec.clone();
        handles.push(thread::spawn(move || -> (usize, Vec<f32>, u64, u64) {
            // One session pair per worker, reused for every hop.
            let mut enc = (*codec).as_ref().map(|h| h.encoder());
            let mut dec = (*codec).as_ref().map(|h| h.decoder());
            let quant = BlockQuantizer::new(Variant::ExmY);
            let mut chunks: Vec<Vec<f32>> =
                data.chunks(chunk).map(|c| c.to_vec()).collect();
            let w = chunks.len();
            let mut wire = 0u64;
            let mut raw = 0u64;

            // --- Reduce-scatter (quantize per hop). ------------------
            for s in 0..w - 1 {
                let send_ci = (i + w - s) % w;
                let q = quant.quantize(&chunks[send_ci]);
                let payload = encode_payload(&mut enc, &q.symbols);
                wire += (payload.len() + q.scales.len()) as u64;
                raw += (q.symbols.len() + q.scales.len()) as u64;
                tx.send(Msg {
                    payload,
                    scales: q.scales,
                    n_symbols: q.symbols.len(),
                })
                .expect("ring send");

                let msg = rx.recv().expect("ring recv");
                let symbols =
                    decode_payload(&mut dec, &msg.payload, msg.n_symbols);
                let incoming = quant.dequantize(&QuantizedBlocks {
                    symbols,
                    scales: msg.scales,
                    variant: Variant::ExmY,
                });
                let recv_ci = (i + w - s - 1) % w;
                for (acc, v) in chunks[recv_ci].iter_mut().zip(&incoming) {
                    *acc += v;
                }
            }

            // --- Final quantization of the owned chunk. ---------------
            let owned_ci = (i + 1) % w;
            let mut quantized: Vec<Option<QuantizedBlocks>> =
                (0..w).map(|_| None).collect();
            quantized[owned_ci] = Some(quant.quantize(&chunks[owned_ci]));

            // --- All-gather (lossless circulation). -------------------
            for s in 0..w - 1 {
                let send_ci = (i + 1 + w - s) % w;
                let q = quantized[send_ci].as_ref().expect("ring invariant");
                let payload = encode_payload(&mut enc, &q.symbols);
                wire += (payload.len() + q.scales.len()) as u64;
                raw += (q.symbols.len() + q.scales.len()) as u64;
                tx.send(Msg {
                    payload,
                    scales: q.scales.clone(),
                    n_symbols: q.symbols.len(),
                })
                .expect("ring send");

                let msg = rx.recv().expect("ring recv");
                let symbols =
                    decode_payload(&mut dec, &msg.payload, msg.n_symbols);
                let recv_ci = (i + w - s) % w;
                quantized[recv_ci] = Some(QuantizedBlocks {
                    symbols,
                    scales: msg.scales,
                    variant: Variant::ExmY,
                });
            }

            let result: Vec<f32> = (0..w)
                .flat_map(|ci| {
                    quant.dequantize(quantized[ci].as_ref().expect("complete"))
                })
                .collect();
            (i, result, wire, raw)
        }));
    }

    let mut results: Vec<Vec<f32>> = vec![Vec::new(); workers];
    let mut wire_bytes = 0u64;
    let mut raw_bytes = 0u64;
    for h in handles {
        let (i, data, wire, raw) = h.join().map_err(|_| "worker panicked")?;
        results[i] = data;
        wire_bytes += wire;
        raw_bytes += raw;
    }
    let report = EngineReport {
        wall_time_s: start.elapsed().as_secs_f64(),
        wire_bytes,
        raw_bytes,
    };
    Ok((results, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{ring_allreduce, Fabric};
    use crate::data::{TensorGen, TensorKind};
    use crate::stats::Histogram;
    use crate::util::rng::Rng;

    fn make_data(w: usize, per: usize, seed: u64) -> Vec<Vec<f32>> {
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(seed);
        (0..w).map(|_| gen.generate(&mut rng, per)).collect()
    }

    #[test]
    fn threaded_matches_simulated_raw() {
        let w = 4;
        let data = make_data(w, w * BLOCK * 8, 1);
        let fabric = Fabric::pod(w);
        let (sim, _) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let (thr, report) =
            threaded_allreduce(w, data, &Transport::Raw).unwrap();
        assert_eq!(sim, thr, "threaded ring must equal simulated ring");
        assert!(report.wall_time_s > 0.0);
        assert_eq!(report.wire_bytes, report.raw_bytes);
    }

    #[test]
    fn threaded_matches_simulated_compressed() {
        let w = 4;
        let data = make_data(w, w * BLOCK * 32, 2);
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(3);
        let cal = Histogram::from_symbols(&gen.symbols(&mut rng, 256 * BLOCK));
        let transport = Transport::Compressed {
            codec: "qlc".into(),
            calibration: Box::new(cal),
        };
        let fabric = Fabric::pod(w);
        let (sim, _) = ring_allreduce(&fabric, &data, &transport).unwrap();
        let (thr, report) = threaded_allreduce(w, data, &transport).unwrap();
        assert_eq!(sim, thr);
        assert!(
            report.wire_bytes < report.raw_bytes,
            "{} !< {}",
            report.wire_bytes,
            report.raw_bytes
        );
    }

    #[test]
    fn scales_with_worker_count() {
        for w in [2usize, 3, 8] {
            let data = make_data(w, w * BLOCK * 2, w as u64);
            let (results, _) =
                threaded_allreduce(w, data, &Transport::Raw).unwrap();
            assert_eq!(results.len(), w);
            for r in &results[1..] {
                assert_eq!(r, &results[0], "w={w}: workers must agree");
            }
        }
    }
}
