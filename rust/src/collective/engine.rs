//! Concurrent collective engine: the chunk-pipelined ring all-reduce
//! of [`super::ring_allreduce`] executed by real workers exchanging
//! compressed chunks over any transport [`Link`].  The per-worker hop
//! loop ([`allreduce_worker`]) is generic over the link, so the same
//! code runs on the threaded bounded-channel backend
//! ([`crate::transport::threaded`]) and on TCP sockets across OS
//! processes ([`crate::transport::net`], via
//! [`crate::collective::dist`]).  Validates that the simulated ring
//! and a concurrent implementation agree bit-for-bit, and measures
//! real end-to-end wall time — here the overlap of decode(k) with
//! transfer(k+1) is physical, not modelled: while one worker decodes a
//! chunk, its upstream neighbour is already encoding and sending the
//! next.

use std::sync::Arc;
use std::thread;
use std::time::Instant;

use super::Transport;
use crate::codecs::CodecHandle;
use crate::formats::{BlockQuantizer, QuantizedBlocks, Variant, BLOCK};
use crate::obs;
use crate::transport::{exchange_hop, threaded, Link, DEFAULT_TRANSPORT_CHUNK};

/// Wall-clock result of a threaded all-reduce.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub wall_time_s: f64,
    pub wire_bytes: u64,
    pub raw_bytes: u64,
    /// Transport chunk granularity the run used (symbols).
    pub chunk_symbols: usize,
}

/// One worker's accumulated transfer accounting across a collective.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerStats {
    /// Bytes this worker put on the wire.
    pub wire_bytes: u64,
    /// Bytes the same hops would ship uncompressed.
    pub raw_bytes: u64,
    /// Measured encode + decode wall time across all hops.
    pub codec_s: f64,
}

impl WorkerStats {
    fn add_hop(&mut self, ex: &crate::transport::HopExchange) {
        self.wire_bytes += ex.wire_bytes;
        self.raw_bytes += ex.raw_bytes;
        self.codec_s += ex.trace.codec_s();
    }
}

/// One worker's side of the lockstep ring all-reduce: lossy
/// quantize-per-hop reduce-scatter, then lossless circulation of
/// (symbols, scales).  Semantically identical to the matching slice of
/// [`super::ring_allreduce`]; every backend that runs it per worker
/// (threads over channels, processes over TCP) produces bit-identical
/// results.
///
/// `data` is this rank's tensor; its length must be a non-zero
/// multiple of `world × BLOCK`.  The codec handle's tables must be
/// identical on every rank (fitted apriori on a shared calibration).
pub fn allreduce_worker<L: Link>(
    link: &mut L,
    rank: usize,
    world: usize,
    data: Vec<f32>,
    codec: Option<&CodecHandle>,
    chunk_symbols: usize,
) -> Result<(Vec<f32>, WorkerStats), String> {
    if world == 0 {
        return Err("collective requires at least one worker".into());
    }
    if rank >= world {
        return Err(format!("rank {rank} out of range for world {world}"));
    }
    let n = data.len();
    if n == 0 || n % (world * BLOCK) != 0 {
        return Err(format!(
            "tensor length {n} must be a non-zero multiple of \
             workers × block = {}",
            world * BLOCK
        ));
    }
    let chunk = n / world;

    // One session pair per worker, reused for every hop.
    let mut enc = codec.map(|h| h.encoder());
    let mut dec = codec.map(|h| h.decoder());
    let quant = BlockQuantizer::new(Variant::ExmY);
    let mut chunks: Vec<Vec<f32>> =
        data.chunks(chunk).map(|c| c.to_vec()).collect();
    let w = world;
    let i = rank;
    let mut stats = WorkerStats::default();

    let hops = obs::global().counter("collective_hops_total");

    // --- Reduce-scatter (quantize per hop). --------------------------
    for s in 0..w - 1 {
        let _sp = obs::span("allreduce.hop")
            .arg("rank", i)
            .arg("step", s)
            .arg("phase", "reduce-scatter");
        let send_ci = (i + w - s) % w;
        let q = quant.quantize(&chunks[send_ci]);
        let ex = exchange_hop(
            link,
            &mut enc,
            &mut dec,
            &q.symbols,
            &q.scales,
            chunk_symbols,
        )?;
        hops.inc();
        stats.add_hop(&ex);
        let incoming = quant.dequantize(&QuantizedBlocks {
            symbols: ex.symbols,
            scales: ex.scales,
            variant: Variant::ExmY,
        });
        let recv_ci = (i + w - s - 1) % w;
        for (acc, v) in chunks[recv_ci].iter_mut().zip(&incoming) {
            *acc += v;
        }
    }

    // --- Final quantization of the owned chunk. ----------------------
    let owned_ci = (i + 1) % w;
    let mut quantized: Vec<Option<QuantizedBlocks>> =
        (0..w).map(|_| None).collect();
    quantized[owned_ci] = Some(quant.quantize(&chunks[owned_ci]));

    // --- All-gather (lossless circulation). --------------------------
    for s in 0..w - 1 {
        let _sp = obs::span("allreduce.hop")
            .arg("rank", i)
            .arg("step", s)
            .arg("phase", "all-gather");
        let send_ci = (i + 1 + w - s) % w;
        let q = quantized[send_ci]
            .as_ref()
            .ok_or("ring invariant broken")?;
        let ex = exchange_hop(
            link,
            &mut enc,
            &mut dec,
            &q.symbols,
            &q.scales,
            chunk_symbols,
        )?;
        hops.inc();
        stats.add_hop(&ex);
        let recv_ci = (i + w - s) % w;
        quantized[recv_ci] = Some(QuantizedBlocks {
            symbols: ex.symbols,
            scales: ex.scales,
            variant: Variant::ExmY,
        });
    }

    let mut result: Vec<f32> = Vec::with_capacity(n);
    for slot in &quantized {
        let q = slot.as_ref().ok_or("ring gather incomplete")?;
        result.extend(quant.dequantize(q));
    }
    Ok((result, stats))
}

/// One worker's side of a ring all-gather of opaque, pre-compressed
/// QLS1 shard bodies: rank `r` contributes shard `r`'s body; after
/// `world - 1` lockstep hops every rank holds all bodies in
/// shard-index order (ready for
/// [`crate::codecs::frame::decompress_sharded`]).  Bodies travel raw —
/// they are already compressed, so no transport codec is stacked on
/// top.
///
/// `shard_symbols` is the manifest's per-shard symbol count (one
/// entry per rank): bodies are opaque on the wire, so the raw-bytes
/// accounting comes from the manifest, not from the hop — the
/// returned stats' `compression_ratio` reflects the shard codec.
pub fn allgather_shards_worker<L: Link>(
    link: &mut L,
    rank: usize,
    world: usize,
    body: Vec<u8>,
    shard_symbols: &[u64],
) -> Result<(Vec<Vec<u8>>, WorkerStats), String> {
    if world == 0 {
        return Err("collective requires at least one worker".into());
    }
    if rank >= world {
        return Err(format!("rank {rank} out of range for world {world}"));
    }
    if shard_symbols.len() != world {
        return Err(format!(
            "manifest describes {} shards for world {world}",
            shard_symbols.len()
        ));
    }
    let mut have: Vec<Option<Vec<u8>>> = (0..world).map(|_| None).collect();
    have[rank] = Some(body);
    let mut stats = WorkerStats::default();
    let mut enc = None;
    let mut dec = None;
    let hops = obs::global().counter("collective_hops_total");
    for s in 0..world - 1 {
        let _sp = obs::span("allgather.hop")
            .arg("rank", rank)
            .arg("step", s)
            .arg("phase", "shard-gather");
        let send_i = (rank + world - s) % world;
        // Borrow the body for the hop only (no per-hop clone of a
        // potentially large compressed shard).
        let ex = {
            let bytes = have[send_i]
                .as_ref()
                .ok_or("ring invariant broken")?;
            exchange_hop(
                link,
                &mut enc,
                &mut dec,
                bytes,
                &[],
                DEFAULT_TRANSPORT_CHUNK,
            )?
        };
        hops.inc();
        stats.wire_bytes += ex.wire_bytes;
        stats.raw_bytes += shard_symbols[send_i];
        stats.codec_s += ex.trace.codec_s();
        let recv_i = (rank + world - s - 1) % world;
        have[recv_i] = Some(ex.symbols);
    }
    let mut bodies = Vec::with_capacity(world);
    for b in have {
        bodies.push(b.ok_or("ring gather incomplete")?);
    }
    Ok((bodies, stats))
}

/// Threaded ring all-reduce with default chunking. Semantically
/// identical to [`super::ring_allreduce`]: lossy quantize-per-hop
/// reduce-scatter, then lossless circulation of (symbols, scales).
pub fn threaded_allreduce(
    workers: usize,
    worker_data: Vec<Vec<f32>>,
    transport: &Transport,
) -> Result<(Vec<Vec<f32>>, EngineReport), String> {
    threaded_allreduce_with(
        workers,
        worker_data,
        transport,
        DEFAULT_TRANSPORT_CHUNK,
        2,
    )
}

/// [`threaded_allreduce`] with explicit transport chunk size and
/// per-link channel depth (chunks buffered in flight).  Chunking and
/// depth change scheduling, never results.
pub fn threaded_allreduce_with(
    workers: usize,
    worker_data: Vec<Vec<f32>>,
    transport: &Transport,
    chunk_symbols: usize,
    channel_depth: usize,
) -> Result<(Vec<Vec<f32>>, EngineReport), String> {
    // Same input contract as the simulated ring (one set of rules for
    // both backends — their bit-for-bit agreement depends on it).
    super::validate_workers(workers, worker_data.len())?;
    super::validate_tensors(&worker_data, workers)?;

    // Resolve the codec once (fitting qlc tables is expensive); the
    // read-only handle is shared by every worker, each of which keeps
    // its own mutable sessions.
    let shared_codec: Arc<Option<CodecHandle>> =
        Arc::new(transport.resolve()?);

    // Ring links: endpoint i sends to i+1, receives from i-1.
    let endpoints = threaded::ring(workers, channel_depth);

    let start = Instant::now();
    let mut handles = Vec::new();
    for ((i, data), mut link) in
        worker_data.into_iter().enumerate().zip(endpoints)
    {
        let codec = shared_codec.clone();
        handles.push(thread::spawn(
            move || -> Result<(usize, Vec<f32>, WorkerStats), String> {
                let (result, stats) = allreduce_worker(
                    &mut link,
                    i,
                    workers,
                    data,
                    (*codec).as_ref(),
                    chunk_symbols,
                )?;
                Ok((i, result, stats))
            },
        ));
    }

    let mut results: Vec<Vec<f32>> = vec![Vec::new(); workers];
    let mut wire_bytes = 0u64;
    let mut raw_bytes = 0u64;
    for h in handles {
        let (i, data, stats) =
            h.join().map_err(|_| "worker panicked")??;
        results[i] = data;
        wire_bytes += stats.wire_bytes;
        raw_bytes += stats.raw_bytes;
    }
    let report = EngineReport {
        wall_time_s: start.elapsed().as_secs_f64(),
        wire_bytes,
        raw_bytes,
        chunk_symbols,
    };
    Ok((results, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::codecs::frame::{self, FrameOptions};
    use crate::codecs::CodecRegistry;
    use crate::collective::{ring_allreduce, Fabric};
    use crate::data::{TensorGen, TensorKind};
    use crate::stats::Histogram;
    use crate::util::rng::Rng;

    fn make_data(w: usize, per: usize, seed: u64) -> Vec<Vec<f32>> {
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(seed);
        (0..w).map(|_| gen.generate(&mut rng, per)).collect()
    }

    #[test]
    fn threaded_matches_simulated_raw() {
        let w = 4;
        let data = make_data(w, w * BLOCK * 8, 1);
        let fabric = Fabric::pod(w);
        let (sim, _) =
            ring_allreduce(&fabric, &data, &Transport::Raw).unwrap();
        let (thr, report) =
            threaded_allreduce(w, data, &Transport::Raw).unwrap();
        assert_eq!(sim, thr, "threaded ring must equal simulated ring");
        assert!(report.wall_time_s > 0.0);
        assert_eq!(report.wire_bytes, report.raw_bytes);
    }

    #[test]
    fn threaded_matches_simulated_compressed() {
        let w = 4;
        let data = make_data(w, w * BLOCK * 32, 2);
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(3);
        let cal = Histogram::from_symbols(&gen.symbols(&mut rng, 256 * BLOCK));
        let transport = Transport::Compressed {
            codec: "qlc".into(),
            calibration: Box::new(cal),
        };
        let fabric = Fabric::pod(w);
        let (sim, _) = ring_allreduce(&fabric, &data, &transport).unwrap();
        let (thr, report) = threaded_allreduce(w, data, &transport).unwrap();
        assert_eq!(sim, thr);
        assert!(
            report.wire_bytes < report.raw_bytes,
            "{} !< {}",
            report.wire_bytes,
            report.raw_bytes
        );
    }

    #[test]
    fn chunked_pipeline_agrees_with_whole_payload() {
        // Many small chunks through shallow channels vs one chunk per
        // hop: identical results, identical raw byte accounting.
        let w = 4;
        let data = make_data(w, w * BLOCK * 16, 5);
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(6);
        let cal = Histogram::from_symbols(&gen.symbols(&mut rng, 128 * BLOCK));
        let transport = Transport::Compressed {
            codec: "huffman".into(),
            calibration: Box::new(cal),
        };
        let (whole, whole_rep) = threaded_allreduce_with(
            w,
            data.clone(),
            &transport,
            usize::MAX,
            2,
        )
        .unwrap();
        for (chunk_symbols, depth) in [(BLOCK, 1), (3 * BLOCK, 2), (256, 4)] {
            let (chunked, rep) = threaded_allreduce_with(
                w,
                data.clone(),
                &transport,
                chunk_symbols,
                depth,
            )
            .unwrap();
            assert_eq!(
                chunked, whole,
                "chunk_symbols={chunk_symbols} depth={depth}"
            );
            assert_eq!(rep.raw_bytes, whole_rep.raw_bytes);
        }
    }

    #[test]
    fn scales_with_worker_count() {
        for w in [2usize, 3, 8] {
            let data = make_data(w, w * BLOCK * 2, w as u64);
            let (results, _) =
                threaded_allreduce(w, data, &Transport::Raw).unwrap();
            assert_eq!(results.len(), w);
            for r in &results[1..] {
                assert_eq!(r, &results[0], "w={w}: workers must agree");
            }
        }
    }

    #[test]
    fn malformed_inputs_are_errors_not_panics() {
        // Wrong worker count.
        let data = make_data(3, 3 * BLOCK * 2, 11);
        assert!(threaded_allreduce(4, data, &Transport::Raw).is_err());
        // Non-divisible tensor size.
        let ragged = vec![vec![0f32; 4 * BLOCK * 2 + 3]; 4];
        assert!(threaded_allreduce(4, ragged, &Transport::Raw).is_err());
        // Empty tensors.
        let empty = vec![Vec::new(); 4];
        assert!(threaded_allreduce(4, empty, &Transport::Raw).is_err());
        // Mismatched lengths.
        let mut uneven = make_data(4, 4 * BLOCK * 2, 12);
        uneven[1].truncate(4 * BLOCK);
        assert!(threaded_allreduce(4, uneven, &Transport::Raw).is_err());
        // Zero workers.
        assert!(
            threaded_allreduce(0, Vec::new(), &Transport::Raw).is_err()
        );
        // Worker-level shape errors surface from the generic body too.
        let mut link = crate::transport::SimLink::new();
        assert!(
            allreduce_worker(&mut link, 2, 2, vec![0f32; 2 * BLOCK], None, 64)
                .is_err(),
            "rank out of range"
        );
        assert!(
            allreduce_worker(&mut link, 0, 2, vec![0f32; BLOCK + 1], None, 64)
                .is_err(),
            "non-divisible tensor"
        );
        assert!(
            allgather_shards_worker(&mut link, 3, 2, Vec::new(), &[1, 1])
                .is_err(),
            "rank out of range"
        );
        assert!(
            allgather_shards_worker(&mut link, 0, 2, Vec::new(), &[1])
                .is_err(),
            "shard table / world mismatch"
        );
    }

    #[test]
    fn dropped_peer_fails_cleanly_instead_of_hanging() {
        // Worker 2 vanishes before the exchange: the survivors must
        // all surface `Err` (send to a hung-up channel, recv from a
        // dropped sender, or recv timeout) — never panic or block
        // forever.
        let mut endpoints =
            threaded::ring_with_timeout(3, 1, Duration::from_millis(200));
        let dead = endpoints.pop().unwrap();
        drop(dead);
        let mut joined = Vec::new();
        for (i, mut link) in endpoints.into_iter().enumerate() {
            joined.push(thread::spawn(move || {
                let data = vec![1f32; 3 * BLOCK];
                allreduce_worker(&mut link, i, 3, data, None, 64)
            }));
        }
        for j in joined {
            let result = j.join().unwrap();
            assert!(result.is_err(), "peer loss must surface as Err");
        }
    }

    #[test]
    fn shard_allgather_workers_reassemble_manifest() {
        // Four workers each hold one QLS1 shard body; after the ring
        // gather every worker reassembles the tensor from the shared
        // manifest — the shard-granular placement path end to end.
        let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
        let mut rng = Rng::new(9);
        let symbols = gen.symbols(&mut rng, 256 * BLOCK);
        let hist = Histogram::from_symbols(&symbols);
        let handle = CodecRegistry::global().resolve("qlc", &hist).unwrap();
        let (manifest, bodies) = frame::compress_sharded(
            &handle,
            &symbols,
            4,
            &FrameOptions::serial(),
        )
        .unwrap();
        assert_eq!(manifest.n_shards(), 4);
        let endpoints = threaded::ring(4, 2);
        let manifest = Arc::new(manifest);
        let symbols = Arc::new(symbols);
        let mut joined = Vec::new();
        for ((rank, body), mut link) in
            bodies.into_iter().enumerate().zip(endpoints)
        {
            let manifest = manifest.clone();
            let symbols = symbols.clone();
            joined.push(thread::spawn(move || {
                let (bodies, stats) = allgather_shards_worker(
                    &mut link,
                    rank,
                    4,
                    body,
                    manifest.shard_symbols(),
                )
                .unwrap();
                let back = frame::decompress_sharded(
                    &manifest,
                    &bodies,
                    &FrameOptions::serial(),
                )
                .unwrap();
                assert_eq!(back, *symbols, "rank {rank}");
                assert!(stats.wire_bytes > 0);
                assert!(
                    stats.wire_bytes < stats.raw_bytes,
                    "stats must reflect the shard codec, not wire==raw"
                );
            }));
        }
        for j in joined {
            j.join().unwrap();
        }
    }
}
