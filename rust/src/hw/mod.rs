//! Hardware decoder cost model — quantifying the paper's central
//! claim: QLC "significantly speeds up the decoding and simplifies the
//! hardware complexity" relative to Huffman.
//!
//! Three decoder micro-architectures are modelled on real encoded
//! streams:
//!
//! * [`HuffmanSerialModel`] — the bit-serial tree FSM the paper calls
//!   "slow": one bit per cycle, so a symbol costs its code length in
//!   cycles, and the *next* symbol cannot start until the walk ends.
//! * [`HuffmanTableModel`] — a hardware multi-level LUT decoder: one
//!   cycle per table level touched; storage is the full table array.
//! * [`QlcModel`] — the paper's decoder: a fixed 2-stage pipeline
//!   (stage 1: P-bit area lookup → length; stage 2: offset add +
//!   256-entry LUT).  Length is known after the prefix, so the
//!   pipeline sustains 1 symbol/cycle regardless of code length.
//!
//! Storage is reported in bits; "critical-path stages" is the
//! worst-case sequential lookups per symbol (a proxy for achievable
//! clock / pipelining depth).

use crate::bitstream::BitReader;
use crate::codecs::huffman::build::CodeBook;
use crate::codecs::huffman::decode::{TableDecoder, TreeDecoder, ROOT_BITS};
use crate::codecs::qlc::QlcCodec;

/// Outcome of simulating one decoder model over a stream.
#[derive(Clone, Debug, PartialEq)]
pub struct CycleReport {
    pub model: String,
    pub symbols: u64,
    pub cycles: u64,
    pub storage_bits: u64,
    /// Worst-case sequential lookups for one symbol.
    pub worst_stages: u32,
}

impl CycleReport {
    pub fn cycles_per_symbol(&self) -> f64 {
        self.cycles as f64 / self.symbols.max(1) as f64
    }

    /// Symbols per cycle (pipeline throughput).
    pub fn throughput(&self) -> f64 {
        self.symbols as f64 / self.cycles.max(1) as f64
    }
}

// ---------------------------------------------------------------------------

/// Bit-serial Huffman FSM.
pub struct HuffmanSerialModel {
    book: CodeBook,
    tree: TreeDecoder,
}

impl HuffmanSerialModel {
    pub fn new(book: &CodeBook) -> Self {
        HuffmanSerialModel { book: book.clone(), tree: TreeDecoder::new(book) }
    }

    /// Node storage: each internal node holds two 9-bit child pointers
    /// (8-bit symbol + leaf flag).
    pub fn storage_bits(&self) -> u64 {
        self.tree.node_count() as u64 * 2 * 9
    }

    /// Simulate: one cycle per bit consumed.
    pub fn simulate(&self, symbols: &[u8]) -> CycleReport {
        let lengths = self.book.lengths();
        let cycles: u64 =
            symbols.iter().map(|&s| lengths[s as usize] as u64).sum();
        CycleReport {
            model: "huffman-serial".into(),
            symbols: symbols.len() as u64,
            cycles,
            storage_bits: self.storage_bits(),
            worst_stages: self.book.max_length(),
        }
    }
}

// ---------------------------------------------------------------------------

/// Hardware multi-level LUT Huffman decoder.
pub struct HuffmanTableModel {
    book: CodeBook,
    table: TableDecoder,
}

impl HuffmanTableModel {
    pub fn new(book: &CodeBook) -> Self {
        HuffmanTableModel { book: book.clone(), table: TableDecoder::new(book) }
    }

    /// Entry storage: each entry holds symbol(8) + length(6) + tag(2).
    pub fn storage_bits(&self) -> u64 {
        self.table.entry_count() as u64 * 16
    }

    /// Levels touched for a code of `len` bits.
    fn levels(len: u32) -> u64 {
        (len as u64).div_ceil(ROOT_BITS as u64).max(1)
    }

    pub fn simulate(&self, symbols: &[u8]) -> CycleReport {
        let lengths = self.book.lengths();
        let cycles: u64 = symbols
            .iter()
            .map(|&s| Self::levels(lengths[s as usize]))
            .sum();
        CycleReport {
            model: "huffman-table".into(),
            symbols: symbols.len() as u64,
            cycles,
            storage_bits: self.storage_bits(),
            worst_stages: Self::levels(self.book.max_length()) as u32,
        }
    }
}

// ---------------------------------------------------------------------------

/// The paper's QLC decoder: 2-stage pipeline, 1 symbol/cycle.
pub struct QlcModel {
    prefix_bits: u32,
    num_areas: usize,
}

impl QlcModel {
    pub fn new(codec: &QlcCodec) -> Self {
        QlcModel {
            prefix_bits: codec.scheme().prefix_bits,
            num_areas: codec.scheme().num_areas(),
        }
    }

    /// Prefix table: 2^P × (4-bit suffix width + 8-bit base rank) plus
    /// the 256×8-bit output LUT (paper Table 4).
    pub fn storage_bits(&self) -> u64 {
        (self.num_areas as u64) * (4 + 8) + 256 * 8
    }

    pub fn simulate(&self, symbols: &[u8]) -> CycleReport {
        // Fully pipelined: n symbols in n + (stages-1) cycles.
        let n = symbols.len() as u64;
        CycleReport {
            model: format!("qlc-p{}", self.prefix_bits),
            symbols: n,
            cycles: n + 1,
            storage_bits: self.storage_bits(),
            worst_stages: 2,
        }
    }
}

// ---------------------------------------------------------------------------

/// Verify the serial model against the real decoder: decoding the
/// stream bit-by-bit must consume exactly `report.cycles` bits.
pub fn verify_serial_model(
    book: &CodeBook,
    symbols: &[u8],
    encoded: &[u8],
) -> bool {
    let model = HuffmanSerialModel::new(book);
    let report = model.simulate(symbols);
    let mut reader = BitReader::new(encoded);
    let mut out = Vec::with_capacity(symbols.len());
    if model.tree.decode(&mut reader, symbols.len(), &mut out).is_err() {
        return false;
    }
    out == symbols && reader.bits_consumed() == report.cycles
}

/// Side-by-side comparison for one PMF (the HEAD experiment).
pub fn compare_on_stream(
    book: &CodeBook,
    qlc: &QlcCodec,
    symbols: &[u8],
) -> Vec<CycleReport> {
    vec![
        HuffmanSerialModel::new(book).simulate(symbols),
        HuffmanTableModel::new(book).simulate(symbols),
        QlcModel::new(qlc).simulate(symbols),
    ]
}

/// Decode-speedup headline: serial-Huffman cycles / QLC cycles.
pub fn qlc_speedup_vs_serial(reports: &[CycleReport]) -> f64 {
    let serial = reports
        .iter()
        .find(|r| r.model == "huffman-serial")
        .expect("serial report");
    let qlc = reports
        .iter()
        .find(|r| r.model.starts_with("qlc"))
        .expect("qlc report");
    serial.cycles as f64 / qlc.cycles as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::qlc::AreaScheme;
    use crate::stats::Histogram;
    use crate::util::rng::{AliasTable, Rng};

    fn setup(alpha: f64, n: usize) -> (CodeBook, QlcCodec, Vec<u8>) {
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = 1.0 / (1.0 + i as f64).powf(alpha);
        }
        let alias = AliasTable::new(&p);
        let mut rng = Rng::new(3);
        let symbols = alias.sample_many(&mut rng, n);
        let hist = Histogram::from_symbols(&symbols);
        let mut freqs = [0u64; 256];
        for i in 0..256 {
            freqs[i] = hist.counts[i].max(1);
        }
        let book = CodeBook::build(&freqs, 48);
        let qlc = QlcCodec::from_pmf(AreaScheme::table1(), &hist.pmf());
        (book, qlc, symbols)
    }

    #[test]
    fn serial_cycles_equal_encoded_bits() {
        let (book, _, symbols) = setup(1.2, 20_000);
        let model = HuffmanSerialModel::new(&book);
        let report = model.simulate(&symbols);
        let total_bits: u64 = symbols
            .iter()
            .map(|&s| book.lengths()[s as usize] as u64)
            .sum();
        assert_eq!(report.cycles, total_bits);
        assert!(report.cycles_per_symbol() >= 1.0);
    }

    #[test]
    fn serial_model_verified_against_real_decoder() {
        let (book, _, symbols) = setup(1.1, 5_000);
        let mut w = crate::bitstream::BitWriter::new();
        for &s in &symbols {
            let (c, l) = book.code(s);
            w.write_bits(c, l);
        }
        let encoded = w.finish();
        assert!(verify_serial_model(&book, &symbols, &encoded));
    }

    #[test]
    fn qlc_sustains_one_symbol_per_cycle() {
        let (_, qlc, symbols) = setup(1.2, 50_000);
        let report = QlcModel::new(&qlc).simulate(&symbols);
        assert!((report.cycles_per_symbol() - 1.0).abs() < 1e-3);
        assert_eq!(report.worst_stages, 2);
    }

    #[test]
    fn qlc_storage_far_below_huffman_table() {
        let (book, qlc, _) = setup(1.2, 10_000);
        let h = HuffmanTableModel::new(&book).storage_bits();
        let q = QlcModel::new(&qlc).storage_bits();
        assert!(
            q * 4 < h,
            "qlc {q} bits should be ≪ huffman table {h} bits"
        );
        // The paper's LUT: 256 entries × 8 bits dominate QLC storage.
        assert!(q < 4 * 1024);
    }

    #[test]
    fn speedup_scales_with_expected_code_length() {
        let (book, qlc, symbols) = setup(1.3, 30_000);
        let reports = compare_on_stream(&book, &qlc, &symbols);
        let speedup = qlc_speedup_vs_serial(&reports);
        let hist = Histogram::from_symbols(&symbols);
        let el = hist.pmf().expected_length(book.lengths());
        assert!(
            (speedup - el).abs() / el < 0.02,
            "speedup {speedup} ≈ E[len] {el}"
        );
        assert!(speedup > 3.0, "meaningful speedup expected, got {speedup}");
    }

    #[test]
    fn table_model_levels() {
        assert_eq!(HuffmanTableModel::levels(1), 1);
        assert_eq!(HuffmanTableModel::levels(11), 1);
        assert_eq!(HuffmanTableModel::levels(12), 2);
        assert_eq!(HuffmanTableModel::levels(22), 2);
        assert_eq!(HuffmanTableModel::levels(23), 3);
    }

    #[test]
    fn table_model_cycles_bounded_by_serial() {
        let (book, _, symbols) = setup(1.2, 10_000);
        let serial = HuffmanSerialModel::new(&book).simulate(&symbols);
        let table = HuffmanTableModel::new(&book).simulate(&symbols);
        assert!(table.cycles <= serial.cycles);
        assert!(table.cycles >= symbols.len() as u64);
    }

    #[test]
    fn deep_tree_inflates_huffman_stages() {
        // Fibonacci counts → very deep codes → many table levels and a
        // long serial walk; QLC stays at 2 stages.
        let mut freqs = [0u64; 256];
        let mut a = 1u64;
        let mut b = 1u64;
        for f in freqs.iter_mut() {
            *f = a;
            let c = a.saturating_add(b);
            a = b;
            b = c;
        }
        let book = CodeBook::build(&freqs, 48);
        let serial = HuffmanSerialModel::new(&book);
        let symbols: Vec<u8> = (0..32).collect(); // the rare/deep end
        let report = serial.simulate(&symbols);
        assert!(report.worst_stages > 30);
        let table = HuffmanTableModel::new(&book).simulate(&symbols);
        assert!(table.worst_stages >= 4);
    }
}

// ---------------------------------------------------------------------------
// N-lane parallel QLC decoder

/// Multi-lane QLC decoder model — the extension the paper's "not
/// completely bit sequential" observation enables: because the code
/// length is known from the P-bit prefix alone, a wide front-end can
/// chain N prefix inspections combinationally (a length-prefix-sum)
/// and emit N symbols per cycle.  A serial Huffman decoder cannot do
/// this: symbol N's start position depends on fully decoding symbol
/// N-1.
///
/// Model: `lanes` symbols/cycle, a front-end adder chain of `lanes`
/// prefix decoders (storage scales linearly), plus the shared 256-entry
/// output LUT replicated per lane for single-cycle access.
pub struct ParallelQlcModel {
    prefix_bits: u32,
    num_areas: usize,
    pub lanes: u32,
}

impl ParallelQlcModel {
    pub fn new(codec: &QlcCodec, lanes: u32) -> Self {
        assert!(lanes >= 1);
        ParallelQlcModel {
            prefix_bits: codec.scheme().prefix_bits,
            num_areas: codec.scheme().num_areas(),
            lanes,
        }
    }

    /// Per-lane prefix table + per-lane output LUT copy.
    pub fn storage_bits(&self) -> u64 {
        self.lanes as u64 * ((self.num_areas as u64) * (4 + 8) + 256 * 8)
    }

    pub fn simulate(&self, symbols: &[u8]) -> CycleReport {
        let n = symbols.len() as u64;
        // lanes symbols per cycle; +1 pipeline fill, +1 for the
        // length-prefix-sum stage once lanes > 1.
        let fill = if self.lanes > 1 { 2 } else { 1 };
        CycleReport {
            model: format!("qlc-p{}x{}", self.prefix_bits, self.lanes),
            symbols: n,
            cycles: n.div_ceil(self.lanes as u64) + fill,
            storage_bits: self.storage_bits(),
            worst_stages: 2 + (self.lanes > 1) as u32,
        }
    }
}

#[cfg(test)]
mod parallel_tests {
    use super::*;
    use crate::codecs::qlc::{AreaScheme, QlcCodec};
    use crate::stats::Histogram;
    use crate::util::rng::Rng;

    fn codec() -> QlcCodec {
        let mut rng = Rng::new(1);
        let symbols: Vec<u8> =
            (0..10_000).map(|_| (rng.normal().abs() * 50.0) as u8).collect();
        QlcCodec::from_pmf(
            AreaScheme::table1(),
            &Histogram::from_symbols(&symbols).pmf(),
        )
    }

    #[test]
    fn throughput_scales_with_lanes() {
        let c = codec();
        let symbols = vec![0u8; 100_000];
        let r1 = ParallelQlcModel::new(&c, 1).simulate(&symbols);
        let r4 = ParallelQlcModel::new(&c, 4).simulate(&symbols);
        let r8 = ParallelQlcModel::new(&c, 8).simulate(&symbols);
        assert!((r4.throughput() / r1.throughput() - 4.0).abs() < 0.01);
        assert!((r8.throughput() / r1.throughput() - 8.0).abs() < 0.01);
    }

    #[test]
    fn storage_scales_linearly() {
        let c = codec();
        let s1 = ParallelQlcModel::new(&c, 1).storage_bits();
        let s8 = ParallelQlcModel::new(&c, 8).storage_bits();
        assert_eq!(s8, 8 * s1);
    }

    #[test]
    fn single_lane_matches_base_model() {
        let c = codec();
        let symbols = vec![0u8; 50_000];
        let base = QlcModel::new(&c).simulate(&symbols);
        let one = ParallelQlcModel::new(&c, 1).simulate(&symbols);
        assert_eq!(base.cycles, one.cycles);
    }
}

// ---------------------------------------------------------------------------
// Encoder-side models (paper ref [12]: "Single-Stage Huffman Encoder")

/// Encoder hardware comparison: both QLC and Huffman encode through a
/// single 256-entry LUT lookup per symbol (one stage, 1 symbol/cycle) —
/// the encoder is not where they differ.  What differs is the *entry
/// width*: a Huffman entry must hold up to `max_len` code bits plus a
/// 6-bit length; a QLC entry holds ≤ 11+4 bits.  The packer barrel
/// shifter also scales with the max code length.
pub struct EncoderModel {
    pub name: String,
    pub max_code_bits: u32,
    pub lut_entries: u32,
}

impl EncoderModel {
    pub fn huffman(book: &CodeBook) -> Self {
        EncoderModel {
            name: "huffman-enc".into(),
            max_code_bits: book.max_length(),
            lut_entries: 256,
        }
    }

    pub fn qlc(codec: &QlcCodec) -> Self {
        let max = (0..codec.scheme().num_areas())
            .map(|a| codec.scheme().code_length(a))
            .max()
            .unwrap();
        EncoderModel {
            name: "qlc-enc".into(),
            max_code_bits: max,
            lut_entries: 256,
        }
    }

    /// LUT bits: entries × (code bits + 6-bit length field).
    pub fn storage_bits(&self) -> u64 {
        self.lut_entries as u64 * (self.max_code_bits as u64 + 6)
    }

    /// Barrel-shifter width of the bit packer (merging variable-length
    /// codes into the output word) — a critical-path proxy.
    pub fn shifter_width_bits(&self) -> u32 {
        self.max_code_bits.next_power_of_two().max(8)
    }

    pub fn simulate(&self, symbols: &[u8]) -> CycleReport {
        // Single stage, fully pipelined: 1 symbol/cycle for both.
        let n = symbols.len() as u64;
        CycleReport {
            model: self.name.clone(),
            symbols: n,
            cycles: n + 1,
            storage_bits: self.storage_bits(),
            worst_stages: 1,
        }
    }
}

#[cfg(test)]
mod encoder_tests {
    use super::*;
    use crate::codecs::qlc::{AreaScheme, QlcCodec};
    use crate::stats::Histogram;
    use crate::util::rng::Rng;

    fn setup() -> (CodeBook, QlcCodec) {
        let mut rng = Rng::new(2);
        let symbols: Vec<u8> =
            (0..20_000).map(|_| (rng.normal().abs() * 45.0) as u8).collect();
        let hist = Histogram::from_symbols(&symbols);
        let mut freqs = [0u64; 256];
        for i in 0..256 {
            freqs[i] = hist.counts[i].max(1);
        }
        (
            CodeBook::build(&freqs, 48),
            QlcCodec::from_pmf(AreaScheme::table1(), &hist.pmf()),
        )
    }

    #[test]
    fn both_encoders_single_stage() {
        let (book, qlc) = setup();
        let symbols = vec![1u8; 1000];
        let h = EncoderModel::huffman(&book).simulate(&symbols);
        let q = EncoderModel::qlc(&qlc).simulate(&symbols);
        assert_eq!(h.worst_stages, 1);
        assert_eq!(q.worst_stages, 1);
        assert_eq!(h.cycles, q.cycles);
    }

    #[test]
    fn qlc_encoder_lut_narrower() {
        let (book, qlc) = setup();
        let h = EncoderModel::huffman(&book);
        let q = EncoderModel::qlc(&qlc);
        assert_eq!(q.max_code_bits, 11);
        assert!(h.max_code_bits > q.max_code_bits);
        assert!(h.storage_bits() > q.storage_bits());
        assert!(h.shifter_width_bits() >= q.shifter_width_bits());
    }
}
