//! Symbol statistics: histograms, PMFs, Shannon entropy,
//! compressibility, divergences, and multi-shard aggregation
//! (the paper averages PMFs over 18 layers × 64 shards).

/// Raw symbol counts over the 256-symbol alphabet.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    pub counts: [u64; 256],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: [0; 256] }
    }

    pub fn from_symbols(symbols: &[u8]) -> Self {
        let mut h = Histogram::new();
        h.add_symbols(symbols);
        h
    }

    /// Count in 4 independent lanes to break the store-to-load
    /// dependency chain (≈3× faster than the naive loop on long inputs).
    pub fn add_symbols(&mut self, symbols: &[u8]) {
        let mut lanes = [[0u32; 256]; 4];
        let mut chunks = symbols.chunks_exact(4);
        for c in &mut chunks {
            lanes[0][c[0] as usize] += 1;
            lanes[1][c[1] as usize] += 1;
            lanes[2][c[2] as usize] += 1;
            lanes[3][c[3] as usize] += 1;
        }
        for &s in chunks.remainder() {
            lanes[0][s as usize] += 1;
        }
        for i in 0..256 {
            self.counts[i] += lanes[0][i] as u64
                + lanes[1][i] as u64
                + lanes[2][i] as u64
                + lanes[3][i] as u64;
        }
    }

    pub fn merge(&mut self, other: &Histogram) {
        for i in 0..256 {
            self.counts[i] += other.counts[i];
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn pmf(&self) -> Pmf {
        let total = self.total();
        assert!(total > 0, "empty histogram has no PMF");
        let mut p = [0f64; 256];
        for i in 0..256 {
            p[i] = self.counts[i] as f64 / total as f64;
        }
        Pmf { p }
    }
}

/// Probability mass function over the 256-symbol alphabet.
#[derive(Clone, Debug, PartialEq)]
pub struct Pmf {
    pub p: [f64; 256],
}

impl Pmf {
    pub fn uniform() -> Self {
        Pmf { p: [1.0 / 256.0; 256] }
    }

    pub fn from_slice(p: &[f64]) -> Self {
        assert_eq!(p.len(), 256);
        let sum: f64 = p.iter().sum();
        assert!(sum > 0.0);
        let mut arr = [0f64; 256];
        for (a, &x) in arr.iter_mut().zip(p) {
            assert!(x >= 0.0);
            *a = x / sum;
        }
        Pmf { p: arr }
    }

    /// Shannon entropy in bits/symbol.
    pub fn entropy(&self) -> f64 {
        -self
            .p
            .iter()
            .filter(|&&p| p > 0.0)
            .map(|&p| p * p.log2())
            .sum::<f64>()
    }

    /// The paper's "ideal compressibility": `(8 - H) / 8`.
    pub fn ideal_compressibility(&self) -> f64 {
        (8.0 - self.entropy()) / 8.0
    }

    /// Expected code length (bits/symbol) under per-symbol lengths.
    pub fn expected_length(&self, lengths: &[u32; 256]) -> f64 {
        self.p
            .iter()
            .zip(lengths)
            .map(|(&p, &l)| p * l as f64)
            .sum()
    }

    /// The paper's "compressibility" of a code: `(8 - E[len]) / 8`.
    pub fn compressibility(&self, lengths: &[u32; 256]) -> f64 {
        (8.0 - self.expected_length(lengths)) / 8.0
    }

    /// Symbols sorted by decreasing probability (rank → symbol).
    /// Ties broken by symbol value for determinism.
    pub fn rank_order(&self) -> [u8; 256] {
        let mut idx: Vec<u8> = (0..=255).collect();
        idx.sort_by(|&a, &b| {
            self.p[b as usize]
                .partial_cmp(&self.p[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut out = [0u8; 256];
        out.copy_from_slice(&idx);
        out
    }

    /// Probabilities in decreasing order (the paper's Fig. 1 / Fig. 4).
    pub fn sorted_desc(&self) -> [f64; 256] {
        let mut s = self.p;
        s.sort_by(|a, b| b.partial_cmp(a).unwrap());
        s
    }

    /// KL(self ‖ other) in bits; +inf if other lacks support.
    pub fn kl_divergence(&self, other: &Pmf) -> f64 {
        let mut kl = 0.0;
        for i in 0..256 {
            if self.p[i] > 0.0 {
                if other.p[i] <= 0.0 {
                    return f64::INFINITY;
                }
                kl += self.p[i] * (self.p[i] / other.p[i]).log2();
            }
        }
        kl
    }

    /// Total-variation distance.
    pub fn tv_distance(&self, other: &Pmf) -> f64 {
        self.p
            .iter()
            .zip(&other.p)
            .map(|(&a, &b)| (a - b).abs())
            .sum::<f64>()
            / 2.0
    }
}

/// Average PMFs across shards (paper: "averaged over all shards").
pub fn average_pmfs(pmfs: &[Pmf]) -> Pmf {
    assert!(!pmfs.is_empty());
    let mut acc = [0f64; 256];
    for pmf in pmfs {
        for i in 0..256 {
            acc[i] += pmf.p[i];
        }
    }
    for a in acc.iter_mut() {
        *a /= pmfs.len() as f64;
    }
    Pmf { p: acc }
}

/// Measured compression summary for a (codec, data) pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompressionReport {
    pub input_bytes: u64,
    pub output_bytes: u64,
}

impl CompressionReport {
    /// Paper's compressibility: fraction of bytes removed.
    pub fn compressibility(&self) -> f64 {
        1.0 - self.output_bytes as f64 / self.input_bytes as f64
    }

    pub fn ratio(&self) -> f64 {
        self.input_bytes as f64 / self.output_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn histogram_counts() {
        let h = Histogram::from_symbols(&[0, 0, 1, 255]);
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[1], 1);
        assert_eq!(h.counts[255], 1);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn histogram_lanes_match_naive() {
        prop::check("histogram lanes", Default::default(), |rng, size| {
            let data = prop::arb_bytes(rng, size);
            let fast = Histogram::from_symbols(&data);
            let mut naive = [0u64; 256];
            for &s in &data {
                naive[s as usize] += 1;
            }
            if fast.counts != naive {
                return Err("lane mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn merge_adds() {
        let mut a = Histogram::from_symbols(&[1, 2]);
        let b = Histogram::from_symbols(&[2, 3]);
        a.merge(&b);
        assert_eq!(a.counts[2], 2);
        assert_eq!(a.total(), 4);
    }

    #[test]
    #[should_panic(expected = "empty histogram")]
    fn empty_pmf_panics() {
        Histogram::new().pmf();
    }

    #[test]
    fn uniform_entropy_is_8() {
        assert!((Pmf::uniform().entropy() - 8.0).abs() < 1e-12);
        assert!(Pmf::uniform().ideal_compressibility().abs() < 1e-12);
    }

    #[test]
    fn deterministic_entropy_is_0() {
        let mut p = [0f64; 256];
        p[7] = 1.0;
        let pmf = Pmf::from_slice(&p);
        assert_eq!(pmf.entropy(), 0.0);
        assert_eq!(pmf.ideal_compressibility(), 1.0);
    }

    #[test]
    fn two_point_entropy() {
        let mut p = [0f64; 256];
        p[0] = 0.5;
        p[1] = 0.5;
        assert!((Pmf::from_slice(&p).entropy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn from_slice_normalizes() {
        let mut p = [0f64; 256];
        p[0] = 2.0;
        p[1] = 2.0;
        let pmf = Pmf::from_slice(&p);
        assert!((pmf.p[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn expected_length_uniform_code() {
        let pmf = Pmf::uniform();
        let lengths = [8u32; 256];
        assert!((pmf.expected_length(&lengths) - 8.0).abs() < 1e-12);
        assert!(pmf.compressibility(&lengths).abs() < 1e-12);
    }

    #[test]
    fn rank_order_sorts_desc() {
        let mut p = [1f64; 256];
        p[42] = 500.0;
        p[7] = 300.0;
        let pmf = Pmf::from_slice(&p);
        let rank = pmf.rank_order();
        assert_eq!(rank[0], 42);
        assert_eq!(rank[1], 7);
        // remaining ties broken by symbol value
        assert_eq!(rank[2], 0);
    }

    #[test]
    fn rank_order_is_permutation() {
        prop::check("rank_order permutation", Default::default(),
                    |rng, _| {
            let mut p = [0f64; 256];
            for v in p.iter_mut() {
                *v = rng.uniform();
            }
            let pmf = Pmf::from_slice(&p);
            let mut seen = [false; 256];
            for &s in pmf.rank_order().iter() {
                if seen[s as usize] {
                    return Err(format!("dup symbol {s}"));
                }
                seen[s as usize] = true;
            }
            Ok(())
        });
    }

    #[test]
    fn sorted_desc_matches_rank_order() {
        let mut p = [1f64; 256];
        p[9] = 10.0;
        let pmf = Pmf::from_slice(&p);
        let sorted = pmf.sorted_desc();
        let rank = pmf.rank_order();
        for i in 0..256 {
            assert_eq!(sorted[i], pmf.p[rank[i] as usize]);
        }
    }

    #[test]
    fn kl_zero_for_identical() {
        let pmf = Pmf::uniform();
        assert!(pmf.kl_divergence(&pmf).abs() < 1e-12);
    }

    #[test]
    fn kl_infinite_without_support() {
        let mut p = [0f64; 256];
        p[0] = 1.0;
        let a = Pmf::from_slice(&p);
        let mut q = [0f64; 256];
        q[1] = 1.0;
        let b = Pmf::from_slice(&q);
        assert!(a.kl_divergence(&b).is_infinite());
    }

    #[test]
    fn tv_distance_bounds() {
        let mut p = [0f64; 256];
        p[0] = 1.0;
        let a = Pmf::from_slice(&p);
        let mut q = [0f64; 256];
        q[1] = 1.0;
        let b = Pmf::from_slice(&q);
        assert!((a.tv_distance(&b) - 1.0).abs() < 1e-12);
        assert!(a.tv_distance(&a).abs() < 1e-12);
    }

    #[test]
    fn average_pmfs_means() {
        let mut p = [0f64; 256];
        p[0] = 1.0;
        let a = Pmf::from_slice(&p);
        let mut q = [0f64; 256];
        q[1] = 1.0;
        let b = Pmf::from_slice(&q);
        let avg = average_pmfs(&[a, b]);
        assert!((avg.p[0] - 0.5).abs() < 1e-12);
        assert!((avg.p[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn entropy_invariant_under_permutation() {
        prop::check("entropy permutation-invariant", Default::default(),
                    |rng, _| {
            let mut p = [0f64; 256];
            for v in p.iter_mut() {
                *v = rng.uniform();
            }
            let pmf = Pmf::from_slice(&p);
            // permute by rotation
            let mut rot = [0f64; 256];
            for i in 0..256 {
                rot[i] = p[(i + 37) % 256];
            }
            let pmf2 = Pmf::from_slice(&rot);
            if (pmf.entropy() - pmf2.entropy()).abs() > 1e-9 {
                return Err("entropy changed under permutation".into());
            }
            Ok(())
        });
    }

    #[test]
    fn compression_report_math() {
        let r = CompressionReport { input_bytes: 100, output_bytes: 80 };
        assert!((r.compressibility() - 0.2).abs() < 1e-12);
        assert!((r.ratio() - 1.25).abs() < 1e-12);
    }
}
