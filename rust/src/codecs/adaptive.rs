//! Streaming adaptive QLC — an extension the paper's §7 sets up
//! ("multiple LUTs … obtained apriori"): instead of a fixed apriori
//! LUT, the encoder re-fits the rank order (and optionally the area
//! scheme) per chunk from the *previous* chunk's histogram, so encoder
//! and decoder stay in lockstep with zero table bytes on the wire
//! after the first chunk.
//!
//! Chunk 0 uses the neutral identity ranking (or a caller-provided
//! prior); every subsequent chunk uses the ranking measured on the
//! chunk before it.  Distribution drift (e.g. across layers or
//! training steps) is absorbed within one chunk.

use super::kernel::BitCursor;
use super::qlc::{AreaScheme, QlcCodec};
use super::{Codec, CodecError};
use crate::bitstream::BitWriter;
use crate::stats::Histogram;

/// Streaming encoder/decoder pair configuration.
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    pub chunk_symbols: usize,
    pub scheme: AreaScheme,
    /// Re-run the area-scheme optimizer each chunk (cost: one DP per
    /// chunk) instead of keeping `scheme` fixed.
    pub reoptimize_scheme: bool,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            chunk_symbols: 64 * 1024,
            scheme: AreaScheme::table1(),
            reoptimize_scheme: false,
        }
    }
}

fn identity_rank() -> [u8; 256] {
    let mut r = [0u8; 256];
    for (i, v) in r.iter_mut().enumerate() {
        *v = i as u8;
    }
    r
}

fn codec_for(
    cfg: &AdaptiveConfig,
    hist: Option<&Histogram>,
) -> QlcCodec {
    match hist {
        None => QlcCodec::from_rank_order(
            cfg.scheme.clone(),
            &identity_rank(),
            "qlc-adaptive",
        ),
        Some(h) => {
            let pmf = h.pmf();
            let scheme = if cfg.reoptimize_scheme {
                super::qlc::optimizer::optimize_for_prefix(
                    &pmf.sorted_desc(),
                    cfg.scheme.prefix_bits,
                )
            } else {
                cfg.scheme.clone()
            };
            QlcCodec::from_pmf(scheme, &pmf)
        }
    }
}

/// Encode a stream with per-chunk adaptation.  The output is pure
/// payload: the decoder reconstructs every table from the decoded
/// history.
pub fn encode(cfg: &AdaptiveConfig, symbols: &[u8]) -> Vec<u8> {
    let mut out = BitWriter::with_capacity(symbols.len());
    let mut prev_hist: Option<Histogram> = None;
    for chunk in symbols.chunks(cfg.chunk_symbols) {
        let codec = codec_for(cfg, prev_hist.as_ref());
        // Chunks share one continuous (non-byte-aligned) bitstream, so
        // this stays on the scalar writer rather than a per-chunk sink.
        codec.encode_scalar(chunk, &mut out);
        prev_hist = Some(Histogram::from_symbols(chunk));
    }
    out.finish()
}

/// Decode `n` symbols produced by [`encode`] with the same config.
///
/// Unlike the QLF2 frame format, adaptive chunks are *not* byte
/// aligned (the stream is one continuous bitstream with zero table
/// bytes after chunk 0), so decode is inherently sequential — each
/// chunk's tables derive from the previous chunk's decoded symbols.
/// The output is still produced via [`Codec::decode_into`] (the
/// batched kernel, on one persistent [`BitCursor`]) straight into the
/// result buffer, one slice per chunk.
pub fn decode(
    cfg: &AdaptiveConfig,
    data: &[u8],
    n: usize,
) -> Result<Vec<u8>, CodecError> {
    let mut cur = BitCursor::new(data);
    let mut out = vec![0u8; n];
    let mut prev_hist: Option<Histogram> = None;
    let mut done = 0usize;
    while done < n {
        let take = cfg.chunk_symbols.min(n - done);
        let codec = codec_for(cfg, prev_hist.as_ref());
        codec.decode_into(&mut cur, &mut out[done..done + take])?;
        prev_hist = Some(Histogram::from_symbols(&out[done..done + take]));
        done += take;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{TensorGen, TensorKind};
    use crate::formats::Variant;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn drifting_stream(n: usize, seed: u64) -> Vec<u8> {
        // Distribution drifts mid-stream: FFN1-like → FFN2-like.
        let mut rng = Rng::new(seed);
        let a = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY)
            .symbols(&mut rng, n / 2);
        let b = TensorGen::new(TensorKind::Ffn2Act, Variant::ExmY)
            .symbols(&mut rng, n - n / 2);
        [a, b].concat()
    }

    #[test]
    fn roundtrip_drifting_stream() {
        let symbols = drifting_stream(512 * 1024, 1);
        let cfg = AdaptiveConfig::default();
        let enc = encode(&cfg, &symbols);
        assert_eq!(decode(&cfg, &enc, symbols.len()).unwrap(), symbols);
        assert!(enc.len() < symbols.len());
    }

    #[test]
    fn roundtrip_with_reoptimized_scheme() {
        let symbols = drifting_stream(256 * 1024, 2);
        let cfg = AdaptiveConfig {
            reoptimize_scheme: true,
            ..Default::default()
        };
        let enc = encode(&cfg, &symbols);
        assert_eq!(decode(&cfg, &enc, symbols.len()).unwrap(), symbols);
    }

    #[test]
    fn adaptation_beats_static_mismatched_lut() {
        // Static codec fitted on the FIRST half only vs adaptive: after
        // the drift, adaptation must win.
        let symbols = drifting_stream(1 << 20, 3);
        let first_half_hist =
            Histogram::from_symbols(&symbols[..symbols.len() / 2]);
        let static_codec = QlcCodec::from_pmf(
            AreaScheme::table1(),
            &first_half_hist.pmf(),
        );
        let static_len = static_codec.encode_to_vec(&symbols).len();
        let cfg = AdaptiveConfig {
            reoptimize_scheme: true,
            ..Default::default()
        };
        let adaptive_len = encode(&cfg, &symbols).len();
        assert!(
            adaptive_len < static_len,
            "adaptive {adaptive_len} !< static {static_len}"
        );
    }

    #[test]
    fn chunk_smaller_than_stream_tail() {
        let symbols = drifting_stream(10_048, 4);
        let cfg = AdaptiveConfig { chunk_symbols: 3000, ..Default::default() };
        let enc = encode(&cfg, &symbols);
        assert_eq!(decode(&cfg, &enc, symbols.len()).unwrap(), symbols);
    }

    #[test]
    fn empty_stream() {
        let cfg = AdaptiveConfig::default();
        assert!(encode(&cfg, &[]).is_empty());
        assert_eq!(decode(&cfg, &[], 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn truncated_stream_errors() {
        let symbols = drifting_stream(100_032, 5);
        let cfg = AdaptiveConfig::default();
        let enc = encode(&cfg, &symbols);
        assert!(decode(&cfg, &enc[..enc.len() / 2], symbols.len()).is_err());
    }

    #[test]
    fn prop_roundtrip_random_configs() {
        prop::check("adaptive roundtrip", prop::Config {
            cases: 24, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size);
            let cfg = AdaptiveConfig {
                chunk_symbols: 1 + rng.below(5000) as usize,
                scheme: if rng.uniform() < 0.5 {
                    AreaScheme::table1()
                } else {
                    AreaScheme::table2()
                },
                reoptimize_scheme: rng.uniform() < 0.5,
            };
            let enc = encode(&cfg, &symbols);
            let dec = decode(&cfg, &enc, symbols.len())
                .map_err(|e| e.to_string())?;
            if dec != symbols {
                return Err("adaptive roundtrip mismatch".into());
            }
            Ok(())
        });
    }
}
