//! Self-describing compressed frame container.
//!
//! Layout (little-endian):
//! ```text
//! magic "QLF1" | codec_tag u8 | reserved u8 | n_symbols u64 |
//! header_len u32 | header bytes… | payload bits…
//! ```
//! The header carries whatever tables the codec needs (Huffman code
//! lengths, QLC scheme + rank LUT, EG order…), so a frame decodes
//! without out-of-band state.  Used by the CLI (`qlc compress` /
//! `decompress`) and as the wire format of the collective transport.

use super::elias::{EliasCodec, EliasKind};
use super::expgolomb::ExpGolombCodec;
use super::huffman::HuffmanCodec;
use super::qlc::{self, QlcCodec};
use super::raw::RawCodec;
use super::{Codec, CodecError};
use crate::stats::Histogram;

pub const MAGIC: [u8; 4] = *b"QLF1";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Tag {
    Raw = 0,
    Huffman = 1,
    Qlc = 2,
    Gamma = 3,
    Delta = 4,
    Omega = 5,
    ExpGolomb = 6,
}

impl Tag {
    fn from_u8(v: u8) -> Option<Tag> {
        Some(match v {
            0 => Tag::Raw,
            1 => Tag::Huffman,
            2 => Tag::Qlc,
            3 => Tag::Gamma,
            4 => Tag::Delta,
            5 => Tag::Omega,
            6 => Tag::ExpGolomb,
            _ => return None,
        })
    }
}

/// A fully-specified codec instance that knows how to serialize its
/// tables into a frame header.
pub enum CodecSpec {
    Raw,
    Huffman(HuffmanCodec),
    Qlc(QlcCodec),
    Elias(EliasCodec, EliasKind),
    ExpGolomb(ExpGolombCodec, u32),
}

impl CodecSpec {
    /// Factory by codec name, fitting tables to `hist` where needed.
    /// Names: raw, huffman, qlc (optimized), qlc-t1, qlc-t2,
    /// elias-gamma, elias-delta, elias-omega, eg0…eg8.
    pub fn by_name(name: &str, hist: &Histogram) -> Result<CodecSpec, String> {
        Ok(match name {
            "raw" => CodecSpec::Raw,
            "huffman" => CodecSpec::Huffman(HuffmanCodec::from_histogram(hist)),
            "qlc" => {
                let pmf = hist.pmf();
                let scheme = qlc::optimize_scheme(&pmf.sorted_desc());
                CodecSpec::Qlc(QlcCodec::from_pmf(scheme, &pmf))
            }
            "qlc-t1" => CodecSpec::Qlc(QlcCodec::from_pmf(
                qlc::AreaScheme::table1(),
                &hist.pmf(),
            )),
            "qlc-t2" => CodecSpec::Qlc(QlcCodec::from_pmf(
                qlc::AreaScheme::table2(),
                &hist.pmf(),
            )),
            "elias-gamma" => {
                CodecSpec::Elias(EliasCodec::new(EliasKind::Gamma), EliasKind::Gamma)
            }
            "elias-delta" => {
                CodecSpec::Elias(EliasCodec::new(EliasKind::Delta), EliasKind::Delta)
            }
            "elias-omega" => {
                CodecSpec::Elias(EliasCodec::new(EliasKind::Omega), EliasKind::Omega)
            }
            _ => {
                if let Some(kstr) = name.strip_prefix("eg") {
                    let k: u32 = kstr
                        .parse()
                        .map_err(|_| format!("bad EG order in '{name}'"))?;
                    if k > 8 {
                        return Err(format!("EG order {k} > 8"));
                    }
                    CodecSpec::ExpGolomb(ExpGolombCodec::new(k), k)
                } else {
                    return Err(format!("unknown codec '{name}'"));
                }
            }
        })
    }

    /// All codec names usable with [`CodecSpec::by_name`].
    pub fn known_names() -> Vec<&'static str> {
        vec![
            "raw", "huffman", "qlc", "qlc-t1", "qlc-t2", "elias-gamma",
            "elias-delta", "elias-omega", "eg0", "eg3",
        ]
    }

    pub fn codec(&self) -> &dyn Codec {
        match self {
            CodecSpec::Raw => &RawCodec,
            CodecSpec::Huffman(c) => c,
            CodecSpec::Qlc(c) => c,
            CodecSpec::Elias(c, _) => c,
            CodecSpec::ExpGolomb(c, _) => c,
        }
    }

    fn tag(&self) -> Tag {
        match self {
            CodecSpec::Raw => Tag::Raw,
            CodecSpec::Huffman(_) => Tag::Huffman,
            CodecSpec::Qlc(_) => Tag::Qlc,
            CodecSpec::Elias(_, EliasKind::Gamma) => Tag::Gamma,
            CodecSpec::Elias(_, EliasKind::Delta) => Tag::Delta,
            CodecSpec::Elias(_, EliasKind::Omega) => Tag::Omega,
            CodecSpec::ExpGolomb(..) => Tag::ExpGolomb,
        }
    }

    fn header(&self) -> Vec<u8> {
        match self {
            CodecSpec::Raw | CodecSpec::Elias(..) => Vec::new(),
            CodecSpec::Huffman(c) => {
                c.code_lengths().iter().map(|&l| l as u8).collect()
            }
            CodecSpec::Qlc(c) => qlc::serde::to_bytes(c),
            CodecSpec::ExpGolomb(_, k) => vec![*k as u8],
        }
    }

    fn from_header(tag: Tag, header: &[u8]) -> Result<CodecSpec, CodecError> {
        let bad = |msg: String| CodecError::BadHeader(msg);
        Ok(match tag {
            Tag::Raw => CodecSpec::Raw,
            Tag::Gamma => {
                CodecSpec::Elias(EliasCodec::new(EliasKind::Gamma), EliasKind::Gamma)
            }
            Tag::Delta => {
                CodecSpec::Elias(EliasCodec::new(EliasKind::Delta), EliasKind::Delta)
            }
            Tag::Omega => {
                CodecSpec::Elias(EliasCodec::new(EliasKind::Omega), EliasKind::Omega)
            }
            Tag::Huffman => {
                if header.len() != 256 {
                    return Err(bad(format!(
                        "huffman header {} bytes",
                        header.len()
                    )));
                }
                let mut lengths = [0u32; 256];
                for (l, &b) in lengths.iter_mut().zip(header) {
                    *l = b as u32;
                }
                CodecSpec::Huffman(HuffmanCodec::from_lengths(&lengths)?)
            }
            Tag::Qlc => CodecSpec::Qlc(
                qlc::serde::from_bytes(header, "qlc").map_err(bad)?,
            ),
            Tag::ExpGolomb => {
                if header.len() != 1 || header[0] > 8 {
                    return Err(bad("bad EG header".into()));
                }
                CodecSpec::ExpGolomb(
                    ExpGolombCodec::new(header[0] as u32),
                    header[0] as u32,
                )
            }
        })
    }
}

/// Compress `symbols` into a self-describing frame.
pub fn compress(spec: &CodecSpec, symbols: &[u8]) -> Vec<u8> {
    let header = spec.header();
    let payload = spec.codec().encode_to_vec(symbols);
    let mut out =
        Vec::with_capacity(4 + 2 + 8 + 4 + header.len() + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(spec.tag() as u8);
    out.push(0); // reserved
    out.extend_from_slice(&(symbols.len() as u64).to_le_bytes());
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(&header);
    out.extend_from_slice(&payload);
    out
}

/// Decompress a frame produced by [`compress`].
pub fn decompress(data: &[u8]) -> Result<Vec<u8>, CodecError> {
    let bad = |msg: &str| CodecError::BadHeader(msg.to_string());
    if data.len() < 18 {
        return Err(bad("frame too short"));
    }
    if data[0..4] != MAGIC {
        return Err(bad("bad magic"));
    }
    let tag = Tag::from_u8(data[4]).ok_or_else(|| bad("unknown codec tag"))?;
    let n = u64::from_le_bytes(data[6..14].try_into().unwrap()) as usize;
    let hlen = u32::from_le_bytes(data[14..18].try_into().unwrap()) as usize;
    if data.len() < 18 + hlen {
        return Err(bad("truncated header"));
    }
    let header = &data[18..18 + hlen];
    let payload = &data[18 + hlen..];
    // Every code is ≥ 1 bit, so a frame that declares more symbols than
    // payload bits is corrupt.  (Without this bound a hostile header
    // could force a huge allocation before the first decode error.)
    if n > payload.len().saturating_mul(8) {
        return Err(bad("declared symbol count exceeds payload bits"));
    }
    let spec = CodecSpec::from_header(tag, header)?;
    spec.codec().decode_from_slice(payload, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::{AliasTable, Rng};

    fn skewed_symbols(n: usize, seed: u64) -> Vec<u8> {
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = (-0.025 * i as f64).exp();
        }
        let alias = AliasTable::new(&p);
        let mut rng = Rng::new(seed);
        alias.sample_many(&mut rng, n)
    }

    #[test]
    fn all_codecs_roundtrip_through_frames() {
        let symbols = skewed_symbols(20_000, 1);
        let hist = Histogram::from_symbols(&symbols);
        for name in CodecSpec::known_names() {
            let spec = CodecSpec::by_name(name, &hist).unwrap();
            let frame = compress(&spec, &symbols);
            let back = decompress(&frame).unwrap();
            assert_eq!(back, symbols, "codec {name}");
        }
    }

    #[test]
    fn frames_are_self_describing() {
        // Decode must not need the original histogram.
        let symbols = skewed_symbols(5_000, 2);
        let hist = Histogram::from_symbols(&symbols);
        let spec = CodecSpec::by_name("qlc", &hist).unwrap();
        let frame = compress(&spec, &symbols);
        drop(spec);
        drop(hist);
        assert_eq!(decompress(&frame).unwrap(), symbols);
    }

    #[test]
    fn compressed_smaller_than_raw_for_skewed_data() {
        let symbols = skewed_symbols(50_000, 3);
        let hist = Histogram::from_symbols(&symbols);
        let raw = compress(&CodecSpec::Raw, &symbols).len();
        for name in ["huffman", "qlc", "qlc-t1"] {
            let spec = CodecSpec::by_name(name, &hist).unwrap();
            let framed = compress(&spec, &symbols).len();
            assert!(framed < raw, "{name}: {framed} !< {raw}");
        }
    }

    #[test]
    fn corrupt_frames_rejected() {
        let symbols = skewed_symbols(1000, 4);
        let hist = Histogram::from_symbols(&symbols);
        let spec = CodecSpec::by_name("huffman", &hist).unwrap();
        let frame = compress(&spec, &symbols);

        let mut bad = frame.clone();
        bad[0] = b'X';
        assert!(matches!(decompress(&bad), Err(CodecError::BadHeader(_))));

        let mut bad = frame.clone();
        bad[4] = 200; // unknown tag
        assert!(decompress(&bad).is_err());

        let bad = &frame[..10];
        assert!(decompress(bad).is_err());

        // Truncated payload.
        let bad = &frame[..frame.len() - 10];
        assert!(decompress(bad).is_err());
    }

    #[test]
    fn unknown_codec_name_errors() {
        let hist = Histogram::from_symbols(&[1, 2, 3]);
        assert!(CodecSpec::by_name("zstd", &hist).is_err());
        assert!(CodecSpec::by_name("eg99", &hist).is_err());
    }

    #[test]
    fn empty_input_roundtrips() {
        let hist = Histogram::from_symbols(&[0]);
        for name in ["raw", "huffman", "qlc-t1", "elias-gamma", "eg0"] {
            let spec = CodecSpec::by_name(name, &hist).unwrap();
            let frame = compress(&spec, &[]);
            assert_eq!(decompress(&frame).unwrap(), Vec::<u8>::new(), "{name}");
        }
    }

    #[test]
    fn prop_frame_roundtrip_random_data() {
        prop::check("frame roundtrip", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size);
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = ["raw", "huffman", "qlc", "elias-delta", "eg2"];
            let name = names[rng.below(names.len() as u64) as usize];
            let spec = CodecSpec::by_name(name, &hist)
                .map_err(|e| e.to_string())?;
            let frame = compress(&spec, &symbols);
            let back = decompress(&frame).map_err(|e| e.to_string())?;
            if back != symbols {
                return Err(format!("{name} roundtrip"));
            }
            Ok(())
        });
    }
}
