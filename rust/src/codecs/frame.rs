//! Self-describing compressed frame container (formats QLF1 + QLF2).
//!
//! # QLF2 — chunked (current, read + write)
//!
//! ```text
//! magic "QLF2" | codec_tag u8 | flags u8 (0) | n_symbols u64 |
//! header_len u32 | header bytes… |
//! n_chunks u32 | n_chunks × { chunk_n_symbols u32 | payload_len u32 } |
//! chunk payloads… (each byte-aligned, independently decodable)
//! ```
//!
//! The codec table header is written **once**; the payload is split
//! into fixed-size symbol chunks (default 64 Ki symbols), each encoded
//! to its own byte-aligned payload.  Chunks share the codec tables but
//! no bitstream state, so encode and decode parallelize across cores —
//! `compress_with`/`decompress` fan chunks out over `std::thread`
//! scoped workers (one [`EncoderSession`]/[`DecoderSession`] per
//! worker; the crate has no rayon in its offline dependency set).
//! Chunk boundaries depend only on [`FrameOptions::chunk_symbols`],
//! never on the worker count, so frame bytes are deterministic.
//!
//! ## Adaptive per-chunk tables (frame flag bit 0)
//!
//! With [`FrameOptions::adaptive_chunks`] and a codec family that
//! supports per-chunk re-fit (QLC, via
//! [`ChunkTables`](super::registry::ChunkTables)), the encoder
//! measures each chunk's PMF and — when the drift past the frame's
//! base tables is worth more payload bits than the delta costs —
//! prefixes that chunk's payload with a serialized *table delta*
//! (`delta_len u16-le | delta bytes`; for QLC a bare 256-byte rank
//! order re-ranked under the frame's area scheme).  The chunk-table
//! entry marks such chunks by setting the top bit of
//! `chunk_n_symbols` (chunk sizes are capped at [`CHUNK_SYMBOL_CAP`],
//! far below it — the writer `Err`s rather than emit a colliding
//! count), and the
//! frame's flags byte sets bit 0 whenever any chunk carries a delta.
//! Chunks remain independently decodable — the delta travels *inside*
//! the chunk payload — so parallel decode is unaffected.
//!
//! # QLF1 — single payload (legacy, read + [`compress_qlf1`])
//!
//! ```text
//! magic "QLF1" | codec_tag u8 | reserved u8 | n_symbols u64 |
//! header_len u32 | header bytes… | payload bits…
//! ```
//!
//! [`decompress`] dispatches on the magic, so pre-QLF2 archives keep
//! decoding.  Both formats share wire tags and table-header layouts
//! via [`CodecRegistry`] — this module contains no per-codec dispatch
//! of its own.
//!
//! # Sharded tensors — QLM1 manifest + QLS1 shards
//!
//! One tensor can span N independently-placed shards that share a
//! single codec table via a [`ShardManifest`]:
//!
//! ```text
//! manifest: magic "QLM1" | codec_tag u8 | flags u8 (0) |
//!           total_symbols u64 | header_len u32 | header bytes… |
//!           n_shards u32 | n_shards × { shard_n_symbols u64 }
//! shard:    magic "QLS1" | shard_index u32 | n_symbols u64 |
//!           n_chunks u32 | chunk table (as QLF2) | chunk payloads…
//! ```
//!
//! Shards carry their own index, so [`decompress_sharded`] accepts
//! them in **any arrival order** — a coordinator can place one shard
//! per worker/NUMA node and reassemble whatever order they land in.
//! The table header is written exactly once (in the manifest), so N
//! shards cost N×16 bytes of framing instead of N table copies.

use super::kernel::{DecodeKernel, EncodeJob, LaneJob, MixedLaneJob};
use super::registry::{CodecHandle, CodecRegistry};
use super::session::{
    chunk_spans, DecodeMode, DecoderSession, EncodeMode, EncoderSession,
    DEFAULT_CHUNK_SYMBOLS,
};
use super::CodecError;
use crate::obs;

pub const MAGIC_QLF1: [u8; 4] = *b"QLF1";
pub const MAGIC_QLF2: [u8; 4] = *b"QLF2";

/// QLF2 flags bit 0: at least one chunk carries a per-chunk table
/// delta (see the module docs).
pub const FLAG_ADAPTIVE_CHUNKS: u8 = 1;
/// Top bit of a chunk-table `chunk_n_symbols` entry: this chunk's
/// payload starts with `delta_len u16-le | delta bytes`.  Chunk sizes
/// are capped at [`CHUNK_SYMBOL_CAP`], so the bit can never be a
/// count.
const CHUNK_DELTA_BIT: u32 = 1 << 31;
/// Hard cap on a single chunk's symbol count, enforced on **both**
/// sides of the wire: the decoder rejects larger counts, and the
/// encoder both clamps its chunking to it and `Err`s if a chunk ever
/// reaches [`write_chunk_table`] above it (a larger count's bits would
/// collide with [`CHUNK_DELTA_BIT`], and a worst-case < 64-bit/symbol
/// payload would overflow the u32 length field).
pub const CHUNK_SYMBOL_CAP: usize = (u32::MAX / 8) as usize;
/// Shard-set manifest: one codec table header shared by N shards.
pub const MAGIC_MANIFEST: [u8; 4] = *b"QLM1";
/// One shard of a sharded tensor: chunk table + payloads, no codec
/// header (that lives in the manifest).
pub const MAGIC_SHARD: [u8; 4] = *b"QLS1";

/// Fixed prefix shared by both formats: magic, tag, flags, n, hlen.
const FIXED_HEADER: usize = 4 + 1 + 1 + 8 + 4;

/// Knobs for chunked frame I/O.
#[derive(Clone, Copy, Debug)]
pub struct FrameOptions {
    /// Symbols per chunk (QLF2).  Smaller chunks → more parallelism
    /// and more per-chunk overhead (8 table bytes + final-byte pad).
    pub chunk_symbols: usize,
    /// Worker threads; 0 = one per available core, 1 = serial.
    pub threads: usize,
    /// Re-fit codec tables per chunk when the chunk's PMF drifts past
    /// the break-even point (QLF2 write path; needs a codec family
    /// with [`ChunkTables`](super::registry::ChunkTables) support —
    /// silently ignored otherwise).
    pub adaptive_chunks: bool,
    /// Which decode path chunk decoding runs: the batched kernel by
    /// default, lane-interleaved multi-cursor lockstep
    /// ([`DecodeMode::Lanes`] — independent chunks within a worker
    /// band decode together), or scalar for the reference comparison.
    pub decode: DecodeMode,
    /// Which encode path chunk encoding runs: the batched
    /// staging-word kernel by default, lane-interleaved lockstep
    /// ([`EncodeMode::Lanes`] — independent chunks within a worker
    /// band encode together), or scalar for the reference comparison.
    /// Every mode writes bit-for-bit identical frames.
    pub encode: EncodeMode,
}

impl Default for FrameOptions {
    fn default() -> Self {
        FrameOptions {
            chunk_symbols: DEFAULT_CHUNK_SYMBOLS,
            threads: 0,
            adaptive_chunks: false,
            decode: DecodeMode::Batched,
            encode: EncodeMode::Batched,
        }
    }
}

impl FrameOptions {
    /// Serial processing (inside worker pools that already own their
    /// parallelism, e.g. the coordinator pipeline).
    pub fn serial() -> Self {
        FrameOptions { threads: 1, ..Default::default() }
    }
}

fn effective_threads(requested: usize, jobs: usize) -> usize {
    let hw = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    hw.min(jobs).max(1)
}

/// Run `work` over contiguous bands of `jobs` on up to `threads`
/// scoped workers (serial when `threads <= 1`).  Each invocation of
/// `work` gets one band and typically amortizes one codec session
/// across it.  Band assignment never affects results: every job
/// carries its own destination.  Returns the first error.
fn run_banded<J, E, F>(jobs: Vec<J>, threads: usize, work: F) -> Result<(), E>
where
    J: Send,
    E: Send,
    F: Fn(Vec<J>) -> Result<(), E> + Sync,
{
    if threads <= 1 {
        return work(jobs);
    }
    let per_band = (jobs.len() + threads - 1) / threads;
    let results = std::thread::scope(|s| {
        let work = &work;
        let mut workers = Vec::with_capacity(threads);
        let mut jobs = jobs;
        while !jobs.is_empty() {
            let band = jobs.split_off(jobs.len().saturating_sub(per_band));
            workers.push(s.spawn(move || work(band)));
        }
        workers
            .into_iter()
            // lint: infallible(join fails only when a worker panicked;
            // re-raising that panic on the caller is the contract)
            .map(|w| w.join().expect("frame worker panicked"))
            .collect::<Vec<_>>()
    });
    results.into_iter().collect()
}

// ---------------------------------------------------------------------------
// Encode

/// Compress `symbols` into a chunked QLF2 frame with default options.
pub fn compress(
    handle: &CodecHandle,
    symbols: &[u8],
) -> Result<Vec<u8>, CodecError> {
    compress_with(handle, symbols, &FrameOptions::default())
}

/// Encode `symbols` into per-chunk byte-aligned payloads, fanning the
/// chunks out over scoped workers.  Shared by the QLF2 writer and the
/// shard writer; chunk boundaries come from
/// [`chunk_spans`](super::chunk_spans), so frame chunks, shard chunks
/// and transport chunks all agree.
///
/// With `adaptive`, chunks whose PMF drifts past the base tables'
/// break-even point are re-encoded with a chunk-local re-fit and their
/// payload prefixed by the serialized delta; the returned flags mark
/// those chunks for the chunk table.
fn encode_payload_chunks<'a>(
    handle: &CodecHandle,
    symbols: &'a [u8],
    opts: &FrameOptions,
    adaptive: bool,
) -> (Vec<&'a [u8]>, Vec<Vec<u8>>, Vec<bool>) {
    // Chunk-table fields are u32; the deepest code in the crate is
    // < 64 bits/symbol, so capping chunks at [`CHUNK_SYMBOL_CAP`]
    // symbols keeps both the symbol count and the worst-case payload
    // length in range (and leaves the top bit free for
    // [`CHUNK_DELTA_BIT`]); [`write_chunk_table`] re-checks the cap
    // and `Err`s rather than emit a colliding count.  The lower bound
    // keeps the chunk *count* in its u32 field too (only binds past
    // 4 Gi symbols of 1-symbol chunks).
    let min_chunk = symbols.len() / u32::MAX as usize + 1;
    let chunk_symbols = opts
        .chunk_symbols
        .clamp(min_chunk.min(CHUNK_SYMBOL_CAP), CHUNK_SYMBOL_CAP)
        .max(1);
    let chunks: Vec<&[u8]> = chunk_spans(symbols.len(), chunk_symbols)
        .into_iter()
        .map(|(a, b)| &symbols[a..b])
        .collect();
    assert!(chunks.len() <= u32::MAX as usize, "chunk count overflows u32");
    let threads = effective_threads(opts.threads, chunks.len());
    let tables = if adaptive { handle.chunk_tables() } else { None };

    let mut payloads: Vec<Vec<u8>> = vec![Vec::new(); chunks.len()];
    let mut deltas: Vec<bool> = vec![false; chunks.len()];
    let jobs: Vec<(&[u8], &mut Vec<u8>, &mut bool)> = chunks
        .iter()
        .copied()
        .zip(payloads.iter_mut())
        .zip(deltas.iter_mut())
        .map(|((c, p), d)| (c, p, d))
        .collect();
    let encode_ok: Result<(), std::convert::Infallible> =
        run_banded(jobs, threads, |band| {
            let _sp = obs::span("frame.encode_band")
                .arg("chunks", band.len())
                .arg("mode", opts.encode.name());
            let lane_chunks =
                obs::global().counter("frame_encode_lane_chunks_total");
            let solo_chunks =
                obs::global().counter("frame_encode_solo_chunks_total");
            let mut enc = handle.encoder_with(opts.encode);
            // Under lane mode, fixed-table chunks of the band collect
            // into one lockstep group (mirror of `decode_band_lanes`);
            // each table-delta chunk encodes through its own
            // chunk-local codec.  Payload bytes are mode-independent.
            let mut fixed: Vec<EncodeJob<'_, '_>> = Vec::new();
            for (chunk, slot, delta_slot) in band {
                if let Some((delta, codec)) =
                    tables.and_then(|t| t.refit(chunk))
                {
                    debug_assert!(delta.len() <= u16::MAX as usize);
                    let mut out =
                        Vec::with_capacity(2 + delta.len() + chunk.len());
                    out.extend_from_slice(
                        &(delta.len() as u16).to_le_bytes(),
                    );
                    out.extend_from_slice(&delta);
                    EncoderSession::with_mode(codec.as_ref(), opts.encode)
                        .encode_chunk(chunk, &mut out);
                    *slot = out;
                    *delta_slot = true;
                    solo_chunks.inc();
                } else if opts.encode == EncodeMode::Lanes {
                    fixed.push(EncodeJob { symbols: chunk, out: slot });
                } else {
                    *slot = enc.encode_chunk_to_vec(chunk);
                    solo_chunks.inc();
                }
            }
            lane_chunks.add(fixed.len() as u64);
            enc.encode_chunk_group(&mut fixed);
            Ok(())
        });
    encode_ok.unwrap(); // lint: infallible(the error type is Infallible)
    (chunks, payloads, deltas)
}

/// Append `n_chunks | chunk table | payloads` (the shared QLF2/QLS1
/// body layout) to `out`.  `counts[i]` is chunk `i`'s symbol count;
/// `deltas[i]` sets [`CHUNK_DELTA_BIT`] on it.
///
/// Enforces the decode-side caps at encode time: a chunk whose symbol
/// count exceeds [`CHUNK_SYMBOL_CAP`] (its bits would collide with the
/// adaptive-delta flag bit the decoder tests) or whose payload
/// overflows the u32 length field is an `Err`, never a silently
/// corrupted table.  On `Err`, `out` may hold a partial table and must
/// be discarded.
fn write_chunk_table(
    out: &mut Vec<u8>,
    counts: &[usize],
    payloads: &[Vec<u8>],
    deltas: &[bool],
) -> Result<(), CodecError> {
    out.extend_from_slice(&(payloads.len() as u32).to_le_bytes());
    for ((&n_symbols, payload), &delta) in
        counts.iter().zip(payloads).zip(deltas)
    {
        if n_symbols > CHUNK_SYMBOL_CAP {
            return Err(CodecError::BadHeader(format!(
                "chunk of {n_symbols} symbols exceeds the QLF2 chunk cap \
                 {CHUNK_SYMBOL_CAP} (the count field's top bit is the \
                 chunk-table delta flag)"
            )));
        }
        if payload.len() > u32::MAX as usize {
            return Err(CodecError::BadHeader(format!(
                "chunk payload of {} bytes overflows the u32 length field",
                payload.len()
            )));
        }
        let mut n = n_symbols as u32;
        if delta {
            n |= CHUNK_DELTA_BIT;
        }
        out.extend_from_slice(&n.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    }
    for payload in payloads {
        out.extend_from_slice(payload);
    }
    Ok(())
}

/// Compress `symbols` into a chunked QLF2 frame.  `Err` only when a
/// chunk would overflow the chunk-table fields (see
/// [`CHUNK_SYMBOL_CAP`]) — unreachable through the clamped chunking,
/// enforced anyway so the cap can never silently rot.
pub fn compress_with(
    handle: &CodecHandle,
    symbols: &[u8],
    opts: &FrameOptions,
) -> Result<Vec<u8>, CodecError> {
    let _sp = obs::span("frame.compress")
        .arg("codec", handle.codec().name())
        .arg("symbols", symbols.len());
    let (chunks, payloads, deltas) =
        encode_payload_chunks(handle, symbols, opts, opts.adaptive_chunks);
    let counts: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
    let header = handle.wire_header();
    let payload_bytes: usize = payloads.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(
        FIXED_HEADER + header.len() + 4 + payloads.len() * 8 + payload_bytes,
    );
    out.extend_from_slice(&MAGIC_QLF2);
    out.push(handle.wire_tag());
    // The flag is set only when a delta is actually present, so
    // non-drifting adaptive frames stay byte-identical to fixed-table
    // frames (and older readers keep accepting them).
    let flags = if deltas.iter().any(|&d| d) {
        FLAG_ADAPTIVE_CHUNKS
    } else {
        0
    };
    out.push(flags);
    out.extend_from_slice(&(symbols.len() as u64).to_le_bytes());
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header);
    write_chunk_table(&mut out, &counts, &payloads, &deltas)?;
    Ok(out)
}

/// Compress `symbols` into a chunked QLF2 frame with per-chunk
/// adaptive tables enabled (the CLI's `--adaptive-chunks`).
pub fn compress_adaptive(
    handle: &CodecHandle,
    symbols: &[u8],
    opts: &FrameOptions,
) -> Result<Vec<u8>, CodecError> {
    let opts = FrameOptions { adaptive_chunks: true, ..*opts };
    compress_with(handle, symbols, &opts)
}

/// Compress `symbols` into a legacy single-payload QLF1 frame.
/// Kept for interoperability with pre-chunking consumers (and to
/// exercise the QLF1 read path); new code should use [`compress`].
pub fn compress_qlf1(handle: &CodecHandle, symbols: &[u8]) -> Vec<u8> {
    let header = handle.wire_header();
    let payload = handle.codec().encode_to_vec(symbols);
    debug_assert!(header.len() <= u32::MAX as usize);
    // lint: cap-checked(sized by this encoder's own output, not wire input)
    let mut out =
        Vec::with_capacity(FIXED_HEADER + header.len() + payload.len());
    out.extend_from_slice(&MAGIC_QLF1);
    out.push(handle.wire_tag());
    out.push(0); // reserved
    out.extend_from_slice(&(symbols.len() as u64).to_le_bytes());
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header);
    out.extend_from_slice(&payload);
    out
}

// ---------------------------------------------------------------------------
// Decode

/// Decompress a QLF1 or QLF2 frame (dispatch on magic).
pub fn decompress(data: &[u8]) -> Result<Vec<u8>, CodecError> {
    decompress_with(data, &FrameOptions::default())
}

/// Decompress with explicit threading options.
pub fn decompress_with(
    data: &[u8],
    opts: &FrameOptions,
) -> Result<Vec<u8>, CodecError> {
    let _sp = obs::span("frame.decompress").arg("bytes", data.len());
    let bad = |msg: &str| CodecError::BadHeader(msg.to_string());
    if data.len() < FIXED_HEADER {
        return Err(bad("frame too short"));
    }
    // lint: infallible(fixed slices of the FIXED_HEADER-checked prefix)
    let magic: [u8; 4] = data[0..4].try_into().unwrap();
    let tag = data[4];
    let n = u64::from_le_bytes(data[6..14].try_into().unwrap());
    if n > usize::MAX as u64 {
        return Err(bad("declared symbol count exceeds address space"));
    }
    let n = n as usize;
    // lint: infallible(fixed 4-byte slice of the checked prefix)
    let hlen = u32::from_le_bytes(data[14..18].try_into().unwrap()) as usize;
    if data.len() - FIXED_HEADER < hlen {
        return Err(bad("truncated header"));
    }
    let header = &data[FIXED_HEADER..FIXED_HEADER + hlen];
    let body = &data[FIXED_HEADER + hlen..];
    match magic {
        MAGIC_QLF1 => decompress_qlf1_body(tag, n, header, body, opts),
        MAGIC_QLF2 => {
            if data[5] & !FLAG_ADAPTIVE_CHUNKS != 0 {
                return Err(bad("unsupported QLF2 flags"));
            }
            let adaptive = data[5] & FLAG_ADAPTIVE_CHUNKS != 0;
            decompress_qlf2_body(tag, n, header, body, opts, adaptive)
        }
        _ => Err(bad("bad magic")),
    }
}

fn decompress_qlf1_body(
    tag: u8,
    n: usize,
    header: &[u8],
    payload: &[u8],
    opts: &FrameOptions,
) -> Result<Vec<u8>, CodecError> {
    // Every code is ≥ 1 bit, so a frame that declares more symbols than
    // payload bits is corrupt.  (Without this bound a hostile header
    // could force a huge allocation before the first decode error.)
    if n as u64 > payload.len() as u64 * 8 {
        return Err(CodecError::BadHeader(
            "declared symbol count exceeds payload bits".into(),
        ));
    }
    let handle = CodecRegistry::global().resolve_wire(tag, header)?;
    handle.decoder_with(opts.decode).decode_chunk_to_vec(payload, n)
}

/// Parse and validate a `n_chunks | chunk table | payloads` body
/// against `n` expected symbols.  Returns per-chunk
/// `(n_symbols, payload_len, has_delta)` entries and the payload
/// area; the sums are checked **before** anything is allocated in
/// proportion to them.  [`CHUNK_DELTA_BIT`] entries are only accepted
/// when `adaptive` (i.e. the frame's flags byte announced them).
fn parse_chunk_table(
    n: usize,
    body: &[u8],
    adaptive: bool,
) -> Result<(Vec<(usize, usize, bool)>, &[u8]), CodecError> {
    let bad = |msg: &str| CodecError::BadHeader(msg.to_string());
    if body.len() < 4 {
        return Err(bad("truncated chunk count"));
    }
    // lint: infallible(4-byte slice; body.len() >= 4 checked above)
    let n_chunks =
        u32::from_le_bytes(body[0..4].try_into().unwrap()) as usize;
    let table = &body[4..];
    // The chunk table must fit in the frame before anything is
    // allocated in proportion to it.
    if table.len() / 8 < n_chunks {
        return Err(bad("truncated chunk table"));
    }
    let (table, payload_area) = table.split_at(n_chunks * 8);

    let mut total_symbols = 0u64;
    let mut total_payload = 0u64;
    let mut entries = Vec::with_capacity(n_chunks);
    for e in table.chunks_exact(8) {
        // lint: infallible(chunks_exact(8) yields 8-byte entries)
        let raw_n = u32::from_le_bytes(e[0..4].try_into().unwrap());
        let has_delta = raw_n & CHUNK_DELTA_BIT != 0;
        if has_delta && !adaptive {
            return Err(bad("chunk delta bit set in a non-adaptive frame"));
        }
        let chunk_n = (raw_n & !CHUNK_DELTA_BIT) as usize;
        // The encoder never emits counts past the cap (see
        // [`CHUNK_SYMBOL_CAP`] / [`write_chunk_table`]); a larger
        // count can only come from a corrupt or hostile table.
        if chunk_n > CHUNK_SYMBOL_CAP {
            return Err(bad("chunk symbol count exceeds the chunk cap"));
        }
        // lint: infallible(4-byte slice of an 8-byte table entry)
        let plen = u32::from_le_bytes(e[4..8].try_into().unwrap()) as usize;
        // Per-chunk sanity: ≥ 1 bit per symbol.
        if chunk_n as u64 > plen as u64 * 8 {
            return Err(bad("chunk symbol count exceeds chunk payload bits"));
        }
        total_symbols += chunk_n as u64;
        total_payload += plen as u64;
        entries.push((chunk_n, plen, has_delta));
    }
    if total_symbols != n as u64 {
        return Err(bad("chunk table does not sum to frame symbol count"));
    }
    if total_payload != payload_area.len() as u64 {
        return Err(bad("chunk table does not sum to payload length"));
    }
    Ok((entries, payload_area))
}

/// Carve validated `(payload, destination, has_delta)` triples and
/// append them to `jobs`, consuming `out_rest` one chunk at a time.
/// Requires the invariants [`parse_chunk_table`] established.
fn carve_chunk_jobs<'a>(
    entries: &[(usize, usize, bool)],
    payload_area: &'a [u8],
    out_rest: &mut &'a mut [u8],
    jobs: &mut Vec<(&'a [u8], &'a mut [u8], bool)>,
) {
    let mut payload_rest = payload_area;
    for &(chunk_n, plen, has_delta) in entries {
        let (payload, ptail) = payload_rest.split_at(plen);
        payload_rest = ptail;
        let (dst, otail) = std::mem::take(out_rest).split_at_mut(chunk_n);
        *out_rest = otail;
        jobs.push((payload, dst, has_delta));
    }
}

/// Split a delta-carrying chunk payload into
/// `(delta bytes, encoded payload)`.
fn split_chunk_delta(payload: &[u8]) -> Result<(&[u8], &[u8]), CodecError> {
    let bad = |msg: &str| CodecError::BadHeader(msg.to_string());
    if payload.len() < 2 {
        return Err(bad("chunk too short for its table delta length"));
    }
    // lint: infallible(2-byte slice; payload.len() >= 2 checked above)
    let dlen = u16::from_le_bytes(payload[0..2].try_into().unwrap()) as usize;
    if payload.len() - 2 < dlen {
        return Err(bad("chunk too short for its table delta"));
    }
    Ok(payload[2..].split_at(dlen))
}

/// Decode carved chunk jobs on up to `threads_req` scoped workers.
/// Delta-carrying chunks rebuild their chunk-local codec via the
/// handle's [`ChunkTables`](super::registry::ChunkTables) hooks.
/// Under [`DecodeMode::Lanes`] each worker's band is scheduled through
/// [`decode_band_lanes`] instead of chunk-after-chunk.
fn decode_chunk_jobs(
    handle: &CodecHandle,
    jobs: Vec<(&[u8], &mut [u8], bool)>,
    opts: &FrameOptions,
) -> Result<(), CodecError> {
    let threads = effective_threads(opts.threads, jobs.len());
    let mode = opts.decode;
    run_banded(jobs, threads, |band| {
        let _sp = obs::span("frame.decode_band")
            .arg("chunks", band.len())
            .arg("mode", mode.name());
        let mut dec = handle.decoder_with(mode);
        if mode == DecodeMode::Lanes {
            return decode_band_lanes(handle, &mut dec, band);
        }
        let solo_chunks =
            obs::global().counter("frame_decode_solo_chunks_total");
        for (payload, dst, has_delta) in band {
            if has_delta {
                let (rest, chunk_codec) =
                    rebuild_delta_codec(handle, payload)?;
                DecoderSession::with_mode(chunk_codec.as_ref(), mode)
                    .decode_chunk(rest, dst)?;
            } else {
                dec.decode_chunk(payload, dst)?;
            }
            solo_chunks.inc();
        }
        Ok(())
    })
}

/// Rebuild a delta chunk's chunk-local codec from the delta its
/// payload starts with; returns the codec plus the encoded remainder.
fn rebuild_delta_codec<'a>(
    handle: &CodecHandle,
    payload: &'a [u8],
) -> Result<(&'a [u8], Box<dyn super::Codec>), CodecError> {
    let tables = handle.chunk_tables().ok_or_else(|| {
        CodecError::BadHeader(
            "chunk table delta for a codec without per-chunk tables".into(),
        )
    })?;
    let (delta, rest) = split_chunk_delta(payload)?;
    let chunk_codec = tables.from_delta(delta)?;
    Ok((rest, chunk_codec))
}

/// Lane-mode decode of one worker band.
///
/// A band with no table-delta chunks runs through the homogeneous lane
/// engine (one shared table pointer, full-group AVX2 peeks).  A band
/// that mixes adaptive table-delta chunks with fixed-table chunks
/// rebuilds each delta chunk's codec via
/// [`ChunkTables`](super::registry::ChunkTables) and schedules the
/// *whole* band as mixed lockstep groups ([`MixedLaneJob`], per-lane
/// table pointers): delta chunks of a QLC frame share the frame's
/// [`AreaScheme`](super::qlc::AreaScheme) — same `max_code_bits` — so
/// they join the same burst rounds instead of falling back to
/// single-cursor decode.
fn decode_band_lanes<'p, 'o>(
    handle: &CodecHandle,
    dec: &mut DecoderSession<'_>,
    band: Vec<(&'p [u8], &'o mut [u8], bool)>,
) -> Result<(), CodecError> {
    if band.iter().all(|(_, _, has_delta)| !has_delta) {
        obs::global()
            .counter("frame_decode_lane_chunks_total")
            .add(band.len() as u64);
        let mut fixed: Vec<LaneJob<'p, 'o>> = band
            .into_iter()
            .map(|(payload, out, _)| LaneJob { payload, out })
            .collect();
        return dec.decode_chunk_group(&mut fixed);
    }
    obs::global()
        .counter("frame_decode_mixed_chunks_total")
        .add(band.len() as u64);
    // Rebuild the chunk-local codecs first (kept alive in `codecs` for
    // the lifetime of the lane group), splitting each delta payload
    // into delta bytes and encoded remainder.
    let mut rests: Vec<&'p [u8]> = Vec::with_capacity(band.len());
    let mut codecs: Vec<Option<Box<dyn super::Codec>>> =
        Vec::with_capacity(band.len());
    for (payload, _, has_delta) in &band {
        if *has_delta {
            let (rest, chunk_codec) = rebuild_delta_codec(handle, payload)?;
            rests.push(rest);
            codecs.push(Some(chunk_codec));
        } else {
            rests.push(payload);
            codecs.push(None);
        }
    }
    let frame_kernel: &dyn DecodeKernel = handle.codec();
    let mut jobs: Vec<MixedLaneJob<'_, 'o, '_>> = band
        .into_iter()
        .enumerate()
        .map(|(i, (_, out, _))| MixedLaneJob {
            payload: rests[i],
            out,
            kernel: codecs[i]
                .as_deref()
                .map_or(frame_kernel, |c| c as &dyn DecodeKernel),
        })
        .collect();
    dec.decode_chunk_group_mixed(&mut jobs)
}

fn decompress_qlf2_body(
    tag: u8,
    n: usize,
    header: &[u8],
    body: &[u8],
    opts: &FrameOptions,
    adaptive: bool,
) -> Result<Vec<u8>, CodecError> {
    let (entries, payload_area) = parse_chunk_table(n, body, adaptive)?;
    let handle = CodecRegistry::global().resolve_wire(tag, header)?;
    // lint: cap-checked(parse_chunk_table bounds n and the entry count
    // against the actual body length before returning)
    let mut out = vec![0u8; n];
    let mut jobs: Vec<(&[u8], &mut [u8], bool)> =
        Vec::with_capacity(entries.len());
    let mut out_rest: &mut [u8] = &mut out;
    carve_chunk_jobs(&entries, payload_area, &mut out_rest, &mut jobs);
    decode_chunk_jobs(&handle, jobs, opts)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Sharded tensors: QLM1 manifest + QLS1 shards

/// Fixed prefix of a shard: magic, shard_index u32, n_symbols u64.
const SHARD_FIXED: usize = 4 + 4 + 8;
/// Fixed prefix of a manifest: magic, tag, flags, total u64, hlen u32.
const MANIFEST_FIXED: usize = 4 + 1 + 1 + 8 + 4;

/// Where one shard's symbols live in the reassembled tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardDesc {
    pub index: usize,
    /// First symbol of the shard in the whole tensor.
    pub start: usize,
    pub n_symbols: usize,
}

/// The shared half of a sharded tensor: codec identity (tag + table
/// header, written once for all shards) plus the per-shard symbol
/// counts.  Coordinators ship this to every consumer and place the
/// [`ShardDesc`]s on workers; shards then travel independently and
/// reassemble in any arrival order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardManifest {
    tag: u8,
    header: Vec<u8>,
    shard_symbols: Vec<u64>,
}

impl ShardManifest {
    /// Build a manifest from a codec's wire identity (tag + serialized
    /// table header) — for callers that hold the identity without a
    /// live [`CodecHandle`], e.g. a coordinator leader.
    pub fn new(
        tag: u8,
        header: Vec<u8>,
        shard_symbols: Vec<u64>,
    ) -> ShardManifest {
        ShardManifest { tag, header, shard_symbols }
    }

    /// Build a manifest for `shard_symbols.len()` shards encoded with
    /// `handle`'s codec.
    pub fn from_handle(
        handle: &CodecHandle,
        shard_symbols: Vec<u64>,
    ) -> ShardManifest {
        ShardManifest::new(
            handle.wire_tag(),
            handle.wire_header().to_vec(),
            shard_symbols,
        )
    }

    pub fn n_shards(&self) -> usize {
        self.shard_symbols.len()
    }

    pub fn total_symbols(&self) -> u64 {
        self.shard_symbols.iter().sum()
    }

    pub fn shard_symbols(&self) -> &[u64] {
        &self.shard_symbols
    }

    pub fn codec_tag(&self) -> u8 {
        self.tag
    }

    pub fn wire_header(&self) -> &[u8] {
        &self.header
    }

    /// Reconstruct the shared codec from the manifest's wire identity.
    pub fn resolve(&self) -> Result<CodecHandle, CodecError> {
        CodecRegistry::global().resolve_wire(self.tag, &self.header)
    }

    /// Placement descriptors, in shard-index order.
    pub fn descriptors(&self) -> Vec<ShardDesc> {
        let mut start = 0usize;
        self.shard_symbols
            .iter()
            .enumerate()
            .map(|(index, &n)| {
                let d = ShardDesc { index, start, n_symbols: n as usize };
                start += n as usize;
                d
            })
            .collect()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        debug_assert!(self.header.len() <= u32::MAX as usize);
        debug_assert!(self.shard_symbols.len() <= u32::MAX as usize);
        let mut out = Vec::with_capacity(
            MANIFEST_FIXED + self.header.len() + 4 + self.shard_symbols.len() * 8,
        );
        out.extend_from_slice(&MAGIC_MANIFEST);
        out.push(self.tag);
        out.push(0); // flags
        out.extend_from_slice(&self.total_symbols().to_le_bytes());
        out.extend_from_slice(&(self.header.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.header);
        out.extend_from_slice(&(self.shard_symbols.len() as u32).to_le_bytes());
        // lint: loop-capped(iterates the in-memory shard table; the
        // bound is the Vec's own length, not a wire value)
        for &n in &self.shard_symbols {
            out.extend_from_slice(&n.to_le_bytes());
        }
        out
    }

    /// Parse and validate a serialized manifest.  All counts are
    /// bounds-checked against the buffer before any allocation sized
    /// by them.
    pub fn parse(data: &[u8]) -> Result<ShardManifest, CodecError> {
        let bad = |msg: &str| CodecError::BadHeader(msg.to_string());
        if data.len() < MANIFEST_FIXED {
            return Err(bad("manifest too short"));
        }
        if data[0..4] != MAGIC_MANIFEST {
            return Err(bad("bad manifest magic"));
        }
        let tag = data[4];
        if data[5] != 0 {
            return Err(bad("unsupported manifest flags"));
        }
        // lint: infallible(fixed 8-byte slice of the checked prefix)
        let total = u64::from_le_bytes(data[6..14].try_into().unwrap());
        if total > usize::MAX as u64 {
            return Err(bad("declared symbol count exceeds address space"));
        }
        // lint: infallible(fixed 4-byte slice of the checked prefix)
        let hlen =
            u32::from_le_bytes(data[14..18].try_into().unwrap()) as usize;
        let rest = &data[MANIFEST_FIXED..];
        if rest.len() < hlen {
            return Err(bad("truncated manifest header"));
        }
        let (header, rest) = rest.split_at(hlen);
        if rest.len() < 4 {
            return Err(bad("truncated shard count"));
        }
        // lint: infallible(4-byte slice; rest.len() >= 4 checked above)
        let n_shards =
            u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
        let table = &rest[4..];
        // Exact length: a truncated table and trailing garbage are both
        // corruption (same strictness as the QLF2 chunk table).
        if table.len() / 8 < n_shards {
            return Err(bad("truncated shard table"));
        }
        if table.len() != n_shards * 8 {
            return Err(bad("trailing bytes after shard table"));
        }
        let mut shard_symbols = Vec::with_capacity(n_shards);
        let mut sum = 0u64;
        for e in table[..n_shards * 8].chunks_exact(8) {
            // lint: infallible(chunks_exact(8) yields 8-byte entries)
            let n = u64::from_le_bytes(e.try_into().unwrap());
            sum = sum
                .checked_add(n)
                .ok_or_else(|| bad("shard symbol counts overflow"))?;
            shard_symbols.push(n);
        }
        if sum != total {
            return Err(bad("shard table does not sum to total symbols"));
        }
        Ok(ShardManifest { tag, header: header.to_vec(), shard_symbols })
    }
}

/// Split `total` symbols into up to `n_shards` contiguous near-equal
/// shards.  Tiny inputs may yield fewer (never empty) shards; an empty
/// input yields one empty shard so a manifest always describes at
/// least one placement unit.
pub fn shard_plan(total: usize, n_shards: usize) -> Vec<ShardDesc> {
    let k = n_shards.max(1);
    if total == 0 {
        return vec![ShardDesc { index: 0, start: 0, n_symbols: 0 }];
    }
    let per = (total + k - 1) / k;
    chunk_spans(total, per)
        .into_iter()
        .enumerate()
        .map(|(index, (a, b))| ShardDesc {
            index,
            start: a,
            n_symbols: b - a,
        })
        .collect()
}

/// Compress one shard body (QLS1): chunk table + payloads, no codec
/// header.  `symbols` must be exactly the shard's slice.  Shards have
/// no flags byte to announce deltas, so the adaptive-chunk path is
/// QLF2-only.
pub fn compress_shard(
    handle: &CodecHandle,
    shard_index: u32,
    symbols: &[u8],
    opts: &FrameOptions,
) -> Result<Vec<u8>, CodecError> {
    let (chunks, payloads, deltas) =
        encode_payload_chunks(handle, symbols, opts, false);
    let counts: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
    let payload_bytes: usize = payloads.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(
        SHARD_FIXED + 4 + payloads.len() * 8 + payload_bytes,
    );
    out.extend_from_slice(&MAGIC_SHARD);
    out.extend_from_slice(&shard_index.to_le_bytes());
    out.extend_from_slice(&(symbols.len() as u64).to_le_bytes());
    write_chunk_table(&mut out, &counts, &payloads, &deltas)?;
    Ok(out)
}

/// Compress `symbols` into `n_shards` independently-decodable shards
/// plus the manifest that ties them together.  Shards are encoded in
/// parallel over scoped workers; bytes are deterministic (boundaries
/// depend only on the plan and `opts.chunk_symbols`).
pub fn compress_sharded(
    handle: &CodecHandle,
    symbols: &[u8],
    n_shards: usize,
    opts: &FrameOptions,
) -> Result<(ShardManifest, Vec<Vec<u8>>), CodecError> {
    let plan = shard_plan(symbols.len(), n_shards);
    // The shard header's index field is u32; a plan only grows past it
    // on > 4 Gi-symbol inputs split into > 4 Gi shards, but truncating
    // there would scatter shards onto colliding indices.
    if plan.len() > u32::MAX as usize {
        return Err(CodecError::BadHeader(format!(
            "{} shards overflow the u32 shard-index field",
            plan.len()
        )));
    }
    // lint: cap-checked(one slot per planned shard; plan.len() is
    // bounded by the symbol count and checked against u32::MAX above)
    let mut bodies: Vec<Vec<u8>> = vec![Vec::new(); plan.len()];
    let jobs: Vec<(ShardDesc, &mut Vec<u8>)> =
        plan.iter().copied().zip(bodies.iter_mut()).collect();
    let threads = effective_threads(opts.threads, jobs.len());
    let serial = FrameOptions { threads: 1, ..*opts };
    run_banded(jobs, threads, |band| {
        for (desc, slot) in band {
            *slot = compress_shard(
                handle,
                // lint: cast-checked(plan.len() <= u32::MAX is enforced
                // above, and every index is < plan.len())
                desc.index as u32,
                // lint: arith-checked(plan_shards derives every range
                // from symbols.len(): start + n_symbols <= len)
                &symbols[desc.start..desc.start + desc.n_symbols],
                &serial,
            )?;
        }
        Ok(())
    })?;
    let manifest = ShardManifest::from_handle(
        handle,
        plan.iter().map(|d| d.n_symbols as u64).collect(),
    );
    Ok((manifest, bodies))
}

/// Reassemble a sharded tensor.  `shards` may arrive in **any order**
/// (each carries its index); every shard must be present exactly once
/// and agree with the manifest.  Chunks across all shards decode in
/// one parallel fan-out.
pub fn decompress_sharded(
    manifest: &ShardManifest,
    shards: &[Vec<u8>],
    opts: &FrameOptions,
) -> Result<Vec<u8>, CodecError> {
    let bad = |msg: &str| CodecError::BadHeader(msg.to_string());
    let k = manifest.n_shards();
    if shards.len() != k {
        return Err(bad("shard count does not match manifest"));
    }
    let total = manifest.total_symbols();
    if total > usize::MAX as u64 {
        return Err(bad("declared symbol count exceeds address space"));
    }

    // Parse every shard header; placement comes from the embedded
    // index, so arrival order is free.
    let mut parsed: Vec<Option<(Vec<(usize, usize, bool)>, &[u8])>> =
        (0..k).map(|_| None).collect();
    for s in shards {
        if s.len() < SHARD_FIXED {
            return Err(bad("shard too short"));
        }
        if s[0..4] != MAGIC_SHARD {
            return Err(bad("bad shard magic"));
        }
        // lint: infallible(fixed slices of the SHARD_FIXED-checked prefix)
        let index =
            u32::from_le_bytes(s[4..8].try_into().unwrap()) as usize;
        let n = u64::from_le_bytes(s[8..16].try_into().unwrap());
        if index >= k {
            return Err(bad("shard index out of range"));
        }
        if n != manifest.shard_symbols[index] {
            return Err(bad("shard symbol count disagrees with manifest"));
        }
        if parsed[index].is_some() {
            return Err(bad("duplicate shard"));
        }
        parsed[index] =
            Some(parse_chunk_table(n as usize, &s[SHARD_FIXED..], false)?);
    }

    let handle = manifest.resolve()?;
    let mut out = vec![0u8; total as usize];
    let mut jobs: Vec<(&[u8], &mut [u8], bool)> = Vec::new();
    let mut out_rest: &mut [u8] = &mut out;
    for p in &parsed {
        let Some((entries, payload_area)) = p else {
            return Err(bad("missing shard"));
        };
        carve_chunk_jobs(entries, payload_area, &mut out_rest, &mut jobs);
    }
    decode_chunk_jobs(&handle, jobs, opts)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Histogram;
    use crate::util::prop;
    use crate::util::rng::{AliasTable, Rng};

    fn registry() -> &'static CodecRegistry {
        CodecRegistry::global()
    }

    fn skewed_symbols(n: usize, seed: u64) -> Vec<u8> {
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = (-0.025 * i as f64).exp();
        }
        let alias = AliasTable::new(&p);
        let mut rng = Rng::new(seed);
        alias.sample_many(&mut rng, n)
    }

    #[test]
    fn all_codecs_roundtrip_through_qlf2_frames() {
        let symbols = skewed_symbols(20_000, 1);
        let hist = Histogram::from_symbols(&symbols);
        for name in registry().known_names() {
            let handle = registry().resolve(name, &hist).unwrap();
            let frame = compress(&handle, &symbols).unwrap();
            assert_eq!(&frame[0..4], &MAGIC_QLF2, "{name}");
            let back = decompress(&frame).unwrap();
            assert_eq!(back, symbols, "codec {name}");
        }
    }

    #[test]
    fn all_codecs_roundtrip_through_qlf1_frames() {
        // Legacy single-payload frames must keep decoding.
        let symbols = skewed_symbols(9_000, 7);
        let hist = Histogram::from_symbols(&symbols);
        for name in registry().known_names() {
            let handle = registry().resolve(name, &hist).unwrap();
            let frame = compress_qlf1(&handle, &symbols);
            assert_eq!(&frame[0..4], &MAGIC_QLF1, "{name}");
            assert_eq!(decompress(&frame).unwrap(), symbols, "codec {name}");
        }
    }

    #[test]
    fn multi_chunk_frames_roundtrip() {
        let symbols = skewed_symbols(100_000, 2);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        for chunk_symbols in [1usize, 37, 4096, 64 * 1024, 1 << 30] {
            let opts = FrameOptions { chunk_symbols, ..Default::default() };
            let frame = compress_with(&handle, &symbols, &opts).unwrap();
            assert_eq!(
                decompress(&frame).unwrap(),
                symbols,
                "chunk_symbols={chunk_symbols}"
            );
        }
    }

    #[test]
    fn frame_bytes_independent_of_thread_count() {
        let symbols = skewed_symbols(200_000, 3);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("huffman", &hist).unwrap();
        let opts = |threads| FrameOptions { chunk_symbols: 8192, threads, ..Default::default() };
        let serial = compress_with(&handle, &symbols, &opts(1)).unwrap();
        for threads in [2usize, 4, 8] {
            assert_eq!(
                compress_with(&handle, &symbols, &opts(threads)).unwrap(),
                serial,
                "threads={threads}"
            );
        }
        // Serial and parallel decode agree too.
        let serial_out =
            decompress_with(&serial, &FrameOptions::serial()).unwrap();
        let parallel_out = decompress_with(
            &serial,
            &FrameOptions { chunk_symbols: 8192, threads: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(serial_out, symbols);
        assert_eq!(parallel_out, symbols);
    }

    #[test]
    fn frames_are_self_describing() {
        // Decode must not need the original histogram.
        let symbols = skewed_symbols(5_000, 2);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let frame = compress(&handle, &symbols).unwrap();
        drop(handle);
        drop(hist);
        assert_eq!(decompress(&frame).unwrap(), symbols);
    }

    #[test]
    fn table_header_written_once_across_chunks() {
        // A many-chunk QLC frame must carry exactly one table header:
        // its size overhead vs a single-chunk frame is only the chunk
        // table (8 bytes/chunk) plus per-chunk padding.
        let symbols = skewed_symbols(256 * 1024, 4);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let one = compress_with(
            &handle,
            &symbols,
            &FrameOptions { chunk_symbols: usize::MAX, threads: 1, ..Default::default() },
        )
        .unwrap();
        let chunks = 256; // 1 Ki symbols per chunk
        let many = compress_with(
            &handle,
            &symbols,
            &FrameOptions { chunk_symbols: 1024, threads: 1, ..Default::default() },
        )
        .unwrap();
        assert!(
            many.len() <= one.len() + chunks * 9,
            "chunk overhead too large: {} vs {}",
            many.len(),
            one.len()
        );
    }

    #[test]
    fn compressed_smaller_than_raw_for_skewed_data() {
        let symbols = skewed_symbols(50_000, 3);
        let hist = Histogram::from_symbols(&symbols);
        let raw_handle = registry().resolve("raw", &hist).unwrap();
        let raw = compress(&raw_handle, &symbols).unwrap().len();
        for name in ["huffman", "qlc", "qlc-t1"] {
            let handle = registry().resolve(name, &hist).unwrap();
            let framed = compress(&handle, &symbols).unwrap().len();
            assert!(framed < raw, "{name}: {framed} !< {raw}");
        }
    }

    #[test]
    fn corrupt_frames_rejected() {
        let symbols = skewed_symbols(1000, 4);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("huffman", &hist).unwrap();
        let frame = compress(&handle, &symbols).unwrap();

        let mut bad = frame.clone();
        bad[0] = b'X';
        assert!(matches!(decompress(&bad), Err(CodecError::BadHeader(_))));

        let mut bad = frame.clone();
        bad[4] = 200; // unknown tag
        assert!(decompress(&bad).is_err());

        let mut bad = frame.clone();
        bad[5] = 1; // unsupported flags
        assert!(decompress(&bad).is_err());

        let bad = &frame[..10];
        assert!(decompress(bad).is_err());

        // Truncated payload.
        let bad = &frame[..frame.len() - 10];
        assert!(decompress(bad).is_err());
    }

    #[test]
    fn corrupt_chunk_table_rejected() {
        let symbols = skewed_symbols(64 * 1024, 5);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let frame = compress_with(
            &handle,
            &symbols,
            &FrameOptions { chunk_symbols: 4096, threads: 1, ..Default::default() },
        )
        .unwrap();
        let hlen =
            u32::from_le_bytes(frame[14..18].try_into().unwrap()) as usize;
        let table_off = FIXED_HEADER + hlen + 4;

        // Inflate the first chunk's symbol count: sums no longer match.
        let mut bad = frame.clone();
        let n0 =
            u32::from_le_bytes(bad[table_off..table_off + 4].try_into().unwrap());
        bad[table_off..table_off + 4]
            .copy_from_slice(&(n0 + 1).to_le_bytes());
        assert!(decompress(&bad).is_err());

        // Claim absurd chunk count.
        let count_off = FIXED_HEADER + hlen;
        let mut bad = frame.clone();
        bad[count_off..count_off + 4]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decompress(&bad).is_err());

        // Shrink a payload length: payload sum mismatch.
        let mut bad = frame.clone();
        let p0 = u32::from_le_bytes(
            bad[table_off + 4..table_off + 8].try_into().unwrap(),
        );
        bad[table_off + 4..table_off + 8]
            .copy_from_slice(&(p0 - 1).to_le_bytes());
        assert!(decompress(&bad).is_err());
    }

    #[test]
    fn hostile_symbol_counts_fail_before_allocating() {
        // A tiny frame claiming 2^50 symbols must be rejected by the
        // bits bound, not by attempting the allocation.
        let symbols = skewed_symbols(100, 6);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("huffman", &hist).unwrap();
        for qlf1 in [false, true] {
            let mut frame = if qlf1 {
                compress_qlf1(&handle, &symbols)
            } else {
                compress(&handle, &symbols).unwrap()
            };
            frame[6..14].copy_from_slice(&(1u64 << 50).to_le_bytes());
            assert!(decompress(&frame).is_err(), "qlf1={qlf1}");
        }
    }

    #[test]
    fn encode_rejects_chunks_past_the_delta_flag_cap() {
        // Regression: the chunk-table writer used to cast
        // `chunk.len() as u32` unchecked, relying on a distant clamp;
        // a count at or past the cap would collide with the
        // adaptive-delta flag bit the decoder tests.  The writer now
        // enforces the decode-side cap itself.
        let payloads = vec![vec![0u8; 4]];
        let deltas = vec![false];
        // At the cap: fine.
        let mut out = Vec::new();
        write_chunk_table(&mut out, &[CHUNK_SYMBOL_CAP], &payloads, &deltas)
            .unwrap();
        // One past the cap: Err, not a silent collision-in-waiting.
        let mut out = Vec::new();
        assert!(matches!(
            write_chunk_table(
                &mut out,
                &[CHUNK_SYMBOL_CAP + 1],
                &payloads,
                &deltas
            ),
            Err(CodecError::BadHeader(_))
        ));
        // The actual collision point (the delta bit itself) is far
        // past the cap and must certainly be rejected.
        let mut out = Vec::new();
        assert!(write_chunk_table(
            &mut out,
            &[CHUNK_DELTA_BIT as usize],
            &payloads,
            &deltas
        )
        .is_err());
        // The public encode paths stay Ok: chunking is clamped to the
        // cap before the writer ever sees a count.
        let symbols = skewed_symbols(10_000, 40);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let opts = FrameOptions {
            chunk_symbols: usize::MAX,
            threads: 1,
            ..Default::default()
        };
        assert!(compress_with(&handle, &symbols, &opts).is_ok());
        assert!(compress_shard(&handle, 0, &symbols, &opts).is_ok());
        // The decode side enforces the same cap: a chunk-table count
        // past it is rejected while parsing the table, before any
        // allocation sized by it.
        let frame = compress_with(&handle, &symbols, &opts).unwrap();
        let hlen =
            u32::from_le_bytes(frame[14..18].try_into().unwrap()) as usize;
        let table_off = FIXED_HEADER + hlen + 4;
        let huge = CHUNK_SYMBOL_CAP as u32 + 1;
        let mut bad = frame.clone();
        bad[6..14].copy_from_slice(&(huge as u64).to_le_bytes());
        bad[table_off..table_off + 4].copy_from_slice(&huge.to_le_bytes());
        assert!(matches!(
            decompress(&bad),
            Err(CodecError::BadHeader(_))
        ));
    }

    #[test]
    fn unknown_codec_name_errors() {
        let hist = Histogram::from_symbols(&[1, 2, 3]);
        assert!(registry().resolve("zstd", &hist).is_err());
        assert!(registry().resolve("eg99", &hist).is_err());
    }

    #[test]
    fn empty_input_roundtrips() {
        let hist = Histogram::from_symbols(&[0]);
        for name in ["raw", "huffman", "qlc-t1", "elias-gamma", "eg0"] {
            let handle = registry().resolve(name, &hist).unwrap();
            let frame = compress(&handle, &[]).unwrap();
            assert_eq!(decompress(&frame).unwrap(), Vec::<u8>::new(), "{name}");
            let v1 = compress_qlf1(&handle, &[]);
            assert_eq!(decompress(&v1).unwrap(), Vec::<u8>::new(), "{name}");
        }
    }

    #[test]
    fn prop_frame_roundtrip_random_data() {
        prop::check("frame roundtrip", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size);
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = ["raw", "huffman", "qlc", "elias-delta", "eg2"];
            let name = names[rng.below(names.len() as u64) as usize];
            let handle = registry()
                .resolve(name, &hist)
                .map_err(|e| e.to_string())?;
            // Random chunking exercises 1..many chunks per frame.
            let opts = FrameOptions {
                chunk_symbols: 1 + rng.below(2048) as usize,
                threads: 1 + rng.below(4) as usize,
                ..Default::default()
            };
            let frame = compress_with(&handle, &symbols, &opts).unwrap();
            let back = decompress(&frame).map_err(|e| e.to_string())?;
            if back != symbols {
                return Err(format!("{name} roundtrip"));
            }
            Ok(())
        });
    }

    fn shuffle<T>(v: &mut [T], rng: &mut Rng) {
        for i in (1..v.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    #[test]
    fn shard_plan_partitions_contiguously() {
        for (total, k) in
            [(0usize, 4usize), (1, 4), (4, 4), (100, 7), (100_000, 13), (5, 0)]
        {
            let plan = shard_plan(total, k);
            assert!(!plan.is_empty(), "total={total} k={k}");
            assert!(plan.len() <= k.max(1));
            assert_eq!(plan[0].start, 0);
            let mut expect_start = 0usize;
            for (i, d) in plan.iter().enumerate() {
                assert_eq!(d.index, i);
                assert_eq!(d.start, expect_start);
                expect_start += d.n_symbols;
            }
            assert_eq!(expect_start, total);
            if total > 0 {
                assert!(plan.iter().all(|d| d.n_symbols > 0));
            }
        }
    }

    #[test]
    fn sharded_roundtrip_any_arrival_order() {
        let symbols = skewed_symbols(120_000, 11);
        let hist = Histogram::from_symbols(&symbols);
        for name in ["qlc", "huffman", "raw"] {
            let handle = registry().resolve(name, &hist).unwrap();
            for n_shards in [1usize, 2, 7] {
                let (manifest, mut shards) = compress_sharded(
                    &handle,
                    &symbols,
                    n_shards,
                    &FrameOptions { chunk_symbols: 4096, threads: 0, ..Default::default() },
                )
                .unwrap();
                assert_eq!(manifest.n_shards(), shards.len());
                assert_eq!(
                    manifest.total_symbols(),
                    symbols.len() as u64
                );
                // Shards reassemble regardless of arrival order.
                let mut rng = Rng::new(n_shards as u64);
                shuffle(&mut shards, &mut rng);
                let back = decompress_sharded(
                    &manifest,
                    &shards,
                    &FrameOptions::default(),
                )
                .unwrap();
                assert_eq!(back, symbols, "{name} x{n_shards}");
                // Shard chunks decode through the lane engine too.
                let laned = decompress_sharded(
                    &manifest,
                    &shards,
                    &FrameOptions {
                        decode: DecodeMode::Lanes,
                        ..FrameOptions::default()
                    },
                )
                .unwrap();
                assert_eq!(laned, symbols, "{name} x{n_shards} lanes");
            }
        }
    }

    #[test]
    fn manifest_serialization_roundtrips() {
        let symbols = skewed_symbols(10_000, 12);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let (manifest, shards) = compress_sharded(
            &handle,
            &symbols,
            4,
            &FrameOptions::default(),
        )
        .unwrap();
        let bytes = manifest.to_bytes();
        assert_eq!(&bytes[0..4], &MAGIC_MANIFEST);
        let parsed = ShardManifest::parse(&bytes).unwrap();
        assert_eq!(parsed, manifest);
        // Truncation and trailing garbage are both rejected.
        assert!(ShardManifest::parse(&bytes[..bytes.len() - 1]).is_err());
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(ShardManifest::parse(&padded).is_err());
        // Descriptors tile the tensor in index order.
        let descs = parsed.descriptors();
        assert_eq!(descs.len(), 4);
        assert_eq!(
            descs.iter().map(|d| d.n_symbols).sum::<usize>(),
            symbols.len()
        );
        // A parsed manifest decodes shards just like the original.
        let back = decompress_sharded(
            &parsed,
            &shards,
            &FrameOptions::default(),
        )
        .unwrap();
        assert_eq!(back, symbols);
    }

    #[test]
    fn sharded_header_written_once() {
        // N shards share one table header via the manifest: total
        // sharded bytes stay close to the single-frame size (framing
        // is 16 bytes + chunk table per shard, never a table copy).
        let symbols = skewed_symbols(256 * 1024, 13);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let single = compress(&handle, &symbols).unwrap();
        let (manifest, shards) =
            compress_sharded(&handle, &symbols, 8, &FrameOptions::default())
                .unwrap();
        let sharded: usize = manifest.to_bytes().len()
            + shards.iter().map(|s| s.len()).sum::<usize>();
        let slack = 8 * (SHARD_FIXED + 4 + 9 * 8) + 64;
        assert!(
            sharded <= single.len() + slack,
            "{sharded} vs {} (+{slack})",
            single.len()
        );
    }

    #[test]
    fn bad_shard_sets_rejected() {
        let symbols = skewed_symbols(20_000, 14);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("huffman", &hist).unwrap();
        let (manifest, shards) =
            compress_sharded(&handle, &symbols, 3, &FrameOptions::default())
                .unwrap();
        let opts = FrameOptions::default();

        // Wrong shard count.
        assert!(decompress_sharded(&manifest, &shards[..2], &opts).is_err());
        // Duplicate shard (same index twice).
        let mut dup = shards.clone();
        dup[1] = shards[0].clone();
        assert!(decompress_sharded(&manifest, &dup, &opts).is_err());
        // Out-of-range index.
        let mut oor = shards.clone();
        oor[2][4..8].copy_from_slice(&99u32.to_le_bytes());
        assert!(decompress_sharded(&manifest, &oor, &opts).is_err());
        // Symbol count disagrees with manifest.
        let mut wrong_n = shards.clone();
        let n = u64::from_le_bytes(wrong_n[0][8..16].try_into().unwrap());
        wrong_n[0][8..16].copy_from_slice(&(n + 1).to_le_bytes());
        assert!(decompress_sharded(&manifest, &wrong_n, &opts).is_err());
        // Bad shard magic.
        let mut magic = shards.clone();
        magic[0][0] = b'X';
        assert!(decompress_sharded(&manifest, &magic, &opts).is_err());
        // Truncated shard.
        let mut trunc = shards.clone();
        trunc[1].truncate(6);
        assert!(decompress_sharded(&manifest, &trunc, &opts).is_err());
        // The pristine set still decodes after all that.
        assert_eq!(
            decompress_sharded(&manifest, &shards, &opts).unwrap(),
            symbols
        );
    }

    #[test]
    fn prop_corrupt_manifest_never_panics() {
        // Fuzz the manifest parser and the sharded reassembly: bit
        // flips, truncations and garbage splices in the manifest or
        // any shard must produce Err or a wrong-but-bounded Ok —
        // never a panic.
        prop::check("manifest fuzz", prop::Config {
            cases: 64, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size.max(32));
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = ["raw", "huffman", "qlc", "eg1"];
            let name = names[rng.below(names.len() as u64) as usize];
            let handle = registry()
                .resolve(name, &hist)
                .map_err(|e| e.to_string())?;
            let n_shards = 1 + rng.below(5) as usize;
            let (manifest, mut shards) = compress_sharded(
                &handle,
                &symbols,
                n_shards,
                &FrameOptions {
                    chunk_symbols: 1 + rng.below(512) as usize,
                    threads: 1,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            let mut manifest_bytes = manifest.to_bytes();
            for _ in 0..16 {
                // Corrupt the manifest or one shard, alternating.
                let target_shard = rng.below(2) == 0 && !shards.is_empty();
                let buf: &mut Vec<u8> = if target_shard {
                    let k = rng.below(shards.len() as u64) as usize;
                    &mut shards[k]
                } else {
                    &mut manifest_bytes
                };
                if buf.is_empty() {
                    continue;
                }
                match rng.below(3) {
                    0 => {
                        let i = rng.below(buf.len() as u64) as usize;
                        buf[i] ^= 1 << rng.below(8);
                    }
                    1 => {
                        let keep = rng.below(buf.len() as u64) as usize;
                        buf.truncate(keep);
                    }
                    _ => {
                        let i = rng.below(buf.len() as u64) as usize;
                        let mut junk = vec![0u8; 8.min(buf.len() - i)];
                        rng.fill_bytes(&mut junk);
                        buf[i..i + junk.len()].copy_from_slice(&junk);
                    }
                }
                match ShardManifest::parse(&manifest_bytes) {
                    Err(_) => {}
                    Ok(m) => match decompress_sharded(
                        &m,
                        &shards,
                        &FrameOptions::serial(),
                    ) {
                        // Payload-internal flips may decode wrong
                        // symbols, but the validated tables pin the
                        // output size.
                        Ok(out) => {
                            if out.len() as u64 != m.total_symbols() {
                                return Err(format!(
                                    "decoded {} of {} declared symbols",
                                    out.len(),
                                    m.total_symbols()
                                ));
                            }
                        }
                        Err(_) => {}
                    },
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_input_sharded_roundtrip() {
        let hist = Histogram::from_symbols(&[0]);
        let handle = registry().resolve("huffman", &hist).unwrap();
        let (manifest, shards) =
            compress_sharded(&handle, &[], 4, &FrameOptions::default())
                .unwrap();
        assert_eq!(manifest.n_shards(), 1, "empty input → one empty shard");
        let back = decompress_sharded(
            &manifest,
            &shards,
            &FrameOptions::default(),
        )
        .unwrap();
        assert!(back.is_empty());
    }

    #[test]
    fn prop_corrupt_qlf2_never_panics() {
        // Fuzz the QLF2 parser: truncations, bit flips and garbage
        // splices anywhere in the frame (chunk table included) must
        // produce Err or a wrong-but-bounded Ok — never a panic.
        prop::check("qlf2 fuzz", prop::Config {
            cases: 96, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size.max(16));
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = ["raw", "huffman", "qlc", "elias-gamma", "eg3"];
            let name = names[rng.below(names.len() as u64) as usize];
            let handle = registry()
                .resolve(name, &hist)
                .map_err(|e| e.to_string())?;
            let frame = compress_with(&handle, &symbols, &FrameOptions {
                chunk_symbols: 1 + rng.below(512) as usize,
                threads: 1,
                ..Default::default()
            })
            .map_err(|e| e.to_string())?;
            for _ in 0..20 {
                let mut corrupt = frame.clone();
                match rng.below(3) {
                    0 => {
                        let i = rng.below(corrupt.len() as u64) as usize;
                        corrupt[i] ^= 1 << rng.below(8);
                    }
                    1 => {
                        let keep = rng.below(corrupt.len() as u64) as usize;
                        corrupt.truncate(keep);
                    }
                    _ => {
                        let i = rng.below(corrupt.len() as u64) as usize;
                        let mut junk = vec![0u8; 16.min(corrupt.len() - i)];
                        rng.fill_bytes(&mut junk);
                        corrupt[i..i + junk.len()].copy_from_slice(&junk);
                    }
                }
                match decompress(&corrupt) {
                    // A payload-internal flip the codec cannot detect
                    // may decode to wrong symbols — but the count is
                    // pinned by the (validated) chunk table.
                    Ok(out) => {
                        if out.len() > symbols.len() + corrupt.len() * 8 {
                            return Err(format!(
                                "decoded {} symbols from a {}-byte frame",
                                out.len(),
                                corrupt.len()
                            ));
                        }
                    }
                    Err(_) => {}
                }
            }
            Ok(())
        });
    }

    // -----------------------------------------------------------------
    // Adaptive per-chunk tables

    /// A stream whose PMF drifts hard at the midpoint: the first half
    /// is rank-ordered for the calibration histogram, the second half
    /// reverses the ranks, so a frame-global QLC table pays long codes
    /// for every frequent symbol after the drift.
    fn drifting_symbols(n: usize, seed: u64) -> Vec<u8> {
        let mut a = skewed_symbols(n / 2, seed);
        let b: Vec<u8> = skewed_symbols(n - n / 2, seed + 1)
            .into_iter()
            .map(|s| 255 - s)
            .collect();
        a.extend_from_slice(&b);
        a
    }

    #[test]
    fn adaptive_chunks_roundtrip_and_shrink_on_drift() {
        let symbols = drifting_symbols(256 * 1024, 21);
        // Calibrate on the full stream (what the CLI does).
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let opts = FrameOptions {
            chunk_symbols: 16 * 1024,
            threads: 0,
            ..Default::default()
        };
        let fixed = compress_with(&handle, &symbols, &opts).unwrap();
        let adaptive = compress_adaptive(&handle, &symbols, &opts).unwrap();
        // The drifted half re-fits: flag byte set, frame no larger
        // than the fixed-table frame (the refit criterion is
        // break-even in bits).
        assert_eq!(adaptive[5] & FLAG_ADAPTIVE_CHUNKS, FLAG_ADAPTIVE_CHUNKS);
        assert!(
            adaptive.len() <= fixed.len(),
            "adaptive {} > fixed {}",
            adaptive.len(),
            fixed.len()
        );
        // Bit-exact roundtrip, parallel and serial, batched and scalar.
        assert_eq!(decompress(&adaptive).unwrap(), symbols);
        assert_eq!(
            decompress_with(&adaptive, &FrameOptions::serial()).unwrap(),
            symbols
        );
        let scalar = FrameOptions {
            decode: DecodeMode::Scalar,
            ..FrameOptions::serial()
        };
        assert_eq!(decompress_with(&adaptive, &scalar).unwrap(), symbols);
    }

    #[test]
    fn adaptive_flag_unset_when_nothing_drifts() {
        // A stationary stream never pays for a delta: the adaptive
        // frame is byte-identical to the fixed-table frame.
        let symbols = skewed_symbols(128 * 1024, 22);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let opts = FrameOptions {
            chunk_symbols: 16 * 1024,
            threads: 1,
            ..Default::default()
        };
        let fixed = compress_with(&handle, &symbols, &opts).unwrap();
        let adaptive = compress_adaptive(&handle, &symbols, &opts).unwrap();
        assert_eq!(adaptive, fixed);
    }

    #[test]
    fn adaptive_chunks_ignored_for_non_adaptive_codecs() {
        // Families without ChunkTables silently keep fixed tables.
        let symbols = drifting_symbols(64 * 1024, 23);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("huffman", &hist).unwrap();
        let opts = FrameOptions::serial();
        let fixed = compress_with(&handle, &symbols, &opts).unwrap();
        let adaptive = compress_adaptive(&handle, &symbols, &opts).unwrap();
        assert_eq!(adaptive, fixed);
        assert_eq!(decompress(&adaptive).unwrap(), symbols);
    }

    #[test]
    fn delta_bit_without_flag_rejected() {
        let symbols = drifting_symbols(64 * 1024, 24);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let opts = FrameOptions {
            chunk_symbols: 8 * 1024,
            threads: 1,
            ..Default::default()
        };
        let frame = compress_adaptive(&handle, &symbols, &opts).unwrap();
        assert_eq!(frame[5], FLAG_ADAPTIVE_CHUNKS);
        // Clearing the flags byte leaves delta bits dangling in the
        // chunk table — the parser must reject, not mis-read counts.
        let mut bad = frame.clone();
        bad[5] = 0;
        assert!(matches!(
            decompress(&bad),
            Err(CodecError::BadHeader(_))
        ));
    }

    #[test]
    fn lane_decode_matches_batched_on_adaptive_frames() {
        // The lane satellite, frame-level: an adaptive frame with
        // mixed delta/fixed chunks must decode identically through
        // lanes, batched and scalar, serial and parallel.
        let symbols = drifting_symbols(128 * 1024, 31);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let opts = FrameOptions {
            chunk_symbols: 8 * 1024,
            threads: 1,
            ..Default::default()
        };
        let frame = compress_adaptive(&handle, &symbols, &opts).unwrap();
        assert_eq!(
            frame[5] & FLAG_ADAPTIVE_CHUNKS,
            FLAG_ADAPTIVE_CHUNKS,
            "drift must produce at least one delta chunk"
        );
        for threads in [1usize, 4] {
            let lanes = FrameOptions {
                decode: DecodeMode::Lanes,
                threads,
                ..Default::default()
            };
            assert_eq!(
                decompress_with(&frame, &lanes).unwrap(),
                symbols,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn prop_lane_frame_decode_equals_batched_and_scalar() {
        // Random codecs, chunkings and (for QLC) adaptive frames: the
        // three decode modes must agree byte-for-byte, and truncated
        // frames must agree on Ok-ness.
        prop::check("frame lanes==batched==scalar", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, size| {
            let adaptive = rng.below(2) == 0;
            let symbols = if adaptive {
                drifting_symbols(size.max(64), rng.below(1 << 20))
            } else {
                prop::arb_bytes(rng, size)
            };
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = ["raw", "huffman", "qlc", "elias-gamma", "eg2"];
            let name = if adaptive {
                "qlc"
            } else {
                names[rng.below(names.len() as u64) as usize]
            };
            let handle = registry()
                .resolve(name, &hist)
                .map_err(|e| e.to_string())?;
            let opts = FrameOptions {
                chunk_symbols: 1 + rng.below(2048) as usize,
                threads: 1 + rng.below(4) as usize,
                ..Default::default()
            };
            let frame = if adaptive {
                compress_adaptive(&handle, &symbols, &opts)
            } else {
                compress_with(&handle, &symbols, &opts)
            }
            .map_err(|e| e.to_string())?;
            let mode_opts = |decode| FrameOptions {
                decode,
                ..FrameOptions::serial()
            };
            let batched =
                decompress_with(&frame, &mode_opts(DecodeMode::Batched))
                    .map_err(|e| e.to_string())?;
            let laned =
                decompress_with(&frame, &mode_opts(DecodeMode::Lanes))
                    .map_err(|e| e.to_string())?;
            let scalar =
                decompress_with(&frame, &mode_opts(DecodeMode::Scalar))
                    .map_err(|e| e.to_string())?;
            if batched != symbols || laned != symbols || scalar != symbols {
                return Err(format!("{name}: decode-mode disagreement"));
            }
            // Truncated frames: lanes and batched must agree on
            // Ok-ness (and bytes when both somehow succeed).
            let keep = rng.below(frame.len() as u64 + 1) as usize;
            let cut = &frame[..keep];
            let b = decompress_with(cut, &mode_opts(DecodeMode::Batched));
            let l = decompress_with(cut, &mode_opts(DecodeMode::Lanes));
            match (&b, &l) {
                (Ok(bv), Ok(lv)) if bv != lv => {
                    return Err(format!(
                        "{name}: truncated at {keep}: modes decoded \
                         different bytes"
                    ));
                }
                (Ok(_), Err(_)) | (Err(_), Ok(_)) => {
                    return Err(format!(
                        "{name}: truncated at {keep}: batched \
                         {:?} vs lanes {:?}",
                        b.is_ok(),
                        l.is_ok()
                    ));
                }
                _ => {}
            }
            Ok(())
        });
    }

    #[test]
    fn encode_modes_write_identical_frames() {
        // The encode-tentpole contract, frame-level: scalar, batched
        // and lane encode write byte-identical frames for every codec
        // family, at any thread count.
        let symbols = skewed_symbols(96 * 1024, 41);
        let hist = Histogram::from_symbols(&symbols);
        for name in ["qlc", "huffman", "raw", "elias-delta", "eg2"] {
            let handle = registry().resolve(name, &hist).unwrap();
            let opts = |encode, threads| FrameOptions {
                chunk_symbols: 8 * 1024,
                threads,
                encode,
                ..Default::default()
            };
            let base =
                compress_with(&handle, &symbols, &opts(EncodeMode::Scalar, 1))
                    .unwrap();
            for encode in [EncodeMode::Batched, EncodeMode::Lanes] {
                for threads in [1usize, 4] {
                    let frame = compress_with(
                        &handle,
                        &symbols,
                        &opts(encode, threads),
                    )
                    .unwrap();
                    assert_eq!(frame, base, "{name} {encode:?} x{threads}");
                }
            }
            assert_eq!(decompress(&base).unwrap(), symbols, "{name}");
        }
    }

    #[test]
    fn adaptive_frames_identical_across_encode_modes() {
        // Table-delta chunks re-encode through a chunk-local codec;
        // that path too must be encode-mode-independent, so adaptive
        // frames stay deterministic bytes.
        let symbols = drifting_symbols(128 * 1024, 42);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let opts = |encode| FrameOptions {
            chunk_symbols: 8 * 1024,
            threads: 1,
            encode,
            ..Default::default()
        };
        let base =
            compress_adaptive(&handle, &symbols, &opts(EncodeMode::Scalar))
                .unwrap();
        assert_eq!(base[5] & FLAG_ADAPTIVE_CHUNKS, FLAG_ADAPTIVE_CHUNKS);
        for encode in [EncodeMode::Batched, EncodeMode::Lanes] {
            let frame =
                compress_adaptive(&handle, &symbols, &opts(encode)).unwrap();
            assert_eq!(frame, base, "{encode:?}");
        }
        assert_eq!(decompress(&base).unwrap(), symbols);
    }

    #[test]
    fn prop_encode_modes_byte_identical_frames() {
        // Random codecs, chunkings, thread counts and (for QLC)
        // adaptive frames: all three encode modes must write the same
        // bytes, and the result must decode through the lane engine.
        prop::check("frame encode modes identical", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, size| {
            let adaptive = rng.below(2) == 0;
            let symbols = if adaptive {
                drifting_symbols(size.max(64), rng.below(1 << 20))
            } else {
                prop::arb_bytes(rng, size)
            };
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = ["raw", "huffman", "qlc", "elias-omega", "eg1"];
            let name = if adaptive {
                "qlc"
            } else {
                names[rng.below(names.len() as u64) as usize]
            };
            let handle = registry()
                .resolve(name, &hist)
                .map_err(|e| e.to_string())?;
            let chunk_symbols = 1 + rng.below(2048) as usize;
            let threads = 1 + rng.below(4) as usize;
            let opts = |encode| FrameOptions {
                chunk_symbols,
                threads,
                encode,
                ..Default::default()
            };
            let emit = |encode| {
                if adaptive {
                    compress_adaptive(&handle, &symbols, &opts(encode))
                } else {
                    compress_with(&handle, &symbols, &opts(encode))
                }
                .map_err(|e| e.to_string())
            };
            let scalar = emit(EncodeMode::Scalar)?;
            let batched = emit(EncodeMode::Batched)?;
            let laned = emit(EncodeMode::Lanes)?;
            if batched != scalar || laned != scalar {
                return Err(format!("{name}: encode-mode disagreement"));
            }
            let back = decompress_with(&scalar, &FrameOptions {
                decode: DecodeMode::Lanes,
                ..FrameOptions::serial()
            })
            .map_err(|e| e.to_string())?;
            if back != symbols {
                return Err(format!("{name}: roundtrip"));
            }
            Ok(())
        });
    }

    #[test]
    fn delta_heavy_adaptive_frames_decode_in_mixed_lane_groups() {
        // Satellite: adaptive table-delta chunks now join the lane
        // lockstep via per-lane table pointers.  Build a frame where
        // *most* chunks carry deltas (calibration on the full stream
        // of two opposed halves makes nearly every chunk drift) and
        // pin lanes ≡ batched ≡ scalar on it.
        let symbols = drifting_symbols(256 * 1024, 43);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let frame = compress_adaptive(&handle, &symbols, &FrameOptions {
            chunk_symbols: 4 * 1024,
            threads: 1,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(frame[5] & FLAG_ADAPTIVE_CHUNKS, FLAG_ADAPTIVE_CHUNKS);
        let mode_opts = |decode, threads| FrameOptions {
            decode,
            threads,
            ..Default::default()
        };
        let batched =
            decompress_with(&frame, &mode_opts(DecodeMode::Batched, 1))
                .unwrap();
        assert_eq!(batched, symbols);
        for threads in [1usize, 4] {
            let laned =
                decompress_with(&frame, &mode_opts(DecodeMode::Lanes, threads))
                    .unwrap();
            assert_eq!(laned, batched, "threads={threads}");
        }
    }

    #[test]
    fn prop_corrupt_table_delta_never_panics() {
        // Fuzz the delta path specifically: corruption anywhere in an
        // adaptive frame (flags, chunk table, delta length, delta
        // bytes, payload) must yield Err or a wrong-but-bounded Ok —
        // never a panic, never an oversized allocation.
        prop::check("adaptive delta fuzz", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, size| {
            let n = size.max(64);
            let symbols = drifting_symbols(n, rng.below(1 << 20));
            let hist = Histogram::from_symbols(&symbols);
            let handle = registry()
                .resolve("qlc", &hist)
                .map_err(|e| e.to_string())?;
            let frame = compress_adaptive(&handle, &symbols, &FrameOptions {
                chunk_symbols: 1 + rng.below(n as u64 / 2 + 1) as usize,
                threads: 1,
                ..Default::default()
            })
            .map_err(|e| e.to_string())?;
            for _ in 0..20 {
                let mut corrupt = frame.clone();
                match rng.below(3) {
                    0 => {
                        let i = rng.below(corrupt.len() as u64) as usize;
                        corrupt[i] ^= 1 << rng.below(8);
                    }
                    1 => {
                        let keep = rng.below(corrupt.len() as u64) as usize;
                        corrupt.truncate(keep);
                    }
                    _ => {
                        let i = rng.below(corrupt.len() as u64) as usize;
                        let mut junk = vec![0u8; 16.min(corrupt.len() - i)];
                        rng.fill_bytes(&mut junk);
                        corrupt[i..i + junk.len()].copy_from_slice(&junk);
                    }
                }
                match decompress_with(&corrupt, &FrameOptions::serial()) {
                    Ok(out) => {
                        if out.len() > symbols.len() + corrupt.len() * 8 {
                            return Err(format!(
                                "decoded {} symbols from a {}-byte frame",
                                out.len(),
                                corrupt.len()
                            ));
                        }
                    }
                    Err(_) => {}
                }
            }
            Ok(())
        });
    }
}
