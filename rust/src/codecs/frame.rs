//! Self-describing compressed frame container (formats QLF1 + QLF2).
//!
//! # QLF2 — chunked (current, read + write)
//!
//! ```text
//! magic "QLF2" | codec_tag u8 | flags u8 (0) | n_symbols u64 |
//! header_len u32 | header bytes… |
//! n_chunks u32 | n_chunks × { chunk_n_symbols u32 | payload_len u32 } |
//! chunk payloads… (each byte-aligned, independently decodable)
//! ```
//!
//! The codec table header is written **once**; the payload is split
//! into fixed-size symbol chunks (default 64 Ki symbols), each encoded
//! to its own byte-aligned payload.  Chunks share the codec tables but
//! no bitstream state, so encode and decode parallelize across cores —
//! `compress_with`/`decompress` fan chunks out over `std::thread`
//! scoped workers (one [`EncoderSession`]/[`DecoderSession`] per
//! worker; the crate has no rayon in its offline dependency set).
//! Chunk boundaries depend only on [`FrameOptions::chunk_symbols`],
//! never on the worker count, so frame bytes are deterministic.
//!
//! # QLF1 — single payload (legacy, read + [`compress_qlf1`])
//!
//! ```text
//! magic "QLF1" | codec_tag u8 | reserved u8 | n_symbols u64 |
//! header_len u32 | header bytes… | payload bits…
//! ```
//!
//! [`decompress`] dispatches on the magic, so pre-QLF2 archives keep
//! decoding.  Both formats share wire tags and table-header layouts
//! via [`CodecRegistry`] — this module contains no per-codec dispatch
//! of its own.

use super::registry::{CodecHandle, CodecRegistry};
use super::session::DEFAULT_CHUNK_SYMBOLS;
use super::CodecError;

pub const MAGIC_QLF1: [u8; 4] = *b"QLF1";
pub const MAGIC_QLF2: [u8; 4] = *b"QLF2";

/// Fixed prefix shared by both formats: magic, tag, flags, n, hlen.
const FIXED_HEADER: usize = 4 + 1 + 1 + 8 + 4;

/// Knobs for chunked frame I/O.
#[derive(Clone, Copy, Debug)]
pub struct FrameOptions {
    /// Symbols per chunk (QLF2).  Smaller chunks → more parallelism
    /// and more per-chunk overhead (8 table bytes + final-byte pad).
    pub chunk_symbols: usize,
    /// Worker threads; 0 = one per available core, 1 = serial.
    pub threads: usize,
}

impl Default for FrameOptions {
    fn default() -> Self {
        FrameOptions { chunk_symbols: DEFAULT_CHUNK_SYMBOLS, threads: 0 }
    }
}

impl FrameOptions {
    /// Serial processing (inside worker pools that already own their
    /// parallelism, e.g. the coordinator pipeline).
    pub fn serial() -> Self {
        FrameOptions { threads: 1, ..Default::default() }
    }
}

fn effective_threads(requested: usize, jobs: usize) -> usize {
    let hw = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    hw.min(jobs).max(1)
}

/// Run `work` over contiguous bands of `jobs` on up to `threads`
/// scoped workers (serial when `threads <= 1`).  Each invocation of
/// `work` gets one band and typically amortizes one codec session
/// across it.  Band assignment never affects results: every job
/// carries its own destination.  Returns the first error.
fn run_banded<J, E, F>(jobs: Vec<J>, threads: usize, work: F) -> Result<(), E>
where
    J: Send,
    E: Send,
    F: Fn(Vec<J>) -> Result<(), E> + Sync,
{
    if threads <= 1 {
        return work(jobs);
    }
    let per_band = (jobs.len() + threads - 1) / threads;
    let results = std::thread::scope(|s| {
        let work = &work;
        let mut workers = Vec::with_capacity(threads);
        let mut jobs = jobs;
        while !jobs.is_empty() {
            let band = jobs.split_off(jobs.len().saturating_sub(per_band));
            workers.push(s.spawn(move || work(band)));
        }
        workers
            .into_iter()
            .map(|w| w.join().expect("frame worker panicked"))
            .collect::<Vec<_>>()
    });
    results.into_iter().collect()
}

// ---------------------------------------------------------------------------
// Encode

/// Compress `symbols` into a chunked QLF2 frame with default options.
pub fn compress(handle: &CodecHandle, symbols: &[u8]) -> Vec<u8> {
    compress_with(handle, symbols, &FrameOptions::default())
}

/// Compress `symbols` into a chunked QLF2 frame.
pub fn compress_with(
    handle: &CodecHandle,
    symbols: &[u8],
    opts: &FrameOptions,
) -> Vec<u8> {
    // Chunk-table fields are u32; the deepest code in the crate is
    // < 64 bits/symbol, so capping chunks at u32::MAX/8 symbols keeps
    // both the symbol count and the worst-case payload length in
    // range.  The lower bound keeps the chunk *count* in its u32 field
    // too (only binds past 4 Gi symbols of 1-symbol chunks).
    let min_chunk = symbols.len() / u32::MAX as usize + 1;
    let chunk_symbols = opts
        .chunk_symbols
        .clamp(min_chunk.min((u32::MAX / 8) as usize), (u32::MAX / 8) as usize)
        .max(1);
    let chunks: Vec<&[u8]> = symbols.chunks(chunk_symbols).collect();
    assert!(chunks.len() <= u32::MAX as usize, "chunk count overflows u32");
    let threads = effective_threads(opts.threads, chunks.len());

    let mut payloads: Vec<Vec<u8>> = vec![Vec::new(); chunks.len()];
    let jobs: Vec<(&[u8], &mut Vec<u8>)> =
        chunks.iter().copied().zip(payloads.iter_mut()).collect();
    let encode_ok: Result<(), std::convert::Infallible> =
        run_banded(jobs, threads, |band| {
            let mut enc = handle.encoder();
            for (chunk, slot) in band {
                *slot = enc.encode_chunk_to_vec(chunk);
            }
            Ok(())
        });
    encode_ok.unwrap(); // Infallible: encoding cannot fail

    let header = handle.wire_header();
    let payload_bytes: usize = payloads.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(
        FIXED_HEADER + header.len() + 4 + payloads.len() * 8 + payload_bytes,
    );
    out.extend_from_slice(&MAGIC_QLF2);
    out.push(handle.wire_tag());
    out.push(0); // flags
    out.extend_from_slice(&(symbols.len() as u64).to_le_bytes());
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header);
    out.extend_from_slice(&(payloads.len() as u32).to_le_bytes());
    for (chunk, payload) in chunks.iter().zip(&payloads) {
        out.extend_from_slice(&(chunk.len() as u32).to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    }
    for payload in &payloads {
        out.extend_from_slice(payload);
    }
    out
}

/// Compress `symbols` into a legacy single-payload QLF1 frame.
/// Kept for interoperability with pre-chunking consumers (and to
/// exercise the QLF1 read path); new code should use [`compress`].
pub fn compress_qlf1(handle: &CodecHandle, symbols: &[u8]) -> Vec<u8> {
    let header = handle.wire_header();
    let payload = handle.codec().encode_to_vec(symbols);
    let mut out =
        Vec::with_capacity(FIXED_HEADER + header.len() + payload.len());
    out.extend_from_slice(&MAGIC_QLF1);
    out.push(handle.wire_tag());
    out.push(0); // reserved
    out.extend_from_slice(&(symbols.len() as u64).to_le_bytes());
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header);
    out.extend_from_slice(&payload);
    out
}

// ---------------------------------------------------------------------------
// Decode

/// Decompress a QLF1 or QLF2 frame (dispatch on magic).
pub fn decompress(data: &[u8]) -> Result<Vec<u8>, CodecError> {
    decompress_with(data, &FrameOptions::default())
}

/// Decompress with explicit threading options.
pub fn decompress_with(
    data: &[u8],
    opts: &FrameOptions,
) -> Result<Vec<u8>, CodecError> {
    let bad = |msg: &str| CodecError::BadHeader(msg.to_string());
    if data.len() < FIXED_HEADER {
        return Err(bad("frame too short"));
    }
    let magic: [u8; 4] = data[0..4].try_into().unwrap();
    let tag = data[4];
    let n = u64::from_le_bytes(data[6..14].try_into().unwrap());
    if n > usize::MAX as u64 {
        return Err(bad("declared symbol count exceeds address space"));
    }
    let n = n as usize;
    let hlen = u32::from_le_bytes(data[14..18].try_into().unwrap()) as usize;
    if data.len() - FIXED_HEADER < hlen {
        return Err(bad("truncated header"));
    }
    let header = &data[FIXED_HEADER..FIXED_HEADER + hlen];
    let body = &data[FIXED_HEADER + hlen..];
    match magic {
        MAGIC_QLF1 => decompress_qlf1_body(tag, n, header, body),
        MAGIC_QLF2 => {
            if data[5] != 0 {
                return Err(bad("unsupported QLF2 flags"));
            }
            decompress_qlf2_body(tag, n, header, body, opts)
        }
        _ => Err(bad("bad magic")),
    }
}

fn decompress_qlf1_body(
    tag: u8,
    n: usize,
    header: &[u8],
    payload: &[u8],
) -> Result<Vec<u8>, CodecError> {
    // Every code is ≥ 1 bit, so a frame that declares more symbols than
    // payload bits is corrupt.  (Without this bound a hostile header
    // could force a huge allocation before the first decode error.)
    if n as u64 > payload.len() as u64 * 8 {
        return Err(CodecError::BadHeader(
            "declared symbol count exceeds payload bits".into(),
        ));
    }
    let handle = CodecRegistry::global().resolve_wire(tag, header)?;
    handle.decoder().decode_chunk_to_vec(payload, n)
}

fn decompress_qlf2_body(
    tag: u8,
    n: usize,
    header: &[u8],
    body: &[u8],
    opts: &FrameOptions,
) -> Result<Vec<u8>, CodecError> {
    let bad = |msg: &str| CodecError::BadHeader(msg.to_string());
    if body.len() < 4 {
        return Err(bad("truncated chunk count"));
    }
    let n_chunks =
        u32::from_le_bytes(body[0..4].try_into().unwrap()) as usize;
    let table = &body[4..];
    // The chunk table must fit in the frame before anything is
    // allocated in proportion to it.
    if table.len() / 8 < n_chunks {
        return Err(bad("truncated chunk table"));
    }
    let (table, payload_area) = table.split_at(n_chunks * 8);

    let mut total_symbols = 0u64;
    let mut total_payload = 0u64;
    let mut entries = Vec::with_capacity(n_chunks);
    for e in table.chunks_exact(8) {
        let chunk_n =
            u32::from_le_bytes(e[0..4].try_into().unwrap()) as usize;
        let plen = u32::from_le_bytes(e[4..8].try_into().unwrap()) as usize;
        // Per-chunk sanity: ≥ 1 bit per symbol.
        if chunk_n as u64 > plen as u64 * 8 {
            return Err(bad("chunk symbol count exceeds chunk payload bits"));
        }
        total_symbols += chunk_n as u64;
        total_payload += plen as u64;
        entries.push((chunk_n, plen));
    }
    if total_symbols != n as u64 {
        return Err(bad("chunk table does not sum to frame symbol count"));
    }
    if total_payload != payload_area.len() as u64 {
        return Err(bad("chunk table does not sum to payload length"));
    }

    let handle = CodecRegistry::global().resolve_wire(tag, header)?;
    let mut out = vec![0u8; n];

    // Carve (payload, destination) pairs for each chunk.
    let mut jobs: Vec<(&[u8], &mut [u8])> = Vec::with_capacity(n_chunks);
    let mut payload_rest = payload_area;
    let mut out_rest: &mut [u8] = &mut out;
    for &(chunk_n, plen) in &entries {
        let (payload, ptail) = payload_rest.split_at(plen);
        payload_rest = ptail;
        let (dst, otail) =
            std::mem::take(&mut out_rest).split_at_mut(chunk_n);
        out_rest = otail;
        jobs.push((payload, dst));
    }

    let threads = effective_threads(opts.threads, jobs.len());
    run_banded(jobs, threads, |band| {
        let mut dec = handle.decoder();
        for (payload, dst) in band {
            dec.decode_chunk(payload, dst)?;
        }
        Ok(())
    })?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Histogram;
    use crate::util::prop;
    use crate::util::rng::{AliasTable, Rng};

    fn registry() -> &'static CodecRegistry {
        CodecRegistry::global()
    }

    fn skewed_symbols(n: usize, seed: u64) -> Vec<u8> {
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = (-0.025 * i as f64).exp();
        }
        let alias = AliasTable::new(&p);
        let mut rng = Rng::new(seed);
        alias.sample_many(&mut rng, n)
    }

    #[test]
    fn all_codecs_roundtrip_through_qlf2_frames() {
        let symbols = skewed_symbols(20_000, 1);
        let hist = Histogram::from_symbols(&symbols);
        for name in registry().known_names() {
            let handle = registry().resolve(name, &hist).unwrap();
            let frame = compress(&handle, &symbols);
            assert_eq!(&frame[0..4], &MAGIC_QLF2, "{name}");
            let back = decompress(&frame).unwrap();
            assert_eq!(back, symbols, "codec {name}");
        }
    }

    #[test]
    fn all_codecs_roundtrip_through_qlf1_frames() {
        // Legacy single-payload frames must keep decoding.
        let symbols = skewed_symbols(9_000, 7);
        let hist = Histogram::from_symbols(&symbols);
        for name in registry().known_names() {
            let handle = registry().resolve(name, &hist).unwrap();
            let frame = compress_qlf1(&handle, &symbols);
            assert_eq!(&frame[0..4], &MAGIC_QLF1, "{name}");
            assert_eq!(decompress(&frame).unwrap(), symbols, "codec {name}");
        }
    }

    #[test]
    fn multi_chunk_frames_roundtrip() {
        let symbols = skewed_symbols(100_000, 2);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        for chunk_symbols in [1usize, 37, 4096, 64 * 1024, 1 << 30] {
            let opts = FrameOptions { chunk_symbols, threads: 0 };
            let frame = compress_with(&handle, &symbols, &opts);
            assert_eq!(
                decompress(&frame).unwrap(),
                symbols,
                "chunk_symbols={chunk_symbols}"
            );
        }
    }

    #[test]
    fn frame_bytes_independent_of_thread_count() {
        let symbols = skewed_symbols(200_000, 3);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("huffman", &hist).unwrap();
        let opts = |threads| FrameOptions { chunk_symbols: 8192, threads };
        let serial = compress_with(&handle, &symbols, &opts(1));
        for threads in [2usize, 4, 8] {
            assert_eq!(
                compress_with(&handle, &symbols, &opts(threads)),
                serial,
                "threads={threads}"
            );
        }
        // Serial and parallel decode agree too.
        let serial_out =
            decompress_with(&serial, &FrameOptions::serial()).unwrap();
        let parallel_out = decompress_with(
            &serial,
            &FrameOptions { chunk_symbols: 8192, threads: 4 },
        )
        .unwrap();
        assert_eq!(serial_out, symbols);
        assert_eq!(parallel_out, symbols);
    }

    #[test]
    fn frames_are_self_describing() {
        // Decode must not need the original histogram.
        let symbols = skewed_symbols(5_000, 2);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let frame = compress(&handle, &symbols);
        drop(handle);
        drop(hist);
        assert_eq!(decompress(&frame).unwrap(), symbols);
    }

    #[test]
    fn table_header_written_once_across_chunks() {
        // A many-chunk QLC frame must carry exactly one table header:
        // its size overhead vs a single-chunk frame is only the chunk
        // table (8 bytes/chunk) plus per-chunk padding.
        let symbols = skewed_symbols(256 * 1024, 4);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let one = compress_with(
            &handle,
            &symbols,
            &FrameOptions { chunk_symbols: usize::MAX, threads: 1 },
        );
        let chunks = 256; // 1 Ki symbols per chunk
        let many = compress_with(
            &handle,
            &symbols,
            &FrameOptions { chunk_symbols: 1024, threads: 1 },
        );
        assert!(
            many.len() <= one.len() + chunks * 9,
            "chunk overhead too large: {} vs {}",
            many.len(),
            one.len()
        );
    }

    #[test]
    fn compressed_smaller_than_raw_for_skewed_data() {
        let symbols = skewed_symbols(50_000, 3);
        let hist = Histogram::from_symbols(&symbols);
        let raw_handle = registry().resolve("raw", &hist).unwrap();
        let raw = compress(&raw_handle, &symbols).len();
        for name in ["huffman", "qlc", "qlc-t1"] {
            let handle = registry().resolve(name, &hist).unwrap();
            let framed = compress(&handle, &symbols).len();
            assert!(framed < raw, "{name}: {framed} !< {raw}");
        }
    }

    #[test]
    fn corrupt_frames_rejected() {
        let symbols = skewed_symbols(1000, 4);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("huffman", &hist).unwrap();
        let frame = compress(&handle, &symbols);

        let mut bad = frame.clone();
        bad[0] = b'X';
        assert!(matches!(decompress(&bad), Err(CodecError::BadHeader(_))));

        let mut bad = frame.clone();
        bad[4] = 200; // unknown tag
        assert!(decompress(&bad).is_err());

        let mut bad = frame.clone();
        bad[5] = 1; // unsupported flags
        assert!(decompress(&bad).is_err());

        let bad = &frame[..10];
        assert!(decompress(bad).is_err());

        // Truncated payload.
        let bad = &frame[..frame.len() - 10];
        assert!(decompress(bad).is_err());
    }

    #[test]
    fn corrupt_chunk_table_rejected() {
        let symbols = skewed_symbols(64 * 1024, 5);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("qlc", &hist).unwrap();
        let frame = compress_with(
            &handle,
            &symbols,
            &FrameOptions { chunk_symbols: 4096, threads: 1 },
        );
        let hlen =
            u32::from_le_bytes(frame[14..18].try_into().unwrap()) as usize;
        let table_off = FIXED_HEADER + hlen + 4;

        // Inflate the first chunk's symbol count: sums no longer match.
        let mut bad = frame.clone();
        let n0 =
            u32::from_le_bytes(bad[table_off..table_off + 4].try_into().unwrap());
        bad[table_off..table_off + 4]
            .copy_from_slice(&(n0 + 1).to_le_bytes());
        assert!(decompress(&bad).is_err());

        // Claim absurd chunk count.
        let count_off = FIXED_HEADER + hlen;
        let mut bad = frame.clone();
        bad[count_off..count_off + 4]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decompress(&bad).is_err());

        // Shrink a payload length: payload sum mismatch.
        let mut bad = frame.clone();
        let p0 = u32::from_le_bytes(
            bad[table_off + 4..table_off + 8].try_into().unwrap(),
        );
        bad[table_off + 4..table_off + 8]
            .copy_from_slice(&(p0 - 1).to_le_bytes());
        assert!(decompress(&bad).is_err());
    }

    #[test]
    fn hostile_symbol_counts_fail_before_allocating() {
        // A tiny frame claiming 2^50 symbols must be rejected by the
        // bits bound, not by attempting the allocation.
        let symbols = skewed_symbols(100, 6);
        let hist = Histogram::from_symbols(&symbols);
        let handle = registry().resolve("huffman", &hist).unwrap();
        type Compressor = fn(&CodecHandle, &[u8]) -> Vec<u8>;
        for make in [compress as Compressor, compress_qlf1 as Compressor] {
            let mut frame = make(&handle, &symbols);
            frame[6..14].copy_from_slice(&(1u64 << 50).to_le_bytes());
            assert!(decompress(&frame).is_err());
        }
    }

    #[test]
    fn unknown_codec_name_errors() {
        let hist = Histogram::from_symbols(&[1, 2, 3]);
        assert!(registry().resolve("zstd", &hist).is_err());
        assert!(registry().resolve("eg99", &hist).is_err());
    }

    #[test]
    fn empty_input_roundtrips() {
        let hist = Histogram::from_symbols(&[0]);
        for name in ["raw", "huffman", "qlc-t1", "elias-gamma", "eg0"] {
            let handle = registry().resolve(name, &hist).unwrap();
            let frame = compress(&handle, &[]);
            assert_eq!(decompress(&frame).unwrap(), Vec::<u8>::new(), "{name}");
            let v1 = compress_qlf1(&handle, &[]);
            assert_eq!(decompress(&v1).unwrap(), Vec::<u8>::new(), "{name}");
        }
    }

    #[test]
    fn prop_frame_roundtrip_random_data() {
        prop::check("frame roundtrip", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size);
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = ["raw", "huffman", "qlc", "elias-delta", "eg2"];
            let name = names[rng.below(names.len() as u64) as usize];
            let handle = registry()
                .resolve(name, &hist)
                .map_err(|e| e.to_string())?;
            // Random chunking exercises 1..many chunks per frame.
            let opts = FrameOptions {
                chunk_symbols: 1 + rng.below(2048) as usize,
                threads: 1 + rng.below(4) as usize,
            };
            let frame = compress_with(&handle, &symbols, &opts);
            let back = decompress(&frame).map_err(|e| e.to_string())?;
            if back != symbols {
                return Err(format!("{name} roundtrip"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_corrupt_qlf2_never_panics() {
        // Fuzz the QLF2 parser: truncations, bit flips and garbage
        // splices anywhere in the frame (chunk table included) must
        // produce Err or a wrong-but-bounded Ok — never a panic.
        prop::check("qlf2 fuzz", prop::Config {
            cases: 96, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size.max(16));
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = ["raw", "huffman", "qlc", "elias-gamma", "eg3"];
            let name = names[rng.below(names.len() as u64) as usize];
            let handle = registry()
                .resolve(name, &hist)
                .map_err(|e| e.to_string())?;
            let frame = compress_with(&handle, &symbols, &FrameOptions {
                chunk_symbols: 1 + rng.below(512) as usize,
                threads: 1,
            });
            for _ in 0..20 {
                let mut corrupt = frame.clone();
                match rng.below(3) {
                    0 => {
                        let i = rng.below(corrupt.len() as u64) as usize;
                        corrupt[i] ^= 1 << rng.below(8);
                    }
                    1 => {
                        let keep = rng.below(corrupt.len() as u64) as usize;
                        corrupt.truncate(keep);
                    }
                    _ => {
                        let i = rng.below(corrupt.len() as u64) as usize;
                        let mut junk = vec![0u8; 16.min(corrupt.len() - i)];
                        rng.fill_bytes(&mut junk);
                        corrupt[i..i + junk.len()].copy_from_slice(&junk);
                    }
                }
                match decompress(&corrupt) {
                    // A payload-internal flip the codec cannot detect
                    // may decode to wrong symbols — but the count is
                    // pinned by the (validated) chunk table.
                    Ok(out) => {
                        if out.len() > symbols.len() + corrupt.len() * 8 {
                            return Err(format!(
                                "decoded {} symbols from a {}-byte frame",
                                out.len(),
                                corrupt.len()
                            ));
                        }
                    }
                    Err(_) => {}
                }
            }
            Ok(())
        });
    }
}
