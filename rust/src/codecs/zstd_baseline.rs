//! Zstandard baseline (paper §1 cites Zstandard as a production
//! Huffman/FSE-based compressor).  This is *block* compression, not a
//! symbol code — it exploits context (repeats, match structure) that
//! symbol codes cannot, at the cost of block-granular decode (no
//! random access, deep hardware).  Included to position QLC against a
//! production general-purpose compressor in the benches.
//!
//! Not a [`super::Codec`]: it has no per-symbol code lengths.  It
//! implements its own tiny API used by the benches and the CLI
//! comparison table.

use std::io::{Error, ErrorKind};

/// Compress a symbol block at the given zstd level (1..=19).
pub fn compress(symbols: &[u8], level: i32) -> std::io::Result<Vec<u8>> {
    zstd::bulk::compress(symbols, level)
}

/// Decompress; `n_symbols` is the exact decoded size.
pub fn decompress(data: &[u8], n_symbols: usize) -> std::io::Result<Vec<u8>> {
    let out = zstd::bulk::decompress(data, n_symbols)?;
    if out.len() != n_symbols {
        return Err(Error::new(
            ErrorKind::InvalidData,
            format!("zstd decoded {} of {n_symbols} symbols", out.len()),
        ));
    }
    Ok(out)
}

/// Compressibility (paper metric) of zstd on a symbol stream.
pub fn compressibility(symbols: &[u8], level: i32) -> f64 {
    let out = compress(symbols, level).expect("zstd compress");
    1.0 - out.len() as f64 / symbols.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{TensorGen, TensorKind};
    use crate::formats::Variant;
    use crate::util::rng::Rng;

    fn symbols(n: usize, seed: u64) -> Vec<u8> {
        let gen = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY);
        let mut rng = Rng::new(seed);
        gen.symbols(&mut rng, n)
    }

    #[test]
    fn roundtrip() {
        let data = symbols(64 * 1024, 1);
        let comp = compress(&data, 3).unwrap();
        assert_eq!(decompress(&comp, data.len()).unwrap(), data);
    }

    #[test]
    fn compresses_skewed_streams() {
        let data = symbols(256 * 1024, 2);
        let c = compressibility(&data, 3);
        assert!(c > 0.05, "zstd should compress e4m3 symbols: {c}");
    }

    #[test]
    fn wrong_size_rejected() {
        let data = symbols(1024, 3);
        let comp = compress(&data, 1).unwrap();
        assert!(decompress(&comp, data.len() + 1).is_err());
    }

    #[test]
    fn corrupt_data_rejected() {
        let data = symbols(4096, 4);
        let mut comp = compress(&data, 3).unwrap();
        comp[0] ^= 0xFF; // clobber the frame magic — always detected
        assert!(decompress(&comp, data.len()).is_err());
    }

    #[test]
    fn empty_roundtrip() {
        let comp = compress(&[], 3).unwrap();
        assert_eq!(decompress(&comp, 0).unwrap(), Vec::<u8>::new());
    }
}
