//! Area schemes: the structural half of a Quad Length Code.

/// One area: `size` rank-consecutive symbols addressed by a
/// `symbol_bits`-wide suffix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Area {
    pub size: u16,
    pub symbol_bits: u32,
}

/// An area scheme: `2^prefix_bits` areas covering the 256 rank-ordered
/// symbols (paper Table 1 / Table 2).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AreaScheme {
    pub prefix_bits: u32,
    pub areas: Vec<Area>,
}

impl AreaScheme {
    /// Validated constructor.
    pub fn new(prefix_bits: u32, areas: Vec<Area>) -> Result<Self, String> {
        if !(1..=8).contains(&prefix_bits) {
            return Err(format!("prefix_bits {prefix_bits} out of range 1..=8"));
        }
        if areas.len() != 1usize << prefix_bits {
            return Err(format!(
                "{} areas but prefix of {prefix_bits} bits addresses {}",
                areas.len(),
                1 << prefix_bits
            ));
        }
        let mut covered = 0u32;
        for (i, a) in areas.iter().enumerate() {
            if a.symbol_bits > 8 {
                return Err(format!("area {i}: symbol_bits {} > 8", a.symbol_bits));
            }
            if a.size == 0 {
                return Err(format!("area {i}: empty area"));
            }
            if a.size as u32 > 1 << a.symbol_bits {
                return Err(format!(
                    "area {i}: {} symbols need more than {} bits",
                    a.size, a.symbol_bits
                ));
            }
            covered += a.size as u32;
        }
        if covered != 256 {
            return Err(format!("areas cover {covered} symbols, need 256"));
        }
        Ok(AreaScheme { prefix_bits, areas })
    }

    /// Paper Table 1: tuned for FFN1-activation-like PMFs.
    /// Lengths {6,7,8,11}; areas 5×8, 16, 32, 168.
    pub fn table1() -> Self {
        AreaScheme::new(
            3,
            vec![
                Area { size: 8, symbol_bits: 3 },
                Area { size: 8, symbol_bits: 3 },
                Area { size: 8, symbol_bits: 3 },
                Area { size: 8, symbol_bits: 3 },
                Area { size: 8, symbol_bits: 3 },
                Area { size: 16, symbol_bits: 4 },
                Area { size: 32, symbol_bits: 5 },
                Area { size: 168, symbol_bits: 8 },
            ],
        )
        .expect("Table 1 is valid")
    }

    /// Paper Table 2: adapted for FFN2-activation-like PMFs with a
    /// dominant zero symbol. Lengths {4,6,8,11}; areas 2, 4×8, 2×32, 158.
    pub fn table2() -> Self {
        AreaScheme::new(
            3,
            vec![
                Area { size: 2, symbol_bits: 1 },
                Area { size: 8, symbol_bits: 3 },
                Area { size: 8, symbol_bits: 3 },
                Area { size: 8, symbol_bits: 3 },
                Area { size: 8, symbol_bits: 3 },
                Area { size: 32, symbol_bits: 5 },
                Area { size: 32, symbol_bits: 5 },
                Area { size: 158, symbol_bits: 8 },
            ],
        )
        .expect("Table 2 is valid")
    }

    pub fn num_areas(&self) -> usize {
        self.areas.len()
    }

    /// Total code length of area `a`.
    #[inline]
    pub fn code_length(&self, area: usize) -> u32 {
        self.prefix_bits + self.areas[area].symbol_bits
    }

    /// First rank covered by area `a`.
    pub fn base_rank(&self, area: usize) -> u32 {
        self.areas[..area].iter().map(|a| a.size as u32).sum()
    }

    /// Area index containing `rank`.
    pub fn area_of_rank(&self, rank: u32) -> usize {
        debug_assert!(rank < 256);
        let mut base = 0u32;
        for (i, a) in self.areas.iter().enumerate() {
            base += a.size as u32;
            if rank < base {
                return i;
            }
        }
        unreachable!("rank {rank} beyond 256")
    }

    /// Code length by *rank* (not symbol value).
    pub fn rank_lengths(&self) -> [u32; 256] {
        let mut out = [0u32; 256];
        let mut rank = 0usize;
        for (i, a) in self.areas.iter().enumerate() {
            for _ in 0..a.size {
                out[rank] = self.code_length(i);
                rank += 1;
            }
        }
        out
    }

    /// Distinct code lengths, ascending (the "quad" in quad length
    /// codes: paper schemes have exactly 4).
    pub fn distinct_lengths(&self) -> Vec<u32> {
        let mut lens: Vec<u32> =
            (0..self.num_areas()).map(|a| self.code_length(a)).collect();
        lens.sort_unstable();
        lens.dedup();
        lens
    }

    /// Expected code length (bits/symbol) against a descending-sorted
    /// PMF (probability of rank r at index r).
    pub fn expected_length_sorted(&self, sorted_pmf: &[f64; 256]) -> f64 {
        let lengths = self.rank_lengths();
        sorted_pmf
            .iter()
            .zip(lengths.iter())
            .map(|(&p, &l)| p * l as f64)
            .sum()
    }

    /// The paper's compressibility metric against a sorted PMF.
    pub fn compressibility_sorted(&self, sorted_pmf: &[f64; 256]) -> f64 {
        (8.0 - self.expected_length_sorted(sorted_pmf)) / 8.0
    }

    /// Wasted code space: Σ (2^bits − size) over areas, in code points.
    /// Table 1 wastes 88 points in area 8; the optimizer minimizes
    /// expected length, not waste, but the bench reports both.
    pub fn slack_code_points(&self) -> u32 {
        self.areas
            .iter()
            .map(|a| (1u32 << a.symbol_bits) - a.size as u32)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let s = AreaScheme::table1();
        assert_eq!(s.prefix_bits, 3);
        assert_eq!(s.num_areas(), 8);
        let sizes: Vec<u16> = s.areas.iter().map(|a| a.size).collect();
        assert_eq!(sizes, vec![8, 8, 8, 8, 8, 16, 32, 168]);
        let lens: Vec<u32> = (0..8).map(|a| s.code_length(a)).collect();
        assert_eq!(lens, vec![6, 6, 6, 6, 6, 7, 8, 11]);
        assert_eq!(s.distinct_lengths(), vec![6, 7, 8, 11]); // "quad"
    }

    #[test]
    fn table1_symbol_ranges_match_paper() {
        // Paper Table 1 symbol ranges: 0-7, 8-15, …, 56-87, 88-255.
        let s = AreaScheme::table1();
        let bases: Vec<u32> = (0..8).map(|a| s.base_rank(a)).collect();
        assert_eq!(bases, vec![0, 8, 16, 24, 32, 40, 56, 88]);
    }

    #[test]
    fn table2_matches_paper() {
        let s = AreaScheme::table2();
        let sizes: Vec<u16> = s.areas.iter().map(|a| a.size).collect();
        assert_eq!(sizes, vec![2, 8, 8, 8, 8, 32, 32, 158]);
        let lens: Vec<u32> = (0..8).map(|a| s.code_length(a)).collect();
        assert_eq!(lens, vec![4, 6, 6, 6, 6, 8, 8, 11]);
        assert_eq!(s.distinct_lengths(), vec![4, 6, 8, 11]);
        let bases: Vec<u32> = (0..8).map(|a| s.base_rank(a)).collect();
        assert_eq!(bases, vec![0, 2, 10, 18, 26, 34, 66, 98]);
    }

    #[test]
    fn area_of_rank_inverts_base_rank() {
        for s in [AreaScheme::table1(), AreaScheme::table2()] {
            for rank in 0..256u32 {
                let a = s.area_of_rank(rank);
                assert!(s.base_rank(a) <= rank);
                assert!(rank < s.base_rank(a) + s.areas[a].size as u32);
            }
        }
    }

    #[test]
    fn rank_lengths_totals() {
        let s = AreaScheme::table1();
        let l = s.rank_lengths();
        assert_eq!(l[0], 6);
        assert_eq!(l[39], 6);
        assert_eq!(l[40], 7);
        assert_eq!(l[55], 7);
        assert_eq!(l[56], 8);
        assert_eq!(l[87], 8);
        assert_eq!(l[88], 11);
        assert_eq!(l[255], 11);
    }

    #[test]
    fn validation_rejects_bad_schemes() {
        // Wrong area count for prefix.
        assert!(AreaScheme::new(3, vec![Area { size: 256, symbol_bits: 8 }])
            .is_err());
        // Coverage != 256.
        let mut areas = vec![Area { size: 8, symbol_bits: 3 }; 8];
        assert!(AreaScheme::new(3, areas.clone()).is_err());
        // size > 2^bits.
        areas = vec![Area { size: 32, symbol_bits: 3 }; 8];
        assert!(AreaScheme::new(3, areas).is_err());
        // Empty area.
        let mut areas = vec![Area { size: 36, symbol_bits: 6 }; 7];
        areas.push(Area { size: 0, symbol_bits: 3 });
        assert!(AreaScheme::new(3, areas).is_err());
        // symbol_bits > 8.
        let areas = vec![
            Area { size: 249, symbol_bits: 9 },
            Area { size: 1, symbol_bits: 0 },
        ];
        assert!(AreaScheme::new(1, areas).is_err());
    }

    #[test]
    fn uniform_pmf_expected_lengths() {
        // Under uniform ranks, E[len] = Σ n_a (P + b_a) / 256.
        let s = AreaScheme::table1();
        let pmf = [1.0 / 256.0; 256];
        let expect = (5.0 * 8.0 * 6.0 + 16.0 * 7.0 + 32.0 * 8.0 + 168.0 * 11.0)
            / 256.0;
        assert!((s.expected_length_sorted(&pmf) - expect).abs() < 1e-12);
    }

    #[test]
    fn slack_code_points() {
        // Table 1: area 8 wastes 256-168 = 88.
        assert_eq!(AreaScheme::table1().slack_code_points(), 88);
        // Table 2: area 8 wastes 256-158 = 98.
        assert_eq!(AreaScheme::table2().slack_code_points(), 98);
    }

    #[test]
    fn skewed_pmf_prefers_table2() {
        // A zero-spiked sorted PMF: rank 0 dominates → Table 2's 4-bit
        // top code wins (the paper's §6 observation).
        let mut pmf = [0.0f64; 256];
        pmf[0] = 0.30;
        let rest = 0.70 / 255.0;
        for p in pmf[1..].iter_mut() {
            *p = rest;
        }
        let t1 = AreaScheme::table1().expected_length_sorted(&pmf);
        let t2 = AreaScheme::table2().expected_length_sorted(&pmf);
        assert!(t2 < t1, "t2 {t2} should beat t1 {t1}");
    }
}
