//! Optimal area-scheme construction — the mathematical formulation the
//! paper defers to future work (§8: "tweak the number of areas, the
//! number of symbols in each area, and the number of unique code
//! lengths").
//!
//! Model: fix the prefix width `P` (so `K = 2^P` areas).  Choose per-
//! area suffix widths `b_1..b_K ∈ 0..=8` and sizes `n_a ≤ 2^{b_a}`
//! with `Σ n_a = 256`, assigning areas to consecutive runs of the
//! descending-sorted PMF.  Minimize `Σ_a (P + b_a) · Pr[area a]`.
//!
//! For a fixed left-to-right assignment it is never beneficial to
//! under-fill a non-final area (moving a symbol rightward can only
//! lengthen its code), so the DP only considers full areas (clipped at
//! the tail), which makes it exact in O(K · 256 · 9).

use super::scheme::{Area, AreaScheme};

/// Exact DP for a fixed prefix width. `sorted_pmf[r]` = probability of
/// rank `r` (descending).
pub fn optimize_for_prefix(
    sorted_pmf: &[f64; 256],
    prefix_bits: u32,
) -> AreaScheme {
    assert!((1..=8).contains(&prefix_bits));
    let k = 1usize << prefix_bits;
    // Suffix of cumulative probability: cum[i] = Σ_{r ≥ i} p_r.
    let mut cum = [0f64; 257];
    for i in (0..256).rev() {
        cum[i] = cum[i + 1] + sorted_pmf[i];
    }

    const INF: f64 = f64::INFINITY;
    // dp[a][pos] = min expected bits for ranks pos.. using areas a..K-1.
    let mut dp = vec![[INF; 257]; k + 1];
    let mut choice = vec![[usize::MAX; 257]; k];
    dp[k][256] = 0.0;
    for a in (0..k).rev() {
        dp[a][256] = 0.0; // all symbols covered; remaining prefixes unused
        for pos in (0..256usize).rev() {
            let areas_left = k - a;
            let remaining = 256 - pos;
            // Even at 8 bits each, the areas left must be able to cover
            // the remainder.
            if areas_left * 256 < remaining {
                continue;
            }
            for b in 0..=8u32 {
                let n = (1usize << b).min(remaining);
                let cost = (prefix_bits + b) as f64 * (cum[pos] - cum[pos + n]);
                let rest = dp[a + 1][pos + n];
                if rest.is_finite() && cost + rest < dp[a][pos] {
                    dp[a][pos] = cost + rest;
                    choice[a][pos] = b as usize;
                }
            }
        }
    }
    assert!(dp[0][0].is_finite(), "DP failed to cover the alphabet");

    // Reconstruct. Unused trailing areas (pos hit 256 early) are padded
    // as 1-symbol areas stolen from the last real area so the scheme
    // stays structurally valid (the prefix space must be fully mapped).
    let mut areas: Vec<Area> = Vec::with_capacity(k);
    let mut pos = 0usize;
    let mut a = 0usize;
    while a < k && pos < 256 {
        let b = choice[a][pos];
        debug_assert!(b != usize::MAX);
        let n = (1usize << b).min(256 - pos);
        areas.push(Area { size: n as u16, symbol_bits: b as u32 });
        pos += n;
        a += 1;
    }
    while areas.len() < k {
        // Donate one symbol per missing area from the largest area.
        let donor = areas
            .iter()
            .enumerate()
            .max_by_key(|(_, ar)| ar.size)
            .map(|(i, _)| i)
            .unwrap();
        assert!(areas[donor].size > 1, "cannot pad scheme to {k} areas");
        areas[donor].size -= 1;
        areas.push(Area { size: 1, symbol_bits: 0 });
    }
    AreaScheme::new(prefix_bits, areas).expect("DP produced a valid scheme")
}

/// Search prefix widths 1..=4 and return the best scheme overall.
pub fn optimize_scheme(sorted_pmf: &[f64; 256]) -> AreaScheme {
    (1..=4u32)
        .map(|p| optimize_for_prefix(sorted_pmf, p))
        .min_by(|a, b| {
            a.expected_length_sorted(sorted_pmf)
                .partial_cmp(&b.expected_length_sorted(sorted_pmf))
                .unwrap()
        })
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn exp_pmf(rate: f64) -> [f64; 256] {
        let mut p = [0f64; 256];
        let mut sum = 0.0;
        for (i, v) in p.iter_mut().enumerate() {
            *v = (-rate * i as f64).exp();
            sum += *v;
        }
        for v in p.iter_mut() {
            *v /= sum;
        }
        p
    }

    fn spiked_pmf(spike: f64, rate: f64) -> [f64; 256] {
        let mut p = exp_pmf(rate);
        let rest: f64 = 1.0 - spike;
        let tail_sum: f64 = p[1..].iter().sum();
        p[0] = spike;
        for v in p[1..].iter_mut() {
            *v *= rest / tail_sum;
        }
        p
    }

    #[test]
    fn uniform_pmf_gets_flat_8bit_scheme() {
        let pmf = [1.0 / 256.0; 256];
        for p in 1..=4u32 {
            let s = optimize_for_prefix(&pmf, p);
            let el = s.expected_length_sorted(&pmf);
            // Cannot beat 8 bits on uniform, but the prefix forces
            // p + b ≥ 8 only if it uses one big area; the optimum is
            // areas of 2^(8-p) → length exactly 8.
            assert!((el - 8.0).abs() < 1e-9, "p={p} el={el}");
        }
    }

    #[test]
    fn optimized_beats_or_ties_table1_on_smooth_pmf() {
        let pmf = exp_pmf(0.022); // entropy ≈ paper's FFN1-like shape
        let t1 = AreaScheme::table1().expected_length_sorted(&pmf);
        let opt = optimize_for_prefix(&pmf, 3).expected_length_sorted(&pmf);
        assert!(opt <= t1 + 1e-12, "opt {opt} vs t1 {t1}");
    }

    #[test]
    fn optimized_beats_or_ties_table2_on_spiked_pmf() {
        let pmf = spiked_pmf(0.25, 0.02);
        let t2 = AreaScheme::table2().expected_length_sorted(&pmf);
        let opt = optimize_for_prefix(&pmf, 3).expected_length_sorted(&pmf);
        assert!(opt <= t2 + 1e-12, "opt {opt} vs t2 {t2}");
    }

    #[test]
    fn never_below_entropy() {
        prop::check("optimizer ≥ entropy", prop::Config {
            cases: 32, ..Default::default()
        }, |rng, _| {
            let mut p = [0f64; 256];
            let mut sum = 0.0;
            for v in p.iter_mut() {
                *v = rng.uniform().powi(3) + 1e-9;
                sum += *v;
            }
            for v in p.iter_mut() {
                *v /= sum;
            }
            // Sort descending (optimizer contract).
            p.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let h: f64 = -p.iter().map(|&x| x * x.log2()).sum::<f64>();
            let s = optimize_scheme(&p);
            let el = s.expected_length_sorted(&p);
            if el < h - 1e-9 {
                return Err(format!("expected length {el} < entropy {h}"));
            }
            Ok(())
        });
    }

    #[test]
    fn spike_earns_short_top_area() {
        // With a dominant rank-0 symbol the optimizer must give it a
        // short code (area of 1–2 symbols), like the paper's Table 2.
        let pmf = spiked_pmf(0.4, 0.02);
        let s = optimize_for_prefix(&pmf, 3);
        assert!(
            s.areas[0].size <= 2,
            "first area holds {} symbols",
            s.areas[0].size
        );
        assert!(s.code_length(0) <= 4);
    }

    #[test]
    fn prefix_search_picks_reasonable_width() {
        // Extremely peaked: almost everything is rank 0 → small prefix
        // wins (1-bit prefix + empty suffix = 1-bit top code beats a
        // 3-bit prefix).
        let pmf = spiked_pmf(0.95, 0.05);
        let best = optimize_scheme(&pmf);
        let el_best = best.expected_length_sorted(&pmf);
        let el_p3 = optimize_for_prefix(&pmf, 3).expected_length_sorted(&pmf);
        assert!(el_best <= el_p3 + 1e-12);
        assert!(best.prefix_bits <= 2, "prefix {}", best.prefix_bits);
    }

    #[test]
    fn schemes_are_always_valid() {
        prop::check("optimizer validity", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, _| {
            let mut p = [0f64; 256];
            let mut sum = 0.0;
            for v in p.iter_mut() {
                *v = rng.uniform().powi(rng.below(5) as i32 + 1) + 1e-12;
                sum += *v;
            }
            for v in p.iter_mut() {
                *v /= sum;
            }
            p.sort_by(|a, b| b.partial_cmp(a).unwrap());
            for prefix in 1..=4u32 {
                let s = optimize_for_prefix(&p, prefix);
                // AreaScheme::new re-validates; also check coverage.
                let total: u32 = s.areas.iter().map(|a| a.size as u32).sum();
                if total != 256 {
                    return Err(format!("coverage {total}"));
                }
                if s.areas.len() != 1 << prefix {
                    return Err("area count".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn monotone_widths_along_ranks() {
        // On a strictly decreasing PMF the chosen suffix widths must be
        // nondecreasing (shorter codes for more probable ranks).
        let pmf = exp_pmf(0.03);
        let s = optimize_for_prefix(&pmf, 3);
        let widths: Vec<u32> = s.areas.iter().map(|a| a.symbol_bits).collect();
        let mut sorted = widths.clone();
        sorted.sort_unstable();
        assert_eq!(widths, sorted, "{widths:?}");
    }
}
