//! Scheme + LUT (de)serialization.
//!
//! Two encodings:
//! * binary — compact header for the frame container and the collective
//!   transport: `prefix_bits u8 | K × (size u16-le, bits u8) | 256-byte
//!   rank order`;
//! * JSON — human-readable (`qlc tables --table 3 --json`, shipping
//!   per-tensor-type LUT files as paper §7 / ref \[12\] suggests).

use super::codec::QlcCodec;
use super::scheme::{Area, AreaScheme};
use crate::util::json::Json;

/// Serialize a bare rank order (the QLF2 per-chunk table *delta*: the
/// chunk keeps the frame's area scheme but re-ranks the symbols).
pub fn rank_to_bytes(rank_order: &[u8; 256]) -> Vec<u8> {
    rank_order.to_vec()
}

/// Parse and validate a bare rank order — must be exactly 256 bytes
/// and a permutation of 0..=255.
pub fn rank_from_bytes(data: &[u8]) -> Result<[u8; 256], String> {
    if data.len() != 256 {
        return Err(format!("rank order is {} bytes, want 256", data.len()));
    }
    let mut rank = [0u8; 256];
    rank.copy_from_slice(data);
    let mut seen = [false; 256];
    for &s in rank.iter() {
        if seen[s as usize] {
            return Err(format!("rank order repeats symbol {s}"));
        }
        seen[s as usize] = true;
    }
    Ok(rank)
}

/// Serialize scheme + rank order to the binary header format.
pub fn to_bytes(codec: &QlcCodec) -> Vec<u8> {
    let scheme = codec.scheme();
    // lint: cap-checked(sized by the in-memory scheme: ≤ 256 areas)
    let mut out = Vec::with_capacity(2 + scheme.num_areas() * 3 + 256);
    // lint: cast-checked(AreaScheme::new caps prefix_bits at 8)
    out.push(scheme.prefix_bits as u8);
    for a in &scheme.areas {
        out.extend_from_slice(&a.size.to_le_bytes());
        // lint: cast-checked(AreaScheme::new caps symbol_bits at 8)
        out.push(a.symbol_bits as u8);
    }
    out.extend_from_slice(codec.rank_order());
    out
}

/// Parse the binary header back into a codec.
pub fn from_bytes(data: &[u8], label: &str) -> Result<QlcCodec, String> {
    if data.is_empty() {
        return Err("empty qlc header".into());
    }
    let prefix_bits = u32::from(data[0]);
    if !(1..=8).contains(&prefix_bits) {
        return Err(format!("bad prefix_bits {prefix_bits}"));
    }
    let k = 1usize << prefix_bits;
    let need = 1 + k * 3 + 256;
    if data.len() != need {
        return Err(format!("qlc header is {} bytes, want {need}", data.len()));
    }
    let mut areas = Vec::with_capacity(k);
    for i in 0..k {
        let off = 1 + i * 3;
        let size = u16::from_le_bytes([data[off], data[off + 1]]);
        let bits = u32::from(data[off + 2]);
        areas.push(Area { size, symbol_bits: bits });
    }
    let scheme = AreaScheme::new(prefix_bits, areas)?;
    // Permutation check (from_rank_order panics; validate first).
    let rank = rank_from_bytes(&data[1 + k * 3..])?;
    Ok(QlcCodec::from_rank_order(scheme, &rank, label))
}

/// JSON form: scheme structure + encoder/decoder tables.
pub fn to_json(codec: &QlcCodec) -> Json {
    let scheme = codec.scheme();
    let areas: Vec<Json> = scheme
        .areas
        .iter()
        .enumerate()
        .map(|(i, a)| {
            Json::obj()
                .set("area", i + 1)
                .set(
                    "area_code",
                    format!(
                        "{:0width$b}",
                        i,
                        width = scheme.prefix_bits as usize
                    ),
                )
                .set("symbols", a.size as usize)
                .set("symbol_bits", a.symbol_bits as usize)
                .set("code_length", scheme.code_length(i) as usize)
                .set(
                    "symbol_range",
                    format!(
                        "{}-{}",
                        scheme.base_rank(i),
                        scheme.base_rank(i) + u32::from(a.size) - 1
                    ),
                )
        })
        .collect();
    let rank: Vec<Json> = codec
        .rank_order()
        .iter()
        .map(|&s| Json::from(s as usize))
        .collect();
    Json::obj()
        .set("prefix_bits", scheme.prefix_bits as usize)
        .set("areas", Json::Arr(areas))
        .set("decoder_lut", Json::Arr(rank))
}

/// Parse the JSON form.
pub fn from_json(v: &Json, label: &str) -> Result<QlcCodec, String> {
    let prefix_raw = v
        .get("prefix_bits")
        .and_then(Json::as_usize)
        .ok_or("missing prefix_bits")?;
    // Checked narrowing: an oversized JSON value must be rejected, not
    // silently truncated into a plausible-looking small one.
    let prefix_bits = u32::try_from(prefix_raw)
        .map_err(|_| format!("prefix_bits {prefix_raw} out of range"))?;
    let areas_json = v
        .get("areas")
        .and_then(Json::as_arr)
        .ok_or("missing areas")?;
    // lint: cap-checked(sized by the already-materialized JSON array)
    let mut areas = Vec::with_capacity(areas_json.len());
    for a in areas_json {
        let symbols = a
            .get("symbols")
            .and_then(Json::as_usize)
            .ok_or("area missing symbols")?;
        let symbol_bits = a
            .get("symbol_bits")
            .and_then(Json::as_usize)
            .ok_or("area missing symbol_bits")?;
        areas.push(Area {
            size: u16::try_from(symbols)
                .map_err(|_| format!("area symbols {symbols} out of range"))?,
            symbol_bits: u32::try_from(symbol_bits).map_err(|_| {
                format!("area symbol_bits {symbol_bits} out of range")
            })?,
        });
    }
    let scheme = AreaScheme::new(prefix_bits, areas)?;
    let lut = v
        .get("decoder_lut")
        .and_then(Json::as_arr)
        .ok_or("missing decoder_lut")?;
    if lut.len() != 256 {
        return Err(format!("decoder_lut has {} entries", lut.len()));
    }
    let mut rank = [0u8; 256];
    let mut seen = [false; 256];
    for (i, e) in lut.iter().enumerate() {
        let s = e.as_usize().ok_or("non-numeric lut entry")?;
        if s > 255 || seen[s] {
            return Err(format!("bad lut entry {s}"));
        }
        seen[s] = true;
        rank[i] = s as u8;
    }
    Ok(QlcCodec::from_rank_order(scheme, &rank, label))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::Codec;
    use crate::stats::Histogram;
    use crate::util::rng::Rng;

    fn sample_codec() -> QlcCodec {
        let mut rng = Rng::new(77);
        let symbols: Vec<u8> =
            (0..50_000).map(|_| (rng.normal().abs() * 40.0) as u8).collect();
        let pmf = Histogram::from_symbols(&symbols).pmf();
        QlcCodec::from_pmf(AreaScheme::table1(), &pmf)
    }

    #[test]
    fn binary_roundtrip() {
        let codec = sample_codec();
        let bytes = to_bytes(&codec);
        assert_eq!(bytes.len(), 1 + 8 * 3 + 256);
        let back = from_bytes(&bytes, "qlc").unwrap();
        assert_eq!(back.scheme(), codec.scheme());
        assert_eq!(back.rank_order(), codec.rank_order());
        // Streams decode identically.
        let data: Vec<u8> = (0..=255).collect();
        let enc = codec.encode_to_vec(&data);
        assert_eq!(back.decode_from_slice(&enc, 256).unwrap(), data);
    }

    #[test]
    fn binary_rejects_corruption() {
        let codec = sample_codec();
        let bytes = to_bytes(&codec);
        // Truncated.
        assert!(from_bytes(&bytes[..bytes.len() - 1], "x").is_err());
        // Bad prefix.
        let mut bad = bytes.clone();
        bad[0] = 9;
        assert!(from_bytes(&bad, "x").is_err());
        // Duplicate rank entry.
        let mut bad = bytes.clone();
        let base = 1 + 8 * 3;
        bad[base] = bad[base + 1];
        assert!(from_bytes(&bad, "x").is_err());
        // Broken coverage (area size).
        let mut bad = bytes;
        bad[1] = 0xFF;
        bad[2] = 0xFF;
        assert!(from_bytes(&bad, "x").is_err());
    }

    #[test]
    fn rank_order_roundtrip_and_validation() {
        let codec = sample_codec();
        let bytes = rank_to_bytes(codec.rank_order());
        assert_eq!(bytes.len(), 256);
        assert_eq!(&rank_from_bytes(&bytes).unwrap(), codec.rank_order());
        // Wrong length.
        assert!(rank_from_bytes(&bytes[..255]).is_err());
        // Duplicate entry.
        let mut dup = bytes.clone();
        dup[0] = dup[1];
        assert!(rank_from_bytes(&dup).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let codec = sample_codec();
        let j = to_json(&codec);
        let text = j.to_string_pretty();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let back = from_json(&parsed, "qlc").unwrap();
        assert_eq!(back.scheme(), codec.scheme());
        assert_eq!(back.rank_order(), codec.rank_order());
    }

    /// Regression: oversized JSON integers used to be `as`-truncated
    /// into plausible small values (e.g. `symbols: 65552` → 16, which
    /// still sums to 256 and parses "successfully" as the wrong
    /// scheme).  They must be rejected outright.
    #[cfg(target_pointer_width = "64")]
    #[test]
    fn json_rejects_out_of_range_scheme_fields() {
        let codec = sample_codec();
        let text = to_json(&codec).to_string_pretty();

        // prefix_bits = 2^32 + 3 used to truncate to 3 and round-trip.
        let big_prefix = (1usize << 32) + 3;
        let bad = text.replacen(
            "\"prefix_bits\": 3",
            &format!("\"prefix_bits\": {big_prefix}"),
            1,
        );
        assert_ne!(bad, text, "fixture must actually rewrite the field");
        let parsed = crate::util::json::Json::parse(&bad).unwrap();
        let err = from_json(&parsed, "x").unwrap_err();
        assert!(err.contains("out of range"), "{err}");

        // symbols = 65536 + 16 used to truncate to 16 (the true area
        // size) and be accepted.
        let bad = text.replacen(
            "\"symbols\": 16",
            &format!("\"symbols\": {}", (1usize << 16) + 16),
            1,
        );
        assert_ne!(bad, text, "fixture must actually rewrite the field");
        let parsed = crate::util::json::Json::parse(&bad).unwrap();
        let err = from_json(&parsed, "x").unwrap_err();
        assert!(err.contains("out of range"), "{err}");

        // symbol_bits = 2^32 + 4 likewise truncated to 4.
        let bad = text.replacen(
            "\"symbol_bits\": 4",
            &format!("\"symbol_bits\": {}", (1usize << 32) + 4),
            1,
        );
        assert_ne!(bad, text, "fixture must actually rewrite the field");
        let parsed = crate::util::json::Json::parse(&bad).unwrap();
        let err = from_json(&parsed, "x").unwrap_err();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn json_matches_paper_table1_layout() {
        let codec = QlcCodec::from_rank_order(
            AreaScheme::table1(),
            codec_identity_rank(),
            "qlc-t1",
        );
        let j = to_json(&codec);
        let areas = j.get("areas").unwrap().as_arr().unwrap();
        assert_eq!(areas.len(), 8);
        // Paper Table 1 row 6: area code 101, 16 symbols, 4 bits, len 7,
        // range 40-55.
        let a6 = &areas[5];
        assert_eq!(a6.get("area_code").unwrap().as_str(), Some("101"));
        assert_eq!(a6.get("symbols").unwrap().as_usize(), Some(16));
        assert_eq!(a6.get("code_length").unwrap().as_usize(), Some(7));
        assert_eq!(a6.get("symbol_range").unwrap().as_str(), Some("40-55"));
    }

    fn codec_identity_rank() -> &'static [u8; 256] {
        static RANK: [u8; 256] = {
            let mut r = [0u8; 256];
            let mut i = 0;
            while i < 256 {
                r[i] = i as u8;
                i += 1;
            }
            r
        };
        &RANK
    }
}
