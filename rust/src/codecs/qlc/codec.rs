//! The QLC encoder/decoder bound to a concrete PMF.
//!
//! Construction mirrors the paper §7: sort symbols by decreasing
//! probability, map to ranks 0..=255, assign each rank the code of its
//! area (Table 3).  Encoding is one 256-entry LUT lookup; decoding is a
//! `2^P`-entry prefix table (suffix width + base rank) followed by one
//! 256-entry LUT (Table 4) — no tree, no bit-serial scan.

use super::scheme::AreaScheme;
use crate::bitstream::{BitReader, BitWriter};
use crate::codecs::kernel::{
    BitCursor, BitSink, DecodeKernel, EncodeKernel, EncodeLane, Lane,
};
use crate::codecs::{Codec, CodecError};
use crate::stats::Pmf;

#[derive(Clone, Copy, Debug)]
struct FastEntry {
    total_len: u32,
    /// `64 - total_len`: right-shift that drops everything below this
    /// code in the staging word.
    word_shift: u32,
    suffix_mask: u32,
    base: u32,
    size: u32,
}

/// Encoder/decoder LUTs for (scheme, rank order).
#[derive(Clone, Debug)]
pub struct QlcCodec {
    scheme: AreaScheme,
    /// Paper Table 3: symbol value → full code word.
    enc_code: [u32; 256],
    /// … and its length in bits.
    enc_len: [u8; 256],
    /// Decode fast path, indexed by prefix: total code length, suffix
    /// mask and base rank — lets `decode_one` resolve a symbol from a
    /// single 16-bit peek (EXPERIMENTS.md §Perf).
    fast_table: Vec<FastEntry>,
    max_code_bits: u32,
    /// Paper Table 4: rank (encoded symbol) → output symbol.
    rank_to_symbol: [u8; 256],
    /// Inverse: symbol → rank.
    symbol_to_rank: [u8; 256],
    label: String,
}

impl QlcCodec {
    /// Build from a scheme and a measured PMF (paper §7).
    pub fn from_pmf(scheme: AreaScheme, pmf: &Pmf) -> Self {
        Self::from_rank_order(scheme, &pmf.rank_order(), "qlc")
    }

    /// Build from an explicit rank order (frame decode path; also lets
    /// tests pin the permutation).
    pub fn from_rank_order(
        scheme: AreaScheme,
        rank_order: &[u8; 256],
        label: &str,
    ) -> Self {
        let mut rank_to_symbol = [0u8; 256];
        let mut symbol_to_rank = [0u8; 256];
        let mut seen = [false; 256];
        for (rank, &sym) in rank_order.iter().enumerate() {
            assert!(!seen[sym as usize], "rank order is not a permutation");
            seen[sym as usize] = true;
            rank_to_symbol[rank] = sym;
            symbol_to_rank[sym as usize] = rank as u8;
        }

        let mut enc_code = [0u32; 256];
        let mut enc_len = [0u8; 256];
        for rank in 0..256u32 {
            let area = scheme.area_of_rank(rank);
            let bits = scheme.areas[area].symbol_bits;
            let offset = rank - scheme.base_rank(area);
            let code = ((area as u32) << bits) | offset;
            let len = scheme.code_length(area);
            let sym = rank_to_symbol[rank as usize] as usize;
            enc_code[sym] = code;
            enc_len[sym] = len as u8;
        }

        let fast_table: Vec<FastEntry> = (0..scheme.num_areas())
            .map(|a| FastEntry {
                total_len: scheme.code_length(a),
                word_shift: 64 - scheme.code_length(a),
                suffix_mask: (1u32 << scheme.areas[a].symbol_bits) - 1,
                base: scheme.base_rank(a),
                size: scheme.areas[a].size as u32,
            })
            .collect();
        // lint: infallible(AreaScheme::new rejects schemes with no areas)
        let max_code_bits = (0..scheme.num_areas())
            .map(|a| scheme.code_length(a))
            .max()
            .unwrap();

        QlcCodec {
            scheme,
            enc_code,
            enc_len,
            fast_table,
            max_code_bits,
            rank_to_symbol,
            symbol_to_rank,
            label: label.to_string(),
        }
    }

    pub fn scheme(&self) -> &AreaScheme {
        &self.scheme
    }

    pub fn rank_order(&self) -> &[u8; 256] {
        &self.rank_to_symbol
    }

    /// Paper Table 3 row for one input symbol:
    /// (input symbol, mapped rank, code, length).
    #[inline]
    pub fn encoder_row(&self, s: u8) -> (u8, u8, u32, u8) {
        let i = s as usize;
        (s, self.symbol_to_rank[i], self.enc_code[i], self.enc_len[i])
    }

    /// Paper Table 3 rows: (input symbol, mapped rank, code, length).
    /// A borrowed view over the LUTs the codec already holds — nothing
    /// is rebuilt or allocated per call.
    pub fn encoder_table(
        &self,
    ) -> impl Iterator<Item = (u8, u8, u32, u8)> + '_ {
        (0..=255u8).map(|s| self.encoder_row(s))
    }

    /// Paper Table 4 row for one encoded symbol (rank):
    /// (encoded symbol/rank, output symbol).
    #[inline]
    pub fn decoder_row(&self, rank: u8) -> (u8, u8) {
        (rank, self.rank_to_symbol[rank as usize])
    }

    /// Paper Table 4 rows: (encoded symbol/rank, output symbol) — a
    /// borrowed view, like [`encoder_table`](Self::encoder_table).
    pub fn decoder_table(&self) -> impl Iterator<Item = (u8, u8)> + '_ {
        (0..=255u8).map(|r| self.decoder_row(r))
    }

    /// Decode one symbol: a single peek covering prefix + longest
    /// suffix, one table lookup, one skip.  Matches the 2-stage
    /// hardware pipeline in `crate::hw::QlcModel`.
    #[inline]
    pub fn decode_one(&self, reader: &mut BitReader) -> Result<u8, CodecError> {
        let p = self.scheme.prefix_bits;
        let w = reader.peek(self.max_code_bits);
        let area = (w >> (self.max_code_bits - p)) as usize;
        let e = &self.fast_table[area];
        let idx = (w >> (self.max_code_bits - e.total_len)) & e.suffix_mask;
        if reader.remaining_bits() < e.total_len as u64 {
            return Err(CodecError::UnexpectedEof);
        }
        if idx >= e.size {
            return Err(CodecError::InvalidCode {
                bit_offset: reader.bits_consumed(),
            });
        }
        reader.skip(e.total_len);
        Ok(self.rank_to_symbol[(e.base + idx) as usize])
    }

    /// Resolve one whole code for `lane` from its staging word `w` and
    /// pre-extracted `area` index.  The single copy of the
    /// validate/consume/store sequence both burst flavours call, so
    /// the proptested lanes ≡ batched equivalence cannot diverge
    /// between the scalar and AVX2 paths.
    #[inline]
    fn resolve_lane_code(
        &self,
        lane: &mut Lane<'_, '_>,
        w: u64,
        area: usize,
    ) -> Result<(), CodecError> {
        let e = &self.fast_table[area];
        let idx = (w >> e.word_shift) as u32 & e.suffix_mask;
        if idx >= e.size {
            return Err(CodecError::InvalidCode {
                bit_offset: lane.cur.bits_consumed(),
            });
        }
        lane.cur.consume(e.total_len);
        lane.out[lane.pos] = self.rank_to_symbol[(e.base + idx) as usize];
        lane.pos += 1;
        Ok(())
    }

    /// One lockstep burst: resolve `rounds` whole codes from every
    /// unfinished lane, lane-major, so the per-lane table chains run
    /// independently.  The caller sized `rounds` from every unfinished
    /// lane's refilled budget, so no refill or EOF check is needed
    /// inside the burst.
    fn lockstep_scalar(
        &self,
        lanes: &mut [Lane<'_, '_>],
        rounds: usize,
    ) -> Result<(), CodecError> {
        let prefix_shift = 64 - self.scheme.prefix_bits;
        for _ in 0..rounds {
            for lane in lanes.iter_mut() {
                if lane.remaining() == 0 {
                    continue;
                }
                let w = lane.cur.word();
                self.resolve_lane_code(
                    lane,
                    w,
                    (w >> prefix_shift) as usize,
                )?;
            }
        }
        Ok(())
    }

    /// AVX2 burst for a full 8-lane group: one vector shift peeks all
    /// eight area prefixes per round; suffix extraction and the rank
    /// LUT stay scalar (suffix widths vary per lane).
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    fn lockstep_avx2(
        &self,
        lanes: &mut [Lane<'_, '_>],
        rounds: usize,
    ) -> Result<(), CodecError> {
        debug_assert_eq!(lanes.len(), 8);
        let prefix_bits = self.scheme.prefix_bits;
        for _ in 0..rounds {
            let mut words = [0u64; 8];
            for (w, lane) in words.iter_mut().zip(lanes.iter()) {
                *w = lane.cur.word();
            }
            // SAFETY: this path is only dispatched after
            // `lanes_avx2_available()` reported AVX2.
            let areas = unsafe {
                crate::codecs::kernel::peek_top_bits_x8(&words, prefix_bits)
            };
            for (lane, (&w, &area)) in
                lanes.iter_mut().zip(words.iter().zip(areas.iter()))
            {
                self.resolve_lane_code(lane, w, area as usize)?;
            }
        }
        Ok(())
    }

    /// NEON burst for a full 8-lane group — the aarch64 mirror of
    /// [`lockstep_avx2`](Self::lockstep_avx2): one vector shift peeks
    /// all eight area prefixes per round; suffix extraction and the
    /// rank LUT stay scalar (suffix widths vary per lane).
    #[cfg(all(target_arch = "aarch64", not(miri)))]
    fn lockstep_neon(
        &self,
        lanes: &mut [Lane<'_, '_>],
        rounds: usize,
    ) -> Result<(), CodecError> {
        debug_assert_eq!(lanes.len(), 8);
        let prefix_bits = self.scheme.prefix_bits;
        for _ in 0..rounds {
            let mut words = [0u64; 8];
            for (w, lane) in words.iter_mut().zip(lanes.iter()) {
                *w = lane.cur.word();
            }
            // SAFETY: this path is only dispatched after
            // `lanes_neon_available()` reported NEON.
            let areas = unsafe {
                crate::codecs::kernel::peek_top_bits_x8_neon(
                    &words,
                    prefix_bits,
                )
            };
            for (lane, (&w, &area)) in
                lanes.iter_mut().zip(words.iter().zip(areas.iter()))
            {
                self.resolve_lane_code(lane, w, area as usize)?;
            }
        }
        Ok(())
    }

    /// Cursor analogue of [`decode_one`](Self::decode_one) — the
    /// kernel's slow tail when fewer than `max_code_bits` are buffered.
    #[inline]
    fn decode_one_cursor(&self, cur: &mut BitCursor) -> Result<u8, CodecError> {
        cur.refill();
        let w = cur.word();
        let area = (w >> (64 - self.scheme.prefix_bits)) as usize;
        let e = &self.fast_table[area];
        if cur.remaining_bits() < e.total_len as u64 {
            return Err(CodecError::UnexpectedEof);
        }
        let idx = (w >> e.word_shift) as u32 & e.suffix_mask;
        if idx >= e.size {
            return Err(CodecError::InvalidCode {
                bit_offset: cur.bits_consumed(),
            });
        }
        cur.consume(e.total_len);
        Ok(self.rank_to_symbol[(e.base + idx) as usize])
    }
}

impl DecodeKernel for QlcCodec {
    /// Word-at-a-time table decode: one refill covers ⌊avail/max⌋
    /// symbols — up to 9 six-bit codes per 64-bit window — with no
    /// refill or EOF checks inside the run (every code is ≤ max bits).
    /// One `2^P`-entry prefix lookup yields the suffix width, mask and
    /// base rank; a second 256-entry LUT maps rank → symbol (paper
    /// Table 4).
    fn decode_batch(
        &self,
        cur: &mut BitCursor,
        out: &mut [u8],
    ) -> Result<usize, CodecError> {
        let n = out.len();
        let max = self.max_code_bits;
        let prefix_shift = 64 - self.scheme.prefix_bits;
        let mut i = 0usize;
        while i < n {
            let avail = cur.refill_buffered();
            if avail < max {
                // Tail: the final codes may be shorter than max, so
                // fall back to the checked single-symbol step.
                out[i] = self.decode_one_cursor(cur)?;
                i += 1;
                continue;
            }
            let k = ((avail / max) as usize).min(n - i);
            for slot in &mut out[i..i + k] {
                let w = cur.word();
                let area = (w >> prefix_shift) as usize;
                let e = &self.fast_table[area];
                let idx = (w >> e.word_shift) as u32 & e.suffix_mask;
                if idx >= e.size {
                    return Err(CodecError::InvalidCode {
                        bit_offset: cur.bits_consumed(),
                    });
                }
                cur.consume(e.total_len);
                *slot = self.rank_to_symbol[(e.base + idx) as usize];
            }
            i += k;
        }
        Ok(n)
    }

    /// Lane-interleaved lockstep decode: every unfinished lane refills
    /// once, then a burst of `rounds` codes is resolved from each lane
    /// in lane-major order, so the prefix-table lookups of independent
    /// chunks overlap in the pipeline instead of serializing on one
    /// cursor's shift-consume chain.  A full 8-lane group takes the
    /// vector-peek path when the CPU has one (AVX2 on x86_64, NEON on
    /// aarch64, runtime-detected);
    /// ragged tails fall back to the checked batched path, keeping
    /// lane decode ≡ batched decode symbol-for-symbol and
    /// consumed-bit-for-bit.
    fn decode_lanes(
        &self,
        lanes: &mut [Lane<'_, '_>],
    ) -> Result<(), CodecError> {
        let max = self.max_code_bits;
        loop {
            // Size one burst: the largest `rounds` every unfinished
            // lane can sustain without another refill or EOF check.
            // A lane that reaches its sub-word tail (its final codes
            // may be shorter than `max_code_bits`) is finished right
            // here on the checked batched path — which surfaces
            // EOF/InvalidCode exactly like batched decode would — so
            // the *group* stays in lockstep instead of collapsing to
            // serial because one ragged chunk ran short.
            let mut rounds = usize::MAX;
            let mut unfinished = 0usize;
            for lane in lanes.iter_mut() {
                if lane.remaining() == 0 {
                    continue;
                }
                let avail = lane.cur.refill_buffered();
                if avail < max {
                    let pos = lane.pos;
                    let n = self
                        .decode_batch(&mut lane.cur, &mut lane.out[pos..])?;
                    lane.pos += n;
                    continue;
                }
                unfinished += 1;
                rounds = rounds
                    .min(((avail / max) as usize).min(lane.remaining()));
            }
            if unfinished == 0 {
                return Ok(());
            }
            #[cfg(all(target_arch = "x86_64", not(miri)))]
            if unfinished == 8
                && lanes.len() == 8
                && crate::codecs::kernel::lanes_avx2_available()
            {
                self.lockstep_avx2(lanes, rounds)?;
                continue;
            }
            #[cfg(all(target_arch = "aarch64", not(miri)))]
            if unfinished == 8
                && lanes.len() == 8
                && crate::codecs::kernel::lanes_neon_available()
            {
                self.lockstep_neon(lanes, rounds)?;
                continue;
            }
            self.lockstep_scalar(lanes, rounds)?;
        }
    }

    /// Every QLC code resolves from one `max_code_bits`-wide window of
    /// a refilled staging word, so table-delta chunks can ride mixed
    /// lockstep groups next to fixed-table chunks.
    fn lockstep_bits(&self) -> Option<u32> {
        Some(self.max_code_bits)
    }

    fn lane_step(&self, lane: &mut Lane<'_, '_>) -> Result<(), CodecError> {
        let w = lane.cur.word();
        self.resolve_lane_code(
            lane,
            w,
            (w >> (64 - self.scheme.prefix_bits)) as usize,
        )
    }
}

impl EncodeKernel for QlcCodec {
    /// The single-stage encoder (paper §7 mirrored onto software): one
    /// `enc_code`/`enc_len` LUT read per symbol, shift-or into a local
    /// accumulator.  Every code is ≤ 13 bits, so four codes (≤ 52
    /// bits) always fit one staging-word push — the sink's word-fill
    /// bookkeeping runs once per *quad*, not once per code.
    fn encode_batch(&self, symbols: &[u8], sink: &mut BitSink) {
        let mut quads = symbols.chunks_exact(4);
        for quad in quads.by_ref() {
            let mut acc = 0u64;
            let mut bits = 0u32;
            for &s in quad {
                let len = self.enc_len[s as usize] as u32;
                acc = (acc << len) | self.enc_code[s as usize] as u64;
                bits += len;
            }
            sink.push(acc, bits);
        }
        for &s in quads.remainder() {
            sink.push(
                self.enc_code[s as usize] as u64,
                self.enc_len[s as usize] as u32,
            );
        }
    }

    /// Lane-major interleaved encode, the mirror of
    /// [`decode_lanes`](DecodeKernel::decode_lanes): each round pushes
    /// one code from every unfinished lane, so the LUT loads of 4/8
    /// independent chunks overlap in the pipeline instead of
    /// serializing on one sink's shift-or chain.  Each lane owns its
    /// sink, so its bytes equal an `encode_batch` of its symbols alone.
    fn encode_lanes(&self, lanes: &mut [EncodeLane<'_>]) {
        loop {
            // Size one burst: every unfinished lane sustains `rounds`
            // pushes with no per-round completion checks.
            let mut rounds = usize::MAX;
            let mut unfinished = 0usize;
            for lane in lanes.iter() {
                let remaining = lane.remaining();
                if remaining == 0 {
                    continue;
                }
                unfinished += 1;
                rounds = rounds.min(remaining);
            }
            if unfinished == 0 {
                return;
            }
            for _ in 0..rounds {
                for lane in lanes.iter_mut() {
                    if lane.remaining() == 0 {
                        continue;
                    }
                    let s = lane.symbols[lane.pos] as usize;
                    lane.sink.push(
                        self.enc_code[s] as u64,
                        self.enc_len[s] as u32,
                    );
                    lane.pos += 1;
                }
            }
        }
    }
}

impl Codec for QlcCodec {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn encode_scalar(&self, symbols: &[u8], out: &mut BitWriter) {
        for &s in symbols {
            out.write_bits(
                self.enc_code[s as usize] as u64,
                self.enc_len[s as usize] as u32,
            );
        }
    }

    fn decode_scalar_into(
        &self,
        reader: &mut BitReader,
        out: &mut [u8],
    ) -> Result<(), CodecError> {
        // Reference path: one symbol per [`Self::decode_one`] call,
        // paying the refill/EOF checks every time.  The batched word
        // loop lives in the [`DecodeKernel`] impl.
        for slot in out.iter_mut() {
            *slot = self.decode_one(reader)?;
        }
        Ok(())
    }

    fn code_lengths(&self) -> [u32; 256] {
        let mut out = [0u32; 256];
        for s in 0..256 {
            out[s] = self.enc_len[s] as u32;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::testutil;
    use crate::stats::Histogram;
    use crate::util::prop;
    use crate::util::rng::{AliasTable, Rng};

    fn identity_rank() -> [u8; 256] {
        let mut r = [0u8; 256];
        for i in 0..256 {
            r[i] = i as u8;
        }
        r
    }

    fn t1_identity() -> QlcCodec {
        QlcCodec::from_rank_order(AreaScheme::table1(), &identity_rank(), "qlc-t1")
    }

    #[test]
    fn paper_example_decode() {
        // Paper §7: "if the area code is 100 and the next 3 bits are
        // 010, then the encoded symbol is 32+2=34".
        let codec = t1_identity();
        let mut w = BitWriter::new();
        w.write_bits(0b100, 3);
        w.write_bits(0b010, 3);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(codec.decode_one(&mut r).unwrap(), 34);
    }

    #[test]
    fn code_structure_matches_table1() {
        let codec = t1_identity();
        // Rank 0 → area 0, code 000_000 (6 bits).
        assert_eq!(codec.enc_code[0], 0);
        assert_eq!(codec.enc_len[0], 6);
        // Rank 8 → area 1 code 001_000.
        assert_eq!(codec.enc_code[8], 0b001_000);
        // Rank 40 → area 5 (101), offset 0, 7 bits.
        assert_eq!(codec.enc_code[40], 0b101_0000);
        assert_eq!(codec.enc_len[40], 7);
        // Rank 88 → area 7 (111), offset 0, 11 bits.
        assert_eq!(codec.enc_code[88], 0b111_0000_0000);
        assert_eq!(codec.enc_len[88], 11);
        // Rank 255 → area 7 offset 167.
        assert_eq!(codec.enc_code[255], (0b111 << 8) | 167);
        assert_eq!(codec.enc_len[255], 11);
    }

    #[test]
    fn roundtrip_all_symbols_both_tables() {
        for scheme in [AreaScheme::table1(), AreaScheme::table2()] {
            let codec =
                QlcCodec::from_rank_order(scheme, &identity_rank(), "qlc");
            let symbols: Vec<u8> = (0..=255).collect();
            let enc = codec.encode_to_vec(&symbols);
            assert_eq!(codec.decode_from_slice(&enc, 256).unwrap(), symbols);
        }
    }

    #[test]
    fn rank_mapping_from_pmf() {
        // Symbol 200 most frequent → rank 0 → 6-bit code; encoder and
        // decoder tables reflect the paper's Table 3/4 layout.
        let mut symbols = vec![200u8; 5000];
        symbols.extend((0..=255u8).cycle().take(2560));
        let pmf = Histogram::from_symbols(&symbols).pmf();
        let codec = QlcCodec::from_pmf(AreaScheme::table1(), &pmf);
        assert_eq!(codec.rank_order()[0], 200);
        assert_eq!(codec.code_lengths()[200], 6);
        let enc = codec.encode_to_vec(&symbols);
        assert_eq!(
            codec.decode_from_slice(&enc, symbols.len()).unwrap(),
            symbols
        );
        // Tables are mutually inverse.
        for (rank, sym) in codec.decoder_table() {
            assert_eq!(codec.encoder_row(sym).1, rank);
        }
    }

    #[test]
    fn invalid_suffix_detected() {
        // Area 7 of Table 1 holds 168 symbols; suffix 200 is invalid.
        let codec = t1_identity();
        let mut w = BitWriter::new();
        w.write_bits(0b111, 3);
        w.write_bits(200, 8);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert!(matches!(
            codec.decode_one(&mut r),
            Err(CodecError::InvalidCode { .. })
        ));
    }

    #[test]
    fn truncated_stream_errors() {
        let codec = t1_identity();
        let enc = codec.encode_to_vec(&[255u8; 10]);
        assert!(codec
            .decode_from_slice(&enc[..enc.len() - 2], 10)
            .is_err());
    }

    #[test]
    fn encoded_bits_exact() {
        let codec = t1_identity();
        // 5 rank-0 symbols (6b) + 3 rank-50 (7b) + 2 rank-100 (11b).
        let symbols = [0u8, 0, 0, 0, 0, 50, 50, 50, 100, 100];
        assert_eq!(codec.encoded_bits(&symbols), 5 * 6 + 3 * 7 + 2 * 11);
    }

    #[test]
    fn compressibility_on_skewed_data_beats_raw() {
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = (-0.03 * i as f64).exp();
        }
        let alias = AliasTable::new(&p);
        let mut rng = Rng::new(3);
        let symbols = alias.sample_many(&mut rng, 100_000);
        let pmf = Histogram::from_symbols(&symbols).pmf();
        let codec = QlcCodec::from_pmf(AreaScheme::table1(), &pmf);
        let enc = codec.encode_to_vec(&symbols);
        assert!(
            (enc.len() as f64) < 0.92 * symbols.len() as f64,
            "compressed {} of {}",
            enc.len(),
            symbols.len()
        );
        let dec = codec.decode_from_slice(&enc, symbols.len()).unwrap();
        assert_eq!(dec, symbols);
    }

    #[test]
    #[should_panic(expected = "not a permutation")]
    fn rejects_non_permutation_rank() {
        let mut rank = identity_rank();
        rank[1] = 0;
        QlcCodec::from_rank_order(AreaScheme::table1(), &rank, "bad");
    }

    #[test]
    fn prop_roundtrip_t1() {
        testutil::roundtrip_property(&t1_identity());
    }

    #[test]
    fn lane_decode_roundtrips_at_both_widths() {
        use crate::codecs::kernel::{LaneDecoder, LaneJob};
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = (-0.03 * i as f64).exp();
        }
        let symbols =
            AliasTable::new(&p).sample_many(&mut Rng::new(17), 120_000);
        let pmf = Histogram::from_symbols(&symbols).pmf();
        let codec = QlcCodec::from_pmf(AreaScheme::table1(), &pmf);
        // 8 equal chunks hit the full-group (AVX2 where present) path;
        // the ragged split exercises drop-out and tails.
        for chunk in [symbols.len() / 8, 7_919] {
            let payloads: Vec<Vec<u8>> = symbols
                .chunks(chunk)
                .map(|c| codec.encode_to_vec(c))
                .collect();
            for width in [4usize, 8] {
                let engine = LaneDecoder::with_lanes(width).unwrap();
                let mut out = vec![0u8; symbols.len()];
                let mut jobs: Vec<LaneJob> = payloads
                    .iter()
                    .zip(out.chunks_mut(chunk))
                    .map(|(p, o)| LaneJob { payload: p, out: o })
                    .collect();
                engine.decode_jobs(&codec, &mut jobs).unwrap();
                assert_eq!(out, symbols, "chunk={chunk} width={width}");
            }
        }
    }

    #[test]
    fn lane_encode_matches_batched_at_both_widths() {
        use crate::codecs::kernel::{EncodeJob, LaneEncoder};
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = (-0.03 * i as f64).exp();
        }
        let symbols =
            AliasTable::new(&p).sample_many(&mut Rng::new(23), 120_000);
        let pmf = Histogram::from_symbols(&symbols).pmf();
        let codec = QlcCodec::from_pmf(AreaScheme::table1(), &pmf);
        // 8 equal chunks fill whole lane groups; the ragged split
        // forces lanes to finish at different rounds.
        for chunk in [symbols.len() / 8, 7_919] {
            let reference: Vec<Vec<u8>> = symbols
                .chunks(chunk)
                .map(|c| codec.encode_to_vec(c))
                .collect();
            for width in [4usize, 8] {
                let engine = LaneEncoder::with_lanes(width).unwrap();
                let mut outs: Vec<Vec<u8>> =
                    vec![Vec::new(); reference.len()];
                let mut jobs: Vec<EncodeJob> = symbols
                    .chunks(chunk)
                    .zip(outs.iter_mut())
                    .map(|(c, o)| EncodeJob { symbols: c, out: o })
                    .collect();
                engine.encode_jobs(&codec, &mut jobs);
                assert_eq!(outs, reference, "chunk={chunk} width={width}");
            }
        }
    }

    #[test]
    fn lane_decode_surfaces_invalid_codes() {
        use crate::codecs::kernel::Lane;
        let codec = t1_identity();
        // Area 7 of Table 1 holds 168 symbols; suffix 200 is invalid.
        let mut w = BitWriter::new();
        w.write_bits(0b111, 3);
        w.write_bits(200, 8);
        // Pad so the lockstep (not the tail) sees the bad code.
        w.write_zeros(61);
        let bad = w.finish();
        let good = codec.encode_to_vec(&[1u8; 64]);
        let mut out_bad = vec![0u8; 4];
        let mut out_good = vec![0u8; 64];
        let mut lanes = vec![
            Lane::new(&bad, &mut out_bad),
            Lane::new(&good, &mut out_good),
        ];
        assert!(matches!(
            codec.decode_lanes(&mut lanes),
            Err(CodecError::InvalidCode { .. })
        ));
    }

    #[test]
    fn prop_roundtrip_t2_random_rank() {
        prop::check("qlc t2 random rank", prop::Config {
            cases: 32, ..Default::default()
        }, |rng, size| {
            // Random permutation via Fisher-Yates.
            let mut rank = identity_rank();
            for i in (1..256usize).rev() {
                let j = rng.below(i as u64 + 1) as usize;
                rank.swap(i, j);
            }
            let codec = QlcCodec::from_rank_order(
                AreaScheme::table2(),
                &rank,
                "qlc-t2",
            );
            let symbols = prop::arb_bytes(rng, size);
            let enc = codec.encode_to_vec(&symbols);
            let dec = codec
                .decode_from_slice(&enc, symbols.len())
                .map_err(|e| e.to_string())?;
            if dec != symbols {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }
}
