//! Quad Length Codes (the paper's contribution, §5–§7).
//!
//! A QLC code word is `area-prefix (P bits) | symbol-index (b_a bits)`:
//! the P-bit prefix selects one of `2^P` *areas*; each area `a` holds
//! `n_a` rank-ordered symbols indexed by a fixed-width `b_a`-bit
//! suffix.  The prefix alone determines the total code length
//! (`P + b_a`), so a decoder needs no tree walk: one P-bit lookup, one
//! fixed-width read, one 256-entry LUT (paper Tables 3–4).
//!
//! * [`scheme`] — [`scheme::AreaScheme`]: the area structure; paper
//!   Table 1 and Table 2 as constructors; validation.
//! * [`codec`] — [`codec::QlcCodec`]: encoder/decoder LUTs bound to a
//!   PMF's rank order.
//! * [`optimizer`] — DP that picks the optimal area structure for a
//!   PMF (the paper's "future work" §8 formulation).
//! * [`serde`] — scheme + LUT (de)serialization (JSON and the binary
//!   frame header).

pub mod codec;
pub mod optimizer;
pub mod scheme;
pub mod serde;

pub use codec::QlcCodec;
pub use optimizer::optimize_scheme;
pub use scheme::{Area, AreaScheme};
