//! Canonical Huffman coding — the paper's optimal-baseline codec.
//!
//! * [`build`] — optimal length-limited code construction
//!   (package-merge).  Unlimited-depth Huffman is the special case of a
//!   generous limit; the default limit of 48 bits never binds on
//!   realistic tensor statistics (the paper's deepest observed code is
//!   39 bits) and keeps codes in one `u64`.
//! * [`decode`] — two decoders:
//!   [`decode::TreeDecoder`], the bit-serial tree walk the paper calls
//!   "slow and bit sequential" (it is also the reference model for the
//!   hardware FSM in `crate::hw`), and [`decode::TableDecoder`], a
//!   multi-level LUT decoder (the fast software path).

pub mod build;
pub mod decode;

use super::kernel::{BitCursor, BitSink, DecodeKernel, EncodeKernel};
use super::{Codec, CodecError};
use crate::bitstream::{BitReader, BitWriter};
use crate::stats::Histogram;
use build::CodeBook;
use decode::TableDecoder;

/// Default depth limit: never binds in practice, keeps codes in u64.
pub const DEFAULT_LIMIT: u32 = 48;

/// Canonical Huffman codec for a fixed histogram.
#[derive(Clone, Debug)]
pub struct HuffmanCodec {
    book: CodeBook,
    decoder: TableDecoder,
}

impl HuffmanCodec {
    /// Build from symbol counts.  Symbols with zero count are smoothed
    /// to count 1 so the codebook covers the whole alphabet (the paper's
    /// encoder LUT has all 256 entries).
    pub fn from_histogram(hist: &Histogram) -> Self {
        Self::from_histogram_limited(hist, DEFAULT_LIMIT)
    }

    pub fn from_histogram_limited(hist: &Histogram, limit: u32) -> Self {
        let mut freqs = [0u64; 256];
        for i in 0..256 {
            freqs[i] = hist.counts[i].max(1);
        }
        let book = CodeBook::build(&freqs, limit);
        let decoder = TableDecoder::new(&book);
        HuffmanCodec { book, decoder }
    }

    /// Build directly from known code lengths (frame decode path).
    pub fn from_lengths(lengths: &[u32; 256]) -> Result<Self, CodecError> {
        let book = CodeBook::from_lengths(lengths)
            .map_err(CodecError::BadHeader)?;
        let decoder = TableDecoder::new(&book);
        Ok(HuffmanCodec { book, decoder })
    }

    pub fn book(&self) -> &CodeBook {
        &self.book
    }

    pub fn max_length(&self) -> u32 {
        self.book.max_length()
    }

    pub fn min_length(&self) -> u32 {
        self.book.min_length()
    }
}

impl DecodeKernel for HuffmanCodec {
    fn decode_batch(
        &self,
        cur: &mut BitCursor,
        out: &mut [u8],
    ) -> Result<usize, CodecError> {
        self.decoder.decode_batch(cur, out)
    }
}

impl EncodeKernel for HuffmanCodec {
    /// Straight from the code table into the staging word, one push
    /// per symbol (codes are depth-limited to ≤ 48 bits, inside the
    /// sink's 57-bit budget).
    fn encode_batch(&self, symbols: &[u8], sink: &mut BitSink) {
        for &s in symbols {
            let (code, len) = self.book.code(s);
            sink.push(code, len);
        }
    }
}

impl Codec for HuffmanCodec {
    fn name(&self) -> String {
        "huffman".to_string()
    }

    fn encode_scalar(&self, symbols: &[u8], out: &mut BitWriter) {
        for &s in symbols {
            let (code, len) = self.book.code(s);
            out.write_bits(code, len);
        }
    }

    fn decode_scalar_into(
        &self,
        reader: &mut BitReader,
        out: &mut [u8],
    ) -> Result<(), CodecError> {
        // One table walk per symbol; the batched root-table loop lives
        // in the [`DecodeKernel`] impl.
        for slot in out.iter_mut() {
            *slot = self.decoder.decode_one(reader)?;
        }
        Ok(())
    }

    fn code_lengths(&self) -> [u32; 256] {
        *self.book.lengths()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::testutil;
    use crate::stats::Pmf;
    use crate::util::prop;
    use crate::util::rng::{AliasTable, Rng};

    fn skewed_hist(seed: u64, n: usize) -> (Histogram, Vec<u8>) {
        // Zipf-ish PMF over 256 symbols.
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = 1.0 / (1.0 + i as f64).powf(1.2);
        }
        let table = AliasTable::new(&p);
        let mut rng = Rng::new(seed);
        let symbols = table.sample_many(&mut rng, n);
        (Histogram::from_symbols(&symbols), symbols)
    }

    #[test]
    fn roundtrip_skewed() {
        let (hist, symbols) = skewed_hist(1, 50_000);
        let codec = HuffmanCodec::from_histogram(&hist);
        let enc = codec.encode_to_vec(&symbols);
        assert!(enc.len() < symbols.len()); // actually compresses
        assert_eq!(
            codec.decode_from_slice(&enc, symbols.len()).unwrap(),
            symbols
        );
    }

    #[test]
    fn beats_entropy_bound_within_one_bit() {
        let (hist, _) = skewed_hist(2, 100_000);
        let codec = HuffmanCodec::from_histogram(&hist);
        let pmf = hist.pmf();
        let h = pmf.entropy();
        let el = pmf.expected_length(&codec.code_lengths());
        assert!(el >= h - 1e-9, "expected length below entropy: {el} < {h}");
        assert!(el < h + 1.0, "Huffman within 1 bit of entropy: {el} vs {h}");
    }

    #[test]
    fn uniform_gives_8bit_codes() {
        let mut hist = Histogram::new();
        hist.counts = [100; 256];
        let codec = HuffmanCodec::from_histogram(&hist);
        assert!(codec.code_lengths().iter().all(|&l| l == 8));
    }

    #[test]
    fn covers_unseen_symbols() {
        // Data contains only symbol 3, but any symbol must roundtrip
        // (smoothing gives everyone a code).
        let hist = Histogram::from_symbols(&[3u8; 1000]);
        let codec = HuffmanCodec::from_histogram(&hist);
        let all: Vec<u8> = (0..=255).collect();
        let enc = codec.encode_to_vec(&all);
        assert_eq!(codec.decode_from_slice(&enc, 256).unwrap(), all);
    }

    #[test]
    fn depth_limit_respected() {
        // Fibonacci-ish counts force deep trees without a limit.
        let mut hist = Histogram::new();
        let mut a = 1u64;
        let mut b = 1u64;
        for i in 0..256 {
            hist.counts[i] = a;
            let c = a.saturating_add(b);
            a = b;
            b = c;
        }
        for limit in [12u32, 16, 20] {
            let codec = HuffmanCodec::from_histogram_limited(&hist, limit);
            assert!(codec.max_length() <= limit, "limit {limit}");
            // Still lossless.
            let data: Vec<u8> = (0..=255).collect();
            let enc = codec.encode_to_vec(&data);
            assert_eq!(codec.decode_from_slice(&enc, 256).unwrap(), data);
        }
    }

    #[test]
    fn from_lengths_roundtrips_codebook() {
        let (hist, symbols) = skewed_hist(3, 20_000);
        let codec = HuffmanCodec::from_histogram(&hist);
        let codec2 = HuffmanCodec::from_lengths(&codec.code_lengths()).unwrap();
        let enc = codec.encode_to_vec(&symbols);
        assert_eq!(
            codec2.decode_from_slice(&enc, symbols.len()).unwrap(),
            symbols
        );
    }

    #[test]
    fn from_lengths_rejects_overfull_kraft() {
        let lengths = [1u32; 256]; // grossly over-subscribed
        assert!(HuffmanCodec::from_lengths(&lengths).is_err());
    }

    #[test]
    fn truncated_stream_errors() {
        let (hist, symbols) = skewed_hist(4, 1000);
        let codec = HuffmanCodec::from_histogram(&hist);
        let enc = codec.encode_to_vec(&symbols);
        assert!(codec
            .decode_from_slice(&enc[..enc.len() / 2], symbols.len())
            .is_err());
    }

    #[test]
    fn expected_compressibility_on_paper_like_pmf() {
        // A smooth exponential-rank PMF with entropy ≈ 6.7 bits: Huffman
        // compressibility should land within a point of ideal, as in
        // the paper (15.9% vs ideal 16.3%).
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = (-0.022 * i as f64).exp();
        }
        let pmf = Pmf::from_slice(&p);
        let mut hist = Histogram::new();
        for i in 0..256 {
            hist.counts[i] = (pmf.p[i] * 1e9) as u64;
        }
        let codec = HuffmanCodec::from_histogram(&hist);
        let ideal = pmf.ideal_compressibility();
        let achieved = pmf.compressibility(&codec.code_lengths());
        assert!(achieved <= ideal + 1e-9);
        assert!(achieved > ideal - 0.01, "{achieved} vs ideal {ideal}");
    }

    #[test]
    fn prop_roundtrip_random_histograms() {
        prop::check("huffman random hist", Default::default(), |rng, size| {
            let data = prop::arb_bytes(rng, size.max(4));
            if data.is_empty() {
                return Ok(());
            }
            let hist = Histogram::from_symbols(&data);
            let codec = HuffmanCodec::from_histogram(&hist);
            let enc = codec.encode_to_vec(&data);
            let dec = codec
                .decode_from_slice(&enc, data.len())
                .map_err(|e| e.to_string())?;
            if dec != data {
                return Err("roundtrip".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_roundtrip_trait() {
        let (hist, _) = skewed_hist(5, 10_000);
        let codec = HuffmanCodec::from_histogram(&hist);
        testutil::roundtrip_property(&codec);
    }
}
