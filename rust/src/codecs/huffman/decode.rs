//! Huffman decoders: the bit-serial tree walk (the hardware baseline
//! the paper criticizes) and a multi-level table decoder (fast software
//! path).

use super::build::CodeBook;
use crate::bitstream::BitReader;
use crate::codecs::kernel::BitCursor;
use crate::codecs::CodecError;

// ---------------------------------------------------------------------------
// Bit-serial tree decoder

/// Explicit binary decode tree.  `nodes[i] = [left, right]`; values
/// ≥ 0x100 encode `symbol + 0x100` leaves, `u32::MAX` is an invalid
/// branch.  Decoding walks one bit at a time — this is the behaviour
/// (and the latency model) of a serial hardware Huffman decoder.
#[derive(Clone, Debug)]
pub struct TreeDecoder {
    nodes: Vec<[u32; 2]>,
}

const INVALID: u32 = u32::MAX;
const LEAF_BASE: u32 = 0x100;

impl TreeDecoder {
    pub fn new(book: &CodeBook) -> Self {
        let mut nodes: Vec<[u32; 2]> = vec![[INVALID, INVALID]];
        for s in 0..256usize {
            let (code, len) = book.code(s as u8);
            let mut node = 0usize;
            for i in (0..len).rev() {
                let bit = ((code >> i) & 1) as usize;
                if i == 0 {
                    nodes[node][bit] = LEAF_BASE + s as u32;
                } else {
                    let next = nodes[node][bit];
                    let next = if next == INVALID {
                        nodes.push([INVALID, INVALID]);
                        let id = (nodes.len() - 1) as u32;
                        nodes[node][bit] = id;
                        id
                    } else {
                        next
                    };
                    debug_assert!(next < LEAF_BASE || next == INVALID);
                    node = next as usize;
                }
            }
        }
        TreeDecoder { nodes }
    }

    /// Number of internal nodes (hardware storage proxy; see crate::hw).
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Decode one symbol, one bit at a time.
    #[inline]
    pub fn decode_one(
        &self,
        reader: &mut BitReader,
    ) -> Result<u8, CodecError> {
        let mut node = 0u32;
        loop {
            let bit = reader
                .read_bit()
                .map_err(|_| CodecError::UnexpectedEof)?;
            let next = self.nodes[node as usize][bit as usize];
            if next == INVALID {
                return Err(CodecError::InvalidCode {
                    bit_offset: reader.bits_consumed(),
                });
            }
            if next >= LEAF_BASE {
                return Ok((next - LEAF_BASE) as u8);
            }
            node = next;
        }
    }

    /// Decode exactly `out.len()` symbols into a caller-provided slice.
    pub fn decode_into(
        &self,
        reader: &mut BitReader,
        out: &mut [u8],
    ) -> Result<(), CodecError> {
        for slot in out.iter_mut() {
            *slot = self.decode_one(reader)?;
        }
        Ok(())
    }

    /// Convenience wrapper appending to a `Vec` (benches, tests).
    pub fn decode(
        &self,
        reader: &mut BitReader,
        n: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), CodecError> {
        let start = out.len();
        out.resize(start + n, 0);
        let r = self.decode_into(reader, &mut out[start..]);
        if r.is_err() {
            out.truncate(start);
        }
        r
    }
}

// ---------------------------------------------------------------------------
// Multi-level table decoder

/// Root-table width in bits.  11 covers most realistic codes in one
/// lookup (paper FFN1 codes span 6–18 bits) while keeping the root
/// table at 2 KiB entries.
pub const ROOT_BITS: u32 = 11;

/// Entry: packed `(symbol, length)` for short codes, or a subtable
/// pointer for codes longer than the level width.
#[derive(Clone, Copy, Debug)]
enum Entry {
    /// Code fully resolved: symbol + total code length (for this level
    /// chain).
    Leaf { symbol: u8, len: u8 },
    /// Index of a subtable covering the next level's bits.
    Sub { table: u32 },
    Invalid,
}

/// Multi-level LUT decoder: peek ROOT_BITS, one lookup resolves any
/// code ≤ ROOT_BITS; longer codes chain through subtables.
#[derive(Clone, Debug)]
pub struct TableDecoder {
    /// Table 0 is the root (2^ROOT_BITS entries); subtables follow.
    entries: Vec<Entry>,
    /// (offset, width_bits) of each table in `entries`.
    tables: Vec<(usize, u32)>,
    /// Longest code in the book (bulk-decode budget guard).
    max_len: u32,
}

impl TableDecoder {
    pub fn new(book: &CodeBook) -> Self {
        let mut dec = TableDecoder {
            entries: Vec::new(),
            tables: Vec::new(),
            max_len: book.max_length(),
        };
        dec.alloc_table(ROOT_BITS);
        for s in 0..256usize {
            let (code, len) = book.code(s as u8);
            dec.insert(0, code, len, len, s as u8);
        }
        dec
    }

    fn alloc_table(&mut self, bits: u32) -> usize {
        let offset = self.entries.len();
        self.entries
            .extend(std::iter::repeat(Entry::Invalid).take(1usize << bits));
        self.tables.push((offset, bits));
        self.tables.len() - 1
    }

    /// Insert `code` (remaining `len` bits of a `total`-bit code) into
    /// `table`.
    fn insert(&mut self, table: usize, code: u64, len: u32, total: u32, symbol: u8) {
        let (offset, width) = self.tables[table];
        if len <= width {
            // Fill all entries whose top `len` bits match the code.
            let base = (code << (width - len)) as usize;
            for fill in 0..(1usize << (width - len)) {
                self.entries[offset + base + fill] =
                    Entry::Leaf { symbol, len: len as u8 };
            }
        } else {
            // Descend into (or create) a subtable for this prefix.
            let prefix = (code >> (len - width)) as usize;
            let sub = match self.entries[offset + prefix] {
                Entry::Sub { table } => table as usize,
                Entry::Invalid => {
                    let bits = (len - width).min(ROOT_BITS);
                    let sub = self.alloc_table(bits);
                    let _ = bits;
                    self.entries[offset + prefix] =
                        Entry::Sub { table: sub as u32 };
                    sub
                }
                Entry::Leaf { .. } => {
                    unreachable!("prefix code collision: book not prefix-free")
                }
            };
            // Subtable width may need to grow: rebuild is complex, so we
            // size subtables at min(remaining, ROOT_BITS) on first touch
            // and keep descending — codes sharing a prefix descend the
            // same chain.
            let rest = code & ((1u64 << (len - width)) - 1);
            self.insert(sub, rest, len - width, total, symbol);
        }
    }

    /// Total entries across all tables (hardware storage proxy).
    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    #[inline]
    pub fn decode_one(
        &self,
        reader: &mut BitReader,
    ) -> Result<u8, CodecError> {
        let mut table = 0usize;
        loop {
            let (offset, width) = self.tables[table];
            let idx = reader.peek(width) as usize;
            match self.entries[offset + idx] {
                Entry::Leaf { symbol, len } => {
                    if reader.remaining_bits() < len as u64 {
                        return Err(CodecError::UnexpectedEof);
                    }
                    reader.skip(len as u32);
                    return Ok(symbol);
                }
                Entry::Sub { table: sub } => {
                    if reader.remaining_bits() < width as u64 {
                        return Err(CodecError::UnexpectedEof);
                    }
                    reader.skip(width);
                    table = sub as usize;
                }
                Entry::Invalid => {
                    return Err(CodecError::InvalidCode {
                        bit_offset: reader.bits_consumed(),
                    });
                }
            }
        }
    }

    /// Decode exactly `out.len()` symbols into a caller-provided slice.
    pub fn decode_into(
        &self,
        reader: &mut BitReader,
        out: &mut [u8],
    ) -> Result<(), CodecError> {
        let n = out.len();
        let (root_off, root_width) = self.tables[0];
        let root_shift = 64 - root_width;
        let mut i = 0usize;
        while i < n {
            // Bulk path: while the staging buffer still holds at least
            // one whole worst-case code, root-level leaves resolve with
            // no refill/EOF checks.  (`word_buffered`'s sub-`avail`
            // bits are zero by construction, so short buffers index the
            // leaf-filled root slots correctly.)
            let mut budget = reader.buffered_bits();
            if budget < self.max_len {
                out[i] = self.decode_one(reader)?;
                i += 1;
                continue;
            }
            while i < n && budget >= self.max_len {
                let idx = (reader.word_buffered() >> root_shift) as usize;
                match self.entries[root_off + idx] {
                    Entry::Leaf { symbol, len } => {
                        reader.skip(len as u32);
                        budget -= len as u32;
                        out[i] = symbol;
                        i += 1;
                    }
                    Entry::Sub { .. } => {
                        out[i] = self.decode_one(reader)?;
                        i += 1;
                        budget = 0; // force re-refill
                    }
                    Entry::Invalid => {
                        return Err(CodecError::InvalidCode {
                            bit_offset: reader.bits_consumed(),
                        });
                    }
                }
            }
        }
        Ok(())
    }

    /// Convenience wrapper appending to a `Vec` (benches, tests).
    pub fn decode(
        &self,
        reader: &mut BitReader,
        n: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), CodecError> {
        let start = out.len();
        out.resize(start + n, 0);
        let r = self.decode_into(reader, &mut out[start..]);
        if r.is_err() {
            out.truncate(start);
        }
        r
    }

    /// Cursor analogue of [`decode_one`](Self::decode_one): the
    /// kernel's checked path for codes near the end of the buffer or
    /// chained through subtables.
    #[inline]
    fn decode_one_cursor(
        &self,
        cur: &mut BitCursor,
    ) -> Result<u8, CodecError> {
        let mut table = 0usize;
        loop {
            let (offset, width) = self.tables[table];
            cur.refill();
            let idx = (cur.word() >> (64 - width)) as usize;
            match self.entries[offset + idx] {
                Entry::Leaf { symbol, len } => {
                    if cur.remaining_bits() < len as u64 {
                        return Err(CodecError::UnexpectedEof);
                    }
                    cur.consume(len as u32);
                    return Ok(symbol);
                }
                Entry::Sub { table: sub } => {
                    if cur.remaining_bits() < width as u64 {
                        return Err(CodecError::UnexpectedEof);
                    }
                    cur.consume(width);
                    table = sub as usize;
                }
                Entry::Invalid => {
                    return Err(CodecError::InvalidCode {
                        bit_offset: cur.bits_consumed(),
                    });
                }
            }
        }
    }

    /// Batched kernel: one refill, then root-table leaves resolve with
    /// no refill/EOF checks while the buffered budget still holds a
    /// whole worst-case code.  This flattens the per-bit tree steps of
    /// the serial decoder into one multi-bit lookup per symbol — and
    /// several symbols per 64-bit window.
    pub fn decode_batch(
        &self,
        cur: &mut BitCursor,
        out: &mut [u8],
    ) -> Result<usize, CodecError> {
        let n = out.len();
        let (root_off, root_width) = self.tables[0];
        let root_shift = 64 - root_width;
        let mut i = 0usize;
        while i < n {
            let mut budget = cur.refill_buffered();
            if budget < self.max_len {
                out[i] = self.decode_one_cursor(cur)?;
                i += 1;
                continue;
            }
            while i < n && budget >= self.max_len {
                let idx = (cur.word() >> root_shift) as usize;
                match self.entries[root_off + idx] {
                    Entry::Leaf { symbol, len } => {
                        cur.consume(len as u32);
                        budget -= len as u32;
                        out[i] = symbol;
                        i += 1;
                    }
                    Entry::Sub { .. } => {
                        out[i] = self.decode_one_cursor(cur)?;
                        i += 1;
                        budget = 0; // force re-refill
                    }
                    Entry::Invalid => {
                        return Err(CodecError::InvalidCode {
                            bit_offset: cur.bits_consumed(),
                        });
                    }
                }
            }
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::BitWriter;
    use crate::stats::Histogram;
    use crate::util::prop;
    use crate::util::rng::{AliasTable, Rng};

    fn encode(book: &CodeBook, symbols: &[u8]) -> Vec<u8> {
        let mut w = BitWriter::new();
        for &s in symbols {
            let (c, l) = book.code(s);
            w.write_bits(c, l);
        }
        w.finish()
    }

    fn skewed_book(alpha: f64) -> CodeBook {
        let mut freqs = [0u64; 256];
        for (i, f) in freqs.iter_mut().enumerate() {
            *f = ((1e9 / (1.0 + i as f64).powf(alpha)) as u64).max(1);
        }
        CodeBook::build(&freqs, 48)
    }

    #[test]
    fn tree_and_table_agree() {
        let book = skewed_book(1.3);
        let tree = TreeDecoder::new(&book);
        let table = TableDecoder::new(&book);
        let mut rng = Rng::new(5);
        let symbols: Vec<u8> =
            (0..10_000).map(|_| rng.below(256) as u8).collect();
        let data = encode(&book, &symbols);
        let mut out_tree = Vec::new();
        tree.decode(&mut BitReader::new(&data), symbols.len(), &mut out_tree)
            .unwrap();
        let mut out_table = Vec::new();
        table
            .decode(&mut BitReader::new(&data), symbols.len(), &mut out_table)
            .unwrap();
        assert_eq!(out_tree, symbols);
        assert_eq!(out_table, symbols);
    }

    #[test]
    fn deep_codes_chain_subtables() {
        // Fibonacci weights: depth ≫ ROOT_BITS forces subtable chains.
        let mut freqs = [0u64; 256];
        let mut a = 1u64;
        let mut b = 1u64;
        for f in freqs.iter_mut() {
            *f = a;
            let c = a.saturating_add(b);
            a = b;
            b = c;
        }
        let book = CodeBook::build(&freqs, 48);
        assert!(book.max_length() > ROOT_BITS);
        let table = TableDecoder::new(&book);
        assert!(table.tables.len() > 1, "must have subtables");
        // Roundtrip every symbol including the deepest.
        let symbols: Vec<u8> = (0..=255).collect();
        let data = encode(&book, &symbols);
        let mut out = Vec::new();
        table
            .decode(&mut BitReader::new(&data), 256, &mut out)
            .unwrap();
        assert_eq!(out, symbols);
    }

    #[test]
    fn tree_node_count_reasonable() {
        let book = skewed_book(1.0);
        let tree = TreeDecoder::new(&book);
        // A full binary tree with 256 leaves has 255 internal nodes; the
        // canonical tree may be larger only if incomplete (it is not).
        assert_eq!(tree.node_count(), 255);
    }

    #[test]
    fn truncated_errors_both() {
        let book = skewed_book(1.1);
        let symbols = vec![255u8; 100];
        let data = encode(&book, &symbols);
        let cut = &data[..data.len() - 8];
        let tree = TreeDecoder::new(&book);
        let table = TableDecoder::new(&book);
        let mut out = Vec::new();
        assert!(tree
            .decode(&mut BitReader::new(cut), 100, &mut out)
            .is_err());
        out.clear();
        assert!(table
            .decode(&mut BitReader::new(cut), 100, &mut out)
            .is_err());
    }

    #[test]
    fn prop_decoders_agree() {
        prop::check("tree==table", prop::Config { cases: 48, ..Default::default() },
                    |rng, size| {
            let mut freqs = [0u64; 256];
            for f in freqs.iter_mut() {
                *f = 1 + rng.below(10_000);
            }
            let book = CodeBook::build(&freqs, 48);
            let hist = Histogram { counts: freqs };
            let table_pmf: Vec<f64> =
                hist.pmf().p.to_vec();
            let alias = AliasTable::new(&table_pmf);
            let symbols = alias.sample_many(rng, size.min(2000));
            let data = encode(&book, &symbols);
            let tree = TreeDecoder::new(&book);
            let tbl = TableDecoder::new(&book);
            let mut a = Vec::new();
            let mut b = Vec::new();
            tree.decode(&mut BitReader::new(&data), symbols.len(), &mut a)
                .map_err(|e| e.to_string())?;
            tbl.decode(&mut BitReader::new(&data), symbols.len(), &mut b)
                .map_err(|e| e.to_string())?;
            if a != symbols || b != symbols {
                return Err("decoder mismatch".into());
            }
            Ok(())
        });
    }
}
