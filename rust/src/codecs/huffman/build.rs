//! Optimal length-limited Huffman construction (package-merge) and
//! canonical code assignment.
//!
//! Package-merge (Larmore & Hirschberg 1990) yields the optimal prefix
//! code subject to a maximum length L.  With L ≥ the unconstrained
//! Huffman depth it reproduces the classic optimum, so we use it
//! unconditionally instead of maintaining two builders.

/// A canonical Huffman codebook over the 256-symbol alphabet.
#[derive(Clone, Debug, PartialEq)]
pub struct CodeBook {
    lengths: [u32; 256],
    /// MSB-first canonical codes, right-aligned in the low `len` bits.
    codes: [u64; 256],
}

impl CodeBook {
    /// Build the optimal codebook for `freqs` (all must be > 0) with
    /// code lengths capped at `limit`.
    pub fn build(freqs: &[u64; 256], limit: u32) -> CodeBook {
        assert!(limit >= 8, "256 symbols need ≥ 8 bits");
        assert!(limit <= 57, "BitWriter field limit");
        assert!(freqs.iter().all(|&f| f > 0), "smooth zero counts first");
        let lengths = package_merge(freqs, limit);
        Self::from_lengths(&lengths).expect("package-merge produced a valid Kraft set")
    }

    /// Assign canonical codes to known lengths.  Errors (as String, the
    /// caller wraps) if the lengths violate the Kraft equality/inequality
    /// or exceed 57 bits.
    pub fn from_lengths(lengths: &[u32; 256]) -> Result<CodeBook, String> {
        let max_len = *lengths.iter().max().unwrap();
        if max_len == 0 {
            return Err("all code lengths zero".into());
        }
        if max_len > 57 {
            return Err(format!("max code length {max_len} > 57"));
        }
        if lengths.iter().any(|&l| l == 0) {
            return Err("every symbol needs a code".into());
        }
        // Kraft sum ≤ 1 (scaled by 2^max_len to stay integral).
        let kraft: u128 = lengths
            .iter()
            .map(|&l| 1u128 << (max_len - l))
            .sum();
        if kraft > (1u128 << max_len) {
            return Err(format!(
                "Kraft sum {kraft}/2^{max_len} exceeds 1: not decodable"
            ));
        }
        // Canonical assignment: sort by (length, symbol).
        let mut order: Vec<u16> = (0..256).collect();
        order.sort_by_key(|&s| (lengths[s as usize], s));
        let mut codes = [0u64; 256];
        let mut code = 0u64;
        let mut prev_len = lengths[order[0] as usize];
        for &s in &order {
            let len = lengths[s as usize];
            code <<= len - prev_len;
            codes[s as usize] = code;
            code += 1;
            prev_len = len;
        }
        Ok(CodeBook { lengths: *lengths, codes })
    }

    #[inline]
    pub fn code(&self, symbol: u8) -> (u64, u32) {
        (self.codes[symbol as usize], self.lengths[symbol as usize])
    }

    pub fn lengths(&self) -> &[u32; 256] {
        &self.lengths
    }

    pub fn codes(&self) -> &[u64; 256] {
        &self.codes
    }

    pub fn max_length(&self) -> u32 {
        *self.lengths.iter().max().unwrap()
    }

    pub fn min_length(&self) -> u32 {
        *self.lengths.iter().min().unwrap()
    }

    /// Kraft sum as a fraction of 1 (== 1 for a complete code).
    pub fn kraft_sum(&self) -> f64 {
        self.lengths.iter().map(|&l| 2f64.powi(-(l as i32))).sum()
    }
}

/// Package-merge: optimal code lengths under `limit`.
fn package_merge(freqs: &[u64; 256], limit: u32) -> [u32; 256] {
    // Active items sorted by weight.  (All freqs > 0 by contract.)
    #[derive(Clone)]
    struct Node {
        w: u128,
        /// Symbols covered by this node (leaf: one; package: several).
        syms: Vec<u16>,
    }
    let mut items: Vec<Node> = (0..256u16)
        .map(|s| Node { w: freqs[s as usize] as u128, syms: vec![s] })
        .collect();
    items.sort_by_key(|n| n.w);

    // lists[l] after processing: candidates of level l.
    let mut prev: Vec<Node> = items.clone();
    for _level in 1..limit {
        // Package pairs from the previous level…
        let mut packages: Vec<Node> = Vec::with_capacity(prev.len() / 2);
        let mut it = prev.chunks_exact(2);
        for pair in &mut it {
            let mut syms = pair[0].syms.clone();
            syms.extend_from_slice(&pair[1].syms);
            packages.push(Node { w: pair[0].w + pair[1].w, syms });
        }
        // …and merge with a fresh copy of the items.
        let mut merged = Vec::with_capacity(items.len() + packages.len());
        let (mut i, mut p) = (0usize, 0usize);
        while i < items.len() || p < packages.len() {
            let take_item = p >= packages.len()
                || (i < items.len() && items[i].w <= packages[p].w);
            if take_item {
                merged.push(items[i].clone());
                i += 1;
            } else {
                merged.push(packages[p].clone());
                p += 1;
            }
        }
        prev = merged;
    }

    // The optimal solution takes the 2(n-1) cheapest nodes of the final
    // level; each appearance of a symbol adds one to its code length.
    let n_active = 256usize;
    let mut lengths = [0u32; 256];
    for node in prev.iter().take(2 * (n_active - 1)) {
        for &s in &node.syms {
            lengths[s as usize] += 1;
        }
    }
    lengths
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn uniform_freqs() -> [u64; 256] {
        [1000; 256]
    }

    #[test]
    fn uniform_is_8_bits() {
        let book = CodeBook::build(&uniform_freqs(), 48);
        assert!(book.lengths().iter().all(|&l| l == 8));
        assert!((book.kraft_sum() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn canonical_codes_are_prefix_free() {
        let mut freqs = [1u64; 256];
        for i in 0..32 {
            freqs[i] = 1000 >> (i / 4);
        }
        let book = CodeBook::build(&freqs, 48);
        for a in 0..256usize {
            for b in 0..256usize {
                if a == b {
                    continue;
                }
                let (ca, la) = book.code(a as u8);
                let (cb, lb) = book.code(b as u8);
                if la <= lb {
                    // a must not be a prefix of b
                    assert_ne!(
                        ca,
                        cb >> (lb - la),
                        "symbol {a} ({ca:b}/{la}) prefixes {b} ({cb:b}/{lb})"
                    );
                }
            }
        }
    }

    #[test]
    fn kraft_equality_for_optimal_code() {
        let mut freqs = [1u64; 256];
        freqs[0] = 1_000_000;
        freqs[1] = 500_000;
        let book = CodeBook::build(&freqs, 48);
        assert!((book.kraft_sum() - 1.0).abs() < 1e-9, "{}", book.kraft_sum());
    }

    #[test]
    fn matches_classic_huffman_small_case() {
        // Known example: freqs {a:45,b:13,c:12,d:16,e:9,f:5} (CLRS) →
        // lengths {1,3,3,3,4,4}. Embed into 256 symbols by giving the
        // rest tiny counts; verify relative lengths of the 6 heavy
        // symbols keep the CLRS ordering.
        let mut freqs = [1u64; 256];
        let heavy = [45_000_000u64, 13_000_000, 12_000_000, 16_000_000,
                     9_000_000, 5_000_000];
        for (i, &f) in heavy.iter().enumerate() {
            freqs[i] = f;
        }
        let book = CodeBook::build(&freqs, 48);
        let l = book.lengths();
        assert!(l[0] < l[3]);
        assert!(l[3] <= l[1]);
        assert!(l[1] <= l[2]);
        assert!(l[2] <= l[4]);
        assert!(l[4] <= l[5]);
    }

    #[test]
    fn optimality_vs_entropy() {
        // Expected length within [H, H+1) for several random PMFs.
        prop::check("huffman optimality", prop::Config {
            cases: 24, ..Default::default()
        }, |rng, _| {
            let mut freqs = [0u64; 256];
            for f in freqs.iter_mut() {
                *f = 1 + rng.below(100_000);
            }
            let total: u64 = freqs.iter().sum();
            let h: f64 = freqs
                .iter()
                .map(|&f| {
                    let p = f as f64 / total as f64;
                    -p * p.log2()
                })
                .sum();
            let book = CodeBook::build(&freqs, 48);
            let el: f64 = freqs
                .iter()
                .zip(book.lengths())
                .map(|(&f, &l)| f as f64 / total as f64 * l as f64)
                .sum();
            if el < h - 1e-9 {
                return Err(format!("expected length {el} below entropy {h}"));
            }
            if el >= h + 1.0 {
                return Err(format!("expected length {el} not within 1 of {h}"));
            }
            Ok(())
        });
    }

    #[test]
    fn limit_binds_and_stays_optimal_shape() {
        let mut freqs = [0u64; 256];
        let mut a = 1u64;
        let mut b = 1u64;
        for f in freqs.iter_mut() {
            *f = a;
            let c = a.saturating_add(b);
            a = b;
            b = c;
        }
        let free = CodeBook::build(&freqs, 57);
        let capped = CodeBook::build(&freqs, 16);
        assert!(free.max_length() > 16, "test premise: deep without limit");
        assert!(capped.max_length() <= 16);
        // Monotone: more frequent symbol never has a longer code.
        let l = capped.lengths();
        for i in 0..255 {
            // freqs is nondecreasing, so lengths must be nonincreasing…
            assert!(l[i] >= l[i + 1], "i={i}");
        }
    }

    #[test]
    fn from_lengths_rejects_incomplete() {
        assert!(CodeBook::from_lengths(&[0u32; 256]).is_err());
        let mut lengths = [8u32; 256];
        lengths[0] = 0;
        assert!(CodeBook::from_lengths(&lengths).is_err());
        assert!(CodeBook::from_lengths(&[7u32; 256]).is_err()); // Kraft > 1
    }

    #[test]
    fn from_lengths_accepts_incomplete_kraft_below_one() {
        // 255 symbols at 9 bits + 1 at 1 bit: Kraft < 1 (incomplete but
        // decodable).
        let mut lengths = [9u32; 256];
        lengths[0] = 1;
        let book = CodeBook::from_lengths(&lengths).unwrap();
        assert!(book.kraft_sum() < 1.0);
    }

    #[test]
    fn codes_fit_their_lengths() {
        prop::check("code width", prop::Config { cases: 16, ..Default::default() },
                    |rng, _| {
            let mut freqs = [0u64; 256];
            for f in freqs.iter_mut() {
                *f = 1 + rng.below(1_000_000_000);
            }
            let book = CodeBook::build(&freqs, 48);
            for s in 0..256usize {
                let (c, l) = book.code(s as u8);
                if l == 0 || l > 48 {
                    return Err(format!("bad length {l}"));
                }
                if l < 64 && c >> l != 0 {
                    return Err(format!("code wider than length for {s}"));
                }
            }
            Ok(())
        });
    }
}
