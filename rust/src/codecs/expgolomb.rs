//! Order-k Exponential-Golomb codes (paper §1 baseline; k=0 is the
//! H.264 ue(v) code).  Like the Elias codecs, supports an optional
//! frequency-rank mapping for the hybrid ablation.

use super::kernel::{BitCursor, BitSink, DecodeKernel, EncodeKernel};
use super::{Codec, CodecError};
use crate::bitstream::{BitReader, BitWriter};

#[derive(Clone, Debug)]
pub struct ExpGolombCodec {
    k: u32,
    map: [u8; 256],
    unmap: [u8; 256],
    ranked: bool,
}

impl ExpGolombCodec {
    pub fn new(k: u32) -> Self {
        assert!(k <= 8, "order-{k} EG is pointless for a 256-symbol alphabet");
        let mut map = [0u8; 256];
        for (i, m) in map.iter_mut().enumerate() {
            *m = i as u8;
        }
        ExpGolombCodec { k, map, unmap: map, ranked: false }
    }

    pub fn with_ranking(k: u32, rank_order: &[u8; 256]) -> Self {
        let mut c = Self::new(k);
        let mut unmap = [0u8; 256];
        for (rank, &sym) in rank_order.iter().enumerate() {
            c.map[sym as usize] = rank as u8;
            unmap[rank] = sym;
        }
        c.unmap = unmap;
        c.ranked = true;
        c
    }

    pub fn order(&self) -> u32 {
        self.k
    }

    /// Length in bits of the order-k EG code of `n ≥ 0`.
    pub fn value_length(k: u32, n: u32) -> u32 {
        let q = (n >> k) + 1;
        let qbits = 32 - q.leading_zeros();
        (2 * qbits - 1) + k
    }

    fn encode_value(&self, n: u32, out: &mut BitWriter) {
        let q = (n >> self.k) + 1;
        let qbits = 32 - q.leading_zeros();
        out.write_zeros(qbits - 1);
        out.write_bits(q as u64, qbits);
        if self.k > 0 {
            out.write_bits((n & ((1 << self.k) - 1)) as u64, self.k);
        }
    }

    fn decode_value(&self, r: &mut BitReader) -> Result<u32, CodecError> {
        let zeros = r.read_unary().map_err(|_| CodecError::UnexpectedEof)?;
        if zeros > 16 {
            return Err(CodecError::InvalidCode {
                bit_offset: r.bits_consumed(),
            });
        }
        let rest = r
            .read_bits(zeros)
            .map_err(|_| CodecError::UnexpectedEof)?;
        let q = (1u32 << zeros) | rest;
        let low = if self.k > 0 {
            r.read_bits(self.k).map_err(|_| CodecError::UnexpectedEof)?
        } else {
            0
        };
        Ok(((q - 1) << self.k) | low)
    }

    /// Kernel path: one `u64::leading_zeros` on the buffered word
    /// yields the quotient width; quotient, remainder and the consume
    /// all come out of the same window — no separate unary walk.
    fn decode_value_cursor(
        &self,
        cur: &mut BitCursor,
    ) -> Result<u32, CodecError> {
        let avail = cur.refill_buffered();
        let w = cur.word();
        let lz = w.leading_zeros();
        let total = 2 * lz + 1 + self.k;
        // Whole code inside the valid window and a sane prefix
        // (`zeros ≤ 16` mirrors the scalar validity bound).
        if lz <= 16 && total <= avail {
            let q = (w >> (63 - 2 * lz)) as u32;
            let low = if self.k > 0 {
                (w >> (64 - total)) as u32 & ((1 << self.k) - 1)
            } else {
                0
            };
            cur.consume(total);
            return Ok(((q - 1) << self.k) | low);
        }
        // Straddling / EOF / invalid-prefix path, checked step by step.
        let zeros = cur.read_unary()?;
        if zeros > 16 {
            return Err(CodecError::InvalidCode {
                bit_offset: cur.bits_consumed(),
            });
        }
        let rest = cur.read_bits(zeros)?;
        let q = (1u32 << zeros) | rest;
        let low =
            if self.k > 0 { cur.read_bits(self.k)? } else { 0 };
        Ok(((q - 1) << self.k) | low)
    }
}

impl DecodeKernel for ExpGolombCodec {
    fn decode_batch(
        &self,
        cur: &mut BitCursor,
        out: &mut [u8],
    ) -> Result<usize, CodecError> {
        for slot in out.iter_mut() {
            let v = self.decode_value_cursor(cur)?;
            if v > 255 {
                return Err(CodecError::InvalidCode {
                    bit_offset: cur.bits_consumed(),
                });
            }
            *slot = self.unmap[v as usize];
        }
        Ok(out.len())
    }
}

impl EncodeKernel for ExpGolombCodec {
    /// Encode mirror of [`decode_value_cursor`]'s fused window: the
    /// unary quotient prefix and the k-bit remainder collapse into one
    /// (value, width) field — `q` carries its own `qbits − 1` zero
    /// prefix, so `(q << k) | low` in `2·qbits − 1 + k` bits is the
    /// whole code (≤ 17 + 8 bits for a 256-symbol alphabet).
    ///
    /// [`decode_value_cursor`]: ExpGolombCodec::decode_value_cursor
    fn encode_batch(&self, symbols: &[u8], sink: &mut BitSink) {
        let k = self.k;
        let low_mask = (1u32 << k) - 1;
        for &s in symbols {
            let n = self.map[s as usize] as u32;
            let q = (n >> k) + 1;
            let qbits = 32 - q.leading_zeros();
            let code = ((q as u64) << k) | (n & low_mask) as u64;
            sink.push(code, (2 * qbits - 1) + k);
        }
    }
}

impl Codec for ExpGolombCodec {
    fn name(&self) -> String {
        if self.ranked {
            format!("expgolomb-k{}-ranked", self.k)
        } else {
            format!("expgolomb-k{}", self.k)
        }
    }

    fn encode_scalar(&self, symbols: &[u8], out: &mut BitWriter) {
        for &s in symbols {
            self.encode_value(self.map[s as usize] as u32, out);
        }
    }

    fn decode_scalar_into(
        &self,
        reader: &mut BitReader,
        out: &mut [u8],
    ) -> Result<(), CodecError> {
        for slot in out.iter_mut() {
            let v = self.decode_value(reader)?;
            if v > 255 {
                return Err(CodecError::InvalidCode {
                    bit_offset: reader.bits_consumed(),
                });
            }
            *slot = self.unmap[v as usize];
        }
        Ok(())
    }

    fn code_lengths(&self) -> [u32; 256] {
        let mut lengths = [0u32; 256];
        for s in 0..256 {
            lengths[s] = Self::value_length(self.k, self.map[s] as u32);
        }
        lengths
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::testutil;

    #[test]
    fn k0_known_codes() {
        // ue(v): 0→"1" (1b), 1→"010", 2→"011", 3→"00100".
        for (n, len) in [(0u32, 1u32), (1, 3), (2, 3), (3, 5), (6, 5), (7, 7)] {
            assert_eq!(ExpGolombCodec::value_length(0, n), len, "n={n}");
        }
    }

    #[test]
    fn k3_lengths() {
        // k=3: values 0..7 → 1+3=4 bits; 8..23 → 3+3=6 bits.
        for n in 0..8u32 {
            assert_eq!(ExpGolombCodec::value_length(3, n), 4);
        }
        for n in 8..24u32 {
            assert_eq!(ExpGolombCodec::value_length(3, n), 6);
        }
    }

    #[test]
    fn value_lengths_match_encoder() {
        for k in 0..=8u32 {
            let codec = ExpGolombCodec::new(k);
            for n in 0..=255u32 {
                let mut w = BitWriter::new();
                codec.encode_value(n, &mut w);
                assert_eq!(
                    w.bit_len(),
                    ExpGolombCodec::value_length(k, n) as u64,
                    "k={k} n={n}"
                );
            }
        }
    }

    #[test]
    fn all_symbols_roundtrip_all_orders() {
        for k in [0u32, 1, 3, 5, 8] {
            let codec = ExpGolombCodec::new(k);
            let symbols: Vec<u8> = (0..=255).collect();
            let enc = codec.encode_to_vec(&symbols);
            assert_eq!(
                codec.decode_from_slice(&enc, 256).unwrap(),
                symbols,
                "k={k}"
            );
        }
    }

    #[test]
    fn ranked_roundtrip() {
        let mut rank = [0u8; 256];
        for i in 0..256 {
            rank[i] = i.wrapping_mul(37) as u8; // a permutation of 0..=255
        }
        let codec = ExpGolombCodec::with_ranking(2, &rank);
        let symbols: Vec<u8> = (0..=255).rev().collect();
        let enc = codec.encode_to_vec(&symbols);
        assert_eq!(codec.decode_from_slice(&enc, 256).unwrap(), symbols);
    }

    #[test]
    fn truncated_errors() {
        let codec = ExpGolombCodec::new(0);
        let enc = codec.encode_to_vec(&[255u8; 3]);
        assert!(codec
            .decode_from_slice(&enc[..enc.len() - 2], 3)
            .is_err());
    }

    #[test]
    #[should_panic(expected = "pointless")]
    fn rejects_excessive_order() {
        ExpGolombCodec::new(9);
    }

    #[test]
    fn prop_roundtrip_k0() {
        testutil::roundtrip_property(&ExpGolombCodec::new(0));
    }

    #[test]
    fn prop_roundtrip_k3() {
        testutil::roundtrip_property(&ExpGolombCodec::new(3));
    }

    #[test]
    fn prop_roundtrip_k8() {
        testutil::roundtrip_property(&ExpGolombCodec::new(8));
    }
}
