//! The batched decode kernel: a 64-bit buffered [`BitCursor`]
//! (refill once, peek many) and the [`DecodeKernel`] trait every codec
//! implements.
//!
//! The paper's whole argument is that QLC's 3-prefix-bit + LUT
//! structure decodes *fast*.  The scalar path
//! ([`Codec::decode_scalar_into`](super::Codec::decode_scalar_into))
//! resolves one symbol per call, paying a refill check, an EOF check
//! and a table walk each time.  The kernel inverts that: one refill
//! tops the staging word up to ≥ 57 valid bits, and the codec then
//! resolves as many whole codes as the word holds with *no* further
//! checks — up to 9 six-bit QLC codes or 8 Huffman root-table hits per
//! refill.  Codes that embed their own length (Elias, Exp-Golomb)
//! batch through `u64::leading_zeros` on the same word: the prefix
//! length, the payload and the consume all come out of a single
//! count-leading-zeros.
//!
//! Everything above `codecs/` decodes through this kernel:
//! [`DecoderSession`](super::DecoderSession) builds a cursor per
//! chunk, the QLF2 frame reader and the transport/collective chunk
//! pipeline decode through sessions, and the registry's handles vend
//! sessions.  The scalar path survives as a reference implementation
//! (`decode_scalar_into`) used by the equivalence proptests, the
//! hardware model and the batched-vs-scalar bench section.
//!
//! # The `DecodeKernel` contract
//!
//! `decode_batch(cur, out)` decodes **exactly `out.len()` symbols**
//! from `cur` and returns that count.  On error (`UnexpectedEof`,
//! `InvalidCode`) the contents of `out` and the cursor position are
//! unspecified.  The cursor is *not* required to be byte-aligned on
//! entry, and it is left exactly past the last consumed code on
//! success — callers (the adaptive codec, multi-chunk QLF1 payloads)
//! may keep decoding from the same cursor.

use super::CodecError;

/// A 64-bit buffered bit cursor over a byte slice, MSB-first (the
/// first bit of byte 0 is bit 63 of the staging word).  The batch
/// decode substrate: `refill` once, then `word`/`consume` many times
/// with no bounds checks until the buffered budget runs out.
#[derive(Clone, Debug)]
pub struct BitCursor<'a> {
    data: &'a [u8],
    /// Next byte to load into the staging word.
    byte_pos: usize,
    /// Staging word: next bit to deliver is the MSB.  Bits below the
    /// valid window are always zero (loads mask them), so indexing a
    /// LUT with more bits than are buffered hits zero-padded slots.
    word: u64,
    /// Valid bits in `word`.
    avail: u32,
    /// Total bits consumed.
    consumed: u64,
}

impl<'a> BitCursor<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        BitCursor { data, byte_pos: 0, word: 0, avail: 0, consumed: 0 }
    }

    /// Refill the staging word to ≥ 57 valid bits (while input
    /// remains).  Fast path: one unaligned 8-byte load masked to the
    /// bytes that fit.
    #[inline]
    pub fn refill(&mut self) {
        if self.avail > 56 {
            return;
        }
        let rem = self.data.len() - self.byte_pos;
        if rem >= 8 {
            let w = u64::from_be_bytes(
                self.data[self.byte_pos..self.byte_pos + 8]
                    .try_into()
                    .unwrap(),
            );
            let take_bytes = ((64 - self.avail) / 8) as usize; // 1..=8
            let keep = w & (!0u64).wrapping_shl(64 - take_bytes as u32 * 8);
            self.word |= keep >> self.avail;
            self.byte_pos += take_bytes;
            self.avail += take_bytes as u32 * 8;
        } else {
            while self.avail <= 56 && self.byte_pos < self.data.len() {
                let b = self.data[self.byte_pos] as u64;
                self.byte_pos += 1;
                self.word |= b << (56 - self.avail);
                self.avail += 8;
            }
        }
    }

    /// Refill, then report how many valid bits are buffered (≤ 64).
    /// Batch loops size their checked-once inner iteration from this.
    #[inline]
    pub fn refill_buffered(&mut self) -> u32 {
        self.refill();
        self.avail
    }

    /// Valid bits currently buffered, without refilling.
    #[inline]
    pub fn buffered(&self) -> u32 {
        self.avail
    }

    /// The raw staging word; its top [`buffered`](Self::buffered) bits
    /// are valid, the rest are zero.
    #[inline]
    pub fn word(&self) -> u64 {
        self.word
    }

    /// Consume `n ≤ buffered()` bits previously examined via
    /// [`word`](Self::word).
    #[inline]
    pub fn consume(&mut self, n: u32) {
        debug_assert!(n <= self.avail);
        // `n` can be a full 64 bits (e.g. eight raw symbols at once);
        // `<<` alone would overflow the shift.
        self.word = if n >= 64 { 0 } else { self.word << n };
        self.avail -= n;
        self.consumed += n as u64;
    }

    /// Peek up to 32 bits without consuming (zero-padded past EOF).
    #[inline]
    pub fn peek(&mut self, n: u32) -> u32 {
        debug_assert!(n <= 32);
        self.refill();
        if n == 0 {
            return 0;
        }
        (self.word >> (64 - n)) as u32
    }

    /// Read `n` ≤ 32 bits MSB-first, checking for EOF.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u32, CodecError> {
        if self.remaining_bits() < n as u64 {
            return Err(CodecError::UnexpectedEof);
        }
        let v = self.peek(n);
        // peek refilled, so avail ≥ n is guaranteed by the bound above.
        self.consume(n);
        Ok(v)
    }

    /// Count and consume leading zero bits up to the next 1 bit, then
    /// consume the 1 bit; returns the zero count.  One
    /// `u64::leading_zeros` resolves runs of up to 64 — the slow-path
    /// complement of the kernels' inline LZC fast paths.
    pub fn read_unary(&mut self) -> Result<u32, CodecError> {
        let mut zeros = 0u32;
        loop {
            self.refill();
            if self.avail == 0 {
                return Err(CodecError::UnexpectedEof);
            }
            // Bits below `avail` are zero, so a 1 found by the LZC is
            // always within the valid window iff lz < avail.
            let lz = self.word.leading_zeros().min(self.avail);
            if lz < self.avail {
                zeros += lz;
                self.consume(lz + 1);
                return Ok(zeros);
            }
            zeros += lz;
            self.consume(lz);
        }
    }

    pub fn bits_consumed(&self) -> u64 {
        self.consumed
    }

    pub fn remaining_bits(&self) -> u64 {
        (self.data.len() as u64) * 8 - self.consumed
    }
}

/// The batched decode primitive.  See the module docs for the full
/// contract: decode **exactly `out.len()`** symbols, return the count,
/// leave the cursor just past the last code.
pub trait DecodeKernel {
    fn decode_batch(
        &self,
        cur: &mut BitCursor<'_>,
        out: &mut [u8],
    ) -> Result<usize, CodecError>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::{BitReader, BitWriter};
    use crate::codecs::{Codec, CodecRegistry};
    use crate::stats::Histogram;
    use crate::util::prop;

    #[test]
    fn cursor_matches_bitreader_on_random_fields() {
        prop::check("cursor==reader", Default::default(), |rng, size| {
            let nfields = rng.below(size as u64 + 1) as usize;
            let fields: Vec<(u64, u32)> = (0..nfields)
                .map(|_| {
                    let n = 1 + rng.below(32) as u32;
                    (rng.next_u64() & ((1u64 << n) - 1), n)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, n) in &fields {
                w.write_bits(v, n);
            }
            let buf = w.finish();
            let mut cur = BitCursor::new(&buf);
            let mut rdr = BitReader::new(&buf);
            for (i, &(v, n)) in fields.iter().enumerate() {
                let a = cur.read_bits(n).map_err(|e| e.to_string())? as u64;
                let b = rdr.read_bits(n).map_err(|e| e.to_string())? as u64;
                if a != v || b != v {
                    return Err(format!("field {i}: cursor {a} reader {b} want {v}"));
                }
                if cur.bits_consumed() != rdr.bits_consumed() {
                    return Err("consumed counts diverged".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cursor_unary_matches_bitreader() {
        for zeros in [0u32, 1, 7, 31, 32, 33, 63, 64, 65, 130] {
            let mut w = BitWriter::new();
            w.write_zeros(zeros);
            w.write_bit(true);
            w.write_bits(0b101, 3);
            let buf = w.finish();
            let mut cur = BitCursor::new(&buf);
            assert_eq!(cur.read_unary().unwrap(), zeros, "zeros={zeros}");
            assert_eq!(cur.read_bits(3).unwrap(), 0b101);
        }
        // All-zero stream: no terminating 1 → EOF.
        let mut cur = BitCursor::new(&[0u8; 4]);
        assert_eq!(cur.read_unary(), Err(CodecError::UnexpectedEof));
    }

    #[test]
    fn cursor_eof_detection() {
        let mut cur = BitCursor::new(&[0xFF]);
        assert_eq!(cur.read_bits(8).unwrap(), 0xFF);
        assert_eq!(cur.read_bits(1), Err(CodecError::UnexpectedEof));
        assert_eq!(cur.remaining_bits(), 0);
    }

    #[test]
    fn word_is_zero_padded_past_eof() {
        let mut cur = BitCursor::new(&[0xFF]);
        cur.refill();
        assert_eq!(cur.buffered(), 8);
        assert_eq!(cur.word(), 0xFFu64 << 56);
    }

    /// The satellite equivalence property: `decode_batch` ≡ the scalar
    /// reference path symbol-for-symbol, for every registered codec,
    /// on random payloads — including the consumed-bit count, so a
    /// kernel cannot "win" by skipping validation work.
    #[test]
    fn prop_batch_equals_scalar_all_registered_codecs() {
        let reg = CodecRegistry::global();
        prop::check("batch==scalar", prop::Config {
            cases: 64, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size);
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();
            let encoded = codec.encode_to_vec(&symbols);

            let mut batched = vec![0u8; symbols.len()];
            let mut cur = BitCursor::new(&encoded);
            codec
                .decode_into(&mut cur, &mut batched)
                .map_err(|e| format!("{name} batched: {e}"))?;

            let mut scalar = vec![0u8; symbols.len()];
            let mut rdr = BitReader::new(&encoded);
            codec
                .decode_scalar_into(&mut rdr, &mut scalar)
                .map_err(|e| format!("{name} scalar: {e}"))?;

            if batched != symbols {
                return Err(format!("{name}: batched decode mismatch"));
            }
            if scalar != symbols {
                return Err(format!("{name}: scalar decode mismatch"));
            }
            if cur.bits_consumed() != rdr.bits_consumed() {
                return Err(format!(
                    "{name}: batched consumed {} bits, scalar {}",
                    cur.bits_consumed(),
                    rdr.bits_consumed()
                ));
            }
            Ok(())
        });
    }

    /// Truncations must error on both paths (never panic, never
    /// diverge into one Ok / one Err on the *same* cut only when the
    /// cut leaves a decodable prefix — then both must agree).
    #[test]
    fn prop_batch_and_scalar_agree_on_truncation() {
        let reg = CodecRegistry::global();
        prop::check("batch==scalar truncated", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size.max(8));
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();
            let encoded = codec.encode_to_vec(&symbols);
            let keep = rng.below(encoded.len() as u64 + 1) as usize;
            let cut = &encoded[..keep];

            let mut batched = vec![0u8; symbols.len()];
            let mut cur = BitCursor::new(cut);
            let b = codec.decode_into(&mut cur, &mut batched);

            let mut scalar = vec![0u8; symbols.len()];
            let mut rdr = BitReader::new(cut);
            let s = codec.decode_scalar_into(&mut rdr, &mut scalar);

            if b.is_ok() != s.is_ok() {
                return Err(format!(
                    "{name}: truncated at {keep}: batched {b:?}, scalar {s:?}"
                ));
            }
            if b.is_ok() && batched != scalar {
                return Err(format!("{name}: truncated decode diverged"));
            }
            Ok(())
        });
    }
}
