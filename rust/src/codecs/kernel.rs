//! The batched codec kernels: a 64-bit buffered [`BitCursor`]
//! (refill once, peek many) and its write-side mirror [`BitSink`]
//! (accumulate codes in a staging word, spill whole words), the
//! [`DecodeKernel`]/[`EncodeKernel`] traits every codec implements,
//! and the lane-interleaved engines ([`LaneDecoder`]/[`LaneEncoder`])
//! that step several independent chunk streams in lockstep.
//!
//! The paper's whole argument is that QLC's 3-prefix-bit + LUT
//! structure decodes *fast*.  The scalar path
//! ([`Codec::decode_scalar_into`](super::Codec::decode_scalar_into))
//! resolves one symbol per call, paying a refill check, an EOF check
//! and a table walk each time.  The kernel inverts that: one refill
//! tops the staging word up to ≥ 57 valid bits, and the codec then
//! resolves as many whole codes as the word holds with *no* further
//! checks — up to 9 six-bit QLC codes or 8 Huffman root-table hits per
//! refill.  Codes that embed their own length (Elias, Exp-Golomb)
//! batch through `u64::leading_zeros` on the same word: the prefix
//! length, the payload and the consume all come out of a single
//! count-leading-zeros.
//!
//! Everything above `codecs/` decodes through this kernel:
//! [`DecoderSession`](super::DecoderSession) builds a cursor per
//! chunk, the QLF2 frame reader and the transport/collective chunk
//! pipeline decode through sessions, and the registry's handles vend
//! sessions.  The scalar path survives as a reference implementation
//! (`decode_scalar_into`) used by the equivalence proptests, the
//! hardware model and the batched-vs-scalar bench section.
//!
//! # The `DecodeKernel` contract
//!
//! `decode_batch(cur, out)` decodes **exactly `out.len()` symbols**
//! from `cur` and returns that count.  On error (`UnexpectedEof`,
//! `InvalidCode`) the contents of `out` and the cursor position are
//! unspecified.  The cursor is *not* required to be byte-aligned on
//! entry, and it is left exactly past the last consumed code on
//! success — callers (the adaptive codec, multi-chunk QLF1 payloads)
//! may keep decoding from the same cursor.
//!
//! # Lanes
//!
//! One cursor's decode is a serial dependency chain: every symbol's
//! table lookup waits on the previous symbol's shift-and-consume.
//! QLF2 chunks are *independent* streams, so
//! [`DecodeKernel::decode_lanes`] steps N of them in lockstep — each
//! round resolves
//! one code from every lane, and because the lanes share no state the
//! lookups of different chunks overlap in the pipeline (software ILP;
//! QLC additionally has an AVX2 vector-peek path behind runtime
//! feature detection).  [`LaneDecoder`] is the scheduling engine:
//! runtime-selected 4- or 8-wide, it tiles an arbitrary job list into
//! lane groups and must decode **exactly** what the batched path
//! decodes, symbol for symbol and consumed-bit for consumed-bit (the
//! equivalence proptests below hold every registered codec to that).
//!
//! # The encode side
//!
//! Encode mirrors the same design.  The scalar path
//! ([`Codec::encode_scalar`](super::Codec::encode_scalar)) pushes one
//! code at a time through [`BitWriter`](crate::bitstream::BitWriter),
//! flushing bytes as they fill.  [`EncodeKernel::encode_batch`]
//! instead reads the codec's (code, length) LUT once per symbol and
//! shift-ors the code into a [`BitSink`] staging word, spilling eight
//! bytes at a time — the "single-stage encoder" structure: no per-bit
//! loop anywhere on the hot path.  Codecs with short codes pack
//! several per push (QLC's ≤ 13-bit codes go four to a staging word;
//! raw bytes go seven); codecs that compute prefix + payload (Elias
//! γ/δ/ω, Exp-Golomb) fuse both into one masked insert.
//! `encode_batch` must produce **bit-for-bit identical** bytes to
//! `encode_scalar` — scalar is the proptest ground truth, and the
//! QLF2 frame format is unchanged no matter which path produced it.
//! [`EncodeKernel::encode_lanes`] interleaves independent chunk
//! encodes in lane-major rounds like the decode engine, and
//! [`LaneEncoder`] tiles job lists into groups the same way
//! [`LaneDecoder`] does.

use super::CodecError;

/// A 64-bit buffered bit cursor over a byte slice, MSB-first (the
/// first bit of byte 0 is bit 63 of the staging word).  The batch
/// decode substrate: `refill` once, then `word`/`consume` many times
/// with no bounds checks until the buffered budget runs out.
#[derive(Clone, Debug)]
pub struct BitCursor<'a> {
    data: &'a [u8],
    /// Next byte to load into the staging word.
    byte_pos: usize,
    /// Staging word: next bit to deliver is the MSB.  Bits below the
    /// valid window are always zero (loads mask them), so indexing a
    /// LUT with more bits than are buffered hits zero-padded slots.
    word: u64,
    /// Valid bits in `word`.
    avail: u32,
    /// Total bits consumed.
    consumed: u64,
}

impl<'a> BitCursor<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        BitCursor { data, byte_pos: 0, word: 0, avail: 0, consumed: 0 }
    }

    /// Refill the staging word to ≥ 57 valid bits (while input
    /// remains).  Fast path: one unaligned 8-byte load masked to the
    /// bytes that fit.
    #[inline]
    pub fn refill(&mut self) {
        if self.avail > 56 {
            return;
        }
        let rem = self.data.len() - self.byte_pos;
        if rem >= 8 {
            // lint: infallible(rem >= 8 guarantees an 8-byte slice)
            let w = u64::from_be_bytes(
                self.data[self.byte_pos..self.byte_pos + 8]
                    .try_into()
                    .unwrap(),
            );
            let take_bytes = ((64 - self.avail) / 8) as usize; // 1..=8
            let keep = w & (!0u64).wrapping_shl(64 - take_bytes as u32 * 8);
            self.word |= keep >> self.avail;
            self.byte_pos += take_bytes;
            self.avail += take_bytes as u32 * 8;
        } else {
            while self.avail <= 56 && self.byte_pos < self.data.len() {
                let b = self.data[self.byte_pos] as u64;
                self.byte_pos += 1;
                self.word |= b << (56 - self.avail);
                self.avail += 8;
            }
        }
    }

    /// Refill, then report how many valid bits are buffered (≤ 64).
    /// Batch loops size their checked-once inner iteration from this.
    #[inline]
    pub fn refill_buffered(&mut self) -> u32 {
        self.refill();
        self.avail
    }

    /// Valid bits currently buffered, without refilling.
    #[inline]
    pub fn buffered(&self) -> u32 {
        self.avail
    }

    /// The raw staging word; its top [`buffered`](Self::buffered) bits
    /// are valid, the rest are zero.
    #[inline]
    pub fn word(&self) -> u64 {
        self.word
    }

    /// Consume `n ≤ buffered()` bits previously examined via
    /// [`word`](Self::word).
    #[inline]
    pub fn consume(&mut self, n: u32) {
        debug_assert!(n <= self.avail);
        // `n` can be a full 64 bits (e.g. eight raw symbols at once);
        // `<<` alone would overflow the shift.
        self.word = if n >= 64 { 0 } else { self.word << n };
        self.avail -= n;
        self.consumed += n as u64;
    }

    /// Peek up to 32 bits without consuming (zero-padded past EOF).
    #[inline]
    pub fn peek(&mut self, n: u32) -> u32 {
        debug_assert!(n <= 32);
        self.refill();
        if n == 0 {
            return 0;
        }
        (self.word >> (64 - n)) as u32
    }

    /// Read `n` ≤ 32 bits MSB-first, checking for EOF.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u32, CodecError> {
        if self.remaining_bits() < n as u64 {
            return Err(CodecError::UnexpectedEof);
        }
        let v = self.peek(n);
        // peek refilled, so avail ≥ n is guaranteed by the bound above.
        self.consume(n);
        Ok(v)
    }

    /// Count and consume leading zero bits up to the next 1 bit, then
    /// consume the 1 bit; returns the zero count.  One
    /// `u64::leading_zeros` resolves runs of up to 64 — the slow-path
    /// complement of the kernels' inline LZC fast paths.
    pub fn read_unary(&mut self) -> Result<u32, CodecError> {
        let mut zeros = 0u32;
        loop {
            self.refill();
            if self.avail == 0 {
                return Err(CodecError::UnexpectedEof);
            }
            // Bits below `avail` are zero, so a 1 found by the LZC is
            // always within the valid window iff lz < avail.
            let lz = self.word.leading_zeros().min(self.avail);
            if lz < self.avail {
                zeros += lz;
                self.consume(lz + 1);
                return Ok(zeros);
            }
            zeros += lz;
            self.consume(lz);
        }
    }

    pub fn bits_consumed(&self) -> u64 {
        self.consumed
    }

    pub fn remaining_bits(&self) -> u64 {
        (self.data.len() as u64) * 8 - self.consumed
    }
}

/// A 64-bit staging-word bit writer, MSB-first — [`BitCursor`]'s
/// write-side mirror and the batch *encode* substrate.  Codes are
/// shift-or'd into the top of the staging word; whenever the word
/// fills, all eight bytes spill to the byte buffer at once
/// (big-endian, so the byte stream is identical to
/// [`BitWriter`](crate::bitstream::BitWriter)'s bit-at-a-time /
/// byte-at-a-time output), and [`finish`](Self::finish) /
/// [`drain_into`](Self::drain_into) flush the ragged tail zero-padded
/// to a byte boundary.  For any sequence of `(value, width)` pushes,
/// the bytes are **exactly** the bytes `BitWriter::write_bits` +
/// `finish` would produce — the kernel equivalence proptests depend
/// on that.
#[derive(Clone, Debug)]
pub struct BitSink {
    buf: Vec<u8>,
    /// Staging word, filled from the MSB down; bits below the filled
    /// window are always zero (so the tail flush is pre-padded).
    word: u64,
    /// Unfilled low bits in `word` (64 − filled).
    free: u32,
    /// Total bits pushed since construction / the last reset.
    total_bits: u64,
}

impl BitSink {
    pub fn new() -> BitSink {
        BitSink { buf: Vec::new(), word: 0, free: 64, total_bits: 0 }
    }

    /// Pre-size the byte buffer for roughly `nbytes` of output.
    pub fn with_capacity(nbytes: usize) -> BitSink {
        BitSink { buf: Vec::with_capacity(nbytes), word: 0, free: 64, total_bits: 0 }
    }

    /// Append the low `n ≤ 57` bits of `code`, MSB-first.  Bits of
    /// `code` above `n` must be zero (codecs' LUT entries and fused
    /// prefix+payload inserts satisfy this by construction).
    #[inline]
    pub fn push(&mut self, code: u64, n: u32) {
        debug_assert!(n <= 57, "push width {n} exceeds the staging budget");
        debug_assert!(n == 64 || code >> 1 >> (n.max(1) - 1) == 0);
        self.total_bits += n as u64;
        if n < self.free {
            self.free -= n;
            self.word |= code << self.free;
        } else {
            // Split: the top `free` bits of the field complete the
            // staging word, the low `over` bits seed the next one.
            let over = n - self.free; // 0..=56
            self.word |= if over == 0 { code } else { code >> over };
            self.buf.extend_from_slice(&self.word.to_be_bytes());
            self.word = if over == 0 { 0 } else { code << (64 - over) };
            self.free = 64 - over;
        }
    }

    /// Total bits pushed (not rounded up to bytes).
    pub fn bit_len(&self) -> u64 {
        self.total_bits
    }

    /// Spill the staged tail (zero-padded to a byte boundary) into the
    /// byte buffer.
    fn flush_tail(&mut self) {
        let filled = 64 - self.free;
        if filled > 0 {
            let nbytes = ((filled + 7) / 8) as usize;
            self.buf.extend_from_slice(&self.word.to_be_bytes()[..nbytes]);
        }
        self.word = 0;
        self.free = 64;
    }

    /// Flush the tail and return the encoded bytes.
    pub fn finish(mut self) -> Vec<u8> {
        self.flush_tail();
        self.buf
    }

    /// Flush the tail, append all bytes to `out`, and reset for reuse
    /// — mirrors [`BitWriter::drain_into`](crate::bitstream::BitWriter::drain_into)
    /// for per-chunk (byte-aligned) encode loops.
    pub fn drain_into(&mut self, out: &mut Vec<u8>) {
        self.flush_tail();
        out.extend_from_slice(&self.buf);
        self.reset();
    }

    /// Clear all state for reuse.
    pub fn reset(&mut self) {
        self.buf.clear();
        self.word = 0;
        self.free = 64;
        self.total_bits = 0;
    }
}

impl Default for BitSink {
    fn default() -> BitSink {
        BitSink::new()
    }
}

/// Maximum number of lanes a lockstep group steps together.
pub const MAX_LANES: usize = 8;

/// One independent compressed stream inside a lockstep lane group: a
/// cursor over its payload plus the destination slice and fill mark.
pub struct Lane<'d, 'o> {
    pub cur: BitCursor<'d>,
    pub out: &'o mut [u8],
    /// Next output index (lanes of unequal size finish at different
    /// rounds).
    pub pos: usize,
}

impl<'d, 'o> Lane<'d, 'o> {
    pub fn new(payload: &'d [u8], out: &'o mut [u8]) -> Lane<'d, 'o> {
        Lane { cur: BitCursor::new(payload), out, pos: 0 }
    }

    /// Symbols this lane still has to decode.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.out.len() - self.pos
    }
}

/// One decode job for the lane engine: an independent byte-aligned
/// chunk payload and the slice its symbols land in (exactly
/// `out.len()` symbols are decoded).
pub struct LaneJob<'d, 'o> {
    pub payload: &'d [u8],
    pub out: &'o mut [u8],
}

/// Whether the AVX2 vector-peek lane path is available on this CPU
/// (cached runtime detection; always `false` off x86_64, and under
/// Miri, which interprets no vector intrinsics).
#[inline]
pub fn lanes_avx2_available() -> bool {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        use std::sync::atomic::{AtomicU8, Ordering};
        static CACHE: AtomicU8 = AtomicU8::new(0); // 0 unknown, 1 no, 2 yes
        match CACHE.load(Ordering::Relaxed) {
            2 => true,
            1 => false,
            _ => {
                let yes = is_x86_feature_detected!("avx2");
                CACHE.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
                yes
            }
        }
    }
    #[cfg(any(not(target_arch = "x86_64"), miri))]
    {
        false
    }
}

/// Whether the NEON vector-peek lane path is available on this CPU
/// (cached runtime detection; always `false` off aarch64, and under
/// Miri, which interprets no vector intrinsics).
#[inline]
pub fn lanes_neon_available() -> bool {
    #[cfg(all(target_arch = "aarch64", not(miri)))]
    {
        use std::sync::atomic::{AtomicU8, Ordering};
        static CACHE: AtomicU8 = AtomicU8::new(0); // 0 unknown, 1 no, 2 yes
        match CACHE.load(Ordering::Relaxed) {
            2 => true,
            1 => false,
            _ => {
                let yes = std::arch::is_aarch64_feature_detected!("neon");
                CACHE.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
                yes
            }
        }
    }
    #[cfg(any(not(target_arch = "aarch64"), miri))]
    {
        false
    }
}

/// Whether *any* vector-peek lane path is available — AVX2 on x86_64,
/// NEON on aarch64.  The lane-width auto-selection keys off this so a
/// full 8-lane group feeds whichever vector burst the CPU has.
#[inline]
pub fn lanes_vector_available() -> bool {
    lanes_avx2_available() || lanes_neon_available()
}

/// Vector peek for a full 8-lane group: the top `bits` of eight
/// staging words extracted with one AVX2 shift per 4-word half.
///
/// # Safety
///
/// Requires AVX2; callers must have checked
/// [`lanes_avx2_available`] first.
#[cfg(all(target_arch = "x86_64", not(miri)))]
#[target_feature(enable = "avx2")]
pub unsafe fn peek_top_bits_x8(words: &[u64; 8], bits: u32) -> [u32; 8] {
    use std::arch::x86_64::{
        __m256i, _mm256_loadu_si256, _mm256_srl_epi64, _mm256_storeu_si256,
        _mm_cvtsi32_si128,
    };
    // SAFETY: the caller upholds the AVX2 contract above; every
    // unaligned load/store touches exactly one half of a stack-owned
    // `[u64; 8]`/`[u64; 4]`-sized buffer, in bounds by construction.
    unsafe {
        let shift = _mm_cvtsi32_si128(64 - bits as i32);
        let lo = _mm256_loadu_si256(words.as_ptr() as *const __m256i);
        let hi = _mm256_loadu_si256(words.as_ptr().add(4) as *const __m256i);
        let lo = _mm256_srl_epi64(lo, shift);
        let hi = _mm256_srl_epi64(hi, shift);
        let mut shifted = [0u64; 8];
        _mm256_storeu_si256(shifted.as_mut_ptr() as *mut __m256i, lo);
        _mm256_storeu_si256(
            shifted.as_mut_ptr().add(4) as *mut __m256i,
            hi,
        );
        let mut out = [0u32; 8];
        for (o, w) in out.iter_mut().zip(shifted.iter()) {
            *o = *w as u32;
        }
        out
    }
}

/// NEON analogue of [`peek_top_bits_x8`]: the top `bits` of eight
/// staging words extracted with four 2-wide `USHL` right shifts
/// (NEON's variable shift takes a negative count for right shifts —
/// there is no variable-immediate `vshrq`).
///
/// # Safety
///
/// Requires NEON; callers must have checked [`lanes_neon_available`]
/// first.
#[cfg(all(target_arch = "aarch64", not(miri)))]
#[target_feature(enable = "neon")]
pub unsafe fn peek_top_bits_x8_neon(
    words: &[u64; 8],
    bits: u32,
) -> [u32; 8] {
    use std::arch::aarch64::{vdupq_n_s64, vld1q_u64, vshlq_u64, vst1q_u64};
    // SAFETY: the caller upholds the NEON contract above; every
    // load/store touches exactly one 2-word pair of a stack-owned
    // `[u64; 8]`-sized buffer, in bounds by construction.
    unsafe {
        let shift = vdupq_n_s64(-((64 - bits) as i64));
        let mut shifted = [0u64; 8];
        for pair in 0..4 {
            let v = vld1q_u64(words.as_ptr().add(pair * 2));
            vst1q_u64(
                shifted.as_mut_ptr().add(pair * 2),
                vshlq_u64(v, shift),
            );
        }
        let mut out = [0u32; 8];
        for (o, w) in out.iter_mut().zip(shifted.iter()) {
            *o = *w as u32;
        }
        out
    }
}

/// The lane-interleaved decode engine: tiles independent chunk jobs
/// into groups of up to [`MAX_LANES`] lanes and steps each group in
/// lockstep through one codec's [`DecodeKernel::decode_lanes`].
///
/// The width is runtime-selected: 8 lanes when the CPU has a vector
/// peek path (AVX2 on x86_64, NEON on aarch64 — a full group feeds
/// it), 4 otherwise (enough independent chains to fill a scalar
/// out-of-order pipeline).
#[derive(Clone, Copy, Debug)]
pub struct LaneDecoder {
    lanes: usize,
}

impl LaneDecoder {
    /// Runtime-selected lane width (see the type docs).
    pub fn auto() -> LaneDecoder {
        LaneDecoder { lanes: if lanes_vector_available() { 8 } else { 4 } }
    }

    /// Explicit lane width; 4 and 8 are supported.
    pub fn with_lanes(lanes: usize) -> Result<LaneDecoder, String> {
        if lanes == 4 || lanes == 8 {
            Ok(LaneDecoder { lanes })
        } else {
            Err(format!("lane width {lanes} unsupported (expected 4 or 8)"))
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Decode every job — `self.lanes` of them in lockstep at a time —
    /// through `kernel`.  Each job decodes exactly `out.len()`
    /// symbols.  Jobs that cannot possibly hold their symbol count
    /// (every code is ≥ 1 bit) are rejected before any cursor is
    /// built, matching
    /// [`DecoderSession::decode_chunk`](super::DecoderSession::decode_chunk).
    /// On `Err` the contents of every job's `out` are unspecified.
    pub fn decode_jobs<K: DecodeKernel + ?Sized>(
        &self,
        kernel: &K,
        jobs: &mut [LaneJob<'_, '_>],
    ) -> Result<(), CodecError> {
        for group in jobs.chunks_mut(self.lanes) {
            for job in group.iter() {
                if job.out.len() as u64 > job.payload.len() as u64 * 8 {
                    return Err(CodecError::UnexpectedEof);
                }
            }
            let mut lanes: Vec<Lane<'_, '_>> = group
                .iter_mut()
                .map(|job| Lane::new(job.payload, &mut *job.out))
                .collect();
            kernel.decode_lanes(&mut lanes)?;
            debug_assert!(lanes.iter().all(|l| l.remaining() == 0));
        }
        Ok(())
    }

    /// Like [`decode_jobs`](Self::decode_jobs), but every job carries
    /// its own kernel: lanes with different code tables (adaptive
    /// table-delta chunks) step in the same lockstep group.  Same
    /// prechecks, same exact-equivalence contract per lane.
    pub fn decode_jobs_mixed(
        &self,
        jobs: &mut [MixedLaneJob<'_, '_, '_>],
    ) -> Result<(), CodecError> {
        for group in jobs.chunks_mut(self.lanes) {
            for job in group.iter() {
                if job.out.len() as u64 > job.payload.len() as u64 * 8 {
                    return Err(CodecError::UnexpectedEof);
                }
            }
            let kernels: Vec<&dyn DecodeKernel> =
                group.iter().map(|job| job.kernel).collect();
            let mut lanes: Vec<Lane<'_, '_>> = group
                .iter_mut()
                .map(|job| Lane::new(job.payload, &mut *job.out))
                .collect();
            decode_lanes_mixed(&kernels, &mut lanes)?;
            debug_assert!(lanes.iter().all(|l| l.remaining() == 0));
        }
        Ok(())
    }
}

impl Default for LaneDecoder {
    fn default() -> LaneDecoder {
        LaneDecoder::auto()
    }
}

/// The batched decode primitive.  See the module docs for the full
/// contract: decode **exactly `out.len()`** symbols, return the count,
/// leave the cursor just past the last code.
pub trait DecodeKernel {
    fn decode_batch(
        &self,
        cur: &mut BitCursor<'_>,
        out: &mut [u8],
    ) -> Result<usize, CodecError>;

    /// Decode every lane to completion (`lane.pos` reaches
    /// `lane.out.len()`), stepping the lanes in lockstep where the
    /// codec supports it.  Must agree with [`decode_batch`]
    /// symbol-for-symbol and consumed-bit-for-bit on every lane; on
    /// `Err` the lanes' outputs and cursors are unspecified.
    ///
    /// The default decodes lane-after-lane through the batched path —
    /// correct for every codec; table-driven codecs (QLC) override it
    /// with a genuinely interleaved loop.
    ///
    /// [`decode_batch`]: Self::decode_batch
    fn decode_lanes(
        &self,
        lanes: &mut [Lane<'_, '_>],
    ) -> Result<(), CodecError> {
        for lane in lanes.iter_mut() {
            let pos = lane.pos;
            let n = self.decode_batch(&mut lane.cur, &mut lane.out[pos..])?;
            lane.pos += n;
        }
        Ok(())
    }

    /// Upper bound on the bits one [`lane_step`](Self::lane_step)
    /// consumes, when the codec can resolve one whole code from a
    /// refilled staging word with no further refill or EOF checks.
    /// `None` (the default) opts the codec out of *mixed* lockstep
    /// groups — its lanes then decode through [`decode_batch`]
    /// lane-after-lane, which is always correct.
    ///
    /// [`decode_batch`]: Self::decode_batch
    fn lockstep_bits(&self) -> Option<u32> {
        None
    }

    /// Resolve exactly one code for `lane` (store the symbol, consume
    /// the bits).  Only called by the mixed-lane engine, on lanes with
    /// ≥ [`lockstep_bits`](Self::lockstep_bits) buffered bits and at
    /// least one symbol remaining.  Must agree with
    /// [`decode_batch`](Self::decode_batch) symbol-for-symbol and
    /// consumed-bit-for-bit.
    fn lane_step(&self, lane: &mut Lane<'_, '_>) -> Result<(), CodecError> {
        debug_assert!(
            self.lockstep_bits().is_some(),
            "lane_step called on a codec without lockstep support"
        );
        let pos = lane.pos;
        let n = self.decode_batch(&mut lane.cur, &mut lane.out[pos..pos + 1])?;
        lane.pos += n;
        Ok(())
    }
}

/// One decode job for the *mixed* lane engine: like [`LaneJob`] but
/// carrying its own kernel, so lanes in one lockstep group may decode
/// through different code tables (the adaptive QLF2 case: table-delta
/// chunks ride in the same group as fixed-table chunks).
pub struct MixedLaneJob<'d, 'o, 'k> {
    pub payload: &'d [u8],
    pub out: &'o mut [u8],
    /// The per-lane table pointer.
    pub kernel: &'k dyn DecodeKernel,
}

/// Step a group of lanes in lockstep where every lane carries its own
/// kernel.  Lanes whose kernel reports no
/// [`lockstep_bits`](DecodeKernel::lockstep_bits) (and lanes too close
/// to EOF for an unchecked burst) finish through their own
/// `decode_batch`; the rest run burst rounds sized by the minimum
/// buffered budget across the group, exactly like the homogeneous
/// lockstep loops.
fn decode_lanes_mixed(
    kernels: &[&dyn DecodeKernel],
    lanes: &mut [Lane<'_, '_>],
) -> Result<(), CodecError> {
    debug_assert_eq!(kernels.len(), lanes.len());
    loop {
        // Plan the burst: refill every unfinished lane, retire lanes
        // that cannot sustain unchecked steps, and size the rounds so
        // no in-burst refill or EOF check is needed.
        let mut rounds = usize::MAX;
        let mut unfinished = 0usize;
        for (lane, kernel) in lanes.iter_mut().zip(kernels.iter()) {
            let remaining = lane.remaining();
            if remaining == 0 {
                continue;
            }
            let Some(bits) = kernel.lockstep_bits() else {
                let pos = lane.pos;
                let n = kernel.decode_batch(&mut lane.cur, &mut lane.out[pos..])?;
                lane.pos += n;
                continue;
            };
            let avail = lane.cur.refill_buffered();
            if avail < bits {
                // Near EOF: the checked batch path drains the tail.
                let pos = lane.pos;
                let n = kernel.decode_batch(&mut lane.cur, &mut lane.out[pos..])?;
                lane.pos += n;
                continue;
            }
            unfinished += 1;
            rounds = rounds.min(((avail / bits) as usize).min(remaining));
        }
        if unfinished == 0 {
            return Ok(());
        }
        for _ in 0..rounds {
            for (lane, kernel) in lanes.iter_mut().zip(kernels.iter()) {
                // Retired and batch-finished lanes have remaining 0;
                // every other lane was sized for `rounds` full steps.
                if lane.remaining() == 0 {
                    continue;
                }
                kernel.lane_step(lane)?;
            }
        }
    }
}

/// One independent symbol stream inside a lockstep *encode* lane
/// group: the chunk's symbols, the read mark, and the sink its codes
/// land in.  Each lane owns its sink, so lane-major interleaving
/// cannot perturb any lane's output bytes.
pub struct EncodeLane<'s> {
    pub symbols: &'s [u8],
    /// Next symbol index (lanes of unequal size finish at different
    /// rounds).
    pub pos: usize,
    pub sink: BitSink,
}

impl<'s> EncodeLane<'s> {
    pub fn new(symbols: &'s [u8]) -> EncodeLane<'s> {
        // A QLC/Huffman code averages ≤ 8 bits on any input the codec
        // would be chosen for; one byte per symbol avoids regrowth.
        EncodeLane { symbols, pos: 0, sink: BitSink::with_capacity(symbols.len()) }
    }

    /// Symbols this lane still has to encode.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.symbols.len() - self.pos
    }
}

/// One encode job for the lane engine: an independent chunk of
/// symbols and the byte vector its (byte-aligned) payload is appended
/// to.
pub struct EncodeJob<'s, 'o> {
    pub symbols: &'s [u8],
    pub out: &'o mut Vec<u8>,
}

/// The lane-interleaved encode engine: [`LaneDecoder`]'s mirror.
/// Tiles independent chunk jobs into groups of up to [`MAX_LANES`]
/// lanes, steps each group through one codec's
/// [`EncodeKernel::encode_lanes`], then drains each lane's sink into
/// its job's output in job order.  Payload bytes per job are
/// **exactly** the bytes `encode_batch` (and therefore
/// `encode_scalar`) would produce for that job alone.
#[derive(Clone, Copy, Debug)]
pub struct LaneEncoder {
    lanes: usize,
}

impl LaneEncoder {
    /// Runtime-selected lane width, matching [`LaneDecoder::auto`]:
    /// 8 on vector-capable cores (AVX2/NEON), 4 otherwise.  Encode
    /// has no vector peek yet — the width is about independent
    /// dependency chains per out-of-order window, which the same
    /// detection proxies.
    pub fn auto() -> LaneEncoder {
        LaneEncoder { lanes: if lanes_vector_available() { 8 } else { 4 } }
    }

    /// Explicit lane width; 4 and 8 are supported.
    pub fn with_lanes(lanes: usize) -> Result<LaneEncoder, String> {
        if lanes == 4 || lanes == 8 {
            Ok(LaneEncoder { lanes })
        } else {
            Err(format!("lane width {lanes} unsupported (expected 4 or 8)"))
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Encode every job — `self.lanes` of them in lockstep at a time —
    /// through `kernel`, appending each job's payload to its `out`.
    pub fn encode_jobs<K: EncodeKernel + ?Sized>(
        &self,
        kernel: &K,
        jobs: &mut [EncodeJob<'_, '_>],
    ) {
        for group in jobs.chunks_mut(self.lanes) {
            let mut lanes: Vec<EncodeLane<'_>> =
                group.iter().map(|job| EncodeLane::new(job.symbols)).collect();
            kernel.encode_lanes(&mut lanes);
            for (lane, job) in lanes.iter_mut().zip(group.iter_mut()) {
                debug_assert_eq!(lane.remaining(), 0);
                lane.sink.drain_into(job.out);
            }
        }
    }
}

impl Default for LaneEncoder {
    fn default() -> LaneEncoder {
        LaneEncoder::auto()
    }
}

/// The batched encode primitive.  See the module docs:
/// `encode_batch` appends the codes for every symbol to `sink` and
/// must be bit-for-bit identical to
/// [`Codec::encode_scalar`](super::Codec::encode_scalar) on the same
/// symbols.  Encoding every byte value is total for every registered
/// codec, so the encode side is infallible.
pub trait EncodeKernel {
    fn encode_batch(&self, symbols: &[u8], sink: &mut BitSink);

    /// Encode every lane to completion (`lane.pos` reaches
    /// `lane.symbols.len()`), stepping the lanes in lockstep where the
    /// codec supports it.  Each lane's sink must end up bit-for-bit
    /// identical to an [`encode_batch`] of that lane's symbols alone.
    ///
    /// The default encodes lane-after-lane through the batched path —
    /// correct for every codec; table-driven codecs (QLC) override it
    /// with a genuinely interleaved lane-major loop.
    ///
    /// [`encode_batch`]: Self::encode_batch
    fn encode_lanes(&self, lanes: &mut [EncodeLane<'_>]) {
        for lane in lanes.iter_mut() {
            let pos = lane.pos;
            self.encode_batch(&lane.symbols[pos..], &mut lane.sink);
            lane.pos = lane.symbols.len();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::{BitReader, BitWriter};
    use crate::codecs::{Codec, CodecRegistry};
    use crate::stats::Histogram;
    use crate::util::prop;

    #[test]
    fn cursor_matches_bitreader_on_random_fields() {
        prop::check("cursor==reader", Default::default(), |rng, size| {
            let nfields = rng.below(size as u64 + 1) as usize;
            let fields: Vec<(u64, u32)> = (0..nfields)
                .map(|_| {
                    let n = 1 + rng.below(32) as u32;
                    (rng.next_u64() & ((1u64 << n) - 1), n)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, n) in &fields {
                w.write_bits(v, n);
            }
            let buf = w.finish();
            let mut cur = BitCursor::new(&buf);
            let mut rdr = BitReader::new(&buf);
            for (i, &(v, n)) in fields.iter().enumerate() {
                let a = cur.read_bits(n).map_err(|e| e.to_string())? as u64;
                let b = rdr.read_bits(n).map_err(|e| e.to_string())? as u64;
                if a != v || b != v {
                    return Err(format!("field {i}: cursor {a} reader {b} want {v}"));
                }
                if cur.bits_consumed() != rdr.bits_consumed() {
                    return Err("consumed counts diverged".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cursor_unary_matches_bitreader() {
        for zeros in [0u32, 1, 7, 31, 32, 33, 63, 64, 65, 130] {
            let mut w = BitWriter::new();
            w.write_zeros(zeros);
            w.write_bit(true);
            w.write_bits(0b101, 3);
            let buf = w.finish();
            let mut cur = BitCursor::new(&buf);
            assert_eq!(cur.read_unary().unwrap(), zeros, "zeros={zeros}");
            assert_eq!(cur.read_bits(3).unwrap(), 0b101);
        }
        // All-zero stream: no terminating 1 → EOF.
        let mut cur = BitCursor::new(&[0u8; 4]);
        assert_eq!(cur.read_unary(), Err(CodecError::UnexpectedEof));
    }

    #[test]
    fn cursor_eof_detection() {
        let mut cur = BitCursor::new(&[0xFF]);
        assert_eq!(cur.read_bits(8).unwrap(), 0xFF);
        assert_eq!(cur.read_bits(1), Err(CodecError::UnexpectedEof));
        assert_eq!(cur.remaining_bits(), 0);
    }

    #[test]
    fn word_is_zero_padded_past_eof() {
        let mut cur = BitCursor::new(&[0xFF]);
        cur.refill();
        assert_eq!(cur.buffered(), 8);
        assert_eq!(cur.word(), 0xFFu64 << 56);
    }

    /// The satellite equivalence property: `decode_batch` ≡ the scalar
    /// reference path symbol-for-symbol, for every registered codec,
    /// on random payloads — including the consumed-bit count, so a
    /// kernel cannot "win" by skipping validation work.
    #[test]
    fn prop_batch_equals_scalar_all_registered_codecs() {
        let reg = CodecRegistry::global();
        prop::check("batch==scalar", prop::Config {
            cases: 64, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size);
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();
            let encoded = codec.encode_to_vec(&symbols);

            let mut batched = vec![0u8; symbols.len()];
            let mut cur = BitCursor::new(&encoded);
            codec
                .decode_into(&mut cur, &mut batched)
                .map_err(|e| format!("{name} batched: {e}"))?;

            let mut scalar = vec![0u8; symbols.len()];
            let mut rdr = BitReader::new(&encoded);
            codec
                .decode_scalar_into(&mut rdr, &mut scalar)
                .map_err(|e| format!("{name} scalar: {e}"))?;

            if batched != symbols {
                return Err(format!("{name}: batched decode mismatch"));
            }
            if scalar != symbols {
                return Err(format!("{name}: scalar decode mismatch"));
            }
            if cur.bits_consumed() != rdr.bits_consumed() {
                return Err(format!(
                    "{name}: batched consumed {} bits, scalar {}",
                    cur.bits_consumed(),
                    rdr.bits_consumed()
                ));
            }
            Ok(())
        });
    }

    /// The lane satellite property: lane decode ≡ batched ≡ scalar
    /// symbol-for-symbol for every registered codec, at both supported
    /// lane widths, over independent chunks of every ragged shape.
    #[test]
    fn prop_lanes_equal_batched_equal_scalar_all_registered_codecs() {
        let reg = CodecRegistry::global();
        prop::check("lanes==batched==scalar", prop::Config {
            cases: 64, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size);
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();
            // Independent chunks (the lane unit), ragged tail included.
            let chunk = 1 + rng.below(size as u64) as usize;
            let payloads: Vec<Vec<u8>> = symbols
                .chunks(chunk)
                .map(|c| codec.encode_to_vec(c))
                .collect();

            let mut batched = vec![0u8; symbols.len()];
            for (p, dst) in payloads.iter().zip(batched.chunks_mut(chunk)) {
                let mut cur = BitCursor::new(p);
                codec
                    .decode_into(&mut cur, dst)
                    .map_err(|e| format!("{name} batched: {e}"))?;
            }
            if batched != symbols {
                return Err(format!("{name}: batched chunk decode mismatch"));
            }

            let mut scalar = vec![0u8; symbols.len()];
            for (p, dst) in payloads.iter().zip(scalar.chunks_mut(chunk)) {
                let mut rdr = BitReader::new(p);
                codec
                    .decode_scalar_into(&mut rdr, dst)
                    .map_err(|e| format!("{name} scalar: {e}"))?;
            }
            if scalar != symbols {
                return Err(format!("{name}: scalar chunk decode mismatch"));
            }

            for width in [4usize, 8] {
                let engine = LaneDecoder::with_lanes(width)?;
                let mut laned = vec![0u8; symbols.len()];
                let mut jobs: Vec<LaneJob> = payloads
                    .iter()
                    .zip(laned.chunks_mut(chunk))
                    .map(|(p, o)| LaneJob { payload: p, out: o })
                    .collect();
                engine
                    .decode_jobs(codec, &mut jobs)
                    .map_err(|e| format!("{name} lanes x{width}: {e}"))?;
                if laned != symbols {
                    return Err(format!(
                        "{name}: lane decode mismatch at width {width}"
                    ));
                }
            }
            Ok(())
        });
    }

    /// Lane cursors must consume exactly the bits the batched path
    /// consumes — a lockstep loop cannot "win" by skipping validation.
    #[test]
    fn lane_cursors_consume_exactly_like_batched() {
        let reg = CodecRegistry::global();
        // Unequal chunk sizes force lanes to drop out at different
        // rounds and exercise the tail path; a tenth-sized variant
        // keeps the interpreted Miri run tractable.
        let sizes: [usize; 5] = if prop::reduced() {
            [900, 1, 1_200, 7, 1_892]
        } else {
            [9_000, 1, 12_000, 7, 18_992]
        };
        let total: u32 = sizes.iter().sum::<usize>() as u32;
        let symbols: Vec<u8> =
            (0..total).map(|i| (i * 31 % 251) as u8).collect();
        let hist = Histogram::from_symbols(&symbols);
        for name in ["qlc", "huffman", "elias-gamma", "eg2", "raw"] {
            let handle = reg.resolve(name, &hist).unwrap();
            let codec = handle.codec();
            assert_eq!(sizes.iter().sum::<usize>(), symbols.len());
            let mut payloads = Vec::new();
            let mut start = 0usize;
            for &s in &sizes {
                payloads.push(codec.encode_to_vec(&symbols[start..start + s]));
                start += s;
            }
            let mut outs: Vec<Vec<u8>> =
                sizes.iter().map(|&s| vec![0u8; s]).collect();
            let mut lanes: Vec<Lane> = payloads
                .iter()
                .zip(outs.iter_mut())
                .map(|(p, o)| Lane::new(p, o))
                .collect();
            codec.decode_lanes(&mut lanes).unwrap();
            let mut start = 0usize;
            for ((lane, p), &s) in lanes.iter().zip(&payloads).zip(&sizes) {
                assert_eq!(lane.remaining(), 0, "{name}");
                assert_eq!(&lane.out[..], &symbols[start..start + s], "{name}");
                let mut cur = BitCursor::new(p);
                let mut reference = vec![0u8; s];
                codec.decode_into(&mut cur, &mut reference).unwrap();
                assert_eq!(
                    lane.cur.bits_consumed(),
                    cur.bits_consumed(),
                    "{name}: lane consumed differently from batched"
                );
                start += s;
            }
        }
    }

    /// Truncated lane inputs must agree with the batched path on
    /// Ok-ness (and on bytes when both succeed).
    #[test]
    fn prop_lanes_and_batched_agree_on_truncation() {
        let reg = CodecRegistry::global();
        prop::check("lanes==batched truncated", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size.max(8));
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();
            let encoded = codec.encode_to_vec(&symbols);
            let keep = rng.below(encoded.len() as u64 + 1) as usize;
            let cut = &encoded[..keep];

            let mut batched = vec![0u8; symbols.len()];
            let mut cur = BitCursor::new(cut);
            let b = codec.decode_into(&mut cur, &mut batched);

            for width in [4usize, 8] {
                let engine = LaneDecoder::with_lanes(width)?;
                let mut laned = vec![0u8; symbols.len()];
                let mut jobs =
                    [LaneJob { payload: cut, out: &mut laned }];
                let l = engine.decode_jobs(codec, &mut jobs);
                if b.is_ok() != l.is_ok() {
                    return Err(format!(
                        "{name}: truncated at {keep}: batched {b:?}, \
                         lanes x{width} {l:?}"
                    ));
                }
                if b.is_ok() && laned != batched {
                    return Err(format!(
                        "{name}: truncated lane decode diverged"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lane_decoder_widths() {
        assert!(LaneDecoder::with_lanes(4).is_ok());
        assert!(LaneDecoder::with_lanes(8).is_ok());
        assert!(LaneDecoder::with_lanes(0).is_err());
        assert!(LaneDecoder::with_lanes(3).is_err());
        assert!(LaneDecoder::with_lanes(16).is_err());
        let auto = LaneDecoder::auto().lanes();
        assert!(auto == 4 || auto == 8);
        if lanes_vector_available() {
            assert_eq!(auto, 8);
        }
    }

    #[test]
    fn lane_jobs_reject_impossible_counts() {
        let reg = CodecRegistry::global();
        let hist = Histogram::from_symbols(&[0]);
        let handle = reg.resolve("raw", &hist).unwrap();
        let mut out = vec![0u8; 17];
        let mut jobs = [LaneJob { payload: &[0xAB, 0xCD], out: &mut out }];
        assert_eq!(
            LaneDecoder::auto().decode_jobs(handle.codec(), &mut jobs),
            Err(CodecError::UnexpectedEof)
        );
        // Empty job lists and empty jobs are no-ops.
        let mut none: [LaneJob; 0] = [];
        LaneDecoder::auto()
            .decode_jobs(handle.codec(), &mut none)
            .unwrap();
        let mut empty = [LaneJob { payload: &[], out: &mut [] }];
        LaneDecoder::auto()
            .decode_jobs(handle.codec(), &mut empty)
            .unwrap();
    }

    #[cfg(all(target_arch = "x86_64", not(miri)))]
    #[test]
    fn avx2_peek_matches_scalar_shift() {
        if !lanes_avx2_available() {
            return;
        }
        let words = [
            0xFFFF_FFFF_FFFF_FFFFu64,
            0x8000_0000_0000_0000,
            0x0123_4567_89AB_CDEF,
            0,
            0x7FFF_FFFF_FFFF_FFFF,
            0xDEAD_BEEF_CAFE_F00D,
            1,
            0xA5A5_A5A5_A5A5_A5A5,
        ];
        for bits in [1u32, 3, 5, 8, 16, 32] {
            let got = unsafe { peek_top_bits_x8(&words, bits) };
            for (g, w) in got.iter().zip(words.iter()) {
                assert_eq!(*g as u64, w >> (64 - bits), "bits={bits}");
            }
        }
    }

    #[cfg(all(target_arch = "aarch64", not(miri)))]
    #[test]
    fn neon_peek_matches_scalar_shift() {
        if !lanes_neon_available() {
            return;
        }
        let words = [
            0xFFFF_FFFF_FFFF_FFFFu64,
            0x8000_0000_0000_0000,
            0x0123_4567_89AB_CDEF,
            0,
            0x7FFF_FFFF_FFFF_FFFF,
            0xDEAD_BEEF_CAFE_F00D,
            1,
            0xA5A5_A5A5_A5A5_A5A5,
        ];
        for bits in [1u32, 3, 5, 8, 16, 32] {
            let got = unsafe { peek_top_bits_x8_neon(&words, bits) };
            for (g, w) in got.iter().zip(words.iter()) {
                assert_eq!(*g as u64, w >> (64 - bits), "bits={bits}");
            }
        }
    }

    /// Whatever vector peek this CPU has must agree with the scalar
    /// top-bits shift on arbitrary words and every peek width the
    /// codecs use.
    #[test]
    fn prop_vector_peek_matches_scalar_shift() {
        if !lanes_vector_available() {
            return;
        }
        prop::check(
            "vector peek == scalar shift",
            prop::Config { cases: 96, ..Default::default() },
            |rng, _size| {
                let mut words = [0u64; 8];
                for w in &mut words {
                    let mut b = [0u8; 8];
                    rng.fill_bytes(&mut b);
                    *w = u64::from_le_bytes(b);
                }
                let bits = 1 + rng.below(32) as u32;
                #[cfg(all(target_arch = "x86_64", not(miri)))]
                // SAFETY: lanes_vector_available() on x86_64 implies
                // AVX2 was runtime-detected.
                let got = unsafe { peek_top_bits_x8(&words, bits) };
                #[cfg(all(target_arch = "aarch64", not(miri)))]
                // SAFETY: lanes_vector_available() on aarch64 implies
                // NEON was runtime-detected.
                let got = unsafe { peek_top_bits_x8_neon(&words, bits) };
                #[cfg(any(
                    not(any(
                        target_arch = "x86_64",
                        target_arch = "aarch64"
                    )),
                    miri
                ))]
                let got: [u32; 8] = {
                    let mut g = [0u32; 8];
                    for (o, w) in g.iter_mut().zip(words.iter()) {
                        *o = (w >> (64 - bits)) as u32;
                    }
                    g
                };
                for (i, (g, w)) in
                    got.iter().zip(words.iter()).enumerate()
                {
                    let want = (w >> (64 - bits)) as u32;
                    if *g != want {
                        return Err(format!(
                            "lane {i}: bits={bits} word={w:#018x}: \
                             vector {g:#x} != scalar {want:#x}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Truncations must error on both paths (never panic, never
    /// diverge into one Ok / one Err on the *same* cut only when the
    /// cut leaves a decodable prefix — then both must agree).
    #[test]
    fn prop_batch_and_scalar_agree_on_truncation() {
        let reg = CodecRegistry::global();
        prop::check("batch==scalar truncated", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size.max(8));
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();
            let encoded = codec.encode_to_vec(&symbols);
            let keep = rng.below(encoded.len() as u64 + 1) as usize;
            let cut = &encoded[..keep];

            let mut batched = vec![0u8; symbols.len()];
            let mut cur = BitCursor::new(cut);
            let b = codec.decode_into(&mut cur, &mut batched);

            let mut scalar = vec![0u8; symbols.len()];
            let mut rdr = BitReader::new(cut);
            let s = codec.decode_scalar_into(&mut rdr, &mut scalar);

            if b.is_ok() != s.is_ok() {
                return Err(format!(
                    "{name}: truncated at {keep}: batched {b:?}, scalar {s:?}"
                ));
            }
            if b.is_ok() && batched != scalar {
                return Err(format!("{name}: truncated decode diverged"));
            }
            Ok(())
        });
    }

    #[test]
    fn sink_known_bytes() {
        let mut s = BitSink::new();
        s.push(0b1, 1);
        s.push(0b0101, 4);
        assert_eq!(s.bit_len(), 5);
        // Tail is zero-padded to a byte boundary, like BitWriter.
        assert_eq!(s.finish(), vec![0b1010_1000]);

        // An exact 64-bit fill spills the whole word with no tail.
        let mut s = BitSink::new();
        for _ in 0..8 {
            s.push(0xAB, 8);
        }
        assert_eq!(s.bit_len(), 64);
        assert_eq!(s.finish(), vec![0xAB; 8]);

        // A push that straddles the word boundary splits cleanly.
        let mut s = BitSink::new();
        s.push(0, 57);
        s.push((1u64 << 14) - 1, 14); // 7 bits complete word 0, 7 seed word 1
        assert_eq!(s.finish(), vec![0, 0, 0, 0, 0, 0, 0, 1, 0xFE]);
    }

    /// The write-side mirror of `cursor_matches_bitreader`: for any
    /// field sequence, `BitSink` must produce exactly `BitWriter`'s
    /// bytes (the exact-output contract every `encode_batch` relies
    /// on).
    #[test]
    fn sink_matches_bitwriter_on_random_fields() {
        prop::check("sink==writer", Default::default(), |rng, size| {
            let nfields = rng.below(size as u64 + 1) as usize;
            let mut w = BitWriter::new();
            let mut s = BitSink::new();
            for _ in 0..nfields {
                let n = 1 + rng.below(57) as u32;
                let v = rng.next_u64() & ((1u64 << n) - 1);
                w.write_bits(v, n);
                s.push(v, n);
            }
            if s.bit_len() != w.bit_len() {
                return Err(format!(
                    "sink counted {} bits, writer {}",
                    s.bit_len(),
                    w.bit_len()
                ));
            }
            if s.finish() != w.finish() {
                return Err("sink bytes diverge from writer".into());
            }
            Ok(())
        });
    }

    /// Streamed per-chunk `drain_into` must equal a fresh sink's
    /// `finish` per chunk — the reuse pattern every session encoder
    /// depends on.
    #[test]
    fn sink_drain_into_matches_finish_per_chunk() {
        let mut streamed = Vec::new();
        let mut reference = Vec::new();
        let mut sink = BitSink::new();
        for chunk in 0u64..5 {
            let mut one = BitSink::new();
            for i in 0..37u64 {
                let n = 1 + ((chunk * 37 + i) % 57) as u32;
                let v = (chunk * 1_000_003 + i)
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    & ((1u64 << n) - 1);
                sink.push(v, n);
                one.push(v, n);
            }
            sink.drain_into(&mut streamed);
            reference.extend_from_slice(&one.finish());
        }
        assert_eq!(streamed, reference);
        assert_eq!(sink.bit_len(), 0);
    }

    /// The encode satellite property: `encode_batch` ≡ `encode_scalar`
    /// bit-for-bit (bytes *and* bit counts) for every registered
    /// codec, and the batched bytes roundtrip through the batched
    /// decoder.
    #[test]
    fn prop_encode_batch_equals_scalar_all_registered_codecs() {
        let reg = CodecRegistry::global();
        prop::check("encode batch==scalar", prop::Config {
            cases: 64, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size);
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();

            let mut w = BitWriter::new();
            codec.encode_scalar(&symbols, &mut w);
            let scalar_bits = w.bit_len();
            let scalar = w.finish();

            let mut sink = BitSink::new();
            codec.encode_batch(&symbols, &mut sink);
            if sink.bit_len() != scalar_bits {
                return Err(format!(
                    "{name}: batched pushed {} bits, scalar wrote {}",
                    sink.bit_len(),
                    scalar_bits
                ));
            }
            let batched = sink.finish();
            if batched != scalar {
                return Err(format!(
                    "{name}: batched encode bytes diverge from scalar"
                ));
            }

            let mut out = vec![0u8; symbols.len()];
            let mut cur = BitCursor::new(&batched);
            codec
                .decode_into(&mut cur, &mut out)
                .map_err(|e| format!("{name}: {e}"))?;
            if out != symbols {
                return Err(format!(
                    "{name}: batched-encode roundtrip mismatch"
                ));
            }
            Ok(())
        });
    }

    /// The lane-encode satellite property: the lane engine's per-job
    /// payloads ≡ scalar encode of each chunk alone, at both widths,
    /// over ragged chunk splits — and the payloads roundtrip through
    /// the lane *decoder*.
    #[test]
    fn prop_lane_encode_equals_scalar_all_registered_codecs() {
        let reg = CodecRegistry::global();
        prop::check("lane encode==scalar", prop::Config {
            cases: 64, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size);
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();
            let chunk = 1 + rng.below(size as u64) as usize;
            let scalar_payloads: Vec<Vec<u8>> = symbols
                .chunks(chunk)
                .map(|c| {
                    let mut w = BitWriter::new();
                    codec.encode_scalar(c, &mut w);
                    w.finish()
                })
                .collect();

            for width in [4usize, 8] {
                let engine = LaneEncoder::with_lanes(width)?;
                let mut outs: Vec<Vec<u8>> =
                    vec![Vec::new(); scalar_payloads.len()];
                let mut jobs: Vec<EncodeJob<'_, '_>> = symbols
                    .chunks(chunk)
                    .zip(outs.iter_mut())
                    .map(|(c, o)| EncodeJob { symbols: c, out: o })
                    .collect();
                engine.encode_jobs(codec, &mut jobs);
                if outs != scalar_payloads {
                    return Err(format!(
                        "{name}: lane encode diverged at width {width}"
                    ));
                }
            }

            let mut decoded = vec![0u8; symbols.len()];
            let mut jobs: Vec<LaneJob<'_, '_>> = scalar_payloads
                .iter()
                .zip(decoded.chunks_mut(chunk))
                .map(|(p, o)| LaneJob { payload: p, out: o })
                .collect();
            LaneDecoder::auto()
                .decode_jobs(codec, &mut jobs)
                .map_err(|e| format!("{name}: {e}"))?;
            if decoded != symbols {
                return Err(format!("{name}: lane roundtrip mismatch"));
            }
            Ok(())
        });
    }

    #[test]
    fn lane_encoder_widths() {
        assert!(LaneEncoder::with_lanes(4).is_ok());
        assert!(LaneEncoder::with_lanes(8).is_ok());
        assert!(LaneEncoder::with_lanes(0).is_err());
        assert!(LaneEncoder::with_lanes(3).is_err());
        assert!(LaneEncoder::with_lanes(16).is_err());
        let auto = LaneEncoder::auto().lanes();
        assert!(auto == 4 || auto == 8);
        assert_eq!(auto, LaneDecoder::auto().lanes());
    }

    /// Mixed groups: lanes with *different* code tables (and one
    /// no-lockstep codec) in the same group must each decode exactly
    /// their own stream.
    #[test]
    fn mixed_lane_groups_decode_heterogeneous_tables() {
        let reg = CodecRegistry::global();
        let a_sym: Vec<u8> = (0..4001u32).map(|i| (i % 7) as u8).collect();
        let b_sym: Vec<u8> =
            (0..5003u32).map(|i| (255 - (i % 11)) as u8).collect();
        let ha = reg.resolve("qlc", &Histogram::from_symbols(&a_sym)).unwrap();
        let hb = reg.resolve("qlc", &Histogram::from_symbols(&b_sym)).unwrap();
        let hr = reg.resolve("raw", &Histogram::from_symbols(&a_sym)).unwrap();
        let pa = ha.codec().encode_to_vec(&a_sym);
        let pb = hb.codec().encode_to_vec(&b_sym);
        let pr = hr.codec().encode_to_vec(&a_sym);
        let mut oa = vec![0u8; a_sym.len()];
        let mut ob = vec![0u8; b_sym.len()];
        let mut oc = vec![0u8; a_sym.len()];
        let mut jobs = [
            MixedLaneJob { payload: &pa, out: &mut oa, kernel: ha.codec() },
            MixedLaneJob { payload: &pb, out: &mut ob, kernel: hb.codec() },
            MixedLaneJob { payload: &pr, out: &mut oc, kernel: hr.codec() },
        ];
        LaneDecoder::auto().decode_jobs_mixed(&mut jobs).unwrap();
        assert_eq!(oa, a_sym);
        assert_eq!(ob, b_sym);
        assert_eq!(oc, a_sym);
    }
}
