//! The batched decode kernel: a 64-bit buffered [`BitCursor`]
//! (refill once, peek many), the [`DecodeKernel`] trait every codec
//! implements, and the lane-interleaved engine ([`LaneDecoder`]) that
//! steps several independent chunk cursors in lockstep.
//!
//! The paper's whole argument is that QLC's 3-prefix-bit + LUT
//! structure decodes *fast*.  The scalar path
//! ([`Codec::decode_scalar_into`](super::Codec::decode_scalar_into))
//! resolves one symbol per call, paying a refill check, an EOF check
//! and a table walk each time.  The kernel inverts that: one refill
//! tops the staging word up to ≥ 57 valid bits, and the codec then
//! resolves as many whole codes as the word holds with *no* further
//! checks — up to 9 six-bit QLC codes or 8 Huffman root-table hits per
//! refill.  Codes that embed their own length (Elias, Exp-Golomb)
//! batch through `u64::leading_zeros` on the same word: the prefix
//! length, the payload and the consume all come out of a single
//! count-leading-zeros.
//!
//! Everything above `codecs/` decodes through this kernel:
//! [`DecoderSession`](super::DecoderSession) builds a cursor per
//! chunk, the QLF2 frame reader and the transport/collective chunk
//! pipeline decode through sessions, and the registry's handles vend
//! sessions.  The scalar path survives as a reference implementation
//! (`decode_scalar_into`) used by the equivalence proptests, the
//! hardware model and the batched-vs-scalar bench section.
//!
//! # The `DecodeKernel` contract
//!
//! `decode_batch(cur, out)` decodes **exactly `out.len()` symbols**
//! from `cur` and returns that count.  On error (`UnexpectedEof`,
//! `InvalidCode`) the contents of `out` and the cursor position are
//! unspecified.  The cursor is *not* required to be byte-aligned on
//! entry, and it is left exactly past the last consumed code on
//! success — callers (the adaptive codec, multi-chunk QLF1 payloads)
//! may keep decoding from the same cursor.
//!
//! # Lanes
//!
//! One cursor's decode is a serial dependency chain: every symbol's
//! table lookup waits on the previous symbol's shift-and-consume.
//! QLF2 chunks are *independent* streams, so
//! [`DecodeKernel::decode_lanes`] steps N of them in lockstep — each
//! round resolves
//! one code from every lane, and because the lanes share no state the
//! lookups of different chunks overlap in the pipeline (software ILP;
//! QLC additionally has an AVX2 vector-peek path behind runtime
//! feature detection).  [`LaneDecoder`] is the scheduling engine:
//! runtime-selected 4- or 8-wide, it tiles an arbitrary job list into
//! lane groups and must decode **exactly** what the batched path
//! decodes, symbol for symbol and consumed-bit for consumed-bit (the
//! equivalence proptests below hold every registered codec to that).

use super::CodecError;

/// A 64-bit buffered bit cursor over a byte slice, MSB-first (the
/// first bit of byte 0 is bit 63 of the staging word).  The batch
/// decode substrate: `refill` once, then `word`/`consume` many times
/// with no bounds checks until the buffered budget runs out.
#[derive(Clone, Debug)]
pub struct BitCursor<'a> {
    data: &'a [u8],
    /// Next byte to load into the staging word.
    byte_pos: usize,
    /// Staging word: next bit to deliver is the MSB.  Bits below the
    /// valid window are always zero (loads mask them), so indexing a
    /// LUT with more bits than are buffered hits zero-padded slots.
    word: u64,
    /// Valid bits in `word`.
    avail: u32,
    /// Total bits consumed.
    consumed: u64,
}

impl<'a> BitCursor<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        BitCursor { data, byte_pos: 0, word: 0, avail: 0, consumed: 0 }
    }

    /// Refill the staging word to ≥ 57 valid bits (while input
    /// remains).  Fast path: one unaligned 8-byte load masked to the
    /// bytes that fit.
    #[inline]
    pub fn refill(&mut self) {
        if self.avail > 56 {
            return;
        }
        let rem = self.data.len() - self.byte_pos;
        if rem >= 8 {
            // lint: infallible(rem >= 8 guarantees an 8-byte slice)
            let w = u64::from_be_bytes(
                self.data[self.byte_pos..self.byte_pos + 8]
                    .try_into()
                    .unwrap(),
            );
            let take_bytes = ((64 - self.avail) / 8) as usize; // 1..=8
            let keep = w & (!0u64).wrapping_shl(64 - take_bytes as u32 * 8);
            self.word |= keep >> self.avail;
            self.byte_pos += take_bytes;
            self.avail += take_bytes as u32 * 8;
        } else {
            while self.avail <= 56 && self.byte_pos < self.data.len() {
                let b = self.data[self.byte_pos] as u64;
                self.byte_pos += 1;
                self.word |= b << (56 - self.avail);
                self.avail += 8;
            }
        }
    }

    /// Refill, then report how many valid bits are buffered (≤ 64).
    /// Batch loops size their checked-once inner iteration from this.
    #[inline]
    pub fn refill_buffered(&mut self) -> u32 {
        self.refill();
        self.avail
    }

    /// Valid bits currently buffered, without refilling.
    #[inline]
    pub fn buffered(&self) -> u32 {
        self.avail
    }

    /// The raw staging word; its top [`buffered`](Self::buffered) bits
    /// are valid, the rest are zero.
    #[inline]
    pub fn word(&self) -> u64 {
        self.word
    }

    /// Consume `n ≤ buffered()` bits previously examined via
    /// [`word`](Self::word).
    #[inline]
    pub fn consume(&mut self, n: u32) {
        debug_assert!(n <= self.avail);
        // `n` can be a full 64 bits (e.g. eight raw symbols at once);
        // `<<` alone would overflow the shift.
        self.word = if n >= 64 { 0 } else { self.word << n };
        self.avail -= n;
        self.consumed += n as u64;
    }

    /// Peek up to 32 bits without consuming (zero-padded past EOF).
    #[inline]
    pub fn peek(&mut self, n: u32) -> u32 {
        debug_assert!(n <= 32);
        self.refill();
        if n == 0 {
            return 0;
        }
        (self.word >> (64 - n)) as u32
    }

    /// Read `n` ≤ 32 bits MSB-first, checking for EOF.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u32, CodecError> {
        if self.remaining_bits() < n as u64 {
            return Err(CodecError::UnexpectedEof);
        }
        let v = self.peek(n);
        // peek refilled, so avail ≥ n is guaranteed by the bound above.
        self.consume(n);
        Ok(v)
    }

    /// Count and consume leading zero bits up to the next 1 bit, then
    /// consume the 1 bit; returns the zero count.  One
    /// `u64::leading_zeros` resolves runs of up to 64 — the slow-path
    /// complement of the kernels' inline LZC fast paths.
    pub fn read_unary(&mut self) -> Result<u32, CodecError> {
        let mut zeros = 0u32;
        loop {
            self.refill();
            if self.avail == 0 {
                return Err(CodecError::UnexpectedEof);
            }
            // Bits below `avail` are zero, so a 1 found by the LZC is
            // always within the valid window iff lz < avail.
            let lz = self.word.leading_zeros().min(self.avail);
            if lz < self.avail {
                zeros += lz;
                self.consume(lz + 1);
                return Ok(zeros);
            }
            zeros += lz;
            self.consume(lz);
        }
    }

    pub fn bits_consumed(&self) -> u64 {
        self.consumed
    }

    pub fn remaining_bits(&self) -> u64 {
        (self.data.len() as u64) * 8 - self.consumed
    }
}

/// Maximum number of lanes a lockstep group steps together.
pub const MAX_LANES: usize = 8;

/// One independent compressed stream inside a lockstep lane group: a
/// cursor over its payload plus the destination slice and fill mark.
pub struct Lane<'d, 'o> {
    pub cur: BitCursor<'d>,
    pub out: &'o mut [u8],
    /// Next output index (lanes of unequal size finish at different
    /// rounds).
    pub pos: usize,
}

impl<'d, 'o> Lane<'d, 'o> {
    pub fn new(payload: &'d [u8], out: &'o mut [u8]) -> Lane<'d, 'o> {
        Lane { cur: BitCursor::new(payload), out, pos: 0 }
    }

    /// Symbols this lane still has to decode.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.out.len() - self.pos
    }
}

/// One decode job for the lane engine: an independent byte-aligned
/// chunk payload and the slice its symbols land in (exactly
/// `out.len()` symbols are decoded).
pub struct LaneJob<'d, 'o> {
    pub payload: &'d [u8],
    pub out: &'o mut [u8],
}

/// Whether the AVX2 vector-peek lane path is available on this CPU
/// (cached runtime detection; always `false` off x86_64, and under
/// Miri, which interprets no vector intrinsics).
#[inline]
pub fn lanes_avx2_available() -> bool {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        use std::sync::atomic::{AtomicU8, Ordering};
        static CACHE: AtomicU8 = AtomicU8::new(0); // 0 unknown, 1 no, 2 yes
        match CACHE.load(Ordering::Relaxed) {
            2 => true,
            1 => false,
            _ => {
                let yes = is_x86_feature_detected!("avx2");
                CACHE.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
                yes
            }
        }
    }
    #[cfg(any(not(target_arch = "x86_64"), miri))]
    {
        false
    }
}

/// Vector peek for a full 8-lane group: the top `bits` of eight
/// staging words extracted with one AVX2 shift per 4-word half.
///
/// # Safety
///
/// Requires AVX2; callers must have checked
/// [`lanes_avx2_available`] first.
#[cfg(all(target_arch = "x86_64", not(miri)))]
#[target_feature(enable = "avx2")]
pub unsafe fn peek_top_bits_x8(words: &[u64; 8], bits: u32) -> [u32; 8] {
    use std::arch::x86_64::{
        __m256i, _mm256_loadu_si256, _mm256_srl_epi64, _mm256_storeu_si256,
        _mm_cvtsi32_si128,
    };
    // SAFETY: the caller upholds the AVX2 contract above; every
    // unaligned load/store touches exactly one half of a stack-owned
    // `[u64; 8]`/`[u64; 4]`-sized buffer, in bounds by construction.
    unsafe {
        let shift = _mm_cvtsi32_si128(64 - bits as i32);
        let lo = _mm256_loadu_si256(words.as_ptr() as *const __m256i);
        let hi = _mm256_loadu_si256(words.as_ptr().add(4) as *const __m256i);
        let lo = _mm256_srl_epi64(lo, shift);
        let hi = _mm256_srl_epi64(hi, shift);
        let mut shifted = [0u64; 8];
        _mm256_storeu_si256(shifted.as_mut_ptr() as *mut __m256i, lo);
        _mm256_storeu_si256(
            shifted.as_mut_ptr().add(4) as *mut __m256i,
            hi,
        );
        let mut out = [0u32; 8];
        for (o, w) in out.iter_mut().zip(shifted.iter()) {
            *o = *w as u32;
        }
        out
    }
}

/// The lane-interleaved decode engine: tiles independent chunk jobs
/// into groups of up to [`MAX_LANES`] lanes and steps each group in
/// lockstep through one codec's [`DecodeKernel::decode_lanes`].
///
/// The width is runtime-selected: 8 lanes when the CPU has AVX2 (a
/// full group feeds the vector peek path), 4 otherwise (enough
/// independent chains to fill a scalar out-of-order pipeline).
#[derive(Clone, Copy, Debug)]
pub struct LaneDecoder {
    lanes: usize,
}

impl LaneDecoder {
    /// Runtime-selected lane width (see the type docs).
    pub fn auto() -> LaneDecoder {
        LaneDecoder { lanes: if lanes_avx2_available() { 8 } else { 4 } }
    }

    /// Explicit lane width; 4 and 8 are supported.
    pub fn with_lanes(lanes: usize) -> Result<LaneDecoder, String> {
        if lanes == 4 || lanes == 8 {
            Ok(LaneDecoder { lanes })
        } else {
            Err(format!("lane width {lanes} unsupported (expected 4 or 8)"))
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Decode every job — `self.lanes` of them in lockstep at a time —
    /// through `kernel`.  Each job decodes exactly `out.len()`
    /// symbols.  Jobs that cannot possibly hold their symbol count
    /// (every code is ≥ 1 bit) are rejected before any cursor is
    /// built, matching
    /// [`DecoderSession::decode_chunk`](super::DecoderSession::decode_chunk).
    /// On `Err` the contents of every job's `out` are unspecified.
    pub fn decode_jobs<K: DecodeKernel + ?Sized>(
        &self,
        kernel: &K,
        jobs: &mut [LaneJob<'_, '_>],
    ) -> Result<(), CodecError> {
        for group in jobs.chunks_mut(self.lanes) {
            for job in group.iter() {
                if job.out.len() as u64 > job.payload.len() as u64 * 8 {
                    return Err(CodecError::UnexpectedEof);
                }
            }
            let mut lanes: Vec<Lane<'_, '_>> = group
                .iter_mut()
                .map(|job| Lane::new(job.payload, &mut *job.out))
                .collect();
            kernel.decode_lanes(&mut lanes)?;
            debug_assert!(lanes.iter().all(|l| l.remaining() == 0));
        }
        Ok(())
    }
}

impl Default for LaneDecoder {
    fn default() -> LaneDecoder {
        LaneDecoder::auto()
    }
}

/// The batched decode primitive.  See the module docs for the full
/// contract: decode **exactly `out.len()`** symbols, return the count,
/// leave the cursor just past the last code.
pub trait DecodeKernel {
    fn decode_batch(
        &self,
        cur: &mut BitCursor<'_>,
        out: &mut [u8],
    ) -> Result<usize, CodecError>;

    /// Decode every lane to completion (`lane.pos` reaches
    /// `lane.out.len()`), stepping the lanes in lockstep where the
    /// codec supports it.  Must agree with [`decode_batch`]
    /// symbol-for-symbol and consumed-bit-for-bit on every lane; on
    /// `Err` the lanes' outputs and cursors are unspecified.
    ///
    /// The default decodes lane-after-lane through the batched path —
    /// correct for every codec; table-driven codecs (QLC) override it
    /// with a genuinely interleaved loop.
    ///
    /// [`decode_batch`]: Self::decode_batch
    fn decode_lanes(
        &self,
        lanes: &mut [Lane<'_, '_>],
    ) -> Result<(), CodecError> {
        for lane in lanes.iter_mut() {
            let pos = lane.pos;
            let n = self.decode_batch(&mut lane.cur, &mut lane.out[pos..])?;
            lane.pos += n;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::{BitReader, BitWriter};
    use crate::codecs::{Codec, CodecRegistry};
    use crate::stats::Histogram;
    use crate::util::prop;

    #[test]
    fn cursor_matches_bitreader_on_random_fields() {
        prop::check("cursor==reader", Default::default(), |rng, size| {
            let nfields = rng.below(size as u64 + 1) as usize;
            let fields: Vec<(u64, u32)> = (0..nfields)
                .map(|_| {
                    let n = 1 + rng.below(32) as u32;
                    (rng.next_u64() & ((1u64 << n) - 1), n)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, n) in &fields {
                w.write_bits(v, n);
            }
            let buf = w.finish();
            let mut cur = BitCursor::new(&buf);
            let mut rdr = BitReader::new(&buf);
            for (i, &(v, n)) in fields.iter().enumerate() {
                let a = cur.read_bits(n).map_err(|e| e.to_string())? as u64;
                let b = rdr.read_bits(n).map_err(|e| e.to_string())? as u64;
                if a != v || b != v {
                    return Err(format!("field {i}: cursor {a} reader {b} want {v}"));
                }
                if cur.bits_consumed() != rdr.bits_consumed() {
                    return Err("consumed counts diverged".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cursor_unary_matches_bitreader() {
        for zeros in [0u32, 1, 7, 31, 32, 33, 63, 64, 65, 130] {
            let mut w = BitWriter::new();
            w.write_zeros(zeros);
            w.write_bit(true);
            w.write_bits(0b101, 3);
            let buf = w.finish();
            let mut cur = BitCursor::new(&buf);
            assert_eq!(cur.read_unary().unwrap(), zeros, "zeros={zeros}");
            assert_eq!(cur.read_bits(3).unwrap(), 0b101);
        }
        // All-zero stream: no terminating 1 → EOF.
        let mut cur = BitCursor::new(&[0u8; 4]);
        assert_eq!(cur.read_unary(), Err(CodecError::UnexpectedEof));
    }

    #[test]
    fn cursor_eof_detection() {
        let mut cur = BitCursor::new(&[0xFF]);
        assert_eq!(cur.read_bits(8).unwrap(), 0xFF);
        assert_eq!(cur.read_bits(1), Err(CodecError::UnexpectedEof));
        assert_eq!(cur.remaining_bits(), 0);
    }

    #[test]
    fn word_is_zero_padded_past_eof() {
        let mut cur = BitCursor::new(&[0xFF]);
        cur.refill();
        assert_eq!(cur.buffered(), 8);
        assert_eq!(cur.word(), 0xFFu64 << 56);
    }

    /// The satellite equivalence property: `decode_batch` ≡ the scalar
    /// reference path symbol-for-symbol, for every registered codec,
    /// on random payloads — including the consumed-bit count, so a
    /// kernel cannot "win" by skipping validation work.
    #[test]
    fn prop_batch_equals_scalar_all_registered_codecs() {
        let reg = CodecRegistry::global();
        prop::check("batch==scalar", prop::Config {
            cases: 64, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size);
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();
            let encoded = codec.encode_to_vec(&symbols);

            let mut batched = vec![0u8; symbols.len()];
            let mut cur = BitCursor::new(&encoded);
            codec
                .decode_into(&mut cur, &mut batched)
                .map_err(|e| format!("{name} batched: {e}"))?;

            let mut scalar = vec![0u8; symbols.len()];
            let mut rdr = BitReader::new(&encoded);
            codec
                .decode_scalar_into(&mut rdr, &mut scalar)
                .map_err(|e| format!("{name} scalar: {e}"))?;

            if batched != symbols {
                return Err(format!("{name}: batched decode mismatch"));
            }
            if scalar != symbols {
                return Err(format!("{name}: scalar decode mismatch"));
            }
            if cur.bits_consumed() != rdr.bits_consumed() {
                return Err(format!(
                    "{name}: batched consumed {} bits, scalar {}",
                    cur.bits_consumed(),
                    rdr.bits_consumed()
                ));
            }
            Ok(())
        });
    }

    /// The lane satellite property: lane decode ≡ batched ≡ scalar
    /// symbol-for-symbol for every registered codec, at both supported
    /// lane widths, over independent chunks of every ragged shape.
    #[test]
    fn prop_lanes_equal_batched_equal_scalar_all_registered_codecs() {
        let reg = CodecRegistry::global();
        prop::check("lanes==batched==scalar", prop::Config {
            cases: 64, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size);
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();
            // Independent chunks (the lane unit), ragged tail included.
            let chunk = 1 + rng.below(size as u64) as usize;
            let payloads: Vec<Vec<u8>> = symbols
                .chunks(chunk)
                .map(|c| codec.encode_to_vec(c))
                .collect();

            let mut batched = vec![0u8; symbols.len()];
            for (p, dst) in payloads.iter().zip(batched.chunks_mut(chunk)) {
                let mut cur = BitCursor::new(p);
                codec
                    .decode_into(&mut cur, dst)
                    .map_err(|e| format!("{name} batched: {e}"))?;
            }
            if batched != symbols {
                return Err(format!("{name}: batched chunk decode mismatch"));
            }

            let mut scalar = vec![0u8; symbols.len()];
            for (p, dst) in payloads.iter().zip(scalar.chunks_mut(chunk)) {
                let mut rdr = BitReader::new(p);
                codec
                    .decode_scalar_into(&mut rdr, dst)
                    .map_err(|e| format!("{name} scalar: {e}"))?;
            }
            if scalar != symbols {
                return Err(format!("{name}: scalar chunk decode mismatch"));
            }

            for width in [4usize, 8] {
                let engine = LaneDecoder::with_lanes(width)?;
                let mut laned = vec![0u8; symbols.len()];
                let mut jobs: Vec<LaneJob> = payloads
                    .iter()
                    .zip(laned.chunks_mut(chunk))
                    .map(|(p, o)| LaneJob { payload: p, out: o })
                    .collect();
                engine
                    .decode_jobs(codec, &mut jobs)
                    .map_err(|e| format!("{name} lanes x{width}: {e}"))?;
                if laned != symbols {
                    return Err(format!(
                        "{name}: lane decode mismatch at width {width}"
                    ));
                }
            }
            Ok(())
        });
    }

    /// Lane cursors must consume exactly the bits the batched path
    /// consumes — a lockstep loop cannot "win" by skipping validation.
    #[test]
    fn lane_cursors_consume_exactly_like_batched() {
        let reg = CodecRegistry::global();
        // Unequal chunk sizes force lanes to drop out at different
        // rounds and exercise the tail path; a tenth-sized variant
        // keeps the interpreted Miri run tractable.
        let sizes: [usize; 5] = if prop::reduced() {
            [900, 1, 1_200, 7, 1_892]
        } else {
            [9_000, 1, 12_000, 7, 18_992]
        };
        let total: u32 = sizes.iter().sum::<usize>() as u32;
        let symbols: Vec<u8> =
            (0..total).map(|i| (i * 31 % 251) as u8).collect();
        let hist = Histogram::from_symbols(&symbols);
        for name in ["qlc", "huffman", "elias-gamma", "eg2", "raw"] {
            let handle = reg.resolve(name, &hist).unwrap();
            let codec = handle.codec();
            assert_eq!(sizes.iter().sum::<usize>(), symbols.len());
            let mut payloads = Vec::new();
            let mut start = 0usize;
            for &s in &sizes {
                payloads.push(codec.encode_to_vec(&symbols[start..start + s]));
                start += s;
            }
            let mut outs: Vec<Vec<u8>> =
                sizes.iter().map(|&s| vec![0u8; s]).collect();
            let mut lanes: Vec<Lane> = payloads
                .iter()
                .zip(outs.iter_mut())
                .map(|(p, o)| Lane::new(p, o))
                .collect();
            codec.decode_lanes(&mut lanes).unwrap();
            let mut start = 0usize;
            for ((lane, p), &s) in lanes.iter().zip(&payloads).zip(&sizes) {
                assert_eq!(lane.remaining(), 0, "{name}");
                assert_eq!(&lane.out[..], &symbols[start..start + s], "{name}");
                let mut cur = BitCursor::new(p);
                let mut reference = vec![0u8; s];
                codec.decode_into(&mut cur, &mut reference).unwrap();
                assert_eq!(
                    lane.cur.bits_consumed(),
                    cur.bits_consumed(),
                    "{name}: lane consumed differently from batched"
                );
                start += s;
            }
        }
    }

    /// Truncated lane inputs must agree with the batched path on
    /// Ok-ness (and on bytes when both succeed).
    #[test]
    fn prop_lanes_and_batched_agree_on_truncation() {
        let reg = CodecRegistry::global();
        prop::check("lanes==batched truncated", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size.max(8));
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();
            let encoded = codec.encode_to_vec(&symbols);
            let keep = rng.below(encoded.len() as u64 + 1) as usize;
            let cut = &encoded[..keep];

            let mut batched = vec![0u8; symbols.len()];
            let mut cur = BitCursor::new(cut);
            let b = codec.decode_into(&mut cur, &mut batched);

            for width in [4usize, 8] {
                let engine = LaneDecoder::with_lanes(width)?;
                let mut laned = vec![0u8; symbols.len()];
                let mut jobs =
                    [LaneJob { payload: cut, out: &mut laned }];
                let l = engine.decode_jobs(codec, &mut jobs);
                if b.is_ok() != l.is_ok() {
                    return Err(format!(
                        "{name}: truncated at {keep}: batched {b:?}, \
                         lanes x{width} {l:?}"
                    ));
                }
                if b.is_ok() && laned != batched {
                    return Err(format!(
                        "{name}: truncated lane decode diverged"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lane_decoder_widths() {
        assert!(LaneDecoder::with_lanes(4).is_ok());
        assert!(LaneDecoder::with_lanes(8).is_ok());
        assert!(LaneDecoder::with_lanes(0).is_err());
        assert!(LaneDecoder::with_lanes(3).is_err());
        assert!(LaneDecoder::with_lanes(16).is_err());
        let auto = LaneDecoder::auto().lanes();
        assert!(auto == 4 || auto == 8);
        if lanes_avx2_available() {
            assert_eq!(auto, 8);
        }
    }

    #[test]
    fn lane_jobs_reject_impossible_counts() {
        let reg = CodecRegistry::global();
        let hist = Histogram::from_symbols(&[0]);
        let handle = reg.resolve("raw", &hist).unwrap();
        let mut out = vec![0u8; 17];
        let mut jobs = [LaneJob { payload: &[0xAB, 0xCD], out: &mut out }];
        assert_eq!(
            LaneDecoder::auto().decode_jobs(handle.codec(), &mut jobs),
            Err(CodecError::UnexpectedEof)
        );
        // Empty job lists and empty jobs are no-ops.
        let mut none: [LaneJob; 0] = [];
        LaneDecoder::auto()
            .decode_jobs(handle.codec(), &mut none)
            .unwrap();
        let mut empty = [LaneJob { payload: &[], out: &mut [] }];
        LaneDecoder::auto()
            .decode_jobs(handle.codec(), &mut empty)
            .unwrap();
    }

    #[cfg(all(target_arch = "x86_64", not(miri)))]
    #[test]
    fn avx2_peek_matches_scalar_shift() {
        if !lanes_avx2_available() {
            return;
        }
        let words = [
            0xFFFF_FFFF_FFFF_FFFFu64,
            0x8000_0000_0000_0000,
            0x0123_4567_89AB_CDEF,
            0,
            0x7FFF_FFFF_FFFF_FFFF,
            0xDEAD_BEEF_CAFE_F00D,
            1,
            0xA5A5_A5A5_A5A5_A5A5,
        ];
        for bits in [1u32, 3, 5, 8, 16, 32] {
            let got = unsafe { peek_top_bits_x8(&words, bits) };
            for (g, w) in got.iter().zip(words.iter()) {
                assert_eq!(*g as u64, w >> (64 - bits), "bits={bits}");
            }
        }
    }

    /// Truncations must error on both paths (never panic, never
    /// diverge into one Ok / one Err on the *same* cut only when the
    /// cut leaves a decodable prefix — then both must agree).
    #[test]
    fn prop_batch_and_scalar_agree_on_truncation() {
        let reg = CodecRegistry::global();
        prop::check("batch==scalar truncated", prop::Config {
            cases: 48, ..Default::default()
        }, |rng, size| {
            let symbols = prop::arb_bytes(rng, size.max(8));
            let mut hist = Histogram::from_symbols(&symbols);
            if hist.total() == 0 {
                hist = Histogram::from_symbols(&[0]);
            }
            let names = reg.known_names();
            let name = names[rng.below(names.len() as u64) as usize];
            let handle =
                reg.resolve(name, &hist).map_err(|e| e.to_string())?;
            let codec = handle.codec();
            let encoded = codec.encode_to_vec(&symbols);
            let keep = rng.below(encoded.len() as u64 + 1) as usize;
            let cut = &encoded[..keep];

            let mut batched = vec![0u8; symbols.len()];
            let mut cur = BitCursor::new(cut);
            let b = codec.decode_into(&mut cur, &mut batched);

            let mut scalar = vec![0u8; symbols.len()];
            let mut rdr = BitReader::new(cut);
            let s = codec.decode_scalar_into(&mut rdr, &mut scalar);

            if b.is_ok() != s.is_ok() {
                return Err(format!(
                    "{name}: truncated at {keep}: batched {b:?}, scalar {s:?}"
                ));
            }
            if b.is_ok() && batched != scalar {
                return Err(format!("{name}: truncated decode diverged"));
            }
            Ok(())
        });
    }
}
