//! Streaming codec sessions: block-oriented encode/decode with
//! reusable scratch state.
//!
//! A session wraps a `&dyn Codec` and processes one *chunk* at a time.
//! Chunks are byte-aligned and independent — a decoder needs only the
//! chunk's payload bytes and its symbol count, which is exactly what
//! makes chunked payloads (frame format QLF2, the collective
//! transport) decodable in parallel and at line rate in hardware.
//!
//! The encoder session keeps one [`BitSink`] (or, in scalar mode, one
//! [`BitWriter`]) alive across chunks so a long stream is encoded with
//! a single scratch allocation; the decoder session decodes into
//! caller-provided `&mut [u8]` buffers, so the destination (tensor
//! shard, frame slice) is written exactly once.  Both track totals for
//! throughput accounting.  Every encode path produces identical bytes
//! — [`EncodeMode`] selects *how* they are produced, never *what*.

use super::kernel::{
    BitCursor, BitSink, DecodeKernel, EncodeJob, LaneDecoder, LaneEncoder,
    LaneJob, MixedLaneJob,
};
use super::{Codec, CodecError};
use crate::bitstream::{BitReader, BitWriter};
use crate::obs;

/// Handles onto the global obs registry for one session direction
/// (`encode`/`decode`), labelled by codec + mode.  Acquired once at
/// session construction; the per-chunk cost is one stopwatch read and
/// a few relaxed atomic adds.
struct SessionStats {
    chunk_ns: obs::Hist,
    group_ns: obs::Hist,
    symbols: obs::Counter,
    bytes: obs::Counter,
    chunks: obs::Counter,
}

impl SessionStats {
    fn new(dir: &str, codec: &dyn Codec, mode: &'static str) -> SessionStats {
        let reg = obs::global();
        let codec_name = codec.name();
        let labels = [("codec", codec_name.as_str()), ("mode", mode)];
        let key = |metric: &str| obs::label(&format!("codec_{dir}_{metric}"), &labels);
        SessionStats {
            chunk_ns: reg.hist(&key("chunk_ns")),
            group_ns: reg.hist(&key("group_ns")),
            symbols: reg.counter(&key("symbols_total")),
            bytes: reg.counter(&key("bytes_total")),
            chunks: reg.counter(&key("chunks_total")),
        }
    }

    fn chunk(&self, elapsed_ns: u64, symbols: u64, bytes: u64) {
        self.chunk_ns.record(elapsed_ns);
        self.symbols.add(symbols);
        self.bytes.add(bytes);
        self.chunks.inc();
    }
}

/// Which decode path a [`DecoderSession`] (and everything above it —
/// frame, transport, CLI) runs: the batched
/// [`DecodeKernel`](super::DecodeKernel) word-at-a-time path, the
/// lane-interleaved multi-cursor path
/// ([`LaneDecoder`](super::LaneDecoder), stepping independent chunks
/// in lockstep), or the scalar one-symbol-per-step reference path.
/// Batched is the default everywhere; lanes multiply single-core
/// throughput when a caller has several chunks in hand
/// ([`DecoderSession::decode_chunk_group`]); scalar exists for
/// equivalence testing and the bench/CLI comparisons.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DecodeMode {
    #[default]
    Batched,
    Scalar,
    Lanes,
}

impl DecodeMode {
    /// Parse the CLI's `--decode` vocabulary.
    pub fn parse(name: &str) -> Result<DecodeMode, String> {
        match name {
            "batched" => Ok(DecodeMode::Batched),
            "scalar" => Ok(DecodeMode::Scalar),
            "lanes" => Ok(DecodeMode::Lanes),
            other => Err(format!(
                "unknown decode mode '{other}' (expected \
                 batched|scalar|lanes)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DecodeMode::Batched => "batched",
            DecodeMode::Scalar => "scalar",
            DecodeMode::Lanes => "lanes",
        }
    }
}

/// Which encode path an [`EncoderSession`] (and everything above it —
/// frame, transport, CLI) runs: the batched
/// [`EncodeKernel`](super::EncodeKernel) staging-word path, the
/// lane-interleaved path ([`LaneEncoder`](super::LaneEncoder),
/// stepping independent chunks in lockstep through
/// [`EncoderSession::encode_chunk_group`]), or the scalar
/// one-code-per-`write_bits` reference path.  [`DecodeMode`]'s mirror:
/// batched is the default everywhere, and all three produce
/// bit-for-bit identical payloads — the mode only changes throughput.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EncodeMode {
    #[default]
    Batched,
    Scalar,
    Lanes,
}

impl EncodeMode {
    /// Parse the CLI's `--encode` vocabulary.
    pub fn parse(name: &str) -> Result<EncodeMode, String> {
        match name {
            "batched" => Ok(EncodeMode::Batched),
            "scalar" => Ok(EncodeMode::Scalar),
            "lanes" => Ok(EncodeMode::Lanes),
            other => Err(format!(
                "unknown encode mode '{other}' (expected \
                 batched|scalar|lanes)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EncodeMode::Batched => "batched",
            EncodeMode::Scalar => "scalar",
            EncodeMode::Lanes => "lanes",
        }
    }
}

/// Default chunk granularity in symbols (64 KiB of e4m3 symbols).
/// Large enough that per-chunk overhead (8 bytes of QLF2 chunk table,
/// one flush) is noise; small enough that a multi-core decode of a
/// multi-megabyte payload has real parallelism.
pub const DEFAULT_CHUNK_SYMBOLS: usize = 64 * 1024;

/// `[start, end)` symbol spans of successive chunks covering `total`
/// symbols at `chunk_symbols` granularity (the last span may be
/// short; `total == 0` yields no spans, matching `slice::chunks`).
/// The one chunking rule shared by the QLF2 frame writer, the shard
/// encoder and the chunk-granular transport — all three must agree on
/// boundaries for their payloads to be interchangeable.
pub fn chunk_spans(total: usize, chunk_symbols: usize) -> Vec<(usize, usize)> {
    let step = chunk_symbols.max(1);
    let mut spans = Vec::with_capacity(total / step + 1);
    let mut start = 0usize;
    while start < total {
        let end = (start + step).min(total);
        spans.push((start, end));
        start = end;
    }
    spans
}

/// Streaming encoder bound to one codec.
///
/// ```
/// use qlc::codecs::{Codec, EncoderSession};
/// use qlc::codecs::raw::RawCodec;
/// let codec = RawCodec;
/// let mut session = codec.encoder();
/// let mut payload = Vec::new();
/// let a = session.encode_chunk(&[1, 2, 3], &mut payload);
/// let b = session.encode_chunk(&[4, 5], &mut payload);
/// assert_eq!((a, b), (3, 2));
/// assert_eq!(payload, [1, 2, 3, 4, 5]);
/// ```
pub struct EncoderSession<'c> {
    codec: &'c dyn Codec,
    mode: EncodeMode,
    /// Reused scratch writer (scalar mode); drained after every chunk.
    writer: BitWriter,
    /// Reused scratch sink (batched/lanes); drained after every chunk.
    sink: BitSink,
    /// Lane engine for [`EncodeMode::Lanes`] group encodes
    /// (runtime-selected width, cached at construction).
    lane: LaneEncoder,
    symbols_in: u64,
    bytes_out: u64,
    chunks: u64,
    /// Global-registry handles (per-chunk latency hist + totals).
    stats: SessionStats,
}

impl<'c> EncoderSession<'c> {
    pub fn new(codec: &'c dyn Codec) -> Self {
        Self::with_mode(codec, EncodeMode::default())
    }

    pub fn with_mode(codec: &'c dyn Codec, mode: EncodeMode) -> Self {
        EncoderSession {
            codec,
            mode,
            writer: BitWriter::new(),
            sink: BitSink::new(),
            lane: LaneEncoder::auto(),
            symbols_in: 0,
            bytes_out: 0,
            chunks: 0,
            stats: SessionStats::new("encode", codec, mode.name()),
        }
    }

    pub fn codec(&self) -> &'c dyn Codec {
        self.codec
    }

    /// Which encode path this session runs.
    pub fn mode(&self) -> EncodeMode {
        self.mode
    }

    /// Encode one chunk, appending its byte-aligned payload to `out`.
    /// Returns the payload length in bytes.  The bytes are identical
    /// in every mode; a lanes-mode session encodes a single chunk
    /// through the batched kernel (the lane win comes from
    /// [`encode_chunk_group`](Self::encode_chunk_group)).
    pub fn encode_chunk(&mut self, symbols: &[u8], out: &mut Vec<u8>) -> usize {
        let sw = obs::Stopwatch::start();
        let before = out.len();
        match self.mode {
            EncodeMode::Batched | EncodeMode::Lanes => {
                self.codec.encode_batch(symbols, &mut self.sink);
                self.sink.drain_into(out);
            }
            EncodeMode::Scalar => {
                self.codec.encode_scalar(symbols, &mut self.writer);
                self.writer.drain_into(out);
            }
        }
        let written = out.len() - before;
        self.symbols_in += symbols.len() as u64;
        self.bytes_out += written as u64;
        self.chunks += 1;
        self.stats.chunk(sw.elapsed_ns(), symbols.len() as u64, written as u64);
        written
    }

    /// Encode several independent chunks in one call, appending each
    /// job's payload to its own `out`.
    ///
    /// Under [`EncodeMode::Lanes`] the jobs run through the
    /// lane-interleaved engine: up to
    /// [`MAX_LANES`](super::kernel::MAX_LANES) chunk sinks step in
    /// lockstep so their LUT loads overlap in the pipeline.  The other
    /// modes encode the jobs serially through
    /// [`encode_chunk`](Self::encode_chunk), so the payload bytes (and
    /// the session accounting) are mode-independent.
    pub fn encode_chunk_group(&mut self, jobs: &mut [EncodeJob<'_, '_>]) {
        match self.mode {
            EncodeMode::Lanes => {
                let sw = obs::Stopwatch::start();
                let before: usize = jobs.iter().map(|j| j.out.len()).sum();
                self.lane.encode_jobs(self.codec, &mut *jobs);
                let after: usize = jobs.iter().map(|j| j.out.len()).sum();
                for job in jobs.iter() {
                    self.symbols_in += job.symbols.len() as u64;
                    self.chunks += 1;
                    self.stats.symbols.add(job.symbols.len() as u64);
                    self.stats.chunks.inc();
                }
                self.bytes_out += (after - before) as u64;
                self.stats.bytes.add((after - before) as u64);
                self.stats.group_ns.record(sw.elapsed_ns());
            }
            EncodeMode::Batched | EncodeMode::Scalar => {
                for job in jobs.iter_mut() {
                    self.encode_chunk(job.symbols, job.out);
                }
            }
        }
    }

    /// Encode one chunk into a fresh buffer.
    pub fn encode_chunk_to_vec(&mut self, symbols: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(symbols.len());
        self.encode_chunk(symbols, &mut out);
        out
    }

    /// Total symbols consumed across all chunks.
    pub fn symbols_in(&self) -> u64 {
        self.symbols_in
    }

    /// Total payload bytes produced across all chunks.
    pub fn bytes_out(&self) -> u64 {
        self.bytes_out
    }

    /// Number of chunks encoded.
    pub fn chunks(&self) -> u64 {
        self.chunks
    }
}

/// Streaming decoder bound to one codec.  Decodes byte-aligned chunk
/// payloads into caller-provided slices via the batched
/// [`DecodeKernel`](super::DecodeKernel), the lane-interleaved engine
/// ([`DecodeMode::Lanes`], see
/// [`decode_chunk_group`](Self::decode_chunk_group)), or the scalar
/// reference path ([`DecodeMode::Scalar`]).
pub struct DecoderSession<'c> {
    codec: &'c dyn Codec,
    mode: DecodeMode,
    /// Lane engine for [`DecodeMode::Lanes`] group decodes
    /// (runtime-selected width, cached at construction).
    lane: LaneDecoder,
    symbols_out: u64,
    bytes_in: u64,
    chunks: u64,
    /// Global-registry handles (per-chunk latency hist + totals).
    stats: SessionStats,
}

impl<'c> DecoderSession<'c> {
    pub fn new(codec: &'c dyn Codec) -> Self {
        Self::with_mode(codec, DecodeMode::default())
    }

    pub fn with_mode(codec: &'c dyn Codec, mode: DecodeMode) -> Self {
        DecoderSession {
            codec,
            mode,
            lane: LaneDecoder::auto(),
            symbols_out: 0,
            bytes_in: 0,
            chunks: 0,
            stats: SessionStats::new("decode", codec, mode.name()),
        }
    }

    pub fn codec(&self) -> &'c dyn Codec {
        self.codec
    }

    /// Which decode path this session runs.
    pub fn mode(&self) -> DecodeMode {
        self.mode
    }

    /// Decode exactly `out.len()` symbols from `payload` into `out`.
    ///
    /// Rejects payloads that cannot possibly hold `out.len()` symbols
    /// (every code is ≥ 1 bit) before touching the bitstream, so a
    /// hostile chunk header fails fast instead of grinding through the
    /// decoder.
    pub fn decode_chunk(
        &mut self,
        payload: &[u8],
        out: &mut [u8],
    ) -> Result<(), CodecError> {
        if out.len() as u64 > payload.len() as u64 * 8 {
            return Err(CodecError::UnexpectedEof);
        }
        let sw = obs::Stopwatch::start();
        match self.mode {
            // A single chunk has nothing to interleave with, so Lanes
            // degenerates to the batched kernel here; the lane win
            // comes from [`Self::decode_chunk_group`].
            DecodeMode::Batched | DecodeMode::Lanes => {
                let mut cur = BitCursor::new(payload);
                self.codec.decode_into(&mut cur, out)?;
            }
            DecodeMode::Scalar => {
                let mut reader = BitReader::new(payload);
                self.codec.decode_scalar_into(&mut reader, out)?;
            }
        }
        self.symbols_out += out.len() as u64;
        self.bytes_in += payload.len() as u64;
        self.chunks += 1;
        self.stats.chunk(
            sw.elapsed_ns(),
            out.len() as u64,
            payload.len() as u64,
        );
        Ok(())
    }

    /// Decode several independent chunk payloads in one call; every
    /// job decodes exactly `job.out.len()` symbols.
    ///
    /// Under [`DecodeMode::Lanes`] the jobs run through the
    /// lane-interleaved engine: up to
    /// [`MAX_LANES`](super::kernel::MAX_LANES) chunk cursors step in
    /// lockstep so their table lookups overlap in the pipeline.  The
    /// other modes decode the jobs serially through
    /// [`decode_chunk`](Self::decode_chunk), so the result (and the
    /// session accounting) is mode-independent.
    pub fn decode_chunk_group(
        &mut self,
        jobs: &mut [LaneJob<'_, '_>],
    ) -> Result<(), CodecError> {
        match self.mode {
            DecodeMode::Lanes => {
                let sw = obs::Stopwatch::start();
                self.lane.decode_jobs(self.codec, &mut *jobs)?;
                for job in jobs.iter() {
                    self.symbols_out += job.out.len() as u64;
                    self.bytes_in += job.payload.len() as u64;
                    self.chunks += 1;
                    self.stats.symbols.add(job.out.len() as u64);
                    self.stats.bytes.add(job.payload.len() as u64);
                    self.stats.chunks.inc();
                }
                self.stats.group_ns.record(sw.elapsed_ns());
                Ok(())
            }
            DecodeMode::Batched | DecodeMode::Scalar => {
                for job in jobs.iter_mut() {
                    self.decode_chunk(job.payload, job.out)?;
                }
                Ok(())
            }
        }
    }

    /// Decode several chunk payloads that do not all share one codec:
    /// each [`MixedLaneJob`] carries its own kernel (e.g. a per-chunk
    /// adaptive table-delta codec alongside the frame codec).
    ///
    /// Under [`DecodeMode::Lanes`] the jobs run through the
    /// mixed-table lockstep engine
    /// ([`LaneDecoder::decode_jobs_mixed`]); lanes whose kernels agree
    /// on a lockstep budget interleave even across different tables.
    /// The other modes decode each job serially through its own
    /// kernel, so the result and accounting stay mode-independent.
    pub fn decode_chunk_group_mixed(
        &mut self,
        jobs: &mut [MixedLaneJob<'_, '_, '_>],
    ) -> Result<(), CodecError> {
        match self.mode {
            DecodeMode::Lanes => {
                let sw = obs::Stopwatch::start();
                self.lane.decode_jobs_mixed(&mut *jobs)?;
                for job in jobs.iter() {
                    self.symbols_out += job.out.len() as u64;
                    self.bytes_in += job.payload.len() as u64;
                    self.chunks += 1;
                    self.stats.symbols.add(job.out.len() as u64);
                    self.stats.bytes.add(job.payload.len() as u64);
                    self.stats.chunks.inc();
                }
                self.stats.group_ns.record(sw.elapsed_ns());
                Ok(())
            }
            DecodeMode::Batched | DecodeMode::Scalar => {
                for job in jobs.iter_mut() {
                    if job.out.len() as u64 > job.payload.len() as u64 * 8 {
                        return Err(CodecError::UnexpectedEof);
                    }
                    let mut cur = BitCursor::new(job.payload);
                    job.kernel.decode_batch(&mut cur, job.out)?;
                    self.symbols_out += job.out.len() as u64;
                    self.bytes_in += job.payload.len() as u64;
                    self.chunks += 1;
                }
                Ok(())
            }
        }
    }

    /// Decode `n` symbols from `payload` into a fresh buffer.
    pub fn decode_chunk_to_vec(
        &mut self,
        payload: &[u8],
        n: usize,
    ) -> Result<Vec<u8>, CodecError> {
        let mut out = vec![0u8; n];
        self.decode_chunk(payload, &mut out)?;
        Ok(out)
    }

    /// Total symbols produced across all chunks.
    pub fn symbols_out(&self) -> u64 {
        self.symbols_out
    }

    /// Total payload bytes consumed across all chunks.
    pub fn bytes_in(&self) -> u64 {
        self.bytes_in
    }

    /// Number of chunks decoded.
    pub fn chunks(&self) -> u64 {
        self.chunks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::huffman::HuffmanCodec;
    use crate::codecs::qlc::{AreaScheme, QlcCodec};
    use crate::codecs::raw::RawCodec;
    use crate::stats::Histogram;
    use crate::util::rng::{AliasTable, Rng};

    fn skewed(n: usize, seed: u64) -> Vec<u8> {
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = (-0.03 * i as f64).exp();
        }
        AliasTable::new(&p).sample_many(&mut Rng::new(seed), n)
    }

    #[test]
    fn session_chunks_equal_single_shot() {
        let symbols = skewed(100_000, 1);
        let hist = Histogram::from_symbols(&symbols);
        let codec = HuffmanCodec::from_histogram(&hist);
        // Single-shot payload of each chunk must equal the session's
        // (chunks are independent: no state leaks across the flush).
        let mut enc = codec.encoder();
        let mut streamed = Vec::new();
        let mut reference = Vec::new();
        for chunk in symbols.chunks(7_919) {
            enc.encode_chunk(chunk, &mut streamed);
            reference.extend_from_slice(&codec.encode_to_vec(chunk));
        }
        assert_eq!(streamed, reference);
        assert_eq!(enc.symbols_in(), symbols.len() as u64);
        assert_eq!(enc.bytes_out(), streamed.len() as u64);
    }

    #[test]
    fn decode_session_fills_caller_buffer() {
        let symbols = skewed(50_000, 2);
        let pmf = Histogram::from_symbols(&symbols).pmf();
        let codec = QlcCodec::from_pmf(AreaScheme::table1(), &pmf);
        let mut enc = codec.encoder();
        let payload = enc.encode_chunk_to_vec(&symbols);
        let mut dec = codec.decoder();
        let mut out = vec![0u8; symbols.len()];
        dec.decode_chunk(&payload, &mut out).unwrap();
        assert_eq!(out, symbols);
        assert_eq!(dec.symbols_out(), symbols.len() as u64);
        assert_eq!(dec.chunks(), 1);
    }

    #[test]
    fn decode_chunk_rejects_impossible_counts() {
        let codec = RawCodec;
        let mut dec = codec.decoder();
        // 2 payload bytes cannot hold 17 one-bit codes, let alone raw.
        let mut out = vec![0u8; 17];
        assert_eq!(
            dec.decode_chunk(&[0xAB, 0xCD], &mut out),
            Err(CodecError::UnexpectedEof)
        );
        assert_eq!(dec.chunks(), 0, "failed chunks must not count");
    }

    #[test]
    fn chunk_spans_cover_exactly() {
        for (total, chunk) in
            [(0usize, 8usize), (1, 8), (8, 8), (9, 8), (1000, 1), (5, 0)]
        {
            let spans = chunk_spans(total, chunk);
            if total == 0 {
                assert!(spans.is_empty());
                continue;
            }
            assert_eq!(spans[0].0, 0);
            assert_eq!(spans.last().unwrap().1, total);
            for w in spans.windows(2) {
                assert_eq!(w[0].1, w[1].0, "spans must be contiguous");
            }
            let step = chunk.max(1);
            assert!(spans.iter().all(|&(a, b)| b - a <= step && b > a));
        }
    }

    #[test]
    fn scalar_and_batched_sessions_agree() {
        let symbols = skewed(30_000, 7);
        let pmf = Histogram::from_symbols(&symbols).pmf();
        let codec = QlcCodec::from_pmf(AreaScheme::table1(), &pmf);
        let payload = codec.encoder().encode_chunk_to_vec(&symbols);
        let mut batched = vec![0u8; symbols.len()];
        DecoderSession::new(&codec)
            .decode_chunk(&payload, &mut batched)
            .unwrap();
        let mut scalar = vec![0u8; symbols.len()];
        let mut s = DecoderSession::with_mode(&codec, DecodeMode::Scalar);
        assert_eq!(s.mode(), DecodeMode::Scalar);
        s.decode_chunk(&payload, &mut scalar).unwrap();
        // A lanes-mode session on a single chunk degenerates to the
        // batched kernel — same bytes either way.
        let mut laned = vec![0u8; symbols.len()];
        let mut l = DecoderSession::with_mode(&codec, DecodeMode::Lanes);
        assert_eq!(l.mode(), DecodeMode::Lanes);
        l.decode_chunk(&payload, &mut laned).unwrap();
        assert_eq!(batched, symbols);
        assert_eq!(scalar, symbols);
        assert_eq!(laned, symbols);
    }

    #[test]
    fn lane_session_group_decodes_independent_chunks() {
        let symbols = skewed(60_000, 9);
        let pmf = Histogram::from_symbols(&symbols).pmf();
        let codec = QlcCodec::from_pmf(AreaScheme::table1(), &pmf);
        let chunk = 7_000usize;
        let mut enc = codec.encoder();
        let payloads: Vec<Vec<u8>> = symbols
            .chunks(chunk)
            .map(|c| enc.encode_chunk_to_vec(c))
            .collect();
        for mode in [DecodeMode::Lanes, DecodeMode::Batched] {
            let mut out = vec![0u8; symbols.len()];
            let mut s = DecoderSession::with_mode(&codec, mode);
            let mut jobs: Vec<LaneJob> = payloads
                .iter()
                .zip(out.chunks_mut(chunk))
                .map(|(p, o)| LaneJob { payload: p, out: o })
                .collect();
            s.decode_chunk_group(&mut jobs).unwrap();
            assert_eq!(out, symbols, "{mode:?}");
            assert_eq!(s.chunks(), payloads.len() as u64, "{mode:?}");
            assert_eq!(s.symbols_out(), symbols.len() as u64, "{mode:?}");
        }
        // Impossible counts are rejected in lanes mode too.
        let mut out = vec![0u8; 17];
        let mut s = DecoderSession::with_mode(&codec, DecodeMode::Lanes);
        let mut jobs = [LaneJob { payload: &[0xAB, 0xCD], out: &mut out }];
        assert_eq!(
            s.decode_chunk_group(&mut jobs),
            Err(CodecError::UnexpectedEof)
        );
    }

    #[test]
    fn sessions_record_into_the_global_registry() {
        let codec = RawCodec;
        let name = codec.name();
        let labels = [("codec", name.as_str()), ("mode", "batched")];
        let sym_key = obs::label("codec_encode_symbols_total", &labels);
        let hist_key = obs::label("codec_decode_chunk_ns", &labels);
        let reg = obs::global();
        let syms_before = reg.counter(&sym_key).get();
        let decodes_before = reg.hist(&hist_key).count();
        let payload = codec.encoder().encode_chunk_to_vec(&[1, 2, 3, 4]);
        let mut out = [0u8; 4];
        codec.decoder().decode_chunk(&payload, &mut out).unwrap();
        // `>=` not `==`: other tests share the raw codec's global keys.
        assert!(reg.counter(&sym_key).get() >= syms_before + 4);
        assert!(reg.hist(&hist_key).count() >= decodes_before + 1);
    }

    #[test]
    fn empty_chunks_are_noops() {
        let codec = RawCodec;
        let mut enc = codec.encoder();
        let mut out = Vec::new();
        assert_eq!(enc.encode_chunk(&[], &mut out), 0);
        let mut dec = codec.decoder();
        dec.decode_chunk(&[], &mut []).unwrap();
        assert_eq!(dec.symbols_out(), 0);
    }
}
