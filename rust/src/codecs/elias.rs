//! Elias gamma / delta / omega universal codes (paper §1 baselines).
//!
//! Universal codes embed the code length in the code itself (leading
//! zeros), so decode is not a deep tree walk — but they ignore the
//! symbol distribution.  By default symbols map to `value + 1`
//! (Elias codes start at 1); [`EliasCodec::with_ranking`] instead maps
//! through a frequency-rank LUT, the "universal code + LUT" hybrid
//! ablation used in `benches/ablation_scheme.rs`.

use super::kernel::{BitCursor, BitSink, DecodeKernel, EncodeKernel};
use super::{Codec, CodecError};
use crate::bitstream::{BitReader, BitWriter};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EliasKind {
    Gamma,
    Delta,
    Omega,
}

impl EliasKind {
    pub fn name(&self) -> &'static str {
        match self {
            EliasKind::Gamma => "elias-gamma",
            EliasKind::Delta => "elias-delta",
            EliasKind::Omega => "elias-omega",
        }
    }
}

#[derive(Clone, Debug)]
pub struct EliasCodec {
    kind: EliasKind,
    /// symbol → encoded value-1 (i.e. the integer fed to the code is
    /// `map[s] + 1`). Identity by default; frequency rank if ranked.
    map: [u8; 256],
    /// Inverse of `map`.
    unmap: [u8; 256],
    ranked: bool,
}

impl EliasCodec {
    pub fn new(kind: EliasKind) -> Self {
        let mut map = [0u8; 256];
        for (i, m) in map.iter_mut().enumerate() {
            *m = i as u8;
        }
        EliasCodec { kind, map, unmap: map, ranked: false }
    }

    /// Map symbols through `rank_order` (rank r ← symbol
    /// `rank_order[r]`) so frequent symbols get short codes.
    pub fn with_ranking(kind: EliasKind, rank_order: &[u8; 256]) -> Self {
        let mut map = [0u8; 256];
        let mut unmap = [0u8; 256];
        for (rank, &sym) in rank_order.iter().enumerate() {
            map[sym as usize] = rank as u8;
            unmap[rank] = sym;
        }
        EliasCodec { kind, map, unmap, ranked: true }
    }

    fn encode_value(&self, n: u32, out: &mut BitWriter) {
        debug_assert!((1..=256).contains(&n));
        match self.kind {
            EliasKind::Gamma => encode_gamma(n, out),
            EliasKind::Delta => encode_delta(n, out),
            EliasKind::Omega => encode_omega(n, out),
        }
    }

    fn decode_value(&self, r: &mut BitReader) -> Result<u32, CodecError> {
        let v = match self.kind {
            EliasKind::Gamma => decode_gamma(r)?,
            EliasKind::Delta => decode_delta(r)?,
            EliasKind::Omega => decode_omega(r)?,
        };
        if !(1..=256).contains(&v) {
            return Err(CodecError::InvalidCode {
                bit_offset: r.bits_consumed(),
            });
        }
        Ok(v)
    }

    /// Code length in bits of integer `n ≥ 1`.
    pub fn value_length(kind: EliasKind, n: u32) -> u32 {
        debug_assert!(n >= 1);
        let nbits = 32 - n.leading_zeros(); // floor(log2 n) + 1
        match kind {
            EliasKind::Gamma => 2 * nbits - 1,
            EliasKind::Delta => {
                let lbits = 32 - nbits.leading_zeros();
                (nbits - 1) + (2 * lbits - 1)
            }
            EliasKind::Omega => {
                // Sum of group lengths + terminating 0.
                let mut len = 1;
                let mut m = n;
                while m > 1 {
                    let g = 32 - m.leading_zeros();
                    len += g;
                    m = g - 1;
                }
                len
            }
        }
    }
}

fn encode_gamma(n: u32, out: &mut BitWriter) {
    let nbits = 32 - n.leading_zeros();
    out.write_zeros(nbits - 1);
    out.write_bits(n as u64, nbits);
}

fn decode_gamma(r: &mut BitReader) -> Result<u32, CodecError> {
    let zeros = r.read_unary().map_err(|_| CodecError::UnexpectedEof)?;
    if zeros > 31 {
        return Err(CodecError::InvalidCode { bit_offset: r.bits_consumed() });
    }
    let rest = r
        .read_bits(zeros)
        .map_err(|_| CodecError::UnexpectedEof)?;
    Ok((1 << zeros) | rest)
}

fn encode_delta(n: u32, out: &mut BitWriter) {
    let nbits = 32 - n.leading_zeros();
    encode_gamma(nbits, out);
    if nbits > 1 {
        out.write_bits((n & ((1 << (nbits - 1)) - 1)) as u64, nbits - 1);
    }
}

fn decode_delta(r: &mut BitReader) -> Result<u32, CodecError> {
    let nbits = decode_gamma(r)?;
    if nbits == 0 || nbits > 32 {
        return Err(CodecError::InvalidCode { bit_offset: r.bits_consumed() });
    }
    if nbits == 1 {
        return Ok(1);
    }
    let rest = r
        .read_bits(nbits - 1)
        .map_err(|_| CodecError::UnexpectedEof)?;
    Ok((1 << (nbits - 1)) | rest)
}

fn encode_omega(n: u32, out: &mut BitWriter) {
    // Build groups back-to-front.
    let mut groups: Vec<(u32, u32)> = Vec::new(); // (value, bits)
    let mut m = n;
    while m > 1 {
        let bits = 32 - m.leading_zeros();
        groups.push((m, bits));
        m = bits - 1;
    }
    for &(v, bits) in groups.iter().rev() {
        out.write_bits(v as u64, bits);
    }
    out.write_bits(0, 1);
}

fn decode_omega(r: &mut BitReader) -> Result<u32, CodecError> {
    let mut n: u32 = 1;
    loop {
        let b = r.read_bit().map_err(|_| CodecError::UnexpectedEof)?;
        if !b {
            return Ok(n);
        }
        if n >= 31 {
            return Err(CodecError::InvalidCode {
                bit_offset: r.bits_consumed(),
            });
        }
        let rest = r
            .read_bits(n)
            .map_err(|_| CodecError::UnexpectedEof)?;
        n = (1 << n) | rest;
    }
}

// ---------------------------------------------------------------------------
// Batched kernel path: leading-zero-count decode on the 64-bit cursor
// word.  A gamma code is `lz` zeros, a 1, then `lz` payload bits — one
// `u64::leading_zeros` yields the prefix length, the value *and* the
// consume width, so a whole code resolves from one buffered word with
// no per-bit steps.  Delta/omega chain through the same primitive.

fn decode_gamma_cursor(cur: &mut BitCursor) -> Result<u32, CodecError> {
    let avail = cur.refill_buffered();
    let w = cur.word();
    let lz = w.leading_zeros();
    // Whole code inside the valid window (implies lz ≤ 31): resolve it
    // from the word in one step.
    if 2 * lz + 1 <= avail {
        let v = (w >> (63 - 2 * lz)) as u32;
        cur.consume(2 * lz + 1);
        return Ok(v);
    }
    // Code straddles the window or the stream ends: checked path.
    let zeros = cur.read_unary()?;
    if zeros > 31 {
        return Err(CodecError::InvalidCode {
            bit_offset: cur.bits_consumed(),
        });
    }
    let rest = cur.read_bits(zeros)?;
    Ok((1 << zeros) | rest)
}

fn decode_delta_cursor(cur: &mut BitCursor) -> Result<u32, CodecError> {
    let nbits = decode_gamma_cursor(cur)?;
    if nbits == 0 || nbits > 32 {
        return Err(CodecError::InvalidCode {
            bit_offset: cur.bits_consumed(),
        });
    }
    if nbits == 1 {
        return Ok(1);
    }
    let rest = cur.read_bits(nbits - 1)?;
    Ok((1 << (nbits - 1)) | rest)
}

fn decode_omega_cursor(cur: &mut BitCursor) -> Result<u32, CodecError> {
    let mut n: u32 = 1;
    loop {
        if cur.read_bits(1)? == 0 {
            return Ok(n);
        }
        if n >= 31 {
            return Err(CodecError::InvalidCode {
                bit_offset: cur.bits_consumed(),
            });
        }
        let rest = cur.read_bits(n)?;
        n = (1 << n) | rest;
    }
}

impl DecodeKernel for EliasCodec {
    fn decode_batch(
        &self,
        cur: &mut BitCursor,
        out: &mut [u8],
    ) -> Result<usize, CodecError> {
        for slot in out.iter_mut() {
            let v = match self.kind {
                EliasKind::Gamma => decode_gamma_cursor(cur)?,
                EliasKind::Delta => decode_delta_cursor(cur)?,
                EliasKind::Omega => decode_omega_cursor(cur)?,
            };
            if !(1..=256).contains(&v) {
                return Err(CodecError::InvalidCode {
                    bit_offset: cur.bits_consumed(),
                });
            }
            *slot = self.unmap[(v - 1) as usize];
        }
        Ok(out.len())
    }
}

// ---------------------------------------------------------------------------
// Batched kernel path, encode side: each code's prefix and payload are
// fused into a single (value, width) field — a gamma code for n is
// just the integer n in `2·nbits − 1` bits (the high nbits − 1 bits of
// that field are the zero prefix), so one masked insert replaces the
// write_zeros + write_bits pair.  Delta and omega concatenate their
// sub-fields into one push the same way; every fused code for n ≤ 2³²
// is ≤ 43 bits, inside the sink's 57-bit budget.

/// Gamma code of `n` as one (value, width) field.
#[inline]
fn gamma_code(n: u32) -> (u64, u32) {
    let nbits = 32 - n.leading_zeros();
    (n as u64, 2 * nbits - 1)
}

/// Delta code of `n`: gamma(bit-length) ++ low `nbits − 1` payload
/// bits, fused.
#[inline]
fn delta_code(n: u32) -> (u64, u32) {
    let nbits = 32 - n.leading_zeros();
    let (gval, glen) = gamma_code(nbits);
    if nbits == 1 {
        return (gval, glen);
    }
    let payload = (n & ((1 << (nbits - 1)) - 1)) as u64;
    ((gval << (nbits - 1)) | payload, glen + nbits - 1)
}

/// Omega code of `n`: the recursive length groups concatenated
/// front-to-back plus the terminating 0 bit, fused.  At most 5 groups
/// for 32-bit `n`, built on the stack (the scalar path's per-symbol
/// `Vec` is the thing this kills).
#[inline]
fn omega_code(n: u32) -> (u64, u32) {
    let mut groups = [(0u32, 0u32); 5];
    let mut count = 0usize;
    let mut m = n;
    while m > 1 {
        let bits = 32 - m.leading_zeros();
        groups[count] = (m, bits);
        count += 1;
        m = bits - 1;
    }
    let mut acc = 0u64;
    let mut len = 0u32;
    for &(v, bits) in groups[..count].iter().rev() {
        acc = (acc << bits) | v as u64;
        len += bits;
    }
    (acc << 1, len + 1)
}

impl EncodeKernel for EliasCodec {
    fn encode_batch(&self, symbols: &[u8], sink: &mut BitSink) {
        match self.kind {
            EliasKind::Gamma => {
                for &s in symbols {
                    let (v, len) = gamma_code(self.map[s as usize] as u32 + 1);
                    sink.push(v, len);
                }
            }
            EliasKind::Delta => {
                for &s in symbols {
                    let (v, len) = delta_code(self.map[s as usize] as u32 + 1);
                    sink.push(v, len);
                }
            }
            EliasKind::Omega => {
                for &s in symbols {
                    let (v, len) = omega_code(self.map[s as usize] as u32 + 1);
                    sink.push(v, len);
                }
            }
        }
    }
}

impl Codec for EliasCodec {
    fn name(&self) -> String {
        if self.ranked {
            format!("{}-ranked", self.kind.name())
        } else {
            self.kind.name().to_string()
        }
    }

    fn encode_scalar(&self, symbols: &[u8], out: &mut BitWriter) {
        for &s in symbols {
            self.encode_value(self.map[s as usize] as u32 + 1, out);
        }
    }

    fn decode_scalar_into(
        &self,
        reader: &mut BitReader,
        out: &mut [u8],
    ) -> Result<(), CodecError> {
        for slot in out.iter_mut() {
            let v = self.decode_value(reader)?;
            *slot = self.unmap[(v - 1) as usize];
        }
        Ok(())
    }

    fn code_lengths(&self) -> [u32; 256] {
        let mut lengths = [0u32; 256];
        for s in 0..256 {
            lengths[s] = Self::value_length(
                self.kind,
                self.map[s] as u32 + 1,
            );
        }
        lengths
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::testutil;

    #[test]
    fn gamma_known_codes() {
        // Classic table: 1→"1", 2→"010", 3→"011", 4→"00100".
        let mut w = BitWriter::new();
        for n in [1u32, 2, 3, 4] {
            encode_gamma(n, &mut w);
        }
        assert_eq!(w.bit_len(), 1 + 3 + 3 + 5);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for n in [1u32, 2, 3, 4] {
            assert_eq!(decode_gamma(&mut r).unwrap(), n);
        }
    }

    #[test]
    fn delta_known_lengths() {
        // δ(1)=1, δ(2)=4, δ(3)=4, δ(4)=5, δ(8)=8 bits? δ(8): nbits=4,
        // gamma(4)=5 bits + 3 rest = 8.
        for (n, len) in [(1u32, 1u32), (2, 4), (3, 4), (4, 5), (8, 8)] {
            assert_eq!(
                EliasCodec::value_length(EliasKind::Delta, n),
                len,
                "n={n}"
            );
        }
    }

    #[test]
    fn omega_known_codes() {
        // ω(1)="0", ω(2)="100", ω(3)="110", ω(4)="101000".
        for (n, len) in [(1u32, 1u32), (2, 3), (3, 3), (4, 6), (16, 11)] {
            assert_eq!(
                EliasCodec::value_length(EliasKind::Omega, n),
                len,
                "n={n}"
            );
        }
        let mut w = BitWriter::new();
        for n in 1..=300u32 {
            if n <= 256 {
                encode_omega(n, &mut w);
            }
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for n in 1..=256u32 {
            assert_eq!(decode_omega(&mut r).unwrap(), n);
        }
    }

    #[test]
    fn value_lengths_match_encoder_all_kinds() {
        for kind in [EliasKind::Gamma, EliasKind::Delta, EliasKind::Omega] {
            for n in 1..=256u32 {
                let mut w = BitWriter::new();
                match kind {
                    EliasKind::Gamma => encode_gamma(n, &mut w),
                    EliasKind::Delta => encode_delta(n, &mut w),
                    EliasKind::Omega => encode_omega(n, &mut w),
                }
                assert_eq!(
                    w.bit_len(),
                    EliasCodec::value_length(kind, n) as u64,
                    "{kind:?} n={n}"
                );
            }
        }
    }

    #[test]
    fn fused_codes_match_scalar_all_values() {
        // The single-insert (value, width) fields must reproduce the
        // write_zeros/write_bits scalar encoders bit-for-bit.
        for n in 1..=300u32 {
            for kind in [EliasKind::Gamma, EliasKind::Delta, EliasKind::Omega]
            {
                let mut w = BitWriter::new();
                let (v, len) = match kind {
                    EliasKind::Gamma => {
                        encode_gamma(n, &mut w);
                        gamma_code(n)
                    }
                    EliasKind::Delta => {
                        encode_delta(n, &mut w);
                        delta_code(n)
                    }
                    EliasKind::Omega => {
                        encode_omega(n, &mut w);
                        omega_code(n)
                    }
                };
                let mut sink = BitSink::new();
                sink.push(v, len);
                assert_eq!(sink.bit_len(), w.bit_len(), "{kind:?} n={n}");
                assert_eq!(sink.finish(), w.finish(), "{kind:?} n={n}");
            }
        }
    }

    #[test]
    fn all_values_roundtrip_all_kinds() {
        for kind in [EliasKind::Gamma, EliasKind::Delta, EliasKind::Omega] {
            let codec = EliasCodec::new(kind);
            let symbols: Vec<u8> = (0..=255).collect();
            let enc = codec.encode_to_vec(&symbols);
            assert_eq!(
                codec.decode_from_slice(&enc, 256).unwrap(),
                symbols,
                "{kind:?}"
            );
        }
    }

    #[test]
    fn ranked_mapping_bijective() {
        let mut rank = [0u8; 256];
        for i in 0..256 {
            rank[i] = (255 - i) as u8; // reverse order
        }
        let codec = EliasCodec::with_ranking(EliasKind::Gamma, &rank);
        let symbols: Vec<u8> = (0..=255).collect();
        let enc = codec.encode_to_vec(&symbols);
        assert_eq!(codec.decode_from_slice(&enc, 256).unwrap(), symbols);
        // Symbol 255 has rank 0 → shortest code (1 bit).
        assert_eq!(codec.code_lengths()[255], 1);
    }

    #[test]
    fn ranked_shrinks_skewed_data() {
        let mut symbols = vec![200u8; 10_000];
        symbols.extend(std::iter::repeat(17u8).take(100));
        let mut rank = [0u8; 256];
        let mut order: Vec<u8> = (0..=255).collect();
        order.sort_by_key(|&s| if s == 200 { 0 } else if s == 17 { 1 } else { 2 + s as u16 });
        rank.copy_from_slice(&order);
        let plain = EliasCodec::new(EliasKind::Gamma);
        let ranked = EliasCodec::with_ranking(EliasKind::Gamma, &rank);
        assert!(
            ranked.encoded_bits(&symbols) < plain.encoded_bits(&symbols) / 4
        );
    }

    #[test]
    fn truncated_errors() {
        for kind in [EliasKind::Gamma, EliasKind::Delta, EliasKind::Omega] {
            let codec = EliasCodec::new(kind);
            let enc = codec.encode_to_vec(&[255u8; 4]);
            let cut = &enc[..enc.len() - 1];
            assert!(codec.decode_from_slice(cut, 4).is_err(), "{kind:?}");
        }
    }

    #[test]
    fn prop_roundtrip_gamma() {
        testutil::roundtrip_property(&EliasCodec::new(EliasKind::Gamma));
    }

    #[test]
    fn prop_roundtrip_delta() {
        testutil::roundtrip_property(&EliasCodec::new(EliasKind::Delta));
    }

    #[test]
    fn prop_roundtrip_omega() {
        testutil::roundtrip_property(&EliasCodec::new(EliasKind::Omega));
    }
}
