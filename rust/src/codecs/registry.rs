//! Unified codec registry: the one place that knows every codec's
//! name(s), wire tag, table-header layout, and constructors.
//!
//! Historically the frame container, the collective transport, the
//! coordinator and the CLI each re-derived parts of this mapping from
//! a `Tag`/`CodecSpec` enum pair; every new codec meant touching all
//! of them.  Now they all resolve through [`CodecRegistry`]:
//!
//! * `resolve(name, hist)` — fit a codec by name ("qlc", "huffman",
//!   "eg3", …) to a calibration histogram, producing a [`CodecHandle`];
//! * `resolve_wire(tag, header)` — reconstruct a codec from the wire
//!   tag + table header of a QLF1/QLF2 frame;
//! * `known_names()` — the CLI's `--codec` vocabulary.
//!
//! A [`CodecHandle`] owns the boxed codec plus its wire identity
//! (tag + serialized table header, fixed at construction), and hands
//! out streaming [`EncoderSession`]/[`DecoderSession`]s.
//!
//! Wire tags are append-only and shared by QLF1 and QLF2 frames:
//! `0=raw 1=huffman 2=qlc 3=elias-gamma 4=elias-delta 5=elias-omega
//! 6=expgolomb`.

use std::sync::OnceLock;

use super::elias::{EliasCodec, EliasKind};
use super::expgolomb::ExpGolombCodec;
use super::huffman::HuffmanCodec;
use super::kernel::{EncodeJob, LaneJob};
use super::qlc::{self, AreaScheme, QlcCodec};
use super::raw::RawCodec;
use super::session::{DecodeMode, DecoderSession, EncodeMode, EncoderSession};
use super::{Codec, CodecError};
use crate::stats::Histogram;

/// Wire tags (QLF1-compatible; append-only).
pub const TAG_RAW: u8 = 0;
pub const TAG_HUFFMAN: u8 = 1;
pub const TAG_QLC: u8 = 2;
pub const TAG_ELIAS_GAMMA: u8 = 3;
pub const TAG_ELIAS_DELTA: u8 = 4;
pub const TAG_ELIAS_OMEGA: u8 = 5;
pub const TAG_EXPGOLOMB: u8 = 6;

/// Per-chunk table adaptation hooks.  A codec family that can trade a
/// small serialized table *delta* for better per-chunk compressibility
/// (today: QLC, via a rank-permutation re-fit under the frame's area
/// scheme) installs one of these on its [`CodecHandle`]; the QLF2
/// frame writer and reader drive it through the chunk-table flag bit.
pub trait ChunkTables: Send + Sync {
    /// Re-fit the tables to one chunk's measured PMF.  Returns the
    /// serialized delta plus the chunk-local codec **only when** the
    /// payload bits saved by the re-fit more than pay for the delta
    /// bytes — i.e. when the chunk's distribution has drifted past the
    /// break-even threshold; `None` keeps the frame's base tables.
    fn refit(&self, chunk: &[u8]) -> Option<(Vec<u8>, Box<dyn Codec>)>;

    /// Rebuild a chunk-local codec from a serialized delta (decode
    /// side; strict validation, `Err` on any malformed delta).
    fn from_delta(&self, delta: &[u8]) -> Result<Box<dyn Codec>, CodecError>;
}

/// [`ChunkTables`] for the QLC family: the delta is a bare 256-byte
/// rank order (`qlc::serde::rank_to_bytes`); the area scheme is the
/// frame's and never changes per chunk, so chunk codecs share the
/// base codec's length structure.
struct QlcChunkTables {
    scheme: AreaScheme,
    /// Base codec's per-symbol code lengths (drift cost baseline).
    base_lengths: [u32; 256],
}

impl ChunkTables for QlcChunkTables {
    fn refit(&self, chunk: &[u8]) -> Option<(Vec<u8>, Box<dyn Codec>)> {
        if chunk.is_empty() {
            return None;
        }
        let hist = Histogram::from_symbols(chunk);
        let base_bits: u64 = (0..256)
            .map(|s| hist.counts[s] * self.base_lengths[s] as u64)
            .sum();
        let rank_order = hist.pmf().rank_order();
        let rank_lengths = self.scheme.rank_lengths();
        let refit_bits: u64 = (0..256)
            .map(|r| {
                hist.counts[rank_order[r] as usize] * rank_lengths[r] as u64
            })
            .sum();
        // Break-even: the delta ships as `len u16 | 256 rank bytes`.
        // Emitting it only when the re-fit saves strictly more payload
        // bits guarantees an adaptive frame is never larger than the
        // fixed-table frame (modulo one byte of chunk padding).
        let delta_cost_bits = 8 * (2 + 256) as u64;
        if base_bits.saturating_sub(refit_bits) <= delta_cost_bits {
            return None;
        }
        let codec: Box<dyn Codec> = Box::new(QlcCodec::from_rank_order(
            self.scheme.clone(),
            &rank_order,
            "qlc-chunk",
        ));
        Some((qlc::serde::rank_to_bytes(&rank_order), codec))
    }

    fn from_delta(&self, delta: &[u8]) -> Result<Box<dyn Codec>, CodecError> {
        let rank = qlc::serde::rank_from_bytes(delta)
            .map_err(CodecError::BadHeader)?;
        Ok(Box::new(QlcCodec::from_rank_order(
            self.scheme.clone(),
            &rank,
            "qlc-chunk",
        )))
    }
}

/// A fully-constructed codec plus its wire identity.  This is what
/// every layer above `codecs/` passes around: the frame writer asks it
/// for `wire_tag()`/`wire_header()`, the transport and coordinator ask
/// it for sessions, nobody matches on codec kinds anymore.
pub struct CodecHandle {
    codec: Box<dyn Codec>,
    name: String,
    tag: u8,
    header: Vec<u8>,
    /// Per-chunk adaptation hooks, when the family supports them.
    chunk_tables: Option<Box<dyn ChunkTables>>,
}

impl CodecHandle {
    fn new(codec: Box<dyn Codec>, name: String, tag: u8, header: Vec<u8>) -> Self {
        CodecHandle { codec, name, tag, header, chunk_tables: None }
    }

    fn with_chunk_tables(mut self, tables: Box<dyn ChunkTables>) -> Self {
        self.chunk_tables = Some(tables);
        self
    }

    /// Per-chunk table adaptation hooks (QLF2 `--adaptive-chunks`);
    /// `None` for families whose tables cannot be re-fit per chunk.
    pub fn chunk_tables(&self) -> Option<&dyn ChunkTables> {
        self.chunk_tables.as_deref()
    }

    /// The resolved codec name (e.g. "qlc-t1", "eg3").
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The codec itself.
    pub fn codec(&self) -> &dyn Codec {
        self.codec.as_ref()
    }

    /// Wire tag written into frame byte 4.
    pub fn wire_tag(&self) -> u8 {
        self.tag
    }

    /// Serialized table header (Huffman lengths, QLC scheme + rank
    /// order, EG order; empty for raw/elias).  Written once per frame,
    /// regardless of chunk count.
    pub fn wire_header(&self) -> &[u8] {
        &self.header
    }

    /// Start a streaming encode session (batched kernel path).
    pub fn encoder(&self) -> EncoderSession<'_> {
        EncoderSession::new(self.codec())
    }

    /// Start a streaming encode session on an explicit encode path
    /// (the CLI's `--encode=batched|scalar|lanes`).
    pub fn encoder_with(&self, mode: EncodeMode) -> EncoderSession<'_> {
        EncoderSession::with_mode(self.codec(), mode)
    }

    /// Encode several independent chunks through the lane-interleaved
    /// engine — the [`EncodeMode::Lanes`] entry point, mirror of
    /// [`decode_chunks_lanes`](Self::decode_chunks_lanes): up to
    /// [`MAX_LANES`](super::kernel::MAX_LANES) chunk sinks step in
    /// lockstep through this codec's tables.  Each job's payload is
    /// appended to its own `out`, bit-for-bit identical to encoding
    /// the chunk through [`CodecHandle::encoder`].
    pub fn encode_chunks_lanes(&self, jobs: &mut [EncodeJob<'_, '_>]) {
        self.encoder_with(EncodeMode::Lanes).encode_chunk_group(jobs)
    }

    /// Start a streaming decode session (batched kernel path).
    pub fn decoder(&self) -> DecoderSession<'_> {
        DecoderSession::new(self.codec())
    }

    /// Start a streaming decode session on an explicit decode path
    /// (the CLI's `--decode=batched|scalar|lanes`).
    pub fn decoder_with(&self, mode: DecodeMode) -> DecoderSession<'_> {
        DecoderSession::with_mode(self.codec(), mode)
    }

    /// Decode several independent chunk payloads through the
    /// lane-interleaved engine — the [`DecodeMode::Lanes`] entry
    /// point: up to [`MAX_LANES`](super::kernel::MAX_LANES) chunk
    /// cursors step in lockstep through this codec's tables, so their
    /// prefix lookups overlap in the pipeline.  Every job decodes
    /// exactly `out.len()` symbols; results are byte-identical to
    /// decoding each chunk through [`CodecHandle::decoder`].
    pub fn decode_chunks_lanes(
        &self,
        jobs: &mut [LaneJob<'_, '_>],
    ) -> Result<(), CodecError> {
        self.decoder_with(DecodeMode::Lanes).decode_chunk_group(jobs)
    }
}

impl std::fmt::Debug for CodecHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CodecHandle")
            .field("name", &self.name)
            .field("tag", &self.tag)
            .field("header_len", &self.header.len())
            .finish()
    }
}

/// One codec family: how names map to constructors and how wire
/// headers map back to codecs.
struct Family {
    /// Canonical family label (diagnostics only).
    family: &'static str,
    tag: u8,
    /// Names advertised to the CLI / docs.  `matches` may accept more
    /// (e.g. every "egK" for the expgolomb family).
    names: &'static [&'static str],
    matches: fn(&str) -> bool,
    build: fn(&str, &Histogram) -> Result<CodecHandle, String>,
    from_header: fn(&[u8]) -> Result<CodecHandle, CodecError>,
}

/// The process-wide codec registry.
pub struct CodecRegistry {
    families: Vec<Family>,
}

impl CodecRegistry {
    /// The global registry (built once, immutable).
    pub fn global() -> &'static CodecRegistry {
        static REGISTRY: OnceLock<CodecRegistry> = OnceLock::new();
        REGISTRY.get_or_init(CodecRegistry::builtin)
    }

    fn builtin() -> CodecRegistry {
        CodecRegistry {
            families: vec![
                Family {
                    family: "raw",
                    tag: TAG_RAW,
                    names: &["raw"],
                    matches: |n| n == "raw",
                    build: |_, _| Ok(handle_raw()),
                    from_header: |header| {
                        if !header.is_empty() {
                            return Err(CodecError::BadHeader(
                                "raw codec takes no header".into(),
                            ));
                        }
                        Ok(handle_raw())
                    },
                },
                Family {
                    family: "huffman",
                    tag: TAG_HUFFMAN,
                    names: &["huffman"],
                    matches: |n| n == "huffman",
                    build: |_, hist| {
                        Ok(handle_huffman(HuffmanCodec::from_histogram(hist)))
                    },
                    from_header: |header| {
                        if header.len() != 256 {
                            return Err(CodecError::BadHeader(format!(
                                "huffman header {} bytes",
                                header.len()
                            )));
                        }
                        let mut lengths = [0u32; 256];
                        for (l, &b) in lengths.iter_mut().zip(header) {
                            *l = b as u32;
                        }
                        Ok(handle_huffman(HuffmanCodec::from_lengths(
                            &lengths,
                        )?))
                    },
                },
                Family {
                    family: "qlc",
                    tag: TAG_QLC,
                    names: &["qlc", "qlc-t1", "qlc-t2"],
                    matches: |n| matches!(n, "qlc" | "qlc-t1" | "qlc-t2"),
                    build: |name, hist| {
                        let pmf = hist.pmf();
                        let codec = match name {
                            "qlc" => {
                                let scheme =
                                    qlc::optimize_scheme(&pmf.sorted_desc());
                                QlcCodec::from_pmf(scheme, &pmf)
                            }
                            "qlc-t1" => QlcCodec::from_pmf(
                                qlc::AreaScheme::table1(),
                                &pmf,
                            ),
                            "qlc-t2" => QlcCodec::from_pmf(
                                qlc::AreaScheme::table2(),
                                &pmf,
                            ),
                            other => {
                                return Err(format!("unknown qlc variant '{other}'"))
                            }
                        };
                        Ok(handle_qlc(codec))
                    },
                    from_header: |header| {
                        let codec = qlc::serde::from_bytes(header, "qlc")
                            .map_err(CodecError::BadHeader)?;
                        Ok(handle_qlc(codec))
                    },
                },
                elias_family("elias-gamma", TAG_ELIAS_GAMMA, EliasKind::Gamma),
                elias_family("elias-delta", TAG_ELIAS_DELTA, EliasKind::Delta),
                elias_family("elias-omega", TAG_ELIAS_OMEGA, EliasKind::Omega),
                Family {
                    family: "expgolomb",
                    tag: TAG_EXPGOLOMB,
                    names: &["eg0", "eg3"],
                    matches: |n| parse_eg_order(n).is_some(),
                    build: |name, _| {
                        let k = parse_eg_order(name)
                            .ok_or_else(|| format!("bad EG order in '{name}'"))?;
                        Ok(handle_eg(k))
                    },
                    from_header: |header| {
                        if header.len() != 1 || header[0] > 8 {
                            return Err(CodecError::BadHeader(
                                "bad EG header".into(),
                            ));
                        }
                        Ok(handle_eg(header[0] as u32))
                    },
                },
            ],
        }
    }

    /// Fit a codec by name to a calibration histogram.  Names: raw,
    /// huffman, qlc (optimized), qlc-t1, qlc-t2, elias-gamma,
    /// elias-delta, elias-omega, eg0…eg8.
    pub fn resolve(
        &self,
        name: &str,
        hist: &Histogram,
    ) -> Result<CodecHandle, String> {
        for f in &self.families {
            if (f.matches)(name) {
                crate::obs::global()
                    .counter(&crate::obs::label(
                        "codec_resolve_total",
                        &[("family", f.family)],
                    ))
                    .inc();
                return (f.build)(name, hist);
            }
        }
        crate::obs::global().counter("codec_resolve_unknown_total").inc();
        Err(format!("unknown codec '{name}'"))
    }

    /// Reconstruct a codec from a frame's wire tag + table header.
    pub fn resolve_wire(
        &self,
        tag: u8,
        header: &[u8],
    ) -> Result<CodecHandle, CodecError> {
        for f in &self.families {
            if f.tag == tag {
                crate::obs::global()
                    .counter(&crate::obs::label(
                        "codec_resolve_wire_total",
                        &[("family", f.family)],
                    ))
                    .inc();
                return (f.from_header)(header);
            }
        }
        crate::obs::global().counter("codec_resolve_unknown_total").inc();
        Err(CodecError::BadHeader(format!("unknown codec tag {tag}")))
    }

    /// All codec names usable with [`CodecRegistry::resolve`] (the
    /// advertised subset; `matches` may accept more, e.g. any `egK`).
    pub fn known_names(&self) -> Vec<&'static str> {
        self.families.iter().flat_map(|f| f.names.iter().copied()).collect()
    }

    /// Family labels and wire tags (diagnostics, `--help` output).
    pub fn families(&self) -> Vec<(&'static str, u8)> {
        self.families.iter().map(|f| (f.family, f.tag)).collect()
    }
}

fn parse_eg_order(name: &str) -> Option<u32> {
    let k: u32 = name.strip_prefix("eg")?.parse().ok()?;
    (k <= 8).then_some(k)
}

fn handle_raw() -> CodecHandle {
    CodecHandle::new(Box::new(RawCodec), "raw".into(), TAG_RAW, Vec::new())
}

fn handle_huffman(codec: HuffmanCodec) -> CodecHandle {
    let header = codec.code_lengths().iter().map(|&l| l as u8).collect();
    CodecHandle::new(Box::new(codec), "huffman".into(), TAG_HUFFMAN, header)
}

fn handle_qlc(codec: QlcCodec) -> CodecHandle {
    let header = qlc::serde::to_bytes(&codec);
    let name = codec.name();
    let tables = QlcChunkTables {
        scheme: codec.scheme().clone(),
        base_lengths: codec.code_lengths(),
    };
    CodecHandle::new(Box::new(codec), name, TAG_QLC, header)
        .with_chunk_tables(Box::new(tables))
}

fn handle_elias(kind: EliasKind, tag: u8) -> CodecHandle {
    CodecHandle::new(
        Box::new(EliasCodec::new(kind)),
        kind.name().into(),
        tag,
        Vec::new(),
    )
}

fn handle_eg(k: u32) -> CodecHandle {
    CodecHandle::new(
        Box::new(ExpGolombCodec::new(k)),
        format!("eg{k}"),
        TAG_EXPGOLOMB,
        vec![k as u8],
    )
}

fn elias_family(name: &'static str, tag: u8, kind: EliasKind) -> Family {
    // One family per kind so each keeps its QLF1 wire tag.
    let (matches, build, from_header): (
        fn(&str) -> bool,
        fn(&str, &Histogram) -> Result<CodecHandle, String>,
        fn(&[u8]) -> Result<CodecHandle, CodecError>,
    ) = match kind {
        EliasKind::Gamma => (
            |n| n == "elias-gamma",
            |_, _| Ok(handle_elias(EliasKind::Gamma, TAG_ELIAS_GAMMA)),
            |h| elias_from_header(EliasKind::Gamma, TAG_ELIAS_GAMMA, h),
        ),
        EliasKind::Delta => (
            |n| n == "elias-delta",
            |_, _| Ok(handle_elias(EliasKind::Delta, TAG_ELIAS_DELTA)),
            |h| elias_from_header(EliasKind::Delta, TAG_ELIAS_DELTA, h),
        ),
        EliasKind::Omega => (
            |n| n == "elias-omega",
            |_, _| Ok(handle_elias(EliasKind::Omega, TAG_ELIAS_OMEGA)),
            |h| elias_from_header(EliasKind::Omega, TAG_ELIAS_OMEGA, h),
        ),
    };
    Family {
        family: name,
        tag,
        names: match kind {
            EliasKind::Gamma => &["elias-gamma"],
            EliasKind::Delta => &["elias-delta"],
            EliasKind::Omega => &["elias-omega"],
        },
        matches,
        build,
        from_header,
    }
}

fn elias_from_header(
    kind: EliasKind,
    tag: u8,
    header: &[u8],
) -> Result<CodecHandle, CodecError> {
    if !header.is_empty() {
        return Err(CodecError::BadHeader(format!(
            "{} codec takes no header",
            kind.name()
        )));
    }
    Ok(handle_elias(kind, tag))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{AliasTable, Rng};

    fn skewed_hist(seed: u64) -> Histogram {
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = (-0.025 * i as f64).exp();
        }
        let symbols =
            AliasTable::new(&p).sample_many(&mut Rng::new(seed), 20_000);
        Histogram::from_symbols(&symbols)
    }

    #[test]
    fn every_known_name_resolves_and_roundtrips() {
        let hist = skewed_hist(1);
        let reg = CodecRegistry::global();
        let symbols: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        for name in reg.known_names() {
            let handle = reg.resolve(name, &hist).unwrap();
            let enc = handle.codec().encode_to_vec(&symbols);
            let dec =
                handle.codec().decode_from_slice(&enc, symbols.len()).unwrap();
            assert_eq!(dec, symbols, "{name}");
        }
    }

    #[test]
    fn wire_roundtrip_reconstructs_equivalent_codec() {
        // resolve → serialize wire identity → resolve_wire must yield a
        // codec that decodes the original's output, for every family.
        let hist = skewed_hist(2);
        let reg = CodecRegistry::global();
        let symbols: Vec<u8> =
            AliasTable::new(&hist.pmf().p).sample_many(&mut Rng::new(3), 8192);
        for name in reg.known_names() {
            let handle = reg.resolve(name, &hist).unwrap();
            let rebuilt = reg
                .resolve_wire(handle.wire_tag(), handle.wire_header())
                .unwrap();
            let enc = handle.codec().encode_to_vec(&symbols);
            assert_eq!(
                rebuilt.codec().decode_from_slice(&enc, symbols.len()).unwrap(),
                symbols,
                "{name}"
            );
        }
    }

    #[test]
    fn wire_tags_are_stable_qlf1_values() {
        let hist = skewed_hist(4);
        let reg = CodecRegistry::global();
        for (name, tag) in [
            ("raw", TAG_RAW),
            ("huffman", TAG_HUFFMAN),
            ("qlc", TAG_QLC),
            ("qlc-t1", TAG_QLC),
            ("elias-gamma", TAG_ELIAS_GAMMA),
            ("elias-delta", TAG_ELIAS_DELTA),
            ("elias-omega", TAG_ELIAS_OMEGA),
            ("eg0", TAG_EXPGOLOMB),
            ("eg8", TAG_EXPGOLOMB),
        ] {
            assert_eq!(
                reg.resolve(name, &hist).unwrap().wire_tag(),
                tag,
                "{name}"
            );
        }
    }

    #[test]
    fn unknown_names_and_tags_rejected() {
        let hist = skewed_hist(5);
        let reg = CodecRegistry::global();
        assert!(reg.resolve("zstd", &hist).is_err());
        assert!(reg.resolve("eg99", &hist).is_err());
        assert!(reg.resolve("", &hist).is_err());
        assert!(matches!(
            reg.resolve_wire(200, &[]),
            Err(CodecError::BadHeader(_))
        ));
    }

    #[test]
    fn corrupt_headers_rejected_per_family() {
        let reg = CodecRegistry::global();
        // Huffman: wrong size and Kraft-violating lengths.
        assert!(reg.resolve_wire(TAG_HUFFMAN, &[8u8; 17]).is_err());
        assert!(reg.resolve_wire(TAG_HUFFMAN, &[1u8; 256]).is_err());
        // QLC: truncated header.
        assert!(reg.resolve_wire(TAG_QLC, &[4u8, 1]).is_err());
        // EG: out-of-range order, wrong length.
        assert!(reg.resolve_wire(TAG_EXPGOLOMB, &[9]).is_err());
        assert!(reg.resolve_wire(TAG_EXPGOLOMB, &[]).is_err());
        // Raw/elias: unexpected header bytes.
        assert!(reg.resolve_wire(TAG_RAW, &[0]).is_err());
        assert!(reg.resolve_wire(TAG_ELIAS_GAMMA, &[0]).is_err());
    }

    #[test]
    fn chunk_tables_only_on_qlc_and_roundtrip_via_delta() {
        let hist = skewed_hist(7);
        let reg = CodecRegistry::global();
        for name in ["raw", "huffman", "elias-gamma", "eg3"] {
            let h = reg.resolve(name, &hist).unwrap();
            assert!(h.chunk_tables().is_none(), "{name}");
        }
        let h = reg.resolve("qlc", &hist).unwrap();
        let tables = h.chunk_tables().expect("qlc supports per-chunk tables");

        // A chunk drawn from a *reversed* distribution drifts hard:
        // refit must fire, and the delta must rebuild a codec that
        // decodes the chunk-local encoding.
        let drifted: Vec<u8> = AliasTable::new(&hist.pmf().p)
            .sample_many(&mut Rng::new(9), 32 * 1024)
            .into_iter()
            .map(|s| 255 - s)
            .collect();
        let (delta, codec) =
            tables.refit(&drifted).expect("drifted chunk must refit");
        let enc = codec.encode_to_vec(&drifted);
        let rebuilt = tables.from_delta(&delta).unwrap();
        assert_eq!(
            rebuilt.decode_from_slice(&enc, drifted.len()).unwrap(),
            drifted
        );

        // A chunk drawn from the calibration PMF itself saves nothing:
        // no refit.
        let stationary =
            AliasTable::new(&hist.pmf().p).sample_many(&mut Rng::new(10), 32 * 1024);
        assert!(tables.refit(&stationary).is_none());
        // Empty chunks never refit; malformed deltas are rejected.
        assert!(tables.refit(&[]).is_none());
        assert!(tables.from_delta(&delta[..200]).is_err());
        let mut dup = delta.clone();
        dup[0] = dup[1];
        assert!(tables.from_delta(&dup).is_err());
    }

    #[test]
    fn handles_decode_lane_groups() {
        // Every family's handle must decode chunk groups through the
        // lane entry point bit-identically to its plain decoder.
        let hist = skewed_hist(8);
        let reg = CodecRegistry::global();
        let symbols =
            AliasTable::new(&hist.pmf().p).sample_many(&mut Rng::new(4), 30_000);
        for name in reg.known_names() {
            let handle = reg.resolve(name, &hist).unwrap();
            let chunk = 4_100usize;
            let mut enc = handle.encoder();
            let payloads: Vec<Vec<u8>> = symbols
                .chunks(chunk)
                .map(|c| enc.encode_chunk_to_vec(c))
                .collect();
            let mut out = vec![0u8; symbols.len()];
            let mut jobs: Vec<LaneJob> = payloads
                .iter()
                .zip(out.chunks_mut(chunk))
                .map(|(p, o)| LaneJob { payload: p, out: o })
                .collect();
            handle.decode_chunks_lanes(&mut jobs).unwrap();
            assert_eq!(out, symbols, "{name}");
        }
    }

    #[test]
    fn handles_encode_lane_groups() {
        // Mirror of `handles_decode_lane_groups`: every family's
        // handle must encode chunk groups through the lane entry point
        // bit-identically to its plain (batched) and scalar encoders.
        let hist = skewed_hist(11);
        let reg = CodecRegistry::global();
        let symbols =
            AliasTable::new(&hist.pmf().p).sample_many(&mut Rng::new(5), 30_000);
        for name in reg.known_names() {
            let handle = reg.resolve(name, &hist).unwrap();
            let chunk = 4_100usize;
            let mut scalar = handle.encoder_with(EncodeMode::Scalar);
            let expected: Vec<Vec<u8>> = symbols
                .chunks(chunk)
                .map(|c| scalar.encode_chunk_to_vec(c))
                .collect();
            let mut outs: Vec<Vec<u8>> = vec![Vec::new(); expected.len()];
            {
                let mut jobs: Vec<EncodeJob> = symbols
                    .chunks(chunk)
                    .zip(outs.iter_mut())
                    .map(|(c, o)| EncodeJob { symbols: c, out: o })
                    .collect();
                handle.encode_chunks_lanes(&mut jobs);
            }
            assert_eq!(outs, expected, "{name}");
        }
    }

    #[test]
    fn handles_vend_sessions() {
        let hist = skewed_hist(6);
        let handle =
            CodecRegistry::global().resolve("huffman", &hist).unwrap();
        let symbols: Vec<u8> = (0..200u8).collect();
        let payload = handle.encoder().encode_chunk_to_vec(&symbols);
        let out = handle
            .decoder()
            .decode_chunk_to_vec(&payload, symbols.len())
            .unwrap();
        assert_eq!(out, symbols);
    }
}
