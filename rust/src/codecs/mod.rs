//! Lossless entropy codecs over the 256-symbol e4m3 alphabet.
//!
//! * [`raw`] — identity baseline (8 bits/symbol).
//! * [`elias`] — Elias gamma/delta/omega universal codes (paper §1).
//! * [`expgolomb`] — order-k Exponential-Golomb (paper §1).
//! * [`huffman`] — canonical Huffman, the paper's optimal baseline.
//! * [`qlc`] — Quad Length Codes, the paper's contribution.
//!
//! # The codec API
//!
//! Every codec implements [`Codec`].  The decode primitive is the
//! batched kernel ([`kernel::DecodeKernel::decode_batch`]): a 64-bit
//! buffered [`kernel::BitCursor`] is refilled once and the codec
//! resolves as many whole codes as the staging word holds — table
//! lookups for QLC/Huffman, leading-zero counts for Elias/Exp-Golomb.
//! [`Codec::decode_into`] routes through it and fills a
//! caller-provided `&mut [u8]` slice, so bulk decoders write straight
//! into their destination (a frame chunk, a transport buffer, a tensor
//! shard) with no per-symbol `Vec` pushes and no intermediate copies.
//! `decode`/`decode_from_slice` remain as thin convenience wrappers,
//! and [`Codec::decode_scalar_into`] keeps the one-symbol-per-step
//! reference path alive for equivalence tests and the
//! batched-vs-scalar bench.  When a caller holds *several* independent
//! chunks, the lane engine ([`LaneDecoder`],
//! [`DecodeKernel::decode_lanes`]) steps up to [`MAX_LANES`] cursors
//! in lockstep so the table lookups of different chunks overlap in the
//! pipeline — the multi-cursor path behind `--decode=lanes`.
//!
//! Encode mirrors that structure: the primitive is
//! [`kernel::EncodeKernel::encode_batch`], which shift-ors (code,
//! length) LUT entries into a [`kernel::BitSink`] staging word and
//! spills whole words — the single-stage encoder, no per-bit loop.
//! [`Codec::encode_scalar`] keeps the one-code-per-step
//! `BitWriter` path alive as the bit-exact ground truth (and the
//! `--encode=scalar` CLI path), and [`LaneEncoder`] /
//! [`EncodeKernel::encode_lanes`] interleave independent chunk
//! encodes behind `--encode=lanes`.  Whichever path runs, the bytes
//! are identical — the encode-equivalence proptests pin that.
//!
//! Block-oriented streaming goes through *sessions*:
//! [`EncoderSession`] / [`DecoderSession`] (constructed via
//! [`Codec::encoder`] / [`Codec::decoder`] or from any `&dyn Codec`)
//! hold reusable scratch state and encode/decode one byte-aligned chunk
//! at a time.  Independent chunks are what let the frame layer
//! ([`frame`], format QLF2) and the collective transport decode in
//! parallel — the paper's whole pitch is decode *speed*, and chunking
//! is how the software path gets it.
//!
//! Codec lookup is centralized in [`registry::CodecRegistry`]
//! (name ↔ wire tag ↔ constructor-from-header); [`frame`] adds the
//! self-describing container (QLF1 read, QLF2 read/write) used by the
//! CLI, the coordinator and the collective transport.

pub mod adaptive;
pub mod elias;
pub mod expgolomb;
pub mod frame;
pub mod huffman;
pub mod kernel;
pub mod qlc;
pub mod raw;
pub mod registry;
mod session;
#[cfg(feature = "zstd")]
pub mod zstd_baseline;

pub use kernel::{
    BitCursor, BitSink, DecodeKernel, EncodeJob, EncodeKernel, EncodeLane,
    Lane, LaneDecoder, LaneEncoder, LaneJob, MixedLaneJob, MAX_LANES,
};
pub use registry::{CodecHandle, CodecRegistry};
pub use session::{
    chunk_spans, DecodeMode, DecoderSession, EncodeMode, EncoderSession,
    DEFAULT_CHUNK_SYMBOLS,
};

use crate::bitstream::{BitReader, BitWriter};

/// Errors surfaced while decoding a (possibly corrupt) stream.
#[derive(Debug, PartialEq, Eq)]
pub enum CodecError {
    /// Bit stream ended before `n` symbols were decoded.
    UnexpectedEof,
    /// A code pattern that no symbol maps to.
    InvalidCode { bit_offset: u64 },
    /// Malformed or unsupported frame/table header.
    BadHeader(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::UnexpectedEof => write!(f, "unexpected end of stream"),
            CodecError::InvalidCode { bit_offset } => {
                write!(f, "invalid code at bit {bit_offset}")
            }
            CodecError::BadHeader(msg) => write!(f, "bad header: {msg}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// A lossless symbol codec. Implementations must satisfy, for all
/// symbol slices `s`: `decode(encode(s), s.len()) == s` (the roundtrip
/// property every codec's proptest asserts),
/// `decode_batch` ≡ `decode_scalar_into` symbol-for-symbol, and
/// `encode_batch` ≡ `encode_scalar` bit-for-bit (both asserted by the
/// kernel equivalence proptests).
pub trait Codec: Send + Sync + DecodeKernel + EncodeKernel {
    /// Short identifier, e.g. "huffman", "qlc-t1".
    fn name(&self) -> String;

    /// Scalar reference encode: append the codes for `symbols` to
    /// `out`, one `write_bits` call per field.  This is the pre-kernel
    /// behaviour, kept as the bit-exact ground truth
    /// [`EncodeKernel::encode_batch`] is checked against (and as the
    /// `--encode=scalar` CLI path).
    fn encode_scalar(&self, symbols: &[u8], out: &mut BitWriter);

    /// Scalar reference decode: exactly `out.len()` symbols, one
    /// symbol per step through `reader`.  This is the pre-kernel
    /// behaviour, kept as the ground truth the batched kernel is
    /// checked against (and as the `--decode=scalar` CLI path).
    fn decode_scalar_into(
        &self,
        reader: &mut BitReader,
        out: &mut [u8],
    ) -> Result<(), CodecError>;

    /// Code length in bits for each of the 256 symbols.
    fn code_lengths(&self) -> [u32; 256];

    /// Decode exactly `out.len()` symbols from `cur` into `out`.
    ///
    /// This is the decode primitive: it routes through the batched
    /// [`DecodeKernel`], filling the slice directly (no `Vec` growth
    /// on the hot path).  On error the contents of `out` are
    /// unspecified.
    fn decode_into(
        &self,
        cur: &mut BitCursor,
        out: &mut [u8],
    ) -> Result<(), CodecError> {
        let n = self.decode_batch(cur, out)?;
        debug_assert_eq!(n, out.len());
        Ok(())
    }

    /// Convenience: decode `n` symbols from `cur`, appending to a
    /// `Vec`.  On error the vector is restored to its original length.
    fn decode(
        &self,
        cur: &mut BitCursor,
        n: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), CodecError> {
        let start = out.len();
        out.resize(start + n, 0);
        match self.decode_into(cur, &mut out[start..]) {
            Ok(()) => Ok(()),
            Err(e) => {
                out.truncate(start);
                Err(e)
            }
        }
    }

    /// Convenience: encode to a fresh byte buffer (batched kernel
    /// path — bit-identical to the scalar path by contract).
    fn encode_to_vec(&self, symbols: &[u8]) -> Vec<u8> {
        let mut sink = BitSink::with_capacity(symbols.len());
        self.encode_batch(symbols, &mut sink);
        sink.finish()
    }

    /// Convenience: decode `n` symbols from a byte buffer (batched
    /// kernel path).
    fn decode_from_slice(
        &self,
        data: &[u8],
        n: usize,
    ) -> Result<Vec<u8>, CodecError> {
        let mut cur = BitCursor::new(data);
        let mut out = vec![0u8; n];
        self.decode_into(&mut cur, &mut out)?;
        Ok(out)
    }

    /// Exact encoded size in bits for `symbols` (from code lengths).
    fn encoded_bits(&self, symbols: &[u8]) -> u64 {
        let lengths = self.code_lengths();
        symbols.iter().map(|&s| lengths[s as usize] as u64).sum()
    }

    /// Start a streaming encode session with reusable scratch state.
    fn encoder(&self) -> EncoderSession<'_>
    where
        Self: Sized,
    {
        EncoderSession::new(self)
    }

    /// Start a streaming decode session.
    fn decoder(&self) -> DecoderSession<'_>
    where
        Self: Sized,
    {
        DecoderSession::new(self)
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared roundtrip property used by every codec's test module.
    use super::*;
    use crate::util::prop;

    pub fn roundtrip_property(codec: &dyn Codec) {
        prop::check(
            &format!("{} roundtrip", codec.name()),
            prop::Config { cases: 96, ..Default::default() },
            |rng, size| {
                let symbols = prop::arb_bytes(rng, size);
                let encoded = codec.encode_to_vec(&symbols);
                let decoded = codec
                    .decode_from_slice(&encoded, symbols.len())
                    .map_err(|e| e.to_string())?;
                if decoded != symbols {
                    return Err(format!(
                        "roundtrip mismatch (len {})",
                        symbols.len()
                    ));
                }
                // The scalar reference path must agree with the kernel.
                let mut scalar = vec![0u8; symbols.len()];
                let mut rdr = crate::bitstream::BitReader::new(&encoded);
                codec
                    .decode_scalar_into(&mut rdr, &mut scalar)
                    .map_err(|e| format!("scalar: {e}"))?;
                if scalar != symbols {
                    return Err("scalar decode mismatch".into());
                }
                // The scalar encoder must produce the same bytes the
                // batched encode_to_vec path did.
                let mut w = BitWriter::with_capacity(symbols.len());
                codec.encode_scalar(&symbols, &mut w);
                if w.finish() != encoded {
                    return Err("batched encode != scalar encode".into());
                }
                // encoded_bits must match the writer exactly.
                let bits = codec.encoded_bits(&symbols);
                if (bits + 7) / 8 != encoded.len() as u64 {
                    return Err(format!(
                        "encoded_bits {} inconsistent with buffer {}",
                        bits,
                        encoded.len()
                    ));
                }
                // Session chunking must agree with single-shot output.
                let mut enc = EncoderSession::new(codec);
                let mut chunked = Vec::new();
                let mut boundaries = Vec::new();
                for chunk in symbols.chunks(97.max(symbols.len() / 3).max(1)) {
                    enc.encode_chunk(chunk, &mut chunked);
                    boundaries.push((chunk.len(), chunked.len()));
                }
                let mut dec = DecoderSession::new(codec);
                let mut restored = vec![0u8; symbols.len()];
                let mut sym_off = 0usize;
                let mut byte_off = 0usize;
                for (n_sym, byte_end) in boundaries {
                    dec.decode_chunk(
                        &chunked[byte_off..byte_end],
                        &mut restored[sym_off..sym_off + n_sym],
                    )
                    .map_err(|e| e.to_string())?;
                    sym_off += n_sym;
                    byte_off = byte_end;
                }
                if restored != symbols {
                    return Err("session chunk roundtrip mismatch".into());
                }
                Ok(())
            },
        );
    }
}
