//! Lossless entropy codecs over the 256-symbol e4m3 alphabet.
//!
//! * [`raw`] — identity baseline (8 bits/symbol).
//! * [`elias`] — Elias gamma/delta/omega universal codes (paper §1).
//! * [`expgolomb`] — order-k Exponential-Golomb (paper §1).
//! * [`huffman`] — canonical Huffman, the paper's optimal baseline.
//! * [`qlc`] — Quad Length Codes, the paper's contribution.
//!
//! Every codec implements [`Codec`]: payload-level encode/decode over a
//! shared [`BitWriter`]/[`BitReader`], plus per-symbol code lengths for
//! analytic compressibility (the paper's tables are expectations over
//! PMFs, not file sizes).  [`frame`] adds a self-describing container
//! (codec id + tables + symbol count) for the CLI and the collective
//! transport.

pub mod adaptive;
pub mod elias;
pub mod expgolomb;
pub mod frame;
pub mod huffman;
pub mod qlc;
pub mod raw;
pub mod zstd_baseline;

use crate::bitstream::{BitReader, BitWriter};

/// Errors surfaced while decoding a (possibly corrupt) stream.
#[derive(Debug, PartialEq, Eq)]
pub enum CodecError {
    /// Bit stream ended before `n` symbols were decoded.
    UnexpectedEof,
    /// A code pattern that no symbol maps to.
    InvalidCode { bit_offset: u64 },
    /// Malformed or unsupported frame/table header.
    BadHeader(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::UnexpectedEof => write!(f, "unexpected end of stream"),
            CodecError::InvalidCode { bit_offset } => {
                write!(f, "invalid code at bit {bit_offset}")
            }
            CodecError::BadHeader(msg) => write!(f, "bad header: {msg}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// A lossless symbol codec. Implementations must satisfy, for all
/// symbol slices `s`: `decode(encode(s), s.len()) == s` (the roundtrip
/// property every codec's proptest asserts).
pub trait Codec: Send + Sync {
    /// Short identifier, e.g. "huffman", "qlc-t1".
    fn name(&self) -> String;

    /// Append the codes for `symbols` to `out`.
    fn encode(&self, symbols: &[u8], out: &mut BitWriter);

    /// Decode exactly `n` symbols from `reader` into `out`.
    fn decode(
        &self,
        reader: &mut BitReader,
        n: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), CodecError>;

    /// Code length in bits for each of the 256 symbols.
    fn code_lengths(&self) -> [u32; 256];

    /// Convenience: encode to a fresh byte buffer.
    fn encode_to_vec(&self, symbols: &[u8]) -> Vec<u8> {
        let mut w = BitWriter::with_capacity(symbols.len());
        self.encode(symbols, &mut w);
        w.finish()
    }

    /// Convenience: decode `n` symbols from a byte buffer.
    fn decode_from_slice(
        &self,
        data: &[u8],
        n: usize,
    ) -> Result<Vec<u8>, CodecError> {
        let mut r = BitReader::new(data);
        let mut out = Vec::with_capacity(n);
        self.decode(&mut r, n, &mut out)?;
        Ok(out)
    }

    /// Exact encoded size in bits for `symbols` (from code lengths).
    fn encoded_bits(&self, symbols: &[u8]) -> u64 {
        let lengths = self.code_lengths();
        symbols.iter().map(|&s| lengths[s as usize] as u64).sum()
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared roundtrip property used by every codec's test module.
    use super::*;
    use crate::util::prop;

    pub fn roundtrip_property(codec: &dyn Codec) {
        prop::check(
            &format!("{} roundtrip", codec.name()),
            prop::Config { cases: 96, ..Default::default() },
            |rng, size| {
                let symbols = prop::arb_bytes(rng, size);
                let encoded = codec.encode_to_vec(&symbols);
                let decoded = codec
                    .decode_from_slice(&encoded, symbols.len())
                    .map_err(|e| e.to_string())?;
                if decoded != symbols {
                    return Err(format!(
                        "roundtrip mismatch (len {})",
                        symbols.len()
                    ));
                }
                // encoded_bits must match the writer exactly.
                let bits = codec.encoded_bits(&symbols);
                if (bits + 7) / 8 != encoded.len() as u64 {
                    return Err(format!(
                        "encoded_bits {} inconsistent with buffer {}",
                        bits,
                        encoded.len()
                    ));
                }
                Ok(())
            },
        );
    }
}
