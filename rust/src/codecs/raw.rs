//! Identity codec: 8 bits/symbol. The uncompressed baseline every
//! paper table normalizes against.

use super::kernel::{BitCursor, BitSink, DecodeKernel, EncodeKernel};
use super::{Codec, CodecError};
use crate::bitstream::{BitReader, BitWriter};

#[derive(Clone, Copy, Debug, Default)]
pub struct RawCodec;

impl DecodeKernel for RawCodec {
    fn decode_batch(
        &self,
        cur: &mut BitCursor,
        out: &mut [u8],
    ) -> Result<usize, CodecError> {
        let n = out.len();
        let mut i = 0usize;
        while i < n {
            // One refill yields up to 8 whole symbols; `avail` counts
            // only real input bits, so the inner loop needs no EOF
            // checks.
            let avail = cur.refill_buffered();
            let k = ((avail / 8) as usize).min(n - i);
            if k == 0 {
                return Err(CodecError::UnexpectedEof);
            }
            let mut w = cur.word();
            for slot in &mut out[i..i + k] {
                *slot = (w >> 56) as u8;
                w <<= 8;
            }
            cur.consume(k as u32 * 8);
            i += k;
        }
        Ok(n)
    }
}

impl EncodeKernel for RawCodec {
    fn encode_batch(&self, symbols: &[u8], sink: &mut BitSink) {
        // Seven whole symbols fit one 56-bit push (the mirror of the
        // decoder's up-to-8-per-refill loop; the sink's staging budget
        // is 57 bits).
        let mut groups = symbols.chunks_exact(7);
        for group in groups.by_ref() {
            let mut acc = 0u64;
            for &s in group {
                acc = (acc << 8) | s as u64;
            }
            sink.push(acc, 56);
        }
        for &s in groups.remainder() {
            sink.push(s as u64, 8);
        }
    }
}

impl Codec for RawCodec {
    fn name(&self) -> String {
        "raw".to_string()
    }

    fn encode_scalar(&self, symbols: &[u8], out: &mut BitWriter) {
        for &s in symbols {
            out.write_bits(s as u64, 8);
        }
    }

    fn decode_scalar_into(
        &self,
        reader: &mut BitReader,
        out: &mut [u8],
    ) -> Result<(), CodecError> {
        for slot in out.iter_mut() {
            let v = reader
                .read_bits(8)
                .map_err(|_| CodecError::UnexpectedEof)?;
            *slot = v as u8;
        }
        Ok(())
    }

    fn code_lengths(&self) -> [u32; 256] {
        [8; 256]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::testutil;

    #[test]
    fn roundtrip_basic() {
        let c = RawCodec;
        let data = vec![0u8, 1, 127, 128, 255];
        let enc = c.encode_to_vec(&data);
        assert_eq!(enc, data); // byte-aligned identity
        assert_eq!(c.decode_from_slice(&enc, data.len()).unwrap(), data);
    }

    #[test]
    fn empty_input() {
        let c = RawCodec;
        assert!(c.encode_to_vec(&[]).is_empty());
        assert_eq!(c.decode_from_slice(&[], 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn truncated_stream_errors() {
        let c = RawCodec;
        assert_eq!(
            c.decode_from_slice(&[1, 2], 3),
            Err(CodecError::UnexpectedEof)
        );
    }

    #[test]
    fn batch_decode_is_identity_at_any_length() {
        // Cross the 8-byte refill boundary repeatedly.
        let c = RawCodec;
        for n in [1usize, 7, 8, 9, 15, 16, 17, 64, 1000] {
            let data: Vec<u8> = (0..n).map(|i| (i * 37) as u8).collect();
            assert_eq!(c.decode_from_slice(&data, n).unwrap(), data, "n={n}");
        }
    }

    #[test]
    fn prop_roundtrip() {
        testutil::roundtrip_property(&RawCodec);
    }
}
