//! Identity codec: 8 bits/symbol. The uncompressed baseline every
//! paper table normalizes against.

use super::{Codec, CodecError};
use crate::bitstream::{BitReader, BitWriter};

#[derive(Clone, Copy, Debug, Default)]
pub struct RawCodec;

impl Codec for RawCodec {
    fn name(&self) -> String {
        "raw".to_string()
    }

    fn encode(&self, symbols: &[u8], out: &mut BitWriter) {
        for &s in symbols {
            out.write_bits(s as u64, 8);
        }
    }

    fn decode_into(
        &self,
        reader: &mut BitReader,
        out: &mut [u8],
    ) -> Result<(), CodecError> {
        for slot in out.iter_mut() {
            let v = reader
                .read_bits(8)
                .map_err(|_| CodecError::UnexpectedEof)?;
            *slot = v as u8;
        }
        Ok(())
    }

    fn code_lengths(&self) -> [u32; 256] {
        [8; 256]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::testutil;

    #[test]
    fn roundtrip_basic() {
        let c = RawCodec;
        let data = vec![0u8, 1, 127, 128, 255];
        let enc = c.encode_to_vec(&data);
        assert_eq!(enc, data); // byte-aligned identity
        assert_eq!(c.decode_from_slice(&enc, data.len()).unwrap(), data);
    }

    #[test]
    fn empty_input() {
        let c = RawCodec;
        assert!(c.encode_to_vec(&[]).is_empty());
        assert_eq!(c.decode_from_slice(&[], 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn truncated_stream_errors() {
        let c = RawCodec;
        assert_eq!(
            c.decode_from_slice(&[1, 2], 3),
            Err(CodecError::UnexpectedEof)
        );
    }

    #[test]
    fn prop_roundtrip() {
        testutil::roundtrip_property(&RawCodec);
    }
}
