//! Ring bootstrap over TCP (handshake format QRZ1).
//!
//! N OS processes form the same ring topology the threaded backend
//! wires in-process:
//!
//! 1. every rank binds an ephemeral *ring listener*;
//! 2. rank 0 listens on the rendezvous address; ranks 1..N connect to
//!    it and send `HELLO {rank, world, ring_addr}`;
//! 3. rank 0 validates the roster (every rank exactly once, matching
//!    world) and answers each peer with `WELCOME {addr[0..N]}` — the
//!    full ring-listener table;
//! 4. every rank connects to `addr[(rank + 1) % world]` (downstream),
//!    identifies itself with a `RING {rank}` record, and accepts the
//!    matching connection from its upstream neighbour.
//!
//! Handshake records are length-prefixed and validated (`Err`, not
//! panic) the same way the data-plane frames are:
//!
//! ```text
//! magic "QRZ1" | kind u8 (1=HELLO, 2=WELCOME, 3=RING) |
//! rank u32 | world u32 | body_len u32 | body bytes…
//! ```
//!
//! HELLO's body is the sender's ring-listener address; WELCOME's body
//! is the newline-joined address table; RING has no body.  The
//! resulting [`TcpLink`] sends to downstream and receives from
//! upstream — exactly [`threaded::ring`](crate::transport::threaded::ring)
//! with sockets for channels.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::tcp::{NetConfig, TcpLink};

const RDZV_MAGIC: [u8; 4] = *b"QRZ1";
const KIND_HELLO: u8 = 1;
const KIND_WELCOME: u8 = 2;
const KIND_RING: u8 = 3;
/// Handshake bodies are tiny (addresses); cap them hard.
const MAX_BODY: usize = 1 << 16;

fn write_msg(
    stream: &mut TcpStream,
    kind: u8,
    rank: u32,
    world: u32,
    body: &[u8],
) -> Result<(), String> {
    if body.len() > MAX_BODY {
        return Err(format!(
            "rendezvous: handshake body {} exceeds {MAX_BODY} bytes",
            body.len()
        ));
    }
    let mut buf = Vec::with_capacity(17 + body.len());
    buf.extend_from_slice(&RDZV_MAGIC);
    buf.push(kind);
    buf.extend_from_slice(&rank.to_le_bytes());
    buf.extend_from_slice(&world.to_le_bytes());
    buf.extend_from_slice(&(body.len() as u32).to_le_bytes());
    buf.extend_from_slice(body);
    stream
        .write_all(&buf)
        .map_err(|e| format!("rendezvous send: {e}"))
}

fn read_msg(
    stream: &mut TcpStream,
) -> Result<(u8, u32, u32, Vec<u8>), String> {
    let mut head = [0u8; 17];
    stream
        .read_exact(&mut head)
        .map_err(|e| format!("rendezvous recv: {e}"))?;
    if head[0..4] != RDZV_MAGIC {
        return Err("rendezvous: bad handshake magic".to_string());
    }
    let kind = head[4];
    // lint: infallible(fixed 4-byte slices of a 17-byte array)
    let rank = u32::from_le_bytes(head[5..9].try_into().unwrap());
    let world = u32::from_le_bytes(head[9..13].try_into().unwrap());
    let len = u32::from_le_bytes(head[13..17].try_into().unwrap()) as usize;
    if len > MAX_BODY {
        return Err(format!(
            "rendezvous: handshake body {len} exceeds {MAX_BODY} bytes"
        ));
    }
    let mut body = vec![0u8; len];
    stream
        .read_exact(&mut body)
        .map_err(|e| format!("rendezvous recv: {e}"))?;
    Ok((kind, rank, world, body))
}

/// Connect with retries until `timeout` — the rendezvous listener may
/// not be up yet when a launcher starts all ranks at once.
fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream, String> {
    let retries = crate::obs::global().counter("rendezvous_connect_retries_total");
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(format!(
                        "rendezvous: cannot reach {addr} within \
                         {timeout:?}: {e}"
                    ));
                }
                retries.inc();
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Accept one connection within `timeout` (std's `TcpListener` has no
/// native accept timeout, so poll non-blocking).
fn accept_timeout(
    listener: &TcpListener,
    timeout: Duration,
) -> Result<TcpStream, String> {
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("rendezvous accept: {e}"))?;
    let deadline = Instant::now() + timeout;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                // Handshake I/O on the accepted socket is blocking
                // with explicit timeouts.
                s.set_nonblocking(false)
                    .map_err(|e| format!("rendezvous accept: {e}"))?;
                set_handshake_timeouts(&s, timeout)?;
                return Ok(s);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(format!(
                        "rendezvous: no peer connected within {timeout:?}"
                    ));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(format!("rendezvous accept: {e}")),
        }
    }
}

fn set_handshake_timeouts(
    stream: &TcpStream,
    timeout: Duration,
) -> Result<(), String> {
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| format!("rendezvous: set_read_timeout: {e}"))?;
    stream
        .set_write_timeout(Some(timeout))
        .map_err(|e| format!("rendezvous: set_write_timeout: {e}"))?;
    Ok(())
}

/// The host part of an address, in a form `TcpListener::bind((host,
/// port))` accepts.  Handles all three shapes a `--listen`/`--connect`
/// flag can carry:
///
/// * `"host:port"` / bare `"host"` — IPv4 or hostname;
/// * `"[v6]:port"` / `"[v6]"` — bracketed IPv6 (`"[::1]:9000"` →
///   `"::1"`; the brackets are URI framing, not part of the address);
/// * a bare unbracketed IPv6 like `"::1"` — returned whole (every
///   colon is part of the address, not a port separator).
fn host_of(addr: &str) -> &str {
    if let Some(rest) = addr.strip_prefix('[') {
        // Bracketed IPv6: the host ends at the matching bracket,
        // whatever follows (`:port` or nothing).
        if let Some(end) = rest.find(']') {
            return &rest[..end];
        }
        // Unterminated bracket: fall through to the generic split so
        // the subsequent bind reports the malformed address.
    }
    match addr.rsplit_once(':') {
        // More than one colon and no brackets → bare IPv6, no port.
        Some((h, _)) if h.contains(':') => addr,
        Some((h, _)) => h,
        None => addr,
    }
}

/// A listen host that names no concrete interface — advertising it to
/// a remote peer would point the peer at *itself*.  Accepts the host
/// as produced by [`host_of`] (brackets already stripped).
fn is_wildcard_host(host: &str) -> bool {
    matches!(host, "" | "0.0.0.0" | "::")
}

/// Rank 0's side of the roster exchange: gather HELLOs, answer with
/// the full address table.  `advertised` is rank 0's ring-listener
/// address when the listen host names a concrete interface; `None`
/// means rank 0 listened on a wildcard, in which case the address is
/// derived from the first accepted connection (the interface the
/// peers actually reached us on) plus `ring_port`.
fn gather_roster(
    rdzv: &TcpListener,
    advertised: Option<String>,
    ring_port: u16,
    world: usize,
    timeout: Duration,
) -> Result<Vec<String>, String> {
    // form_ring validates world before calling us, but the roster is
    // the trust boundary: re-check here so every allocation, loop and
    // header cast below is locally bounded.
    if world < 2 || world > u32::MAX as usize {
        return Err(format!(
            "rendezvous: world {world} out of range for QRZ1 headers"
        ));
    }
    let mut addrs: Vec<Option<String>> = vec![None; world];
    addrs[0] = advertised;
    let mut peers: Vec<TcpStream> = Vec::with_capacity(world - 1);
    for _ in 1..world {
        let mut s = accept_timeout(rdzv, timeout)?;
        if addrs[0].is_none() {
            let ip = s
                .local_addr()
                .map_err(|e| format!("rendezvous: local_addr: {e}"))?
                .ip();
            addrs[0] =
                Some(std::net::SocketAddr::new(ip, ring_port).to_string());
        }
        let (kind, rank, w, body) = read_msg(&mut s)?;
        if kind != KIND_HELLO {
            return Err(format!(
                "rendezvous: expected HELLO, got record kind {kind}"
            ));
        }
        if w as usize != world {
            return Err(format!(
                "rendezvous: peer rank {rank} believes world is {w}, \
                 not {world}"
            ));
        }
        let rank = rank as usize;
        if rank == 0 || rank >= world {
            return Err(format!("rendezvous: peer sent bad rank {rank}"));
        }
        if addrs[rank].is_some() {
            return Err(format!("rendezvous: duplicate rank {rank}"));
        }
        let addr = String::from_utf8(body)
            .map_err(|_| "rendezvous: non-utf8 peer address".to_string())?;
        addrs[rank] = Some(addr);
        peers.push(s);
    }
    let table: Vec<String> = addrs
        .into_iter()
        .collect::<Option<Vec<String>>>()
        .ok_or("rendezvous: roster incomplete (a rank never reported)")?;
    let body = table.join("\n");
    for s in &mut peers {
        write_msg(s, KIND_WELCOME, 0, world as u32, body.as_bytes())?;
    }
    Ok(table)
}

/// Ranks 1..N: announce our ring listener on the already-connected
/// rendezvous stream, receive the table.
fn join_roster(
    rdzv: &mut TcpStream,
    my_ring_addr: &str,
    rank: usize,
    world: usize,
) -> Result<Vec<String>, String> {
    // lint: cast-checked(form_ring rejects world > u32::MAX before any
    // roster I/O, and validates rank < world)
    let (rank32, world32) = (rank as u32, world as u32);
    write_msg(rdzv, KIND_HELLO, rank32, world32, my_ring_addr.as_bytes())?;
    let (kind, _, w, body) = read_msg(rdzv)?;
    if kind != KIND_WELCOME {
        return Err(format!(
            "rendezvous: expected WELCOME, got record kind {kind}"
        ));
    }
    if w as usize != world {
        return Err(format!(
            "rendezvous: leader believes world is {w}, not {world}"
        ));
    }
    let text = String::from_utf8(body)
        .map_err(|_| "rendezvous: non-utf8 address table".to_string())?;
    let table: Vec<String> = text.split('\n').map(str::to_string).collect();
    if table.len() != world {
        return Err(format!(
            "rendezvous: address table has {} entries for world {world}",
            table.len()
        ));
    }
    Ok(table)
}

/// Bootstrap this rank's ring endpoint: rank 0 listens on `addr`,
/// ranks 1..world connect to it; everyone then wires the ring and
/// returns a [`TcpLink`] that sends to `(rank + 1) % world` and
/// receives from `(rank + world - 1) % world`.
pub fn form_ring(
    rank: usize,
    world: usize,
    addr: &str,
    cfg: &NetConfig,
) -> Result<TcpLink, String> {
    if world < 2 {
        return Err(
            "form_ring requires world >= 2 (a ring needs two endpoints); \
             run world 1 collectives in-process"
                .to_string(),
        );
    }
    if rank >= world {
        return Err(format!("rank {rank} out of range for world {world}"));
    }
    // QRZ1 headers carry rank/world in u32 fields; a world that cannot
    // be represented must be rejected here, before any socket I/O,
    // instead of truncating into a different (plausible) world size.
    if world > u32::MAX as usize {
        return Err(format!(
            "form_ring: world {world} exceeds the QRZ1 u32 wire field"
        ));
    }
    let timeout = cfg.io_timeout;

    // Roster exchange: everyone ends up with the same ring-listener
    // address table.  The ring listener is bound *before* the roster
    // is shared, so no downstream connect can beat it.
    let (ring_listener, table) = if rank == 0 {
        let rdzv = TcpListener::bind(addr)
            .map_err(|e| format!("rendezvous: bind {addr}: {e}"))?;
        let ring_listener = TcpListener::bind((host_of(addr), 0u16))
            .map_err(|e| format!("rendezvous: bind ring listener: {e}"))?;
        let ring_addr = ring_listener
            .local_addr()
            .map_err(|e| format!("rendezvous: local_addr: {e}"))?;
        // A wildcard listen host cannot be advertised (a remote peer
        // would connect to itself); the concrete interface is learned
        // from the first accepted rendezvous connection instead.
        let advertised = if is_wildcard_host(host_of(addr)) {
            None
        } else {
            Some(ring_addr.to_string())
        };
        let table = gather_roster(
            &rdzv,
            advertised,
            ring_addr.port(),
            world,
            timeout,
        )?;
        (ring_listener, table)
    } else {
        // The rendezvous stream tells us which local interface
        // reaches the leader; the ring listener binds on it.
        let mut rdzv = connect_retry(addr, timeout)?;
        set_handshake_timeouts(&rdzv, timeout)?;
        let ip = rdzv
            .local_addr()
            .map_err(|e| format!("rendezvous: local_addr: {e}"))?
            .ip();
        let ring_listener = TcpListener::bind((ip, 0u16))
            .map_err(|e| format!("rendezvous: bind ring listener: {e}"))?;
        let my_ring_addr = ring_listener
            .local_addr()
            .map_err(|e| format!("rendezvous: local_addr: {e}"))?
            .to_string();
        let table = join_roster(&mut rdzv, &my_ring_addr, rank, world)?;
        (ring_listener, table)
    };

    // Wire the ring: connect downstream, identify, accept upstream.
    let down = &table[(rank + 1) % world];
    let mut tx = connect_retry(down, timeout)?;
    set_handshake_timeouts(&tx, timeout)?;
    write_msg(&mut tx, KIND_RING, rank as u32, world as u32, &[])?;

    let mut rx = accept_timeout(&ring_listener, timeout)?;
    let (kind, peer, w, _) = read_msg(&mut rx)?;
    if kind != KIND_RING {
        return Err(format!(
            "rendezvous: expected RING identification, got kind {kind}"
        ));
    }
    if w as usize != world {
        return Err(format!(
            "rendezvous: ring peer believes world is {w}, not {world}"
        ));
    }
    let upstream = (rank + world - 1) % world;
    if peer as usize != upstream {
        return Err(format!(
            "rendezvous: ring connection from rank {peer}, expected \
             upstream rank {upstream}"
        ));
    }
    TcpLink::new(tx, rx, *cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::registry::TAG_RAW;
    use crate::transport::exchange_hop;

    fn free_addr() -> String {
        TcpListener::bind("127.0.0.1:0")
            .unwrap()
            .local_addr()
            .unwrap()
            .to_string()
    }

    #[test]
    fn ring_routes_to_downstream_neighbour_over_tcp() {
        let world = 3;
        let addr = free_addr();
        let cfg = NetConfig::new(TAG_RAW)
            .with_timeout(Duration::from_secs(20));
        let mut joined = Vec::new();
        for rank in 0..world {
            let addr = addr.clone();
            joined.push(std::thread::spawn(move || {
                let mut link =
                    form_ring(rank, world, &addr, &cfg).unwrap();
                let symbols = vec![rank as u8; 512];
                let mut enc = None;
                let mut dec = None;
                let ex = exchange_hop(
                    &mut link, &mut enc, &mut dec, &symbols, &[], 128,
                )
                .unwrap();
                let upstream = ((rank + world - 1) % world) as u8;
                assert_eq!(ex.symbols, vec![upstream; 512], "rank {rank}");
            }));
        }
        for j in joined {
            j.join().unwrap();
        }
    }

    #[test]
    fn wildcard_listen_advertises_concrete_interface() {
        // Rank 0 listens on 0.0.0.0; the WELCOME table must carry the
        // interface peers actually reached (here loopback), never the
        // wildcard — otherwise a remote rank would connect to itself.
        let port = free_addr().rsplit_once(':').unwrap().1.to_string();
        let listen = format!("0.0.0.0:{port}");
        let connect = format!("127.0.0.1:{port}");
        let cfg = NetConfig::new(TAG_RAW)
            .with_timeout(Duration::from_secs(20));
        let t0 = std::thread::spawn({
            let listen = listen.clone();
            move || form_ring(0, 2, &listen, &cfg).unwrap()
        });
        let t1 = std::thread::spawn(move || {
            form_ring(1, 2, &connect, &cfg).unwrap()
        });
        let mut a = t0.join().unwrap();
        let mut b = t1.join().unwrap();
        // One lockstep hop proves the ring is live both ways.
        let ja = std::thread::spawn(move || {
            let mut enc = None;
            let mut dec = None;
            exchange_hop(&mut a, &mut enc, &mut dec, &[1u8; 64], &[], 32)
                .unwrap()
                .symbols
        });
        let jb = std::thread::spawn(move || {
            let mut enc = None;
            let mut dec = None;
            exchange_hop(&mut b, &mut enc, &mut dec, &[2u8; 64], &[], 32)
                .unwrap()
                .symbols
        });
        assert_eq!(ja.join().unwrap(), vec![2u8; 64]);
        assert_eq!(jb.join().unwrap(), vec![1u8; 64]);
    }

    #[test]
    fn host_parsing_handles_ipv4_ipv6_and_hostnames() {
        // IPv4 / hostname with port.
        assert_eq!(host_of("127.0.0.1:9000"), "127.0.0.1");
        assert_eq!(host_of("node7:9000"), "node7");
        assert_eq!(host_of("127.0.0.1"), "127.0.0.1");
        // Bracketed IPv6, with and without port.
        assert_eq!(host_of("[::1]:9000"), "::1");
        assert_eq!(host_of("[::1]"), "::1");
        assert_eq!(host_of("[fe80::1%eth0]:7001"), "fe80::1%eth0");
        assert_eq!(host_of("[2001:db8::42]:80"), "2001:db8::42");
        // Bare IPv6 (no port to strip — every colon is address).
        assert_eq!(host_of("::1"), "::1");
        assert_eq!(host_of("2001:db8::42"), "2001:db8::42");
        // Wildcards, bracketed or not.
        assert!(is_wildcard_host(host_of("0.0.0.0:9000")));
        assert!(is_wildcard_host(host_of("[::]:9000")));
        assert!(is_wildcard_host(host_of("")));
        assert!(!is_wildcard_host(host_of("[::1]:9000")));
        assert!(!is_wildcard_host(host_of("10.0.0.1:1")));
    }

    #[test]
    fn ipv6_bracketed_rendezvous_forms_a_ring() {
        // ROADMAP open item: `--listen [::1]:port` must work end to
        // end.  Skip quietly on hosts without IPv6 loopback.
        let Ok(probe) = TcpListener::bind(("::1", 0)) else {
            eprintln!("skipping: no IPv6 loopback on this host");
            return;
        };
        let port = probe.local_addr().unwrap().port();
        drop(probe);
        let addr = format!("[::1]:{port}");
        let cfg = NetConfig::new(TAG_RAW)
            .with_timeout(Duration::from_secs(20));
        let world = 3;
        let mut joined = Vec::new();
        for rank in 0..world {
            let addr = addr.clone();
            joined.push(std::thread::spawn(move || {
                let mut link =
                    form_ring(rank, world, &addr, &cfg).unwrap();
                let symbols = vec![rank as u8; 256];
                let mut enc = None;
                let mut dec = None;
                let ex = exchange_hop(
                    &mut link, &mut enc, &mut dec, &symbols, &[], 64,
                )
                .unwrap();
                let upstream = ((rank + world - 1) % world) as u8;
                assert_eq!(ex.symbols, vec![upstream; 256], "rank {rank}");
            }));
        }
        for j in joined {
            j.join().unwrap();
        }
    }

    #[test]
    fn invalid_shapes_rejected() {
        let cfg = NetConfig::new(TAG_RAW);
        assert!(form_ring(0, 0, "127.0.0.1:1", &cfg).is_err());
        assert!(form_ring(0, 1, "127.0.0.1:1", &cfg).is_err());
        assert!(form_ring(5, 3, "127.0.0.1:1", &cfg).is_err());
    }

    #[test]
    #[cfg(target_pointer_width = "64")]
    fn oversized_world_is_rejected_before_io() {
        // The QRZ1 header stores world as u32; a larger world must be
        // an immediate Err (no sockets touched) rather than a
        // truncated handshake a peer could mistake for a valid ring.
        let cfg = NetConfig::new(TAG_RAW);
        let world = (u32::MAX as usize) + 2;
        let err = form_ring(1, world, "127.0.0.1:1", &cfg).unwrap_err();
        assert!(err.contains("u32 wire field"), "{err}");
    }

    #[test]
    fn connect_to_nobody_times_out() {
        let cfg = NetConfig::new(TAG_RAW)
            .with_timeout(Duration::from_millis(120));
        // A bound-then-dropped port with nobody listening.
        let addr = free_addr();
        let err = form_ring(1, 2, &addr, &cfg).unwrap_err();
        assert!(err.contains("cannot reach"), "{err}");
    }

    #[test]
    fn handshake_records_validate() {
        // A non-handshake byte stream is rejected, not mis-parsed.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET / HTTP/1.1\r\n\r\n but much longer junk")
                .unwrap();
        });
        let (mut s, _) = listener.accept().unwrap();
        let err = read_msg(&mut s).unwrap_err();
        assert!(err.contains("magic"), "{err}");
        writer.join().unwrap();
    }
}
