//! Socket-backed [`Link`]: one non-blocking [`TcpStream`] to the
//! downstream neighbour and one from the upstream neighbour, speaking
//! the [`super::wire`] frame protocol.
//!
//! Both streams are non-blocking and every `send`/`recv` *pumps* both
//! directions: while a send is back-pressured by a full socket buffer
//! it keeps draining inbound bytes (and vice versa), so the lockstep
//! send-one/receive-one schedule of
//! [`exchange_hop`](crate::transport::exchange_hop) can never deadlock
//! on mutual writes — the in-flight window is bounded by the OS socket
//! buffers exactly the way the threaded backend is bounded by its
//! channel depth.
//!
//! When neither direction can progress the pump does **not** sleep-poll:
//! it parks on the configured [`Reactor`] backend
//! ([`NetConfig::backend`]) until the kernel reports one of the two
//! sockets ready.  On Linux that is epoll — zero sleeps, wakeup at
//! readiness — and elsewhere the capped exponential-backoff fallback;
//! `tcp_poll_sleeps_total{backend=...}` counts only waits that actually
//! slept, so the epoll path can be held to its no-sleep contract.
//! A configurable progress timeout turns a stalled or
//! silent peer into an `Err`, mirroring the threaded backend's
//! `recv_timeout` failure mode; an overall per-call deadline cap
//! ([`NetConfig::hop_timeout`]) additionally fails a *trickling* peer
//! whose byte-at-a-time progress would reset the stall deadline
//! forever.
//!
//! Frame ordering is validated on both directions: the link stamps a
//! per-direction hop ordinal (incremented after each `last` chunk) and
//! checks that inbound frames arrive with the expected hop/seq and the
//! agreed codec tag, so a desynchronized or foreign stream fails fast
//! instead of decoding garbage.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::wire;
use crate::obs;
use crate::transport::reactor::{self, Backend, Interest, Reactor};
use crate::transport::{ChunkMsg, Link};

/// Read granularity of the inbound pump.
const READ_CHUNK: usize = 64 * 1024;

/// Reactor token for the inbound (upstream) stream.
const TOKEN_RX: u64 = 0;
/// Reactor token for the outbound (downstream) stream.
const TOKEN_TX: u64 = 1;

/// Socket link configuration.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Maximum time with zero forward progress (no byte written or
    /// read) before `send`/`recv` gives up with an `Err`.
    pub io_timeout: Duration,
    /// Hard cap on one whole `send`/`recv` call, **regardless** of
    /// progress.  The progress timeout alone is gameable: a peer
    /// trickling one byte per poll interval resets it forever and
    /// never completes a frame.  This cap turns that pathology into an
    /// `Err` too.  Defaults to 10× `io_timeout`; size it for the
    /// largest chunk a link legitimately moves.
    pub hop_timeout: Duration,
    /// Whether `hop_timeout` was set explicitly
    /// ([`NetConfig::with_hop_timeout`]); [`NetConfig::with_timeout`]
    /// re-derives the default 10× cap only while this is unset, so the
    /// two builders compose in either order.
    hop_explicit: bool,
    /// Wire tag of the transport codec both endpoints agreed on
    /// apriori (tables are never shipped per hop); stamped on outgoing
    /// frames and enforced on inbound ones.
    pub codec_tag: u8,
    /// Which [`Reactor`] backend parks the pump when neither direction
    /// can progress.  `Auto` (the default) resolves to epoll on Linux
    /// — readiness waits with no sleep-polling — and to the capped
    /// exponential-backoff fallback elsewhere.
    pub backend: Backend,
}

impl NetConfig {
    pub fn new(codec_tag: u8) -> NetConfig {
        NetConfig {
            io_timeout: Duration::from_secs(30),
            hop_timeout: Duration::from_secs(300),
            hop_explicit: false,
            codec_tag,
            backend: Backend::Auto,
        }
    }

    /// Select the readiness-wait backend (`--reactor` on the CLI).
    pub fn with_backend(mut self, backend: Backend) -> NetConfig {
        self.backend = backend;
        self
    }

    /// Set the progress timeout; the overall per-call cap follows at
    /// its default 10× relationship unless it was set explicitly with
    /// [`NetConfig::with_hop_timeout`].
    pub fn with_timeout(mut self, io_timeout: Duration) -> NetConfig {
        self.io_timeout = io_timeout;
        if !self.hop_explicit {
            self.hop_timeout = io_timeout.saturating_mul(10);
        }
        self
    }

    /// Set the overall per-call deadline cap independently.
    pub fn with_hop_timeout(mut self, hop_timeout: Duration) -> NetConfig {
        self.hop_timeout = hop_timeout;
        self.hop_explicit = true;
        self
    }
}

/// Global-registry counters for the socket pump's traffic and
/// failure/backoff paths (shared by every link in the process — the
/// keys carry no per-link label, so a world-level merge just sums).
/// The two wait-path counters are labeled by reactor backend so a
/// readiness backend can be held to its no-sleep contract even while
/// fallback links run in the same process.
struct LinkStats {
    frames_sent: obs::Counter,
    frames_recv: obs::Counter,
    /// Waits that *slept* (the fallback's backoff naps) rather than
    /// parking on kernel readiness.  Zero, by construction, on epoll.
    poll_sleeps: obs::Counter,
    /// Every no-progress park, sleeping or not.
    reactor_waits: obs::Counter,
    hop_timeouts: obs::Counter,
    stall_timeouts: obs::Counter,
}

impl LinkStats {
    fn new(backend: &str) -> LinkStats {
        let reg = obs::global();
        let labels = &[("backend", backend)];
        LinkStats {
            frames_sent: reg.counter("tcp_frames_sent_total"),
            frames_recv: reg.counter("tcp_frames_recv_total"),
            poll_sleeps: reg
                .counter(&obs::label("tcp_poll_sleeps_total", labels)),
            reactor_waits: reg
                .counter(&obs::label("tcp_reactor_waits_total", labels)),
            hop_timeouts: reg.counter("tcp_hop_timeouts_total"),
            stall_timeouts: reg.counter("tcp_stall_timeouts_total"),
        }
    }
}

/// One worker's socket endpoints in the ring: `tx` to the downstream
/// neighbour, `rx` from the upstream one.
pub struct TcpLink {
    tx: TcpStream,
    rx: TcpStream,
    cfg: NetConfig,
    /// Outbound bytes not yet accepted by the OS (`out[out_pos..]`).
    out: Vec<u8>,
    out_pos: usize,
    /// Inbound bytes not yet framed.
    inbuf: Vec<u8>,
    rx_eof: bool,
    send_hop: u32,
    recv_hop: u32,
    recv_seq: u32,
    /// Parks the pump when neither direction can progress.
    reactor: Box<dyn Reactor>,
    /// Whether `tx` is currently registered for writable readiness
    /// (only while bytes are queued — a drained socket is nearly
    /// always writable and would turn level-triggered waits into a
    /// busy loop).
    tx_armed: bool,
    /// Scratch event buffer reused across waits.
    events: Vec<reactor::Event>,
    stats: LinkStats,
}

/// The identity the reactor watches a stream under.
fn stream_fd(s: &TcpStream) -> reactor::RawFd {
    #[cfg(unix)]
    {
        use std::os::fd::AsRawFd;
        s.as_raw_fd()
    }
    #[cfg(not(unix))]
    {
        // The portable fallback only uses fds as map keys; local
        // port numbers are distinct per stream here.
        s.local_addr().map(|a| a.port() as i32).unwrap_or(0)
    }
}

impl TcpLink {
    /// Wrap a connected stream pair.  Switches both streams to
    /// non-blocking mode, disables Nagle on the send side (hops are
    /// latency-sensitive lockstep exchanges) and registers both with
    /// the configured [`Reactor`] backend.
    pub fn new(
        tx: TcpStream,
        rx: TcpStream,
        cfg: NetConfig,
    ) -> Result<TcpLink, String> {
        tx.set_nodelay(true)
            .map_err(|e| format!("tcp link: set_nodelay: {e}"))?;
        tx.set_nonblocking(true)
            .map_err(|e| format!("tcp link: set_nonblocking(tx): {e}"))?;
        rx.set_nonblocking(true)
            .map_err(|e| format!("tcp link: set_nonblocking(rx): {e}"))?;
        let mut reactor = reactor::new_reactor(cfg.backend)?;
        reactor
            .register(stream_fd(&rx), TOKEN_RX, Interest::READABLE)
            .map_err(|e| format!("tcp link: register rx: {e}"))?;
        reactor
            .register(stream_fd(&tx), TOKEN_TX, Interest::NONE)
            .map_err(|e| format!("tcp link: register tx: {e}"))?;
        let stats = LinkStats::new(reactor.name());
        Ok(TcpLink {
            tx,
            rx,
            cfg,
            out: Vec::new(),
            out_pos: 0,
            inbuf: Vec::new(),
            rx_eof: false,
            send_hop: 0,
            recv_hop: 0,
            recv_seq: 0,
            reactor,
            tx_armed: false,
            events: Vec::new(),
            stats,
        })
    }

    /// Bytes currently queued for the downstream peer.
    pub fn pending_out(&self) -> usize {
        self.out.len() - self.out_pos
    }

    /// The reactor backend this link parks on (metric label value).
    pub fn backend_name(&self) -> &'static str {
        self.reactor.name()
    }

    /// Park until the kernel reports a watched socket ready (or
    /// `timeout` passes): writable interest on `tx` is armed only
    /// while bytes are queued, and `rx` stops being watched at EOF so
    /// a closed peer cannot spin the wait loop.
    fn wait_ready(&mut self, timeout: Duration) -> Result<(), String> {
        let want_tx = self.pending_out() > 0;
        if want_tx != self.tx_armed {
            let interest =
                if want_tx { Interest::WRITABLE } else { Interest::NONE };
            self.reactor
                .reregister(stream_fd(&self.tx), TOKEN_TX, interest)
                .map_err(|e| format!("tcp link: rearm tx: {e}"))?;
            self.tx_armed = want_tx;
        }
        self.stats.reactor_waits.inc();
        let mut events = std::mem::take(&mut self.events);
        let slept = self.reactor.wait(&mut events, timeout)?;
        self.events = events;
        if slept {
            self.stats.poll_sleeps.inc();
        }
        Ok(())
    }

    /// Push queued bytes into the socket; `Ok(true)` if any moved.
    fn try_flush(&mut self) -> Result<bool, String> {
        let mut progressed = false;
        while self.out_pos < self.out.len() {
            match self.tx.write(&self.out[self.out_pos..]) {
                Ok(0) => {
                    return Err(
                        "tcp send: downstream peer closed the connection"
                            .to_string(),
                    )
                }
                Ok(n) => {
                    self.out_pos += n;
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(format!("tcp send: {e}")),
            }
        }
        if self.out_pos == self.out.len() && self.out_pos > 0 {
            self.out.clear();
            self.out_pos = 0;
        }
        Ok(progressed)
    }

    /// Drain available inbound bytes; `Ok(true)` if any arrived.
    fn try_fill(&mut self) -> Result<bool, String> {
        if self.rx_eof {
            return Ok(false);
        }
        let mut progressed = false;
        let mut buf = [0u8; READ_CHUNK];
        loop {
            match self.rx.read(&mut buf) {
                Ok(0) => {
                    self.rx_eof = true;
                    // Stop watching a closed peer: level-triggered
                    // readiness would otherwise report EOF-readable
                    // forever and spin the wait loop.
                    self.reactor
                        .deregister(stream_fd(&self.rx))
                        .map_err(|e| format!("tcp link: drop rx: {e}"))?;
                    break;
                }
                Ok(n) => {
                    self.inbuf.extend_from_slice(&buf[..n]);
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(format!("tcp recv: {e}")),
            }
        }
        Ok(progressed)
    }
}

impl Link for TcpLink {
    /// Frame `msg` and push it out, pumping the inbound direction
    /// whenever the socket back-pressures so mutual sends cannot
    /// deadlock.  Returns once every byte is in the OS send buffer.
    fn send(&mut self, msg: ChunkMsg) -> Result<(), String> {
        let last = msg.last;
        wire::encode_frame(self.send_hop, self.cfg.codec_tag, &msg, &mut self.out)?;
        if last {
            self.send_hop = self.send_hop.wrapping_add(1);
        }
        let hard_deadline = Instant::now() + self.cfg.hop_timeout;
        let mut deadline = Instant::now() + self.cfg.io_timeout;
        while self.out_pos < self.out.len() {
            // The per-call cap is checked while the send is still
            // incomplete — a call whose final bytes just flushed exits
            // through the loop condition, never through this error.
            // Trickled progress resets the stall deadline below
            // forever; this cap is what still fails fast.
            let now = Instant::now();
            if now >= hard_deadline {
                self.stats.hop_timeouts.inc();
                return Err(format!(
                    "tcp send: {} bytes still queued after the {:?} \
                     per-call deadline (peer draining too slowly?)",
                    self.pending_out(),
                    self.cfg.hop_timeout
                ));
            }
            let wrote = self.try_flush()?;
            let read = self.try_fill()?;
            if wrote || read {
                deadline = Instant::now() + self.cfg.io_timeout;
                self.reactor.note_progress();
            } else {
                let now = Instant::now();
                if now >= deadline {
                    self.stats.stall_timeouts.inc();
                    return Err(format!(
                        "tcp send: no progress for {:?} ({} bytes still \
                         queued; peer stalled?)",
                        self.cfg.io_timeout,
                        self.pending_out()
                    ));
                }
                // saturating: the hard deadline may have passed
                // during the I/O pass above; a zero wait falls
                // through to the deadline checks next iteration.
                let remaining = deadline
                    .min(hard_deadline)
                    .saturating_duration_since(now);
                self.wait_ready(remaining)?;
            }
        }
        self.stats.frames_sent.inc();
        Ok(())
    }

    /// Pump until one complete frame is buffered, validate its framing
    /// (codec tag, hop/seq order) and hand back the [`ChunkMsg`].
    fn recv(&mut self) -> Result<ChunkMsg, String> {
        let hard_deadline = Instant::now() + self.cfg.hop_timeout;
        let mut deadline = Instant::now() + self.cfg.io_timeout;
        loop {
            if let Some((frame, used)) = wire::decode_frame(&self.inbuf)? {
                self.inbuf.drain(..used);
                if frame.codec_tag != self.cfg.codec_tag {
                    return Err(format!(
                        "tcp recv: frame codec tag {} does not match the \
                         agreed transport codec tag {}",
                        frame.codec_tag, self.cfg.codec_tag
                    ));
                }
                if frame.hop != self.recv_hop
                    || frame.msg.seq != self.recv_seq
                {
                    return Err(format!(
                        "tcp recv: out-of-order frame hop {} seq {} \
                         (expected hop {} seq {})",
                        frame.hop,
                        frame.msg.seq,
                        self.recv_hop,
                        self.recv_seq
                    ));
                }
                if frame.msg.last {
                    self.recv_hop = self.recv_hop.wrapping_add(1);
                    self.recv_seq = 0;
                } else {
                    self.recv_seq += 1;
                }
                self.stats.frames_recv.inc();
                return Ok(frame.msg);
            }
            if self.rx_eof {
                return Err(if self.inbuf.is_empty() {
                    "tcp recv: upstream peer disconnected".to_string()
                } else {
                    "tcp recv: upstream peer disconnected mid-frame"
                        .to_string()
                });
            }
            // The per-call cap is checked only after the frame-decode
            // attempt above failed, so bytes that just completed a
            // frame are always decoded before the deadline can reject
            // them.  A trickling peer makes progress every poll and
            // never trips the stall deadline; this cap does.
            let now = Instant::now();
            if now >= hard_deadline {
                self.stats.hop_timeouts.inc();
                return Err(format!(
                    "tcp recv: no complete frame after the {:?} per-call \
                     deadline (peer trickling?)",
                    self.cfg.hop_timeout
                ));
            }
            let read = self.try_fill()?;
            let wrote = self.try_flush()?;
            if read || wrote {
                deadline = Instant::now() + self.cfg.io_timeout;
                self.reactor.note_progress();
            } else {
                let now = Instant::now();
                if now >= deadline {
                    self.stats.stall_timeouts.inc();
                    return Err(format!(
                        "tcp recv: no data for {:?} (peer stalled?)",
                        self.cfg.io_timeout
                    ));
                }
                // saturating: the hard deadline may have passed
                // during the I/O pass above; a zero wait falls
                // through to the deadline checks next iteration.
                let remaining = deadline
                    .min(hard_deadline)
                    .saturating_duration_since(now);
                self.wait_ready(remaining)?;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    use crate::codecs::registry::TAG_RAW;
    use crate::transport::exchange_hop;

    /// Two fully-wired 2-ring endpoints over loopback: `a.tx → b.rx`
    /// and `b.tx → a.rx`, plus raw handles onto the b→a wire for fault
    /// injection.
    fn loopback_pair(cfg: NetConfig) -> (TcpLink, TcpLink, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a_tx = TcpStream::connect(addr).unwrap();
        let (b_rx, _) = listener.accept().unwrap();
        let b_tx = TcpStream::connect(addr).unwrap();
        let (a_rx, _) = listener.accept().unwrap();
        let raw_b_tx = b_tx.try_clone().unwrap();
        let a = TcpLink::new(a_tx, a_rx, cfg).unwrap();
        let b = TcpLink::new(b_tx, b_rx, cfg).unwrap();
        (a, b, raw_b_tx)
    }

    fn msg(seq: u32, last: bool, payload: Vec<u8>) -> ChunkMsg {
        ChunkMsg {
            seq,
            last,
            n_symbols: payload.len(),
            payload,
            scales: Vec::new(),
        }
    }

    #[test]
    fn chunks_roundtrip_over_loopback() {
        let cfg = NetConfig::new(TAG_RAW);
        let (mut a, mut b, _raw) = loopback_pair(cfg);
        for hop in 0..3u8 {
            a.send(msg(0, false, vec![hop; 10])).unwrap();
            a.send(msg(1, true, vec![hop ^ 0xFF; 5])).unwrap();
            let m0 = b.recv().unwrap();
            assert_eq!(m0.seq, 0);
            assert!(!m0.last);
            assert_eq!(m0.payload, vec![hop; 10]);
            let m1 = b.recv().unwrap();
            assert!(m1.last);
            assert_eq!(m1.payload, vec![hop ^ 0xFF; 5]);
        }
    }

    #[test]
    fn exchange_hop_runs_the_two_ring() {
        let cfg = NetConfig::new(TAG_RAW);
        let (mut a, mut b, _raw) = loopback_pair(cfg);
        let data_a: Vec<u8> = (0..40_000).map(|i| (i % 251) as u8).collect();
        let data_b: Vec<u8> = (0..40_000).map(|i| (i % 13) as u8).collect();
        let expect_a = data_b.clone();
        let expect_b = data_a.clone();
        let ta = std::thread::spawn(move || {
            let mut enc = None;
            let mut dec = None;
            let scales = vec![2.5f32; 4];
            let ex = exchange_hop(
                &mut a, &mut enc, &mut dec, &data_a, &scales, 1024,
            )
            .unwrap();
            assert_eq!(ex.symbols, expect_a);
            assert_eq!(ex.scales, vec![2.5f32; 4]);
        });
        let tb = std::thread::spawn(move || {
            let mut enc = None;
            let mut dec = None;
            let scales = vec![2.5f32; 4];
            let ex = exchange_hop(
                &mut b, &mut enc, &mut dec, &data_b, &scales, 1024,
            )
            .unwrap();
            assert_eq!(ex.symbols, expect_b);
        });
        ta.join().unwrap();
        tb.join().unwrap();
    }

    #[test]
    fn large_mutual_whole_payload_hop_does_not_deadlock() {
        // Both sides send a multi-megabyte single chunk first (the
        // chunk_symbols = usize::MAX configuration): without the
        // read-while-write pump this would deadlock on full socket
        // buffers.
        let cfg = NetConfig::new(TAG_RAW)
            .with_timeout(Duration::from_secs(20));
        let (mut a, mut b, _raw) = loopback_pair(cfg);
        let big: Vec<u8> = (0..4 << 20).map(|i| (i % 255) as u8).collect();
        let big2 = big.clone();
        let expect = big.clone();
        let ta = std::thread::spawn(move || {
            let mut enc = None;
            let mut dec = None;
            exchange_hop(
                &mut a, &mut enc, &mut dec, &big, &[], usize::MAX,
            )
            .unwrap()
            .symbols
        });
        let tb = std::thread::spawn(move || {
            let mut enc = None;
            let mut dec = None;
            exchange_hop(
                &mut b, &mut enc, &mut dec, &big2, &[], usize::MAX,
            )
            .unwrap()
            .symbols
        });
        assert_eq!(ta.join().unwrap(), expect);
        assert_eq!(tb.join().unwrap(), expect);
    }

    #[test]
    fn trickling_peer_trips_the_per_call_deadline() {
        // One byte every 20 ms is forward progress on every poll, so
        // the 80 ms stall deadline never fires — only the overall
        // per-call cap can fail this peer.
        let cfg = NetConfig::new(TAG_RAW)
            .with_timeout(Duration::from_millis(80))
            .with_hop_timeout(Duration::from_millis(250));
        let (mut a, _b, mut raw) = loopback_pair(cfg);
        let mut frame = Vec::new();
        crate::transport::net::wire::encode_frame(
            0,
            TAG_RAW,
            &msg(0, true, vec![7u8; 256]),
            &mut frame,
        )
        .unwrap();
        let writer = std::thread::spawn(move || {
            for &byte in &frame {
                if raw.write_all(&[byte]).is_err() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        });
        let t0 = Instant::now();
        let err = a.recv().unwrap_err();
        assert!(err.contains("per-call deadline"), "{err}");
        // The full trickled frame would take > 5 s; the cap fails it
        // at ~250 ms.
        assert!(
            t0.elapsed() < Duration::from_secs(3),
            "recv took {:?}",
            t0.elapsed()
        );
        drop(a);
        writer.join().unwrap();
    }

    #[test]
    fn silent_peer_times_out() {
        let cfg = NetConfig::new(TAG_RAW)
            .with_timeout(Duration::from_millis(50));
        let (mut a, _b, _raw) = loopback_pair(cfg);
        let err = a.recv().unwrap_err();
        assert!(err.contains("no data"), "{err}");
    }

    #[test]
    fn disconnected_peer_is_an_error() {
        let cfg = NetConfig::new(TAG_RAW)
            .with_timeout(Duration::from_secs(5));
        let (mut a, b, raw) = loopback_pair(cfg);
        drop(b);
        drop(raw);
        let err = a.recv().unwrap_err();
        assert!(err.contains("disconnected"), "{err}");
    }

    #[test]
    fn garbage_on_the_wire_is_an_error_not_a_hang() {
        let cfg = NetConfig::new(TAG_RAW)
            .with_timeout(Duration::from_secs(5));
        let (mut a, _b, mut raw) = loopback_pair(cfg);
        raw.write_all(b"definitely not a QWC1 frame").unwrap();
        let err = a.recv().unwrap_err();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn codec_tag_mismatch_rejected() {
        let (mut a, b, _raw) =
            loopback_pair(NetConfig::new(TAG_RAW));
        // Rebuild b with a different agreed tag.
        let mut b = TcpLink { cfg: NetConfig::new(3), ..b };
        b.send(msg(0, true, vec![1, 2, 3])).unwrap();
        let err = a.recv().unwrap_err();
        assert!(err.contains("codec tag"), "{err}");
    }

    #[test]
    fn out_of_order_frames_rejected() {
        let (mut a, mut b, _raw) =
            loopback_pair(NetConfig::new(TAG_RAW));
        b.send(msg(5, true, vec![9])).unwrap(); // expected seq 0
        let err = a.recv().unwrap_err();
        assert!(err.contains("out-of-order"), "{err}");
    }

    /// Readiness waits on the epoll backend never sleep: the labeled
    /// `tcp_poll_sleeps_total{backend="epoll"}` counter must stay at
    /// zero across a multi-hop loopback exchange — even one large
    /// enough to back-pressure the socket buffers and force the pump
    /// to park repeatedly.
    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_loopback_hop_never_sleep_polls() {
        use crate::transport::reactor::Backend;
        let sleeps = crate::obs::global().counter(&crate::obs::label(
            "tcp_poll_sleeps_total",
            &[("backend", "epoll")],
        ));
        let before = sleeps.get();
        let cfg = NetConfig::new(TAG_RAW)
            .with_backend(Backend::Epoll)
            .with_timeout(Duration::from_secs(20));
        let (mut a, mut b, _raw) = loopback_pair(cfg);
        assert_eq!(a.backend_name(), "epoll");
        // Big enough that sends block on full socket buffers and the
        // pump must park on readiness between passes.
        let big: Vec<u8> = (0..2 << 20).map(|i| (i % 253) as u8).collect();
        let big2 = big.clone();
        let expect = big.clone();
        let ta = std::thread::spawn(move || {
            let (mut enc, mut dec) = (None, None);
            exchange_hop(&mut a, &mut enc, &mut dec, &big, &[], 128 * 1024)
                .unwrap()
                .symbols
        });
        let tb = std::thread::spawn(move || {
            let (mut enc, mut dec) = (None, None);
            exchange_hop(&mut b, &mut enc, &mut dec, &big2, &[], 128 * 1024)
                .unwrap()
                .symbols
        });
        assert_eq!(ta.join().unwrap(), expect);
        assert_eq!(tb.join().unwrap(), expect);
        // Other tests share the process-global registry, but every
        // epoll-backed wait reports `slept = false`, so the epoll
        // label can never move regardless of what runs concurrently.
        assert_eq!(
            sleeps.get(),
            before,
            "epoll readiness waits must not sleep-poll"
        );
    }

    /// The fallback backend *does* sleep — and says so through the
    /// same labeled counter, which is what makes the epoll zero above
    /// a real claim and not a dead metric.
    #[test]
    fn fallback_backend_accounts_its_sleeps() {
        use crate::transport::reactor::Backend;
        let sleeps = crate::obs::global().counter(&crate::obs::label(
            "tcp_poll_sleeps_total",
            &[("backend", "fallback")],
        ));
        let before = sleeps.get();
        let cfg = NetConfig::new(TAG_RAW)
            .with_backend(Backend::Fallback)
            .with_timeout(Duration::from_secs(10));
        let (mut a, mut b, _raw) = loopback_pair(cfg);
        assert_eq!(a.backend_name(), "fallback");
        let send = std::thread::spawn(move || {
            // Delay the peer so a.recv() has to park at least once.
            std::thread::sleep(Duration::from_millis(30));
            b.send(msg(0, true, vec![5u8; 64])).unwrap();
            b
        });
        assert_eq!(a.recv().unwrap().payload, vec![5u8; 64]);
        let _b = send.join().unwrap();
        assert!(
            sleeps.get() > before,
            "fallback waits must be visible as poll sleeps"
        );
    }
}
