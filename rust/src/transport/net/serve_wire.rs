//! Handshake and acknowledgement wire formats for the `qlc serve`
//! request/response protocol, plus the per-connection request framing
//! state machine.
//!
//! A serve connection opens with exactly one client handshake (format
//! QSV1) naming the operation and the codec identity:
//!
//! ```text
//! magic "QSV1" | version u8 (= 1) | op u8 (1 = compress,
//! 2 = decompress) | codec_tag u8 | header_len u32 | header bytes…
//! ```
//!
//! The server answers with one acknowledgement (format QSA1):
//!
//! ```text
//! magic "QSA1" | status u8 (0 = ok, 1 = error) | msg_len u32 | msg…
//! ```
//!
//! On an ok ack both sides switch to [`super::wire`] QWC1 frames:
//! `hop` carries the request ordinal (0, 1, 2, … per connection),
//! `seq` the chunk ordinal within the request, and `FLAG_LAST`
//! terminates a request.  The server streams back one response frame
//! per request frame under the same hop/seq ordinals, so a client can
//! pipeline requests and still match responses positionally.
//!
//! Validation mirrors `wire`: strict, `Err`-returning, never
//! panicking, with every untrusted length capped *before* any
//! allocation it sizes.  Decoders distinguish "incomplete, read more"
//! (`Ok(None)`) from corruption (`Err`).  [`RequestTracker`] is the
//! sequencing half: it enforces the hop/seq ordinals and the serve
//! per-chunk caps so an interleaved, replayed or foreign stream fails
//! fast instead of corrupting session state.

use super::wire::WireFrame;

/// Handshake magic (client → server, once per connection).
pub const HS_MAGIC: [u8; 4] = *b"QSV1";
/// Handshake format version this build speaks.
pub const HS_VERSION: u8 = 1;
/// Fixed handshake prefix: magic, version, op, codec_tag, header_len.
pub const HS_HEADER_LEN: usize = 4 + 1 + 1 + 1 + 4;
/// Hard cap on the codec wire header carried by a handshake (1 MiB —
/// real headers are a few bytes of codec parameters).
pub const MAX_WIRE_HEADER: usize = 1 << 20;

/// Acknowledgement magic (server → client, once per connection).
pub const ACK_MAGIC: [u8; 4] = *b"QSA1";
/// Fixed ack prefix: magic, status, msg_len.
pub const ACK_HEADER_LEN: usize = 4 + 1 + 4;
/// Hard cap on the ack's human-readable error message.
pub const MAX_ACK_MSG: usize = 1 << 10;

/// Per-chunk payload cap on the serve path (16 MiB), deliberately
/// tighter than the link-level [`super::wire::MAX_PAYLOAD_BYTES`]: a
/// serve request is sliced client-side into transport chunks, so one
/// hostile connection can never pin a gigabyte of server memory.
pub const MAX_REQ_PAYLOAD: usize = 1 << 24;
/// Per-chunk symbol-count cap on the serve path (16 Mi symbols); the
/// decompress side allocates `n_symbols` output bytes per chunk, so
/// this bounds that allocation the way `MAX_REQ_PAYLOAD` bounds the
/// payload one.
pub const MAX_CHUNK_SYMBOLS: usize = 1 << 24;

/// What a serve connection asks the server to do with its stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Client streams raw bytes up, server streams compressed chunks
    /// back.
    Compress,
    /// Client streams compressed chunks up, server streams raw bytes
    /// back.
    Decompress,
}

impl Op {
    /// The byte this op travels as in a QSV1 handshake.
    pub fn wire_byte(self) -> u8 {
        match self {
            Op::Compress => 1,
            Op::Decompress => 2,
        }
    }

    /// Inverse of [`Op::wire_byte`].
    pub fn from_wire(byte: u8) -> Result<Op, String> {
        match byte {
            1 => Ok(Op::Compress),
            2 => Ok(Op::Decompress),
            other => Err(format!("unknown handshake op byte {other:#04x}")),
        }
    }

    /// CLI/metrics-facing name.
    pub fn name(self) -> &'static str {
        match self {
            Op::Compress => "compress",
            Op::Decompress => "decompress",
        }
    }

    /// Inverse of [`Op::name`], for `--op` style flags.
    pub fn parse(name: &str) -> Result<Op, String> {
        match name {
            "compress" => Ok(Op::Compress),
            "decompress" => Ok(Op::Decompress),
            other => Err(format!(
                "unknown op '{other}' (expected compress|decompress)"
            )),
        }
    }
}

/// The decoded client handshake: what to do, and the full wire
/// identity of the codec so the server can reconstruct it bit-exactly
/// via `CodecRegistry::resolve_wire`.
#[derive(Clone, Debug, PartialEq)]
pub struct Handshake {
    pub op: Op,
    /// Registry wire tag of the codec.
    pub codec_tag: u8,
    /// Codec-specific wire header (tables, parameters), opaque here.
    pub header: Vec<u8>,
}

/// Serialize one handshake appended to `out`.
pub fn encode_handshake(
    hs: &Handshake,
    out: &mut Vec<u8>,
) -> Result<(), String> {
    if hs.header.len() > MAX_WIRE_HEADER {
        return Err(format!(
            "codec wire header {} exceeds the {MAX_WIRE_HEADER}-byte \
             handshake cap",
            hs.header.len()
        ));
    }
    out.reserve(HS_HEADER_LEN + hs.header.len());
    out.extend_from_slice(&HS_MAGIC);
    out.push(HS_VERSION);
    out.push(hs.op.wire_byte());
    out.push(hs.codec_tag);
    out.extend_from_slice(&(hs.header.len() as u32).to_le_bytes());
    out.extend_from_slice(&hs.header);
    Ok(())
}

/// Try to decode one handshake from the front of `buf`.
///
/// `Ok(Some((hs, consumed)))` on a complete valid handshake,
/// `Ok(None)` while the (so-far valid) handshake is incomplete,
/// `Err(_)` on corruption — checked field by field, so a wrong magic,
/// foreign version, unknown op or hostile header length fails fast
/// without waiting for (or buffering) the declared tail.
pub fn decode_handshake(
    buf: &[u8],
) -> Result<Option<(Handshake, usize)>, String> {
    let probe = buf.len().min(4);
    if buf[..probe] != HS_MAGIC[..probe] {
        return Err("bad handshake magic (not a qlc serve client?)".to_string());
    }
    if buf.len() < HS_HEADER_LEN {
        return Ok(None);
    }
    let version = buf[4];
    if version != HS_VERSION {
        return Err(format!(
            "handshake version {version} not supported (this build speaks \
             {HS_VERSION})"
        ));
    }
    let op = Op::from_wire(buf[5])?;
    let codec_tag = buf[6];
    // lint: infallible(fixed 4-byte slice of the length-checked header)
    let header_len = u32::from_le_bytes(buf[7..11].try_into().unwrap()) as usize;
    if header_len > MAX_WIRE_HEADER {
        return Err(format!(
            "handshake declares a {header_len}-byte codec header (cap \
             {MAX_WIRE_HEADER})"
        ));
    }
    let total = HS_HEADER_LEN + header_len;
    if buf.len() < total {
        return Ok(None);
    }
    let header = buf[HS_HEADER_LEN..total].to_vec();
    Ok(Some((Handshake { op, codec_tag, header }, total)))
}

/// The server's verdict on a handshake.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ack {
    pub ok: bool,
    /// Human-readable rejection reason (empty on ok).
    pub msg: String,
}

impl Ack {
    pub fn ok() -> Ack {
        Ack { ok: true, msg: String::new() }
    }

    pub fn err(msg: impl Into<String>) -> Ack {
        Ack { ok: false, msg: msg.into() }
    }
}

/// Serialize one ack appended to `out`.  Oversized messages are
/// truncated (on a char boundary) rather than rejected: the ack is the
/// error path, and an error about the error helps nobody.
pub fn encode_ack(ack: &Ack, out: &mut Vec<u8>) {
    let mut msg = ack.msg.as_str();
    while msg.len() > MAX_ACK_MSG {
        let mut cut = MAX_ACK_MSG;
        while !msg.is_char_boundary(cut) {
            cut -= 1;
        }
        msg = &msg[..cut];
    }
    out.reserve(ACK_HEADER_LEN + msg.len());
    out.extend_from_slice(&ACK_MAGIC);
    out.push(if ack.ok { 0 } else { 1 });
    out.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    out.extend_from_slice(msg.as_bytes());
}

/// Try to decode one ack from the front of `buf`; same tri-state
/// contract as [`decode_handshake`].
pub fn decode_ack(buf: &[u8]) -> Result<Option<(Ack, usize)>, String> {
    let probe = buf.len().min(4);
    if buf[..probe] != ACK_MAGIC[..probe] {
        return Err("bad ack magic (not a qlc serve server?)".to_string());
    }
    if buf.len() < ACK_HEADER_LEN {
        return Ok(None);
    }
    let status = buf[4];
    if status > 1 {
        return Err(format!("unknown ack status byte {status:#04x}"));
    }
    // lint: infallible(fixed 4-byte slice of the length-checked header)
    let msg_len = u32::from_le_bytes(buf[5..9].try_into().unwrap()) as usize;
    if msg_len > MAX_ACK_MSG {
        return Err(format!(
            "ack declares a {msg_len}-byte message (cap {MAX_ACK_MSG})"
        ));
    }
    let total = ACK_HEADER_LEN + msg_len;
    if buf.len() < total {
        return Ok(None);
    }
    let msg = String::from_utf8_lossy(&buf[ACK_HEADER_LEN..total]).into_owned();
    Ok(Some((Ack { ok: status == 0, msg }, total)))
}

/// Sequencing state machine for one direction of a serve connection:
/// validates that QWC1 frames arrive with the expected request (`hop`)
/// and chunk (`seq`) ordinals, the agreed codec tag, and payloads
/// under the serve caps.
///
/// Both endpoints run one per direction — the server on inbound
/// request frames, the client on inbound response frames — so a
/// desynchronized, interleaved or foreign stream is rejected at the
/// framing layer, before any codec state is touched.
#[derive(Clone, Debug)]
pub struct RequestTracker {
    codec_tag: u8,
    next_hop: u32,
    next_seq: u32,
}

impl RequestTracker {
    pub fn new(codec_tag: u8) -> RequestTracker {
        RequestTracker { codec_tag, next_hop: 0, next_seq: 0 }
    }

    /// Ordinal of the request the next frame must belong to.
    pub fn current_request(&self) -> u32 {
        self.next_hop
    }

    /// Ordinal the next frame's `seq` field must carry.
    pub fn expected_seq(&self) -> u32 {
        self.next_seq
    }

    /// Validate one inbound frame.  `Ok(true)` when the frame carries
    /// `FLAG_LAST` and completes the current request (the tracker
    /// advances to the next request ordinal), `Ok(false)` mid-request,
    /// `Err(_)` on any ordinal/tag/cap violation — after which the
    /// connection must be torn down, not resynchronized.
    pub fn accept(&mut self, frame: &WireFrame) -> Result<bool, String> {
        if frame.codec_tag != self.codec_tag {
            return Err(format!(
                "frame carries codec tag {} but the handshake agreed on {}",
                frame.codec_tag, self.codec_tag
            ));
        }
        if frame.hop != self.next_hop {
            return Err(format!(
                "frame belongs to request {} but request {} is in flight \
                 (interleaved or replayed stream?)",
                frame.hop, self.next_hop
            ));
        }
        if frame.msg.seq != self.next_seq {
            return Err(format!(
                "request {} chunk arrived with seq {} (expected {})",
                frame.hop, frame.msg.seq, self.next_seq
            ));
        }
        if frame.msg.payload.len() > MAX_REQ_PAYLOAD {
            return Err(format!(
                "request chunk payload {} exceeds the serve cap \
                 {MAX_REQ_PAYLOAD}",
                frame.msg.payload.len()
            ));
        }
        if frame.msg.n_symbols > MAX_CHUNK_SYMBOLS {
            return Err(format!(
                "request chunk declares {} symbols (serve cap \
                 {MAX_CHUNK_SYMBOLS})",
                frame.msg.n_symbols
            ));
        }
        if frame.msg.last {
            self.next_hop = self.next_hop.wrapping_add(1);
            self.next_seq = 0;
            Ok(true)
        } else {
            self.next_seq = self.next_seq.checked_add(1).ok_or_else(|| {
                "request chunk ordinal overflowed u32".to_string()
            })?;
            Ok(false)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::wire;
    use super::*;
    use crate::transport::ChunkMsg;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn hs(op: Op, tag: u8, header: &[u8]) -> Handshake {
        Handshake { op, codec_tag: tag, header: header.to_vec() }
    }

    #[test]
    fn handshake_roundtrips() {
        for (op, tag, header) in [
            (Op::Compress, 2u8, &b"\x01\x02\x03"[..]),
            (Op::Decompress, 0, &b""[..]),
            (Op::Compress, 255, &[0u8; 300][..]),
        ] {
            let mut buf = Vec::new();
            encode_handshake(&hs(op, tag, header), &mut buf).unwrap();
            let (got, used) = decode_handshake(&buf).unwrap().unwrap();
            assert_eq!(used, buf.len());
            assert_eq!(got.op, op);
            assert_eq!(got.codec_tag, tag);
            assert_eq!(got.header, header);
        }
    }

    #[test]
    fn handshake_prefixes_ask_for_more_bytes() {
        let mut buf = Vec::new();
        encode_handshake(&hs(Op::Compress, 2, b"hdr"), &mut buf).unwrap();
        for keep in 0..buf.len() {
            assert!(
                matches!(decode_handshake(&buf[..keep]), Ok(None)),
                "prefix {keep}"
            );
        }
    }

    #[test]
    fn malformed_handshakes_rejected() {
        let mut buf = Vec::new();
        encode_handshake(&hs(Op::Compress, 2, b"hdr"), &mut buf).unwrap();

        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(decode_handshake(&bad).is_err());
        assert!(decode_handshake(&bad[..1]).is_err(), "fail on first byte");

        let mut bad = buf.clone();
        bad[4] = 9; // foreign version
        assert!(decode_handshake(&bad).is_err());

        let mut bad = buf.clone();
        bad[5] = 3; // unknown op
        assert!(decode_handshake(&bad).is_err());

        // Hostile header length: Err immediately, not Ok(None) while
        // "waiting" for a megabyte that will never arrive.
        let mut bad = buf.clone();
        bad[7..11]
            .copy_from_slice(&((MAX_WIRE_HEADER as u32) + 1).to_le_bytes());
        assert!(decode_handshake(&bad).is_err());

        // QWC1 frame where a handshake belongs (client skipped the
        // handshake): wrong magic, rejected.
        let msg = ChunkMsg {
            seq: 0,
            last: true,
            n_symbols: 8,
            payload: vec![0xAB; 8],
            scales: Vec::new(),
        };
        let mut frame = Vec::new();
        wire::encode_frame(0, 2, &msg, &mut frame).unwrap();
        assert!(decode_handshake(&frame).is_err());
    }

    #[test]
    fn encode_handshake_rejects_oversized_header() {
        let mut buf = Vec::new();
        let bad = hs(Op::Compress, 2, &vec![0u8; MAX_WIRE_HEADER + 1]);
        assert!(encode_handshake(&bad, &mut buf).is_err());
        assert!(buf.is_empty());
    }

    #[test]
    fn ack_roundtrips_and_truncates() {
        for ack in [Ack::ok(), Ack::err("no such codec 'zstd'")] {
            let mut buf = Vec::new();
            encode_ack(&ack, &mut buf);
            let (got, used) = decode_ack(&buf).unwrap().unwrap();
            assert_eq!(used, buf.len());
            assert_eq!(got, ack);
        }
        // Oversized message: truncated to the cap, still decodable.
        let mut buf = Vec::new();
        encode_ack(&Ack::err("x".repeat(MAX_ACK_MSG * 2)), &mut buf);
        let (got, _) = decode_ack(&buf).unwrap().unwrap();
        assert_eq!(got.msg.len(), MAX_ACK_MSG);
    }

    #[test]
    fn malformed_acks_rejected() {
        let mut buf = Vec::new();
        encode_ack(&Ack::ok(), &mut buf);

        let mut bad = buf.clone();
        bad[0] = b'Q';
        bad[1] = b'W'; // QWC1-ish magic
        assert!(decode_ack(&bad).is_err());

        let mut bad = buf.clone();
        bad[4] = 2; // unknown status
        assert!(decode_ack(&bad).is_err());

        let mut bad = buf.clone();
        bad[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_ack(&bad).is_err());

        for keep in 0..buf.len() {
            assert!(matches!(decode_ack(&buf[..keep]), Ok(None)));
        }
    }

    fn chunk(seq: u32, last: bool, n: usize) -> ChunkMsg {
        ChunkMsg {
            seq,
            last,
            n_symbols: n,
            payload: vec![0x5A; n],
            scales: Vec::new(),
        }
    }

    fn frame_of(hop: u32, tag: u8, msg: &ChunkMsg) -> WireFrame {
        WireFrame { hop, codec_tag: tag, msg: msg.clone() }
    }

    #[test]
    fn tracker_walks_requests_in_order() {
        let mut t = RequestTracker::new(2);
        assert_eq!(t.current_request(), 0);
        // Request 0: three chunks.
        assert!(!t.accept(&frame_of(0, 2, &chunk(0, false, 4))).unwrap());
        assert!(!t.accept(&frame_of(0, 2, &chunk(1, false, 4))).unwrap());
        assert!(t.accept(&frame_of(0, 2, &chunk(2, true, 4))).unwrap());
        assert_eq!(t.current_request(), 1);
        assert_eq!(t.expected_seq(), 0);
        // Request 1: single-chunk.
        assert!(t.accept(&frame_of(1, 2, &chunk(0, true, 1))).unwrap());
        assert_eq!(t.current_request(), 2);
    }

    #[test]
    fn tracker_rejects_desync_and_oversize() {
        let mut t = RequestTracker::new(2);
        // Foreign codec tag.
        assert!(t.accept(&frame_of(0, 1, &chunk(0, true, 1))).is_err());
        // Interleaved request (hop from the future).
        assert!(t.accept(&frame_of(1, 2, &chunk(0, true, 1))).is_err());
        // Wrong chunk ordinal.
        assert!(t.accept(&frame_of(0, 2, &chunk(7, false, 1))).is_err());
        // Over the serve payload cap (declared, not allocated here —
        // the tracker is exactly the pre-allocation gate).
        let mut big = chunk(0, false, 1);
        big.payload = vec![0u8; MAX_REQ_PAYLOAD + 1];
        big.n_symbols = big.payload.len();
        assert!(t.accept(&frame_of(0, 2, &big)).is_err());
        // Errors do not advance the tracker.
        assert_eq!(t.current_request(), 0);
        assert_eq!(t.expected_seq(), 0);
        // A well-formed frame still goes through afterwards.
        assert!(t.accept(&frame_of(0, 2, &chunk(0, true, 1))).unwrap());
    }

    #[test]
    fn prop_corrupt_serve_streams_never_panic() {
        // Fuzz the whole serve read path the way `qlc serve` runs it:
        // a handshake followed by request frames, under bit flips,
        // truncations and junk splices.  Every outcome must be
        // "incomplete", "clean parse" or `Err` — never a panic, never
        // consuming more bytes than the buffer holds.
        prop::check(
            "serve stream fuzz",
            prop::Config { cases: 96, ..Default::default() },
            |rng, size| {
                let tag = rng.below(7) as u8;
                let header: Vec<u8> = {
                    let mut h = vec![0u8; rng.below(24) as usize];
                    rng.fill_bytes(&mut h);
                    h
                };
                let op =
                    if rng.below(2) == 0 { Op::Compress } else { Op::Decompress };
                let mut stream = Vec::new();
                encode_handshake(&hs(op, tag, &header), &mut stream)
                    .map_err(|e| e.to_string())?;
                // Two requests, a few chunks each.
                for hop in 0..2u32 {
                    let n_chunks = 1 + rng.below(3) as u32;
                    for seq in 0..n_chunks {
                        let n = 1 + rng.below(size.max(1) as u64) as usize;
                        let msg = chunk(seq, seq + 1 == n_chunks, n);
                        wire::encode_frame(hop, tag, &msg, &mut stream)
                            .map_err(|e| e.to_string())?;
                    }
                }
                for _ in 0..12 {
                    let mut corrupt = stream.clone();
                    match rng.below(3) {
                        0 => {
                            let i = rng.below(corrupt.len() as u64) as usize;
                            corrupt[i] ^= 1 << rng.below(8);
                        }
                        1 => {
                            let keep = rng.below(corrupt.len() as u64) as usize;
                            corrupt.truncate(keep);
                        }
                        _ => {
                            let i = rng.below(corrupt.len() as u64) as usize;
                            let mut junk = vec![0u8; 6.min(corrupt.len() - i)];
                            rng.fill_bytes(&mut junk);
                            corrupt[i..i + junk.len()].copy_from_slice(&junk);
                        }
                    }
                    drive_serve_parse(&corrupt)?;
                }
                // The uncorrupted stream must parse to completion.
                let (consumed, requests) = drive_serve_parse(&stream)?;
                if consumed != stream.len() || requests != 2 {
                    return Err(format!(
                        "clean stream: consumed {consumed}/{} bytes, \
                         {requests} requests",
                        stream.len()
                    ));
                }
                Ok(())
            },
        );
    }

    /// The server's framing loop in miniature: handshake, then frames
    /// through a [`RequestTracker`].  Returns (bytes consumed,
    /// requests completed); `Err` strings describe contract
    /// violations (never panics).
    fn drive_serve_parse(buf: &[u8]) -> Result<(usize, u32), String> {
        let mut pos = 0usize;
        let (hs, used) = match decode_handshake(buf) {
            Ok(Some(v)) => v,
            Ok(None) => return Ok((0, 0)),
            Err(_) => return Ok((0, 0)), // rejected: connection torn down
        };
        pos += used;
        let mut tracker = RequestTracker::new(hs.codec_tag);
        let mut done = 0u32;
        loop {
            match wire::decode_frame(&buf[pos..]) {
                Ok(Some((frame, used))) => {
                    if pos + used > buf.len() {
                        return Err(format!(
                            "frame consumed {used} bytes at {pos} of {}",
                            buf.len()
                        ));
                    }
                    pos += used;
                    match tracker.accept(&frame) {
                        Ok(true) => done += 1,
                        Ok(false) => {}
                        Err(_) => break, // torn down
                    }
                }
                Ok(None) | Err(_) => break,
            }
        }
        Ok((pos, done))
    }
}
