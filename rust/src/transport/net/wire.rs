//! Length-prefixed wire protocol for chunk hops (format QWC1).
//!
//! One [`ChunkMsg`] travels as one frame:
//!
//! ```text
//! magic "QWC1" | flags u8 (bit0 = last chunk of this hop) |
//! codec_tag u8 | hop u32 | seq u32 | n_symbols u32 | n_scales u32 |
//! payload_len u32 | payload bytes… | scales (f32 LE × n_scales)
//! ```
//!
//! All integers are little-endian.  The header is fixed-size
//! ([`HEADER_LEN`] bytes) and fully self-delimiting: `payload_len` and
//! `n_scales` bound the variable tail, so a receiver can frame a byte
//! stream without peeking past the current record.
//!
//! Validation is strict and `Err`-returning, never panicking: bad
//! magic, unknown flag bits, lengths over the hard caps, and symbol
//! counts that cannot fit the payload (every codec in the registry
//! emits ≥ 1 bit per symbol) are all rejected *before* any allocation
//! sized by untrusted fields.  [`decode_frame`] distinguishes "frame
//! incomplete, read more bytes" (`Ok(None)`) from corruption (`Err`).

use crate::transport::ChunkMsg;

pub const MAGIC: [u8; 4] = *b"QWC1";
/// Fixed frame header: magic, flags, codec tag, hop, seq, n_symbols,
/// n_scales, payload_len.
pub const HEADER_LEN: usize = 4 + 1 + 1 + 4 + 4 + 4 + 4 + 4;
/// Flag bit: this is the final chunk of its hop.
pub const FLAG_LAST: u8 = 1;
/// Hard cap on a single chunk payload (1 GiB).  A hostile header can
/// therefore never force more than this in buffering.
pub const MAX_PAYLOAD_BYTES: usize = 1 << 30;
/// Hard cap on per-chunk shared scales (2^26 blocks = 2 Gi symbols).
pub const MAX_SCALES: usize = 1 << 26;

/// A decoded wire frame: the transported [`ChunkMsg`] plus the framing
/// identity the link layer validates (hop ordinal, codec tag).
#[derive(Clone, Debug)]
pub struct WireFrame {
    /// Hop ordinal on this link (increments after each `last` chunk).
    pub hop: u32,
    /// Wire tag of the transport codec both endpoints agreed on.
    pub codec_tag: u8,
    pub msg: ChunkMsg,
}

/// Shared sanity rule: a chunk that declares `n_symbols` must carry at
/// least one bit per symbol, and a zero-symbol chunk carries no
/// payload at all.
fn check_symbol_payload(n_symbols: usize, payload_len: usize) -> Result<(), String> {
    if n_symbols == 0 && payload_len != 0 {
        return Err(format!(
            "frame declares 0 symbols but {payload_len} payload bytes"
        ));
    }
    if n_symbols as u64 > payload_len as u64 * 8 {
        return Err(format!(
            "frame declares {n_symbols} symbols in {payload_len} payload \
             bytes (< 1 bit/symbol)"
        ));
    }
    Ok(())
}

/// Serialize `msg` as one wire frame appended to `out`.
pub fn encode_frame(
    hop: u32,
    codec_tag: u8,
    msg: &ChunkMsg,
    out: &mut Vec<u8>,
) -> Result<(), String> {
    if msg.payload.len() > MAX_PAYLOAD_BYTES {
        return Err(format!(
            "chunk payload {} exceeds the {MAX_PAYLOAD_BYTES}-byte frame cap",
            msg.payload.len()
        ));
    }
    if msg.scales.len() > MAX_SCALES {
        return Err(format!(
            "chunk carries {} scales (cap {MAX_SCALES})",
            msg.scales.len()
        ));
    }
    if msg.n_symbols > u32::MAX as usize {
        return Err(format!(
            "chunk symbol count {} overflows the u32 frame field",
            msg.n_symbols
        ));
    }
    check_symbol_payload(msg.n_symbols, msg.payload.len())?;
    out.reserve(HEADER_LEN + msg.payload.len() + msg.scales.len() * 4);
    out.extend_from_slice(&MAGIC);
    out.push(if msg.last { FLAG_LAST } else { 0 });
    out.push(codec_tag);
    out.extend_from_slice(&hop.to_le_bytes());
    out.extend_from_slice(&msg.seq.to_le_bytes());
    out.extend_from_slice(&(msg.n_symbols as u32).to_le_bytes());
    out.extend_from_slice(&(msg.scales.len() as u32).to_le_bytes());
    out.extend_from_slice(&(msg.payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&msg.payload);
    for s in &msg.scales {
        out.extend_from_slice(&s.to_le_bytes());
    }
    Ok(())
}

/// Try to decode one frame from the front of `buf`.
///
/// * `Ok(Some((frame, consumed)))` — a complete, valid frame;
///   `consumed` bytes belong to it.
/// * `Ok(None)` — the (so-far valid) frame is incomplete; read more.
/// * `Err(_)` — the stream is corrupt and the link must be torn down.
///
/// Header fields are validated before the payload is complete, so a
/// hostile length never buffers more than [`MAX_PAYLOAD_BYTES`].
pub fn decode_frame(buf: &[u8]) -> Result<Option<(WireFrame, usize)>, String> {
    // Reject a wrong magic as soon as the first bytes disagree — a
    // desynchronized stream fails fast instead of waiting on a bogus
    // "length".
    let probe = buf.len().min(4);
    if buf[..probe] != MAGIC[..probe] {
        return Err("bad frame magic (stream desynchronized?)".to_string());
    }
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    let flags = buf[4];
    if flags & !FLAG_LAST != 0 {
        return Err(format!("unknown frame flag bits {flags:#04x}"));
    }
    let codec_tag = buf[5];
    // lint: infallible(fixed 4-byte slices; HEADER_LEN checked above)
    let hop = u32::from_le_bytes(buf[6..10].try_into().unwrap());
    let seq = u32::from_le_bytes(buf[10..14].try_into().unwrap());
    let n_symbols = u32::from_le_bytes(buf[14..18].try_into().unwrap()) as usize;
    let n_scales = u32::from_le_bytes(buf[18..22].try_into().unwrap()) as usize;
    // lint: infallible(fixed 4-byte slice of the length-checked header)
    let payload_len =
        u32::from_le_bytes(buf[22..26].try_into().unwrap()) as usize;
    if payload_len > MAX_PAYLOAD_BYTES {
        return Err(format!(
            "frame payload length {payload_len} exceeds the \
             {MAX_PAYLOAD_BYTES}-byte cap"
        ));
    }
    if n_scales > MAX_SCALES {
        return Err(format!("frame scale count {n_scales} exceeds cap"));
    }
    check_symbol_payload(n_symbols, payload_len)?;
    let total = HEADER_LEN + payload_len + n_scales * 4;
    if buf.len() < total {
        return Ok(None);
    }
    let payload = buf[HEADER_LEN..HEADER_LEN + payload_len].to_vec();
    let mut scales = Vec::with_capacity(n_scales);
    for c in buf[HEADER_LEN + payload_len..total].chunks_exact(4) {
        // lint: infallible(chunks_exact(4) yields 4-byte slices)
        scales.push(f32::from_le_bytes(c.try_into().unwrap()));
    }
    let frame = WireFrame {
        hop,
        codec_tag,
        msg: ChunkMsg {
            seq,
            last: flags & FLAG_LAST != 0,
            n_symbols,
            payload,
            scales,
        },
    };
    Ok(Some((frame, total)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn arb_msg(rng: &mut Rng, size: usize) -> ChunkMsg {
        let n_payload = rng.below(size as u64 + 1) as usize;
        let mut payload = vec![0u8; n_payload];
        rng.fill_bytes(&mut payload);
        // Any count the ≥1-bit rule admits (0 symbols ⇒ 0 payload).
        let n_symbols = if n_payload == 0 {
            0
        } else {
            1 + rng.below((n_payload as u64 * 8).min(u32::MAX as u64)) as usize
        };
        let scales: Vec<f32> = (0..rng.below(9))
            .map(|i| i as f32 * 0.5 - 1.0)
            .collect();
        ChunkMsg {
            seq: rng.below(1 << 20) as u32,
            last: rng.below(2) == 0,
            n_symbols,
            payload,
            scales,
        }
    }

    fn assert_msg_eq(a: &ChunkMsg, b: &ChunkMsg) {
        assert_eq!(a.seq, b.seq);
        assert_eq!(a.last, b.last);
        assert_eq!(a.n_symbols, b.n_symbols);
        assert_eq!(a.payload, b.payload);
        assert_eq!(a.scales, b.scales);
    }

    #[test]
    fn frame_roundtrips() {
        let mut rng = Rng::new(1);
        for case in 0..64 {
            let msg = arb_msg(&mut rng, 1 + case * 7);
            let mut buf = Vec::new();
            encode_frame(case as u32, 2, &msg, &mut buf).unwrap();
            let (frame, used) = decode_frame(&buf).unwrap().unwrap();
            assert_eq!(used, buf.len(), "case {case}");
            assert_eq!(frame.hop, case as u32);
            assert_eq!(frame.codec_tag, 2);
            assert_msg_eq(&frame.msg, &msg);
        }
    }

    #[test]
    fn back_to_back_frames_consume_exactly() {
        let mut rng = Rng::new(2);
        let a = arb_msg(&mut rng, 100);
        let b = arb_msg(&mut rng, 50);
        let mut buf = Vec::new();
        encode_frame(0, 1, &a, &mut buf).unwrap();
        let first_len = buf.len();
        encode_frame(0, 1, &b, &mut buf).unwrap();
        let (fa, ua) = decode_frame(&buf).unwrap().unwrap();
        assert_eq!(ua, first_len);
        assert_msg_eq(&fa.msg, &a);
        let (fb, ub) = decode_frame(&buf[ua..]).unwrap().unwrap();
        assert_eq!(ua + ub, buf.len());
        assert_msg_eq(&fb.msg, &b);
    }

    #[test]
    fn truncated_frames_ask_for_more_bytes() {
        let msg = ChunkMsg {
            seq: 3,
            last: true,
            n_symbols: 8,
            payload: vec![7u8; 8],
            scales: vec![1.5],
        };
        let mut buf = Vec::new();
        encode_frame(1, 2, &msg, &mut buf).unwrap();
        // Every proper prefix is "incomplete", never Err, never panic.
        for keep in 0..buf.len() {
            assert!(
                matches!(decode_frame(&buf[..keep]), Ok(None)),
                "prefix {keep}"
            );
        }
        assert!(decode_frame(&buf).unwrap().is_some());
    }

    #[test]
    fn bad_magic_and_flags_rejected() {
        let msg = ChunkMsg {
            seq: 0,
            last: false,
            n_symbols: 1,
            payload: vec![0xAA],
            scales: Vec::new(),
        };
        let mut buf = Vec::new();
        encode_frame(0, 0, &msg, &mut buf).unwrap();

        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(decode_frame(&bad).is_err());
        // A wrong magic fails even on a one-byte prefix.
        assert!(decode_frame(&bad[..1]).is_err());

        let mut bad = buf.clone();
        bad[4] |= 0x80; // unknown flag bit
        assert!(decode_frame(&bad).is_err());
    }

    #[test]
    fn hostile_lengths_rejected_before_buffering() {
        let msg = ChunkMsg {
            seq: 0,
            last: true,
            n_symbols: 4,
            payload: vec![1, 2, 3, 4],
            scales: Vec::new(),
        };
        let mut buf = Vec::new();
        encode_frame(0, 1, &msg, &mut buf).unwrap();

        // Payload length over the cap: Err even though the bytes for
        // it are "missing" (no Ok(None) stall on a hostile length).
        let mut bad = buf.clone();
        bad[22..26]
            .copy_from_slice(&((MAX_PAYLOAD_BYTES as u32) + 1).to_le_bytes());
        assert!(decode_frame(&bad).is_err());

        // Scale count over the cap.
        let mut bad = buf.clone();
        bad[18..22].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_frame(&bad).is_err());

        // More symbols than payload bits.
        let mut bad = buf.clone();
        bad[14..18].copy_from_slice(&1000u32.to_le_bytes());
        assert!(decode_frame(&bad).is_err());

        // Symbols declared with an empty payload.
        let mut bad = buf;
        bad[14..18].copy_from_slice(&1u32.to_le_bytes());
        bad[22..26].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_frame(&bad).is_err());
    }

    #[test]
    fn encode_rejects_overflowing_messages() {
        let mut out = Vec::new();
        // Symbol count that cannot fit the payload.
        let msg = ChunkMsg {
            seq: 0,
            last: false,
            n_symbols: 9,
            payload: vec![0u8; 1],
            scales: Vec::new(),
        };
        assert!(encode_frame(0, 0, &msg, &mut out).is_err());
        // Zero symbols with a non-empty payload.
        let msg = ChunkMsg {
            seq: 0,
            last: false,
            n_symbols: 0,
            payload: vec![0u8; 1],
            scales: Vec::new(),
        };
        assert!(encode_frame(0, 0, &msg, &mut out).is_err());
        assert!(out.is_empty(), "failed encodes must not emit bytes");
    }

    #[test]
    fn prop_corrupt_frames_never_panic() {
        // Fuzz the validator: bit flips, truncations and garbage
        // splices must yield Ok(None), Ok(frame) or Err — never a
        // panic, and never a frame larger than the buffer claims.
        prop::check(
            "wire frame fuzz",
            prop::Config { cases: 96, ..Default::default() },
            |rng, size| {
                let msg = arb_msg(rng, size.max(4));
                let mut buf = Vec::new();
                encode_frame(
                    rng.below(1 << 16) as u32,
                    rng.below(7) as u8,
                    &msg,
                    &mut buf,
                )
                .map_err(|e| e.to_string())?;
                for _ in 0..16 {
                    let mut corrupt = buf.clone();
                    match rng.below(3) {
                        0 => {
                            let i = rng.below(corrupt.len() as u64) as usize;
                            corrupt[i] ^= 1 << rng.below(8);
                        }
                        1 => {
                            let keep =
                                rng.below(corrupt.len() as u64) as usize;
                            corrupt.truncate(keep);
                        }
                        _ => {
                            let i = rng.below(corrupt.len() as u64) as usize;
                            let mut junk =
                                vec![0u8; 8.min(corrupt.len() - i)];
                            rng.fill_bytes(&mut junk);
                            corrupt[i..i + junk.len()]
                                .copy_from_slice(&junk);
                        }
                    }
                    match decode_frame(&corrupt) {
                        Ok(Some((_, used))) => {
                            if used > corrupt.len() {
                                return Err(format!(
                                    "consumed {used} of {} bytes",
                                    corrupt.len()
                                ));
                            }
                        }
                        Ok(None) | Err(_) => {}
                    }
                }
                Ok(())
            },
        );
    }
}
