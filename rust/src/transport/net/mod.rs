//! Multi-host TCP transport: the third [`Link`](crate::transport::Link)
//! backend, after the fabric simulator and the threaded channels.
//!
//! Three layers, bottom-up:
//!
//! * [`wire`] — the length-prefixed QWC1 frame protocol: one
//!   [`ChunkMsg`](crate::transport::ChunkMsg) per frame, strict
//!   `Err`-returning validation, hard caps on every untrusted length;
//! * [`serve_wire`] — the `qlc serve` session layer over QWC1: the
//!   QSV1 handshake / QSA1 ack formats and the [`RequestTracker`]
//!   request/chunk sequencing state machine (see
//!   [`crate::serve`] for the event-driven server built on them);
//! * [`tcp`] — [`TcpLink`], the [`Link`](crate::transport::Link)
//!   implementation over non-blocking [`std::net::TcpStream`] pairs
//!   with read/write buffering, bidirectional pumping (no deadlock on
//!   mutual whole-payload sends) and configurable progress timeouts;
//! * [`rendezvous`] — [`form_ring`]: rank 0 listens, ranks connect and
//!   exchange ring-listener addresses, every rank wires sockets to its
//!   ring neighbours.
//!
//! The payoff: `N` OS processes run the exact lockstep chunk exchange
//! the threaded engine runs on channels, so the overlap of decode(k)
//! with transfer(k+1) is measured wall time over real sockets — see
//! [`crate::collective::dist`] and the `qlc worker` / `qlc launch`
//! subcommands.

pub mod rendezvous;
pub mod serve_wire;
pub mod tcp;
pub mod wire;

pub use rendezvous::form_ring;
pub use serve_wire::RequestTracker;
pub use tcp::{NetConfig, TcpLink};
pub use wire::WireFrame;
