//! Readiness-based event loop backends behind the [`Reactor`] trait.
//!
//! The sleep-poll pump the TCP link shipped with (fixed
//! `thread::sleep` between `WouldBlock` passes) burns a syscall and a
//! scheduler round-trip per idle pass and puts a hard floor under hop
//! latency.  A reactor replaces that with *readiness waits*: callers
//! register the file descriptors they are blocked on and `wait` parks
//! the thread until the kernel reports one of them readable/writable
//! (or a timeout passes).
//!
//! Two backends:
//!
//! * [`EpollReactor`] — Linux `epoll` via raw syscalls.  The crate is
//!   dependency-free by policy, so the four syscalls are declared as
//!   `extern "C"` bindings against the libc that `std` already links;
//!   no crate is added.  Level-triggered, so a spurious or stale
//!   readiness report at worst costs one `WouldBlock` pass — exactly
//!   the idiom every caller already implements.
//! * [`BackoffReactor`] — the portable fallback: it cannot ask the
//!   kernel about readiness, so `wait` sleeps with capped exponential
//!   backoff and then reports **every** registered descriptor as ready
//!   per its interest.  That over-approximation is safe for the same
//!   reason spurious epoll wakeups are: callers retry and absorb
//!   `WouldBlock`.  [`Reactor::note_progress`] resets the backoff so a
//!   fresh stall starts at the short end of the curve.
//!
//! The contract every backend upholds (documented for implementors and
//! relied on by `TcpLink` and `qlc serve`):
//!
//! 1. `wait` may return spuriously (extra events, or none); callers
//!    must re-attempt their non-blocking I/O and treat `WouldBlock`
//!    as "wait again".
//! 2. Writable interest should be registered only while output is
//!    actually queued — a mostly-writable socket would otherwise turn
//!    level-triggered `wait` into a busy loop.
//! 3. `wait` returns `true` iff it *slept* instead of parking on
//!    kernel readiness — the signal the link layer uses to keep the
//!    `tcp_poll_sleeps_total` accounting honest per backend.
//! 4. Error/hangup conditions are reported as readable+writable so the
//!    caller's next `read`/`write` surfaces the real `io::Error`.

use std::time::Duration;

#[cfg(unix)]
pub use std::os::fd::RawFd;
#[cfg(not(unix))]
pub type RawFd = i32;

/// Which readiness kinds a registration asks for.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READABLE: Interest =
        Interest { readable: true, writable: false };
    pub const WRITABLE: Interest =
        Interest { readable: false, writable: true };
    pub const BOTH: Interest = Interest { readable: true, writable: true };
    pub const NONE: Interest = Interest { readable: false, writable: false };
}

/// One readiness report from [`Reactor::wait`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// The token the descriptor was registered under.
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// A readiness-wait backend.  See the module docs for the contract.
/// `Send` is a supertrait so reactor-driven endpoints (links, the
/// serve loop, clients) can move onto worker threads.
pub trait Reactor: Send {
    /// Start watching `fd` under `token`.
    fn register(
        &mut self,
        fd: RawFd,
        token: u64,
        interest: Interest,
    ) -> Result<(), String>;

    /// Change the interest set of an already-registered `fd`.
    fn reregister(
        &mut self,
        fd: RawFd,
        token: u64,
        interest: Interest,
    ) -> Result<(), String>;

    /// Stop watching `fd`.
    fn deregister(&mut self, fd: RawFd) -> Result<(), String>;

    /// Park until something registered is ready or `timeout` passes.
    /// Appends the ready set to `events` (cleared first).  Returns
    /// `true` iff the backend *slept* rather than parking on kernel
    /// readiness (the fallback path).
    fn wait(
        &mut self,
        events: &mut Vec<Event>,
        timeout: Duration,
    ) -> Result<bool, String>;

    /// Hint that the caller made forward progress since the last
    /// `wait` — resets the fallback's backoff curve.  No-op on
    /// kernel-readiness backends.
    fn note_progress(&mut self) {}

    /// Backend name for metric labels and diagnostics.
    fn name(&self) -> &'static str;
}

/// Reactor backend selector (the CLI's `--reactor` vocabulary).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// Epoll where the platform supports it, fallback otherwise.
    #[default]
    Auto,
    Epoll,
    Fallback,
}

impl Backend {
    pub fn parse(name: &str) -> Result<Backend, String> {
        match name {
            "auto" => Ok(Backend::Auto),
            "epoll" => Ok(Backend::Epoll),
            "fallback" => Ok(Backend::Fallback),
            other => Err(format!(
                "unknown reactor backend '{other}' (expected \
                 auto|epoll|fallback)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::Epoll => "epoll",
            Backend::Fallback => "fallback",
        }
    }
}

/// Whether the epoll backend can be constructed on this platform.
pub fn epoll_available() -> bool {
    #[cfg(target_os = "linux")]
    {
        EpollReactor::new().is_ok()
    }
    #[cfg(not(target_os = "linux"))]
    {
        false
    }
}

/// Build a reactor for `backend`.  `Auto` resolves to epoll on Linux
/// and the backoff fallback elsewhere (or if epoll setup fails, e.g.
/// under an fd-exhausted or seccomp-restricted process).
pub fn new_reactor(backend: Backend) -> Result<Box<dyn Reactor>, String> {
    match backend {
        Backend::Fallback => Ok(Box::new(BackoffReactor::new())),
        Backend::Epoll => {
            #[cfg(target_os = "linux")]
            {
                Ok(Box::new(EpollReactor::new()?))
            }
            #[cfg(not(target_os = "linux"))]
            {
                Err("the epoll reactor backend is Linux-only".to_string())
            }
        }
        Backend::Auto => {
            #[cfg(target_os = "linux")]
            {
                match EpollReactor::new() {
                    Ok(r) => Ok(Box::new(r)),
                    Err(_) => Ok(Box::new(BackoffReactor::new())),
                }
            }
            #[cfg(not(target_os = "linux"))]
            {
                Ok(Box::new(BackoffReactor::new()))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Epoll backend (Linux)

#[cfg(target_os = "linux")]
pub use epoll::EpollReactor;

#[cfg(target_os = "linux")]
mod epoll {
    use super::{Event, Interest, RawFd, Reactor};
    use std::io::ErrorKind;
    use std::time::Duration;

    // The crate links no external crates, but std already links libc;
    // declaring the four epoll entry points directly keeps the
    // zero-dependency policy intact.
    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(
            epfd: i32,
            op: i32,
            fd: i32,
            event: *mut EpollEvent,
        ) -> i32;
        fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout_ms: i32,
        ) -> i32;
        fn close(fd: i32) -> i32;
    }

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    /// `struct epoll_event` ABI: packed on x86-64 (the kernel chose a
    /// packed layout there for 32/64-bit compat), natural alignment on
    /// every other architecture.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    /// Readiness waits via Linux `epoll`, level-triggered.
    pub struct EpollReactor {
        epfd: i32,
        /// Scratch buffer reused across `wait` calls.
        buf: Vec<EpollEvent>,
    }

    fn interest_mask(interest: Interest) -> u32 {
        let mut mask = EPOLLRDHUP;
        if interest.readable {
            mask |= EPOLLIN;
        }
        if interest.writable {
            mask |= EPOLLOUT;
        }
        mask
    }

    fn os_err(call: &str) -> String {
        format!("{call}: {}", std::io::Error::last_os_error())
    }

    impl EpollReactor {
        pub fn new() -> Result<EpollReactor, String> {
            // SAFETY: epoll_create1 takes a flags integer and returns
            // a new fd or -1; no pointers are involved.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(os_err("epoll_create1"));
            }
            Ok(EpollReactor {
                epfd,
                buf: vec![EpollEvent { events: 0, data: 0 }; 64],
            })
        }

        fn ctl(
            &mut self,
            op: i32,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> Result<(), String> {
            let mut ev =
                EpollEvent { events: interest_mask(interest), data: token };
            // SAFETY: `ev` is a live, properly initialized
            // repr(C)-compatible epoll_event for the duration of the
            // call; the kernel copies it before returning.  DEL
            // ignores the pointer but a non-null one is valid on every
            // kernel (pre-2.6.9 required it).
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(os_err("epoll_ctl"));
            }
            Ok(())
        }
    }

    impl Reactor for EpollReactor {
        fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> Result<(), String> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        fn reregister(
            &mut self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> Result<(), String> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        fn deregister(&mut self, fd: RawFd) -> Result<(), String> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, Interest::NONE)
        }

        fn wait(
            &mut self,
            events: &mut Vec<Event>,
            timeout: Duration,
        ) -> Result<bool, String> {
            events.clear();
            // Millisecond resolution; a sub-millisecond remainder must
            // not round down to "poll and spin", so round it up.
            let ms = timeout.as_millis();
            let ms = if ms > i32::MAX as u128 {
                i32::MAX
            } else if ms == 0 && !timeout.is_zero() {
                1
            } else {
                ms as i32 // lint: cast-checked(clamped to i32::MAX above)
            };
            let cap = self.buf.len() as i32; // lint: cast-checked(fixed 64-slot scratch)
            // SAFETY: `buf` is a live, writable slice of `cap`
            // epoll_event slots for the duration of the call; the
            // kernel writes at most `cap` entries.
            let n = unsafe {
                epoll_wait(self.epfd, self.buf.as_mut_ptr(), cap, ms)
            };
            if n < 0 {
                let err = std::io::Error::last_os_error();
                if err.kind() == ErrorKind::Interrupted {
                    // EINTR: report an empty ready set; callers loop.
                    return Ok(false);
                }
                return Err(format!("epoll_wait: {err}"));
            }
            for slot in self.buf.iter().take(n as usize) {
                // Copy out of the (possibly packed) struct before use.
                let mask = slot.events;
                let token = slot.data;
                let fatal = mask & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0;
                events.push(Event {
                    token,
                    // Errors/hangups surface as ready-on-everything so
                    // the caller's next I/O call reads the real error.
                    readable: mask & EPOLLIN != 0 || fatal,
                    writable: mask & EPOLLOUT != 0 || fatal,
                });
            }
            Ok(false)
        }

        fn name(&self) -> &'static str {
            "epoll"
        }
    }

    impl Drop for EpollReactor {
        fn drop(&mut self) {
            // SAFETY: `epfd` is a valid fd owned exclusively by this
            // reactor; closing it once on drop cannot double-close.
            unsafe {
                close(self.epfd);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Portable fallback

/// Shortest fallback sleep — one scheduler quantum's worth of poll.
const BACKOFF_MIN: Duration = Duration::from_micros(50);
/// Backoff cap: bounds worst-case added latency once a stream goes
/// idle, while keeping the idle duty cycle ~zero.
const BACKOFF_MAX: Duration = Duration::from_millis(5);

/// The portable readiness "wait": capped exponential backoff sleeps
/// that report every registered descriptor as ready per its interest.
/// Safe because callers absorb spurious readiness as `WouldBlock`.
pub struct BackoffReactor {
    registered: Vec<(RawFd, u64, Interest)>,
    backoff: Duration,
}

impl BackoffReactor {
    pub fn new() -> BackoffReactor {
        BackoffReactor { registered: Vec::new(), backoff: BACKOFF_MIN }
    }

    /// The next sleep this reactor would take (test introspection).
    pub fn current_backoff(&self) -> Duration {
        self.backoff
    }
}

impl Default for BackoffReactor {
    fn default() -> BackoffReactor {
        BackoffReactor::new()
    }
}

impl Reactor for BackoffReactor {
    fn register(
        &mut self,
        fd: RawFd,
        token: u64,
        interest: Interest,
    ) -> Result<(), String> {
        if self.registered.iter().any(|&(f, _, _)| f == fd) {
            return Err(format!("fd {fd} is already registered"));
        }
        self.registered.push((fd, token, interest));
        Ok(())
    }

    fn reregister(
        &mut self,
        fd: RawFd,
        token: u64,
        interest: Interest,
    ) -> Result<(), String> {
        for slot in self.registered.iter_mut() {
            if slot.0 == fd {
                *slot = (fd, token, interest);
                return Ok(());
            }
        }
        Err(format!("fd {fd} is not registered"))
    }

    fn deregister(&mut self, fd: RawFd) -> Result<(), String> {
        let before = self.registered.len();
        self.registered.retain(|&(f, _, _)| f != fd);
        if self.registered.len() == before {
            return Err(format!("fd {fd} is not registered"));
        }
        Ok(())
    }

    fn wait(
        &mut self,
        events: &mut Vec<Event>,
        timeout: Duration,
    ) -> Result<bool, String> {
        events.clear();
        let nap = self.backoff.min(timeout);
        if !nap.is_zero() {
            std::thread::sleep(nap);
        }
        self.backoff = (self.backoff * 2).min(BACKOFF_MAX);
        for &(_, token, interest) in &self.registered {
            if interest.readable || interest.writable {
                events.push(Event {
                    token,
                    readable: interest.readable,
                    writable: interest.writable,
                });
            }
        }
        Ok(true)
    }

    fn note_progress(&mut self) {
        self.backoff = BACKOFF_MIN;
    }

    fn name(&self) -> &'static str {
        "fallback"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn backend_names_parse_and_roundtrip() {
        for b in [Backend::Auto, Backend::Epoll, Backend::Fallback] {
            assert_eq!(Backend::parse(b.name()).unwrap(), b);
        }
        assert!(Backend::parse("kqueue").is_err());
        assert_eq!(Backend::default(), Backend::Auto);
    }

    #[test]
    fn auto_reactor_always_constructs() {
        let r = new_reactor(Backend::Auto).unwrap();
        if cfg!(target_os = "linux") {
            assert_eq!(r.name(), "epoll");
        } else {
            assert_eq!(r.name(), "fallback");
        }
    }

    #[test]
    fn fallback_reports_registered_interest_and_backs_off() {
        let mut r = BackoffReactor::new();
        r.register(7, 42, Interest::READABLE).unwrap();
        r.register(8, 43, Interest::NONE).unwrap();
        let mut events = Vec::new();
        let slept = r.wait(&mut events, Duration::from_micros(200)).unwrap();
        assert!(slept);
        // Interest::NONE registrations are silent; the rest are
        // reported exactly per their interest.
        assert_eq!(
            events,
            vec![Event { token: 42, readable: true, writable: false }]
        );
        // Exponential growth, capped, reset on progress.
        let b0 = r.current_backoff();
        r.wait(&mut events, Duration::ZERO).unwrap();
        assert!(r.current_backoff() > b0);
        for _ in 0..16 {
            r.wait(&mut events, Duration::ZERO).unwrap();
        }
        assert_eq!(r.current_backoff(), BACKOFF_MAX);
        r.note_progress();
        assert_eq!(r.current_backoff(), BACKOFF_MIN);
    }

    #[test]
    fn fallback_registration_bookkeeping() {
        let mut r = BackoffReactor::new();
        r.register(3, 1, Interest::BOTH).unwrap();
        assert!(r.register(3, 2, Interest::BOTH).is_err());
        r.reregister(3, 2, Interest::WRITABLE).unwrap();
        let mut events = Vec::new();
        r.wait(&mut events, Duration::ZERO).unwrap();
        assert_eq!(
            events,
            vec![Event { token: 2, readable: false, writable: true }]
        );
        r.deregister(3).unwrap();
        assert!(r.deregister(3).is_err());
        assert!(r.reregister(3, 1, Interest::BOTH).is_err());
        r.wait(&mut events, Duration::ZERO).unwrap();
        assert!(events.is_empty());
    }

    #[cfg(target_os = "linux")]
    mod linux {
        use super::*;
        use std::io::{Read, Write};
        use std::net::{TcpListener, TcpStream};
        use std::os::fd::AsRawFd;

        fn pair() -> (TcpStream, TcpStream) {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let a = TcpStream::connect(addr).unwrap();
            let (b, _) = listener.accept().unwrap();
            (a, b)
        }

        #[test]
        fn epoll_is_available_here() {
            assert!(epoll_available());
            assert!(new_reactor(Backend::Epoll).is_ok());
        }

        #[test]
        fn epoll_reports_readable_when_bytes_arrive() {
            let (mut a, b) = pair();
            b.set_nonblocking(true).unwrap();
            let mut r = EpollReactor::new().unwrap();
            r.register(b.as_raw_fd(), 9, Interest::READABLE).unwrap();
            let mut events = Vec::new();
            // Nothing to read yet: the wait times out empty (and it
            // parked on readiness, not a sleep).
            let slept =
                r.wait(&mut events, Duration::from_millis(10)).unwrap();
            assert!(!slept);
            assert!(events.is_empty());
            a.write_all(b"ping").unwrap();
            let t0 = Instant::now();
            r.wait(&mut events, Duration::from_secs(5)).unwrap();
            // Readiness, not timeout: the wakeup must be immediate.
            assert!(t0.elapsed() < Duration::from_secs(1));
            assert_eq!(events.len(), 1);
            assert_eq!(events[0].token, 9);
            assert!(events[0].readable);
            let mut buf = [0u8; 8];
            let mut b2 = &b;
            assert_eq!(b2.read(&mut buf).unwrap(), 4);
        }

        #[test]
        fn epoll_writable_interest_and_reregister() {
            let (a, _b) = pair();
            a.set_nonblocking(true).unwrap();
            let mut r = EpollReactor::new().unwrap();
            // An idle socket with an empty send buffer is writable.
            r.register(a.as_raw_fd(), 1, Interest::WRITABLE).unwrap();
            let mut events = Vec::new();
            r.wait(&mut events, Duration::from_secs(5)).unwrap();
            assert!(events.iter().any(|e| e.token == 1 && e.writable));
            // Dropping write interest silences it again.
            r.reregister(a.as_raw_fd(), 1, Interest::READABLE).unwrap();
            let slept =
                r.wait(&mut events, Duration::from_millis(10)).unwrap();
            assert!(!slept);
            assert!(events.is_empty());
            r.deregister(a.as_raw_fd()).unwrap();
            assert!(r.deregister(a.as_raw_fd()).is_err());
        }

        #[test]
        fn epoll_reports_hangup_as_ready_everything() {
            let (a, b) = pair();
            b.set_nonblocking(true).unwrap();
            let mut r = EpollReactor::new().unwrap();
            r.register(b.as_raw_fd(), 5, Interest::READABLE).unwrap();
            drop(a);
            let mut events = Vec::new();
            r.wait(&mut events, Duration::from_secs(5)).unwrap();
            assert_eq!(events.len(), 1);
            assert!(events[0].readable && events[0].writable);
        }

        #[test]
        fn epoll_sub_millisecond_timeout_rounds_up_not_to_spin() {
            let mut r = EpollReactor::new().unwrap();
            let mut events = Vec::new();
            // No registrations: a 100 µs wait must still block ~1 ms,
            // not degrade into timeout=0 spinning.
            let t0 = Instant::now();
            r.wait(&mut events, Duration::from_micros(100)).unwrap();
            assert!(t0.elapsed() >= Duration::from_micros(100));
            assert!(events.is_empty());
        }
    }
}
