//! Threaded transport backend: bounded per-chunk channels between real
//! worker threads.  [`ring`] wires `W` endpoints so that endpoint `i`
//! sends to `i+1` and receives from `i-1`; each endpoint moves into
//! its worker thread and speaks [`super::exchange_hop`].
//!
//! Channels are bounded (`depth` chunks) so a fast encoder cannot run
//! unboundedly ahead of a slow decoder — backpressure, not buffering,
//! paces the pipeline, exactly like a NIC send queue.
//!
//! Failure modes mirror the TCP backend's: a dropped peer surfaces as
//! `Err` from `send`/`recv` (never a panic or a hang), and
//! [`ring_with_timeout`] adds a receive timeout so a peer that is
//! alive but silent fails the exchange the same way a stalled socket
//! does.

use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender,
};
use std::time::Duration;

use super::{ChunkMsg, Link};

/// One worker's view of the ring: a bounded sender to the downstream
/// neighbour and a receiver from the upstream neighbour.
pub struct ThreadedEndpoint {
    tx: SyncSender<ChunkMsg>,
    rx: Receiver<ChunkMsg>,
    /// `Some` ⇒ `recv` gives up after this long without a chunk.
    timeout: Option<Duration>,
}

impl Link for ThreadedEndpoint {
    fn send(&mut self, msg: ChunkMsg) -> Result<(), String> {
        self.tx
            .send(msg)
            .map_err(|_| "ring send: downstream peer hung up".to_string())
    }

    fn recv(&mut self) -> Result<ChunkMsg, String> {
        match self.timeout {
            None => self
                .rx
                .recv()
                .map_err(|_| "ring recv: upstream peer hung up".to_string()),
            Some(t) => match self.rx.recv_timeout(t) {
                Ok(msg) => Ok(msg),
                Err(RecvTimeoutError::Timeout) => Err(format!(
                    "ring recv: no chunk from upstream within {t:?} \
                     (peer stalled?)"
                )),
                Err(RecvTimeoutError::Disconnected) => {
                    Err("ring recv: upstream peer hung up".to_string())
                }
            },
        }
    }
}

/// Build the ring topology: endpoint `i` sends to `(i+1) % workers`.
/// `depth` is the per-link chunk buffer (must be ≥ 1 for the lockstep
/// exchange to make progress).  Receives block indefinitely; see
/// [`ring_with_timeout`] for the bounded-wait variant.
pub fn ring(workers: usize, depth: usize) -> Vec<ThreadedEndpoint> {
    wire_ring(workers, depth, None)
}

/// [`ring`] with a receive timeout per endpoint — the in-process
/// analogue of the TCP backend's progress timeout, so both transports
/// turn a stalled peer into the same `Err` instead of hanging.
pub fn ring_with_timeout(
    workers: usize,
    depth: usize,
    timeout: Duration,
) -> Vec<ThreadedEndpoint> {
    wire_ring(workers, depth, Some(timeout))
}

fn wire_ring(
    workers: usize,
    depth: usize,
    timeout: Option<Duration>,
) -> Vec<ThreadedEndpoint> {
    let depth = depth.max(1);
    let mut senders: Vec<Option<SyncSender<ChunkMsg>>> =
        (0..workers).map(|_| None).collect();
    let mut receivers: Vec<Option<Receiver<ChunkMsg>>> =
        (0..workers).map(|_| None).collect();
    for i in 0..workers {
        let (tx, rx) = sync_channel::<ChunkMsg>(depth);
        senders[i] = Some(tx);
        receivers[(i + 1) % workers] = Some(rx);
    }
    senders
        .into_iter()
        .zip(receivers)
        .map(|(tx, rx)| ThreadedEndpoint {
            // lint: infallible(the loop above fills every ring slot)
            tx: tx.expect("ring wiring"),
            rx: rx.expect("ring wiring"),
            timeout,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::exchange_hop;

    #[test]
    fn ring_routes_to_downstream_neighbour() {
        let endpoints = ring(3, 2);
        let mut joined = Vec::new();
        for (i, mut ep) in endpoints.into_iter().enumerate() {
            joined.push(std::thread::spawn(move || {
                let symbols = vec![i as u8; 64];
                let mut enc = None;
                let mut dec = None;
                let ex = exchange_hop(
                    &mut ep, &mut enc, &mut dec, &symbols, &[], 16,
                )
                .unwrap();
                // Worker i receives from worker (i + 2) % 3 upstream.
                let upstream = ((i + 3 - 1) % 3) as u8;
                assert_eq!(ex.symbols, vec![upstream; 64]);
            }));
        }
        for j in joined {
            j.join().unwrap();
        }
    }

    #[test]
    fn many_chunks_through_shallow_buffers_do_not_deadlock() {
        // 64 chunks per hop through depth-1 channels: the lockstep
        // alternation must stream them without deadlock.
        let w = 4;
        let endpoints = ring(w, 1);
        let mut joined = Vec::new();
        for (i, mut ep) in endpoints.into_iter().enumerate() {
            joined.push(std::thread::spawn(move || {
                let symbols: Vec<u8> =
                    (0..4096).map(|k| (k % 251) as u8 ^ i as u8).collect();
                let mut enc = None;
                let mut dec = None;
                let ex = exchange_hop(
                    &mut ep, &mut enc, &mut dec, &symbols, &[], 64,
                )
                .unwrap();
                assert_eq!(ex.symbols.len(), symbols.len());
            }));
        }
        for j in joined {
            j.join().unwrap();
        }
    }

    #[test]
    fn hung_up_peer_surfaces_as_error() {
        let mut endpoints = ring(2, 1);
        let b = endpoints.pop().unwrap();
        let mut a = endpoints.pop().unwrap();
        drop(b); // peer gone: its receiver and sender both drop
        let msg = ChunkMsg {
            seq: 0,
            last: true,
            n_symbols: 1,
            payload: vec![1],
            scales: Vec::new(),
        };
        assert!(a.send(msg).is_err());
        assert!(a.recv().is_err());
    }

    #[test]
    fn silent_but_alive_peer_times_out() {
        let mut endpoints =
            ring_with_timeout(2, 1, Duration::from_millis(40));
        // Endpoint b stays alive (channels open) but never sends.
        let _quiet = endpoints.pop().unwrap();
        let mut a = endpoints.pop().unwrap();
        let err = a.recv().unwrap_err();
        assert!(err.contains("no chunk"), "{err}");
    }
}
