//! Token-stepped fabric simulator: an in-memory FIFO [`SimLink`] plus
//! the [`HopTrace`] that replays measured per-chunk codec times against
//! a [`Fabric`] under the pipelined-hop recurrence (module docs of
//! [`crate::transport`]).

use std::collections::VecDeque;

use super::{ChunkMsg, Fabric, Link};

/// Measured stage times of one transport chunk.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChunkTiming {
    /// Encode wall time, seconds.
    pub encode_s: f64,
    /// Bytes this chunk puts on the wire (payload + any scale bytes).
    pub wire_bytes: usize,
    /// Decode wall time, seconds.
    pub decode_s: f64,
}

/// Per-chunk stage times of one hop, in chunk order.
#[derive(Clone, Debug, Default)]
pub struct HopTrace {
    pub chunks: Vec<ChunkTiming>,
}

impl HopTrace {
    pub fn push(&mut self, t: ChunkTiming) {
        self.chunks.push(t);
    }

    /// Attach the decode time for chunk `idx` (recorded when the chunk
    /// comes back off the link, which may lag its send).
    pub fn set_decode(&mut self, idx: usize, decode_s: f64) {
        match self.chunks.get_mut(idx) {
            Some(c) => c.decode_s += decode_s,
            // Peer sent more chunks than we did: account the decode
            // as its own stage entry so no time is dropped.
            None => self.chunks.push(ChunkTiming {
                encode_s: 0.0,
                wire_bytes: 0,
                decode_s,
            }),
        }
    }

    /// Total bytes on the wire across all chunks.
    pub fn wire_bytes(&self) -> u64 {
        self.chunks.iter().map(|c| c.wire_bytes as u64).sum()
    }

    /// Total codec (encode + decode) wall time, no overlap.
    pub fn codec_s(&self) -> f64 {
        self.chunks.iter().map(|c| c.encode_s + c.decode_s).sum()
    }

    /// Non-pipelined hop time: whole-payload encode, then one
    /// transfer, then whole-payload decode.
    pub fn serial_s(&self, fabric: &Fabric) -> f64 {
        fabric.wire_time(self.wire_bytes() as usize) + self.codec_s()
    }

    /// Pipelined hop time under the three-stage recurrence: encoder,
    /// link and decoder each process chunks in order; transfer of
    /// chunk `k+1` overlaps decode of chunk `k`.  Latency is charged
    /// once, on the first transfer.  Never exceeds [`Self::serial_s`]
    /// (up to float rounding).
    pub fn pipelined_s(&self, fabric: &Fabric) -> f64 {
        let mut enc_done = 0.0f64;
        let mut xfer_done = 0.0f64;
        let mut dec_done = 0.0f64;
        for (k, c) in self.chunks.iter().enumerate() {
            enc_done += c.encode_s;
            let latency = if k == 0 { fabric.link_latency } else { 0.0 };
            xfer_done = enc_done.max(xfer_done)
                + latency
                + c.wire_bytes as f64 / fabric.link_bandwidth;
            dec_done = xfer_done.max(dec_done) + c.decode_s;
        }
        dec_done
    }
}

/// In-memory FIFO link for the fabric simulator: `send` enqueues,
/// `recv` dequeues.  The simulator plays both endpoints of a hop, so
/// what comes back is this hop's own message after the encode/decode
/// round-trip; the caller delivers it to the downstream worker.
#[derive(Debug, Default)]
pub struct SimLink {
    queue: VecDeque<ChunkMsg>,
}

impl SimLink {
    pub fn new() -> Self {
        SimLink { queue: VecDeque::new() }
    }
}

impl Link for SimLink {
    fn send(&mut self, msg: ChunkMsg) -> Result<(), String> {
        self.queue.push_back(msg);
        Ok(())
    }

    fn recv(&mut self) -> Result<ChunkMsg, String> {
        self.queue
            .pop_front()
            .ok_or_else(|| "sim link: receive from empty queue".to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_link_is_fifo() {
        let mut link = SimLink::new();
        for seq in 0..3u32 {
            link.send(ChunkMsg {
                seq,
                last: seq == 2,
                n_symbols: 1,
                payload: vec![seq as u8],
                scales: Vec::new(),
            })
            .unwrap();
        }
        for seq in 0..3u32 {
            assert_eq!(link.recv().unwrap().seq, seq);
        }
        assert!(link.recv().is_err());
    }

    #[test]
    fn wire_bound_hop_hides_codec_time() {
        // Chunk wire time 10 µs dominates 1 µs codec stages: the
        // pipelined hop approaches pure wire time while the serial hop
        // pays wire + codec in full.
        let fabric =
            Fabric { workers: 2, link_bandwidth: 1e9, link_latency: 0.0 };
        let mut trace = HopTrace::default();
        let n = 32;
        for _ in 0..n {
            trace.push(ChunkTiming {
                encode_s: 1e-6,
                wire_bytes: 10_000, // 10 µs at 1 GB/s
                decode_s: 1e-6,
            });
        }
        let wire = fabric.wire_time(trace.wire_bytes() as usize);
        let pipelined = trace.pipelined_s(&fabric);
        let serial = trace.serial_s(&fabric);
        // Serial pays all 64 µs of codec; pipelined hides all but the
        // first encode and last decode behind the wire.
        assert!(serial >= wire + 63e-6, "{serial} vs {wire}");
        assert!(pipelined <= wire + 3e-6, "{pipelined} vs {wire}");
    }

    #[test]
    fn decode_for_unknown_chunk_still_counted() {
        let mut trace = HopTrace::default();
        trace.set_decode(5, 1e-3);
        assert_eq!(trace.chunks.len(), 1);
        assert!((trace.codec_s() - 1e-3).abs() < 1e-12);
    }
}
