//! Unified chunk-granular transport layer.
//!
//! The unit of transfer here is the *chunk*, not the payload: QLF2
//! chunks are byte-aligned and independently decodable, so a hop can
//! stream a message as a sequence of [`ChunkMsg`]s and the receiver
//! can decode chunk `k` while chunk `k+1` is still on the wire.  That
//! overlap is what turns "codec on the critical path" into "codec
//! hidden behind the wire" — the paper's motivating collective setting.
//!
//! Three backends implement the [`Link`] trait:
//!
//! * [`sim::SimLink`] — an in-memory FIFO driven by the token-stepped
//!   fabric simulator.  Per-chunk encode/decode wall times are recorded
//!   in a [`sim::HopTrace`], which replays them against a [`Fabric`]
//!   under the pipelined-hop time model (below).
//! * [`threaded::ThreadedEndpoint`] — real bounded channels between
//!   worker threads.  The same lockstep chunk exchange runs on real
//!   cores, and the overlap shows up as measured wall time instead of
//!   a model.
//! * [`net::TcpLink`] — real sockets between OS processes: the QWC1
//!   wire protocol over non-blocking TCP pairs, bootstrapped into a
//!   ring by [`net::form_ring`].  The same exchange again, now
//!   spanning hosts (`qlc worker` / `qlc launch`).
//!
//! Both backends speak the same hop protocol, [`exchange_hop`]: encode
//! chunk `k`, send it, receive and decode the peer's chunk `k`, repeat.
//! The strict send/receive alternation keeps bounded ring channels
//! deadlock-free (every endpoint holds at most one un-received chunk
//! per peer buffer slot), and it is exactly the schedule that lets
//! decode overlap transfer.
//!
//! # The pipelined-hop time model
//!
//! A hop ships `C` chunks through three serial resources — the
//! encoder, the link, the decoder — each of which processes chunks in
//! order.  With `e_k`, `t_k`, `d_k` the per-chunk stage times:
//!
//! ```text
//! enc_done[k]  = enc_done[k-1] + e_k
//! xfer_done[k] = max(enc_done[k], xfer_done[k-1]) + t_k   (+ latency, k = 0)
//! dec_done[k]  = max(xfer_done[k], dec_done[k-1]) + d_k
//! pipelined    = dec_done[C-1]
//! ```
//!
//! The non-pipelined ("serial") time is `latency + Σt + Σe + Σd` —
//! whole-payload encode, then transfer, then decode.  The recurrence
//! never exceeds it, and when the wire is the bottleneck the codec
//! terms vanish into the `max`: compression becomes free once
//! `e_k, d_k ≤ t_k`.  Benches report both numbers plus the overlap
//! savings `1 - pipelined/serial`.

pub mod net;
pub mod reactor;
pub mod sim;
pub mod threaded;

pub use net::{NetConfig, TcpLink};
pub use reactor::{Backend, Event, Interest, Reactor};
pub use sim::{ChunkTiming, HopTrace, SimLink};
pub use threaded::ThreadedEndpoint;

use std::time::Instant;

use crate::codecs::{chunk_spans, DecoderSession, EncoderSession};
use crate::obs;

/// Default transport chunk granularity, in symbols.  Small enough that
/// a megabyte-scale hop splits into several pipeline stages, large
/// enough that per-chunk overhead (one flush, one message) is noise.
pub const DEFAULT_TRANSPORT_CHUNK: usize = 16 * 1024;

/// Network model: a homogeneous ring of `workers` with identical
/// full-duplex links.  All links in a collective step run in parallel.
#[derive(Clone, Copy, Debug)]
pub struct Fabric {
    pub workers: usize,
    /// Per-link bandwidth, bytes/second.
    pub link_bandwidth: f64,
    /// Per-hop latency, seconds.
    pub link_latency: f64,
}

impl Fabric {
    /// Accelerator pod scale-out fabric: 50 GB/s per link (a 400 Gb/s
    /// NIC per direction), 2 µs per hop (switched RDMA-class fabric).
    pub fn pod(workers: usize) -> Self {
        Fabric { workers, link_bandwidth: 50e9, link_latency: 2e-6 }
    }

    /// Superpod scale-up domain: 450 GB/s per link (NVLink-generation
    /// point-to-point), 0.5 µs per hop (no NIC/switch traversal).
    pub fn superpod(workers: usize) -> Self {
        Fabric { workers, link_bandwidth: 450e9, link_latency: 5e-7 }
    }

    /// Commodity datacenter Ethernet: 12.5 GB/s per link (100 GbE),
    /// 10 µs per hop (kernel TCP stack + ToR switch).
    pub fn ethernet(workers: usize) -> Self {
        Fabric { workers, link_bandwidth: 12.5e9, link_latency: 10e-6 }
    }

    /// Resolve a preset by name (the CLI's `--fabric` vocabulary).
    pub fn preset(name: &str, workers: usize) -> Result<Fabric, String> {
        match name {
            "pod" => Ok(Fabric::pod(workers)),
            "superpod" => Ok(Fabric::superpod(workers)),
            "ethernet" => Ok(Fabric::ethernet(workers)),
            other => Err(format!(
                "unknown fabric preset '{other}' (expected one of {})",
                Fabric::preset_names().join("|")
            )),
        }
    }

    /// Names accepted by [`Fabric::preset`].
    pub fn preset_names() -> Vec<&'static str> {
        vec!["pod", "superpod", "ethernet"]
    }

    /// Serial wire time for `bytes` on one link.
    pub fn wire_time(&self, bytes: usize) -> f64 {
        self.link_latency + bytes as f64 / self.link_bandwidth
    }
}

/// One chunk of a hop's message.  Chunks are byte-aligned and
/// independently decodable; block scales ride with the first chunk.
#[derive(Clone, Debug)]
pub struct ChunkMsg {
    pub seq: u32,
    /// Final chunk of this hop's message.
    pub last: bool,
    /// Symbols encoded in `payload`.
    pub n_symbols: usize,
    pub payload: Vec<u8>,
    /// Per-block shared scales (first chunk only; empty otherwise).
    pub scales: Vec<f32>,
}

/// A chunk-granular duplex link endpoint: `send` ships one chunk to
/// the downstream peer, `recv` takes one chunk from the upstream peer.
pub trait Link {
    fn send(&mut self, msg: ChunkMsg) -> Result<(), String>;
    fn recv(&mut self) -> Result<ChunkMsg, String>;
}

/// Payload-only chunk encode (tables pre-shared apriori; paper §7).
/// `None` session means raw transport.  Sessions route through the
/// batched [`crate::codecs::EncodeKernel`] staging-word path (the
/// session default), so the encode half of every measured hop — and
/// therefore the `codec_time_s` the collectives report — runs the
/// batched encoder, mirroring [`decode_payload_into`].
pub fn encode_payload(
    enc: &mut Option<EncoderSession<'_>>,
    symbols: &[u8],
) -> Vec<u8> {
    match enc {
        None => symbols.to_vec(),
        Some(s) => s.encode_chunk_to_vec(symbols),
    }
}

/// Payload-only chunk decode appended to `out`; inverse of
/// [`encode_payload`].  Decodes straight into the destination's tail —
/// no intermediate buffer on the hot path.  Sessions route through the
/// batched [`crate::codecs::DecodeKernel`], so every per-chunk decode
/// time a [`HopTrace`] records — and therefore the `codec_time_s` the
/// pipelined-hop model and the TCP workers report — measures the
/// word-at-a-time kernel path, not the scalar reference decoder.
pub fn decode_payload_into(
    dec: &mut Option<DecoderSession<'_>>,
    payload: &[u8],
    n_symbols: usize,
    out: &mut Vec<u8>,
) -> Result<(), String> {
    match dec {
        None => {
            out.extend_from_slice(payload);
            Ok(())
        }
        Some(s) => {
            let len = out.len();
            out.resize(len + n_symbols, 0);
            s.decode_chunk(payload, &mut out[len..])
                .map_err(|e| format!("transport payload: {e}"))
        }
    }
}

/// Bytes on the wire for a hop: payload plus one byte per 32-symbol
/// block (E8M0-style shared scale, as in the OCP MX formats).
pub fn hop_bytes(payload_len: usize, n_blocks: usize) -> usize {
    payload_len + n_blocks
}

/// Everything one [`exchange_hop`] produced.
#[derive(Clone, Debug)]
pub struct HopExchange {
    /// Symbols received from the upstream peer.
    pub symbols: Vec<u8>,
    /// Scales received from the upstream peer.
    pub scales: Vec<f32>,
    /// Per-chunk stage timings of this endpoint (encode of the sent
    /// chunks, decode of the received ones) for the simulator's
    /// pipelined-hop model.
    pub trace: HopTrace,
    /// Bytes this endpoint put on the wire (payloads + scale bytes).
    pub wire_bytes: u64,
    /// Bytes the same hop would ship uncompressed.
    pub raw_bytes: u64,
}

/// Run one hop through a [`Link`]: stream `symbols` out as transport
/// chunks while receiving and decoding the peer's chunks.  The strict
/// send-one/receive-one alternation is deadlock-free on bounded ring
/// channels and is what lets decode of chunk `k` overlap the transfer
/// of chunk `k+1`.
///
/// On a [`SimLink`] the "peer" is the queue itself, so the returned
/// symbols are this hop's own message after an encode/decode
/// round-trip — exactly what the fabric simulator delivers downstream.
pub fn exchange_hop<L: Link>(
    link: &mut L,
    enc: &mut Option<EncoderSession<'_>>,
    dec: &mut Option<DecoderSession<'_>>,
    symbols: &[u8],
    scales: &[f32],
    chunk_symbols: usize,
) -> Result<HopExchange, String> {
    let mut spans = chunk_spans(symbols.len(), chunk_symbols);
    if spans.is_empty() {
        // Always ship at least a `last` marker so the peer terminates.
        spans.push((0, 0));
    }
    let n_out = spans.len();

    let mut trace = HopTrace::default();
    let mut wire_bytes = 0u64;
    let raw_bytes = (symbols.len() + scales.len()) as u64;
    let mut out_symbols: Vec<u8> = Vec::with_capacity(symbols.len());
    let mut out_scales: Vec<f32> = Vec::new();

    // Per-phase latency histograms + traffic counters on the global
    // registry; the per-chunk cost is a few relaxed atomic adds.
    let reg = obs::global();
    let encode_ns = reg.hist("transport_encode_ns");
    let decode_ns = reg.hist("transport_decode_ns");
    let wire_wait_ns = reg.hist("transport_wire_wait_ns");
    let chunks_sent = reg.counter("transport_chunks_sent_total");
    let chunks_recv = reg.counter("transport_chunks_recv_total");
    let wire_total = reg.counter("transport_wire_bytes_total");
    let raw_total = reg.counter("transport_raw_bytes_total");
    raw_total.add(raw_bytes);

    let mut sent = 0usize;
    let mut done_recv = false;
    while sent < n_out || !done_recv {
        if sent < n_out {
            let (a, b) = spans[sent];
            let _sp = obs::span("hop.encode").arg("seq", sent);
            let t0 = Instant::now();
            let payload = encode_payload(enc, &symbols[a..b]);
            let encode_s = t0.elapsed().as_secs_f64();
            drop(_sp);
            encode_ns.record((encode_s * 1e9) as u64);
            let first = sent == 0;
            let chunk_wire =
                hop_bytes(payload.len(), if first { scales.len() } else { 0 });
            wire_bytes += chunk_wire as u64;
            wire_total.add(chunk_wire as u64);
            trace.push(ChunkTiming {
                encode_s,
                wire_bytes: chunk_wire,
                decode_s: 0.0,
            });
            link.send(ChunkMsg {
                seq: sent as u32,
                last: sent + 1 == n_out,
                n_symbols: b - a,
                payload,
                scales: if first { scales.to_vec() } else { Vec::new() },
            })?;
            chunks_sent.inc();
            sent += 1;
        }
        if !done_recv {
            let wait = obs::Stopwatch::start();
            let sp = obs::span("hop.wire_wait");
            let msg = link.recv()?;
            drop(sp);
            wire_wait_ns.record(wait.elapsed_ns());
            chunks_recv.inc();
            let _sp = obs::span("hop.decode").arg("seq", msg.seq);
            let t0 = Instant::now();
            decode_payload_into(
                dec,
                &msg.payload,
                msg.n_symbols,
                &mut out_symbols,
            )?;
            let decode_s = t0.elapsed().as_secs_f64();
            drop(_sp);
            decode_ns.record((decode_s * 1e9) as u64);
            trace.set_decode(msg.seq as usize, decode_s);
            if msg.seq == 0 {
                out_scales = msg.scales;
            }
            if msg.last {
                done_recv = true;
            }
        }
    }
    Ok(HopExchange {
        symbols: out_symbols,
        scales: out_scales,
        trace,
        wire_bytes,
        raw_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::CodecRegistry;
    use crate::stats::Histogram;
    use crate::util::rng::{AliasTable, Rng};

    fn skewed(n: usize, seed: u64) -> Vec<u8> {
        let mut p = [0f64; 256];
        for (i, v) in p.iter_mut().enumerate() {
            *v = (-0.03 * i as f64).exp();
        }
        AliasTable::new(&p).sample_many(&mut Rng::new(seed), n)
    }

    #[test]
    fn presets_resolve_and_order_sensibly() {
        for name in Fabric::preset_names() {
            let f = Fabric::preset(name, 8).unwrap();
            assert_eq!(f.workers, 8, "{name}");
            assert!(f.link_bandwidth > 0.0 && f.link_latency > 0.0, "{name}");
        }
        assert!(Fabric::preset("infiniband9000", 4).is_err());
        // Faster fabric → strictly smaller wire time for the same bytes.
        let bytes = 1 << 20;
        let sp = Fabric::superpod(4).wire_time(bytes);
        let pod = Fabric::pod(4).wire_time(bytes);
        let eth = Fabric::ethernet(4).wire_time(bytes);
        assert!(sp < pod && pod < eth, "{sp} {pod} {eth}");
    }

    #[test]
    fn sim_exchange_roundtrips_symbols_and_scales() {
        let symbols = skewed(50_000, 1);
        let scales: Vec<f32> = (0..symbols.len() / 32)
            .map(|i| 1.0 + i as f32)
            .collect();
        let hist = Histogram::from_symbols(&symbols);
        let handle = CodecRegistry::global().resolve("qlc", &hist).unwrap();
        for chunk_symbols in [7usize, 4096, usize::MAX] {
            let mut enc = Some(handle.encoder());
            let mut dec = Some(handle.decoder());
            let mut link = SimLink::new();
            let ex = exchange_hop(
                &mut link,
                &mut enc,
                &mut dec,
                &symbols,
                &scales,
                chunk_symbols,
            )
            .unwrap();
            assert_eq!(ex.symbols, symbols, "chunk_symbols={chunk_symbols}");
            assert_eq!(ex.scales, scales);
            assert!(ex.wire_bytes > 0);
            assert_eq!(
                ex.raw_bytes,
                (symbols.len() + scales.len()) as u64
            );
        }
    }

    #[test]
    fn raw_exchange_is_identity_with_exact_byte_accounting() {
        let symbols = skewed(10_000, 2);
        let mut enc = None;
        let mut dec = None;
        let mut link = SimLink::new();
        let ex = exchange_hop(
            &mut link, &mut enc, &mut dec, &symbols, &[], 1024,
        )
        .unwrap();
        assert_eq!(ex.symbols, symbols);
        assert!(ex.scales.is_empty());
        // Raw transport ships exactly the symbols.
        assert_eq!(ex.wire_bytes, symbols.len() as u64);
        assert_eq!(ex.raw_bytes, symbols.len() as u64);
    }

    #[test]
    fn empty_hop_still_terminates() {
        let mut enc = None;
        let mut dec = None;
        let mut link = SimLink::new();
        let ex =
            exchange_hop(&mut link, &mut enc, &mut dec, &[], &[], 64).unwrap();
        assert!(ex.symbols.is_empty());
        assert_eq!(ex.wire_bytes, 0);
    }

    #[test]
    fn pipelined_time_never_exceeds_serial() {
        let fabric = Fabric::ethernet(4);
        let mut trace = HopTrace::default();
        for k in 0..16 {
            trace.push(ChunkTiming {
                encode_s: 1e-5 * (1 + k % 3) as f64,
                wire_bytes: 4096 + 17 * k,
                decode_s: 2e-5 * (1 + k % 2) as f64,
            });
        }
        let pipelined = trace.pipelined_s(&fabric);
        let serial = trace.serial_s(&fabric);
        assert!(
            pipelined <= serial * (1.0 + 1e-9),
            "{pipelined} > {serial}"
        );
        // With real codec work there must be genuine overlap.
        assert!(pipelined < serial, "{pipelined} !< {serial}");
        assert!(pipelined > 0.0);
    }

    #[test]
    fn single_chunk_pipeline_degenerates_to_serial() {
        let fabric = Fabric::pod(2);
        let mut trace = HopTrace::default();
        trace.push(ChunkTiming {
            encode_s: 1e-4,
            wire_bytes: 1 << 16,
            decode_s: 3e-4,
        });
        let pipelined = trace.pipelined_s(&fabric);
        let serial = trace.serial_s(&fabric);
        assert!((pipelined - serial).abs() <= serial * 1e-9);
    }
}
